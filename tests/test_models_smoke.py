"""Per-architecture smoke tests: reduced same-family configs, one forward /
train-loss / decode step on CPU, asserting output shapes and no NaNs.

The FULL assigned configs are exercised only via the dry-run
(ShapeDtypeStruct, no allocation) — see launch/dryrun.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import build_model, split_tree
from repro.models.transformer import _pad_cache_seq

ALL_ARCHS = sorted(ARCHS)


def _batch_for(cfg, b=2, s=32, seed=1):
    tokens = jax.random.randint(jax.random.key(seed), (b, s), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.family == "encdec":
        batch["enc"] = jax.random.normal(
            jax.random.key(seed + 1), (b, cfg.encoder_seq, cfg.d_model)) * 0.02
    if cfg.family == "vlm":
        batch["enc"] = jax.random.normal(
            jax.random.key(seed + 2), (b, cfg.vision_seq, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_tiny_train_loss(name):
    cfg = get_arch(name).tiny()
    m = build_model(cfg)
    prm, _ = split_tree(m.init_params(jax.random.key(0)))
    batch = _batch_for(cfg)
    loss, metrics = jax.jit(m.loss)(prm, batch)
    assert np.isfinite(float(loss)), f"{name} loss NaN"
    # untrained CE should be near ln(V)
    assert abs(float(metrics["ce"]) - np.log(cfg.vocab_size)) < 1.5


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_tiny_prefill_and_decode(name):
    cfg = get_arch(name).tiny()
    m = build_model(cfg)
    prm, _ = split_tree(m.init_params(jax.random.key(0)))
    b, s, cap = 2, 16, 32
    batch = _batch_for(cfg, b=b, s=s)
    logits, part_cache = jax.jit(m.prefill)(prm, batch)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()

    cache, _ = split_tree(m.init_cache(b, cap))
    cache = _pad_cache_seq(cache, part_cache)
    pos = jnp.full((b,), s, jnp.int32)
    tok = batch["tokens"][:, -1:]
    enc = batch.get("enc")
    logits2, cache2 = jax.jit(m.decode_step)(prm, cache, tok, pos, enc)
    assert logits2.shape == (b, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2)).all()
    # cache must actually change
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(bb))
        for a, bb in zip(jax.tree.leaves(cache), jax.tree.leaves(cache2))
    )
    assert changed, f"{name} decode did not update cache"


@pytest.mark.parametrize("name", ["olmo-1b", "mamba2-2.7b", "gemma3-12b",
                                  "zamba2-2.7b", "h2o-danube-3-4b"])
def test_decode_matches_prefill(name):
    """Incremental decode from scratch reproduces the full-seq forward."""
    cfg = get_arch(name).tiny()
    m = build_model(cfg)
    prm, _ = split_tree(m.init_params(jax.random.key(0)))
    b, s = 2, 8
    batch = _batch_for(cfg, b=b, s=s)
    ref_logits, _ = jax.jit(m.prefill)(prm, batch)

    cache, _ = split_tree(m.init_cache(b, s))
    step = jax.jit(m.decode_step)
    pos0 = jnp.zeros((b,), jnp.int32)
    logits = None
    for t in range(s):
        logits, cache = step(prm, cache, batch["tokens"][:, t : t + 1],
                             pos0 + t, batch.get("enc"))
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(ref_logits), rtol=2e-2, atol=2e-3)


def test_param_count_matches_analytic():
    """init params ≈ ArchConfig.n_params on a tiny config (same formula path)."""
    for name in ("olmo-1b", "phi3.5-moe-42b-a6.6b"):
        cfg = get_arch(name).tiny()
        m = build_model(cfg)
        prm, _ = split_tree(m.init_params(jax.random.key(0)))
        actual = sum(x.size for x in jax.tree.leaves(prm))
        approx = cfg.n_params()
        assert abs(actual - approx) / max(actual, 1) < 0.25, (name, actual, approx)
