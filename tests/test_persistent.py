"""Persistent multi-step traversal: registry, bit-parity with the
single-step pallas backend across codecs and modes, launch-boundary
resume round-trips, steps_per_launch invariance, scheduler integration,
and interpret-mode parity of the VMEM-resident multi-step kernel."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (BIG_BUDGET, SearchConfig, SearchEngine,
                        available_backends, get_backend)
from repro.data import make_dataset, make_label_workload
from repro.index import build_graph_index

QCFG = dict(pq_subspaces=8, pq_centroids=32, pq_iters=8)


@pytest.fixture(scope="module")
def world():
    ds = make_dataset(n=2000, dim=24, n_clusters=6, alphabet_size=32, seed=0)
    graph = build_graph_index(ds.vectors, degree=16, seed=0)
    engines = {
        p: SearchEngine.build(ds, graph, precision=p, quant_cfg=QCFG)
        for p in ("float32", "int8", "pq")
    }
    return ds, graph, engines


def _workload(ds, batch=13, seed=3):
    # odd batch: the driver's power-of-two compaction ladder must pad
    wl = make_label_workload(ds, batch=batch, kind="contain", seed=seed)
    return wl, SearchConfig(k=5, queue_size=64)


def _assert_states_equal(a, b, quantized=False):
    """Exact equality on every field. For quantized codecs the two float
    distance fields are compared to the repo's standard kernel-vs-host
    tolerance instead: lane compaction changes the batch width per launch,
    and XLA:CPU contracts the int8-ADC/PQ-LUT reductions differently at
    different widths (the same ULP-level FMA effect test_quant pins for
    kernel vs dense). Ids, counters, visited bits stay exact."""
    float_fields = ("cand_dist", "res_dist", "q_err_sum", "d_start")
    for f in a._fields:
        x, y = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        if quantized and f in float_fields:
            np.testing.assert_array_equal(
                np.isfinite(x), np.isfinite(y),
                err_msg=f"SearchState field {f!r} finite pattern")
            fin = np.isfinite(x)
            np.testing.assert_allclose(x[fin], y[fin], rtol=1e-5, atol=1e-5,
                                       err_msg=f"SearchState field {f!r}")
        else:
            np.testing.assert_array_equal(
                x, y, err_msg=f"SearchState field {f!r} diverged")


# ------------------------------------------------------------- registry ----
def test_registry_has_persistent():
    assert "pallas_persistent" in available_backends()
    bk = get_backend("pallas_persistent")
    assert getattr(bk, "persistent", False) is True
    # single-step backends must not accidentally grow the flag
    assert not getattr(get_backend("pallas"), "persistent", False)
    assert not getattr(get_backend("dense"), "persistent", False)


# --------------------------------------------------------------- parity ----
@pytest.mark.parametrize("mode", ["post", "pre", "widen"])
@pytest.mark.parametrize("precision", ["float32", "int8", "pq"])
def test_persistent_matches_pallas_every_field(world, mode, precision):
    """The acceptance bar: every SearchState field identical to the
    single-step pallas backend (quant distance fields to the standard
    tolerance, see _assert_states_equal), for all three codecs × all three
    traversal modes, under heterogeneous per-lane budgets (so lanes
    terminate at different launches and the compaction ladder engages)."""
    ds, _, engines = world
    eng = engines[precision]
    wl, cfg = _workload(ds)
    cfg = dataclasses.replace(cfg, mode=mode)
    buds = np.random.default_rng(0).integers(40, 900, size=wl.batch)
    buds = buds.astype(np.int32)
    a = eng.search(dataclasses.replace(cfg, backend="pallas"),
                   wl.queries, wl.spec, buds)
    b = eng.search(dataclasses.replace(cfg, backend="pallas_persistent"),
                   wl.queries, wl.spec, buds)
    _assert_states_equal(a, b, quantized=precision != "float32")


@pytest.mark.parametrize("spl", [1, 3, 8, 64])
def test_steps_per_launch_invariance(world, spl):
    """The launch width is a dispatch knob, not a semantic one."""
    ds, _, engines = world
    eng = engines["float32"]
    wl, cfg = _workload(ds)
    ref = eng.search(dataclasses.replace(cfg, backend="pallas"),
                     wl.queries, wl.spec, 700)
    out = eng.search(
        dataclasses.replace(cfg, backend="pallas_persistent",
                            steps_per_launch=spl),
        wl.queries, wl.spec, 700)
    _assert_states_equal(ref, out)


def test_greedy_stop_parity(world):
    ds, _, engines = world
    eng = engines["float32"]
    wl, cfg = _workload(ds)
    cfg = dataclasses.replace(cfg, greedy_stop=True)
    a = eng.search(dataclasses.replace(cfg, backend="pallas"),
                   wl.queries, wl.spec, BIG_BUDGET)
    b = eng.search(dataclasses.replace(cfg, backend="pallas_persistent"),
                   wl.queries, wl.spec, BIG_BUDGET)
    _assert_states_equal(a, b)


def test_max_steps_cutoff_parity(world):
    """max_steps accounting across launches == the flat loop's cutoff,
    including cutoffs that land mid-launch."""
    ds, _, engines = world
    eng = engines["float32"]
    wl, cfg = _workload(ds)
    for max_steps in (1, 5, 17):
        c = dataclasses.replace(cfg, max_steps=max_steps)
        a = eng.search(dataclasses.replace(c, backend="pallas"),
                       wl.queries, wl.spec, BIG_BUDGET)
        b = eng.search(dataclasses.replace(c, backend="pallas_persistent"),
                       wl.queries, wl.spec, BIG_BUDGET)
        _assert_states_equal(a, b)


# ------------------------------------------------------ probe / resume ----
def test_probe_resume_roundtrip_at_launch_boundaries(world):
    """A probe stopped anywhere (budget boundaries ≠ launch boundaries)
    resumes bit-exactly — the launch grouping must exit with a full
    SearchState at whatever step the budget landed on."""
    ds, _, engines = world
    eng = engines["float32"]
    wl, cfg = _workload(ds)
    cfg = dataclasses.replace(cfg, backend="pallas_persistent",
                              steps_per_launch=8)
    one = eng.search(cfg, wl.queries, wl.spec, 700)
    st = eng.search(cfg, wl.queries, wl.spec, 120)  # mid-launch budgets
    st = eng.search(cfg, wl.queries, wl.spec, 700, state=st)
    _assert_states_equal(one, st)


@pytest.mark.parametrize("precision", ["int8", "pq"])
def test_cross_backend_resume(world, precision):
    """Persistent probe → single-step resume (and the reverse): the carry
    is one bit-compatible SearchState, so the serving layer may mix
    backends across slices."""
    ds, _, engines = world
    eng = engines[precision]
    wl, cfg = _workload(ds)
    cp = dataclasses.replace(cfg, backend="pallas_persistent")
    cs = dataclasses.replace(cfg, backend="pallas")
    one = eng.search(cs, wl.queries, wl.spec, 700)
    st = eng.search(cp, wl.queries, wl.spec, 120)
    st = eng.search(cs, wl.queries, wl.spec, 700, state=st)
    _assert_states_equal(one, st, quantized=True)
    st = eng.search(cs, wl.queries, wl.spec, 120)
    st = eng.search(cp, wl.queries, wl.spec, 700, state=st)
    _assert_states_equal(one, st, quantized=True)


def test_run_search_donation_does_not_copy_semantics(world):
    """Donated resume: the returned state is correct and the donated carry
    is consumed (reusing it raises on CPU) — callers pass fresh slices."""
    ds, _, engines = world
    eng = engines["float32"]
    wl, cfg = _workload(ds)
    cfg = dataclasses.replace(cfg, backend="pallas")
    one = eng.search(cfg, wl.queries, wl.spec, 700)
    st = eng.search(cfg, wl.queries, wl.spec, 120)
    keep = jax.tree.map(jnp.copy, st)
    out = eng.search(cfg, wl.queries, wl.spec, 700, state=st)
    _assert_states_equal(one, out)
    with pytest.raises(RuntimeError):
        np.asarray(st.cnt)  # donated buffer is gone
    out2 = eng.search(cfg, wl.queries, wl.spec, 700, state=keep)
    _assert_states_equal(one, out2)


# ------------------------------------------------------------ scheduler ----
def test_scheduled_equals_oneshot_persistent(world):
    """Scheduling on a persistent engine stays bit-invisible, and the
    metrics record launch amortization + early-exit lane fractions."""
    from repro.core import CostEstimator, e2e_search, generate_training_data
    from repro.serve import (CostAwareScheduler, ServeConfig,
                             requests_from_workload)

    ds, graph, engines = world
    engine = SearchEngine.build(ds, graph, backend="pallas_persistent")
    cfg = SearchConfig(k=5, queue_size=64)
    wl_tr = make_label_workload(ds, batch=96, kind="contain", seed=7)
    td = generate_training_data(engine, ds, wl_tr, cfg, probe_budget=48,
                                chunk=48)
    est = CostEstimator.fit(td.features, td.w_q, n_trees=40, depth=4)

    wl = make_label_workload(ds, batch=12, kind="contain", seed=42)
    one = e2e_search(engine, est, cfg, wl.queries, wl.spec, probe_budget=48,
                     alpha=1.5)
    scfg = ServeConfig(lane_width=8, buckets=(128, 512, None),
                       probe_budget=48, alpha=1.5, cache_capacity=0)
    sched = CostAwareScheduler(engine, est, cfg, scfg)
    reqs = requests_from_workload(wl)
    for r in reqs:
        assert sched.submit(r, 0.0) == "queued"
    sched.run_until_idle(0.0)
    reqs.sort(key=lambda r: r.rid)
    np.testing.assert_array_equal(
        np.stack([r.res_idx for r in reqs]), np.asarray(one.state.res_idx))
    np.testing.assert_array_equal(
        np.asarray([r.ndc for r in reqs]), np.asarray(one.state.cnt))

    summ = sched.metrics.summary()
    probe = summ["batches_by_phase"]["probe"]
    # a persistent engine amortizes: strictly fewer launches than steps.
    # Launch counts are driver-observed dispatches (core.search dispatch
    # counters), never fewer than the ⌈steps/spl⌉ lower bound — a probe
    # dispatches once per snapshot and compaction relaunches add more.
    spl = max(1, cfg.steps_per_launch)
    probe_steps = [b["steps"] for b in sched.metrics.batches
                   if b["phase"] == "probe"]
    assert probe["launches"] >= sum(-(-s // spl) for s in probe_steps)
    assert 0 < probe["launches"] < sum(probe_steps)  # amortization is real
    assert 0.0 <= probe["early_exit_frac"] <= 1.0


# --------------------------------------------- interpret-mode kernel ----
@pytest.mark.parametrize("precision", ["float32", "int8", "pq"])
def test_persistent_kernel_interpret_parity(precision):
    """The VMEM-resident multi-step kernel vs U host single-steps, in
    Pallas interpret mode. float32 is fully bit-exact; compressed codecs
    pin ids/counters/visited exactly and distances to the repo's standard
    kernel-vs-host tolerance (XLA contracts FMAs differently between the
    two graphs). Micro sizes keep the unrolled bitonic networks (width 16)
    and the per-lane DMA unroll within XLA:CPU's compile budget."""
    from repro.core.state import init_state
    from repro.core.step import make_step
    from repro.filters import FilterSpec
    from repro.filters.compile import compile_spec
    from repro.filters.predicates import PRED_RANGE
    from repro.kernels.persistent_step import (build_persistent_operands,
                                               persistent_multi_step)
    from repro.quant.codecs import build_quant_index, prepare_query

    n, dim, r, b, m, k, u = 256, 8, 8, 8, 8, 4, 6
    rng = np.random.default_rng(0)
    vecs = jnp.asarray(rng.normal(size=(n, dim)).astype(np.float32))
    nbrs = rng.integers(0, n, size=(n, r)).astype(np.int32)
    self_loop = nbrs == np.arange(n)[:, None]
    nbrs[self_loop] = (nbrs[self_loop] + 1) % n
    nbrs = jnp.asarray(nbrs)
    labels = jnp.asarray(rng.integers(0, 2 ** 16, size=(n, 1)).astype(np.uint32))
    values = jnp.asarray(rng.random((n, 1)).astype(np.float32))
    queries = jnp.asarray(rng.normal(size=(b, dim)).astype(np.float32))
    spec = FilterSpec(PRED_RANGE, None, np.full(b, 0.2, np.float32),
                      np.full(b, 0.9, np.float32))
    prog = jax.tree.map(jnp.asarray, compile_spec(spec, 1))
    budgets = jnp.asarray(rng.integers(20, 120, size=(b,)).astype(np.int32))
    gt = jnp.asarray(np.sort(rng.random((b, k)).astype(np.float32), axis=1))

    cfg = SearchConfig(k=k, queue_size=m, degree=r, mode="post",
                       precision=None if precision == "float32" else precision)
    quant = qprep = None
    if precision != "float32":
        quant = build_quant_index(precision, vecs, pq_subspaces=4,
                                  pq_centroids=16, pq_levels=1)
        qprep = prepare_query(precision, quant, queries)
    st0 = init_state(cfg, queries, prog, vecs, (labels, values), 0,
                     quant=quant, qprep=qprep)
    step = make_step(cfg, get_backend("pallas"), queries, prog, vecs,
                     (labels, values), nbrs, budgets, gt, quant=quant,
                     qprep=qprep)
    host = st0
    for _ in range(u):
        host = step(host)

    rows, aux = build_persistent_operands(precision, vecs, labels, values,
                                          quant)
    kern = persistent_multi_step(cfg, queries, prog, rows, aux, nbrs,
                                 budgets, st0, jnp.int32(10 ** 6), gt, qprep,
                                 steps=u, n_values=1, has_gt=True,
                                 interpret=True, block_b=4)
    for f in st0._fields:
        a, b_ = np.asarray(getattr(host, f)), np.asarray(getattr(kern, f))
        if precision != "float32" and f in ("cand_dist", "res_dist"):
            np.testing.assert_array_equal(np.isfinite(a), np.isfinite(b_),
                                          err_msg=f"{f} finite pattern")
            fin = np.isfinite(a)
            np.testing.assert_allclose(a[fin], b_[fin], rtol=1e-5,
                                       atol=1e-5, err_msg=f)
        else:
            np.testing.assert_array_equal(a, b_, err_msg=f)
