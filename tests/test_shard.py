"""Index-axis sharding: merge correctness, parity with per-shard reference
searches, elastic mesh shapes, tiering, and the e2e/planner/serve stack on a
sharded engine.

The parity tests pin the sharded contract (core/sharded.py docstring):

  loop path  — bit-identical to independent per-shard searches followed by
               a host lexsort merge of the pools under (dist, pos), with
               exact integer counter sums, at every precision.
  mesh path  — bit-identical to the loop path at float32 (subprocess test
               with a forced multi-device host platform); quantized
               distances agree within 1 ulp (XLA:CPU SPMD FMA-contraction
               caveat) with identical candidate ids and exact counters.
"""
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from _hyp_compat import given, settings, st  # hypothesis or fallback

import jax.numpy as jnp

from repro.core import SearchConfig, SearchEngine, ShardedSearchEngine
from repro.data import make_dataset, make_label_workload
from repro.distributed.fault_tolerance import best_search_mesh_shape
from repro.distributed.merge import merge_stacked
from repro.index import build_graph_index
from repro.index.builder import build_sharded_graph_index
from repro.index.graph import GraphIndex


# ------------------------------------------------------------- mesh shapes ----

def test_best_search_mesh_shape():
    """Index axis = largest common divisor of devices and shards; the rest
    goes to batch. Indivisible counts degrade to index=1, never wedge."""
    assert best_search_mesh_shape(6, 4) == ((3, 2), ("data", "index"))
    assert best_search_mesh_shape(7, 4) == ((7, 1), ("data", "index"))
    assert best_search_mesh_shape(8, 4) == ((2, 4), ("data", "index"))
    assert best_search_mesh_shape(4, 6) == ((2, 2), ("data", "index"))
    assert best_search_mesh_shape(1, 1) == ((1, 1), ("data", "index"))
    assert best_search_mesh_shape(4, 1) == ((4, 1), ("data", "index"))
    with pytest.raises(ValueError):
        best_search_mesh_shape(0, 4)
    with pytest.raises(ValueError):
        best_search_mesh_shape(4, 0)


# --------------------------------------------------------- graph validation ----

def test_graph_validate_names_offending_shard():
    """A neighbor id >= n_s in a shard slice is a cross-shard edge — the
    error must carry the shard ordinal and global row range."""
    nb = np.zeros((8, 2), np.int32)
    nb[0] = [1, 2]
    nb[5] = [9, -1]  # >= n: global id leaked into a shard-local slice
    g = GraphIndex(neighbors=nb, entry_point=0, dim=4, shard=2, offset=16)
    with pytest.raises(ValueError) as ei:
        g.validate()
    msg = str(ei.value)
    assert "shard 2" in msg and "[16, 24)" in msg and "global 21" in msg


def test_sharded_graph_builder_rejects_indivisible():
    ds = make_dataset(n=130, dim=8, n_clusters=2, alphabet_size=8, seed=0)
    with pytest.raises(ValueError):
        build_sharded_graph_index(ds.vectors, 4, degree=4, seed=0)


# ---------------------------------------------------------- merge property ----

@settings(max_examples=40, deadline=None)
@given(st.integers(1, 4), st.integers(1, 8), st.integers(1, 6),
       st.integers(1, 12), st.integers(0, 2**31 - 1))
def test_merge_stacked_matches_host_lexsort(b, w, s, m, seed):
    """The log-depth merge tree == a flat host lexsort of the concatenated
    pools under (dist, pos) — ties included (distances drawn from 4 values
    so collisions are the norm, resolved by the unique position lane)."""
    rng = np.random.default_rng(seed)
    dists = np.sort(rng.integers(0, 4, (b, s, w)).astype(np.float32), axis=2)
    # sprinkle INF pads like real part-filled pools (payload -1)
    pad = rng.random((b, s, w)) < 0.2
    dists[pad] = np.inf
    dists = np.sort(dists, axis=2)
    pays = rng.integers(0, 1000, (b, s, w)).astype(np.int32)
    pays[np.isinf(dists)] = -1
    m = min(m, s * w)

    d, p, o = merge_stacked(jnp.asarray(dists), jnp.asarray(pays), m)
    d, p, o = np.asarray(d), np.asarray(p), np.asarray(o)

    pos = np.broadcast_to(
        (np.arange(s)[:, None] * w + np.arange(w))[None], (b, s, w))
    fd, fo = dists.reshape(b, -1), np.ascontiguousarray(pos.reshape(b, -1))
    fp = pays.reshape(b, -1)
    for i in range(b):
        order = np.lexsort((fo[i], fd[i]))[:m]
        assert np.array_equal(d[i], fd[i][order]), (i, d[i], fd[i][order])
        assert np.array_equal(o[i], fo[i][order])
        assert np.array_equal(p[i], fp[i][order])


# ------------------------------------------------------------ parity matrix ----

def _host_merge_res(states, offsets, k):
    """Reference cross-shard merge of the per-shard result pools: flat
    numpy lexsort by (dist, pos), pos = shard * k + slot."""
    s = len(states)
    b = states[0].res_dist.shape[0]
    dist = np.stack([np.asarray(st.res_dist) for st in states], axis=1)
    idx = np.stack([np.asarray(st.res_idx) for st in states], axis=1)
    gidx = np.where(idx >= 0, idx + np.asarray(offsets)[None, :, None], -1)
    pos = np.broadcast_to(
        (np.arange(s)[:, None] * k + np.arange(k))[None], (b, s, k))
    out_d = np.empty((b, k), np.float32)
    out_i = np.empty((b, k), np.int32)
    for q in range(b):
        order = np.lexsort((pos[q].ravel(), dist[q].ravel()))[:k]
        out_d[q] = dist[q].ravel()[order]
        out_i[q] = gidx[q].ravel()[order]
    return out_d, out_i


@pytest.fixture(scope="module")
def shard_ds():
    ds = make_dataset(n=512, dim=8, n_clusters=4, alphabet_size=16, seed=0)
    wl = make_label_workload(ds, batch=9, kind="contain", seed=3)
    return ds, wl


def _sg2(ds):
    return build_sharded_graph_index(np.asarray(ds.vectors), 2, degree=8,
                                     seed=0)


@pytest.mark.parametrize("n_shards", [2, 4])
@pytest.mark.parametrize("precision", ["float32", "int8", "pq"])
def test_sharded_matches_per_shard_reference(shard_ds, n_shards, precision):
    """Loop-path sharded search (traverse + widen) == independent per-shard
    searches + host lexsort merge; merged counters are exact sums."""
    ds, wl = shard_ds
    qcfg = ({"pq_subspaces": 4, "train_sample_size": 256}
            if precision == "pq" else {"train_sample_size": 256})
    sg = build_sharded_graph_index(np.asarray(ds.vectors), n_shards,
                                   degree=8, seed=0)
    eng = ShardedSearchEngine.build(ds, sg, mesh=None, precision=precision,
                                    quant_cfg=None if precision == "float32"
                                    else qcfg)
    for mode in ("post", "widen"):
        cfg = SearchConfig(k=5, queue_size=32, pred_kind=0, mode=mode)
        budget = 300
        out = eng.search(cfg, wl.queries, wl.spec, budget)

        sbud = -(-budget // n_shards)
        parts = [sh.search(cfg, wl.queries, wl.spec, sbud)
                 for sh in eng.shards]
        rd, ri = _host_merge_res(parts, eng.offsets, cfg.k)
        assert np.array_equal(np.asarray(out.res_dist), rd), (mode, precision)
        assert np.array_equal(np.asarray(out.res_idx), ri)
        for f in ("cnt", "n_inspected", "hops", "n_clause_valid"):
            want = sum(np.asarray(getattr(p, f), np.int64) for p in parts)
            assert np.array_equal(np.asarray(getattr(out, f), np.int64),
                                  want), (mode, precision, f)
        assert np.array_equal(
            np.asarray(out.active),
            np.any(np.stack([np.asarray(p.active) for p in parts]), axis=0))


def test_single_shard_engine_is_the_plain_engine(shard_ds):
    """S=1 anchor: a 1-shard sharded engine is bitwise the unsharded one
    (merge of one pool is the identity)."""
    ds, wl = shard_ds
    graph = build_graph_index(ds.vectors, degree=8, seed=0)
    plain = SearchEngine.build(ds, graph, mesh=None)
    shard1 = ShardedSearchEngine.build(
        ds, build_sharded_graph_index(np.asarray(ds.vectors), 1, degree=8,
                                      seed=0), mesh=None)
    cfg = SearchConfig(k=5, queue_size=32, pred_kind=0)
    a = plain.search(cfg, wl.queries, wl.spec, 400)
    b = shard1.search(cfg, wl.queries, wl.spec, 400)
    for f in ("res_idx", "res_dist", "cnt", "cand_idx", "cand_dist",
              "n_inspected", "d_start"):
        assert np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f))), f


@pytest.mark.parametrize("n_shards", [2, 4])
@pytest.mark.parametrize("precision", ["float32", "int8", "pq"])
def test_sharded_scan_matches_unsharded(shard_ds, n_shards, precision):
    """The scan plan is an exact filtered brute force — sharding must not
    change its results or its NDC accounting at any precision (shard codecs
    train on the same global sample as the unsharded engine's, so the
    compressed scan metric is identical)."""
    from repro.core.plans import scan_search

    ds, wl = shard_ds
    qcfg = (None if precision == "float32"
            else {"pq_subspaces": 4, "train_sample_size": 256}
            if precision == "pq" else {"train_sample_size": 256})
    graph = build_graph_index(ds.vectors, degree=8, seed=0)
    plain = SearchEngine.build(ds, graph, mesh=None, precision=precision,
                               quant_cfg=None if qcfg is None
                               else dict(qcfg))
    sg = build_sharded_graph_index(np.asarray(ds.vectors), n_shards,
                                   degree=8, seed=0)
    eng = ShardedSearchEngine.build(ds, sg, mesh=None, precision=precision,
                                    quant_cfg=None if qcfg is None
                                    else dict(qcfg))
    cfg = SearchConfig(k=5, queue_size=32, pred_kind=0)
    a = scan_search(plain, cfg, wl.queries, wl.spec)
    b = scan_search(eng, cfg, wl.queries, wl.spec)
    assert np.array_equal(np.asarray(a.res_dist), np.asarray(b.res_dist))
    assert np.array_equal(np.asarray(a.res_idx), np.asarray(b.res_idx))
    assert np.array_equal(np.asarray(a.cnt), np.asarray(b.cnt))


def test_probe_resume_parity(shard_ds):
    """probe → resume on the sharded engine == one direct search at the
    final budget (the resume-exactness contract, now across shards)."""
    ds, wl = shard_ds
    eng = ShardedSearchEngine.build(ds, _sg2(ds), mesh=None)
    cfg = SearchConfig(k=5, queue_size=32, pred_kind=0)
    direct = eng.search(cfg, wl.queries, wl.spec, 400)
    st = eng.search(cfg, wl.queries, wl.spec, 60)
    st = eng.search(cfg, wl.queries, wl.spec, 400, state=st)
    for f in ("res_idx", "res_dist", "cnt", "cand_idx"):
        assert np.array_equal(np.asarray(getattr(direct, f)),
                              np.asarray(getattr(st, f))), f


# ----------------------------------------------------------------- tiering ----

def test_host_tier_rerank_bitwise_matches_device_tier(shard_ds):
    """Same compressed traversal + same exact float32 rerank whether the
    rerank vectors live on device or in host memory."""
    ds, wl = shard_ds
    qcfg = {"train_sample_size": 256}
    cfg = SearchConfig(k=5, queue_size=32, pred_kind=0)
    outs = {}
    for tier in ("device", "host"):
        eng = ShardedSearchEngine.build(
            ds, _sg2(ds), mesh=None, precision="int8",
            quant_cfg=dict(qcfg), tier=tier)
        st = eng.search(cfg, wl.queries, wl.spec, 300)
        outs[tier] = eng.rerank(cfg, wl.queries, st)
    for f in ("res_idx", "res_dist"):
        assert np.array_equal(np.asarray(getattr(outs["device"], f)),
                              np.asarray(getattr(outs["host"], f))), f


def test_float32_traversal_on_compressed_engine_raises(shard_ds):
    ds, _ = shard_ds
    eng = ShardedSearchEngine.build(
        ds, _sg2(ds), mesh=None, precision="int8",
        quant_cfg={"train_sample_size": 256}, tier="host")
    wl = make_label_workload(ds, batch=4, kind="contain", seed=1)
    cfg = SearchConfig(k=5, queue_size=32, pred_kind=0, precision="float32")
    with pytest.raises(ValueError, match="float32 traversal"):
        eng.search(cfg, wl.queries, wl.spec, 100)


# -------------------------------------------------- e2e / planner / serve ----

def test_e2e_planner_serve_on_sharded_engine(shard_ds):
    """The adaptive pipeline runs unchanged on a sharded engine: training
    data, estimator fit, e2e_search with EXPLAIN, planner routing, and the
    serving scheduler's shard-layout telemetry."""
    from repro.core.e2e import e2e_search
    from repro.core.estimator import CostEstimator
    from repro.core.training import generate_training_data
    from repro.serve.scheduler import CostAwareScheduler, ServeConfig

    ds, wl = shard_ds
    eng = ShardedSearchEngine.build(ds, _sg2(ds), mesh=None)
    assert eng.n_shards == 2 and eng.is_sharded
    cfg = SearchConfig(k=5, queue_size=32, pred_kind=0)
    td = generate_training_data(eng, ds, wl, cfg, probe_budget=32, chunk=16)
    assert td.features.shape[0] == wl.batch
    est = CostEstimator.fit(td.features, td.w_q, n_trees=8, depth=3)
    res = e2e_search(eng, est, cfg, wl.queries, wl.spec, probe_budget=32,
                     explain=True)
    assert res.state.res_idx.shape == (wl.batch, cfg.k)
    assert len(res.reports) == wl.batch
    # NDC accounting stays exact under sharding: merged cnt covers the
    # granted budget for every budget-terminated lane
    cnt = np.asarray(res.state.cnt)
    bud = np.asarray(res.predicted_budget)
    active = np.asarray(res.state.active)
    assert np.all(cnt >= 1)
    # a lane still active after the resume stopped on its budget, and the
    # merged NDC must show that (per-shard splits sum back to >= W)
    assert np.all(cnt[active] >= bud[active])

    sched = CostAwareScheduler(eng, est, cfg, ServeConfig(lane_width=4))
    assert sched.summary()["n_shards"] == 2

    # planner stage-0 inputs route through the sharded delegation: one
    # ScanStats over the whole corpus, assembled from per-shard bitmaps
    from repro.core.planner import scan_stats
    stats = scan_stats(eng, eng.compile(wl.spec))
    assert stats.n == eng.n and stats.valid.shape[1] == eng.n


# --------------------------------------------------------------- mesh path ----

def test_sharded_mesh_matches_loop_path():
    """Forced 4-device host platform: 2-D (data × index) shard_map vs the
    host loop over shards. Float32 is bitwise (full state + resume);
    int8 keeps identical ids/counters with distances within 1 ulp (the
    XLA:CPU SPMD FMA-contraction caveat in core/sharded.py)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np
        from repro.core import SearchConfig, ShardedSearchEngine
        from repro.data import make_dataset, make_label_workload

        ds = make_dataset(n=512, dim=8, n_clusters=4, alphabet_size=16, seed=0)
        wl = make_label_workload(ds, batch=6, kind="contain", seed=3)
        cfg = SearchConfig(k=5, queue_size=32, pred_kind=0)

        from repro.index.builder import build_sharded_graph_index
        sg = build_sharded_graph_index(np.asarray(ds.vectors), 2, degree=8, seed=0)
        loop = ShardedSearchEngine.build(ds, sg, mesh=None)
        mesh = ShardedSearchEngine.build(ds, sg, mesh="auto")
        assert mesh.mesh is not None and dict(mesh.mesh.shape)["index"] == 2
        a = loop.search(cfg, wl.queries, wl.spec, 300)
        b = mesh.search(cfg, wl.queries, wl.spec, 300)
        for f in ("res_idx", "res_dist", "cnt", "cand_idx", "cand_dist",
                  "d_start", "n_inspected", "visited"):
            assert np.array_equal(np.asarray(getattr(a, f)),
                                  np.asarray(getattr(b, f))), f
        s1 = mesh.search(cfg, wl.queries, wl.spec, 80)
        s1 = mesh.search(cfg, wl.queries, wl.spec, 300, state=s1)
        assert np.array_equal(np.asarray(a.res_idx), np.asarray(s1.res_idx))
        assert np.array_equal(np.asarray(a.cnt), np.asarray(s1.cnt))

        qc = {"train_sample_size": 256}
        lq = ShardedSearchEngine.build(ds, sg, mesh=None, precision="int8",
                                       quant_cfg=dict(qc))
        mq = ShardedSearchEngine.build(ds, sg, mesh="auto", precision="int8",
                                       quant_cfg=dict(qc))
        c = lq.search(cfg, wl.queries, wl.spec, 300)
        d = mq.search(cfg, wl.queries, wl.spec, 300)
        for f in ("res_idx", "cand_idx", "cnt", "n_inspected", "hops"):
            assert np.array_equal(np.asarray(getattr(c, f)),
                                  np.asarray(getattr(d, f))), f
        for f in ("res_dist", "cand_dist"):
            x, y = np.asarray(getattr(c, f)), np.asarray(getattr(d, f))
            fin = np.isfinite(x)
            assert np.array_equal(fin, np.isfinite(y)), f
            assert np.all(np.abs(x[fin] - y[fin]) <= np.spacing(x[fin])), f
        print("OK")
    """)
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=1200,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"})
    assert "OK" in r.stdout, r.stderr
