"""Cost-aware serving subsystem: batcher invariants, bucket routing,
scheduled-vs-oneshot bit-identity (incl. mixed-boolean-structure batches),
cache correctness, admission control."""
import dataclasses
import json

import numpy as np
import pytest

from repro.core import (CostEstimator, SearchConfig, SearchEngine, e2e_search,
                        generate_training_data)
from repro.data import (make_composite_workload, make_dataset,
                        make_label_workload, make_range_workload)
from repro.filters import And, Contain, Not, Or, Range
from repro.filters.predicates import PRED_CONTAIN, PRED_EQUAL, PRED_RANGE
from repro.index import build_graph_index
from repro.serve import (AdmissionQueue, CostAwareScheduler, MicroBatcher,
                         Request, ServeConfig, requests_from_workload)
from repro.serve.cache import request_key


@pytest.fixture(scope="module")
def world():
    ds = make_dataset(n=2500, dim=24, n_clusters=6, alphabet_size=32, seed=0)
    graph = build_graph_index(ds.vectors, degree=16, seed=0)
    engine = SearchEngine.build(ds, graph)
    cfg = SearchConfig(k=5, queue_size=64, pred_kind=PRED_CONTAIN)
    wl_tr = make_label_workload(ds, batch=192, kind="contain", seed=7)
    td = generate_training_data(engine, ds, wl_tr, cfg, probe_budget=48,
                                chunk=96)
    est = CostEstimator.fit(td.features, td.w_q, n_trees=60, depth=4)
    return ds, engine, cfg, est


def _req(rid, kind=PRED_CONTAIN, budget=None, arrival=0.0, dim=4, words=1):
    r = Request(rid=rid, query=np.full(dim, rid, np.float32), kind=kind,
                arrival=arrival)
    if kind == PRED_RANGE:
        r.range_lo, r.range_hi = 0.0, 1.0
    else:
        r.label_mask = np.asarray([rid + 1] * words, np.uint32)
    r.budget = budget
    return r


# -------------------------------------------------------------- batcher ----
def test_batcher_padding_invariants():
    b = MicroBatcher(lane_width=8, buckets=(100, None), n_words=1, n_values=1)
    reqs = [_req(i, budget=50, arrival=i) for i in range(3)]
    q = np.asarray(b.pad_queries(reqs))
    assert q.shape == (8, 4)
    assert (q[3:] == 0).all()                       # pad lanes zeroed
    prog = b.pad_program(reqs)
    assert prog.masks.shape == (8, 1, 1)            # 3 single-clause + pads
    assert (np.asarray(prog.masks)[3:] == 0).all()
    # pad lanes are match-nothing: no active term → valid ≡ False
    assert not np.asarray(prog.term_active)[3:].any()
    assert np.asarray(prog.term_active)[:3].all()
    budgets = np.asarray(b.pad_budgets(reqs, cap=None))
    assert budgets.shape == (8,)
    assert (budgets[:3] == 50).all() and (budgets[3:] == 0).all()


def test_batcher_mixes_filter_structures():
    """Compiled programs erase the same-kind restriction: one FIFO batch
    carries label, range, and composite filters together."""
    b = MicroBatcher(lane_width=4, buckets=(100, None), fill=True,
                     n_words=1, n_values=1)
    b.enqueue(_req(0, kind=PRED_CONTAIN, budget=50, arrival=0.0))
    b.enqueue(_req(1, kind=PRED_RANGE, budget=50, arrival=1.0))
    r2 = Request(rid=2, query=np.zeros(4, np.float32), arrival=2.0,
                 expr=And(Contain([1]), Range(0.1, 0.9)))
    r2.budget = 50
    b.enqueue(r2)
    _, reqs, _ = b.form_batch()
    assert [r.rid for r in reqs] == [0, 1, 2]        # strict FIFO, one batch
    prog = b.pad_program(reqs, width=4)
    # slot shape covers the widest program (the 2-clause conjunction),
    # rounded to a power of two
    assert prog.n_slots == 2 and prog.batch == 4
    active = np.asarray(prog.active)
    assert active.sum(axis=1).tolist() == [1, 1, 2, 0]


def test_bucket_routing_deterministic():
    b = MicroBatcher(lane_width=4, buckets=(100, 400, None))
    assert b.bucket_of(1) == 0
    assert b.bucket_of(100) == 0                     # cap is inclusive
    assert b.bucket_of(101) == 1
    assert b.bucket_of(400) == 1
    assert b.bucket_of(401) == 2
    assert b.bucket_of(10**9) == 2
    # same inputs → same batches, twice
    def fill(bb):
        for i, w in enumerate([50, 500, 90, 120, 10**6]):
            bb.enqueue(_req(i, budget=w, arrival=i))
        out = []
        while bb.depth():
            idx, reqs, cap = bb.form_batch()
            out.append((idx, [r.rid for r in reqs], cap))
        return out
    b2 = MicroBatcher(lane_width=4, buckets=(100, 400, None))
    assert fill(b) == fill(b2)


def test_opportunistic_fill_rides_spare_lanes():
    b = MicroBatcher(lane_width=4, buckets=(100, None), fill=True)
    for i in range(3):
        b.enqueue(_req(i, budget=50 + i, arrival=float(i)))
    for i in (3, 4):
        b.enqueue(_req(i, budget=5000, arrival=3.0 + i))
    (plan, idx), reqs, cap = b.form_batch()
    assert plan == "traverse" and idx == 0 and cap == 100
    # 3 residents → natural width 4 → exactly one free pad lane for a rider
    assert [r.rid for r in reqs] == [0, 1, 2, 3]
    # the rider runs a bounded slice: its lane budget is clamped to the cap
    budgets = np.asarray(b.pad_budgets(reqs, cap, width=4))
    assert budgets.tolist() == [50, 51, 52, 100]


def test_fill_never_widens_past_natural_width():
    """Riders must not push a batch to a wider (costlier) lane shape."""
    b = MicroBatcher(lane_width=16, buckets=(100, None), fill=True)
    for i in range(3):                              # natural width 4
        b.enqueue(_req(i, budget=50, arrival=float(i)))
    for i in range(10, 22):                         # plenty of riders
        b.enqueue(_req(i, budget=5000, arrival=float(i)))
    _, reqs, _ = b.form_batch()
    assert len(reqs) == 4 == b.width_for(3)         # 1 rider, not 13


def test_batcher_rejects_unordered_buckets():
    with pytest.raises(ValueError, match="ascending"):
        MicroBatcher(buckets=(400, 100, None))


def test_form_batch_on_empty_named_bucket():
    b = MicroBatcher(lane_width=4, buckets=(100, None), fill=True)
    b.enqueue(_req(0, budget=5000, arrival=0.0))     # lives in bucket 1
    key, reqs, cap = b.form_batch(bucket=("traverse", 0))  # bucket 0 empty
    assert (key, reqs, cap) == (("traverse", 0), [], 100)
    assert b.depth() == 1                            # nothing was lost


# ------------------------------------------------- scheduled == one-shot ----
@pytest.mark.parametrize("policy", ["direct", "escalate"])
def test_scheduled_equals_oneshot(world, policy):
    """The acceptance bar: scheduling (micro-batching, bucket routing,
    resume-requeue slicing, lane padding) is bit-invisible in the results."""
    ds, engine, cfg, est = world
    wl = make_label_workload(ds, batch=24, kind="contain", seed=42)
    one = e2e_search(engine, est, cfg, wl.queries, wl.spec, probe_budget=48,
                     alpha=1.5)
    scfg = ServeConfig(lane_width=8, buckets=(128, 512, None), policy=policy,
                       probe_budget=48, alpha=1.5, cache_capacity=0)
    sched = CostAwareScheduler(engine, est, cfg, scfg)
    reqs = requests_from_workload(wl)
    for r in reqs:
        assert sched.submit(r, 0.0) == "queued"
    sched.run_until_idle(0.0)
    reqs.sort(key=lambda r: r.rid)
    np.testing.assert_array_equal(
        np.stack([r.res_idx for r in reqs]), np.asarray(one.state.res_idx))
    np.testing.assert_array_equal(
        np.stack([r.res_dist for r in reqs]), np.asarray(one.state.res_dist))
    np.testing.assert_array_equal(
        np.asarray([r.ndc for r in reqs]), np.asarray(one.state.cnt))
    np.testing.assert_array_equal(
        np.asarray([r.budget for r in reqs]), one.predicted_budget)
    if policy == "escalate":
        # the preemption path must actually have been exercised
        assert any(r.n_slices >= 2 for r in reqs)


def test_scheduled_equals_oneshot_with_padding(world):
    """5 requests through 8-wide lanes: pad lanes must be inert."""
    ds, engine, cfg, est = world
    wl = make_label_workload(ds, batch=5, kind="contain", seed=13)
    one = e2e_search(engine, est, cfg, wl.queries, wl.spec, probe_budget=48,
                     alpha=1.5)
    sched = CostAwareScheduler(engine, est, cfg, ServeConfig(
        lane_width=8, buckets=(128, 512, None), probe_budget=48, alpha=1.5,
        cache_capacity=0))
    reqs = requests_from_workload(wl)
    for r in reqs:
        sched.submit(r, 0.0)
    sched.run_until_idle(0.0)
    reqs.sort(key=lambda r: r.rid)
    np.testing.assert_array_equal(
        np.stack([r.res_idx for r in reqs]), np.asarray(one.state.res_idx))


def test_scheduled_mixed_kinds_equal_per_kind_oneshot(world):
    """Interleaved contain/range requests: each kind matches its one-shot."""
    ds, engine, cfg, est = world
    wl_c = make_label_workload(ds, batch=8, kind="contain", seed=5)
    wl_r = make_range_workload(ds, batch=8, seed=6)
    cfg_r = dataclasses.replace(cfg, pred_kind=PRED_RANGE)
    one_c = e2e_search(engine, est, cfg, wl_c.queries, wl_c.spec,
                       probe_budget=48, alpha=1.5)
    one_r = e2e_search(engine, est, cfg_r, wl_r.queries, wl_r.spec,
                       probe_budget=48, alpha=1.5)
    sched = CostAwareScheduler(engine, est, cfg, ServeConfig(
        lane_width=8, buckets=(128, 512, None), probe_budget=48, alpha=1.5,
        cache_capacity=0))
    rc = requests_from_workload(wl_c, start_rid=0)
    rr = requests_from_workload(wl_r, start_rid=100)
    inter = [r for pair in zip(rc, rr) for r in pair]
    for r in inter:
        sched.submit(r, 0.0)
    sched.run_until_idle(0.0)
    np.testing.assert_array_equal(np.stack([r.res_idx for r in rc]),
                                  np.asarray(one_c.state.res_idx))
    np.testing.assert_array_equal(np.stack([r.res_idx for r in rr]),
                                  np.asarray(one_r.state.res_idx))


def test_scheduled_mixed_structures_equal_oneshot(world):
    """Mixed-boolean-structure batch (And/Or/Not composites + bare leaves
    interleaved): the scheduler batches them into shared lanes and the
    results stay bit-identical to one-shot `e2e_search` over the same
    workload — the compiled-program generalization of the serving
    subsystem's core guarantee."""
    ds, engine, cfg, est = world
    wl = make_composite_workload(ds, batch=20, structure="mixed", seed=77)
    one = e2e_search(engine, est, cfg, wl.queries, wl.exprs, probe_budget=48,
                     alpha=1.5)
    sched = CostAwareScheduler(engine, est, cfg, ServeConfig(
        lane_width=8, buckets=(128, 512, None), probe_budget=48, alpha=1.5,
        cache_capacity=0))
    reqs = requests_from_workload(wl)
    for r in reqs:
        assert sched.submit(r, 0.0) == "queued"
    sched.run_until_idle(0.0)
    reqs.sort(key=lambda r: r.rid)
    # probe batches mixed at least two different program structures
    assert len({r.program.n_slots for r in reqs}) > 1
    np.testing.assert_array_equal(
        np.stack([r.res_idx for r in reqs]), np.asarray(one.state.res_idx))
    np.testing.assert_array_equal(
        np.stack([r.res_dist for r in reqs]), np.asarray(one.state.res_dist))
    np.testing.assert_array_equal(
        np.asarray([r.ndc for r in reqs]), np.asarray(one.state.cnt))
    np.testing.assert_array_equal(
        np.asarray([r.budget for r in reqs]), one.predicted_budget)


# ---------------------------------------------------------------- cache ----
def test_cache_hit_returns_identical_result(world):
    ds, engine, cfg, est = world
    wl = make_label_workload(ds, batch=4, kind="contain", seed=3)
    sched = CostAwareScheduler(engine, est, cfg, ServeConfig(
        lane_width=4, buckets=(128, None), probe_budget=48, alpha=1.5))
    reqs = requests_from_workload(wl)
    for r in reqs:
        assert sched.submit(r, 0.0) == "queued"
    sched.run_until_idle(0.0)
    again = requests_from_workload(wl)
    for r in again:
        assert sched.submit(r, 1.0) == "hit"
    for a, b in zip(reqs, again):
        assert b.cache_hit and b.completed == 1.0
        np.testing.assert_array_equal(a.res_idx, b.res_idx)
        assert a.ndc == b.ndc
    assert sched.cache.hit_rate == 0.5  # 4 misses then 4 hits


def test_cache_keys_distinguish_filter_spec_collisions():
    q = np.ones(8, np.float32)
    base = dict(k=5, queue_size=64, alpha=1.5, probe_budget=48)
    contain = Request(0, q, PRED_CONTAIN, label_mask=np.asarray([7], np.uint32))
    equal = Request(1, q, PRED_EQUAL, label_mask=np.asarray([7], np.uint32))
    # same query, same mask *bytes*, different predicate kind
    assert request_key(contain, **base) != request_key(equal, **base)
    # same kind, different mask
    other = Request(2, q, PRED_CONTAIN, label_mask=np.asarray([9], np.uint32))
    assert request_key(contain, **base) != request_key(other, **base)
    # a range whose float bytes shadow the mask bytes still differs
    lo, hi = np.frombuffer(np.asarray([7, 7], np.uint32).tobytes(),
                           np.float32)[:2]
    rng_req = Request(3, q, PRED_RANGE, range_lo=float(lo), range_hi=float(hi))
    assert request_key(contain, **base) != request_key(rng_req, **base)
    # search parameters are part of the key — every answer-changing one
    assert (request_key(contain, 5, 64, 1.5, 48)
            != request_key(contain, 5, 64, 2.0, 48))
    assert (request_key(contain, **base)
            != request_key(contain, **base, min_budget=64))
    assert (request_key(contain, **base)
            != request_key(contain, **base, n_probes=1))
    assert (request_key(contain, **base)
            != request_key(contain, **base, ablate_filter=True))
    # the engine's codec identity is answer-changing: compressed-domain
    # traversal keeps a different candidate pool, and a retrained codebook
    # (different digest) changes the pool again — neither may share entries
    # with float32 or with each other
    assert (request_key(contain, **base)
            != request_key(contain, **base, codec="int8:aabbccddeeff"))
    assert (request_key(contain, **base, codec="int8:aabbccddeeff")
            != request_key(contain, **base, codec="pq:aabbccddeeff"))
    assert (request_key(contain, **base, codec="pq:aabbccddeeff")
            != request_key(contain, **base, codec="pq:001122334455"))
    assert (request_key(contain, **base, codec="float32")
            == request_key(contain, **base))          # explicit default collides
    # identical requests collide on purpose
    twin = Request(4, q.copy(), PRED_CONTAIN,
                   label_mask=np.asarray([7], np.uint32))
    assert request_key(contain, **base) == request_key(twin, **base)


def test_cache_keys_canonicalize_composite_filters():
    """And(a,b) vs Or(a,b) must differ; And(a,b) vs And(b,a) must collide
    (same canonical program → same traversal → same answer)."""
    q = np.ones(8, np.float32)
    base = dict(k=5, queue_size=64, alpha=1.5, probe_budget=48)
    a, b = Contain([3]), Range(0.25, 0.75)

    def key(expr):
        return request_key(Request(0, q, expr=expr), **base)

    assert key(And(a, b)) == key(And(b, a))          # commutativity collides
    assert key(Or(a, b)) == key(Or(b, a))
    assert key(And(a, b)) != key(Or(a, b))           # structure distinguishes
    assert key(And(a, b)) != key(And(a, Not(b)))     # negation distinguishes
    assert key(a) != key(And(a, b))
    # double negation is semantic identity → canonical collision
    assert key(Not(Not(a))) == key(a)
    # a bare leaf and its legacy-field spelling collide (the shim contract)
    legacy = Request(1, q, PRED_CONTAIN, label_mask=np.asarray([8], np.uint32))
    assert request_key(legacy, **base) == key(Contain([3]))


@pytest.fixture(scope="module")
def auto_planner(world):
    from repro.core import fit_planner, generate_plan_training_data

    ds, engine, cfg, est = world
    wl = make_composite_workload(ds, batch=96, seed=11, structure="mixed",
                                 selectivities=(0.01, 0.1, 0.3))
    data = generate_plan_training_data(engine, ds, wl, cfg, probe_budget=48,
                                       chunk=48)
    return fit_planner(data, probe_budget=48, n_trees=60, depth=4)


def test_cache_plan_collision_matrix(world, auto_planner):
    """The plan ∈ key contract: plan enters the cache key exactly when it
    can change the answer. traverse == legacy key; scan/widen/auto are
    pairwise distinct; an auto completion is dual-put under the chosen
    forced key iff it executed the exact bitwise forced path (plan_pure)."""
    ds, engine, cfg, est = world
    base = dict(k=5, queue_size=64, alpha=1.5, probe_budget=48)
    probe = Request(0, np.ones(ds.dim, np.float32),
                    expr=And(Contain([3]), Range(0.25, 0.75)))
    keys = {p: request_key(probe, **base, plan=p)
            for p in ("traverse", "scan", "widen", "auto")}
    assert keys["traverse"] == request_key(probe, **base)  # legacy stable
    assert len(set(keys.values())) == 4                    # pairwise distinct

    # end-to-end: run an auto scheduler, then read the cache through every
    # forced-plan key — only the chosen plan's key may hit, and only when
    # the executed path was plan-pure
    scfg = ServeConfig(lane_width=8, buckets=(256, None), probe_budget=48,
                       plan="auto")
    sched = CostAwareScheduler(engine, est, cfg, scfg, planner=auto_planner)
    wl = make_composite_workload(ds, batch=8, seed=21, structure="mixed",
                                 selectivities=(0.01, 0.3))
    reqs = requests_from_workload(wl)
    for r in reqs:
        assert sched.submit(r, 0.0) == "queued"
    sched.run_until_idle(0.0)
    plans = {"scan", "traverse", "widen"}
    assert all(r.plan in plans for r in reqs)
    for r in reqs:
        hit = {p: sched.cache.get(sched._key_for(r, p)) is not None
               for p in plans | {"auto"}}
        assert hit["auto"]                       # always stored under auto
        assert hit[r.plan] == r.plan_pure        # dual-put iff bitwise-pure
        assert not any(hit[p] for p in plans - {r.plan})  # others never

    # a forced-plan scheduler sharing the cache hits exactly those entries
    pure = [r for r in reqs if r.plan_pure]
    assert pure                                  # routing produced pure lanes
    victim = pure[0]
    pos = reqs.index(victim)
    forced_same = CostAwareScheduler(
        engine, est, cfg, dataclasses.replace(scfg, plan=victim.plan),
        planner=auto_planner)
    forced_same.cache = sched.cache
    assert forced_same.submit(requests_from_workload(wl)[pos], 1.0) == "hit"
    other = next(p for p in plans if p != victim.plan)
    forced_other = CostAwareScheduler(
        engine, est, cfg, dataclasses.replace(scfg, plan=other),
        planner=auto_planner)
    forced_other.cache = sched.cache
    assert (forced_other.submit(requests_from_workload(wl)[pos], 1.0)
            == "queued")                         # forced-Y never sees X's entry

    # late-scan completions (probe counters leaked into NDC) must NOT be
    # dual-put: a forced-scan run never pays the probe
    late = Request(99, np.full(ds.dim, 0.5, np.float32),
                   expr=Contain([5]), arrival=2.0)
    late.plan, late.plan_pure = "scan", False
    sched._finish(late, np.full(cfg.k, -1, np.int32),
                  np.full(cfg.k, np.inf, np.float32), 17, 2.0)
    assert sched.cache.get(sched._key(late)) is not None
    assert sched.cache.get(sched._key_for(late, "scan")) is None


def test_uncompilable_filter_rejected_at_submit(world):
    """A filter the compiler rejects must raise at submit() with nothing
    queued — compiling after admission would poison the pump loop."""
    ds, engine, cfg, est = world
    sched = CostAwareScheduler(engine, est, cfg, ServeConfig(
        lane_width=4, buckets=(128, None), cache_capacity=0))
    pairs = [Or(Contain([2 * i]), Contain([2 * i + 1])) for i in range(6)]
    bomb = Request(0, np.zeros(ds.dim, np.float32), expr=And(*pairs))  # 2^6 DNF
    with pytest.raises(ValueError, match="clauses"):
        sched.submit(bomb, 0.0)
    assert sched.depth() == 0                        # nothing poisoned
    ok = requests_from_workload(make_label_workload(ds, batch=3, seed=1))
    for r in ok:
        assert sched.submit(r, 0.0) == "queued"
    sched.run_until_idle(0.0)                        # pump still healthy
    assert all(r.res_idx is not None for r in ok)


# ------------------------------------------------------------- admission ----
def test_admission_backpressure_and_deadlines():
    q = AdmissionQueue(capacity=2)
    a, b, c = (_req(i, arrival=float(i)) for i in range(3))
    assert q.offer(a, 0.0) and q.offer(b, 0.0)
    assert not q.offer(c, 0.0)                       # full → shed
    assert q.n_shed == 1
    d = _req(9, arrival=0.0)
    d.deadline = 1.0
    assert not q.offer(d, 2.0)                       # expired on arrival
    assert q.n_expired == 1
    assert len(q) == 2


def test_scheduler_reports_shed_and_metrics_json(world):
    ds, engine, cfg, est = world
    wl = make_label_workload(ds, batch=6, kind="contain", seed=9)
    sched = CostAwareScheduler(engine, est, cfg, ServeConfig(
        lane_width=4, buckets=(128, None), probe_budget=48, alpha=1.5,
        queue_capacity=4, cache_capacity=0))
    reqs = requests_from_workload(wl)
    outcomes = [sched.submit(r, 0.0) for r in reqs]
    assert outcomes.count("queued") == 4 and outcomes.count("shed") == 2
    sched.run_until_idle(0.0)
    s = sched.summary()
    assert s["n_completed"] == 4 and s["n_shed"] == 2
    assert s["latency"]["p50"] <= s["latency"]["p99"]
    json.dumps(s)  # BENCH artifact requirement: plain-JSON serializable
