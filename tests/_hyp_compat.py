"""`hypothesis` shim: use the real library when installed, else a tiny
deterministic fallback so the property tests still *run* (rather than
skip) in containers without it.

The fallback implements exactly the strategy surface these tests use —
`st.integers(lo, hi)` and `st.lists(elem, min_size, max_size)` — and drives
each test with `max_examples` pseudo-random draws from a per-test seeded
generator. No shrinking, no database; failures print the offending example.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:

    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elem.example(rng) for _ in range(n)]

            return _Strategy(draw)

    st = _St()

    def settings(max_examples=25, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*pos_strategies, **kw_strategies):
        def deco(fn):
            # nb: no functools.wraps — __wrapped__ would make pytest
            # introspect fn's params and demand fixtures for them
            def wrapper():
                n = getattr(wrapper, "_max_examples", 25)
                rng = np.random.default_rng(
                    zlib.adler32(fn.__qualname__.encode()))
                for _ in range(n):
                    args = [s.example(rng) for s in pos_strategies]
                    kwargs = {k: s.example(rng) for k, s in kw_strategies.items()}
                    try:
                        fn(*args, **kwargs)
                    except Exception:
                        print(f"falsifying example: args={args} kwargs={kwargs}")
                        raise

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._max_examples = 25
            return wrapper

        return deco
