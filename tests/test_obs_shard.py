"""Shard-aware observability: the per-shard EXPLAIN sum invariant (every
per-shard counter sums exactly to its merged counterpart, all shard counts
× plans), tracing bit-identity with zero added dispatches on *sharded*
engines (PR 7 pinned only dense + persistent unsharded), drift-monitor
alarm math against hand-computed PSI / log-RMSE on an injected shift,
trace-sink rotation bounds, and the scheduler's per-shard NDC / bitmap
telemetry + health surface."""
import json

import numpy as np
import pytest

from repro.core import CostEstimator, SearchConfig, SearchEngine, e2e_search
from repro.core.features import FEATURE_NAMES
from repro.core.planner import Planner, planned_search
from repro.core.search import dispatch_counters
from repro.core.sharded import ShardedSearchEngine
from repro.data import make_dataset, make_label_workload
from repro.distributed.merge import merge_plan
from repro.filters.predicates import PRED_CONTAIN
from repro.index.builder import build_graph_index, build_sharded_graph_index
from repro.obs import (CalibrationMonitor, DriftConfig, DriftMonitor, Tracer,
                       prometheus_text, psi, validate_prometheus)
from repro.obs.shard import build_shard_sections, shard_budgets, work_balance
from repro.serve import CostAwareScheduler, ServeConfig, requests_from_workload

F = 2 * len(FEATURE_NAMES)


# ---------------------------------------------------------- merge plan ----
def test_merge_plan_closed_form():
    assert merge_plan(1) == (0, 0)
    assert merge_plan(2) == (1, 1)
    assert merge_plan(4) == (3, 2)
    assert merge_plan(5) == (4, 3)
    assert merge_plan(8) == (7, 3)


def test_shard_budgets_and_balance():
    np.testing.assert_array_equal(shard_budgets(np.array([300, 301]), 2),
                                  [150, 151])
    bal = work_balance(np.array([[100, 100], [200, 0], [0, 0]]))
    np.testing.assert_allclose(bal, [1.0, 0.5, 1.0])


# ----------------------------------------------------------------- psi ----
def test_psi_hand_computed():
    # 2 bins at the reference median: ref (0.5, 0.5) vs cur (0.9, 0.1)
    # psi = 0.4·ln(0.9/0.5) − 0.4·ln(0.1/0.5)
    expect = 0.4 * np.log(0.9 / 0.5) - 0.4 * np.log(0.1 / 0.5)
    got = psi([0.0] * 50 + [1.0] * 50, [0.0] * 90 + [1.0] * 10, bins=2)
    np.testing.assert_allclose(got, expect, rtol=1e-6)
    # identical windows → 0; empty / single-valued reference → 0
    rng = np.random.default_rng(0)
    v = rng.normal(size=500)
    assert psi(v, v) == pytest.approx(0.0, abs=1e-9)
    assert psi([], [1.0]) == 0.0
    assert psi(v, []) == 0.0
    # single-valued reference has no usable quantile edges → 0 by design
    assert psi(np.zeros(100), np.ones(100) * 5) == 0.0
    # far-shifted current → large but finite (clip floor)
    shifted = psi(v, v + 5.0)
    assert 1.0 < shifted < np.inf


# ------------------------------------------------------------- fixture ----
@pytest.fixture(scope="module")
def world():
    ds = make_dataset(n=512, dim=8, n_clusters=4, alphabet_size=16, seed=0)
    cfg = SearchConfig(k=5, queue_size=32, pred_kind=PRED_CONTAIN)
    graph = build_graph_index(ds.vectors, degree=8, seed=0)
    engines = {1: SearchEngine.build(ds, graph, backend="dense")}
    for s in (2, 4):
        g = build_sharded_graph_index(np.asarray(ds.vectors), s, degree=8,
                                      seed=0)
        engines[s] = ShardedSearchEngine.build(ds, g, backend="dense",
                                               mesh=None)
    # constant-label heads: these tests pin accounting plumbing, not
    # prediction quality, so a trivial forest predicting ~300 NDC is enough
    rng = np.random.default_rng(0)
    fit = lambda w: CostEstimator.fit(                        # noqa: E731
        rng.normal(size=(64, w)).astype(np.float32), np.full(64, 300.0),
        n_trees=5, depth=2)
    est = fit(F)
    planner = Planner(traverse=fit(F), widen=fit(F), static=fit(8))
    return ds, cfg, engines, est, planner


# ------------------------------------------------- sum invariant ----------
@pytest.mark.parametrize("s", [1, 2, 4])
def test_shard_sections_sum_invariant(world, s):
    """Per-shard sections must sum EXACTLY to the merged counters — they
    read the same stacked arrays the merge reduced, so equality is to the
    integer, not approximate."""
    ds, cfg, engines, est, _ = world
    eng = engines[s]
    wl = make_label_workload(ds, batch=6, kind="contain", seed=3)
    r = e2e_search(eng, est, cfg, wl.queries, wl.spec, probe_budget=32,
                   alpha=1.5, explain=True)
    st = r.state
    merged_clause = np.asarray(st.n_clause_valid)
    for i, rep in enumerate(r.reports):
        if s == 1:
            assert rep.shards == [] and rep.work_balance == 1.0
            assert (rep.merge_pairwise, rep.merge_depth) == (0, 0)
            continue
        assert len(rep.shards) == s
        assert (rep.merge_pairwise, rep.merge_depth) == merge_plan(s)
        assert sum(sec.ndc for sec in rep.shards) == rep.actual_ndc
        assert (sum(sec.hops for sec in rep.shards)
                == int(np.asarray(st.hops)[i]))
        assert (sum(sec.n_inspected for sec in rep.shards)
                == int(np.asarray(st.n_inspected)[i]))
        clause = np.sum([sec.n_clause_valid for sec in rep.shards], axis=0)
        np.testing.assert_array_equal(clause, merged_clause[i])
        sb = int(shard_budgets(rep.predicted_budget, s)[()])
        for j, sec in enumerate(rep.shards):
            assert sec.shard == j and sec.budget == sb
            assert sec.termination in ("queue-drained", "budget", "greedy",
                                       "active")
        assert 1.0 / s <= rep.work_balance <= 1.0
        # serializable + rendered
        d = json.loads(rep.to_json())
        assert len(d["shards"]) == s
        assert f"shards={s}" in rep.format()


@pytest.mark.parametrize("plan", ["scan", "traverse", "widen"])
def test_shard_sections_all_plans(world, plan):
    """The invariant holds on every execution plan's report, and scan
    lanes override per-shard termination too (each shard's slice of the
    bitmap was scanned exhaustively)."""
    ds, cfg, engines, _, planner = world
    eng = engines[2]
    wl = make_label_workload(ds, batch=4, kind="contain", seed=5)
    res = planned_search(eng, planner, cfg, wl.queries, wl.spec,
                         probe_budget=32, alpha=1.5, force_plan=plan,
                         explain=True)
    st = res.state
    for i, rep in enumerate(res.reports):
        assert rep.plan == plan and len(rep.shards) == 2
        assert sum(sec.ndc for sec in rep.shards) == int(np.asarray(st.cnt)[i])
        assert (sum(sec.hops for sec in rep.shards)
                == int(np.asarray(st.hops)[i]))
        if plan == "scan":
            assert rep.termination == "scan-exhaustive"
            assert all(sec.termination == "scan-exhaustive"
                       for sec in rep.shards)


def test_direct_sections_match_engine_search(world):
    ds, cfg, engines, _, _ = world
    eng = engines[4]
    wl = make_label_workload(ds, batch=4, kind="contain", seed=7)
    st = eng.search(cfg, wl.queries, wl.spec, 200)
    secs = build_shard_sections(cfg, st, 200)
    cnt = np.asarray(st.shard.cnt)
    for i in range(4):
        assert [sec.ndc for sec in secs[i]] == [int(v) for v in cnt[i]]
        assert sum(sec.ndc for sec in secs[i]) == int(np.asarray(st.cnt)[i])


# --------------------------------------------- sharded tracing contract ----
@pytest.mark.parametrize("backend", ["dense", "pallas_persistent"])
def test_sharded_tracing_bit_identity_zero_dispatch(world, backend):
    """PR 7's contract, extended to sharded engines: tracing must change
    no result bit and add no device dispatch; shard spans carry the shard
    index and merge topology as plain ints."""
    ds, cfg, engines, est, _ = world
    if backend == "dense":
        eng = engines[2]
    else:
        g = build_sharded_graph_index(np.asarray(ds.vectors), 2, degree=8,
                                      seed=0)
        eng = ShardedSearchEngine.build(ds, g, backend=backend, mesh=None)
    wl = make_label_workload(ds, batch=6, kind="contain", seed=9)

    d0 = dispatch_counters()
    plain = e2e_search(eng, est, cfg, wl.queries, wl.spec, probe_budget=32,
                       alpha=1.5)
    d1 = dispatch_counters()
    tr = Tracer()
    traced = e2e_search(eng, est, cfg, wl.queries, wl.spec, probe_budget=32,
                        alpha=1.5, tracer=tr, explain=True)
    d2 = dispatch_counters()

    np.testing.assert_array_equal(np.asarray(plain.state.res_idx),
                                  np.asarray(traced.state.res_idx))
    np.testing.assert_array_equal(np.asarray(plain.state.res_dist),
                                  np.asarray(traced.state.res_dist))
    np.testing.assert_array_equal(np.asarray(plain.state.cnt),
                                  np.asarray(traced.state.cnt))
    if backend == "pallas_persistent":
        assert (d2["launches"] - d1["launches"]
                == d1["launches"] - d0["launches"])

    searches = tr.spans(name="shard-search")
    assert searches and all(sp.attrs["n_shards"] == 2 for sp in searches)
    assert {sp.attrs["shard"] for sp in searches} == {0, 1}
    merges = tr.spans(name="shard-merge")
    assert merges
    for sp in merges:
        assert (sp.attrs["pairwise"], sp.attrs["depth"]) == merge_plan(2)
        assert sp.attrs["path"] == "loop"
        assert all(isinstance(v, (int, float, str, bool))
                   for v in sp.attrs.values())


# ---------------------------------------------------------------- drift ----
def _record_window(cal, n, loc, actual_mult, seed):
    rng = np.random.default_rng(seed)
    for i in range(n):
        cal.record(rid=i, plan="traverse", predicted=300,
                   actual=int(300 * actual_mult * np.exp(rng.normal(0, 0.1))),
                   probe_ndc=32, n_slices=1, alpha=1.5,
                   features=rng.normal(loc=loc, size=6).astype(np.float32))


def test_drift_quiet_on_stationary_alarms_on_shift():
    dcfg = DriftConfig(min_ref=64, min_cur=32)
    cal = CalibrationMonitor()
    mon = DriftMonitor(dcfg)
    rep = mon.observe(cal)
    assert not rep["ready"] and not rep["alarm"]     # below min_ref

    _record_window(cal, 100, loc=0.0, actual_mult=1.0, seed=1)
    rep = mon.observe(cal)                           # freezes the reference
    assert rep["ready"] and rep["n_ref"] == 100 and rep["n_cur"] == 0
    assert not rep["alarm"]

    _record_window(cal, 100, loc=0.0, actual_mult=1.0, seed=2)
    rep = mon.observe(cal)                           # stationary → quiet
    assert rep["n_cur"] == 100 and not rep["alarm"]
    assert rep["psi_max"] < dcfg.psi_threshold

    mon.advance(cal)     # consume the quiet window so the next one is pure
    _record_window(cal, 100, loc=3.0, actual_mult=8.0, seed=3)
    rep = mon.observe(cal)                           # injected shift → alarm
    assert rep["alarm"] and rep["alarms"]["psi"] and rep["alarms"]["log_rmse"]
    # actual = 8× predicted with σ=0.1 noise ⇒ log-RMSE ≈ ln 8
    assert rep["log_rmse_cur"] == pytest.approx(np.log(8.0), abs=0.15)

    mon.advance(cal)                                 # trainer consumed it
    rep = mon.observe(cal)
    assert rep["n_cur"] == 0 and not rep["alarm"]

    # every value round-trips through JSON and the strict exporter
    json.dumps(rep)
    names = validate_prometheus(
        prometheus_text({"n_completed": 1}, None, rep))
    assert {"repro_drift_alarm", "repro_drift_psi_max",
            "repro_drift_alarm_detail"} <= set(names)


def test_drift_psi_matches_hand_recomputation():
    """report()'s per-feature PSI must equal psi() applied to the exact
    reference / current windows the monitor claims to use."""
    dcfg = DriftConfig(min_ref=32, min_cur=16, psi_bins=4)
    cal = CalibrationMonitor()
    mon = DriftMonitor(dcfg)
    _record_window(cal, 40, loc=0.0, actual_mult=1.0, seed=4)
    mon.observe(cal)
    _record_window(cal, 40, loc=1.0, actual_mult=1.0, seed=5)
    rep = mon.report(cal)
    ref = mon._ref["features"]
    cols = cal.arrays()
    cur = cols["features"][-40:]
    for j, got in enumerate(rep["psi_by_feature"]):
        assert got == pytest.approx(psi(ref[:, j], cur[:, j], bins=4),
                                    rel=1e-9)


def test_drift_win_rate_shift_detector():
    cfgd = DriftConfig(min_ref=32, min_cur=32, min_plan_n=24,
                       win_rate_shift=0.25)
    cal = CalibrationMonitor()
    mon = DriftMonitor(cfgd)
    rng = np.random.default_rng(0)
    feats = lambda: rng.normal(size=4).astype(np.float32)   # noqa: E731
    for i in range(50):      # reference: traverse always wins (act ≤ pred)
        cal.record(rid=i, plan="traverse", predicted=300, actual=200,
                   probe_ndc=8, n_slices=1, alpha=1.0, features=feats())
    mon.set_reference(cal)
    for i in range(50):      # shifted: traverse always loses
        cal.record(rid=i, plan="traverse", predicted=300, actual=400,
                   probe_ndc=8, n_slices=1, alpha=1.0, features=feats())
    rep = mon.report(cal)
    assert rep["alarms"]["win_rate"]
    assert rep["plans"]["traverse"]["shift"] == pytest.approx(1.0)
    assert rep["plans"]["traverse"]["judged"]
    # scan never reaches min_plan_n on either side → not judged, no alarm
    assert not rep["plans"]["scan"]["judged"]


def test_drift_window_is_bounded():
    dcfg = DriftConfig(min_ref=16, min_cur=8, window=32)
    cal = CalibrationMonitor()
    mon = DriftMonitor(dcfg)
    _record_window(cal, 20, loc=0.0, actual_mult=1.0, seed=6)
    mon.observe(cal)
    _record_window(cal, 200, loc=0.0, actual_mult=1.0, seed=7)
    rep = mon.report(cal)
    assert rep["n_cur"] == 32                        # capped at `window`


# ------------------------------------------------------- sink rotation ----
def test_trace_sink_rotation_bounds_disk(tmp_path):
    import os

    path = str(tmp_path / "spans.jsonl")
    cap = 2000
    tr = Tracer(sink=path, sink_max_bytes=cap)
    for i in range(300):
        tr.emit("launch", f"q-{i}", width=8, step=i)
    tr.flush()
    assert tr.n_rotations > 0
    assert os.path.getsize(path) <= cap
    assert os.path.getsize(path + ".1") <= cap
    for f in (path, path + ".1"):                    # kept lines stay valid
        for line in open(f):
            json.loads(line)
    tr.close()
    # re-opening an existing file resumes the byte count from its size
    tr2 = Tracer(sink=path, sink_max_bytes=cap)
    assert tr2._sink_bytes == os.path.getsize(path)
    tr2.close()


# --------------------------------------------- scheduler shard telemetry ----
def test_scheduler_shard_ndc_sums_to_request_ndc(world):
    ds, cfg, engines, est, _ = world
    eng = engines[2]
    scfg = ServeConfig(lane_width=4, buckets=(128, None), probe_budget=32,
                       alpha=1.5, cache_capacity=0, queue_capacity=64)
    sched = CostAwareScheduler(eng, est, cfg, scfg)
    wl = make_label_workload(ds, batch=10, kind="contain", seed=11)
    reqs = requests_from_workload(wl, arrivals=np.zeros(wl.batch))
    for r in reqs:
        sched.submit(r, 0.0)
    sched.run_until_idle(0.0)
    sh = sched.summary()["shards"]
    assert sh["n_shards"] == 2 and len(sh["ndc_by_shard"]) == 2
    assert sum(sh["ndc_by_shard"]) == sum(r.ndc for r in reqs)
    assert sh["ndc_skew"] >= 1.0 and 0.0 < sh["work_balance"] <= 1.0
    names = validate_prometheus(sched.prometheus())
    assert names["repro_shard_ndc_total"] == 2
    assert "repro_shard_work_balance" in names


def test_scheduler_shard_bitmap_counts(world):
    """Forced-scan serving counts each admitted filter's bitmap exactly
    once, split at the engine's shard offsets — equal to an offline
    popcount of the same workload's validity mask."""
    from repro.core.planner import scan_stats

    ds, cfg, engines, est, _ = world
    eng = engines[2]
    scfg = ServeConfig(lane_width=4, buckets=(128, None), plan="scan",
                       cache_capacity=0, queue_capacity=64)
    sched = CostAwareScheduler(eng, est, cfg, scfg)
    wl = make_label_workload(ds, batch=8, kind="contain", seed=13)
    reqs = requests_from_workload(wl, arrivals=np.zeros(wl.batch))
    for r in reqs:
        sched.submit(r, 0.0)
    sched.run_until_idle(0.0)
    valid = np.asarray(scan_stats(eng, eng.compile(wl.spec)).valid)
    ns = eng.shard_size
    expect = [int(valid[:, int(o):int(o) + ns].sum()) for o in eng.offsets]
    sh = sched.summary()["shards"]
    assert sh["bitmap_by_shard"] == expect
    names = validate_prometheus(sched.prometheus())
    assert names["repro_shard_bitmap_count_total"] == 2


def test_scheduler_status_surface(world):
    ds, cfg, engines, est, _ = world
    sched = CostAwareScheduler(
        engines[2], est, cfg,
        ServeConfig(lane_width=4, probe_budget=32, cache_capacity=0,
                    queue_capacity=64),
        drift=DriftConfig(min_ref=4, min_cur=2))
    wl = make_label_workload(ds, batch=6, kind="contain", seed=15)
    for r in requests_from_workload(wl, arrivals=np.zeros(wl.batch)):
        sched.submit(r, 0.0)
    sched.run_until_idle(0.0)
    st = sched.status()
    json.dumps(st)                                   # fully serializable
    assert st["healthy"] is True
    assert st["queue"]["depth"] == 0 and st["queue"]["capacity"] == 64
    assert st["summary"]["shards"]["n_shards"] == 2
    assert st["drift"]["ready"]                      # min_ref=4 < 6 records
    assert st["calibration"]["n_records"] == 6
    # drift opt-out: no monitor, surface still healthy
    s2 = CostAwareScheduler(engines[1], est, cfg,
                            ServeConfig(lane_width=4), drift=False)
    st2 = s2.status()
    assert st2["drift"] is None and st2["healthy"] is True
    assert "shards" not in st2["summary"]            # unsharded: no block


def test_unsharded_engine_has_no_shard_metrics(world):
    ds, cfg, engines, est, _ = world
    sched = CostAwareScheduler(
        engines[1], est, cfg,
        ServeConfig(lane_width=4, probe_budget=32, cache_capacity=0,
                    queue_capacity=64))
    wl = make_label_workload(ds, batch=4, kind="contain", seed=17)
    for r in requests_from_workload(wl, arrivals=np.zeros(wl.batch)):
        sched.submit(r, 0.0)
    sched.run_until_idle(0.0)
    assert "shards" not in sched.summary()
    validate_prometheus(sched.prometheus())
