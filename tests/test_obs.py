"""Observability subsystem: tracer ring/sink invariants, the
tracing-changes-nothing contract (bit-identical results, no extra device
dispatches), EXPLAIN termination semantics, calibration telemetry schema +
persistence, Prometheus exposition validity, ServeMetrics hardening, and
the scheduler's driver-observed launch accounting."""
import json
import types

import numpy as np
import pytest

from repro.core import (CostEstimator, SearchConfig, SearchEngine, e2e_search,
                        generate_training_data)
from repro.core.search import dispatch_counters
from repro.data import make_dataset, make_label_workload
from repro.filters.predicates import PRED_CONTAIN
from repro.index import build_graph_index
from repro.obs import (NO_TRACE, PLAN_NAMES, RECORD_FIELDS, SCHEMA_VERSION,
                       CalibrationMonitor, NullTracer, Tracer, as_tracer,
                       build_reports, feature_dict, prometheus_text,
                       termination_reasons, validate_prometheus)
from repro.obs.trace import _host_scalar
from repro.serve import (CostAwareScheduler, ServeConfig, ServeMetrics,
                         requests_from_workload)


# ------------------------------------------------------------- tracer ----
def test_tracer_ring_ids_and_filters():
    clock = iter(float(i) for i in range(10_000))
    tr = Tracer(capacity=4, clock=lambda: next(clock))
    assert tr.new_trace("q") == "q-000001"
    assert tr.new_trace("req") == "req-000002"      # one counter, replayable
    for i in range(6):
        tr.emit("launch", "q-000001", steps=i)
    assert tr.n_emitted == 6                        # lifetime count
    assert len(tr) == 4                             # ring evicted the oldest
    assert [s.attrs["steps"] for s in tr.spans()] == [2, 3, 4, 5]
    assert tr.spans(name="nope") == []
    assert len(tr.spans(trace_id="q-000001", name="launch")) == 4
    with tr.span("probe", "q-000001", budget=64) as sp:
        sp.set(steps=7)
    got = tr.spans(name="probe")[0]
    assert got.attrs == dict(budget=64, steps=7)
    assert got.t1 >= got.t0                         # monotonic interval
    tr.clear()
    assert len(tr) == 0 and tr.n_emitted == 7       # clear keeps lifetime


def test_tracer_sink_jsonl(tmp_path):
    path = str(tmp_path / "spans.jsonl")
    tr = Tracer(capacity=2, sink=path)
    for i in range(5):
        tr.emit("launch", f"q-{i}", width=8)
    tr.close()
    lines = [json.loads(l) for l in open(path)]
    assert len(lines) == 5                          # sink outlives the ring
    assert lines[3] == {**lines[3], "name": "launch", "trace": "q-3",
                        "width": 8}


def test_span_attrs_must_be_host_scalars():
    tr = Tracer()
    assert _host_scalar(np.int32(3)) == 3
    assert _host_scalar(np.float64(2.5)) == 2.5
    assert _host_scalar(np.bool_(True)) is True
    with pytest.raises(TypeError):
        tr.emit("bad", "t", arr=np.zeros(4))        # arrays must not leak
    with pytest.raises(TypeError):
        with tr.span("bad", "t") as sp:
            sp.set(arr=np.zeros(4))                 # ... via sp.set either


def test_null_tracer_is_inert():
    assert as_tracer(None) is NO_TRACE
    t = Tracer()
    assert as_tracer(t) is t
    assert isinstance(NO_TRACE, NullTracer)
    assert NO_TRACE.new_trace() == ""
    with NO_TRACE.span("x", "t", a=1) as sp:
        sp.set(b=2)                                 # writable, discarded
    NO_TRACE.emit("x", arr=np.zeros(3))             # no validation either
    assert len(NO_TRACE) == 0 and NO_TRACE.spans() == []


# ------------------------------------------------------------ explain ----
def _fake_state(cand_dist, cand_exp, res_worst, cnt):
    """Minimal duck-typed final carry for termination_reasons."""
    cand_dist = np.asarray(cand_dist, np.float32)
    k = 3
    res = np.full((cand_dist.shape[0], k), np.inf, np.float32)
    res[:, -1] = res_worst
    return types.SimpleNamespace(
        cand_dist=cand_dist,
        cand_idx=np.where(np.isfinite(cand_dist), 1, -1).astype(np.int32),
        cand_exp=np.asarray(cand_exp, bool),
        res_dist=res,
        cnt=np.asarray(cnt, np.int32),
        hops=np.zeros(cand_dist.shape[0], np.int32),
        res_idx=np.zeros((cand_dist.shape[0], k), np.int32),
    )


def test_termination_reason_priority():
    inf = np.inf
    st = _fake_state(
        # lane 0: every candidate expanded → queue-drained (beats budget:
        #         its cnt is also ≥ budget, drained wins the priority)
        # lane 1: unexpanded candidate + cnt ≥ budget → budget
        # lane 2: unexpanded cand worse than worst result → greedy
        # lane 3: none of the above → active
        cand_dist=[[1.0, 2.0], [1.0, inf], [9.0, inf], [1.0, inf]],
        cand_exp=[[True, True], [False, False], [False, False],
                  [False, False]],
        res_worst=[5.0, 5.0, 5.0, 5.0],
        cnt=[100, 100, 10, 10],
    )
    cfg = SearchConfig(k=3, greedy_stop=True)
    assert termination_reasons(cfg, st, 50) == [
        "queue-drained", "budget", "greedy", "active"]
    # greedy_stop off: the greedy condition must not fire
    cfg = SearchConfig(k=3, greedy_stop=False)
    assert termination_reasons(cfg, st, 50) == [
        "queue-drained", "budget", "active", "active"]
    # per-lane budgets broadcast
    assert termination_reasons(
        SearchConfig(k=3), st, [100, 101, 5, 100]) == [
        "queue-drained", "active", "budget", "active"]


def test_feature_dict_naming():
    from repro.core.features import FEATURE_NAMES
    n = len(FEATURE_NAMES)
    d = feature_dict(np.arange(2 * n + 1, dtype=np.float32))
    assert list(d)[:n] == list(FEATURE_NAMES)
    assert list(d)[n:2 * n] == [f"d_{f}" for f in FEATURE_NAMES]
    assert list(d)[-1] == f"f{2 * n}"               # overflow block
    assert d[FEATURE_NAMES[1]] == 1.0


def test_build_reports_roundtrip():
    st = _fake_state(cand_dist=[[1.0, np.inf]], cand_exp=[[False, False]],
                     res_worst=[5.0], cnt=[80])
    reports = build_reports(
        SearchConfig(k=3), st, 64, backend="dense", plans=["widen"],
        probe_ndc=[32], trace_ids=["t-1"],
        features=np.ones((1, 4), np.float32))
    r = reports[0]
    assert (r.plan, r.termination, r.predicted_budget, r.actual_ndc,
            r.probe_ndc) == ("widen", "budget", 64, 80, 32)
    d = json.loads(r.to_json())
    assert d["trace_id"] == "t-1" and d["backend"] == "dense"
    assert "plan=widen" in r.format() and "terminated=budget" in r.format()


# -------------------------------------------------------- calibration ----
def test_calibration_schema_is_frozen():
    """The recalibration PR trains from saved windows — names, dtypes and
    order are a contract. Changing them requires a SCHEMA_VERSION bump."""
    assert SCHEMA_VERSION == 1
    assert [(n, d) for n, d, _ in RECORD_FIELDS] == [
        ("rid", "int64"), ("plan", "int32"), ("predicted", "int64"),
        ("actual", "int64"), ("probe_ndc", "int64"), ("n_slices", "int32"),
        ("alpha", "float32"), ("recall", "float32")]
    assert PLAN_NAMES == ("traverse", "scan", "widen")


def test_calibration_report_math():
    mon = CalibrationMonitor()
    assert np.isfinite(list(mon.report()["predicted"].values())).all()
    # traverse: predicted 100 vs actual {50, 200} → one win, one loss
    mon.record(predicted=100, actual=50, plan="traverse", rid=0)
    mon.record(predicted=100, actual=200, plan="traverse", rid=1)
    mon.record(predicted=300, actual=100, plan="scan", rid=2, recall=0.9)
    rep = mon.report()
    assert rep["n_records"] == 3 and rep["n_recorded_total"] == 3
    assert rep["overprediction_rate"] == pytest.approx(2 / 3)
    assert rep["underprediction_rate"] == pytest.approx(1 / 3)
    assert rep["per_plan"]["traverse"]["win_rate"] == pytest.approx(0.5)
    assert rep["per_plan"]["scan"]["win_rate"] == 1.0
    assert rep["per_plan"]["scan"]["share"] == pytest.approx(1 / 3)
    assert rep["recall_mean"] == pytest.approx(0.9)
    assert rep["n_with_recall"] == 1
    expected = np.sqrt(np.mean(np.log([100 / 50, 100 / 200, 300 / 100]) ** 2))
    assert rep["log_rmse"] == pytest.approx(expected)
    mon.set_recall({0: 1.0})
    assert mon.report()["n_with_recall"] == 2


def test_calibration_save_load_roundtrip(tmp_path):
    mon = CalibrationMonitor()
    for i in range(7):
        mon.record(rid=i, predicted=64 + i, actual=60 + 2 * i,
                   plan=PLAN_NAMES[i % 3], probe_ndc=32, n_slices=1,
                   alpha=1.5, features=np.arange(6, dtype=np.float32) + i)
    path = mon.save(str(tmp_path), tag="win0")
    mon2, manifest = CalibrationMonitor.load(path)
    assert manifest["schema_version"] == SCHEMA_VERSION
    assert manifest["feature_width"] == 6
    a, b = mon.arrays(), mon2.arrays()
    for name, _, _ in RECORD_FIELDS:
        np.testing.assert_array_equal(a[name], b[name])
    np.testing.assert_array_equal(a["features"], b["features"])
    # integrity: a torn/tampered npz must not load silently
    import os
    data = os.path.join(path, "arrays.npz")
    with open(data, "ab") as f:
        f.write(b"x")
    with pytest.raises(IOError):
        CalibrationMonitor.load(path)
    CalibrationMonitor.load(path, validate=False)   # escape hatch


# --------------------------------------------------------- prometheus ----
def _tiny_summary():
    m = ServeMetrics()
    m.observe_batch("probe", size=8, fill=8, busy=0.1, steps=40, launches=5,
                    early_exit_frac=0.5)
    m.observe_batch("resume", size=4, fill=8, busy=0.2, steps=80, launches=10,
                    early_exit_frac=0.25)
    m.observe_depth(0.0, 3)
    req = types.SimpleNamespace(rid=0, completed=1.0, arrival=0.0,
                                probe_done=0.5, ndc=120, budget=128,
                                n_slices=1, cache_hit=False, deadline=None)
    m.complete(req)
    return m.summary()


def test_prometheus_text_is_valid_and_nan_free():
    mon = CalibrationMonitor()
    mon.record(predicted=100, actual=80, plan="scan")
    text = prometheus_text(_tiny_summary(), mon.report())
    names = validate_prometheus(text)               # raises on any violation
    for expect in ("repro_requests_completed_total", "repro_latency",
                   "repro_launches_total", "repro_early_exit_frac",
                   "repro_phase_batches_total", "repro_calibration_log_rmse",
                   "repro_plan_win_rate", "repro_plan_queries_total"):
        assert expect in names, (expect, sorted(names))
    assert "nan" not in text.lower()
    # a NaN smuggled into the summary renders as 0.0, not as "nan"
    s = _tiny_summary()
    s["latency"]["p99"] = float("nan")
    validate_prometheus(prometheus_text(s))
    # custom prefix propagates
    assert "acme_launches_total" in validate_prometheus(
        prometheus_text(_tiny_summary(), prefix="acme"))


def test_prometheus_validator_rejects_garbage():
    with pytest.raises(ValueError):
        validate_prometheus("")                     # empty scrape
    with pytest.raises(ValueError):
        validate_prometheus("this is not a metric line\n")
    with pytest.raises(ValueError):                 # sample before # TYPE
        validate_prometheus("repro_x 1.0\n")
    with pytest.raises(ValueError):                 # NaN sample
        validate_prometheus(
            "# HELP repro_x x\n# TYPE repro_x gauge\nrepro_x NaN\n")
    with pytest.raises(ValueError):                 # malformed labels
        validate_prometheus(
            "# HELP repro_x x\n# TYPE repro_x gauge\n"
            'repro_x{quantile=0.5} 1.0\n')


# ----------------------------------------------- metrics hardening (s1) ----
def test_metrics_summary_finite_on_empty_and_singleton():
    m = ServeMetrics()
    s = m.summary()
    flat = [s["latency"]["p50"], s["latency"]["p99"], s["latency_mean"],
            s["probe_latency"]["p95"], s["ndc"]["p50"], s["queue_depth_mean"],
            s["early_exit_frac"], s["deadline_miss_rate"]]
    assert np.isfinite(flat).all() and s["launches_total"] == 0
    req = types.SimpleNamespace(rid=0, completed=2.0, arrival=1.0,
                                probe_done=None, ndc=None, budget=None,
                                n_slices=0, cache_hit=False, deadline=None)
    m.complete(req)
    s = m.summary()                                 # singleton window
    assert s["latency"]["p50"] == s["latency"]["p99"] == 1.0
    assert s["ndc"]["p99"] == 0.0                   # ndc=None drops cleanly


def test_metrics_percentiles_drop_nonfinite():
    m = ServeMetrics()
    for lat in (1.0, float("nan"), 3.0, float("inf")):
        m.complete(types.SimpleNamespace(
            rid=0, completed=lat, arrival=0.0, probe_done=None, ndc=10,
            budget=None, n_slices=0, cache_hit=False, deadline=None))
    s = m.summary()
    assert s["latency"]["p50"] == pytest.approx(2.0)  # only {1, 3} survive
    assert np.isfinite(s["latency"]["p99"])


def test_metrics_early_exit_weighted_by_real_lanes():
    m = ServeMetrics()
    # a full 64-lane batch at 0.5 and a 1-lane tail at 1.0: an unweighted
    # mean says 0.75; the truth over the 65 real lanes is (32+1)/65
    m.observe_batch("resume", size=64, fill=64, busy=1.0, steps=10,
                    launches=2, early_exit_frac=0.5)
    m.observe_batch("resume", size=1, fill=8, busy=1.0, steps=10,
                    launches=1, early_exit_frac=1.0)
    s = m.summary()
    want = (0.5 * 64 + 1.0 * 1) / 65
    assert s["early_exit_frac"] == pytest.approx(want, abs=1e-4)
    assert s["batches_by_phase"]["resume"]["early_exit_frac"] == \
        pytest.approx(want, abs=1e-4)
    assert s["launches_total"] == 3 and s["steps_total"] == 20


# ------------------------------------------------ engine integration ----
@pytest.fixture(scope="module")
def world():
    ds = make_dataset(n=2000, dim=16, n_clusters=6, alphabet_size=32, seed=0)
    graph = build_graph_index(ds.vectors, degree=16, seed=0)
    cfg = SearchConfig(k=5, queue_size=64, pred_kind=PRED_CONTAIN)
    dense = SearchEngine.build(ds, graph, backend="dense")
    wl_tr = make_label_workload(ds, batch=96, kind="contain", seed=7)
    td = generate_training_data(dense, ds, wl_tr, cfg, probe_budget=48,
                                chunk=96)
    est = CostEstimator.fit(td.features, td.w_q, n_trees=40, depth=4)
    return ds, graph, cfg, dense, est


@pytest.mark.parametrize("backend", ["dense", "pallas_persistent"])
def test_e2e_tracing_changes_nothing_and_explains(world, backend):
    """The overhead contract: tracing+explain must return bit-identical
    results and (persistent) add zero device dispatches; the launch spans
    must account for every driver dispatch 1:1."""
    ds, graph, cfg, dense, est = world
    engine = (dense if backend == "dense"
              else SearchEngine.build(ds, graph, backend=backend))
    wl = make_label_workload(ds, batch=12, kind="contain", seed=3)

    d0 = dispatch_counters()
    plain = e2e_search(engine, est, cfg, wl.queries, wl.spec,
                       probe_budget=48, alpha=1.5)
    d1 = dispatch_counters()
    tr = Tracer()
    traced = e2e_search(engine, est, cfg, wl.queries, wl.spec,
                        probe_budget=48, alpha=1.5, tracer=tr, explain=True)
    d2 = dispatch_counters()

    np.testing.assert_array_equal(np.asarray(plain.state.res_idx),
                                  np.asarray(traced.state.res_idx))
    np.testing.assert_array_equal(np.asarray(plain.state.res_dist),
                                  np.asarray(traced.state.res_dist))
    np.testing.assert_array_equal(np.asarray(plain.state.cnt),
                                  np.asarray(traced.state.cnt))
    np.testing.assert_array_equal(np.asarray(plain.predicted_budget),
                                  np.asarray(traced.predicted_budget))

    if backend == "pallas_persistent":
        launches_plain = d1["launches"] - d0["launches"]
        launches_traced = d2["launches"] - d1["launches"]
        assert launches_traced == launches_plain     # zero added dispatches
        # every driver dispatch produced exactly one "launch" span
        assert len(tr.spans(name="launch")) == launches_traced
        for sp in tr.spans(name="launch"):
            assert sp.attrs["steps"] >= 1 and sp.attrs["width"] >= 1

    names = {s.name for s in tr.spans()}
    assert {"probe", "feature-extract", "estimate", "resume"} <= names
    assert len(tr.spans(name="probe")) == 2          # n_probes=2 snapshots

    reports = traced.reports
    assert plain.reports is None and len(reports) == wl.batch
    buds = np.asarray(traced.predicted_budget)
    cnts = np.asarray(traced.state.cnt)
    for i, r in enumerate(reports):
        assert r.backend == backend and r.plan == "traverse"
        assert r.termination in ("budget", "queue-drained", "greedy",
                                 "active")
        assert r.predicted_budget == int(buds[i])
        assert r.actual_ndc == int(cnts[i]) and r.probe_ndc > 0
        assert [s.name for s in r.stages] == ["probe", "estimate", "resume",
                                              "rerank"]
        probe_st, _, resume_st, _ = r.stages
        assert probe_st.ndc + resume_st.ndc == r.actual_ndc
        assert probe_st.launches >= 1 and r.features  # named feature dict
        assert "ndc=" in r.format(features=True)


def test_scheduler_launch_accounting_and_telemetry(world):
    """Satellite: Σ per-batch launches recorded by the scheduler must equal
    the driver-observed dispatch count on a persistent engine — the old
    ⌈steps/steps_per_launch⌉ estimate undercounted compaction relaunches
    and multi-snapshot probes. Also pins scheduled bit-identity under
    tracing and the calibration/Prometheus surfaces."""
    ds, graph, cfg, dense, est = world
    engine = SearchEngine.build(ds, graph, backend="pallas_persistent")
    wl = make_label_workload(ds, batch=24, kind="contain", seed=11)
    scfg = ServeConfig(lane_width=8, probe_budget=48)

    def run(tracer, calibration):
        sch = CostAwareScheduler(engine, est, cfg, scfg, tracer=tracer,
                                 calibration=calibration)
        reqs = requests_from_workload(wl, arrivals=np.zeros(wl.batch))
        d0 = dispatch_counters()["launches"]
        for r in reqs:
            sch.submit(r, now=0.0)
        sch.run_until_idle(now=0.0)
        return sch, reqs, dispatch_counters()["launches"] - d0

    tr = Tracer()
    s1, r1, delta = run(tr, True)
    s2, r2, delta2 = run(None, False)
    for a, b in zip(r1, r2):
        assert np.array_equal(a.res_idx, b.res_idx)
        assert np.array_equal(a.res_dist, b.res_dist)
        assert a.ndc == b.ndc
    assert delta == delta2                           # tracing adds nothing

    summ = s1.summary()
    assert summ["launches_total"] == delta           # 1:1 accounting
    assert summ["launches_total"] == sum(
        p["launches"] for p in summ["batches_by_phase"].values())

    n_miss = sum(1 for r in r1 if not r.cache_hit)
    rep = s1.calibration_report()
    assert rep["n_records"] == n_miss                # cache hits not recorded
    assert set(rep["per_plan"]) <= set(PLAN_NAMES)
    assert s2.calibration_report() is None           # opt-out honored

    names = validate_prometheus(s1.prometheus())
    assert "repro_calibration_records_total" in names
    assert all(r.trace_id.startswith("req-") for r in r1)
    assert len(tr.spans(name="admit")) == wl.batch
    assert len(tr.spans(name="complete")) == wl.batch
    done = tr.spans(name="probe-done")
    assert 0 < len(done) <= wl.batch                 # cache hits skip probe
    assert all("rid" in s.attrs and "budget" in s.attrs for s in done)
