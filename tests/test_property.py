"""Property-based tests (hypothesis) on system invariants."""
import numpy as np
import pytest
from _hyp_compat import given, settings, st  # hypothesis or fallback

from repro.core.gbdt import train_gbdt
from repro.core.estimator import spearman
from repro.filters.predicates import (FilterSpec, PRED_CONTAIN, PRED_EQUAL,
                                      PRED_RANGE, pack_labels,
                                      predicate_contains, predicate_equals)
from repro.index.builder import _best_r_distinct
import jax.numpy as jnp


@settings(max_examples=50, deadline=None)
@given(st.lists(st.lists(st.integers(0, 63), max_size=6), min_size=1, max_size=20),
       st.lists(st.integers(0, 63), max_size=4))
def test_predicate_containment_matches_sets(label_sets, query):
    packed = pack_labels([tuple(set(s)) for s in label_sets], 64)
    qmask = pack_labels([tuple(set(query))], 64)[0]
    got = np.asarray(predicate_contains(jnp.asarray(packed), jnp.asarray(qmask)))
    want = np.array([set(query) <= set(s) for s in label_sets])
    np.testing.assert_array_equal(got, want)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.lists(st.integers(0, 31), max_size=5), min_size=1, max_size=20),
       st.lists(st.integers(0, 31), max_size=5))
def test_predicate_equality_matches_sets(label_sets, query):
    packed = pack_labels([tuple(set(s)) for s in label_sets], 32)
    qmask = pack_labels([tuple(set(query))], 32)[0]
    got = np.asarray(predicate_equals(jnp.asarray(packed), jnp.asarray(qmask)))
    want = np.array([set(query) == set(s) for s in label_sets])
    np.testing.assert_array_equal(got, want)


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 40), st.integers(1, 16), st.integers(0, 2**31 - 1))
def test_best_r_distinct_properties(n_cand, r, seed):
    rng = np.random.default_rng(seed)
    cand = rng.integers(-1, 50, size=(4, n_cand)).astype(np.int32)
    dist = rng.random((4, n_cand)).astype(np.float32)
    self_ids = rng.integers(0, 50, size=4).astype(np.int32)
    out_c, out_d = _best_r_distinct(cand, dist, r, self_ids)
    for row in range(4):
        vals = out_c[row][out_c[row] >= 0]
        # distinct, no self
        assert len(set(vals.tolist())) == len(vals)
        assert self_ids[row] not in vals
        # sorted ascending by distance
        dd = out_d[row][np.isfinite(out_d[row])]
        assert (np.diff(dd) >= 0).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(30, 200), st.integers(0, 2**31 - 1))
def test_gbdt_predictions_bounded_by_targets(n, seed):
    """GBDT with shrinkage must predict within the convex hull-ish range."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 4)).astype(np.float32)
    y = rng.normal(size=n)
    model = train_gbdt(x, y, n_trees=30, depth=3, learning_rate=0.3)
    p = model.predict(x)
    lo, hi = y.min(), y.max()
    span = max(hi - lo, 1e-6)
    assert p.min() >= lo - 0.5 * span and p.max() <= hi + 0.5 * span


def test_spearman_invariances():
    rng = np.random.default_rng(0)
    a = rng.normal(size=100)
    assert spearman(a, a) == pytest.approx(1.0)
    assert spearman(a, -a) == pytest.approx(-1.0)
    assert abs(spearman(a, rng.normal(size=100))) < 0.35
    # monotone-transform invariance
    assert spearman(a, np.exp(a)) == pytest.approx(1.0)
