"""Quantized index subsystem: codec round-trips, compressed-domain
dense/pallas parity, in-kernel ADC vs independent oracle (interpret mode),
exact rerank, probe/resume bit-compatibility, serving integration."""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import BIG_BUDGET, SearchConfig, SearchEngine
from repro.data import make_dataset, make_label_workload
from repro.filters.expr import Range
from repro.index import build_graph_index, filtered_knn_exact
from repro.index.bruteforce import recall_at_k
from repro.quant import (Int8Index, PQIndex, build_quant_index, codec_key,
                         decode_int8, decode_pq, exact_rerank, index_nbytes,
                         prepare_query, quant_dist)
from repro.quant.codecs import QuantGather

QCFG = dict(pq_subspaces=8, pq_centroids=32, pq_iters=8)


@pytest.fixture(scope="module")
def world():
    ds = make_dataset(n=2000, dim=24, n_clusters=6, alphabet_size=32, seed=0)
    graph = build_graph_index(ds.vectors, degree=16, seed=0)
    engines = {
        p: SearchEngine.build(ds, graph, precision=p, quant_cfg=QCFG)
        for p in ("float32", "int8", "pq")
    }
    return ds, graph, engines


def _workload(ds, batch=12, seed=3):
    wl = make_label_workload(ds, batch=batch, kind="contain", seed=seed)
    return wl, SearchConfig(k=5, queue_size=64)


# ---------------------------------------------------------------- codecs ----
def test_int8_roundtrip_error_bound(world):
    ds, _, engines = world
    idx = engines["int8"].quant
    assert isinstance(idx, Int8Index) and idx.codes.dtype == jnp.int8
    dec = np.asarray(decode_int8(idx))
    # affine SQ reconstructs within half a quantization step per dimension
    step = np.asarray(idx.scale)
    assert np.all(np.abs(dec - ds.vectors) <= step[None, :] * 0.5 + 1e-6)
    # the stored per-node error is exactly the reconstruction residual
    err = ((ds.vectors - dec) ** 2).sum(axis=1)
    np.testing.assert_allclose(np.asarray(idx.err), err, rtol=1e-4, atol=1e-6)


def test_pq_roundtrip_and_err(world):
    ds, _, engines = world
    idx = engines["pq"].quant
    assert isinstance(idx, PQIndex) and idx.codes.dtype == jnp.uint8
    dec = np.asarray(decode_pq(idx))
    err = ((ds.vectors - dec) ** 2).sum(axis=1)
    np.testing.assert_allclose(np.asarray(idx.err), err, rtol=1e-4, atol=1e-6)
    # codebooks beat the trivial one-centroid quantizer on reconstruction
    mse_pq = err.mean()
    mse_mean = ((ds.vectors - ds.vectors.mean(0)) ** 2).sum(axis=1).mean()
    assert mse_pq < 0.5 * mse_mean


def test_pq_adc_matches_decoded_distance(world):
    """ADC distance == exact distance to the reconstructed vector: the LUT
    decomposition is algebraically exact for PQ."""
    ds, _, engines = world
    idx = engines["pq"].quant
    rng = np.random.default_rng(0)
    q = ds.vectors[rng.integers(0, ds.n, 6)] + 0.03 * rng.normal(
        size=(6, ds.dim)).astype(np.float32)
    prep = prepare_query("pq", idx, q)
    sub = jnp.asarray(rng.integers(0, ds.n, 50))
    qg = QuantGather(prep=prep, codes=idx.codes[sub][None].astype(jnp.int32)
                     .repeat(6, 0), norms=idx.norms[sub][None].repeat(6, 0))
    got = np.asarray(quant_dist("pq", qg))
    dec = np.asarray(decode_pq(idx))[np.asarray(sub)]
    want = ((q[:, None, :] - dec[None, :, :]) ** 2).sum(-1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_int8_adc_error_within_query_quantization_bound(world):
    ds, _, engines = world
    idx = engines["int8"].quant
    rng = np.random.default_rng(1)
    q = ds.vectors[rng.integers(0, ds.n, 6)].astype(np.float32)
    prep = prepare_query("int8", idx, q)
    sub = np.asarray(rng.integers(0, ds.n, 64))
    codes_g = idx.codes[jnp.asarray(sub)][None].repeat(6, 0)
    norms_g = idx.norms[jnp.asarray(sub)][None].repeat(6, 0)
    got = np.asarray(quant_dist(
        "int8", QuantGather(prep=prep, codes=codes_g, norms=norms_g)))
    dec = np.asarray(decode_int8(idx))[sub]
    want = ((q[:, None, :] - dec[None, :, :]) ** 2).sum(-1)
    # the only approximation vs the decoded distance is quantizing the query
    # factor qs to int8: |qs - sq*qq| <= sq/2 per dim, |c| <= 127
    bound = (np.asarray(prep.sq)[:, None] * 127 * ds.dim) + 1e-4
    assert np.all(np.abs(got - want) <= bound)
    # and empirically it is far tighter than the worst case
    assert np.abs(got - want).mean() < 0.05 * want.mean()


def test_codec_key_identity(world):
    ds, _, engines = world
    assert engines["float32"].codec_key() == "float32"
    k8, kpq = engines["int8"].codec_key(), engines["pq"].codec_key()
    assert k8.startswith("int8:") and kpq.startswith("pq:") and k8 != kpq
    # a per-call precision override keys under what actually runs: a quant
    # engine served at float32 must cache as float32, not as its codec
    cfg32 = SearchConfig(k=5, queue_size=64, precision="float32")
    assert engines["pq"].codec_key(cfg32) == "float32"
    assert engines["pq"].codec_key(SearchConfig(k=5, queue_size=64)) == kpq
    # same corpus + same codec params → same identity (cache-collide on
    # purpose); a retrained codebook (different seed) → different identity
    rebuilt = build_quant_index("pq", ds.vectors, **QCFG)
    assert codec_key("pq", rebuilt) == kpq
    other = build_quant_index("pq", ds.vectors, **{**QCFG, "seed": 7})
    assert codec_key("pq", other) != kpq


def test_pq_memory_reduction(world):
    ds, _, engines = world
    f32 = np.asarray(engines["pq"].base_vectors).nbytes
    assert f32 / index_nbytes(engines["pq"].quant) >= 2.0  # dim=24 world;
    # the >=4x acceptance claim is measured at benchmark scale (dim 64+)


# ---------------------------------------------------- traversal parity ----
@pytest.mark.parametrize("precision", ["int8", "pq"])
@pytest.mark.parametrize("mode", ["post", "pre"])
def test_dense_pallas_parity_compressed(world, precision, mode):
    """Identical top-k ids, NDC, queue contents, and bias counters across
    backends in the compressed domain (shared ADC expression)."""
    ds, _, engines = world
    wl, cfg = _workload(ds)
    cfg = dataclasses.replace(cfg, mode=mode)
    eng = engines[precision]
    sd = eng.search(dataclasses.replace(cfg, backend="dense"),
                    wl.queries, wl.spec, 1200)
    sp = eng.search(dataclasses.replace(cfg, backend="pallas"),
                    wl.queries, wl.spec, 1200)
    np.testing.assert_array_equal(np.asarray(sd.res_idx), np.asarray(sp.res_idx))
    np.testing.assert_array_equal(np.asarray(sd.cnt), np.asarray(sp.cnt))
    np.testing.assert_array_equal(np.asarray(sd.cand_idx), np.asarray(sp.cand_idx))
    np.testing.assert_array_equal(np.asarray(sd.q_err_sum),
                                  np.asarray(sp.q_err_sum))
    np.testing.assert_allclose(np.asarray(sd.res_dist), np.asarray(sp.res_dist),
                               rtol=1e-6, atol=1e-6)


def test_float32_engine_unchanged_by_quant_build(world):
    """A precision="float32" engine and a quantized engine searching with
    an explicit float32 override produce bit-identical results — the
    float32 path is untouched by the quant layer."""
    ds, _, engines = world
    wl, cfg = _workload(ds, seed=11)
    a = engines["float32"].search(cfg, wl.queries, wl.spec, 900)
    cfg32 = dataclasses.replace(cfg, precision="float32")
    b = engines["int8"].search(cfg32, wl.queries, wl.spec, 900)
    np.testing.assert_array_equal(np.asarray(a.res_idx), np.asarray(b.res_idx))
    np.testing.assert_array_equal(np.asarray(a.res_dist), np.asarray(b.res_dist))
    np.testing.assert_array_equal(np.asarray(a.cnt), np.asarray(b.cnt))


def test_precision_without_index_raises(world):
    ds, _, engines = world
    wl, cfg = _workload(ds)
    with pytest.raises(ValueError, match="without a quant index"):
        engines["float32"].search(dataclasses.replace(cfg, precision="int8"),
                                  wl.queries, wl.spec, 100)


# ------------------------------------------------- in-kernel ADC (TPU) ----
@pytest.mark.parametrize("precision", ["int8", "pq"])
def test_fused_kernel_interpret_vs_oracle(world, precision):
    """The real Pallas kernel body (interpret mode) against an independent
    numpy ADC oracle + the shared host merge path.

    Micro buffer sizes (wq=16, wr=8): the interpret path still compiles
    the statically unrolled bitonic networks through XLA:CPU, whose
    compile time explodes exponentially in network width (see
    kernels/topk.py) — the ADC dataflow under test is width-independent.
    """
    from repro.filters.compile import compile_filters
    from repro.kernels.fused_step import fused_step, fused_step_host
    from repro.kernels.topk import pack_payload

    ds, _, engines = world
    idx = engines[precision].quant
    rng = np.random.default_rng(2)
    b, r, m, k = 5, 4, 8, 2
    q = ds.vectors[rng.integers(0, ds.n, b)].astype(np.float32)
    nb = rng.integers(0, ds.n, (b, r)).astype(np.int32)
    is_new = jnp.asarray(rng.random((b, r)) < 0.8)
    prog = compile_filters([Range(0.0, 1.0)] * b, ds.n_words,
                           ds.n_value_attrs)
    prog = type(prog)(*(jnp.asarray(a) for a in prog))
    labels_g = jnp.asarray(ds.labels_packed)[nb]
    values_g = jnp.asarray(ds.value_matrix)[nb]
    cand_dist = jnp.sort(jnp.asarray(rng.random((b, m)), jnp.float32), axis=1)
    cand_pay = pack_payload(jnp.asarray(rng.integers(0, ds.n, (b, m)),
                                        jnp.int32),
                            jnp.zeros((b, m), bool), jnp.ones((b, m), bool))
    res_dist = jnp.full((b, k), jnp.inf)
    res_idx = jnp.full((b, k), -1, jnp.int32)

    prep = prepare_query(precision, idx, q)
    codes_g = idx.codes[nb]
    if codes_g.dtype == jnp.uint8:
        codes_g = codes_g.astype(jnp.int32)
    qg = QuantGather(prep=prep, codes=codes_g, norms=idx.norms[nb])

    kern = fused_step(jnp.asarray(q), None, jnp.asarray(nb), is_new, prog,
                      labels_g, values_g, cand_dist, cand_pay, res_dist,
                      res_idx, pre=False, interpret=True, quant=qg,
                      precision=precision)
    host = fused_step_host(jnp.asarray(q), None, jnp.asarray(nb), is_new,
                           prog, labels_g, values_g, cand_dist, cand_pay,
                           res_dist, res_idx, pre=False, quant=qg,
                           precision=precision)

    # independent oracle for the distance block: decode + numpy arithmetic
    dec = np.asarray(decode_int8(idx) if precision == "int8"
                     else decode_pq(idx))
    if precision == "int8":
        # the kernel quantizes the query factor; mirror it independently
        qq = np.asarray(prep.qq, np.int64)
        sq = np.asarray(prep.sq)
        qn = np.asarray(prep.qn)
        codes = np.asarray(idx.codes, np.int64)[nb]
        norms = np.asarray(idx.norms)[nb]
        dot = (qq[:, None, :] * codes).sum(-1)
        oracle = np.maximum(qn[:, None] + norms - 2.0 * sq[:, None] * dot, 0.0)
    else:
        oracle = ((q[:, None, :] - dec[nb]) ** 2).sum(-1)
    # kernel vs the shared host path (same semantics, independent merge
    # implementation: unrolled bitonic network vs log-depth sorted merge)
    np.testing.assert_array_equal(np.asarray(kern[3]), np.asarray(host[3]))
    np.testing.assert_allclose(np.asarray(kern[2]), np.asarray(host[2]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(kern[0]), np.asarray(host[0]),
                               rtol=1e-5, atol=1e-5)
    # result-set distances equal the oracle distances of the chosen ids
    ri = np.asarray(kern[3])
    rd = np.asarray(kern[2])
    for i in range(b):
        for j in range(k):
            if ri[i, j] < 0:
                continue
            pos = np.where(nb[i] == ri[i, j])[0]
            assert np.isclose(rd[i, j], oracle[i, pos].min(), rtol=1e-5,
                              atol=1e-5)


# ------------------------------------------------------------- rerank ----
def test_rerank_restores_exact_topk_over_pool(world):
    """Exact contract: rerank == brute-force float32 top-k over the pool
    (result set ∪ valid candidates), and on an exhaustive traversal it
    recovers the true filtered top-k despite compressed routing."""
    ds, _, engines = world
    eng = engines["pq"]
    wl, _ = _workload(ds, batch=8, seed=9)
    cfg = SearchConfig(k=5, queue_size=512, backend="pallas")
    filt = [Range(0.0, 1.0)] * wl.batch           # matches every node
    st = eng.search(cfg, wl.queries, filt, BIG_BUDGET)
    rd, ri = eng.rerank_arrays(wl.queries, st)
    rd, ri = np.asarray(rd), np.asarray(ri)

    # pool oracle (host, independent): float32 distances over pool ids
    cand = np.asarray(st.cand_idx)
    cvalid = np.asarray(st.cand_valid)
    res = np.asarray(st.res_idx)
    for i in range(wl.batch):
        pool = set(res[i][res[i] >= 0]) | set(cand[i][(cand[i] >= 0) & cvalid[i]])
        pool = np.asarray(sorted(pool))
        d = ((wl.queries[i][None, :] - ds.vectors[pool]) ** 2).sum(-1)
        order = np.argsort(d, kind="stable")[:5]
        np.testing.assert_array_equal(np.sort(pool[order]), np.sort(ri[i]))
        np.testing.assert_allclose(np.sort(d[order]), np.sort(rd[i]),
                                   rtol=1e-5)

    # end-to-end: exhaustive compressed traversal + rerank == exact
    gt_idx, _ = filtered_knn_exact(wl.queries, ds.vectors, filt,
                                   ds.labels_packed, ds.value_matrix, 5)
    assert recall_at_k(ri, gt_idx).mean() == 1.0


def test_rerank_improves_recall(world):
    ds, _, engines = world
    eng = engines["pq"]
    wl, cfg = _workload(ds, batch=16, seed=13)
    cfg = dataclasses.replace(cfg, backend="pallas")
    gt_idx, _ = filtered_knn_exact(wl.queries, ds.vectors, wl.spec,
                                   ds.labels_packed, ds.values, cfg.k)
    st = eng.search(cfg, wl.queries, wl.spec, BIG_BUDGET)
    before = recall_at_k(np.asarray(st.res_idx), gt_idx).mean()
    after = recall_at_k(np.asarray(eng.rerank(cfg, wl.queries, st).res_idx),
                        gt_idx).mean()
    assert after >= before
    # selective contain filters on a 2k-node graph cap reachability (the
    # paper's filtered-subgraph pathology), not the rerank — a loose floor
    # guards against gross regressions only; exactness is pinned by
    # test_rerank_restores_exact_topk_over_pool
    assert after >= 0.75


# ------------------------------------------------------ probe / resume ----
@pytest.mark.parametrize("precision", ["int8", "pq"])
@pytest.mark.parametrize("backend", ["dense", "pallas"])
def test_probe_resume_bitcompat_compressed(world, precision, backend):
    """Zero-overhead probe survives quantization: probe(budget=f) + resume
    == one-shot, bit for bit, within a precision mode."""
    ds, _, engines = world
    wl, cfg = _workload(ds, seed=7)
    cfg = dataclasses.replace(cfg, backend=backend)
    eng = engines[precision]
    one = eng.search(cfg, wl.queries, wl.spec, 700)
    st = eng.search(cfg, wl.queries, wl.spec, 120)
    st = eng.search(cfg, wl.queries, wl.spec, 700, state=st)
    np.testing.assert_array_equal(np.asarray(one.res_idx), np.asarray(st.res_idx))
    np.testing.assert_array_equal(np.asarray(one.res_dist),
                                  np.asarray(st.res_dist))
    np.testing.assert_array_equal(np.asarray(one.cnt), np.asarray(st.cnt))
    np.testing.assert_array_equal(np.asarray(one.cand_idx), np.asarray(st.cand_idx))
    np.testing.assert_array_equal(np.asarray(one.q_err_sum),
                                  np.asarray(st.q_err_sum))


# ------------------------------------------------- estimator features ----
def test_quant_bias_features_populate(world):
    from repro.core import FEATURE_NAMES, extract_features

    ds, _, engines = world
    wl, cfg = _workload(ds)
    i_mean = FEATURE_NAMES.index("quant_err_mean")
    i_head = FEATURE_NAMES.index("quant_err_head")
    z32 = np.asarray(extract_features(
        engines["float32"].search(cfg, wl.queries, wl.spec, 300)))
    zq = np.asarray(extract_features(
        engines["pq"].search(cfg, wl.queries, wl.spec, 300)))
    assert np.all(z32[:, [i_mean, i_head]] == 0.0)
    assert np.all(zq[:, [i_mean, i_head]] > 0.0)


def test_training_converges_on_quant_engine(world):
    """Compressed-domain convergence targets keep W_q labels informative
    (they would all collapse to exhaustion cost against float32 gt)."""
    from repro.core import generate_training_data

    ds, _, engines = world
    wl = make_label_workload(ds, batch=32, kind="contain", seed=10)
    cfg = SearchConfig(k=5, queue_size=64, backend="pallas")
    td = generate_training_data(engines["int8"], ds, wl, cfg,
                                probe_budget=48, chunk=16)
    assert td.converged.mean() > 0.3
    assert len(np.unique(td.w_q)) > 5


# ------------------------------------------------------------ serving ----
def test_scheduler_quant_engine_matches_oneshot(world):
    """Scheduled result on a quantized engine (probe → bucket → resume →
    rerank) is bit-identical to one-shot e2e_search with rerank."""
    from repro.core import CostEstimator, e2e_search, generate_training_data
    from repro.serve import (CostAwareScheduler, ServeConfig,
                             requests_from_workload)

    ds, _, engines = world
    eng = engines["int8"]
    cfg = SearchConfig(k=5, queue_size=64)
    wlt = make_label_workload(ds, batch=48, kind="contain", seed=10)
    td = generate_training_data(eng, ds, wlt, cfg, probe_budget=48, chunk=24)
    est = CostEstimator.fit(td.features, td.w_q, n_trees=40, depth=3)

    wl = make_label_workload(ds, batch=12, kind="contain", seed=5)
    one = e2e_search(eng, est, cfg, wl.queries, wl.spec, probe_budget=48,
                     alpha=1.5)
    sched = CostAwareScheduler(eng, est, cfg, ServeConfig(
        lane_width=8, buckets=(128, 512, None), probe_budget=48, alpha=1.5,
        cache_capacity=0))
    reqs = requests_from_workload(wl)
    for r in reqs:
        assert sched.submit(r, 0.0) == "queued"
    sched.run_until_idle(0.0)
    np.testing.assert_array_equal(np.stack([r.res_idx for r in reqs]),
                                  np.asarray(one.state.res_idx))
    np.testing.assert_array_equal(np.stack([r.res_dist for r in reqs]),
                                  np.asarray(one.state.res_dist))


# ----------------------------------------------------- graph.validate ----
def test_graph_validate_raises_real_exceptions():
    from repro.index.graph import GraphIndex

    good = GraphIndex(neighbors=np.asarray([[1], [0]], np.int32),
                      entry_point=0, dim=4)
    good.validate()
    with pytest.raises(TypeError, match="int32"):
        GraphIndex(np.asarray([[1], [0]], np.int64), 0, 4).validate()
    with pytest.raises(ValueError, match="out of range"):
        GraphIndex(np.asarray([[5], [0]], np.int32), 0, 4).validate()
    with pytest.raises(ValueError, match="self loop"):
        GraphIndex(np.asarray([[0], [0]], np.int32), 0, 4).validate()
    with pytest.raises(ValueError, match="entry_point"):
        GraphIndex(np.asarray([[1], [0]], np.int32), 9, 4).validate()
    with pytest.raises(ValueError, match="-1"):
        GraphIndex(np.asarray([[-3], [0]], np.int32), 0, 4).validate()


def test_engine_build_validates_graph(world):
    from repro.index.graph import GraphIndex

    ds, _, _ = world
    bad = GraphIndex(neighbors=np.full((ds.n, 4), ds.n, np.int32),
                     entry_point=0, dim=ds.dim)
    with pytest.raises(ValueError, match="out of range"):
        SearchEngine.build(ds, bad)
