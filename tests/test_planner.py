"""Plan-parity + property test layer for the adaptive per-query planner.

Pins the three contracts the planning layer is built on:
  1. the pre-filter scan plan is bit-identical to the bruteforce oracle on
     float32 (dense + pallas dispatch, match-nothing / match-all included);
  2. a planner forced to one plan equals calling that plan directly —
     counters included — so "auto" can only ever *choose*, never perturb;
  3. routing never loses recall: planner recall ≥ best single plan (−2pp)
     and planned NDC ≤ standard traversal NDC on selective conjunctions.
"""
import dataclasses
import functools

import numpy as np
import pytest

from repro.core import (PLANS, SearchConfig, SearchEngine, extract_features,
                        fit_planner, generate_plan_training_data,
                        planned_search, run_plan, scan_search, scan_stats)
from repro.core.planner import static_features
from repro.core.plans import ScanStats
from repro.core.step import gather_frontier
from repro.data import make_composite_workload, make_dataset
from repro.filters import And, Contain, Range
from repro.index import build_graph_index
from repro.index.bruteforce import filtered_knn_exact, recall_at_k

from tests._hyp_compat import given, settings, st


# Cached module-level builders (not only fixtures): the hypothesis shim's
# @given wrapper takes no pytest fixtures, so the property test calls these
# directly.
@functools.lru_cache(maxsize=1)
def _world():
    ds = make_dataset(n=2500, dim=24, n_clusters=6, alphabet_size=32, seed=0)
    graph = build_graph_index(ds.vectors, degree=16, seed=0)
    engine = SearchEngine.build(ds, graph)
    cfg = SearchConfig(k=5, queue_size=64, degree=16)
    return ds, engine, cfg


@functools.lru_cache(maxsize=1)
def _planner():
    ds, engine, cfg = _world()
    wl = make_composite_workload(ds, batch=96, seed=11, structure="mixed",
                                 selectivities=(0.01, 0.1, 0.3))
    data = generate_plan_training_data(engine, ds, wl, cfg, probe_budget=48,
                                       chunk=48)
    return fit_planner(data, probe_budget=48, n_trees=60, depth=4)


@pytest.fixture(scope="module")
def world():
    return _world()


@pytest.fixture(scope="module")
def planner():
    return _planner()


def _oracle(ds, wl_or_filters, queries, k):
    filt = (wl_or_filters.filters
            if hasattr(wl_or_filters, "filters") else wl_or_filters)
    return filtered_knn_exact(queries, ds.vectors, filt, ds.labels_packed,
                              ds.value_matrix, k)


# ------------------------------------------------------------ scan plan ----
@pytest.mark.parametrize("structure", ["and", "mixed"])
def test_scan_bit_identity_vs_oracle(world, structure):
    """The scan plan IS the oracle: same distance source, same stable tie
    order — identical idx and bitwise-identical f32 distances."""
    ds, engine, cfg = world
    wl = make_composite_workload(ds, batch=24, seed=3, structure=structure,
                                 selectivities=(0.01, 0.1, 0.4))
    st_ = scan_search(engine, cfg, wl.queries, wl.filters)
    gi, gd = _oracle(ds, wl, wl.queries, cfg.k)
    assert np.array_equal(np.asarray(st_.res_idx), gi)
    assert np.array_equal(
        np.asarray(st_.res_dist).view(np.uint32), gd.view(np.uint32))
    # cost accounting is closed-form: cnt == σ·N exactly, 0 traversal hops
    stats = scan_stats(engine, engine.compile(wl.filters))
    assert np.array_equal(np.asarray(st_.cnt), stats.counts)
    assert not np.asarray(st_.hops).any()
    assert not np.asarray(st_.active).any()   # terminal — never resumed


def test_scan_match_nothing_and_match_all(world):
    ds, engine, cfg = world
    exprs = [Range(1e9, 1e9 + 1),          # matches nothing
             Range(-1e9, 1e9),             # matches everything
             And(Contain([1]), Range(1e9, 1e9 + 1))]  # conjunction → nothing
    q = np.asarray(ds.vectors[:3], np.float32)
    st_ = scan_search(engine, cfg, q, exprs)
    gi, gd = _oracle(ds, exprs, q, cfg.k)
    assert np.array_equal(np.asarray(st_.res_idx), gi)
    assert np.array_equal(
        np.asarray(st_.res_dist).view(np.uint32), gd.view(np.uint32))
    cnt = np.asarray(st_.cnt)
    assert cnt[0] == 0 and cnt[1] == ds.vectors.shape[0] and cnt[2] == 0
    # match-nothing rows pad with the oracle's sentinels
    assert (np.asarray(st_.res_idx)[0] == -1).all()
    assert np.isinf(np.asarray(st_.res_dist)[0]).all()


def test_scan_pallas_kernel_matches_host(world):
    """The TPU scan path (the traversal's masked-distance Pallas kernel)
    agrees with the per-lane host path on SCAN_ALIGN-shaped blocks."""
    from repro.kernels.distance import (SCAN_ALIGN, scan_sqdist_lanes,
                                        sqdist_masked)

    rng = np.random.default_rng(0)
    b, v, d = 6, 2 * SCAN_ALIGN, 24
    q = rng.standard_normal((b, d)).astype(np.float32)
    x = rng.standard_normal((b, v, d)).astype(np.float32)
    mask = rng.random((b, v)) < 0.7
    host = np.asarray(scan_sqdist_lanes(q, x, mask))
    kern = np.asarray(sqdist_masked(q, x, mask, interpret=True))
    assert np.isinf(host[~mask]).all() and np.isinf(kern[~mask]).all()
    np.testing.assert_allclose(kern[mask], host[mask], rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError, match="SCAN_ALIGN"):
        scan_sqdist_lanes(q, x[:, : SCAN_ALIGN + 1], mask[:, : SCAN_ALIGN + 1])


def test_scan_lane_and_width_invariance(world):
    """A lane's scan result is independent of batchmates and of the padded
    gather width — the property serving-time batch shapes rely on."""
    ds, engine, cfg = world
    wl = make_composite_workload(ds, batch=12, seed=5, structure="and",
                                 selectivities=(0.02, 0.3))
    full = scan_search(engine, cfg, wl.queries, wl.filters)
    sub_idx = [1, 4, 9]
    sub = scan_search(engine, cfg, wl.queries[sub_idx],
                      [wl.exprs[i] for i in sub_idx])
    for leaf_full, leaf_sub in zip(
            (full.res_idx, full.res_dist, full.cand_dist, full.cnt),
            (sub.res_idx, sub.res_dist, sub.cand_dist, sub.cnt)):
        assert np.array_equal(np.asarray(leaf_full)[sub_idx],
                              np.asarray(leaf_sub))


def test_quant_scan_pool_covers_exact(world):
    """Compressed-domain scan + exact rerank recovers the float32 oracle
    exactly whenever the candidate queue holds the whole valid set."""
    ds, _, _ = world
    graph = build_graph_index(ds.vectors, degree=16, seed=0)
    engine8 = SearchEngine.build(ds, graph, precision="int8")
    cfg8 = SearchConfig(k=5, queue_size=64, degree=16, precision="int8")
    wl = make_composite_workload(ds, batch=16, seed=7, structure="and",
                                 selectivities=(0.005, 0.01))
    stats = scan_stats(engine8, engine8.compile(wl.filters))
    assert (stats.counts <= cfg8.queue_size).all()   # pool ⊇ valid set
    st_ = scan_search(engine8, cfg8, wl.queries, wl.filters)
    assert (np.asarray(st_.q_err_sum)[stats.counts > 0] > 0).all()
    st_ = engine8.rerank(cfg8, wl.queries, st_)
    gi, _ = _oracle(ds, wl, wl.queries, cfg8.k)
    assert np.array_equal(np.asarray(st_.res_idx), gi)


# ----------------------------------------------------------- widen mode ----
def _widen_frontier_ref(neighbors, u, stride):
    """Independent host reference for the widened frontier: 1-hop ∪ strided
    2-hop, first occurrence kept, later duplicates blanked to -1."""
    nb = list(neighbors[u])
    out = list(nb)
    n2 = len(neighbors[0][::stride])
    for v in nb:
        out.extend(list(neighbors[v][::stride]) if v >= 0 else [-1] * n2)
    seen, res = set(), []
    for x in out:
        if x >= 0 and x in seen:
            res.append(-1)
        else:
            res.append(int(x))
            if x >= 0:
                seen.add(int(x))
    return res


def test_widen_frontier_matches_reference(world):
    import jax.numpy as jnp

    ds, engine, cfg = world
    cfgw = dataclasses.replace(cfg, mode="widen", two_hop_stride=4)
    nb = np.asarray(engine.neighbors)
    rng = np.random.default_rng(3)
    u = rng.integers(0, nb.shape[0], size=8).astype(np.int32)
    got = np.asarray(gather_frontier(cfgw, jnp.asarray(nb), jnp.asarray(u)))
    for i, ui in enumerate(u):
        assert got[i].tolist() == _widen_frontier_ref(nb, ui, 4)


def test_widen_post_accounting_and_backend_parity(world):
    """widen pays distance NDC for every new neighbor (post accounting,
    unlike pre), and dense/pallas agree bitwise."""
    ds, engine, cfg = world
    wl = make_composite_workload(ds, batch=12, seed=9, structure="and",
                                 selectivities=(0.01, 0.05))
    cfgw = dataclasses.replace(cfg, mode="widen")
    st_ = engine.search(cfgw, wl.queries, wl.filters, budgets=600)
    assert np.array_equal(np.asarray(st_.cnt), np.asarray(st_.n_inspected))
    stp = engine.search(dataclasses.replace(cfgw, backend="pallas"),
                        wl.queries, wl.filters, budgets=600)
    for a, b in zip(st_, stp):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------- plan parity ----
@pytest.mark.parametrize("plan", PLANS)
def test_forced_plan_equals_direct(world, planner, plan):
    """planned_search(force_plan=X) ≡ run_plan(X) bitwise, every state
    leaf — counters included. The router can choose, never perturb."""
    ds, engine, cfg = world
    wl = make_composite_workload(ds, batch=16, seed=13, structure="mixed",
                                 selectivities=(0.01, 0.2))
    forced = planned_search(engine, planner, cfg, wl.queries, wl.filters,
                            probe_budget=48, alpha=1.2, force_plan=plan)
    direct = run_plan(engine, planner, plan, cfg, wl.queries, wl.filters,
                      probe_budget=48, alpha=1.2)
    assert (forced.plan == PLANS.index(plan)).all()
    for name, a, b in zip(direct._fields, forced.state, direct):
        assert np.array_equal(np.asarray(a), np.asarray(b)), name


def test_planner_degenerate_stats(world, planner):
    """Zero passing candidates / single-query batches route to scan at
    stage 0 (no probe) and every feature stays finite."""
    ds, engine, cfg = world
    exprs = [Range(1e9, 1e9 + 1)]            # matches nothing
    q = np.asarray(ds.vectors[:1], np.float32)
    res = planned_search(engine, planner, cfg, q, exprs, probe_budget=48)
    assert res.plan.tolist() == [0] and res.pre_probe.all()
    assert int(res.state.cnt[0]) == 0
    assert (np.asarray(res.state.res_idx)[0] == -1).all()
    assert np.isfinite(np.asarray(extract_features(res.state))).all()
    # static features are finite even at σ = 0
    stats = scan_stats(engine, engine.compile(exprs))
    sf = static_features(stats, engine.compile(exprs))
    assert np.isfinite(sf).all() and sf[0, 0] == 0.0


def test_scan_states_keep_features_finite(world):
    """extract_features on terminal scan states (the planner may hand them
    to downstream feature consumers) is NaN-free, including lanes whose
    queue is empty."""
    ds, engine, cfg = world
    exprs = [Range(1e9, 1e9 + 1), Range(-1e9, 1e9), Contain([1])]
    q = np.asarray(ds.vectors[:3], np.float32)
    st_ = scan_search(engine, cfg, q, exprs)
    assert np.isfinite(np.asarray(extract_features(st_))).all()


# ------------------------------------------------------- property tests ----
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6))
def test_property_planner_dominates_single_plans(seed):
    """On selective conjunctions the planner's recall is at least the best
    single plan's (−2pp) and its NDC no worse than standard traversal —
    the two clauses of the routing guarantee, at matched α."""
    ds, engine, cfg = _world()
    planner = _planner()
    wl = make_composite_workload(ds, batch=12, seed=seed, structure="and",
                                 selectivities=(0.005, 0.01))
    gi, _ = _oracle(ds, wl, wl.queries, cfg.k)
    auto = planned_search(engine, planner, cfg, wl.queries, wl.filters,
                          probe_budget=48, alpha=1.2)
    singles = {p: run_plan(engine, planner, p, cfg, wl.queries, wl.filters,
                           probe_budget=48, alpha=1.2) for p in PLANS}
    rec_auto = recall_at_k(np.asarray(auto.state.res_idx), gi).mean()
    best_single = max(
        recall_at_k(np.asarray(s.res_idx), gi).mean()
        for s in singles.values())
    assert rec_auto >= best_single - 0.02
    ndc_auto = np.asarray(auto.state.cnt, np.int64).mean()
    ndc_trav = np.asarray(singles["traverse"].cnt, np.int64).mean()
    assert ndc_auto <= ndc_trav
