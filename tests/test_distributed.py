"""Distribution substrate: sharding rules, quantized optimizer, checkpoint
round-trip (+ elastic reshard path), straggler mitigation, gradient
compression."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.fault_tolerance import (StepMonitor, best_mesh_shape,
                                               clamp_budgets)
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import (AdamWConfig, QBLOCK, adamw_update,
                                   dequantize, init_opt_state, quantize)
from repro.models.common import P, split_tree


# ------------------------------------------------------------- sharding ----
def test_spec_rules_divisibility():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        from repro.launch.mesh import make_test_mesh
        from repro.distributed.sharding import spec_for, batch_spec
        from jax.sharding import PartitionSpec as PS
        mesh = make_test_mesh((2, 4), ("data", "model"))
        # mlp divisible by model=4 -> sharded
        assert spec_for(mesh, (64, 128), ("embed", "mlp")) == PS("data", "model")
        # kv_heads=2 not divisible by 4 -> falls through; seq-parallel cache
        # (flash-decoding rule) takes model before head_dim
        assert spec_for(mesh, (8, 16, 2, 8), ("batch", "seq", "kv_heads", "head_dim")) \\
            == PS("data", "model")
        # batch=1 -> replicated batch, seq picks up data
        assert spec_for(mesh, (1, 64, 32), ("batch", "seq", None)) == PS(None, "data")
        assert batch_spec(mesh, 7) == PS(None)
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert "OK" in r.stdout, r.stderr


# ------------------------------------------------------ int8 optimizer ----
def test_quantize_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(33, 300)).astype(np.float32)) * 5.0
    qt = quantize(x)
    back = dequantize(qt, x.shape)
    err = np.abs(np.asarray(back) - np.asarray(x))
    scale = np.abs(np.asarray(x)).max()
    assert err.max() <= scale / 127 + 1e-6


@pytest.mark.parametrize("moment_dtype", ["float32", "int8"])
def test_adamw_converges_quadratic(moment_dtype):
    """Minimize ||p - target||² — int8 moments must still converge."""
    target = jnp.asarray(np.random.default_rng(1).normal(size=(4, 256)).astype(np.float32))
    params = {"w": P(jnp.zeros((4, 256)), ("embed", "mlp"))}
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, moment_dtype=moment_dtype)
    opt_p = init_opt_state(params, cfg)
    vals, _ = split_tree(params)
    opt, _ = split_tree(opt_p)

    @jax.jit
    def step(vals, opt):
        grads = jax.grad(lambda v: jnp.mean((v["w"] - target) ** 2))(vals)
        return adamw_update(vals, grads, opt, cfg)

    for _ in range(200):
        vals, opt = step(vals, opt)
    loss = float(jnp.mean((vals["w"] - target) ** 2))
    assert loss < 1e-2, loss


def test_grad_compression_error_feedback():
    """int8 EF round trip: compressed-grad training still converges."""
    from repro.train.train_step import TrainConfig, make_train_step, make_init_state

    class ToyModel:
        def init_params(self, key):
            return {"w": P(jnp.zeros((8, 32)), (None, None))}

        def loss(self, prm, batch):
            pred = batch["x"] @ prm["w"]
            return jnp.mean((pred - batch["y"]) ** 2), {"ce": jnp.float32(0)}

    rng = np.random.default_rng(2)
    w_true = rng.normal(size=(8, 32)).astype(np.float32)
    x = rng.normal(size=(64, 8)).astype(np.float32)
    y = x @ w_true
    model = ToyModel()
    tc = TrainConfig(opt=AdamWConfig(lr=0.02, weight_decay=0.0),
                     grad_compression="int8_ef")
    state_p = make_init_state(model, tc)(jax.random.key(0))
    state, _ = split_tree(state_p)
    step = jax.jit(make_train_step(model, tc))
    batch = {"x": jnp.asarray(x), "y": jnp.asarray(y)}
    for _ in range(300):
        state, metrics = step(state, batch)
    assert float(metrics["loss"]) < 0.05, float(metrics["loss"])


# ----------------------------------------------------------- checkpoint ----
def test_checkpoint_roundtrip(tmp_path):
    state = {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
        "step": jnp.int32(7),
    }
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(7, state)
    mgr.save(8, jax.tree.map(lambda x: x + 1, state))
    mgr.save(9, jax.tree.map(lambda x: x + 2, state))
    assert mgr.all_steps() == [8, 9]  # rotation
    abstract = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored, manifest = mgr.restore_latest(abstract)
    assert manifest["step"] == 9
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]) + 2)


def test_checkpoint_integrity_detection(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.ones((4,))}
    path = mgr.save(1, state)
    # corrupt the payload
    with open(f"{path}/arrays.npz", "r+b") as f:
        f.seek(-1, 2)
        last = f.read(1)
        f.seek(-1, 2)
        f.write(bytes([last[0] ^ 0xFF]))
    abstract = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    with pytest.raises(IOError):
        mgr.restore(1, abstract)


def test_checkpoint_elastic_reshard(tmp_path):
    """Save unsharded, restore onto a 8-device mesh (N→M path)."""
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.launch.mesh import make_test_mesh
        from repro.train.checkpoint import CheckpointManager
        state = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        mgr = CheckpointManager({str(tmp_path)!r})
        mgr.save(3, state)
        mesh = make_test_mesh((4, 2), ("data", "model"))
        sh = {{"w": NamedSharding(mesh, PartitionSpec("data", "model"))}}
        abstract = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        restored, m = mgr.restore(3, abstract, shardings=sh)
        assert restored["w"].sharding.num_devices == 8
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert "OK" in r.stdout, r.stderr


# ------------------------------------------------------------ stragglers ----
def test_best_mesh_shape():
    assert best_mesh_shape(512) == ((2, 16, 16), ("pod", "data", "model"))
    assert best_mesh_shape(256) == ((16, 16), ("data", "model"))
    assert best_mesh_shape(240) == ((15, 16), ("data", "model"))
    assert best_mesh_shape(768)[0] == (3, 16, 16)


def test_step_monitor_flags_straggler():
    mon = StepMonitor(factor=3.0)
    for i in range(10):
        assert mon.observe(i, 1.0) is None
    ev = mon.observe(10, 5.0)
    assert ev is not None and ev.step == 10


def test_clamp_budgets():
    b = np.array([10, 20, 30, 40, 100000])
    clamped, mask = clamp_budgets(b, quantile=0.75)
    assert clamped.max() <= np.quantile(b, 0.75) + 1
    assert mask.sum() == 1 and mask[-1]
