"""End-to-end behaviour tests for the E2E filtered-AKNN system."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (BIG_BUDGET, CostEstimator, SearchConfig, SearchEngine,
                        baselines, e2e_search, generate_training_data)
from repro.data import make_dataset, make_label_workload, make_range_workload
from repro.filters.predicates import (FilterSpec, PRED_CONTAIN, PRED_EQUAL,
                                      PRED_RANGE)
from repro.index import build_graph_index, filtered_knn_exact, knn_exact
from repro.index.bruteforce import recall_at_k, valid_mask


@pytest.fixture(scope="module")
def world():
    ds = make_dataset(n=4000, dim=32, n_clusters=8, alphabet_size=32, seed=0)
    graph = build_graph_index(ds.vectors, degree=20, seed=0)
    return ds, graph, SearchEngine.build(ds, graph)


def test_graph_quality_unfiltered(world):
    """Navigability: unfiltered recall@10 >= 0.9 at small beam."""
    ds, graph, engine = world
    rng = np.random.default_rng(5)
    q = ds.vectors[rng.integers(0, ds.n, 32)]
    spec = FilterSpec(PRED_RANGE, None, np.zeros(32, np.float32),
                      np.ones(32, np.float32))
    cfg = SearchConfig(k=10, queue_size=64, pred_kind=PRED_RANGE)
    st = engine.search(cfg, q, spec, BIG_BUDGET)
    gt, _ = knn_exact(q, ds.vectors, 10)
    assert recall_at_k(np.asarray(st.res_idx), gt).mean() > 0.9


@pytest.mark.parametrize("kind,ptag", [("contain", PRED_CONTAIN),
                                       ("equal", PRED_EQUAL)])
def test_filtered_search_only_returns_valid(world, kind, ptag):
    ds, graph, engine = world
    wl = make_label_workload(ds, batch=16, kind=kind, seed=3)
    cfg = SearchConfig(k=5, queue_size=128, pred_kind=ptag)
    st = engine.search(cfg, wl.queries, wl.spec, BIG_BUDGET)
    ok = valid_mask(wl.spec, ds.labels_packed, ds.values)
    ri = np.asarray(st.res_idx)
    for b in range(16):
        for ix in ri[b]:
            if ix >= 0:
                assert ok[b, ix], f"invalid node {ix} in results of lane {b}"


def test_range_filtered_recall(world):
    ds, graph, engine = world
    wl = make_range_workload(ds, batch=32, seed=4)
    cfg = SearchConfig(k=10, queue_size=512, pred_kind=PRED_RANGE)
    st = engine.search(cfg, wl.queries, wl.spec, BIG_BUDGET)
    gt, _ = filtered_knn_exact(wl.queries, ds.vectors, wl.spec,
                               ds.labels_packed, ds.values, 10)
    assert recall_at_k(np.asarray(st.res_idx), gt).mean() > 0.75


def test_budget_monotonicity(world):
    """More NDC budget can only improve (or equal) the result distances."""
    ds, graph, engine = world
    wl = make_label_workload(ds, batch=8, kind="contain", seed=6)
    cfg = SearchConfig(k=5, queue_size=256, pred_kind=PRED_CONTAIN)
    prev = None
    for budget in (50, 200, 1000, BIG_BUDGET):
        st = engine.search(cfg, wl.queries, wl.spec, budget)
        d = np.asarray(st.res_dist)
        if prev is not None:
            assert (d <= prev + 1e-5).all()
        prev = d


def test_probe_resume_equals_oneshot(world):
    """Zero-overhead probe: probe+resume == single search at same budget."""
    ds, graph, engine = world
    wl = make_label_workload(ds, batch=8, kind="contain", seed=7)
    cfg = SearchConfig(k=5, queue_size=128, pred_kind=PRED_CONTAIN)
    one = engine.search(cfg, wl.queries, wl.spec, 800)
    st = engine.search(cfg, wl.queries, wl.spec, 100)
    st = engine.search(cfg, wl.queries, wl.spec, 800, state=st)
    np.testing.assert_array_equal(np.asarray(one.res_idx), np.asarray(st.res_idx))
    np.testing.assert_array_equal(np.asarray(one.cnt), np.asarray(st.cnt))


def test_e2e_pipeline_beats_matched_naive(world):
    """At (approximately) matched mean NDC, E2E recall >= naive recall."""
    ds, graph, engine = world
    cfg = SearchConfig(k=10, queue_size=512, pred_kind=PRED_CONTAIN)
    wl_tr = make_label_workload(ds, batch=256, kind="contain", seed=10)
    td = generate_training_data(engine, ds, wl_tr, cfg, probe_budget=64, chunk=64)
    est = CostEstimator.fit(td.features, td.w_q, n_trees=120, depth=4)

    wl = make_label_workload(ds, batch=64, kind="contain", seed=99)
    gt, _ = filtered_knn_exact(wl.queries, ds.vectors, wl.spec,
                               ds.labels_packed, ds.values, 10)
    r = e2e_search(engine, est, cfg, wl.queries, wl.spec, probe_budget=64,
                   alpha=1.5)
    rec_e2e = recall_at_k(np.asarray(r.state.res_idx), gt).mean()
    ndc_e2e = float(np.asarray(r.state.cnt).mean())

    pts = []
    for ef in (32, 64, 128, 256, 512):
        st = baselines.naive_search(engine, cfg, wl.queries, wl.spec, ef)
        pts.append((float(np.asarray(st.cnt).mean()),
                    recall_at_k(np.asarray(st.res_idx), gt).mean()))
    xs, ys = zip(*sorted(pts))
    rec_naive = float(np.interp(ndc_e2e, xs, ys))
    assert rec_e2e >= rec_naive - 0.02, (rec_e2e, rec_naive, ndc_e2e)


def test_pre_mode_only_valid_in_queue(world):
    """ACORN-style PreFiltering: candidate queue holds valid nodes only."""
    ds, graph, engine = world
    wl = make_label_workload(ds, batch=8, kind="contain", seed=11)
    cfg = SearchConfig(k=5, queue_size=128, pred_kind=PRED_CONTAIN, mode="pre")
    st = engine.search(cfg, wl.queries, wl.spec, BIG_BUDGET)
    ci = np.asarray(st.cand_idx)
    ok = valid_mask(wl.spec, ds.labels_packed, ds.values)
    for b in range(8):
        members = ci[b][ci[b] >= 0]
        flags = np.array([ok[b, ix] for ix in members])
        # entry point may be invalid; allow at most that one
        assert (~flags).sum() <= 1
    # NDC in pre mode counts only valid distance computations
    assert (np.asarray(st.cnt) <= np.asarray(st.n_inspected)).all()


def test_estimator_quality_on_heldout(world):
    ds, graph, engine = world
    cfg = SearchConfig(k=10, queue_size=512, pred_kind=PRED_CONTAIN)
    wl_tr = make_label_workload(ds, batch=384, kind="contain", seed=21)
    td = generate_training_data(engine, ds, wl_tr, cfg, probe_budget=64, chunk=128)
    est = CostEstimator.fit(td.features, td.w_q, n_trees=150, depth=5)
    wl_ev = make_label_workload(ds, batch=128, kind="contain", seed=22)
    td_ev = generate_training_data(engine, ds, wl_ev, cfg, probe_budget=64,
                                   chunk=128)
    m = est.eval_metrics(td_ev.features, td_ev.w_q)
    assert m["spearman"] > 0.4, m  # paper range: 0.54-0.79 at full scale
