"""Filter algebra + compiler: canonicalization laws, compiled-program parity
vs the naive host oracle (property-based), single-clause bit-identity vs the
legacy FilterSpec path on both traversal backends, chunked selectivity."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st  # hypothesis or fallback

from repro.filters import (And, Contain, Equal, In, Not, Or, Range,
                           FilterProgram, FilterSpec, PRED_CONTAIN, PRED_EQUAL,
                           PRED_RANGE, canonical_dnf, canonical_key,
                           compile_filters, compile_spec, eval_expr,
                           eval_program_gathered, filter_matrix,
                           labels_from_mask, pack_labels, selectivity)

ALPHABET = 64   # 2 mask words
N_WORDS = 2
N_VALUES = 2


def _world(rng, n=160):
    labels = rng.integers(0, 1 << 32, (n, N_WORDS), dtype=np.uint32)
    values = rng.random((n, N_VALUES)).astype(np.float32)
    return labels, values


def _random_expr(rng, depth=2):
    """Random expression tree over the full algebra."""
    if depth == 0 or rng.random() < 0.4:
        c = int(rng.integers(0, 4))
        if c == 0:
            return Contain(rng.integers(0, ALPHABET, int(rng.integers(0, 3))))
        if c == 1:
            return Equal(rng.integers(0, ALPHABET, int(rng.integers(0, 3))))
        if c == 2:
            return In(rng.integers(0, ALPHABET, int(rng.integers(0, 3))))
        lo = float(rng.random())
        return Range(lo, lo + 0.6 * float(rng.random()),
                     attr=int(rng.integers(0, N_VALUES)))
    kind = int(rng.integers(0, 3))
    if kind == 2:
        return Not(_random_expr(rng, depth - 1))
    kids = [_random_expr(rng, depth - 1)
            for _ in range(int(rng.integers(1, 4)))]
    return And(*kids) if kind == 0 else Or(*kids)


def _eval_compiled(exprs, labels, values):
    """Compile a batch and evaluate it over the whole corpus at once."""
    prog = compile_filters(exprs, N_WORDS, N_VALUES)
    prog = FilterProgram(*(jnp.asarray(a) for a in prog))
    b = len(exprs)
    lg = jnp.broadcast_to(jnp.asarray(labels)[None], (b,) + labels.shape)
    vg = jnp.broadcast_to(jnp.asarray(values)[None], (b,) + values.shape)
    valid, _ = eval_program_gathered(prog, lg, vg)
    return np.asarray(valid)


# ----------------------------------------------------- compiled vs oracle ----
@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), batch=st.integers(1, 6))
def test_compiled_program_matches_host_oracle(seed, batch):
    """Property: for random expression batches (heterogeneous structure),
    the compiled fixed-shape program equals the naive recursive evaluator
    on every item — the tentpole's correctness core."""
    rng = np.random.default_rng(seed)
    labels, values = _world(rng)
    exprs = [_random_expr(rng) for _ in range(batch)]
    got = _eval_compiled(exprs, labels, values)
    want = np.stack([eval_expr(e, labels, values) for e in exprs])
    np.testing.assert_array_equal(got, want, err_msg=repr(exprs))


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_canonicalization_laws(seed):
    """Commutativity collides; double negation is identity; De Morgan holds
    both semantically (oracle) and canonically (key equality)."""
    rng = np.random.default_rng(seed)
    labels, values = _world(rng, n=80)
    a, b = _random_expr(rng, 1), _random_expr(rng, 1)
    assert canonical_key(And(a, b)) == canonical_key(And(b, a))
    assert canonical_key(Or(a, b)) == canonical_key(Or(b, a))
    assert canonical_key(Not(Not(a))) == canonical_key(a)
    assert canonical_key(Not(And(a, b))) == canonical_key(Or(Not(a), Not(b)))
    # canonical equivalence must imply semantic equivalence
    np.testing.assert_array_equal(
        eval_expr(Not(And(a, b)), labels, values),
        eval_expr(Or(Not(a), Not(b)), labels, values))


def test_canonical_keys_distinguish_structure():
    a, b = Contain([3]), Range(0.2, 0.8)
    assert canonical_key(And(a, b)) != canonical_key(Or(a, b))
    assert canonical_key(a) != canonical_key(Not(a))
    assert canonical_key(Contain([3])) != canonical_key(Equal([3]))
    assert canonical_key(Contain([3])) != canonical_key(In([3]))
    assert canonical_key(Range(0.2, 0.8)) != canonical_key(Range(0.2, 0.8, attr=1))


def test_degenerate_expressions():
    rng = np.random.default_rng(0)
    labels, values = _world(rng, n=50)
    cases = {
        Contain(()): True,     # ⊆ of the empty set
        In(()): False,         # any-of nothing
        Or(): False,           # empty disjunction
        And(): True,           # empty conjunction
        And(Contain([3]), Not(Contain([3]))): False,  # contradiction
        Or(Contain([3]), Not(Contain([3]))): True,    # tautology
    }
    got = _eval_compiled(list(cases), labels, values)
    for i, (e, const) in enumerate(cases.items()):
        assert (got[i] == const).all(), e
        np.testing.assert_array_equal(got[i], eval_expr(e, labels, values))


def test_labels_from_mask_roundtrip():
    for labs in [(), (0,), (31, 32, 63), (5, 17, 40)]:
        mask = pack_labels([labs], ALPHABET)[0]
        assert labels_from_mask(mask) == labs


# -------------------------------------------- legacy FilterSpec bit-identity ----
@pytest.fixture(scope="module")
def world():
    from repro.core import SearchConfig, SearchEngine
    from repro.data import make_dataset
    from repro.index import build_graph_index

    ds = make_dataset(n=2500, dim=24, n_clusters=6, alphabet_size=32, seed=0)
    graph = build_graph_index(ds.vectors, degree=16, seed=0)
    return ds, SearchEngine.build(ds, graph), SearchConfig(k=5, queue_size=64)


@pytest.mark.parametrize("backend", ["dense", "pallas"])
@pytest.mark.parametrize("kind", ["contain", "equal", "range"])
def test_single_clause_bit_identity_vs_filterspec(world, backend, kind):
    """The acceptance bar: a single-clause compiled program (via the
    FilterSpec.to_expr shim) returns bit-identical top-k ids, distances,
    NDC, and every counter to the legacy FilterSpec entry point, on both
    traversal backends."""
    from repro.data import make_label_workload, make_range_workload

    ds, engine, cfg = world
    cfg = dataclasses.replace(cfg, backend=backend)
    wl = (make_range_workload(ds, batch=12, seed=4) if kind == "range"
          else make_label_workload(ds, batch=12, kind=kind, seed=4))
    via_spec = engine.search(cfg, wl.queries, wl.spec, 1200)
    via_expr = engine.search(cfg, wl.queries, wl.spec.to_expr(), 1200)
    for field in ("res_idx", "res_dist", "cnt", "cand_idx", "n_inspected",
                  "n_valid_visited", "hops"):
        np.testing.assert_array_equal(
            np.asarray(getattr(via_spec, field)),
            np.asarray(getattr(via_expr, field)), err_msg=field)


def test_spec_compile_matches_expr_compile():
    """compile_spec (vectorized) == compile_filters(spec.to_expr())."""
    rng = np.random.default_rng(3)
    masks = rng.integers(0, 1 << 16, (6, N_WORDS), dtype=np.uint32)
    for kind in (PRED_CONTAIN, PRED_EQUAL):
        spec = FilterSpec(kind, masks)
        a = compile_spec(spec, N_WORDS, N_VALUES)
        b = compile_filters(spec.to_expr(), N_WORDS, N_VALUES)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    lo = rng.random(6).astype(np.float32)
    spec = FilterSpec(PRED_RANGE, None, lo, lo + 0.25)
    a = compile_spec(spec, N_WORDS, N_VALUES)
    b = compile_filters(spec.to_expr(), N_WORDS, N_VALUES)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# --------------------------------------------------- selectivity chunking ----
def test_selectivity_chunking_equivalent():
    """The [B, N, W]-blowup fix: chunked evaluation must be exact, for both
    FilterSpec batches and expression lists, at every chunk/batch ratio."""
    rng = np.random.default_rng(1)
    labels, values = _world(rng, n=300)
    masks = rng.integers(0, 1 << 10, (17, N_WORDS), dtype=np.uint32)
    spec = FilterSpec(PRED_CONTAIN, masks)
    exprs = [_random_expr(rng) for _ in range(17)]
    for filt in (spec, exprs):
        full = selectivity(filt, labels, values, chunk=10**9)
        for chunk in (1, 4, 16, 17, 64):
            np.testing.assert_array_equal(
                selectivity(filt, labels, values, chunk=chunk), full)
    # and the chunked oracle agrees with the per-query matrix
    np.testing.assert_allclose(
        selectivity(exprs, labels, values, chunk=5),
        filter_matrix(exprs, labels, values).mean(axis=1))


def test_filter_matrix_handles_single_channel_values():
    """Legacy [N] value arrays keep working for FilterSpec ranges."""
    rng = np.random.default_rng(2)
    v1 = rng.random(100).astype(np.float32)
    spec = FilterSpec(PRED_RANGE, None, np.asarray([0.2], np.float32),
                      np.asarray([0.7], np.float32))
    a = filter_matrix(spec, None, v1)
    b = filter_matrix(spec, None, np.stack([v1, rng.random(100)], axis=1))
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a[0], (v1 >= 0.2) & (v1 <= 0.7))
