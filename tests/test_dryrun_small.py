"""Reduced-mesh dry-run: proves the (arch × mode × mesh) lowering machinery
end-to-end on an 8-device host mesh with tiny configs. The production-mesh
(256/512-way) runs live in launch/dryrun.py; this is the CI-sized replica.
"""
import subprocess
import sys
import textwrap

import pytest

ARCHS = ["olmo-1b", "phi3.5-moe-42b-a6.6b", "mamba2-2.7b", "whisper-small"]


@pytest.mark.parametrize("arch", ARCHS)
def test_tiny_mesh_train_lowering(arch):
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp
        from repro.configs import get_arch
        from repro.models import build_model, split_tree
        from repro.launch.mesh import make_test_mesh
        from repro.distributed.sharding import tree_shardings, batch_spec
        from repro.train.optimizer import AdamWConfig
        from repro.train.train_step import TrainConfig, make_init_state, make_train_step
        from jax.sharding import NamedSharding, PartitionSpec

        cfg = get_arch({arch!r}).tiny()
        cfg = dataclasses.replace(cfg, param_dtype=jnp.float32,
                                  compute_dtype=jnp.float32)
        model = build_model(cfg)
        mesh = make_test_mesh((4, 2), ("data", "model"))
        tc = TrainConfig(opt=AdamWConfig())
        state_abs = jax.eval_shape(make_init_state(model, tc), jax.random.key(0))
        sds, axes = split_tree(state_abs)
        sh = tree_shardings(mesh, sds, axes)
        gb, s = 8, 32
        bspec = batch_spec(mesh, gb)
        batch_sds = {{"tokens": jax.ShapeDtypeStruct((gb, s), jnp.int32)}}
        batch_sh = {{"tokens": NamedSharding(mesh, bspec)}}
        if cfg.family in ("encdec", "vlm"):
            se = cfg.encoder_seq if cfg.family == "encdec" else cfg.vision_seq
            batch_sds["enc"] = jax.ShapeDtypeStruct((gb, se, cfg.d_model), jnp.float32)
            batch_sh["enc"] = NamedSharding(mesh, PartitionSpec(*bspec, None, None))
        step = make_train_step(model, tc)
        with mesh:
            lowered = jax.jit(step, in_shardings=(sh, batch_sh),
                              out_shardings=(sh, None)).lower(sds, batch_sds)
        compiled = lowered.compile()
        ca = compiled.cost_analysis()
        assert (ca[0] if isinstance(ca, list) else ca).get("flops", 0) > 0
        print("OK", {arch!r})
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "OK" in r.stdout, r.stderr[-3000:]
