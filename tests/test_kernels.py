"""Per-kernel validation: shape/dtype sweeps + hypothesis, vs ref.py oracles.

Kernels execute in interpret mode on CPU (the kernel bodies themselves),
so these tests validate exactly what the TPU lowering would compute.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.gbdt import train_gbdt
from repro.kernels import ops, ref
from repro.kernels.topk import pack_payload, unpack_payload


# ------------------------------------------------------------- distance ----
@pytest.mark.parametrize("b,r,d", [(4, 8, 16), (8, 32, 64), (5, 17, 33),
                                   (16, 64, 128), (1, 1, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sqdist_shapes(b, r, d, dtype):
    key = jax.random.key(b * 1000 + r + d)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, d), dtype)
    x = jax.random.normal(k2, (b, r, d), dtype)
    mask = jax.random.bernoulli(k3, 0.7, (b, r))
    got = ops.batched_sqdist(q, x, mask)
    want = ref.sqdist_masked_ref(q, x, mask)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    finite = np.isfinite(np.asarray(want))
    np.testing.assert_allclose(np.asarray(got)[finite], np.asarray(want)[finite],
                               rtol=tol, atol=tol)
    assert np.all(np.isinf(np.asarray(got)[~finite]))


@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 12), r=st.integers(1, 40), d=st.integers(1, 96),
       seed=st.integers(0, 2**31 - 1))
def test_sqdist_hypothesis(b, r, d, seed):
    key = jax.random.key(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, d))
    x = jax.random.normal(k2, (b, r, d))
    mask = jax.random.bernoulli(k3, 0.5, (b, r))
    got = np.asarray(ops.batched_sqdist(q, x, mask))
    want = np.asarray(ref.sqdist_masked_ref(q, x, mask))
    finite = np.isfinite(want)
    np.testing.assert_allclose(got[finite], want[finite], rtol=2e-5, atol=2e-5)
    # invariant: distances are non-negative
    assert (got[finite] >= 0).all()


# ----------------------------------------------------------------- top-M ----
@pytest.mark.parametrize("b,m,r", [(4, 16, 8), (8, 128, 32), (3, 64, 64),
                                   (2, 512, 64)])
def test_topm_merge(b, m, r):
    rng = np.random.default_rng(m * 7 + r)
    dist = np.sort(rng.random((b, m)).astype(np.float32), axis=1)
    dist[:, m // 2 :] = np.inf  # half-empty buffers
    pay = rng.integers(0, 1 << 20, (b, m)).astype(np.int32)
    pay[np.isinf(dist)] = -1
    nd = rng.random((b, r)).astype(np.float32)
    npay = rng.integers(0, 1 << 20, (b, r)).astype(np.int32)

    gd, gp = ops.queue_merge(jnp.asarray(dist), jnp.asarray(pay),
                             jnp.asarray(nd), jnp.asarray(npay))
    wd, wp = ref.topm_merge_ref(jnp.asarray(dist), jnp.asarray(pay),
                                jnp.asarray(nd), jnp.asarray(npay))
    np.testing.assert_array_equal(np.asarray(gd), np.asarray(wd))
    np.testing.assert_array_equal(np.asarray(gp), np.asarray(wp))
    # semantic check vs plain sort
    alld = np.concatenate([dist, nd], axis=1)
    want_sorted = np.sort(alld, axis=1)[:, :m]
    np.testing.assert_allclose(np.asarray(gd), want_sorted)
    # output sortedness invariant
    g = np.asarray(gd)
    assert (np.diff(g, axis=1)[np.isfinite(g[:, 1:])] >= 0).all()


def test_payload_pack_roundtrip():
    idx = jnp.asarray([-1, 0, 5, (1 << 29) - 1], jnp.int32)
    exp = jnp.asarray([False, True, False, True])
    val = jnp.asarray([False, False, True, True])
    i2, e2, v2 = unpack_payload(pack_payload(idx, exp, val))
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(idx))
    np.testing.assert_array_equal(np.asarray(e2)[1:], np.asarray(exp)[1:])
    np.testing.assert_array_equal(np.asarray(v2)[1:], np.asarray(val)[1:])


# ------------------------------------------------------------------ gbdt ----
@pytest.mark.parametrize("n,f,trees,depth", [(64, 8, 20, 3), (128, 28, 60, 5),
                                             (33, 5, 7, 2)])
def test_gbdt_kernel_vs_model(n, f, trees, depth):
    rng = np.random.default_rng(n + f)
    x = rng.normal(size=(n, f)).astype(np.float32)
    y = x[:, 0] * 2 + np.sin(x[:, min(1, f - 1)]) + 0.1 * rng.normal(size=n)
    model = train_gbdt(x, y, n_trees=trees, depth=depth, learning_rate=0.2)
    want = model.predict(x)
    feats = jnp.asarray(x)
    got = ops.estimator_predict(
        feats, (jnp.asarray(model.feat), jnp.asarray(model.thresh),
                jnp.asarray(model.leaf), model.base), model.depth)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


def test_gbdt_kernel_matches_jax_path():
    from repro.core.gbdt import predict_jax

    rng = np.random.default_rng(0)
    x = rng.normal(size=(50, 12)).astype(np.float32)
    y = (x**2).sum(axis=1)
    model = train_gbdt(x, y, n_trees=40, depth=4)
    feats = jnp.asarray(x)
    a = predict_jax(model.pack_jax(), feats, model.depth)
    b = ops.estimator_predict(
        feats, (jnp.asarray(model.feat), jnp.asarray(model.thresh),
                jnp.asarray(model.leaf), model.base), model.depth)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)
