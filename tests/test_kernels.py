"""Per-kernel validation: shape/dtype sweeps + hypothesis, vs ref.py oracles.

Kernels execute in interpret mode on CPU (the kernel bodies themselves),
so these tests validate exactly what the TPU lowering would compute.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st  # hypothesis or fallback

from repro.core.gbdt import train_gbdt
from repro.kernels import ops, ref
from repro.kernels.topk import pack_payload, unpack_payload


# ------------------------------------------------------------- distance ----
@pytest.mark.parametrize("b,r,d", [(4, 8, 16), (8, 32, 64), (5, 17, 33),
                                   (16, 64, 128), (1, 1, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sqdist_shapes(b, r, d, dtype):
    key = jax.random.key(b * 1000 + r + d)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, d), dtype)
    x = jax.random.normal(k2, (b, r, d), dtype)
    mask = jax.random.bernoulli(k3, 0.7, (b, r))
    got = ops.batched_sqdist(q, x, mask)
    want = ref.sqdist_masked_ref(q, x, mask)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    finite = np.isfinite(np.asarray(want))
    np.testing.assert_allclose(np.asarray(got)[finite], np.asarray(want)[finite],
                               rtol=tol, atol=tol)
    assert np.all(np.isinf(np.asarray(got)[~finite]))


@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 12), r=st.integers(1, 40), d=st.integers(1, 96),
       seed=st.integers(0, 2**31 - 1))
def test_sqdist_hypothesis(b, r, d, seed):
    key = jax.random.key(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, d))
    x = jax.random.normal(k2, (b, r, d))
    mask = jax.random.bernoulli(k3, 0.5, (b, r))
    got = np.asarray(ops.batched_sqdist(q, x, mask))
    want = np.asarray(ref.sqdist_masked_ref(q, x, mask))
    finite = np.isfinite(want)
    np.testing.assert_allclose(got[finite], want[finite], rtol=2e-5, atol=2e-5)
    # invariant: distances are non-negative
    assert (got[finite] >= 0).all()


# ----------------------------------------------------------------- top-M ----
@pytest.mark.parametrize("b,m,r", [(4, 16, 8), (8, 128, 32), (3, 64, 64),
                                   (2, 512, 64)])
def test_topm_merge(b, m, r):
    rng = np.random.default_rng(m * 7 + r)
    dist = np.sort(rng.random((b, m)).astype(np.float32), axis=1)
    dist[:, m // 2 :] = np.inf  # half-empty buffers
    pay = rng.integers(0, 1 << 20, (b, m)).astype(np.int32)
    pay[np.isinf(dist)] = -1
    nd = rng.random((b, r)).astype(np.float32)
    npay = rng.integers(0, 1 << 20, (b, r)).astype(np.int32)

    gd, gp = ops.queue_merge(jnp.asarray(dist), jnp.asarray(pay),
                             jnp.asarray(nd), jnp.asarray(npay))
    wd, wp = ref.topm_merge_ref(jnp.asarray(dist), jnp.asarray(pay),
                                jnp.asarray(nd), jnp.asarray(npay))
    np.testing.assert_array_equal(np.asarray(gd), np.asarray(wd))
    np.testing.assert_array_equal(np.asarray(gp), np.asarray(wp))
    # semantic check vs plain sort
    alld = np.concatenate([dist, nd], axis=1)
    want_sorted = np.sort(alld, axis=1)[:, :m]
    np.testing.assert_allclose(np.asarray(gd), want_sorted)
    # output sortedness invariant
    g = np.asarray(gd)
    assert (np.diff(g, axis=1)[np.isfinite(g[:, 1:])] >= 0).all()


def test_topm_merge_host_stable_on_ties():
    """Host merge == stable argsort over [old|new] even with tied keys."""
    from repro.kernels.topk import topm_merge_host

    rng = np.random.default_rng(7)
    b, m, r = 6, 32, 16
    dist = np.sort(rng.integers(0, 6, (b, m)).astype(np.float32), axis=1)
    dist[:, 3 * m // 4:] = np.inf
    pay = rng.integers(0, 1 << 20, (b, m)).astype(np.int32)
    pay[np.isinf(dist)] = -1
    nd = rng.integers(0, 6, (b, r)).astype(np.float32)
    npay = rng.integers(0, 1 << 20, (b, r)).astype(np.int32)
    gd, gp = topm_merge_host(jnp.asarray(dist), jnp.asarray(pay),
                             jnp.asarray(nd), jnp.asarray(npay))
    d = np.concatenate([dist, nd], axis=1)
    p = np.concatenate([pay, npay], axis=1)
    order = np.argsort(d, axis=1, kind="stable")[:, :m]
    np.testing.assert_array_equal(np.asarray(gd),
                                  np.take_along_axis(d, order, axis=1))
    np.testing.assert_array_equal(np.asarray(gp),
                                  np.take_along_axis(p, order, axis=1))


def test_topm_merge_kernel_interpret_micro():
    """Execute the actual Pallas kernel body (interpret mode) at a width
    small enough for XLA:CPU to compile the unrolled network."""
    from repro.kernels.topk import topm_merge

    rng = np.random.default_rng(3)
    b, m, r = 4, 8, 4  # width 16 -> 10 unrolled stages
    dist = np.sort(rng.random((b, m)).astype(np.float32), axis=1)
    pay = rng.integers(0, 1 << 20, (b, m)).astype(np.int32)
    nd = rng.random((b, r)).astype(np.float32)
    npay = rng.integers(0, 1 << 20, (b, r)).astype(np.int32)
    gd, gp = topm_merge(jnp.asarray(dist), jnp.asarray(pay),
                        jnp.asarray(nd), jnp.asarray(npay), interpret=True)
    wd, wp = ref.topm_merge_ref(jnp.asarray(dist), jnp.asarray(pay),
                                jnp.asarray(nd), jnp.asarray(npay))
    np.testing.assert_array_equal(np.asarray(gd), np.asarray(wd))
    np.testing.assert_array_equal(np.asarray(gp), np.asarray(wp))


def _random_program_batch(rng, b, n_words, n_values, max_slots=3,
                          max_terms=2):
    """Random compiled programs (via random expressions) + gathered attrs."""
    from repro.filters.compile import FilterProgram, compile_filters
    from repro.filters.expr import And, Contain, In, Not, Or, Range

    def leaf():
        c = rng.integers(0, 4)
        if c == 0:
            return Contain(rng.integers(0, 32 * n_words, rng.integers(1, 3)))
        if c == 1:
            return In(rng.integers(0, 32 * n_words, rng.integers(1, 3)))
        lo = float(rng.random())
        return Range(lo, lo + float(rng.random()) * 0.5,
                     attr=int(rng.integers(0, n_values)))

    def expr():
        leaves = [leaf() for _ in range(int(rng.integers(1, max_slots + 1)))]
        leaves = [Not(l) if rng.random() < 0.3 else l for l in leaves]
        comb = And(*leaves) if rng.random() < 0.5 else Or(*leaves)
        return comb

    prog = compile_filters([expr() for _ in range(b)], n_words, n_values,
                           n_terms=max_terms)
    return FilterProgram(*(jnp.asarray(a) for a in prog))


def _fused_attrs(rng, b, r, n_words, n_values):
    labels = jnp.asarray(
        rng.integers(0, 1 << 32, (b, r, n_words), dtype=np.uint32))
    values = jnp.asarray(rng.random((b, r, n_values)).astype(np.float32))
    return labels, values


def test_fused_step_kernel_interpret_micro():
    """Execute the actual fused kernel body (interpret mode): in-kernel
    program evaluation + distances + dual merge at a width small enough
    for XLA:CPU to compile the unrolled network."""
    from repro.kernels.fused_step import fused_step

    rng = np.random.default_rng(4)
    b, m, r, k, d, w, v = 4, 8, 4, 2, 8, 2, 2  # wq=16, wr=8
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(b, r, d)).astype(np.float32))
    nb = jnp.asarray(rng.integers(0, 1 << 20, (b, r)).astype(np.int32))
    is_new = jnp.asarray(rng.random((b, r)) < 0.8)
    prog = _random_program_batch(rng, b, w, v)
    labels, values = _fused_attrs(rng, b, r, w, v)
    cd = jnp.asarray(np.sort(rng.random((b, m)).astype(np.float32) * 50, axis=1))
    cp = jnp.asarray(rng.integers(0, 1 << 20, (b, m)).astype(np.int32))
    rd = jnp.asarray(np.sort(rng.random((b, k)).astype(np.float32) * 50, axis=1))
    ri = jnp.asarray(rng.integers(0, 1 << 20, (b, k)).astype(np.int32))
    for pre in (False, True):
        got = fused_step(q, x, nb, is_new, prog, labels, values, cd, cp, rd,
                         ri, pre=pre, interpret=True)
        want = ref.fused_step_ref(q, x, nb, is_new, prog, labels, values, cd,
                                  cp, rd, ri, pre=pre)
        for g, w_ in zip(got, want):
            g, w_ = np.asarray(g), np.asarray(w_)
            if g.dtype == np.float32:
                finite = np.isfinite(w_)
                np.testing.assert_allclose(g[finite], w_[finite], rtol=1e-5,
                                           atol=1e-5)
                assert np.isinf(g[~finite]).all()
            else:
                np.testing.assert_array_equal(g, w_)


# ------------------------------------------------------------ fused step ----
@pytest.mark.parametrize("b,m,r,k,d", [(4, 32, 8, 5, 12), (8, 128, 32, 10, 24),
                                       (3, 64, 17, 7, 33)])
@pytest.mark.parametrize("pre", [False, True])
def test_fused_step_vs_ref(b, m, r, k, d, pre):
    """ops.fused_traversal_step == ref oracle (program + distances + dual
    merge + clause counters)."""
    rng = np.random.default_rng(b * 100 + m + r)
    w, v = 2, 2
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(b, r, d)).astype(np.float32))
    nb = jnp.asarray(rng.integers(0, 1 << 20, (b, r)).astype(np.int32))
    is_new = jnp.asarray(rng.random((b, r)) < 0.8)
    prog = _random_program_batch(rng, b, w, v)
    labels, values = _fused_attrs(rng, b, r, w, v)
    cd = np.sort(rng.random((b, m)).astype(np.float32) * 50, axis=1)
    cd[:, m // 2:] = np.inf  # half-empty buffer
    cp = rng.integers(0, 1 << 20, (b, m)).astype(np.int32)
    cp[np.isinf(cd)] = -1
    rd = np.sort(rng.random((b, k)).astype(np.float32) * 50, axis=1)
    rd[:, k // 2:] = np.inf
    ri = rng.integers(0, 1 << 20, (b, k)).astype(np.int32)
    ri[np.isinf(rd)] = -1

    args = (q, x, nb, is_new, prog, labels, values, jnp.asarray(cd),
            jnp.asarray(cp), jnp.asarray(rd), jnp.asarray(ri))
    got = ops.fused_traversal_step(*args, pre=pre)
    want = ref.fused_step_ref(*args, pre=pre)
    for g, w_, name in zip(got, want, ("cand_dist", "cand_pay", "res_dist",
                                       "res_idx", "valid", "clause_add")):
        g, w_ = np.asarray(g), np.asarray(w_)
        if g.dtype == np.float32:
            finite = np.isfinite(w_)
            np.testing.assert_allclose(g[finite], w_[finite], rtol=1e-5,
                                       atol=1e-5, err_msg=name)
            assert np.isinf(g[~finite]).all(), name
        else:
            np.testing.assert_array_equal(g, w_, err_msg=name)
    # sortedness invariant on both output buffers
    for gd in (np.asarray(got[0]), np.asarray(got[2])):
        assert (np.diff(gd, axis=1)[np.isfinite(gd[:, 1:])] >= 0).all()


def test_payload_pack_roundtrip():
    idx = jnp.asarray([-1, 0, 5, (1 << 29) - 1], jnp.int32)
    exp = jnp.asarray([False, True, False, True])
    val = jnp.asarray([False, False, True, True])
    i2, e2, v2 = unpack_payload(pack_payload(idx, exp, val))
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(idx))
    np.testing.assert_array_equal(np.asarray(e2)[1:], np.asarray(exp)[1:])
    np.testing.assert_array_equal(np.asarray(v2)[1:], np.asarray(val)[1:])


# ------------------------------------------------------------------ gbdt ----
@pytest.mark.parametrize("n,f,trees,depth", [(64, 8, 20, 3), (128, 28, 60, 5),
                                             (33, 5, 7, 2)])
def test_gbdt_kernel_vs_model(n, f, trees, depth):
    rng = np.random.default_rng(n + f)
    x = rng.normal(size=(n, f)).astype(np.float32)
    y = x[:, 0] * 2 + np.sin(x[:, min(1, f - 1)]) + 0.1 * rng.normal(size=n)
    model = train_gbdt(x, y, n_trees=trees, depth=depth, learning_rate=0.2)
    want = model.predict(x)
    feats = jnp.asarray(x)
    got = ops.estimator_predict(
        feats, (jnp.asarray(model.feat), jnp.asarray(model.thresh),
                jnp.asarray(model.leaf), model.base), model.depth)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


def test_gbdt_kernel_matches_jax_path():
    from repro.core.gbdt import predict_jax

    rng = np.random.default_rng(0)
    x = rng.normal(size=(50, 12)).astype(np.float32)
    y = (x**2).sum(axis=1)
    model = train_gbdt(x, y, n_trees=40, depth=4)
    feats = jnp.asarray(x)
    a = predict_jax(model.pack_jax(), feats, model.depth)
    b = ops.estimator_predict(
        feats, (jnp.asarray(model.feat), jnp.asarray(model.thresh),
                jnp.asarray(model.leaf), model.base), model.depth)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)
