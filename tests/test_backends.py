"""Traversal-backend stack: registry, dense/Pallas parity, resumability,
shard-aware engine equivalence."""
import dataclasses
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (BIG_BUDGET, SearchConfig, SearchEngine,
                        available_backends, get_backend, register_backend)
from repro.core.backends import DenseBackend
from repro.data import make_dataset, make_label_workload, make_range_workload
from repro.index import build_graph_index


@pytest.fixture(scope="module")
def world():
    ds = make_dataset(n=2500, dim=24, n_clusters=6, alphabet_size=32, seed=0)
    graph = build_graph_index(ds.vectors, degree=16, seed=0)
    return ds, graph, SearchEngine.build(ds, graph)


def _workload(ds, kind, batch=16, seed=3):
    if kind == "range":
        wl = make_range_workload(ds, batch=batch, seed=seed)
        return wl, SearchConfig(k=5, queue_size=64, pred_kind=2)
    wl = make_label_workload(ds, batch=batch, kind=kind, seed=seed)
    return wl, SearchConfig(k=5, queue_size=64, pred_kind=0)


# ------------------------------------------------------------- registry ----
def test_registry_lists_both():
    names = available_backends()
    assert "dense" in names and "pallas" in names
    assert get_backend("dense") is not get_backend("pallas")


def test_registry_unknown_backend_raises():
    with pytest.raises(ValueError, match="unknown traversal backend"):
        get_backend("nope")


def test_custom_backend_registration(world):
    ds, graph, engine = world

    @register_backend("test-delegate")
    class _Delegate(DenseBackend):
        pass

    wl, cfg = _workload(ds, "contain")
    a = engine.search(dataclasses.replace(cfg, backend="dense"),
                      wl.queries, wl.spec, 800)
    b = engine.search(dataclasses.replace(cfg, backend="test-delegate"),
                      wl.queries, wl.spec, 800)
    np.testing.assert_array_equal(np.asarray(a.res_idx), np.asarray(b.res_idx))


# --------------------------------------------------------------- parity ----
@pytest.mark.parametrize("mode", ["post", "pre"])
@pytest.mark.parametrize("kind", ["contain", "range"])
def test_dense_pallas_parity(world, mode, kind):
    """Identical top-k ids, NDC, and queue contents across backends."""
    ds, graph, engine = world
    wl, cfg = _workload(ds, kind)
    cfg = dataclasses.replace(cfg, mode=mode)
    sd = engine.search(dataclasses.replace(cfg, backend="dense"),
                       wl.queries, wl.spec, 1500)
    sp = engine.search(dataclasses.replace(cfg, backend="pallas"),
                       wl.queries, wl.spec, 1500)
    np.testing.assert_array_equal(np.asarray(sd.res_idx), np.asarray(sp.res_idx))
    np.testing.assert_array_equal(np.asarray(sd.cnt), np.asarray(sp.cnt))
    np.testing.assert_array_equal(np.asarray(sd.cand_idx), np.asarray(sp.cand_idx))
    np.testing.assert_array_equal(np.asarray(sd.n_inspected),
                                  np.asarray(sp.n_inspected))
    np.testing.assert_array_equal(np.asarray(sd.hops), np.asarray(sp.hops))
    np.testing.assert_allclose(np.asarray(sd.res_dist), np.asarray(sp.res_dist),
                               rtol=1e-6, atol=1e-6)


def test_parity_unbounded_budget(world):
    ds, graph, engine = world
    wl, cfg = _workload(ds, "contain")
    sd = engine.search(dataclasses.replace(cfg, backend="dense"),
                       wl.queries, wl.spec, BIG_BUDGET)
    sp = engine.search(dataclasses.replace(cfg, backend="pallas"),
                       wl.queries, wl.spec, BIG_BUDGET)
    np.testing.assert_array_equal(np.asarray(sd.res_idx), np.asarray(sp.res_idx))
    np.testing.assert_array_equal(np.asarray(sd.cnt), np.asarray(sp.cnt))


# --------------------------------------------------------- resumability ----
@pytest.mark.parametrize("backend", ["dense", "pallas"])
def test_probe_resume_equals_oneshot(world, backend):
    """Zero-overhead probe: run_search(budget=f) then resume == one-shot."""
    ds, graph, engine = world
    wl, cfg = _workload(ds, "contain", seed=7)
    cfg = dataclasses.replace(cfg, backend=backend)
    one = engine.search(cfg, wl.queries, wl.spec, 700)
    st = engine.search(cfg, wl.queries, wl.spec, 120)
    st = engine.search(cfg, wl.queries, wl.spec, 700, state=st)
    np.testing.assert_array_equal(np.asarray(one.res_idx), np.asarray(st.res_idx))
    np.testing.assert_array_equal(np.asarray(one.cnt), np.asarray(st.cnt))
    np.testing.assert_array_equal(np.asarray(one.cand_idx), np.asarray(st.cand_idx))


# ------------------------------------------------------- sharded engine ----
def test_sharded_engine_matches_single_device():
    """shard_map over a forced 8-device batch mesh == single-device run,
    including resume, batch padding (B % ndev != 0), and both backends."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np
        from repro.core import SearchConfig, SearchEngine
        from repro.data import make_dataset, make_label_workload
        from repro.index import build_graph_index
        ds = make_dataset(n=1500, dim=16, n_clusters=4, alphabet_size=32, seed=0)
        graph = build_graph_index(ds.vectors, degree=12, seed=0)
        e1 = SearchEngine.build(ds, graph, mesh=None)
        e8 = SearchEngine.build(ds, graph)            # auto 8-device mesh
        assert e8.mesh is not None and e8.mesh.devices.size == 8
        cfg = SearchConfig(k=5, queue_size=64, pred_kind=0)
        wl = make_label_workload(ds, batch=13, kind="contain", seed=3)  # pads
        a = e1.search(cfg, wl.queries, wl.spec, 900)
        b = e8.search(cfg, wl.queries, wl.spec, 900)
        assert np.array_equal(np.asarray(a.res_idx), np.asarray(b.res_idx))
        assert np.array_equal(np.asarray(a.cnt), np.asarray(b.cnt))
        st = e8.search(cfg, wl.queries, wl.spec, 100)
        st = e8.search(cfg, wl.queries, wl.spec, 900, state=st)
        assert np.array_equal(np.asarray(a.res_idx), np.asarray(st.res_idx))
        ep = SearchEngine.build(ds, graph, backend="pallas")
        c = ep.search(cfg, wl.queries, wl.spec, 900)
        assert np.array_equal(np.asarray(a.res_idx), np.asarray(c.res_idx))
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert "OK" in r.stdout, r.stderr
