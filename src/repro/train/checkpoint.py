"""Checkpoint/restart with elastic reshard-on-restore.

Design for real clusters (documented in DESIGN.md):
  - atomic writes (tmp + rename) with a JSON manifest carrying step,
    content hashes, and the saving mesh shape — a torn write can never be
    mistaken for a valid checkpoint;
  - rotating retention (`keep`);
  - restore is *mesh-agnostic*: arrays are loaded whole and re-placed via
    `jax.device_put` against the CURRENT mesh's NamedShardings, so a job
    restarted on a different device count (elastic N→M) reshards
    transparently. (At 1000+ nodes the same API is backed by per-host
    sharded files + a distributed barrier; single-process here.)
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import time

import jax
import numpy as np


def _flatten(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save ----
    def save(self, step: int, state, meta: dict | None = None) -> str:
        flat = _flatten(state)
        arrays = {k: np.asarray(v) for k, v in flat.items()}
        tag = f"step_{step:010d}"
        tmp = os.path.join(self.dir, f".tmp_{tag}_{os.getpid()}")
        os.makedirs(tmp, exist_ok=True)
        data_path = os.path.join(tmp, "arrays.npz")
        np.savez(data_path, **{_safe(k): v for k, v in arrays.items()})
        digest = hashlib.sha256(open(data_path, "rb").read()).hexdigest()
        manifest = {
            "step": int(step),
            "time": time.time(),
            "sha256": digest,
            "keys": {_safe(k): k for k in arrays},
            "shapes": {_safe(k): list(v.shape) for k, v in arrays.items()},
            "dtypes": {_safe(k): str(v.dtype) for k, v in arrays.items()},
            "meta": meta or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(self.dir, tag)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore ----
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, "manifest.json")):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, abstract_state, shardings=None, validate=True):
        """Restore into the structure of `abstract_state`.

        shardings: optional matching tree of NamedSharding — arrays are
        placed sharded on the current mesh (elastic reshard path).
        """
        tag = f"step_{step:010d}"
        root = os.path.join(self.dir, tag)
        manifest = json.load(open(os.path.join(root, "manifest.json")))
        data_path = os.path.join(root, "arrays.npz")
        if validate:
            digest = hashlib.sha256(open(data_path, "rb").read()).hexdigest()
            if digest != manifest["sha256"]:
                raise IOError(f"checkpoint {tag} failed integrity check")
        z = np.load(data_path)

        flat_abs, treedef = jax.tree_util.tree_flatten_with_path(abstract_state)
        sh_flat = None
        if shardings is not None:
            sh_flat = treedef.flatten_up_to(shardings)
        leaves = []
        for i, (path, leaf) in enumerate(flat_abs):
            k = _safe(jax.tree_util.keystr(path))
            arr = z[k]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"shape mismatch for {k}: ckpt {arr.shape} "
                                 f"vs state {leaf.shape}")
            arr = arr.astype(leaf.dtype)
            if sh_flat is not None:
                arr = jax.device_put(arr, sh_flat[i])
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest

    def restore_latest(self, abstract_state, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        return self.restore(step, abstract_state, shardings)


def _safe(key: str) -> str:
    return key.replace("/", "_")
