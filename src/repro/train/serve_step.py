"""Serving steps: prefill and single-token decode, pjit-ready."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_prefill(model):
    def prefill(params, batch):
        return model.prefill(params, batch)

    return prefill


def make_decode_step(model, greedy: bool = True):
    def decode_step(params, cache, tokens, pos, enc=None):
        logits, cache = model.decode_step(params, cache, tokens, pos, enc)
        if greedy:
            next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        else:
            next_tok = None
        return logits, next_tok, cache

    return decode_step
