from repro.train.optimizer import AdamWConfig, init_opt_state, adamw_update
from repro.train.train_step import TrainConfig, make_train_step, make_init_state
from repro.train.serve_step import make_prefill, make_decode_step

__all__ = [
    "AdamWConfig", "init_opt_state", "adamw_update",
    "TrainConfig", "make_train_step", "make_init_state",
    "make_prefill", "make_decode_step",
]
