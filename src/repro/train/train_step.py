"""train_step: loss → grads → (compressed) update, pjit-ready.

Features:
  - gradient accumulation (scan over microbatches)
  - int8 error-feedback gradient compression (cross-pod DP trick: the
    quantize→dequantize round-trip models the compressed all-reduce wire
    format; the residual is carried in TrainState.ef_error so no signal is
    lost — standard EF-SGD structure)
  - optional Adam moment quantization (see optimizer.py)

All functions consume/produce pure value trees; logical-axis trees for
sharding come from `make_init_state` + `split_tree`.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import P, split_tree
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state, quantize, dequantize


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    grad_accum: int = 1
    grad_compression: str = "none"   # none | int8_ef


def make_init_state(model, tc: TrainConfig):
    """Returns init(key) -> P-tree TrainState (traceable by eval_shape)."""

    def init(key):
        params = model.init_params(key)
        state = {
            "params": params,
            "opt": init_opt_state(params, tc.opt),
            "step": P(jnp.zeros((), jnp.int32), ()),
        }
        if tc.grad_compression == "int8_ef":
            is_p = lambda x: isinstance(x, P)
            state["ef_error"] = jax.tree.map(
                lambda p: P(jnp.zeros(p.value.shape, jnp.float32), p.axes),
                params, is_leaf=is_p)
        return state

    return init


def make_train_step(model, tc: TrainConfig):
    """Returns step(state_values, batch) -> (new_state_values, metrics)."""

    def compute_grads(params, batch):
        if tc.grad_accum <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, batch)
            return loss, metrics, grads

        import os
        acc_dt = jnp.bfloat16 if os.environ.get("REPRO_ACCUM_DTYPE") == "bfloat16" \
            else jnp.float32

        def micro(carry, mb):
            acc, _ = carry
            (loss, metrics), g = jax.value_and_grad(
                model.loss, has_aux=True)(params, mb)
            acc = jax.tree.map(lambda a, x: a + x.astype(acc_dt), acc, g)
            return (acc, loss), metrics

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
        mbs = jax.tree.map(
            lambda x: x.reshape((tc.grad_accum, x.shape[0] // tc.grad_accum)
                                + x.shape[1:]), batch)
        (gsum, loss), metrics = jax.lax.scan(micro, (zeros, jnp.float32(0)), mbs)
        grads = jax.tree.map(lambda g: g / tc.grad_accum, gsum)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss, metrics, grads

    def step(state, batch):
        params = state["params"]
        loss, metrics, grads = compute_grads(params, batch)

        if tc.grad_compression == "int8_ef":
            err = state["ef_error"]
            new_err = {}
            comp = {}

            flat_g, tdef = jax.tree.flatten(grads)
            flat_e = tdef.flatten_up_to(err)
            out_g, out_e = [], []
            for g, e in zip(flat_g, flat_e):
                x = g.astype(jnp.float32) + e
                qt = quantize(x)
                deq = dequantize(qt, x.shape)
                out_g.append(deq)
                out_e.append(x - deq)
            grads = jax.tree.unflatten(tdef, out_g)
            new_err = jax.tree.unflatten(tdef, out_e)

        new_params, new_opt = adamw_update(params, grads, state["opt"], tc.opt)
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        if tc.grad_compression == "int8_ef":
            new_state["ef_error"] = new_err
        metrics = dict(metrics)
        metrics["loss"] = loss
        return new_state, metrics

    return step
