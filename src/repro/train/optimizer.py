"""AdamW with optionally block-quantized (int8) moments.

Why: the assigned deepseek-v3-671b cell must fit 256 × 16 GB chips.
fp32 m/v costs 8 B/param (5.4 TB for 671B) — int8 moments with per-128-block
scales cost ~2.06 B/param, the difference between OOM and fitting (napkin
math in EXPERIMENTS.md §Dry-run). Quantization is symmetric per block of the
last dim; error behaves like stochastic rounding noise on the moment EMA and
is a standard distributed-optimization trick (8-bit Adam).

Moment tensors inherit the param's logical sharding axes; scale tensors
shard like the param with the last dim shrunk by 128 (divisibility-aware
rules handle the fallback).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import P

QBLOCK = 128


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"   # float32 | bfloat16 | int8
    grad_clip: float = 1.0


class QTensor(NamedTuple):
    q: jax.Array       # int8 quantized values
    scale: jax.Array   # f32 per-block scales (last dim / QBLOCK)


def _pad_to_block(x):
    last = x.shape[-1]
    pad = (-last) % QBLOCK
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, pad


def quantize(x: jax.Array) -> QTensor:
    xp, _ = _pad_to_block(x.astype(jnp.float32))
    blocks = xp.reshape(*xp.shape[:-1], xp.shape[-1] // QBLOCK, QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale[..., None], 1e-20))
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return QTensor(q=q.reshape(xp.shape), scale=scale)


def dequantize(qt: QTensor, shape) -> jax.Array:
    q = qt.q.reshape(*qt.q.shape[:-1], qt.q.shape[-1] // QBLOCK, QBLOCK)
    x = q.astype(jnp.float32) * qt.scale[..., None]
    x = x.reshape(qt.q.shape)
    return x[..., : shape[-1]].reshape(shape)


def _moment_init(p_leaf: P, cfg: AdamWConfig):
    v = p_leaf.value
    if cfg.moment_dtype == "int8":
        padded = v.shape[-1] + ((-v.shape[-1]) % QBLOCK)
        qshape = v.shape[:-1] + (padded,)
        sshape = v.shape[:-1] + (padded // QBLOCK,)
        return {
            "q": P(jnp.zeros(qshape, jnp.int8), p_leaf.axes),
            "scale": P(jnp.zeros(sshape, jnp.float32), p_leaf.axes),
        }
    dt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    return P(jnp.zeros(v.shape, dt), p_leaf.axes)


def init_opt_state(param_tree, cfg: AdamWConfig):
    """param_tree: P-tree. Returns P-tree opt state {m, v, count}."""
    is_p = lambda x: isinstance(x, P)
    return {
        "m": jax.tree.map(lambda p: _moment_init(p, cfg), param_tree, is_leaf=is_p),
        "v": jax.tree.map(lambda p: _moment_init(p, cfg), param_tree, is_leaf=is_p),
        "count": P(jnp.zeros((), jnp.int32), ()),
    }


def _read_moment(m, shape, cfg: AdamWConfig, second: bool = False):
    if cfg.moment_dtype == "int8":
        x = dequantize(QTensor(m["q"], m["scale"]), shape)
        # v is stored in sqrt-domain: squaring restores it non-negative with
        # bounded *relative* error (the 8-bit Adam trick for the 2nd moment)
        return x * x if second else x
    return m.astype(jnp.float32)


def _write_moment(x, cfg: AdamWConfig, second: bool = False):
    if cfg.moment_dtype == "int8":
        if second:
            x = jnp.sqrt(jnp.maximum(x, 0.0))
        qt = quantize(x)
        return {"q": qt.q, "scale": qt.scale}
    dt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    return x.astype(dt)


def adamw_update(params, grads, opt_state, cfg: AdamWConfig):
    """Pure-value trees in, pure-value trees out (no P wrappers)."""
    count = opt_state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    # global-norm clip
    if cfg.grad_clip > 0:
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                          for g in jax.tree.leaves(grads)) + 1e-12)
        cscale = jnp.minimum(1.0, cfg.grad_clip / gn)
    else:
        cscale = 1.0

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])

    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        g32 = g.astype(jnp.float32) * cscale
        m32 = _read_moment(m, p.shape, cfg)
        v32 = _read_moment(v, p.shape, cfg, second=True)
        m32 = cfg.b1 * m32 + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v32 + (1 - cfg.b2) * g32 * g32
        mhat = m32 / b1c
        vhat = v32 / b2c
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - cfg.lr * upd).astype(p.dtype))
        new_m.append(_write_moment(m32, cfg))
        new_v.append(_write_moment(v32, cfg, second=True))

    return (
        jax.tree.unflatten(treedef, new_p),
        {
            "m": jax.tree.unflatten(treedef, new_m),
            "v": jax.tree.unflatten(treedef, new_v),
            "count": count,
        },
    )
