from repro.data.synthetic import (
    AttributedDataset,
    QueryWorkload,
    make_dataset,
    make_label_workload,
    make_range_workload,
    make_composite_workload,
    DATASET_PRESETS,
    make_preset,
)

__all__ = [
    "AttributedDataset",
    "QueryWorkload",
    "make_dataset",
    "make_label_workload",
    "make_range_workload",
    "make_composite_workload",
    "DATASET_PRESETS",
    "make_preset",
]
