"""Synthetic attributed-vector datasets with *controllable* query↔filter
correlation.

The paper's central phenomenon (Fig. 2/3) is that local correlation ρ_local
between a query's vector neighborhood and its filter predicate diverges
wildly from global selectivity σ_global. We reproduce it by construction:

- Vectors are drawn from a GMM with C clusters in R^d (unit-normalized, so
  L2 ≈ angular distance, like text/image embeddings).
- **Label attributes**: each cluster has a skewed label distribution over a
  global alphabet; items sample 1..max_labels labels from their cluster's
  distribution, so label density is locally coherent (a query inside a
  cluster sees high ρ_local for that cluster's labels, near-zero for
  others) — mimicking Tripclick clinical areas / Arxiv categories.
- **Range attributes**: value = w·x + ε, a noisy linear probe of the vector
  (mimicking "luxury watch image ↔ high price"); queries with a range around
  their own value are *aligned* (easy), ranges shifted into another part of
  the value distribution are *anti-correlated* (hard) — exactly the paper's
  Fig. 2 hard-range construction.

Selectivity spectra follow the MSMARCO protocol: σ_global ∈ {1,5,10,20}%.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import numpy as np

from repro.filters.predicates import (
    FilterSpec,
    PRED_CONTAIN,
    PRED_EQUAL,
    PRED_RANGE,
    pack_labels,
)


@dataclasses.dataclass
class AttributedDataset:
    """Host-side attributed vector dataset (paper Def. 2.1).

    Items carry one label-set attribute (packed multi-hot) plus one or more
    numeric attribute channels: `values` is the primary channel (kept 1-D
    for the legacy FilterSpec range path) and `values_aux` holds any extra
    channels the filter algebra's `Range(..., attr=c)` can address.
    """

    name: str
    vectors: np.ndarray          # [N, d] float32, unit norm
    labels_packed: np.ndarray    # [N, W] uint32 multi-hot
    label_sets: list             # python list of per-item label tuples
    values: np.ndarray           # [N] float32 numeric attribute (channel 0)
    alphabet_size: int
    cluster_ids: np.ndarray      # [N] int32 (generation metadata)
    values_aux: np.ndarray | None = None  # [N, V-1] float32 extra channels

    @property
    def n(self) -> int:
        return self.vectors.shape[0]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]

    @property
    def n_words(self) -> int:
        return self.labels_packed.shape[1]

    @property
    def n_value_attrs(self) -> int:
        return 1 + (0 if self.values_aux is None else self.values_aux.shape[1])

    @property
    def value_matrix(self) -> np.ndarray:
        """[N, V] float32 — every numeric channel, channel 0 = `values`."""
        if self.values_aux is None:
            return self.values[:, None]
        return np.concatenate([self.values[:, None], self.values_aux], axis=1)

    def sample_vectors(self, n: int, seed: int = 0) -> np.ndarray:
        """Deterministic without-replacement vector sample.

        Codec fitting (k-means codebooks, int8 min/max) doesn't need the
        full corpus; a bounded sample keeps quantized-engine bring-up
        independent of N. Returns the full set when n >= N.
        """
        if n >= self.n:
            return self.vectors
        idx = np.random.default_rng(seed).choice(self.n, size=n, replace=False)
        return self.vectors[idx]


@dataclasses.dataclass
class QueryWorkload:
    """A batch of filtered queries q = (x_q, f_q) plus generation metadata.

    Filters are carried either as a legacy single-kind `FilterSpec` batch
    (`spec`) or as per-query filter-algebra expressions (`exprs`) — the
    composite-filter generators below emit the latter. `filters` is the
    form to hand to `engine.search` / the brute-force oracle.
    """

    queries: np.ndarray       # [B, d] float32
    spec: FilterSpec | None   # batched single-kind filters (legacy form)
    sigma_global: np.ndarray  # [B] measured global selectivity
    hardness: np.ndarray      # [B] 0 = aligned/easy, 1 = anti-correlated/hard
    exprs: list | None = None  # [B] filter-algebra expressions

    @property
    def batch(self) -> int:
        return self.queries.shape[0]

    @property
    def filters(self):
        return self.exprs if self.exprs is not None else self.spec

    def filter_slice(self, s: int, e: int):
        """Filters of queries [s:e), in whichever form the workload holds."""
        if self.exprs is not None:
            return self.exprs[s:e]
        return self.spec.slice(slice(s, e))


def _unit(x: np.ndarray) -> np.ndarray:
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-12)


def make_dataset(
    n: int = 20000,
    dim: int = 64,
    n_clusters: int = 32,
    alphabet_size: int = 64,
    max_labels: int = 3,
    label_skew: float = 4.0,
    value_noise: float = 0.1,
    seed: int = 0,
    name: str = "synthetic",
    n_value_attrs: int = 2,
) -> AttributedDataset:
    rng = np.random.default_rng(seed)
    centers = _unit(rng.normal(size=(n_clusters, dim)).astype(np.float32))
    cluster_ids = rng.integers(0, n_clusters, size=n).astype(np.int32)
    spread = 0.35
    vecs = centers[cluster_ids] + spread * rng.normal(size=(n, dim)).astype(np.float32)
    vecs = _unit(vecs).astype(np.float32)

    # Per-cluster label distribution: a Zipf-ish reweighting of a random
    # permutation of the alphabet, so each cluster concentrates on a few
    # "home" labels but shares tails with others.
    label_probs = np.zeros((n_clusters, alphabet_size), dtype=np.float64)
    base = 1.0 / np.arange(1, alphabet_size + 1) ** label_skew
    for c in range(n_clusters):
        perm = rng.permutation(alphabet_size)
        label_probs[c, perm] = base
    label_probs /= label_probs.sum(axis=1, keepdims=True)

    label_sets = []
    for i in range(n):
        k = int(rng.integers(1, max_labels + 1))
        labs = rng.choice(alphabet_size, size=k, replace=False, p=label_probs[cluster_ids[i]])
        label_sets.append(tuple(sorted(int(x) for x in labs)))
    labels_packed = pack_labels(label_sets, alphabet_size)

    # Numeric attribute: noisy linear probe of the vector, rescaled to [0,1].
    w = rng.normal(size=dim).astype(np.float32)
    raw = vecs @ w + value_noise * rng.normal(size=n).astype(np.float32)
    values = (raw - raw.min()) / max(raw.max() - raw.min(), 1e-9)
    values = values.astype(np.float32)

    # Extra numeric channels (for the filter algebra's Range(..., attr=c)):
    # independent noisy probes, drawn *after* every legacy stream draw so
    # channel 0 / labels / vectors are bit-identical to n_value_attrs=1.
    values_aux = None
    if n_value_attrs > 1:
        cols = []
        for _ in range(n_value_attrs - 1):
            wa = rng.normal(size=dim).astype(np.float32)
            ra = vecs @ wa + value_noise * rng.normal(size=n).astype(np.float32)
            cols.append((ra - ra.min()) / max(ra.max() - ra.min(), 1e-9))
        values_aux = np.stack(cols, axis=1).astype(np.float32)

    return AttributedDataset(
        name=name,
        vectors=vecs,
        labels_packed=labels_packed,
        label_sets=label_sets,
        values=values,
        alphabet_size=alphabet_size,
        cluster_ids=cluster_ids,
        values_aux=values_aux,
    )


def _sample_query_vectors(ds: AttributedDataset, b: int, rng) -> tuple[np.ndarray, np.ndarray]:
    """Perturbed held-in samples: on-manifold queries (paper §5.1)."""
    idx = rng.integers(0, ds.n, size=b)
    q = ds.vectors[idx] + 0.05 * rng.normal(size=(b, ds.dim)).astype(np.float32)
    return _unit(q).astype(np.float32), idx


def make_label_workload(
    ds: AttributedDataset,
    batch: int = 64,
    kind: Literal["contain", "equal"] = "contain",
    hard_fraction: float = 0.5,
    seed: int = 1,
) -> QueryWorkload:
    """Label-filtered queries.

    Easy/aligned: filter = subset of the labels of a data item *near* the
    query (high ρ_local). Hard/anti-correlated: filter = labels of an item
    from a *different* cluster (σ_global similar, ρ_local ≈ 0) — the paper's
    feature-filter misalignment.
    """
    rng = np.random.default_rng(seed)
    q, src_idx = _sample_query_vectors(ds, batch, rng)
    hard = (rng.random(batch) < hard_fraction).astype(np.int32)
    masks = np.zeros((batch, ds.n_words), dtype=np.uint32)
    ptag = PRED_CONTAIN if kind == "contain" else PRED_EQUAL
    for i in range(batch):
        if hard[i]:
            # borrow the label set of an item in another cluster
            while True:
                j = int(rng.integers(0, ds.n))
                if ds.cluster_ids[j] != ds.cluster_ids[src_idx[i]]:
                    break
        else:
            j = int(src_idx[i])
        labs = ds.label_sets[j]
        if ptag == PRED_CONTAIN and len(labs) > 1:
            # containment uses a random non-empty subset
            ksub = int(rng.integers(1, len(labs) + 1))
            labs = tuple(rng.choice(labs, size=ksub, replace=False))
        for lab in labs:
            masks[i, lab // 32] |= np.uint32(1) << np.uint32(lab % 32)
    spec = FilterSpec(kind=ptag, label_masks=masks)

    from repro.filters.predicates import selectivity

    sig = selectivity(spec, ds.labels_packed, ds.values)
    return QueryWorkload(queries=q, spec=spec, sigma_global=sig, hardness=hard.astype(np.float32))


def make_range_workload(
    ds: AttributedDataset,
    batch: int = 64,
    selectivities: tuple = (0.01, 0.05, 0.10, 0.20),
    hard_fraction: float = 0.5,
    seed: int = 2,
) -> QueryWorkload:
    """Range-filtered queries with controlled σ_global.

    The range width is chosen on the empirical value CDF so that the window
    covers exactly `sel` of the dataset. Easy: window centered at the
    query's own attribute value. Hard: window centered at the *opposite*
    quantile (anti-correlated with the query's neighborhood).
    """
    rng = np.random.default_rng(seed)
    q, src_idx = _sample_query_vectors(ds, batch, rng)
    hard = (rng.random(batch) < hard_fraction).astype(np.int32)
    sorted_vals = np.sort(ds.values)
    n = ds.n
    lo = np.zeros(batch, dtype=np.float32)
    hi = np.zeros(batch, dtype=np.float32)
    for i in range(batch):
        sel = float(rng.choice(selectivities))
        width = max(2, int(round(sel * n)))
        own_val = ds.values[src_idx[i]]
        own_rank = int(np.searchsorted(sorted_vals, own_val))
        if hard[i]:
            center = n - 1 - own_rank  # opposite quantile
        else:
            center = own_rank
        start = int(np.clip(center - width // 2, 0, n - width))
        lo[i] = sorted_vals[start]
        hi[i] = sorted_vals[start + width - 1]
    spec = FilterSpec(kind=PRED_RANGE, range_lo=lo, range_hi=hi)

    from repro.filters.predicates import selectivity

    sig = selectivity(spec, ds.labels_packed, ds.values)
    return QueryWorkload(queries=q, spec=spec, sigma_global=sig, hardness=hard.astype(np.float32))


def _window_on_cdf(sorted_vals: np.ndarray, center_rank: int, sel: float,
                   ) -> tuple[float, float]:
    """[lo, hi] covering `sel` of the empirical CDF around a rank."""
    n = sorted_vals.shape[0]
    width = max(2, int(round(sel * n)))
    start = int(np.clip(center_rank - width // 2, 0, n - width))
    return float(sorted_vals[start]), float(sorted_vals[start + width - 1])


def make_composite_workload(
    ds: AttributedDataset,
    batch: int = 64,
    structure: Literal["and", "or", "not", "mixed"] = "and",
    hard_fraction: float = 0.5,
    selectivities: tuple = (0.05, 0.10, 0.20),
    seed: int = 3,
) -> QueryWorkload:
    """Composite-filter workloads over the filter algebra (PathFinder-style).

    Per-leaf selectivity is controlled the same way as the single-kind
    generators (label leaves borrow real item label sets; range leaves take
    windows on the empirical value CDF), and the easy/hard axis is the
    paper's correlation knob: easy leaves describe the query's own
    neighborhood, hard leaves an anti-correlated one.

      and    Contain(labels near query) ∧ Range(value window)   — the
             canonical "tag AND price band" conjunction; σ_global is the
             product-ish of the leaf selectivities, ρ_local diverges per
             leaf (exactly what the per-clause rho features observe).
      or     Contain(tags A) ∨ Contain(tags B from another cluster) — the
             multi-tag disjunction; hard queries draw *both* tag sets from
             foreign clusters.
      not    Range(wide window) ∧ ¬In(blacklisted labels) — exclusion
             filtering (negated any-of).
      mixed  uniform mix of the above plus bare single-leaf filters —
             the serving-layer stress shape (heterogeneous structure in
             one batch).
    """
    from repro.filters.expr import And, Contain, In, Not, Or, Range

    rng = np.random.default_rng(seed)
    q, src_idx = _sample_query_vectors(ds, batch, rng)
    hard = (rng.random(batch) < hard_fraction).astype(np.int32)
    n_chan = ds.n_value_attrs
    vm = ds.value_matrix
    sorted_by_chan = [np.sort(vm[:, c]) for c in range(n_chan)]
    rank_by_chan = [np.searchsorted(sorted_by_chan[c], vm[:, c])
                    for c in range(n_chan)]

    def other_cluster_item(i):
        while True:
            j = int(rng.integers(0, ds.n))
            if ds.cluster_ids[j] != ds.cluster_ids[src_idx[i]]:
                return j

    def label_subset(j):
        labs = ds.label_sets[j]
        ksub = int(rng.integers(1, len(labs) + 1))
        return tuple(int(x) for x in rng.choice(labs, size=ksub, replace=False))

    def contain_leaf(i):
        j = other_cluster_item(i) if hard[i] else int(src_idx[i])
        return Contain(label_subset(j))

    def range_leaf(i, sel=None, chan=None):
        c = int(rng.integers(0, n_chan)) if chan is None else chan
        sel = float(rng.choice(selectivities)) if sel is None else sel
        own_rank = int(rank_by_chan[c][src_idx[i]])
        center = (ds.n - 1 - own_rank) if hard[i] else own_rank
        lo, hi = _window_on_cdf(sorted_by_chan[c], center, sel)
        return Range(lo, hi, attr=c)

    def build(i, shape):
        if shape == "and":
            return And(contain_leaf(i), range_leaf(i))
        if shape == "or":
            a = Contain(label_subset(other_cluster_item(i) if hard[i]
                                     else int(src_idx[i])))
            b = Contain(label_subset(other_cluster_item(i)))
            return Or(a, b)
        if shape == "not":
            # generous range minus a foreign cluster's tag blacklist
            wide = range_leaf(i, sel=0.5)
            block = In(label_subset(other_cluster_item(i)))
            return And(wide, Not(block))
        if shape == "contain":
            return contain_leaf(i)
        if shape == "range":
            return range_leaf(i)
        raise ValueError(shape)

    shapes = (["and", "or", "not", "contain", "range"] if structure == "mixed"
              else [structure])
    exprs = [build(i, shapes[int(rng.integers(0, len(shapes)))])
             for i in range(batch)]

    from repro.filters.predicates import selectivity

    sig = selectivity(exprs, ds.labels_packed, vm)
    return QueryWorkload(queries=q, spec=None, sigma_global=sig,
                         hardness=hard.astype(np.float32), exprs=exprs)


# Named presets standing in for the paper's four datasets, scaled to the
# container (scaling factors recorded in EXPERIMENTS.md).
DATASET_PRESETS = {
    # paper: Tripclick 1.0M x 768, clinical-area labels  -> scaled
    "tripclick-s": dict(n=20000, dim=96, n_clusters=24, alphabet_size=48, max_labels=3, seed=11),
    # paper: Youtube 1.0M x 128, audio tags
    "youtube-s": dict(n=20000, dim=64, n_clusters=40, alphabet_size=64, max_labels=4, seed=12),
    # paper: Arxiv 1.7M x 4096, categories + dates
    "arxiv-s": dict(n=24000, dim=128, n_clusters=32, alphabet_size=40, max_labels=2, seed=13),
    # paper: MSMARCO 1.0M x 1024, synthetic int attr
    "msmarco-s": dict(n=20000, dim=96, n_clusters=16, alphabet_size=32, max_labels=2, seed=14),
}


def make_preset(name: str, **overrides) -> AttributedDataset:
    cfg = dict(DATASET_PRESETS[name])
    cfg.update(overrides)
    return make_dataset(name=name, **cfg)
