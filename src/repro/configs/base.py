"""ArchConfig / ShapeConfig — the (architecture × input-shape) grid.

Every assigned architecture is a frozen ArchConfig; `tiny()` derives the
reduced same-family config used by CPU smoke tests. The four assigned
input shapes are fixed ShapeConfigs; `applicable_shapes(cfg)` applies the
documented skips (long_500k only for sub-quadratic archs).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 32000
    head_dim: int = 0               # 0 -> d_model // n_heads

    # --- attention pattern ---
    attn_kind: str = "global"       # global | local | local_global
    local_window: int = 4096
    local_global_period: int = 0    # e.g. 6 => 5 local : 1 global

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- MLA (deepseek) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # --- SSM (mamba2 family) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # --- hybrid (zamba2): shared attn block after every `hybrid_period` ssm layers
    hybrid_period: int = 0

    # --- encoder-decoder (whisper) ---
    n_encoder_layers: int = 0
    encoder_seq: int = 1500         # stub audio frames

    # --- vlm (llama-3.2-vision): cross-attn block every `cross_attn_period`
    cross_attn_period: int = 0
    vision_seq: int = 1601          # stub patch embeddings

    # --- misc ---
    norm_type: str = "rmsnorm"      # rmsnorm | layernorm | nonparam_ln
    act: str = "silu"               # silu (gated) | gelu
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    mtp: bool = False               # deepseek multi-token prediction head
    sub_quadratic: bool = False     # eligible for long_500k
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    remat: bool = True
    scan_layers: bool = True
    unroll_inner: bool = False      # unroll flash/SSD/CE chunk loops (roofline)
    source: str = ""                # provenance note

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    def with_dtypes(self, param_dtype, compute_dtype) -> "ArchConfig":
        return dataclasses.replace(self, param_dtype=param_dtype,
                                   compute_dtype=compute_dtype)

    def n_params(self) -> int:
        """Analytic parameter count (for 6ND roofline and memory napkin)."""
        d, l = self.d_model, self.n_layers
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for li in range(l):
            total += self._layer_params(li)
        if self.family == "encdec":
            for _ in range(self.n_encoder_layers):
                h = self.n_heads * self.hd
                total += d * h * 2 + d * self.n_kv_heads * self.hd * 2  # attn
                total += 2 * d * self.d_ff                              # mlp (gelu)
        if self.family == "vlm" and self.cross_attn_period:
            n_cross = l // self.cross_attn_period
            h = self.n_heads * self.hd
            total += n_cross * (d * h * 2 + d * self.n_kv_heads * self.hd * 2)
        if self.mtp:
            total += self._layer_params(l - 1)  # one extra block
        return total

    def _layer_params(self, li: int) -> int:
        d = self.d_model
        if self.family in ("ssm", "hybrid"):
            di = self.ssm_expand * d
            n = self.ssm_state
            h = di // self.ssm_head_dim
            p = 2 * d * di + 2 * d * n + d * h + di * d  # projections
            if self.family == "hybrid" and self.hybrid_period:
                # amortized shared attn+mlp block (single copy over all groups)
                if li == 0:
                    hh = self.n_heads * self.hd
                    p += d * hh * 2 + d * self.n_kv_heads * self.hd * 2
                    p += 3 * d * self.d_ff
            return p
        # attention
        if self.use_mla:
            qk = self.qk_nope_dim + self.qk_rope_dim
            attn = (d * self.q_lora_rank + self.q_lora_rank * self.n_heads * qk
                    + d * (self.kv_lora_rank + self.qk_rope_dim)
                    + self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                    + self.n_heads * self.v_head_dim * d)
        else:
            hh = self.n_heads * self.hd
            attn = d * hh + 2 * d * self.n_kv_heads * self.hd + hh * d
        # ffn
        is_moe = self.n_experts > 0 and li >= self.first_dense_layers
        if is_moe:
            ffn = self.n_experts * 3 * d * self.moe_d_ff + d * self.n_experts
            ffn += self.n_shared_experts * 3 * d * self.moe_d_ff
        else:
            n_gate = 3 if self.act == "silu" else 2
            ffn = n_gate * d * self.d_ff
        return attn + ffn

    def n_active_params(self) -> int:
        """Active params per token (MoE top-k counting)."""
        if self.n_experts == 0:
            return self.n_params()
        d, l = self.d_model, self.n_layers
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for li in range(l):
            full = self._layer_params(li)
            is_moe = li >= self.first_dense_layers
            if is_moe:
                routed = self.n_experts * 3 * d * self.moe_d_ff
                active = self.top_k * 3 * d * self.moe_d_ff
                full = full - routed + active
            total += full
        return total

    def tiny(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        reps = dict(
            n_layers=max(2, min(4, self.n_layers)),
            d_model=64,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            head_dim=16 if self.n_heads else 0,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=min(2, self.n_kv_heads) if self.n_kv_heads else 0,
            local_window=32,
            encoder_seq=24 if self.family == "encdec" else self.encoder_seq,
            vision_seq=16 if self.family == "vlm" else self.vision_seq,
            ssm_chunk=16 if self.ssm_state else self.ssm_chunk,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else self.ssm_head_dim,
            scan_layers=self.scan_layers,
        )
        if self.n_experts:
            reps.update(n_experts=4, top_k=min(2, self.top_k), moe_d_ff=64,
                        first_dense_layers=min(1, self.first_dense_layers))
        if self.use_mla:
            reps.update(q_lora_rank=32, kv_lora_rank=32, qk_rope_dim=8,
                        qk_nope_dim=16, v_head_dim=16)
        if self.local_global_period:
            reps.update(local_global_period=2)
        if self.hybrid_period:
            reps.update(hybrid_period=2)
        if self.cross_attn_period:
            reps.update(cross_attn_period=2)
        if self.n_encoder_layers:
            reps.update(n_encoder_layers=2)
        # keep layer-count divisibility with periods
        period = reps.get("local_global_period") or reps.get("hybrid_period") \
            or reps.get("cross_attn_period")
        if period:
            reps["n_layers"] = 2 * period
        return dataclasses.replace(self, **reps)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    """The assigned shape cells for this arch, applying documented skips."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")
    return out
