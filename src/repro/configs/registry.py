"""The 10 assigned architectures, exact configs from the assignment sheet.

`[source; tier]` provenance is recorded per config. Values not present in
the assignment line (head_dim, window sizes, MLA ranks, dense-prefix FFN)
come from the cited public model cards and are marked in `source`.
"""
from __future__ import annotations

from repro.configs.base import ArchConfig

ARCHS: dict[str, ArchConfig] = {}


def _reg(cfg: ArchConfig) -> ArchConfig:
    ARCHS[cfg.name] = cfg
    return cfg


mamba2_2p7b = _reg(ArchConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
    sub_quadratic=True,
    source="[arXiv:2405.21060; unverified] SSD; 80 heads of P=64",
))

whisper_small = _reg(ArchConfig(
    name="whisper-small", family="encdec",
    n_layers=12, n_encoder_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=51865, encoder_seq=1500,
    norm_type="layernorm", act="gelu",
    source="[arXiv:2212.04356; unverified] enc-dec; conv frontend stubbed "
           "(batch['enc'] = precomputed 1500-frame embeddings)",
))

llama32_vision_90b = _reg(ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256,
    cross_attn_period=5, vision_seq=1601, rope_theta=500000.0,
    source="[hf:meta-llama/Llama-3.2-11B-Vision scaled; unverified] "
           "cross-attn image layers every 5; patch embeddings stubbed",
))

olmo_1b = _reg(ArchConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=50304,
    norm_type="nonparam_ln", tie_embeddings=True,
    source="[arXiv:2402.00838; hf] non-parametric LN, tied embeddings",
))

granite_3_2b = _reg(ArchConfig(
    name="granite-3-2b", family="dense",
    n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, vocab_size=49155, tie_embeddings=True,
    source="[hf:ibm-granite/granite-3.0-2b-base; hf] GQA kv=8",
))

h2o_danube3_4b = _reg(ArchConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, head_dim=120,
    d_ff=10240, vocab_size=32000,
    attn_kind="local", local_window=4096, sub_quadratic=True,
    source="[arXiv:2401.16818; unverified] llama+mistral mix, SWA window "
           "4096 (mistral default)",
))

gemma3_12b = _reg(ArchConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15360, vocab_size=262144,
    attn_kind="local_global", local_global_period=6, local_window=1024,
    rope_theta=1000000.0, sub_quadratic=True, tie_embeddings=True,
    source="[hf:google/gemma-3-12b family; unverified] 5 local (w=1024) : "
           "1 global, 128k ctx",
))

phi35_moe = _reg(ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=6400, vocab_size=32064,
    n_experts=16, top_k=2, moe_d_ff=6400, capacity_factor=1.25,
    source="[hf:microsoft/Phi-3.5-MoE-instruct; hf] 16 experts top-2",
))

deepseek_v3 = _reg(ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432, vocab_size=129280,
    n_experts=256, top_k=8, moe_d_ff=2048, n_shared_experts=1,
    first_dense_layers=3, capacity_factor=1.25,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_rope_dim=64, qk_nope_dim=128, v_head_dim=128,
    mtp=True,
    source="[arXiv:2412.19437; hf] MLA; 1 shared + 256 routed top-8; MTP "
           "depth-1; dense d_ff=18432 for the 3-layer dense prefix "
           "(assignment's d_ff=2048 is the routed expert size)",
))

zamba2_2p7b = _reg(ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
    hybrid_period=6, sub_quadratic=True,
    source="[arXiv:2411.15242; hf] Mamba2 backbone + shared attn+MLP block "
           "every 6 layers (LoRA specialization simplified to per-group "
           "input norms; see DESIGN.md)",
))


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch '{name}'; have {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs() -> list[str]:
    return sorted(ARCHS)
