from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, applicable_shapes
from repro.configs.registry import ARCHS, get_arch, list_archs

__all__ = [
    "ArchConfig",
    "ShapeConfig",
    "SHAPES",
    "applicable_shapes",
    "ARCHS",
    "get_arch",
    "list_archs",
]
