"""E2E: probe → estimate → resume (paper Algorithm 1).

The three stages of the framework (paper Fig. 4):
  1. Early Probe   — run the lockstep search with per-lane budget f. The
                     probe *is* the first f NDCs of the real traversal.
  2. Cost Estimate — extract z_q from the live SearchState, run the GBDT,
                     obtain Ŵ_q = α·exp(M(z_q)).
  3. Adaptive Term — resume the identical loop carry with budget Ŵ_q.

On a quantized engine (precision "int8" / "pq") a fourth, terminal stage
runs: the exact float32 rerank of the final candidate pool (repro.quant),
which re-scores ≤ (M+K) retained vectors per query so recall survives the
compressed-domain traversal. The rerank replaces only the result buffers;
`state.cnt` keeps counting compressed-domain NDCs.

Also provides the DARTH-style iterative variant (`repredict_every` > 0):
re-extract features and re-predict every Δ NDCs, stopping when the
prediction no longer exceeds the spent budget.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.engine import BIG_BUDGET, SearchEngine
from repro.core.estimator import CostEstimator
from repro.core.features import ablate_filter_features, extract_features
from repro.core.search import SearchConfig, SearchState


@dataclasses.dataclass
class E2EResult:
    state: SearchState
    predicted_budget: np.ndarray  # [B]
    probe_features: np.ndarray    # [B, F]
    reports: list | None = None   # explain=True: [B] obs.QueryReport


def probe_and_features(
    engine: SearchEngine,
    cfg: SearchConfig,
    queries: np.ndarray,
    filt,                          # FilterSpec | Expr(s) | FilterProgram
    probe_budget: int,
    n_probes: int = 2,
    gt_dist: np.ndarray | None = None,
    tracer=None,
    trace_id: str = "",
):
    """Run the early probe and extract trajectory features.

    With n_probes=2 (default), features are taken at budget f/2 and f and
    concatenated as [z_f, z_f - z_{f/2}] — the deltas encode *convergence
    speed* (how fast valid results accumulate / distances shrink), a signal
    a single snapshot cannot carry. This is our beyond-paper extension of
    the probe phase; n_probes=1 reproduces the paper exactly. The probe
    remains zero-overhead: both snapshots are prefixes of the same
    traversal carry.

    `tracer` wraps each probe dispatch in a "probe" span and the feature
    extraction in a "feature-extract" span; both measure host dispatch
    only (no reads of device values are added), so the state stream is
    untouched with tracing on.
    """
    import jax.numpy as jnp

    from repro.obs.trace import as_tracer

    tr = as_tracer(tracer)
    # budget may be scalar or per-lane [B] (the scheduler zeroes padding
    # lanes); span attrs must be host scalars, so report the lane max
    bud = (int(probe_budget) if np.ndim(probe_budget) == 0
           else int(np.asarray(probe_budget).max(initial=0)))
    # compile once up front — engine.compile passes a FilterProgram through
    # untouched, so the per-phase engine.search calls skip the host-side
    # expression lowering (a Python loop over the batch for exprs)
    filt = engine.compile(filt)
    if n_probes <= 1:
        with tr.span("probe", trace_id, budget=bud, snapshot=1,
                     n_probes=1):
            state = engine.search(cfg, queries, filt, probe_budget,
                                  gt_dist=gt_dist, tracer=tracer,
                                  trace_id=trace_id)
        with tr.span("feature-extract", trace_id, n_probes=1):
            z = extract_features(state)
        return state, z
    with tr.span("probe", trace_id, budget=bud // 2, snapshot=1,
                 n_probes=int(n_probes)):
        state = engine.search(cfg, queries, filt, probe_budget // 2,
                              gt_dist=gt_dist, tracer=tracer,
                              trace_id=trace_id)
    z1 = extract_features(state)
    with tr.span("probe", trace_id, budget=bud, snapshot=2,
                 n_probes=int(n_probes)):
        state = engine.search(cfg, queries, filt, probe_budget, state=state,
                              gt_dist=gt_dist, tracer=tracer,
                              trace_id=trace_id)
    with tr.span("feature-extract", trace_id, n_probes=int(n_probes)):
        z2 = extract_features(state)
    return state, jnp.concatenate([z2, z2 - z1], axis=1)


def predict_budgets(
    estimator: CostEstimator,
    feats,
    alpha: float,
    min_budget: int = 32,
    max_budget: int = BIG_BUDGET,
    ablate_filter: bool = False,
    packed=None,
):
    """Stage 2 of the pipeline: features → clipped per-lane budgets Ŵ_q.

    Factored out of `e2e_search` so the serving scheduler's probe batches go
    through byte-for-byte the same prediction path as the one-shot pipeline
    (the scheduled-vs-oneshot equivalence guarantee depends on it). Returns
    (budgets [B] i32, feats-as-predicted) — the latter reflects ablation.
    """
    if ablate_filter:
        feats = ablate_filter_features(feats)
    packed = estimator.packed() if packed is None else packed
    budgets = estimator.predict_budget_jax(packed, feats, alpha, min_budget,
                                           max_budget)
    return budgets, feats


def e2e_search(
    engine: SearchEngine,
    estimator: CostEstimator,
    cfg: SearchConfig,
    queries: np.ndarray,
    filt,                          # FilterSpec | Expr(s) | FilterProgram
    probe_budget: int = 64,
    alpha: float = 1.0,
    min_budget: int = 32,
    max_budget: int = BIG_BUDGET,
    ablate_filter: bool = False,
    repredict_every: int = 0,
    max_repredict: int = 8,
    n_probes: int = 2,
    tracer=None,
    trace_id: str = "",
    explain: bool = False,
) -> E2EResult:
    """`tracer` emits lifecycle spans (probe / feature-extract / estimate /
    resume / rerank) at the host dispatch boundaries that already exist —
    results are bit-identical with tracing on vs. off. `explain=True`
    additionally builds one `obs.QueryReport` per lane (features, Ŵ_q,
    per-stage NDC + launch counts, termination reason) in
    `E2EResult.reports`; this reads back per-stage counters on the host,
    which explain mode accepts as its (post-search) cost."""
    from repro.core.search import dispatch_counters
    from repro.obs.trace import as_tracer

    tr = as_tracer(tracer)
    if tracer is not None and not trace_id:
        trace_id = tr.new_trace("e2e")

    # --- stage 1: early probe (zero overhead — same traversal carry) ---
    filt = engine.compile(filt)  # once for probe + resume + repredict loops
    d0 = dispatch_counters()
    state, feats = probe_and_features(engine, cfg, queries, filt, probe_budget,
                                      n_probes, tracer=tracer,
                                      trace_id=trace_id)
    d1 = dispatch_counters()
    probe_cnt = np.asarray(state.cnt).copy() if explain else None

    # --- stage 2: cost estimation ---
    packed = estimator.packed()
    with tr.span("estimate", trace_id, alpha=float(alpha)):
        budgets, feats = predict_budgets(estimator, feats, alpha, min_budget,
                                         max_budget, ablate_filter,
                                         packed=packed)

    # --- stage 3: adaptive termination (resume with predicted budget) ---
    n_resume_calls = 0
    if repredict_every <= 0:
        with tr.span("resume", trace_id):
            state = engine.search(cfg, queries, filt, budgets, state=state,
                                  tracer=tracer, trace_id=trace_id)
        n_resume_calls = 1
    else:
        # DARTH-style stepwise: advance Δ NDCs, re-predict, stop when the
        # model says the spent budget suffices.
        import jax.numpy as jnp

        prev = extract_features(state)
        for rp in range(max_repredict):
            cur = np.asarray(state.cnt)
            tgt = np.asarray(budgets)
            if np.all(tgt <= cur):
                break
            step_budget = np.minimum(tgt, cur + repredict_every)
            with tr.span("resume", trace_id, repredict=rp):
                state = engine.search(cfg, queries, filt, step_budget,
                                      state=state, tracer=tracer,
                                      trace_id=trace_id)
            n_resume_calls += 1
            znow = extract_features(state)
            f2 = jnp.concatenate([znow, znow - prev], axis=1) if n_probes > 1 else znow
            prev = znow
            if ablate_filter:
                f2 = ablate_filter_features(f2)
            budgets = estimator.predict_budget_jax(packed, f2, alpha, min_budget, max_budget)
    d2 = dispatch_counters()

    # --- stage 4 (quantized engines): terminal exact float32 rerank ---
    with tr.span("rerank", trace_id,
                 precision=engine.effective_precision(cfg)):
        state = engine.rerank(cfg, queries, state)

    reports = None
    if explain:
        from repro.core.search import get_backend
        from repro.obs.explain import StageReport, build_reports

        final_cnt = np.asarray(state.cnt)
        bud = np.asarray(budgets)
        b = final_cnt.shape[0]
        backend_name = cfg.backend or engine.backend or "dense"
        if getattr(get_backend(backend_name), "persistent", False):
            probe_l = d1["launches"] - d0["launches"]
            resume_l = d2["launches"] - d1["launches"]
        else:
            # single-dispatch backends: one device dispatch per search call
            probe_l = 1 if n_probes <= 1 else 2
            resume_l = n_resume_calls
        stages = [
            [StageReport("probe", ndc=int(probe_cnt[i]), launches=probe_l,
                         attrs=dict(budget=int(probe_budget),
                                    n_probes=int(n_probes))),
             StageReport("estimate", attrs=dict(alpha=float(alpha))),
             StageReport("resume", ndc=int(final_cnt[i] - probe_cnt[i]),
                         launches=resume_l),
             StageReport("rerank", attrs=dict(
                 precision=engine.effective_precision(cfg)))]
            for i in range(b)
        ]
        reports = build_reports(
            cfg, state, bud, backend=backend_name,
            probe_ndc=probe_cnt, features=np.asarray(feats),
            trace_ids=[f"{trace_id or 'e2e'}:{i}" for i in range(b)],
            stages=stages)
        if getattr(state, "shard", None) is not None:
            from repro.obs.shard import attach_shard_sections

            attach_shard_sections(reports, cfg, state, bud)

    return E2EResult(
        state=state,
        predicted_budget=np.asarray(budgets),
        probe_features=np.asarray(feats),
        reports=reports,
    )
