"""Search-state layer: configuration, the lockstep carry, init and resume.

This is the bottom of the traversal stack (state → step → backend → engine).
Everything here is backend-agnostic: the same `SearchState` flows through the
dense-jnp reference backend and the fused Pallas backend, which is what makes
the paper's zero-overhead probe (run with budget=f, resume the carry with
budget=Ŵ_q) a property of the *state*, not of any particular kernel.

Key structures (all static shapes):
  candidate queue   sorted ascending [B, M]  (dist, idx, expanded, valid)
  result set        sorted ascending [B, K]  (valid nodes only)
  visited set       packed bitset    [B, ceil(N/32)] uint32
  counters          cnt (NDC), n_inspected, n_valid_visited, n_pop_valid,
                    n_clause_valid (per predicate clause), hops

Filters arrive as a compiled `FilterProgram` (filters/compile.py): a padded
clause-slot program a whole heterogeneous batch evaluates in one pass, so
neither the state nor the step ever branches on a predicate kind.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.filters.compile import clause_counts, eval_program_gathered
from repro.filters.predicates import PRED_CONTAIN

INF = jnp.float32(jnp.inf)


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    k: int = 10                # result set size
    queue_size: int = 128      # M — beam width / ef analogue
    degree: int = 32           # graph out-degree R (static)
    pred_kind: int = PRED_CONTAIN  # legacy tag; traversal is driven entirely
                               # by the compiled FilterProgram and ignores it
    mode: str = "post"         # "post" | "pre" | "widen"
                               # widen = filtered-expansion traversal (the
                               # planner's middle plan): the pre-mode
                               # widened frontier (1-hop ∪ strided 2-hop)
                               # with post-mode scoring/accounting — every
                               # new neighbor is distance-scored and NDC'd,
                               # but the frontier can step across invalid
                               # regions a selective conjunction carves out
    two_hop_stride: int = 8    # pre/widen: sample every s-th 2-hop neighbor
    max_steps: int = 100000
    greedy_stop: bool = False  # optional: stop when best cand > worst result
    backend: str | None = None # TraversalBackend name; None → inherit the
                               # engine default (or "dense" standalone)
    steps_per_launch: int = 8  # persistent backends: lockstep steps grouped
                               # into one dispatch (VMEM-resident multi-step
                               # kernel on TPU, launch-grouped host stepping
                               # elsewhere). Ignored by single-step backends.
    use_pallas: bool = False   # dense backend: route distances through Pallas
    precision: str | None = None  # "float32" | "int8" | "pq"; None → inherit
                               # the engine's precision ("float32" standalone).
                               # Non-float32 evaluates traversal distances in
                               # the compressed domain (repro.quant) and
                               # requires the engine/run_search quant index.


class SearchState(NamedTuple):
    cand_dist: jax.Array       # [B, M] f32 sorted ascending, inf padded
    cand_idx: jax.Array        # [B, M] i32, -1 padded
    cand_exp: jax.Array        # [B, M] bool — already expanded
    cand_valid: jax.Array      # [B, M] bool — predicate validity
    res_dist: jax.Array        # [B, K] f32 sorted ascending, inf padded
    res_idx: jax.Array         # [B, K] i32, -1 padded
    visited: jax.Array         # [B, NW] u32 bitset
    cnt: jax.Array             # [B] i32 — NDC (paper's W_q unit)
    n_inspected: jax.Array     # [B] i32 — predicate evaluations
    n_valid_visited: jax.Array # [B] i32 — valid among inspected
    n_clause_valid: jax.Array  # [B, C] i32 — per-clause-slot hits among
                               # inspected (C = CLAUSE_FEATURE_SLOTS, fixed
                               # regardless of the program's slot count)
    n_pop_valid: jax.Array     # [B] i32 — valid among popped/expanded
    q_err_sum: jax.Array       # [B] f32 — Σ reconstruction error ‖x − x̂‖²
                               # over inspected nodes (0 in float32 mode);
                               # feeds the quant_err_* bias features
    hops: jax.Array            # [B] i32 — expansions (search hops)
    active: jax.Array          # [B] bool
    d_start: jax.Array         # [B] f32 — entry-point distance (feature)
    conv_cnt: jax.Array        # [B] i32 — NDC at first full-recall, -1 if not yet
    res_full_cnt: jax.Array    # [B] i32 — NDC when the k-th valid was found, -1 if not yet


def init_state(
    cfg: SearchConfig,
    queries: jax.Array,      # [B, d]
    prog,                    # FilterProgram (leaves [B, S, ...])
    base_vectors: jax.Array, # [N, d]
    attrs,                   # (labels [N, W] u32, values [N, V] f32)
    entry_point: int,
    gt_dist: jax.Array | None = None,  # [B, K] for convergence tracking
    quant=None,                        # Int8Index | PQIndex (compressed mode)
    qprep=None,                        # prepared per-query ADC state
) -> SearchState:
    from repro.kernels.distance import sqdist_bdrd

    del gt_dist  # tracked by the step fn; accepted for signature stability
    b = queries.shape[0]
    n = base_vectors.shape[0]
    nw = (n + 31) // 32
    m, k = cfg.queue_size, cfg.k
    labels, values = attrs

    ep = jnp.full((b, 1), entry_point, dtype=jnp.int32)
    if (cfg.precision or "float32") != "float32":
        # entry distance in the compressed domain — the whole traversal
        # (d_start feature included) lives in one consistent metric
        from repro.quant.codecs import QuantGather, quant_dist

        norms0 = quant.norms[ep]
        codes0 = quant.codes[ep]
        if codes0.dtype == jnp.uint8:
            codes0 = codes0.astype(jnp.int32)
        d0 = quant_dist(cfg.precision,
                        QuantGather(prep=qprep, codes=codes0, norms=norms0))
        err0 = quant.err[ep][:, 0]
    else:
        d0 = sqdist_bdrd(queries, base_vectors[ep])          # [B,1]
        err0 = jnp.zeros((b,), jnp.float32)
    val0, csat0 = eval_program_gathered(prog, labels[ep], values[ep])
    cadd0 = clause_counts(csat0, jnp.ones_like(val0))

    cand_dist = jnp.full((b, m), INF).at[:, :1].set(d0)
    cand_idx = jnp.full((b, m), -1, dtype=jnp.int32).at[:, :1].set(ep)
    cand_exp = jnp.zeros((b, m), dtype=bool)
    cand_valid = jnp.zeros((b, m), dtype=bool).at[:, :1].set(val0)

    res_dist = jnp.full((b, k), INF)
    res_idx = jnp.full((b, k), -1, dtype=jnp.int32)
    res_dist = res_dist.at[:, 0].set(jnp.where(val0[:, 0], d0[:, 0], INF))
    res_idx = res_idx.at[:, 0].set(jnp.where(val0[:, 0], ep[:, 0], -1))

    visited = jnp.zeros((b, nw), dtype=jnp.uint32)
    word = entry_point // 32
    bit = jnp.uint32(1) << jnp.uint32(entry_point % 32)
    visited = visited.at[:, word].set(bit)

    ndc0 = jnp.ones((b,), jnp.int32)  # entry distance is computed in both modes
    return SearchState(
        cand_dist=cand_dist,
        cand_idx=cand_idx,
        cand_exp=cand_exp,
        cand_valid=cand_valid,
        res_dist=res_dist,
        res_idx=res_idx,
        visited=visited,
        cnt=ndc0,
        n_inspected=jnp.ones((b,), jnp.int32),
        n_valid_visited=val0[:, 0].astype(jnp.int32),
        n_clause_valid=cadd0,
        n_pop_valid=jnp.zeros((b,), jnp.int32),
        q_err_sum=err0,
        hops=jnp.zeros((b,), jnp.int32),
        active=jnp.ones((b,), bool),
        d_start=d0[:, 0],
        conv_cnt=jnp.full((b,), -1, jnp.int32),
        res_full_cnt=jnp.where(val0[:, 0] & (k == 1), 1, -1).astype(jnp.int32),
    )


def prepare_resume(state: SearchState) -> SearchState:
    """Reactivate lanes that stopped purely on budget (probe → resume)."""
    return state._replace(active=jnp.ones_like(state.active))


# ---- lane surgery (serving layer) -------------------------------------------
# The lockstep loop has no cross-lane collectives, so a SearchState (or any
# per-query pytree) can be sliced apart and re-stacked freely between search
# calls: a lane's trajectory depends only on its own buffers. The serving
# scheduler relies on this to carry individual requests' states across
# micro-batches (probe batch → budget-bucket batch → requeue batch).


# All three helpers are jitted: a SearchState has ~17 leaves, and eager
# per-op dispatch (~0.7 ms/op on CPU) would make every slice/stack cost
# more than the traversal work it routes. Retraces are bounded by the few
# distinct (tree structure, lane count) combinations a scheduler produces.


@jax.jit
def take_lanes(tree, idx):
    """Select lanes `idx` (int array / list) along axis 0 of every leaf."""
    idx = jnp.asarray(idx, jnp.int32)
    return jax.tree.map(lambda a: a[idx], tree)


@jax.jit
def concat_lanes(trees):
    """Stack per-lane pytrees ([b_i, ...] leaves) into one batch along axis 0."""
    if len(trees) == 1:
        return trees[0]
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *trees)


@functools.partial(jax.jit, donate_argnums=(0,))
def put_lanes(tree, sub, idx):
    """Scatter `sub`'s lanes back into `tree` at rows `idx` (inverse of
    take_lanes). Donates the full-width tree: the scatter updates buffers in
    place instead of copying ~17 [B, ...] leaves per launch. Duplicate rows
    in `idx` are fine when the duplicated lanes carry identical values (the
    persistent driver pads its selection by repeating a lane)."""
    idx = jnp.asarray(idx, jnp.int32)
    return jax.tree.map(lambda a, s: a.at[idx].set(s), tree, sub)


@functools.partial(jax.jit, static_argnames=("pad",))
def pad_lanes(tree, pad: int):
    """Zero-pad every array leaf along axis 0. Padded lanes are inert: they
    carry a 0 NDC budget at the call site and deactivate on their first step,
    so the zero values never influence real lanes."""
    if pad == 0:
        return tree
    return jax.tree.map(
        lambda a: jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1)), tree)


# ---- shard surgery (index-axis-sharded engines) -----------------------------
# A sharded engine carries one SearchState per index shard, stacked along a
# *second* axis ([B, S, ...] leaves) so the lane-surgery helpers above keep
# operating on axis 0 unchanged. These two helpers move between the stacked
# form and the per-shard [B, ...] states the lockstep loop consumes.


@jax.jit
def stack_shards(states):
    """Stack per-shard pytrees ([B, ...] leaves) along a new shard axis 1."""
    if len(states) == 1:
        return jax.tree.map(lambda a: a[:, None], states[0])
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=1), *states)


@functools.partial(jax.jit, static_argnames=("s",))
def take_shard(tree, s: int):
    """Select shard `s` from a shard-stacked pytree ([B, S, ...] leaves)."""
    return jax.tree.map(lambda a: a[:, s], tree)


def topk_results(state: SearchState) -> tuple[np.ndarray, np.ndarray]:
    """Host-side (idx, dist) of the result set."""
    return np.asarray(state.res_idx), np.asarray(state.res_dist)
