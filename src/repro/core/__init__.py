# The paper's primary contribution: E2E cost estimation + adaptive
# termination for filtered AKNN search, as a composable JAX module.
from repro.core.search import SearchConfig, SearchState, run_search, init_state
from repro.core.state import (take_lanes, concat_lanes, pad_lanes,
                              stack_shards, take_shard)
from repro.core.sharded import (ShardedSearchEngine, ShardedSearchState,
                                merge_shard_states)
from repro.core.backends import (
    TraversalBackend,
    available_backends,
    get_backend,
    register_backend,
)
from repro.core.engine import SearchEngine, BIG_BUDGET, make_search_mesh
from repro.core.features import (
    extract_features,
    ablate_filter_features,
    FEATURE_NAMES,
    FILTER_FEATURE_IDX,
    N_FEATURES,
)
from repro.core.gbdt import GBDTModel, train_gbdt, predict_jax
from repro.core.estimator import CostEstimator, spearman
from repro.core.training import TrainingData, generate_training_data
from repro.core.e2e import E2EResult, e2e_search, predict_budgets, probe_and_features
from repro.core.plans import ScanStats, scan_search, scan_stats
from repro.core.planner import (
    PLANS,
    Planner,
    PlanResult,
    PlanTrainingData,
    fit_planner,
    generate_plan_training_data,
    planned_search,
    run_plan,
    static_features,
)
from repro.core import baselines

__all__ = [
    "SearchConfig",
    "SearchState",
    "run_search",
    "init_state",
    "SearchEngine",
    "BIG_BUDGET",
    "make_search_mesh",
    "TraversalBackend",
    "available_backends",
    "get_backend",
    "register_backend",
    "extract_features",
    "ablate_filter_features",
    "FEATURE_NAMES",
    "FILTER_FEATURE_IDX",
    "N_FEATURES",
    "GBDTModel",
    "train_gbdt",
    "predict_jax",
    "CostEstimator",
    "spearman",
    "TrainingData",
    "generate_training_data",
    "E2EResult",
    "e2e_search",
    "predict_budgets",
    "probe_and_features",
    "take_lanes",
    "concat_lanes",
    "pad_lanes",
    "stack_shards",
    "take_shard",
    "ShardedSearchEngine",
    "ShardedSearchState",
    "merge_shard_states",
    "ScanStats",
    "scan_search",
    "scan_stats",
    "PLANS",
    "Planner",
    "PlanResult",
    "PlanTrainingData",
    "fit_planner",
    "generate_plan_training_data",
    "planned_search",
    "run_plan",
    "static_features",
    "baselines",
]
