"""Ground-truth W_q generation + estimator training (paper §4.3).

For each training query: run the probe (budget = f NDC) and snapshot the
feature vector z_q, then *continue the same traversal* with an effectively
unlimited budget while tracking `conv_cnt` — the NDC at which the result set
first covers the bruteforce filtered top-k (recall = 1.0). That NDC is the
regression target W_q.

Queries whose ground truth is unreachable through the graph (filtered
sub-graph disconnection — exactly the paper's PreFiltering pathology) never
converge; for them W_q = the NDC at search exhaustion, i.e. the true cost
of the maximal traversal. This matches the paper's "fixed and large enough
budget" protocol.

On a quantized engine the *convergence* target switches to the
compressed-domain filtered top-k (quant.compressed_filtered_topk): the
traversal's result distances are compressed, so requiring them to cover the
exact float32 ground truth would (correctly) never succeed and every W_q
label would collapse to the exhaustion cost — an estimator trained on that
predicts one number. Covering the compressed-domain optimum is the
achievable definition of "done" pre-rerank, which is what keeps the cost
model calibrated under quantization. The exact gt_idx/gt_dist returned in
`TrainingData` stay float32-exact (they are what recall is measured
against, post-rerank).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.engine import BIG_BUDGET, SearchEngine
from repro.core.features import extract_features
from repro.core.search import SearchConfig
from repro.data.synthetic import AttributedDataset, QueryWorkload
from repro.index.bruteforce import filtered_knn_exact


@dataclasses.dataclass
class TrainingData:
    features: np.ndarray   # [n, F]
    w_q: np.ndarray        # [n]
    converged: np.ndarray  # [n] bool
    gt_idx: np.ndarray     # [n, k]
    gt_dist: np.ndarray    # [n, k]


def generate_training_data(
    engine: SearchEngine,
    ds: AttributedDataset,
    workload: QueryWorkload,
    cfg: SearchConfig,
    probe_budget: int = 64,
    chunk: int = 64,
    n_probes: int = 2,
) -> TrainingData:
    from repro.core.e2e import probe_and_features

    compressed = engine.effective_precision(cfg) != "float32"
    n = workload.batch
    feats, wq, conv, gti, gtd = [], [], [], [], []
    for s in range(0, n, chunk):
        e = min(s + chunk, n)
        q = workload.queries[s:e]
        filt = workload.filter_slice(s, e)
        # ground truth comes from the dataset, not the engine's device
        # arrays: host-tiered and index-sharded engines hold placeholders /
        # per-shard slices, and `ds` is the same rows either way
        gt_idx, gt_dist = filtered_knn_exact(
            q, np.asarray(ds.vectors), filt,
            np.asarray(ds.labels_packed), np.asarray(ds.value_matrix), cfg.k,
        )
        if compressed:
            # convergence is judged in the metric the traversal actually
            # searches in (see module docstring)
            from repro.index.bruteforce import valid_mask
            from repro.quant import compressed_filtered_topk

            ok = valid_mask(filt, np.asarray(ds.labels_packed),
                            np.asarray(ds.value_matrix))
            conv_dist, _ = compressed_filtered_topk(
                engine.effective_precision(cfg),
                getattr(engine, "quant_concat", None) or engine.quant,
                q, ok, cfg.k)
        else:
            conv_dist = gt_dist
        prog = engine.compile(filt)  # once for the probe + exhaustion resume
        # probe phase (budget = f) -> trajectory features
        st, z = probe_and_features(engine, cfg, q, prog, probe_budget,
                                   n_probes, gt_dist=conv_dist)
        z = np.asarray(z)
        # resume to exhaustion, tracking convergence NDC
        st = engine.search(cfg, q, prog, BIG_BUDGET, state=st, gt_dist=conv_dist)
        cc = np.asarray(st.conv_cnt)
        cnt = np.asarray(st.cnt)
        converged = cc > 0
        w = np.where(converged, cc, cnt).astype(np.int64)
        feats.append(z)
        wq.append(w)
        conv.append(converged)
        gti.append(gt_idx)
        gtd.append(gt_dist)
    return TrainingData(
        features=np.concatenate(feats),
        w_q=np.concatenate(wq),
        converged=np.concatenate(conv),
        gt_idx=np.concatenate(gti),
        gt_dist=np.concatenate(gtd),
    )
