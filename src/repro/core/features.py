"""Runtime feature extraction (paper Table 1) from a `SearchState`.

Four groups — Global, Filter (ours), Queue (DARTH/LAET-adapted), Result-set
(DARTH/LAET-adapted) — computed entirely from the sorted fixed-size buffers,
so extraction is O(M) elementwise work per lane and jit-compatible (it runs
between the probe and the adaptive-termination phases with no host sync).

Sentinels: lanes with empty queues / result sets fall back to d_start-scaled
defaults (GBDT is insensitive to the exact choice; it just needs a
consistent encoding).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.state import SearchState
from repro.filters.compile import CLAUSE_FEATURE_SLOTS

FEATURE_NAMES: tuple[str, ...] = (
    # --- Global (LAET†) ---
    "d_start",
    "n_hops",
    "log_cnt",
    # --- Filter (ours*) ---
    "rho_pilot",
    "rho_queue",
    "rho_pop",
    # --- Queue (DARTH‡ / LAET†) ---
    "d_queue_head",
    "d_queue_tail",
    "r_queue_head",
    "r_queue_tail",
    "avg_queue",
    "var_queue",
    "perc25_queue",
    "perc50_queue",
    "perc75_queue",
    "queue_fill",
    # --- Result set (DARTH‡ / LAET†) ---
    "d_nn_first",
    "d_nn_last",
    "r_nn_first",
    "r_nn_last",
    "avg_nn",
    "var_nn",
    "perc25_nn",
    "perc50_nn",
    "perc75_nn",
    "res_fill",
    # --- progression (ours*) ---
    "log_res_full_cnt",   # NDC at which the k-th valid appeared (sentinel: 2·cnt)
    "gap_queue_nn",       # (d_queue_tail - d_nn_last)/d_start — frontier vs results
    # --- compressed-distance bias (ours*, quantized index; 0 at float32) ---
    # mean per-inspected-node reconstruction error ‖x − x̂‖², accumulated
    # during the probe. A lane whose compressed distances are noisy relative
    # to its own distance scale (quant_err_mean) or to its current frontier
    # (quant_err_head) needs more budget for the same recall — without
    # these the GBDT trained under quantization mixes two cost regimes.
    "quant_err_mean",     # Σ err / n_inspected, d_start-normalized
    "quant_err_head",     # Σ err / n_inspected, queue-head-normalized
    # --- per-clause probe selectivities (ours*, filter algebra) ---
    # rho of each compiled clause slot among inspected nodes: a conjunction
    # whose clauses have very different local selectivities costs very
    # differently from one whose clauses agree, which one aggregate rho
    # cannot express. Slots beyond the program's clause count read 0.
    "rho_clause_0",
    "rho_clause_1",
    "rho_clause_2",
    "rho_clause_3",
)

N_FEATURES = len(FEATURE_NAMES)

assert FEATURE_NAMES[-CLAUSE_FEATURE_SLOTS:] == tuple(
    f"rho_clause_{c}" for c in range(CLAUSE_FEATURE_SLOTS)
), "rho_clause_* names must track filters.compile.CLAUSE_FEATURE_SLOTS"

# Feature indices that constitute the paper's novel Filter group — the
# no-filter-features ablation (paper Figs. 5/6 "w/o filter") zeroes these.
# (includes the progression features, which are also filter-derived: they
# measure how fast *valid* results accumulate, and the per-clause rhos;
# the quant_err_* pair is quantization-derived, not filter-derived, and
# stays out of the ablation)
FILTER_FEATURE_IDX = tuple(
    FEATURE_NAMES.index(n)
    for n in ("rho_pilot", "rho_queue", "rho_pop", "log_res_full_cnt",
              "gap_queue_nn", "rho_clause_0", "rho_clause_1", "rho_clause_2",
              "rho_clause_3"))


def _stats_sorted(dist: jax.Array, d_start: jax.Array):
    """Stats over the finite prefix of an ascending-sorted [B, M] buffer."""
    b, m = dist.shape
    finite = jnp.isfinite(dist)
    count = finite.sum(axis=1)                                # [B]
    has = count > 0
    safe_count = jnp.maximum(count, 1)

    head = jnp.where(has, dist[:, 0], d_start)
    tail_ix = jnp.clip(count - 1, 0, m - 1)
    tail = jnp.take_along_axis(dist, tail_ix[:, None], axis=1)[:, 0]
    tail = jnp.where(has, tail, d_start)

    dz = jnp.where(finite, dist, 0.0)
    s1 = dz.sum(axis=1)
    s2 = (dz * dz).sum(axis=1)
    mean = s1 / safe_count
    var = jnp.maximum(s2 / safe_count - mean * mean, 0.0)
    mean = jnp.where(has, mean, d_start)
    var = jnp.where(has, var, 0.0)

    percs = []
    for qq in (0.25, 0.5, 0.75):
        ix = jnp.clip(jnp.round(qq * (count - 1)).astype(jnp.int32), 0, m - 1)
        pv = jnp.take_along_axis(dist, ix[:, None], axis=1)[:, 0]
        percs.append(jnp.where(has, pv, d_start))
    fill = count.astype(jnp.float32) / m
    return head, tail, mean, var, percs, fill


@jax.jit
def extract_features(state: SearchState) -> jax.Array:
    """SearchState -> [B, N_FEATURES] float32 feature matrix z_q.

    Jitted: ~60 elementwise/stat ops over small arrays — eager per-op
    dispatch on CPU costs more than the math and would dominate the
    serving scheduler's probe batches (it runs twice per probe)."""
    ds = jnp.maximum(state.d_start, 1e-12)

    qh, qt, qm, qv, qp, qfill = _stats_sorted(state.cand_dist, state.d_start)
    rh, rt, rm, rv, rp, rfill = _stats_sorted(state.res_dist, state.d_start)

    in_q = state.cand_idx >= 0
    nq = jnp.maximum(in_q.sum(axis=1), 1)
    rho_queue = (state.cand_valid & in_q).sum(axis=1) / nq
    rho_pilot = state.n_valid_visited / jnp.maximum(state.n_inspected, 1)
    rho_pop = state.n_pop_valid / jnp.maximum(state.hops, 1)
    rho_clause = state.n_clause_valid / jnp.maximum(state.n_inspected, 1)[:, None]
    err_mean = state.q_err_sum / jnp.maximum(state.n_inspected, 1)

    feats = jnp.stack(
        [
            state.d_start,
            state.hops.astype(jnp.float32),
            jnp.log1p(state.cnt.astype(jnp.float32)),
            rho_pilot.astype(jnp.float32),
            rho_queue.astype(jnp.float32),
            rho_pop.astype(jnp.float32),
            qh,
            qt,
            qh / ds,
            qt / ds,
            qm,
            qv,
            qp[0],
            qp[1],
            qp[2],
            qfill,
            rh,
            rt,
            rh / ds,
            rt / ds,
            rm,
            rv,
            rp[0],
            rp[1],
            rp[2],
            rfill,
            jnp.log1p(
                jnp.where(state.res_full_cnt >= 0, state.res_full_cnt, 2 * state.cnt)
                .astype(jnp.float32)
            ),
            (qt - rt) / ds,
            err_mean / ds,
            err_mean / jnp.maximum(qh, 1e-12),
        ]
        + [rho_clause[:, c].astype(jnp.float32)
           for c in range(rho_clause.shape[1])],
        axis=1,
    )
    return feats.astype(jnp.float32)


def ablate_filter_features(feats: jax.Array) -> jax.Array:
    """Zero the paper's filter-aware features (the Figs. 5/6 ablation).

    Handles multi-probe concatenated feature vectors ([z, Δz] stacking of
    the base block): the filter indices are zeroed in every block.
    """
    out = feats
    n_blocks = feats.shape[1] // N_FEATURES
    for b in range(n_blocks):
        for ix in FILTER_FEATURE_IDX:
            out = out.at[:, b * N_FEATURES + ix].set(0.0)
    return out


def feature_names(n_probes: int = 2) -> list[str]:
    if n_probes <= 1:
        return list(FEATURE_NAMES)
    return list(FEATURE_NAMES) + [f"d_{n}" for n in FEATURE_NAMES]
