"""Baselines the paper compares against (§5, Figs. 5-7).

  naive_search     Naive HNSW-style: conservative static configuration —
                   beam width M (the efsearch analogue) swept over a grid,
                   no budget termination. The paper's primary baseline.
  fixed_budget     static global NDC budget (worst-case provisioning).
  laet_search      LAET [28]-style learned termination: same probe+predict
                   pipeline but with the Filter feature group removed
                   (distance-only features) — the "w/o filter" ablation of
                   Figs. 5/6 and the Feature-Filter-Misalignment victim.
  oracle_search    lower bound: terminate exactly at the ground-truth W_q.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.e2e import E2EResult, e2e_search
from repro.core.engine import BIG_BUDGET, SearchEngine
from repro.core.estimator import CostEstimator
from repro.core.search import SearchConfig, SearchState


def naive_search(
    engine: SearchEngine,
    cfg: SearchConfig,
    queries: np.ndarray,
    spec,
    ef: int,
) -> SearchState:
    """Static beam (efsearch) sweep point: queue_size=ef, unlimited budget."""
    c = dataclasses.replace(cfg, queue_size=ef)
    return engine.search(c, queries, spec, BIG_BUDGET)


def fixed_budget_search(
    engine: SearchEngine,
    cfg: SearchConfig,
    queries: np.ndarray,
    spec,
    budget: int,
) -> SearchState:
    return engine.search(cfg, queries, spec, budget)


def laet_search(
    engine: SearchEngine,
    estimator_nofilter: CostEstimator,
    cfg: SearchConfig,
    queries: np.ndarray,
    spec,
    probe_budget: int = 64,
    alpha: float = 1.0,
) -> E2EResult:
    """Distance-feature-only adaptive termination (filter group ablated)."""
    return e2e_search(
        engine, estimator_nofilter, cfg, queries, spec,
        probe_budget=probe_budget, alpha=alpha, ablate_filter=True,
    )


def oracle_search(
    engine: SearchEngine,
    cfg: SearchConfig,
    queries: np.ndarray,
    spec,
    w_q: np.ndarray,
    alpha: float = 1.0,
) -> SearchState:
    budgets = np.maximum((alpha * w_q).astype(np.int64), 1)
    return engine.search(cfg, queries, spec, budgets)
