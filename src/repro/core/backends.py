"""Traversal-backend layer: pluggable implementations of the per-step hot path.

A `TraversalBackend` owns the arithmetic core of one lockstep step — neighbor
distance evaluation and the two sorted-buffer merges (candidate queue top-M,
result set top-K). Everything else (pop, visited bitset, predicate, counters)
is shared in `repro.core.step`, so a backend is ~30 lines of focused code.

Registered backends:
  dense    reference path: jnp einsum distances + two stable argsort merges
           (optionally routing distances through the Pallas distance kernel
           via cfg.use_pallas — the pre-refactor behavior).
  pallas   fused hot path: one Pallas kernel computes distances on the MXU,
           applies the filter/visited mask, and merges queue + result buffers
           with bitonic top-M/top-K networks — no argsort, one VMEM pass
           (see repro.kernels.fused_step).
  pallas_persistent
           same per-step hot path, but `persistent = True` makes the search
           layer amortize dispatch across up to cfg.steps_per_launch steps:
           on TPU (post mode) via the persistent multi-step kernel
           (repro.kernels.persistent_step) whose state stays VMEM-resident,
           elsewhere via launch-grouped stepping with eager active-lane
           compaction between launches (core/search.py). Per-step results
           are bit-identical to "pallas" at every step boundary.

Both backends evaluate compressed-domain distances when the step hands them
a `QuantGather` (cfg.precision "int8" | "pq", see repro.quant): dense and
the pallas host path share `quant.codecs.quant_dist`, and the TPU kernel
runs the matching in-kernel ADC variant.

New backends register with `@register_backend("name")` and become selectable
via `SearchConfig(backend="name")` / `SearchEngine.build(..., backend="name")`.
"""
from __future__ import annotations

from typing import Protocol

import jax.numpy as jnp

from repro.core.state import INF, SearchConfig
from repro.filters.compile import clause_counts, eval_program_gathered


class TraversalBackend(Protocol):
    """Per-step hot path: filter program + distances + queue/result merges."""

    name: str

    def merge_step(self, cfg: SearchConfig, queries, xv, nb, is_new, prog,
                   labels_g, values_g,
                   cand_dist, cand_idx, cand_exp, cand_valid, res_dist,
                   res_idx, quant=None):
        """Evaluate the predicate program and neighbor distances, then merge
        into the sorted buffers.

        queries   [B, d]    query vectors
        xv        [B, R', d] gathered neighbor vectors (None in compressed
                            mode — distances come from `quant` instead)
        quant     QuantGather | None — prepared per-query ADC state plus the
                            step's gathered codes/norms (repro.quant); set
                            iff cfg.precision is "int8" or "pq"
        nb        [B, R']   neighbor ids (-1 padded)
        is_new    [B, R']   first-visit mask (visited-bitset test upstream)
        prog      FilterProgram — compiled predicate clauses ([B, S, ...])
        labels_g  [B, R', W] u32 gathered label masks
        values_g  [B, R', V] f32 gathered numeric attributes
        cand_*    [B, M]    sorted candidate queue buffers
        res_*     [B, K]    sorted result buffers

        The distance mask follows cfg.mode: "post" scores every new node,
        "pre" scores only the predicate-valid ones (ACORN accounting).

        Returns (cand_dist, cand_idx, cand_exp, cand_valid, res_dist,
        res_idx, valid, clause_add): the merged sorted buffers, the
        per-candidate validity `valid = program(attrs) & is_new` [B, R'],
        and per-clause hit counters `clause_add` [B, CLAUSE_FEATURE_SLOTS]
        over the newly inspected candidates.
        """
        ...


_BACKENDS: dict[str, TraversalBackend] = {}


def register_backend(name: str):
    """Class decorator: instantiate and register a backend under `name`."""

    def deco(cls):
        inst = cls()
        inst.name = name
        _BACKENDS[name] = inst
        return cls

    return deco


def get_backend(name: str) -> TraversalBackend:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown traversal backend {name!r}; "
            f"registered: {sorted(_BACKENDS)}"
        ) from None


def available_backends() -> list[str]:
    return sorted(_BACKENDS)


# --------------------------------------------------------------------------
# dense reference backend
# --------------------------------------------------------------------------
def _sqdist(q, x, use_pallas: bool):
    """q[B,d], x[B,R,d] -> [B,R] squared L2."""
    if use_pallas:
        from repro.kernels import ops as kops

        return kops.batched_sqdist(q, x)
    from repro.kernels.distance import sqdist_bdrd

    return sqdist_bdrd(q, x)


def _merge_queue(dist, idx, exp, valid, new_dist, new_idx, new_valid, m):
    """Merge sorted [B,M] buffers with new [B,R] entries; keep best M."""
    d = jnp.concatenate([dist, new_dist], axis=1)
    i = jnp.concatenate([idx, new_idx], axis=1)
    e = jnp.concatenate([exp, jnp.zeros_like(new_idx, dtype=bool)], axis=1)
    v = jnp.concatenate([valid, new_valid], axis=1)
    order = jnp.argsort(d, axis=1, stable=True)[:, :m]
    return (
        jnp.take_along_axis(d, order, axis=1),
        jnp.take_along_axis(i, order, axis=1),
        jnp.take_along_axis(e, order, axis=1),
        jnp.take_along_axis(v, order, axis=1),
    )


def _merge_results(res_dist, res_idx, new_dist, new_idx, k):
    d = jnp.concatenate([res_dist, new_dist], axis=1)
    i = jnp.concatenate([res_idx, new_idx], axis=1)
    order = jnp.argsort(d, axis=1, stable=True)[:, :k]
    return jnp.take_along_axis(d, order, axis=1), jnp.take_along_axis(i, order, axis=1)


@register_backend("dense")
class DenseBackend:
    """Pure-jnp reference: shared program eval + einsum distances + stable
    argsort merges."""

    def merge_step(self, cfg, queries, xv, nb, is_new, prog, labels_g,
                   values_g, cand_dist, cand_idx, cand_exp, cand_valid,
                   res_dist, res_idx, quant=None):
        m, k = cfg.queue_size, cfg.k
        pvalid, clause_sat = eval_program_gathered(prog, labels_g, values_g)
        valid = pvalid & is_new
        clause_add = clause_counts(clause_sat, is_new)
        dist_mask = valid if cfg.mode == "pre" else is_new

        if quant is None:
            dd = _sqdist(queries, xv, cfg.use_pallas)
        else:
            from repro.quant.codecs import quant_dist

            dd = quant_dist(cfg.precision, quant)
        dd = jnp.where(dist_mask, dd, INF)

        cand_dist, cand_idx, cand_exp, cand_valid = _merge_queue(
            cand_dist, cand_idx, cand_exp, cand_valid,
            dd, jnp.where(jnp.isfinite(dd), nb, -1), valid, m,
        )

        res_in_d = jnp.where(valid & jnp.isfinite(dd), dd, INF)
        res_dist, res_idx = _merge_results(
            res_dist, res_idx, res_in_d,
            jnp.where(jnp.isfinite(res_in_d), nb, -1), k,
        )
        return (cand_dist, cand_idx, cand_exp, cand_valid, res_dist, res_idx,
                valid, clause_add)


# --------------------------------------------------------------------------
# fused Pallas backend
# --------------------------------------------------------------------------
@register_backend("pallas")
class PallasBackend:
    """Fused kernel: predicate program + distances + bitonic merges, one pass.

    The kernel evaluates the compiled clause program on the gathered
    attribute words in VMEM (bitwise ops + range compares, kinds selected
    per slot), computes distances on the MXU, and merges both sorted
    buffers — the validity mask never round-trips through HBM. The
    candidate queue rides as (dist, packed payload): node id +
    expanded/valid flags packed into one int32 so the bitonic network
    permutes a single value lane (see kernels.topk.pack_payload).
    """

    def merge_step(self, cfg, queries, xv, nb, is_new, prog, labels_g,
                   values_g, cand_dist, cand_idx, cand_exp, cand_valid,
                   res_dist, res_idx, quant=None):
        from repro.kernels import ops as kops

        cand_pay = kops.pack_payload(cand_idx, cand_exp, cand_valid)
        (cand_dist, cand_pay, res_dist, res_idx, valid,
         clause_add) = kops.fused_traversal_step(
            queries, xv, nb, is_new, prog, labels_g, values_g,
            cand_dist, cand_pay, res_dist, res_idx, pre=cfg.mode == "pre",
            quant=quant, precision=cfg.precision or "float32",
        )
        cand_idx, cand_exp, cand_valid = kops.unpack_payload(cand_pay)
        return (cand_dist, cand_idx, cand_exp, cand_valid, res_dist, res_idx,
                valid, clause_add)


@register_backend("pallas_persistent")
class PallasPersistentBackend(PallasBackend):
    """Multi-step launch amortization over the fused pallas hot path.

    The per-step arithmetic is inherited unchanged from `PallasBackend` —
    that is what keeps every step boundary bit-identical to the single-step
    path. The `persistent` flag is what the search layer keys on to group
    up to `cfg.steps_per_launch` steps per dispatch: the VMEM-resident
    multi-step kernel on TPU (kernels/persistent_step.py), launch-grouped
    stepping with eager active-lane compaction on other platforms
    (`run_search_persistent` in core/search.py).
    """

    persistent = True
