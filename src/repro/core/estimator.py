"""Cost estimator M: features z_q -> predicted search budget Ŵ_q.

Implements the paper's §4.3 training strategy: regress log(W_q) with MSE
(= MSLE in raw space, penalizing *relative* error across the heavy-tailed
cost distribution), then at query time Ŵ_q = α · exp(M(z_q)). α ≥ 1 is the
recall knob that sweeps the recall-vs-cost tradeoff (Figs. 5/6).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gbdt import GBDTModel, predict_jax, train_gbdt


@dataclasses.dataclass
class CostEstimator:
    model: GBDTModel
    log_target: bool = True

    @classmethod
    def fit(
        cls,
        features: np.ndarray,  # [n, F]
        w_q: np.ndarray,       # [n] ground-truth NDC at full recall
        log_target: bool = True,
        **gbdt_kwargs,
    ) -> "CostEstimator":
        y = np.log(np.maximum(w_q, 1.0)) if log_target else np.asarray(w_q, np.float64)
        model = train_gbdt(features, y, **gbdt_kwargs)
        return cls(model=model, log_target=log_target)

    # ---- host-side ----
    def predict_cost(self, features: np.ndarray) -> np.ndarray:
        p = self.model.predict(np.asarray(features, np.float32))
        return np.exp(p) if self.log_target else p

    # ---- device-side (jit-compatible; used inside the serving pipeline) ----
    def packed(self):
        return self.model.pack_jax()

    def predict_budget_jax(
        self,
        packed,
        features: jax.Array,
        alpha: float,
        min_budget: int,
        max_budget: int,
    ) -> jax.Array:
        p = predict_jax(packed, features, self.model.depth)
        w = jnp.exp(p) if self.log_target else p
        w = jnp.clip(alpha * w, float(min_budget), float(max_budget))
        return w.astype(jnp.int32)

    def eval_metrics(self, features: np.ndarray, w_q: np.ndarray) -> dict:
        """Table-3 metrics: Log-RMSE, R² (log space), Spearman ρ."""
        y = np.log(np.maximum(w_q, 1.0))
        p = self.model.predict(np.asarray(features, np.float32))
        if not self.log_target:
            p = np.log(np.maximum(p, 1.0))
        err = p - y
        log_rmse = float(np.sqrt(np.mean(err**2)))
        ss_res = float(np.sum(err**2))
        ss_tot = float(np.sum((y - y.mean()) ** 2)) + 1e-12
        r2 = 1.0 - ss_res / ss_tot
        rho = spearman(p, y)
        return dict(log_rmse=log_rmse, r2=r2, spearman=rho)


def spearman(a: np.ndarray, b: np.ndarray) -> float:
    """Spearman rank correlation (average ranks for ties)."""

    def ranks(v):
        order = np.argsort(v, kind="stable")
        r = np.empty_like(order, dtype=np.float64)
        r[order] = np.arange(len(v))
        # average ties
        sv = v[order]
        i = 0
        while i < len(sv):
            j = i
            while j + 1 < len(sv) and sv[j + 1] == sv[i]:
                j += 1
            if j > i:
                r[order[i : j + 1]] = (i + j) / 2.0
            i = j + 1
        return r

    ra, rb = ranks(np.asarray(a)), ranks(np.asarray(b))
    ra -= ra.mean()
    rb -= rb.mean()
    denom = np.sqrt((ra**2).sum() * (rb**2).sum()) + 1e-12
    return float((ra * rb).sum() / denom)
