"""Index-axis sharding: per-shard traversal + log-depth global top-k merge.

`SearchEngine` (core.engine) scales over the *batch* axis — every device
holds the whole index. This module scales over the *index* axis: the corpus
is cut into S contiguous equal slices, each with its own independent graph
(shard-local node ids, own entry point), quant codes and attribute bundle.
A query traverses every shard with budget ⌈W/S⌉ and per-shard state
(candidate queue, result set, visited bitset over the shard's N/S nodes —
which is what keeps the PR-6 bitset bound of N ≤ 4M *per shard*), and the S
sorted pools are combined by the log-depth cross-shard merge
(distributed.merge) into the global result set.

Two execution paths:

  loop   (mesh=None, the default) — a host loop over shards, each through
         the plain per-shard `SearchEngine.search` (persistent driver,
         compaction and tracing included), then `merge_shard_states` on
         the stacked states.
  mesh   a 2-D ("data" × "index") `shard_map`: each device runs its local
         shards' traversals, merges them locally, and joins the XOR
         butterfly (`distributed.merge.butterfly_merge`) over the index
         axis — ⌈log2 S⌉ pairwise merge rounds instead of gathering S
         pools anywhere.

Bit-parity argument: per-shard traversals are the same traced computation
in both paths; pool entries carry unique (dist, pos) keys (pos = global
shard · width + slot), a total order under which top-m is associative and
commutative — so the host merge tree and the device butterfly produce THE
unique sorted top-m of the pool union. Counters are merged outside the
mesh in both paths, by the same jitted reduction over the same stacked
values.

The loop path is bit-identical to the single-device engine at every
precision; the mesh path is bit-identical at float32. Quantized (int8/pq)
distances under the mesh path can differ from the loop path by 1 ulp:
XLA's SPMD pipeline fuses the ADC float tail (qn + xn − 2·s·dot)
differently inside `shard_map` than under plain `jit`, contracting the
mul/subtract into an FMA in one context but not the other. This is a
compiler codegen property, not a reduction-order issue — it reproduces on
a 1-device mesh with fully replicated operands, and survives
`optimization_barrier` pinning and --xla_cpu_enable_fast_math=false — so
the quantized mesh-path contract is "allclose within 1 ulp" (candidate
*sets* still match; only distance bits wobble).

Accounting contract (what keeps the estimator, planner, probe→resume and
EXPLAIN working unchanged):

  exact      cnt (NDC), n_inspected, n_valid_visited, n_clause_valid,
             n_pop_valid, hops — integer sums over shards; q_err_sum —
             float sum in a fixed shard order (same order both paths).
  semantics  active = any(shard active); d_start = min over shards (the
             best entry distance a query saw); visited = concatenation of
             the word-padded per-shard bitsets [B, S·ceil(Ns/32)].
  approx     conv_cnt / res_full_cnt: summed when every shard reached the
             milestone, else -1 ("not yet"). A single shard usually cannot
             reach global full-recall on its own, so these fire later than
             on an unsharded engine — the feature extractor already treats
             -1 as "not converged" and substitutes its sentinel, so
             features stay well-defined (they are *trained* per deployment
             anyway; an estimator is fitted on the engine shape it serves).

Memory tiering composes here exactly as on the plain engine: compressed
engines keep per-shard [Ns, 0] float32 placeholders and route the exact
rerank through one global `quant.tiering` store (device- or host-resident)
gathering only the ≤ (M+K) merged-pool rows per query.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import ClassVar, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.search import SearchConfig, SearchState, run_search_impl
from repro.core.state import pad_lanes, stack_shards, take_shard
from repro.data.synthetic import AttributedDataset
from repro.distributed.merge import butterfly_merge, merge_plan, merge_stacked
from repro.obs.trace import as_tracer
from repro.distributed.sharding import INDEX_AXIS, search_mesh_2d
from repro.filters.compile import FilterProgram, as_program
from repro.index.graph import ShardedGraphIndex
from repro.kernels.topk import pack_payload, unpack_payload

BATCH_AXIS = "data"


class ShardedSearchState(NamedTuple):
    """Full state of a sharded search: per-shard carries + the merged view.

    `shard` is a SearchState whose leaves carry the shard axis SECOND
    ([B, S, ...]), so the serving layer's lane surgery (take/put/concat/pad
    on axis 0) keeps working on sharded states untouched. `merged` is a
    plain [B, ...] SearchState — the global view every consumer (features,
    planner, EXPLAIN, rerank, serving) reads; all 17 SearchState field
    names delegate to it, so a ShardedSearchState quacks like the state
    those consumers were written against. Resume reads `shard` (per-shard
    queues and bitsets are the resumable truth); results read `merged`.
    """

    shard: SearchState    # [B, S, ...] leaves
    merged: SearchState   # [B, ...] leaves — global pools + summed counters

    # -- delegation: every SearchState field name reads the merged view ----
    @property
    def cand_dist(self): return self.merged.cand_dist

    @property
    def cand_idx(self): return self.merged.cand_idx

    @property
    def cand_exp(self): return self.merged.cand_exp

    @property
    def cand_valid(self): return self.merged.cand_valid

    @property
    def res_dist(self): return self.merged.res_dist

    @property
    def res_idx(self): return self.merged.res_idx

    @property
    def visited(self): return self.merged.visited

    @property
    def cnt(self): return self.merged.cnt

    @property
    def n_inspected(self): return self.merged.n_inspected

    @property
    def n_valid_visited(self): return self.merged.n_valid_visited

    @property
    def n_clause_valid(self): return self.merged.n_clause_valid

    @property
    def n_pop_valid(self): return self.merged.n_pop_valid

    @property
    def q_err_sum(self): return self.merged.q_err_sum

    @property
    def hops(self): return self.merged.hops

    @property
    def active(self): return self.merged.active

    @property
    def d_start(self): return self.merged.d_start

    @property
    def conv_cnt(self): return self.merged.conv_cnt

    @property
    def res_full_cnt(self): return self.merged.res_full_cnt


def _merged_from(stacked: SearchState, rd, rp, cd, cp) -> SearchState:
    """Assemble the merged view from stacked states + already-merged pools."""
    b = stacked.res_dist.shape[0]
    ci, ce, cv = unpack_payload(cp)
    isum = lambda x: jnp.sum(x, axis=1)                          # noqa: E731
    # "reached on every shard" counters: sum when all shards report ≥ 0,
    # else the -1 "not yet" sentinel the feature extractor substitutes for
    opt = lambda x: jnp.where(jnp.all(x >= 0, axis=1),           # noqa: E731
                              jnp.sum(x, axis=1), -1).astype(jnp.int32)
    return SearchState(
        cand_dist=cd, cand_idx=ci, cand_exp=ce, cand_valid=cv,
        res_dist=rd, res_idx=rp,
        visited=stacked.visited.reshape(b, -1),
        cnt=isum(stacked.cnt),
        n_inspected=isum(stacked.n_inspected),
        n_valid_visited=isum(stacked.n_valid_visited),
        n_clause_valid=isum(stacked.n_clause_valid),
        n_pop_valid=isum(stacked.n_pop_valid),
        q_err_sum=isum(stacked.q_err_sum),
        hops=isum(stacked.hops),
        active=jnp.any(stacked.active, axis=1),
        d_start=jnp.min(stacked.d_start, axis=1),
        conv_cnt=opt(stacked.conv_cnt),
        res_full_cnt=opt(stacked.res_full_cnt),
    )


def _merge_pools(stacked: SearchState, offsets):
    """Host merge tree over the stacked per-shard pools → global pools.

    Result pools merge on bare global ids; candidate pools pack
    (global id, expanded, valid) into one int32 payload (kernels.topk)
    so the queue flags ride the merge with their entry.
    """
    k = stacked.res_dist.shape[2]
    m = stacked.cand_dist.shape[2]
    off = jnp.asarray(offsets, jnp.int32)[None, :, None]
    res_g = jnp.where(stacked.res_idx >= 0, stacked.res_idx + off, -1)
    rd, rp, _ = merge_stacked(stacked.res_dist, res_g, k)
    cand_g = jnp.where(stacked.cand_idx >= 0, stacked.cand_idx + off, -1)
    cpay = pack_payload(cand_g, stacked.cand_exp, stacked.cand_valid)
    cd, cp, _ = merge_stacked(stacked.cand_dist, cpay, m)
    return rd, rp, cd, cp


@jax.jit
def merge_shard_states(stacked: SearchState, offsets) -> SearchState:
    """Merged global view of stacked per-shard states ([B, S, ...] leaves).

    `offsets` [S] — each shard's first global row (shard-local id s,i ↦
    global id offsets[s] + i). The loop execution path's merge; the mesh
    path substitutes its butterfly-merged pools via `merge_with_pools` and
    shares everything else.
    """
    rd, rp, cd, cp = _merge_pools(stacked, offsets)
    return _merged_from(stacked, rd, rp, cd, cp)


@jax.jit
def merge_with_pools(stacked: SearchState, rd, rp, cd, cp) -> SearchState:
    """`merge_shard_states` with externally merged (butterfly) pools."""
    return _merged_from(stacked, rd, rp, cd, cp)


@dataclasses.dataclass
class ShardedSearchEngine:
    """S per-shard `SearchEngine`s + the cross-shard merge, one facade.

    Duck-type compatible with `SearchEngine` everywhere the stack consumes
    an engine (`search`/`rerank`/`compile`/`codec_key`/`n_words`/...), and
    its states are `ShardedSearchState` — consumers reading state fields
    get the merged global view transparently.
    """

    shards: list                       # [S] SearchEngine (mesh=None each)
    offsets: np.ndarray                # [S] first global row per shard
    entry_points: np.ndarray           # [S] shard-local entry node ids
    backend: str | None = None
    mesh: Mesh | None = None           # 2-D ("data", "index") | None → loop
    precision: str = "float32"
    vector_store: object | None = None  # global rerank tier (compressed mode)
    tier: str = "device"
    _stacked: dict | None = dataclasses.field(
        default=None, repr=False, compare=False)

    #: duck-typing marker — plans/planner route on this, never on isinstance
    is_sharded: ClassVar[bool] = True

    # ------------------------------------------------------------ build ----
    @classmethod
    def build(cls, ds: AttributedDataset, graph: ShardedGraphIndex | int,
              backend: str | None = None, mesh: Mesh | str | None = "auto",
              precision: str = "float32", quant_cfg: dict | None = None,
              tier: str = "device") -> "ShardedSearchEngine":
        """Construct an index-axis-sharded engine over `ds`.

        graph   a ShardedGraphIndex (index.build_sharded_graph_index), or an
                int shard count to build one here with default knobs.
        mesh    "auto" → 2-D (data × index) mesh when >1 device is visible
                (distributed.search_mesh_2d); an explicit Mesh must carry a
                "data" axis and an "index" axis whose size divides S; None
                forces the single-device shard loop.
        tier    "device" | "host" — where the float32 rerank tier lives in
                compressed mode (quant.tiering). Compressed shard engines
                always hold [Ns, 0] vector placeholders: exactly one global
                float32 copy exists, in the chosen tier.

        Quantized builds train every shard's codec on the SAME global
        sample (ds.sample_vectors), so codec parameters — and therefore the
        compressed metric and the per-query ADC prep — are identical across
        shards: per-shard distances are mutually comparable and the merged
        pool lives in one metric.
        """
        if isinstance(graph, (int, np.integer)):
            from repro.index.builder import build_sharded_graph_index

            graph = build_sharded_graph_index(np.asarray(ds.vectors),
                                              int(graph))
        graph.validate()
        n, s = graph.n, graph.n_shards
        if len(ds.vectors) != n:
            raise ValueError(
                f"dataset has {len(ds.vectors)} rows but the sharded graph "
                f"covers {n}")
        if tier != "device" and precision == "float32":
            raise ValueError(
                "tier='host' requires a compressed traversal precision "
                "('int8' or 'pq') — a float32 traversal reads the full "
                "vector store every step, which defeats the tier")
        ns = graph.shard_size
        offsets = np.asarray(graph.offsets)

        quants = [None] * s
        store = None
        if precision != "float32":
            from repro.quant import build_quant_index
            from repro.quant.tiering import as_vector_store

            qcfg = dict(quant_cfg or {})
            sample_n = qcfg.pop("train_sample_size", 16384)
            sample = ds.sample_vectors(sample_n, seed=qcfg.get("seed", 0))
            quants = [
                build_quant_index(precision, ds.vectors[offsets[i]:
                                                        offsets[i] + ns],
                                  train_sample=sample, **qcfg)
                for i in range(s)
            ]
            store = as_vector_store(ds.vectors, tier)

        from repro.core.engine import SearchEngine

        vals = np.asarray(ds.value_matrix)
        shards = []
        for i in range(s):
            lo, hi = int(offsets[i]), int(offsets[i]) + ns
            if precision != "float32":
                vec = jnp.zeros((ns, 0), jnp.float32)  # placeholder: only
                # the row count is read in compressed mode
            else:
                vec = jnp.asarray(ds.vectors[lo:hi], jnp.float32)
            shards.append(SearchEngine(
                base_vectors=vec,
                label_attrs=jnp.asarray(ds.labels_packed[lo:hi]),
                value_attrs=jnp.asarray(vals[lo:hi]),
                neighbors=jnp.asarray(graph.shards[i].neighbors),
                entry_point=int(graph.shards[i].entry_point),
                backend=backend,
                mesh=None,              # batch sharding happens above, once
                precision=precision,
                quant=quants[i],
            ))
        if mesh == "auto":
            mesh = search_mesh_2d(s)
        if mesh is not None:
            if BATCH_AXIS not in mesh.shape or INDEX_AXIS not in mesh.shape:
                raise ValueError(
                    f"sharded engine mesh needs axes ({BATCH_AXIS!r}, "
                    f"{INDEX_AXIS!r}); got {mesh.axis_names}")
            if s % mesh.shape[INDEX_AXIS]:
                raise ValueError(
                    f"index axis of size {mesh.shape[INDEX_AXIS]} does not "
                    f"divide {s} shards")
        return cls(shards=shards, offsets=offsets,
                   entry_points=np.asarray(graph.entry_points),
                   backend=backend, mesh=mesh, precision=precision,
                   vector_store=store, tier=tier)

    # ------------------------------------------------------- properties ----
    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def shard_size(self) -> int:
        return int(self.shards[0].neighbors.shape[0])

    @property
    def n(self) -> int:
        return self.n_shards * self.shard_size

    @property
    def n_words(self) -> int:
        return self.shards[0].n_words

    @property
    def n_values(self) -> int:
        return self.shards[0].n_values

    @property
    def quant(self):
        """Shard 0's quant index — codec parameters are shared by training
        contract, so this is *the* codec for identity purposes."""
        return self.shards[0].quant

    @property
    def quant_concat(self):
        """Global-view quant index: per-shard codes/norms/err concatenated
        in shard order (= global row order), codec parameters from shard 0
        (identical across shards by the shared-sample training contract).
        This is what corpus-wide consumers (compressed ground truth in
        core.training / core.planner) read; it is NOT cached — they call
        it once per training run and the concat would double code memory.
        """
        q0 = self.shards[0].quant
        if q0 is None:
            return None
        from repro.quant.codecs import Int8Index, PQIndex

        def cat(name):
            return jnp.concatenate(
                [getattr(e.quant, name) for e in self.shards], axis=0)

        if isinstance(q0, Int8Index):
            return Int8Index(codes=cat("codes"), scale=q0.scale,
                             zero=q0.zero, norms=cat("norms"),
                             err=cat("err"))
        if isinstance(q0, PQIndex):
            return PQIndex(codes=cat("codes"), codebooks=q0.codebooks,
                           norms=cat("norms"), err=cat("err"))
        raise TypeError(f"unknown quant index {type(q0).__name__}")

    @property
    def label_attrs(self):
        """Concatenated [N, W] label words (global row order) — for host
        consumers like the bruteforce validity oracle; traversals read the
        per-shard bundles, never this."""
        return jnp.concatenate([e.label_attrs for e in self.shards], axis=0)

    @property
    def value_attrs(self):
        return jnp.concatenate([e._attrs()[1] for e in self.shards], axis=0)

    def compile(self, filt) -> FilterProgram:
        prog = as_program(filt, self.n_words, self.n_values)
        return FilterProgram(*(jnp.asarray(a) for a in prog))

    def effective_precision(self, cfg: SearchConfig) -> str:
        return cfg.precision or self.precision

    def codec_key(self, cfg: SearchConfig | None = None) -> str:
        return self.shards[0].codec_key(cfg)

    # ----------------------------------------------------------- search ----
    def _resolve(self, cfg: SearchConfig) -> SearchConfig:
        cfg = dataclasses.replace(
            cfg, degree=int(self.shards[0].neighbors.shape[1]))
        if cfg.backend is None:
            cfg = dataclasses.replace(cfg, backend=self.backend or "dense")
        cfg = dataclasses.replace(cfg,
                                  precision=self.effective_precision(cfg))
        if cfg.precision != "float32" and self.quant is None:
            raise ValueError(
                f"SearchConfig(precision={cfg.precision!r}) on a sharded "
                "engine without a quant index — build with precision=...")
        if (cfg.precision == "float32"
                and self.shards[0].base_vectors.shape[1] == 0):
            raise ValueError(
                "float32 traversal on a compressed sharded engine: shards "
                "hold only vector placeholders (the float32 copy lives in "
                "the rerank tier) — search at the engine's compressed "
                "precision, the terminal rerank stays exact")
        return cfg

    def search(self, cfg: SearchConfig, queries, filt, budgets,
               state: ShardedSearchState | None = None,
               gt_dist=None, tracer=None, trace_id: str = "",
               ) -> ShardedSearchState:
        """Sharded search/probe/resume. Same contract as SearchEngine.search
        except states are ShardedSearchState and `budgets` is the *global*
        NDC budget: each shard runs under ⌈W/S⌉, and the merged `cnt` is
        the exact total the query actually spent (Σ per-shard NDC), which
        is what the estimator's features and EXPLAIN read."""
        cfg = self._resolve(cfg)
        prog = self.compile(filt)
        q = jnp.asarray(queries, jnp.float32)
        b = q.shape[0]
        s = self.n_shards
        budgets = jnp.broadcast_to(jnp.asarray(budgets, jnp.int32), (b,))
        # per-shard slice of the global budget; ⌈W/S⌉ so S·shard ≥ W and a
        # budget-terminated query is still visible as cnt ≥ W to EXPLAIN
        sbud = (budgets + jnp.int32(s - 1)) // jnp.int32(s)
        gt = None if gt_dist is None else jnp.asarray(gt_dist, jnp.float32)
        tr = as_tracer(tracer)
        if self.mesh is None:
            # spans wrap host dispatches that exist regardless of tracing
            # (per-shard engine.search calls, the one merge jit call) with
            # static int attrs — no device reads, so the PR-7 zero-added-
            # dispatch / bit-identity contract holds on sharded engines too
            outs = []
            for i, eng in enumerate(self.shards):
                st = None if state is None else take_shard(state.shard, i)
                with tr.span("shard-search", trace_id, shard=i, n_shards=s):
                    outs.append(eng.search(
                        cfg, q, prog, sbud, state=st, gt_dist=gt,
                        tracer=tracer,
                        trace_id=f"{trace_id}/s{i}" if trace_id else ""))
            pairwise, depth = merge_plan(s)
            with tr.span("shard-merge", trace_id, n_shards=s,
                         pairwise=pairwise, depth=depth, path="loop"):
                stacked = stack_shards(outs)
                merged = merge_shard_states(stacked, self.offsets)
            return ShardedSearchState(shard=stacked, merged=merged)
        pairwise, depth = merge_plan(s)
        with tr.span("shard-search", trace_id, shard=-1, n_shards=s,
                     pairwise=pairwise, depth=depth, path="mesh"):
            return self._search_mesh(cfg, q, prog, sbud, state, gt)

    # ------------------------------------------------------ mesh path ------
    def _stacked_arrays(self) -> dict:
        """Index-side arrays stacked [S, ...] and placed P(index) once."""
        if self._stacked is None:
            stx = {
                "neighbors": jnp.stack([e.neighbors for e in self.shards]),
                "labels": jnp.stack([e.label_attrs for e in self.shards]),
                "values": jnp.stack([e._attrs()[1] for e in self.shards]),
                "base": jnp.stack([e.base_vectors for e in self.shards]),
                "entries": jnp.asarray(self.entry_points, jnp.int32),
                "offsets": jnp.asarray(self.offsets, jnp.int32),
            }
            if self.quant is not None:
                stx["quant"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *[e.quant for e in self.shards])
            if self.mesh is not None:
                sh = NamedSharding(self.mesh, P(INDEX_AXIS))
                stx = {k: jax.device_put(v, sh) for k, v in stx.items()}
            self._stacked = stx
        return self._stacked

    def _search_mesh(self, cfg, q, prog, sbud, state, gt):
        from jax.experimental.shard_map import shard_map

        mesh = self.mesh
        ddata = int(mesh.shape[BATCH_AXIS])
        dindex = int(mesh.shape[INDEX_AXIS])
        s = self.n_shards
        nloc = s // dindex                    # shards per index device
        k, m = cfg.k, cfg.queue_size
        stx = self._stacked_arrays()

        b = q.shape[0]
        pad = (-b) % ddata
        q = pad_lanes(q, pad)
        prog = pad_lanes(prog, pad)
        sbud = pad_lanes(sbud, pad)           # 0-budget pad lanes are inert
        st_in = None if state is None else pad_lanes(state.shard, pad)
        gt = None if gt is None else pad_lanes(gt, pad)

        bspec = P(BATCH_AXIS)
        ispec = P(INDEX_AXIS)
        bsspec = P(BATCH_AXIS, INDEX_AXIS)
        has_state, has_gt = st_in is not None, gt is not None
        has_quant = cfg.precision != "float32"

        args = [q, prog, sbud, stx["base"], stx["labels"], stx["values"],
                stx["neighbors"], stx["entries"], stx["offsets"]]
        specs = [bspec, bspec, bspec, ispec, ispec, ispec, ispec, ispec,
                 ispec]
        if has_state:
            args.append(st_in)
            specs.append(bsspec)
        if has_gt:
            args.append(gt)
            specs.append(bspec)
        if has_quant:
            args.append(stx["quant"])
            specs.append(ispec)

        def fn(qq, qa, bud, base, labels, values, nb, entries, offs, *rest):
            j = 0
            st = rest[j] if has_state else None
            j += has_state
            g = rest[j] if has_gt else None
            j += has_gt
            qt = rest[j] if has_quant else None
            outs = []
            for jj in range(nloc):            # static unroll: local shards
                stj = (None if st is None
                       else jax.tree.map(lambda a: a[:, jj], st))
                qtj = (None if qt is None
                       else jax.tree.map(lambda a: a[jj], qt))
                outs.append(run_search_impl(
                    cfg, qq, qa, base[jj], (labels[jj], values[jj]), nb[jj],
                    bud, entries[jj], state=stj, gt_dist=g, quant=qtj))
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=1), *outs)
            # local merge tree on the global position space (shard0 keys
            # this device's pools into the virtual concatenation of all S)
            shard0 = jax.lax.axis_index(INDEX_AXIS) * nloc
            off = offs[None, :, None]
            res_g = jnp.where(stacked.res_idx >= 0,
                              stacked.res_idx + off, -1)
            rd, rp, ro = merge_stacked(stacked.res_dist, res_g, k,
                                       shard0=shard0)
            cpay = pack_payload(
                jnp.where(stacked.cand_idx >= 0, stacked.cand_idx + off, -1),
                stacked.cand_exp, stacked.cand_valid)
            cd, cp, co = merge_stacked(stacked.cand_dist, cpay, m,
                                       shard0=shard0)
            # cross-device butterfly: after log2(dindex) rounds every index
            # device holds the identical global pools
            rd, rp, ro = butterfly_merge(rd, rp, ro, k, INDEX_AXIS, dindex)
            cd, cp, co = butterfly_merge(cd, cp, co, m, INDEX_AXIS, dindex)
            return stacked, rd, rp, cd, cp

        stacked, rd, rp, cd, cp = shard_map(
            fn, mesh=mesh, in_specs=tuple(specs),
            out_specs=(bsspec, bspec, bspec, bspec, bspec), check_rep=False,
        )(*args)
        merged = merge_with_pools(stacked, rd, rp, cd, cp)
        out = ShardedSearchState(shard=stacked, merged=merged)
        if pad:
            out = jax.tree.map(lambda a: a[:b], out)
        return out

    # ------------------------------------------------------------- scan ----
    def scan_stats(self, prog: FilterProgram, chunk: int = 2048):
        """Global ScanStats assembled from per-shard bitmap passes.

        counts is exactly the sum of per-shard counts (each the popcount of
        its bitmap slice); clause_frac is the Ns-weighted mean of per-shard
        fractions, i.e. the global fraction.
        """
        from repro.core.plans import ScanStats, scan_stats

        per = [scan_stats(e, prog, chunk=chunk) for e in self.shards]
        valid = np.concatenate([p.valid for p in per], axis=1)
        frac = np.sum([p.clause_frac * p.n for p in per], axis=0)
        frac = (frac / max(self.n, 1)).astype(np.float32)
        return ScanStats(valid=valid,
                         counts=valid.sum(axis=1).astype(np.int64),
                         clause_frac=frac, n=self.n)

    def scan(self, cfg: SearchConfig, queries, filt, stats=None,
             base_state: ShardedSearchState | None = None,
             ) -> ShardedSearchState:
        """Pre-filter scan plan on a sharded engine: per-shard scans over
        the bitmap slices, merged like a traversal. Exactness carries over:
        merged cnt adds exactly σ_q·N (Σ of per-shard popcounts) and the
        result pool equals the unsharded scan's (same distances, same
        global-id tie order). Per-shard clause_add rounds rint(frac·Ns), so
        the merged n_clause_valid may differ from the unsharded engine's
        rint(frac·N) by ±S/2 — a feature input, not an accounting value.
        """
        from repro.core.plans import ScanStats, scan_search

        prog = self.compile(filt)
        if stats is None:
            stats = self.scan_stats(prog)
        ns = self.shard_size
        outs = []
        for i, eng in enumerate(self.shards):
            lo = int(self.offsets[i])
            sl = stats.valid[:, lo:lo + ns]
            sstats = ScanStats(valid=sl,
                               counts=sl.sum(axis=1).astype(np.int64),
                               clause_frac=stats.clause_frac, n=ns)
            bs = (None if base_state is None
                  else take_shard(base_state.shard, i))
            outs.append(scan_search(eng, cfg, queries, prog, stats=sstats,
                                    base_state=bs))
        stacked = stack_shards(outs)
        merged = merge_shard_states(stacked, self.offsets)
        return ShardedSearchState(shard=stacked, merged=merged)

    # ----------------------------------------------------------- rerank ----
    def rerank_arrays(self, queries, state):
        """Exact float32 re-scoring of the merged candidate pool via the
        global vector store — ≤ (M+K) streamed row gathers per query
        regardless of tier."""
        from repro.quant import exact_rerank_store

        st = state.merged if isinstance(state, ShardedSearchState) else state
        if self.vector_store is None:
            raise ValueError("rerank on a float32 sharded engine is a no-op "
                             "(results are already exact)")
        return exact_rerank_store(
            jnp.asarray(queries, jnp.float32), self.vector_store,
            st.cand_idx, st.cand_valid, st.res_idx,
            int(st.res_idx.shape[1]))

    def rerank(self, cfg: SearchConfig, queries,
               state: ShardedSearchState) -> ShardedSearchState:
        """Terminal exact rerank of the merged view (no-op at float32).
        Only `merged` is rewritten — per-shard carries keep compressed
        pools, and like the plain engine a reranked state must not be
        resumed."""
        if self.effective_precision(cfg) == "float32":
            return state
        rd, ri = self.rerank_arrays(queries, state)
        return ShardedSearchState(
            shard=state.shard,
            merged=state.merged._replace(res_dist=rd, res_idx=ri))
