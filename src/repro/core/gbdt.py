"""From-scratch histogram gradient-boosted decision trees (LightGBM stand-in).

The paper uses LightGBM for the cost estimator; that package is unavailable
offline, so the trainer below implements the same algorithm family:
  - global quantile binning (≤255 bins per feature)
  - depth-wise growth of complete binary trees
  - variance-gain splits from (count, gradient-sum) histograms
  - shrinkage (learning rate), L2 leaf regularization, min-child counts
  - per-feature *gain* importances (used for the Fig. 8 benchmark)

Trees are stored heap-packed in dense arrays so inference is D gathers +
selects per tree — vectorized over trees and batch in JAX (`predict_jax`)
and implemented as a Pallas kernel in `repro.kernels.gbdt`.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class GBDTModel:
    """Heap-packed complete-tree ensemble.

    feat[t, i]   feature index tested at internal node i of tree t
    thresh[t, i] go left iff x[feat] <= thresh (dead nodes: thresh=+inf)
    leaf[t, j]   leaf values (already scaled by learning rate)
    base         global prior (mean target)
    """

    feat: np.ndarray      # [T, 2^D - 1] int32
    thresh: np.ndarray    # [T, 2^D - 1] float32
    leaf: np.ndarray      # [T, 2^D] float32
    base: float
    depth: int
    importances: np.ndarray  # [F] gain-based

    @property
    def n_trees(self) -> int:
        return self.feat.shape[0]

    def predict(self, x: np.ndarray) -> np.ndarray:
        """Vectorized numpy inference (oracle for the JAX/Pallas paths)."""
        n = x.shape[0]
        out = np.full(n, self.base, dtype=np.float64)
        n_internal = self.feat.shape[1]
        for t in range(self.n_trees):
            idx = np.zeros(n, dtype=np.int64)
            for _ in range(self.depth):
                f = self.feat[t, idx]
                go_left = x[np.arange(n), f] <= self.thresh[t, idx]
                idx = 2 * idx + 1 + (~go_left)
            out += self.leaf[t, idx - n_internal]
        return out.astype(np.float32)

    def pack_jax(self):
        return (
            jnp.asarray(self.feat),
            jnp.asarray(self.thresh),
            jnp.asarray(self.leaf),
            jnp.float32(self.base),
        )

    def save(self, path: str) -> None:
        np.savez_compressed(
            path, feat=self.feat, thresh=self.thresh, leaf=self.leaf,
            base=self.base, depth=self.depth, importances=self.importances,
        )

    @staticmethod
    def load(path: str) -> "GBDTModel":
        z = np.load(path)
        return GBDTModel(
            feat=z["feat"], thresh=z["thresh"], leaf=z["leaf"],
            base=float(z["base"]), depth=int(z["depth"]),
            importances=z["importances"],
        )


@functools.partial(jax.jit, static_argnames=("depth",))
def predict_jax(packed, x: jax.Array, depth: int) -> jax.Array:
    """x[B, F] -> [B] predictions; `packed` from GBDTModel.pack_jax().

    Jitted: the unrolled depth-loop is ~4·depth tiny ops whose eager
    dispatch (~0.7 ms each on CPU) would otherwise dominate serving-path
    probe batches."""
    feat, thresh, leaf, base = packed
    t = feat.shape[0]
    n_internal = feat.shape[1]
    b = x.shape[0]
    t_ix = jnp.arange(t)[None, :]                       # [1, T]
    idx = jnp.zeros((b, t), dtype=jnp.int32)
    for _ in range(depth):
        f = feat[t_ix, idx]                             # [B, T]
        xv = jnp.take_along_axis(x, f, axis=1)          # [B, T]
        go_left = xv <= thresh[t_ix, idx]
        idx = 2 * idx + 1 + (1 - go_left.astype(jnp.int32))
    vals = leaf[t_ix, idx - n_internal]                 # [B, T]
    return base + vals.sum(axis=1)


def _quantile_bins(x: np.ndarray, n_bins: int) -> np.ndarray:
    """Per-feature bin edges [F, n_bins-1] from quantiles (deduplicated)."""
    qs = np.linspace(0, 1, n_bins + 1)[1:-1]
    edges = np.quantile(x, qs, axis=0).T.astype(np.float32)  # [F, n_bins-1]
    return edges


def train_gbdt(
    x: np.ndarray,
    y: np.ndarray,
    n_trees: int = 200,
    depth: int = 5,
    learning_rate: float = 0.1,
    n_bins: int = 64,
    min_child: int = 20,
    l2: float = 1.0,
    subsample: float = 1.0,
    seed: int = 0,
    early_stop_tol: float = 0.0,
    objective: str = "l2",   # "l2" | "quantile"
    tau: float = 0.5,        # pinball quantile (objective="quantile")
) -> GBDTModel:
    """GBDT on (x [n,F], y [n]).

    objective="l2": classic least-squares boosting (the paper's setup).
    objective="quantile": pinball-loss boosting — trees are grown on the
    pinball gradient and leaves are *renewed* to the τ-quantile of the
    in-leaf residuals (LightGBM's quantile trick). Used for the
    beyond-paper safety-margin budget estimator.
    """
    rng = np.random.default_rng(seed)
    x = np.asarray(x, np.float32)
    y = np.asarray(y, np.float64)
    n, f = x.shape
    edges = _quantile_bins(x, n_bins)
    # binned features: bin id in [0, n_bins-1]
    xb = np.empty((n, f), dtype=np.int32)
    for j in range(f):
        xb[:, j] = np.searchsorted(edges[j], x[:, j], side="right")

    n_internal = 2**depth - 1
    n_leaves = 2**depth
    feat = np.zeros((n_trees, n_internal), dtype=np.int32)
    thresh = np.full((n_trees, n_internal), np.inf, dtype=np.float32)
    leaf = np.zeros((n_trees, n_leaves), dtype=np.float32)
    importances = np.zeros(f, dtype=np.float64)

    if objective == "quantile":
        base = float(np.quantile(y, tau)) if n else 0.0
    else:
        base = float(y.mean()) if n else 0.0
    pred = np.full(n, base, dtype=np.float64)

    for t in range(n_trees):
        if objective == "quantile":
            # pinball gradient direction: τ where y>pred else τ-1
            resid = np.where(y > pred, tau, tau - 1.0)
        else:
            resid = y - pred
        if subsample < 1.0:
            use = rng.random(n) < subsample
        else:
            use = np.ones(n, dtype=bool)
        # node id per sample within the complete tree (heap index)
        node = np.zeros(n, dtype=np.int64)
        node[~use] = -1

        for level in range(depth):
            lvl_start = 2**level - 1
            lvl_nodes = 2**level
            # histograms per (node-at-level, feature, bin)
            act = node >= 0
            rel = node[act] - lvl_start  # 0..lvl_nodes-1
            rr = resid[act]
            best_gain = np.full(lvl_nodes, 0.0)
            best_feat = np.zeros(lvl_nodes, dtype=np.int32)
            best_bin = np.full(lvl_nodes, -1, dtype=np.int64)

            tot_cnt = np.bincount(rel, minlength=lvl_nodes).astype(np.float64)
            tot_sum = np.bincount(rel, weights=rr, minlength=lvl_nodes)
            parent_score = tot_sum**2 / (tot_cnt + l2)

            for j in range(f):
                key = rel * n_bins + xb[act, j]
                hc = np.bincount(key, minlength=lvl_nodes * n_bins).reshape(lvl_nodes, n_bins)
                hs = np.bincount(key, weights=rr, minlength=lvl_nodes * n_bins).reshape(
                    lvl_nodes, n_bins
                )
                cl = hc.cumsum(axis=1)[:, :-1]  # left counts per split bin
                sl = hs.cumsum(axis=1)[:, :-1]
                cr = tot_cnt[:, None] - cl
                sr = tot_sum[:, None] - sl
                ok = (cl >= min_child) & (cr >= min_child)
                gain = np.where(
                    ok,
                    sl**2 / (cl + l2) + sr**2 / (cr + l2) - parent_score[:, None],
                    -np.inf,
                )
                gb = gain.argmax(axis=1)
                gv = gain[np.arange(lvl_nodes), gb]
                better = gv > best_gain
                best_gain = np.where(better, gv, best_gain)
                best_feat = np.where(better, j, best_feat)
                best_bin = np.where(better, gb, best_bin)

            # record splits; dead nodes keep thresh=+inf (all go left)
            for ni in range(lvl_nodes):
                gi = lvl_start + ni
                if best_bin[ni] >= 0 and best_gain[ni] > early_stop_tol:
                    feat[t, gi] = best_feat[ni]
                    thresh[t, gi] = edges[best_feat[ni], best_bin[ni]]
                    importances[best_feat[ni]] += best_gain[ni]
                # else: feat 0 / thresh inf — passthrough left

            # descend
            cur = node >= 0
            fsel = feat[t, np.maximum(node, 0)]
            tsel = thresh[t, np.maximum(node, 0)]
            go_left = x[np.arange(n), fsel] <= tsel
            node = np.where(cur, 2 * node + 1 + (~go_left), node)

        # leaf values
        leaf_id = node - n_internal
        act = node >= 0
        if objective == "quantile":
            # renew leaves to the τ-quantile of raw residuals in-leaf
            raw = y - pred
            lv = np.zeros(n_leaves)
            for li in np.unique(leaf_id[act]):
                vals = raw[act & (leaf_id == li)]
                if vals.size:
                    lv[li] = np.quantile(vals, tau)
        else:
            lc = np.bincount(leaf_id[act], minlength=n_leaves).astype(np.float64)
            ls = np.bincount(leaf_id[act], weights=resid[act], minlength=n_leaves)
            lv = ls / (lc + l2)
        leaf[t] = (learning_rate * lv).astype(np.float32)

        # update predictions for ALL samples (not just subsampled)
        idx = np.zeros(n, dtype=np.int64)
        for _ in range(depth):
            ff = feat[t, idx]
            go_left = x[np.arange(n), ff] <= thresh[t, idx]
            idx = 2 * idx + 1 + (~go_left)
        pred += leaf[t, idx - n_internal]

    return GBDTModel(
        feat=feat, thresh=thresh, leaf=leaf, base=base, depth=depth,
        importances=importances,
    )
