"""Sequential numpy reference of the lockstep engine (oracle for tests).

Mirrors `core.search` post-mode semantics *exactly* (same stable-sort merge
order, same NDC accounting, same termination rules) but written as the
obvious per-query CPU loop — the shape of the paper's own Algorithm 1.
"""
from __future__ import annotations

import numpy as np

from repro.filters.predicates import PRED_CONTAIN, PRED_EQUAL, PRED_RANGE


def _pred_one(kind, attrs, q_attr, ids):
    ids = np.asarray(ids)
    if kind == PRED_RANGE:
        lo, hi = q_attr
        v = attrs[ids]
        return (v >= lo) & (v <= hi)
    masks = attrs[ids]
    if kind == PRED_CONTAIN:
        return ((masks & q_attr) == q_attr).all(axis=-1)
    if kind == PRED_EQUAL:
        return (masks == q_attr).all(axis=-1)
    raise ValueError(kind)


def ref_search_single(
    query: np.ndarray,
    q_attr,
    base: np.ndarray,
    attrs,
    neighbors: np.ndarray,
    entry: int,
    k: int,
    queue_size: int,
    budget: int,
    pred_kind: int,
    gt_dist: np.ndarray | None = None,
):
    """Returns dict with res_idx/res_dist/cnt/hops/counters/conv_cnt."""
    m = queue_size
    d0 = float(((query - base[entry]) ** 2).sum())
    v0 = bool(_pred_one(pred_kind, attrs, q_attr, np.array([entry]))[0])

    cand_d = np.full(m, np.inf, np.float32)
    cand_i = np.full(m, -1, np.int64)
    cand_e = np.zeros(m, bool)
    cand_v = np.zeros(m, bool)
    cand_d[0], cand_i[0], cand_v[0] = d0, entry, v0

    res_d = np.full(k, np.inf, np.float32)
    res_i = np.full(k, -1, np.int64)
    if v0:
        res_d[0], res_i[0] = d0, entry

    visited = {entry}
    cnt, insp, nvv, npop, hops = 1, 1, int(v0), 0, 0
    conv = -1
    res_full = 1 if (v0 and k == 1) else -1

    def covered():
        return gt_dist is not None and np.all(res_d <= gt_dist + 1e-6)

    while True:
        pk = np.where(~cand_e & (cand_i >= 0), cand_d, np.inf)
        p = int(np.argmin(pk))
        if not np.isfinite(pk[p]):
            break
        if cnt >= budget:
            break
        u = int(cand_i[p])
        cand_e[p] = True
        npop += int(cand_v[p])
        hops += 1

        nb = neighbors[u]
        nb = nb[nb >= 0]
        new = np.array([x for x in nb if x not in visited], dtype=np.int64)
        visited.update(int(x) for x in new)
        if new.size:
            dd = ((base[new] - query) ** 2).sum(axis=1).astype(np.float32)
            vv = _pred_one(pred_kind, attrs, q_attr, new)
            cnt += new.size
            insp += new.size
            nvv += int(vv.sum())
            # queue merge — identical stable order to lockstep concat
            md = np.concatenate([cand_d, dd])
            mi = np.concatenate([cand_i, new])
            me = np.concatenate([cand_e, np.zeros(new.size, bool)])
            mv = np.concatenate([cand_v, vv])
            order = np.argsort(md, kind="stable")[:m]
            cand_d, cand_i, cand_e, cand_v = md[order], mi[order], me[order], mv[order]
            # result merge
            rd = np.concatenate([res_d, np.where(vv, dd, np.inf)])
            ri = np.concatenate([res_i, np.where(vv, new, -1)])
            order = np.argsort(rd, kind="stable")[:k]
            res_d, res_i = rd[order], ri[order]
        if conv < 0 and covered():
            conv = cnt
        if res_full < 0 and np.isfinite(res_d[-1]):
            res_full = cnt

    return dict(
        res_idx=res_i,
        res_dist=res_d,
        cnt=cnt,
        n_inspected=insp,
        n_valid_visited=nvv,
        n_pop_valid=npop,
        hops=hops,
        conv_cnt=conv,
        res_full_cnt=res_full,
    )
