"""Pre-filter scan plan: bitmap-compile the filter, scan only passing rows.

The planner's alternative to graph traversal for selective filters. Three
fixed-shape stages, all per-lane deterministic:

  bitmap    `filters.compile.eval_program_matrix` evaluates the compiled
            FilterProgram against the full attribute store — a [B, N] bool
            candidate bitmap plus exact per-query selectivity σ_q and
            per-clause global selectivities. Boolean work only: 0 NDC (the
            repo counts predicate evaluations in n_inspected, not cnt).
  gather    per lane, the σ_q·N passing row ids (stable ascending order),
            padded to a shared 64-aligned width V (kernels.distance
            .SCAN_ALIGN) so the distance block keeps a fixed shape and the
            padded width cannot change any value.
  distance  `kernels.ops.masked_scan_dist` — the traversal's masked-distance
            Pallas kernel on TPU, the per-lane-deterministic host path on
            CPU — then one stable top-M/top-k selection.

Cost is exactly σ_q·N distance computations per lane (`state.cnt`), the
closed-form quantity the planner compares against predicted traversal NDC.
On float32 engines the result is bit-identical to the bruteforce oracle
`index.bruteforce.filtered_knn_exact` (same distance source, same stable
tie order — tests/test_planner.py pins it). On quantized engines the scan
runs in the compressed domain (int8 ADC / PQ LUT over the gathered codes)
and fills the candidate queue with the top-M compressed candidates, so the
engine's terminal exact float32 rerank restores exact-domain results from
the same pool contract the traversal uses.

The returned SearchState is terminal: `active` is all-False and the queue
is fully expanded — scan states must not be resumed, only reranked/read.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.engine import SearchEngine
from repro.core.search import SearchConfig, SearchState
from repro.core.state import INF
from repro.filters.compile import (CLAUSE_FEATURE_SLOTS, FilterProgram,
                                   eval_program_matrix)
from repro.kernels import ops as kops
from repro.kernels.distance import SCAN_ALIGN


class ScanStats(NamedTuple):
    """Bitmap-stage output: the scan plan's input and the planner's exact
    pre-probe statistics (σ_q and global per-clause selectivities)."""

    valid: np.ndarray        # [B, N] bool candidate bitmap
    counts: np.ndarray       # [B] i64 — σ_q·N, exact
    clause_frac: np.ndarray  # [B, CLAUSE_FEATURE_SLOTS] f32 global clause σ
    n: int                   # corpus size N

    @property
    def sigma(self) -> np.ndarray:
        return self.counts.astype(np.float64) / max(self.n, 1)

    def rows(self, idx) -> "ScanStats":
        """Lane subset (planner partition / serving batch slicing)."""
        idx = np.asarray(idx)
        return ScanStats(valid=self.valid[idx], counts=self.counts[idx],
                         clause_frac=self.clause_frac[idx], n=self.n)


def scan_stats(engine: SearchEngine, prog: FilterProgram,
               chunk: int = 2048) -> ScanStats:
    """Compile the candidate bitmap + exact selectivity statistics."""
    if getattr(engine, "is_sharded", False):
        # index-axis-sharded engine: per-shard bitmap passes, one global
        # ScanStats (core.sharded) — keeps the planner engine-agnostic
        return engine.scan_stats(prog, chunk=chunk)
    valid, frac = eval_program_matrix(prog, engine.label_attrs,
                                      engine.value_attrs, chunk=chunk)
    return ScanStats(valid=valid, counts=valid.sum(axis=1).astype(np.int64),
                     clause_frac=frac, n=int(valid.shape[1]))


def _aligned_width(max_count: int, n: int) -> int:
    """Smallest power of two ≥ max(count, SCAN_ALIGN), capped at ⌈N⌉₆₄.

    Power-of-two rounding bounds the jit shape count across heterogeneous
    batches (the program compiler applies the same discipline to slot
    counts); every candidate width is a SCAN_ALIGN multiple, so which width
    a batch lands on cannot change any distance value.
    """
    v = max(SCAN_ALIGN, 1 << max(0, int(max_count - 1).bit_length()))
    cap = -(-n // SCAN_ALIGN) * SCAN_ALIGN
    return min(v, cap)


def scan_search(
    engine: SearchEngine,
    cfg: SearchConfig,
    queries: np.ndarray,
    filt,                                # FilterSpec | Expr(s) | FilterProgram
    stats: ScanStats | None = None,
    base_state: SearchState | None = None,
) -> SearchState:
    """Execute the pre-filter scan plan; returns a terminal SearchState.

    `stats` reuses a bitmap the planner already compiled for routing.
    `base_state` carries a probed lane's counters into the scan (the
    planner's post-probe fallback path): cnt/n_inspected/etc. accumulate on
    top of the probe's, and d_start is preserved so feature extraction on
    the merged batch stays finite and consistent. Result/queue buffers are
    *replaced* — the scan covers the full valid set, a superset of anything
    the probe saw.
    """
    if getattr(engine, "is_sharded", False):
        # sharded engines scan shard-by-shard and merge (core.sharded);
        # the returned ShardedSearchState is terminal like this one
        return engine.scan(cfg, queries, filt, stats=stats,
                           base_state=base_state)
    prog = engine.compile(filt)
    if stats is None:
        stats = scan_stats(engine, prog)
    q = jnp.asarray(queries, jnp.float32)
    b = q.shape[0]
    n = stats.n
    m, k = cfg.queue_size, cfg.k
    precision = engine.effective_precision(cfg)

    counts = jnp.asarray(stats.counts, jnp.int32)
    v = _aligned_width(int(stats.counts.max(initial=0)), n)
    take = min(v, n)
    validj = jnp.asarray(stats.valid)
    # stable argsort over ~valid puts passing rows first, in ascending id
    # order — deterministic per lane, which both the oracle tie order and
    # the serving bit-identity rely on
    order = jnp.argsort(~validj, axis=1, stable=True)[:, :take]
    idx = jnp.zeros((b, v), jnp.int32).at[:, :take].set(
        order.astype(jnp.int32))
    mask = jnp.arange(v)[None, :] < counts[:, None]

    if precision == "float32":
        if engine.base_vectors.shape[1] == 0:
            raise ValueError(
                "float32 scan on a host-tiered engine: the device holds "
                "only a vector placeholder — scan at the engine's "
                "compressed precision (the terminal rerank stays exact)")
        xg = engine.base_vectors[idx]
        dd = kops.masked_scan_dist(q, xg, mask)
        err_add = jnp.zeros((b,), jnp.float32)
    else:
        # compressed-domain ADC over the gathered codes — same dispatch the
        # traversal backends use, so the rerank pool lives in one metric
        from repro.quant.codecs import QuantGather, prepare_query, quant_dist

        quant = engine.quant
        prep = prepare_query(precision, quant, q)
        codes_g = quant.codes[idx]
        if codes_g.dtype == jnp.uint8:
            codes_g = codes_g.astype(jnp.int32)
        dd = quant_dist(precision,
                        QuantGather(prep=prep, codes=codes_g,
                                    norms=quant.norms[idx]))
        dd = jnp.where(mask, dd, INF)
        err_add = jnp.where(mask, quant.err[idx], 0.0).sum(axis=1)

    # one stable ascending selection serves both buffers: results are the
    # first k columns of the top-M candidate pool
    p = min(v, m)
    sel = jnp.argsort(dd, axis=1, stable=True)[:, :p]
    top_d = jnp.take_along_axis(dd, sel, axis=1)
    top_i = jnp.where(jnp.isfinite(top_d),
                      jnp.take_along_axis(idx, sel, axis=1), -1)
    pad = m - p
    cand_dist = jnp.pad(top_d, ((0, 0), (0, pad)), constant_values=INF)
    cand_idx = jnp.pad(top_i, ((0, 0), (0, pad)), constant_values=-1)
    in_pool = cand_idx >= 0
    res_dist, res_idx = cand_dist[:, :k], cand_idx[:, :k]

    cnt_add = counts
    if base_state is None:
        carry = SearchState(
            cand_dist=cand_dist, cand_idx=cand_idx, cand_exp=in_pool,
            cand_valid=in_pool, res_dist=res_dist, res_idx=res_idx,
            visited=jnp.zeros((b, (n + 31) // 32), jnp.uint32),
            cnt=jnp.zeros((b,), jnp.int32),
            n_inspected=jnp.zeros((b,), jnp.int32),
            n_valid_visited=jnp.zeros((b,), jnp.int32),
            n_clause_valid=jnp.zeros((b, CLAUSE_FEATURE_SLOTS), jnp.int32),
            n_pop_valid=jnp.zeros((b,), jnp.int32),
            q_err_sum=jnp.zeros((b,), jnp.float32),
            hops=jnp.zeros((b,), jnp.int32),
            active=jnp.zeros((b,), bool),
            d_start=jnp.zeros((b,), jnp.float32),
            conv_cnt=jnp.full((b,), -1, jnp.int32),
            res_full_cnt=jnp.full((b,), -1, jnp.int32),
        )
    else:
        carry = base_state._replace(
            cand_dist=cand_dist, cand_idx=cand_idx, cand_exp=in_pool,
            cand_valid=in_pool, res_dist=res_dist, res_idx=res_idx,
            active=jnp.zeros((b,), bool))
    clause_add = jnp.asarray(
        np.rint(stats.clause_frac * n).astype(np.int32))
    return carry._replace(
        cnt=carry.cnt + cnt_add,
        n_inspected=carry.n_inspected + jnp.full((b,), n, jnp.int32),
        n_valid_visited=carry.n_valid_visited + counts,
        n_clause_valid=carry.n_clause_valid + clause_add,
        q_err_sum=carry.q_err_sum + err_add,
        res_full_cnt=jnp.where(jnp.isfinite(res_dist[:, -1]),
                               carry.cnt + cnt_add, carry.res_full_cnt),
    )
