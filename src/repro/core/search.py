"""Batched lockstep filtered beam search over a fixed-degree proximity graph.

This is the TPU-native adaptation of the paper's Algorithm 1 (PostFiltering
Early-Termination Search). A batch of B queries traverses the graph in
lockstep inside one `jax.lax.while_loop`; per-lane `active` masks realize
per-query adaptive termination (the E2E mechanism) without breaking SPMD.

Key structures (all static shapes):
  candidate queue   sorted ascending [B, M]  (dist, idx, expanded, valid)
  result set        sorted ascending [B, K]  (valid nodes only)
  visited set       packed bitset    [B, ceil(N/32)] uint32
  counters          cnt (NDC), n_inspected, n_valid_visited, n_pop_valid, hops

The engine is *resumable*: `run_search` consumes and returns a `SearchState`,
so the paper's zero-overhead early probe is literally the same loop run with
budget=f, whose carry then seeds the adaptive-termination phase (budget=Ŵ_q).

Two traversal modes (static):
  post  PostFiltering (paper §2.2): all new nodes get distances (NDC) and
        enter the queue; only predicate-valid nodes enter the result set.
  pre   PreFiltering / ACORN-γ (paper §A.3): neighbors (1-hop ∪ strided
        2-hop) are *inspected* first; distances are computed only for valid
        nodes, and only those enter the queue. NDC counts valid only;
        ρ_visited = valid/inspected carries the cost signal.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.filters.predicates import PRED_CONTAIN, PRED_EQUAL, PRED_RANGE

INF = jnp.float32(jnp.inf)


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    k: int = 10                # result set size
    queue_size: int = 128      # M — beam width / ef analogue
    degree: int = 32           # graph out-degree R (static)
    pred_kind: int = PRED_CONTAIN
    mode: str = "post"         # "post" | "pre"
    two_hop_stride: int = 8    # pre mode: sample every s-th 2-hop neighbor
    max_steps: int = 100000
    greedy_stop: bool = False  # optional: stop when best cand > worst result
    use_pallas: bool = False   # route distance eval through the Pallas kernel


class SearchState(NamedTuple):
    cand_dist: jax.Array       # [B, M] f32 sorted ascending, inf padded
    cand_idx: jax.Array        # [B, M] i32, -1 padded
    cand_exp: jax.Array        # [B, M] bool — already expanded
    cand_valid: jax.Array      # [B, M] bool — predicate validity
    res_dist: jax.Array        # [B, K] f32 sorted ascending, inf padded
    res_idx: jax.Array         # [B, K] i32, -1 padded
    visited: jax.Array         # [B, NW] u32 bitset
    cnt: jax.Array             # [B] i32 — NDC (paper's W_q unit)
    n_inspected: jax.Array     # [B] i32 — predicate evaluations
    n_valid_visited: jax.Array # [B] i32 — valid among inspected
    n_pop_valid: jax.Array     # [B] i32 — valid among popped/expanded
    hops: jax.Array            # [B] i32 — expansions (search hops)
    active: jax.Array          # [B] bool
    d_start: jax.Array         # [B] f32 — entry-point distance (feature)
    conv_cnt: jax.Array        # [B] i32 — NDC at first full-recall, -1 if not yet
    res_full_cnt: jax.Array    # [B] i32 — NDC when the k-th valid was found, -1 if not yet


def _sqdist(q: jax.Array, x: jax.Array, use_pallas: bool) -> jax.Array:
    """q[B,d], x[B,R,d] -> [B,R] squared L2."""
    if use_pallas:
        from repro.kernels import ops as kops

        return kops.batched_sqdist(q, x)
    qn = jnp.sum(q * q, axis=-1)[:, None]
    xn = jnp.sum(x * x, axis=-1)
    qx = jnp.einsum("bd,brd->br", q, x)
    return jnp.maximum(qn + xn - 2.0 * qx, 0.0)


def _predicate(kind: int, attrs, q_attr, nb_safe):
    """Gather node attributes for nb [B,R] and evaluate the filter."""
    if kind == PRED_RANGE:
        vals = attrs[nb_safe]  # [B, R]
        lo, hi = q_attr
        return (vals >= lo[:, None]) & (vals <= hi[:, None])
    masks = attrs[nb_safe]  # [B, R, W]
    qm = q_attr[:, None, :]
    if kind == PRED_CONTAIN:
        return jnp.all((masks & qm) == qm, axis=-1)
    if kind == PRED_EQUAL:
        return jnp.all(masks == qm, axis=-1)
    raise ValueError(kind)


def _merge_queue(dist, idx, exp, valid, new_dist, new_idx, new_valid, m):
    """Merge sorted [B,M] buffers with new [B,R] entries; keep best M."""
    d = jnp.concatenate([dist, new_dist], axis=1)
    i = jnp.concatenate([idx, new_idx], axis=1)
    e = jnp.concatenate([exp, jnp.zeros_like(new_idx, dtype=bool)], axis=1)
    v = jnp.concatenate([valid, new_valid], axis=1)
    order = jnp.argsort(d, axis=1, stable=True)[:, :m]
    return (
        jnp.take_along_axis(d, order, axis=1),
        jnp.take_along_axis(i, order, axis=1),
        jnp.take_along_axis(e, order, axis=1),
        jnp.take_along_axis(v, order, axis=1),
    )


def _merge_results(res_dist, res_idx, new_dist, new_idx, k):
    d = jnp.concatenate([res_dist, new_dist], axis=1)
    i = jnp.concatenate([res_idx, new_idx], axis=1)
    order = jnp.argsort(d, axis=1, stable=True)[:, :k]
    return jnp.take_along_axis(d, order, axis=1), jnp.take_along_axis(i, order, axis=1)


def init_state(
    cfg: SearchConfig,
    queries: jax.Array,      # [B, d]
    q_attr,                  # [B, W] masks or (lo[B], hi[B])
    base_vectors: jax.Array, # [N, d]
    attrs,                   # [N, W] u32 or [N] f32
    entry_point: int,
    gt_dist: jax.Array | None = None,  # [B, K] for convergence tracking
) -> SearchState:
    b = queries.shape[0]
    n = base_vectors.shape[0]
    nw = (n + 31) // 32
    m, k = cfg.queue_size, cfg.k

    ep = jnp.full((b, 1), entry_point, dtype=jnp.int32)
    d0 = _sqdist(queries, base_vectors[ep], cfg.use_pallas)  # [B,1]
    val0 = _predicate(cfg.pred_kind, attrs, q_attr, ep)      # [B,1]

    cand_dist = jnp.full((b, m), INF).at[:, :1].set(d0)
    cand_idx = jnp.full((b, m), -1, dtype=jnp.int32).at[:, :1].set(ep)
    cand_exp = jnp.zeros((b, m), dtype=bool)
    cand_valid = jnp.zeros((b, m), dtype=bool).at[:, :1].set(val0)

    res_dist = jnp.full((b, k), INF)
    res_idx = jnp.full((b, k), -1, dtype=jnp.int32)
    res_dist = res_dist.at[:, 0].set(jnp.where(val0[:, 0], d0[:, 0], INF))
    res_idx = res_idx.at[:, 0].set(jnp.where(val0[:, 0], ep[:, 0], -1))

    visited = jnp.zeros((b, nw), dtype=jnp.uint32)
    word = entry_point // 32
    bit = jnp.uint32(1) << jnp.uint32(entry_point % 32)
    visited = visited.at[:, word].set(bit)

    ndc0 = jnp.ones((b,), jnp.int32)  # entry distance is computed in both modes
    return SearchState(
        cand_dist=cand_dist,
        cand_idx=cand_idx,
        cand_exp=cand_exp,
        cand_valid=cand_valid,
        res_dist=res_dist,
        res_idx=res_idx,
        visited=visited,
        cnt=ndc0,
        n_inspected=jnp.ones((b,), jnp.int32),
        n_valid_visited=val0[:, 0].astype(jnp.int32),
        n_pop_valid=jnp.zeros((b,), jnp.int32),
        hops=jnp.zeros((b,), jnp.int32),
        active=jnp.ones((b,), bool),
        d_start=d0[:, 0],
        conv_cnt=jnp.full((b,), -1, jnp.int32),
        res_full_cnt=jnp.where(val0[:, 0] & (k == 1), 1, -1).astype(jnp.int32),
    )


def _make_step(cfg: SearchConfig, queries, q_attr, base_vectors, attrs, neighbors,
               budgets, gt_dist):
    """Build the while_loop body closed over static data and per-lane budgets."""
    b = queries.shape[0]
    m, k, r = cfg.queue_size, cfg.k, cfg.degree
    rows = jnp.arange(b, dtype=jnp.int32)[:, None]

    def step(state: SearchState) -> SearchState:
        # ---- pop best unexpanded candidate per lane ----
        unexp = (~state.cand_exp) & (state.cand_idx >= 0)
        pop_key = jnp.where(unexp, state.cand_dist, INF)
        p = jnp.argmin(pop_key, axis=1)                      # [B]
        best_d = jnp.take_along_axis(pop_key, p[:, None], axis=1)[:, 0]
        has_cand = jnp.isfinite(best_d)
        u = jnp.take_along_axis(state.cand_idx, p[:, None], axis=1)[:, 0]
        u_valid = jnp.take_along_axis(state.cand_valid, p[:, None], axis=1)[:, 0]

        stop_budget = state.cnt >= budgets
        act = state.active & has_cand & (~stop_budget)
        if cfg.greedy_stop:
            worst_res = state.res_dist[:, -1]
            act = act & ~(jnp.isfinite(worst_res) & (best_d > worst_res))

        # ---- mark popped slot expanded ----
        exp_new = state.cand_exp.at[rows[:, 0], p].set(True)
        cand_exp = jnp.where(act[:, None], exp_new, state.cand_exp)

        # ---- gather neighbor lists ----
        u_safe = jnp.maximum(u, 0)
        nb = neighbors[u_safe]                               # [B, R]
        if cfg.mode == "pre":
            hop2 = neighbors[jnp.maximum(nb, 0)]             # [B, R, R]
            hop2 = hop2[:, :, :: cfg.two_hop_stride].reshape(b, -1)
            hop2 = jnp.where(jnp.repeat(nb >= 0, hop2.shape[1] // r, axis=1), hop2, -1)
            nb = jnp.concatenate([nb, hop2], axis=1)
            # intra-step dedup (2-hop lists may repeat 1-hop entries)
            order = jnp.argsort(nb, axis=1, stable=True)
            s = jnp.take_along_axis(nb, order, axis=1)
            dup_sorted = jnp.concatenate(
                [jnp.zeros((b, 1), bool), s[:, 1:] == s[:, :-1]], axis=1
            )
            inv = jnp.argsort(order, axis=1, stable=True)
            dup = jnp.take_along_axis(dup_sorted, inv, axis=1)
            nb = jnp.where(dup, -1, nb)
        nb_ok = (nb >= 0) & act[:, None]
        nb_safe = jnp.maximum(nb, 0)

        # ---- visited-set test (packed bitset) ----
        word_idx = nb_safe >> 5
        bit = jnp.uint32(1) << (nb_safe & 31).astype(jnp.uint32)
        words = jnp.take_along_axis(state.visited, word_idx, axis=1)
        seen = (words & bit) != 0
        is_new = nb_ok & (~seen)

        # ---- predicate on inspected nodes ----
        valid = _predicate(cfg.pred_kind, attrs, q_attr, nb_safe) & is_new

        # ---- distances ----
        if cfg.mode == "pre":
            dist_mask = valid           # ACORN: distances only for valid nodes
        else:
            dist_mask = is_new          # PostFiltering: distances for all new
        xv = base_vectors[nb_safe]                            # [B, R', d]
        dd = _sqdist(queries, xv, cfg.use_pallas)
        dd = jnp.where(dist_mask, dd, INF)

        # ---- visited bits: set for every inspected-new node ----
        scat_w = jnp.where(is_new, word_idx, b * 0 - 1)       # -1 dropped
        scat_b = jnp.where(is_new, bit, jnp.uint32(0))
        visited = state.visited.at[rows, scat_w].add(scat_b, mode="drop")

        # ---- queue merge (post: all new; pre: valid only, via inf dist) ----
        cand_dist, cand_idx, cand_exp2, cand_valid = _merge_queue(
            state.cand_dist, state.cand_idx, cand_exp, state.cand_valid,
            dd, jnp.where(jnp.isfinite(dd), nb, -1), valid, m,
        )

        # ---- result merge (valid only) ----
        res_in_d = jnp.where(valid & jnp.isfinite(dd), dd, INF)
        res_dist, res_idx = _merge_results(
            state.res_dist, state.res_idx, res_in_d,
            jnp.where(jnp.isfinite(res_in_d), nb, -1), k,
        )

        # ---- counters ----
        ndc_add = dist_mask.sum(axis=1).astype(jnp.int32)
        insp_add = is_new.sum(axis=1).astype(jnp.int32)
        valid_add = valid.sum(axis=1).astype(jnp.int32)
        cnt = state.cnt + jnp.where(act, ndc_add, 0)
        n_inspected = state.n_inspected + jnp.where(act, insp_add, 0)
        n_valid_visited = state.n_valid_visited + jnp.where(act, valid_add, 0)
        n_pop_valid = state.n_pop_valid + jnp.where(act & u_valid, 1, 0)
        hops = state.hops + jnp.where(act, 1, 0)

        # ---- convergence tracking for W_q ground truth ----
        if gt_dist is not None:
            covered = jnp.all(res_dist <= gt_dist + 1e-6, axis=1)
            first = (state.conv_cnt < 0) & covered
            conv_cnt = jnp.where(first, cnt, state.conv_cnt)
        else:
            conv_cnt = state.conv_cnt

        # ---- NDC at which the result set filled (feature) ----
        now_full = jnp.isfinite(res_dist[:, -1]) & act
        first_full = (state.res_full_cnt < 0) & now_full
        res_full_cnt = jnp.where(first_full, cnt, state.res_full_cnt)

        # ---- lane masking: inactive lanes keep their old arrays ----
        am = act[:, None]
        return SearchState(
            cand_dist=jnp.where(am, cand_dist, state.cand_dist),
            cand_idx=jnp.where(am, cand_idx, state.cand_idx),
            cand_exp=jnp.where(am, cand_exp2, cand_exp),
            cand_valid=jnp.where(am, cand_valid, state.cand_valid),
            res_dist=jnp.where(am, res_dist, state.res_dist),
            res_idx=jnp.where(am, res_idx, state.res_idx),
            visited=jnp.where(am, visited, state.visited),
            cnt=cnt,
            n_inspected=n_inspected,
            n_valid_visited=n_valid_visited,
            n_pop_valid=n_pop_valid,
            hops=hops,
            active=act,
            d_start=state.d_start,
            conv_cnt=conv_cnt,
            res_full_cnt=res_full_cnt,
        )

    return step


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "entry_point"),
)
def run_search(
    cfg: SearchConfig,
    queries: jax.Array,
    q_attr,
    base_vectors: jax.Array,
    attrs,
    neighbors: jax.Array,
    budgets: jax.Array,            # [B] i32 NDC budgets (use big value for ∞)
    entry_point: int,
    state: SearchState | None = None,
    gt_dist: jax.Array | None = None,
) -> SearchState:
    """Run (or resume) the lockstep search until all lanes terminate.

    Termination per lane: queue exhausted, NDC ≥ budget, or (optional)
    greedy result-bound stop. Resuming with a larger budget continues
    exactly where the previous phase stopped — the paper's zero-overhead
    probe reuse.
    """
    if state is None:
        state = init_state(cfg, queries, q_attr, base_vectors, attrs, entry_point,
                           gt_dist)
    else:
        # reactivate lanes that stopped purely on budget
        state = state._replace(active=jnp.ones_like(state.active))

    step = _make_step(cfg, queries, q_attr, base_vectors, attrs, neighbors,
                      budgets, gt_dist)

    def cond(carry):
        state, it = carry
        return jnp.any(state.active) & (it < cfg.max_steps)

    def body(carry):
        state, it = carry
        return step(state), it + 1

    state, _ = jax.lax.while_loop(cond, body, (state, jnp.int32(0)))
    return state


def topk_results(state: SearchState) -> tuple[np.ndarray, np.ndarray]:
    """Host-side (idx, dist) of the result set."""
    return np.asarray(state.res_idx), np.asarray(state.res_dist)
