"""Batched lockstep filtered beam search — facade over the traversal stack.

This is the TPU-native adaptation of the paper's Algorithm 1 (PostFiltering
Early-Termination Search). A batch of B queries traverses the graph in
lockstep inside one `jax.lax.while_loop`; per-lane `active` masks realize
per-query adaptive termination (the E2E mechanism) without breaking SPMD.

The implementation is layered (see docs/ARCHITECTURE.md):

  repro.core.state     SearchConfig / SearchState, init + resume logic
  repro.core.step      backend-agnostic per-step bookkeeping (pop, visited
                       bitset, predicate, counters, convergence tracking)
  repro.core.backends  pluggable TraversalBackend hot paths — "dense"
                       (jnp reference), "pallas" (fused kernel) and
                       "pallas_persistent" (fused kernel + multi-step launch
                       grouping); selected statically via SearchConfig.backend
  repro.core.engine    shard-aware SearchEngine facade over device meshes

`run_search` here stitches those layers into the jitted while_loop and is
*resumable*: it consumes and returns a `SearchState`, so the paper's
zero-overhead early probe is literally the same loop run with budget=f,
whose carry then seeds the adaptive-termination phase (budget=Ŵ_q).

Persistent execution (backend "pallas_persistent") adds two entry points on
top of the same carry contract:

  `_persistent_launch`     one jitted dispatch advancing a state by up to
                           cfg.steps_per_launch lockstep steps — the host
                           analogue of the VMEM-resident multi-step kernel
                           (repro.kernels.persistent_step), which it routes
                           to on TPU in post mode.
  `run_search_persistent`  eager driver looping launches until every lane
                           terminates, compacting to the active lanes
                           between launches (valid because the lockstep loop
                           has no cross-lane collectives — the same property
                           the serving scheduler's lane surgery relies on).
                           Every launch boundary is a legal step boundary:
                           the returned state is bit-identical to
                           `run_search`'s, so probe→estimate→resume and the
                           scheduler's preemption slices work unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

# Re-exports: the public surface predates the layering and stays stable.
from repro.core.backends import (  # noqa: F401
    TraversalBackend,
    available_backends,
    get_backend,
    register_backend,
)
from repro.core.state import (  # noqa: F401
    INF,
    SearchConfig,
    SearchState,
    init_state,
    prepare_resume,
    put_lanes,
    take_lanes,
    topk_results,
)
from repro.core.step import make_step


def _make_qprep(cfg: SearchConfig, queries, quant):
    """Per-query ADC state for compressed-domain traversal (None at f32)."""
    precision = cfg.precision or "float32"
    if precision == "float32":
        return None
    if quant is None:
        raise ValueError(
            f"cfg.precision={precision!r} needs a quant index — build "
            "the engine with precision=... or pass quant= explicitly")
    from repro.quant.codecs import prepare_query

    return prepare_query(precision, quant, queries)


def _run_search_impl(
    cfg: SearchConfig,
    queries: jax.Array,
    prog,                          # FilterProgram (leaves [B, S, ...])
    base_vectors: jax.Array,
    attrs,                         # (labels [N, W] u32, values [N, V] f32)
    neighbors: jax.Array,
    budgets: jax.Array,            # [B] i32 NDC budgets (use big value for ∞)
    entry_point: int,
    state: SearchState | None = None,
    gt_dist: jax.Array | None = None,
    quant=None,                    # Int8Index | PQIndex for compressed mode
) -> SearchState:
    """Run (or resume) the lockstep search until all lanes terminate.

    Filters arrive pre-compiled: `prog` is a `FilterProgram` whose padded
    clause slots let a batch of heterogeneous boolean filters evaluate in
    one traced pass (the engine compiles FilterSpec / expression inputs).
    Termination per lane: queue exhausted, NDC ≥ budget, or (optional)
    greedy result-bound stop. Resuming with a larger budget continues
    exactly where the previous phase stopped — the paper's zero-overhead
    probe reuse. The traversal backend is resolved statically from
    `cfg.backend`, so dense and Pallas hot paths share this loop verbatim.

    When `cfg.precision` is "int8" or "pq", `quant` must carry the matching
    compressed index (repro.quant); the per-query ADC state is prepared
    once here and every step evaluates distances in the compressed domain.
    Probe/resume semantics are unchanged — the compressed traversal is
    bit-resumable within its precision mode.

    The jitted wrapper (`run_search`) donates `state`: a resumed carry's
    buffers are updated in place rather than copied, so callers must not
    reuse a state object after passing it here (slice lanes out with
    `take_lanes` first if a copy is needed — every in-repo caller either
    rebinds or passes a fresh slice).
    """
    backend = get_backend(cfg.backend or "dense")
    qprep = _make_qprep(cfg, queries, quant)
    if state is None:
        state = init_state(cfg, queries, prog, base_vectors, attrs, entry_point,
                           gt_dist, quant=quant, qprep=qprep)
    else:
        state = prepare_resume(state)

    step = make_step(cfg, backend, queries, prog, base_vectors, attrs,
                     neighbors, budgets, gt_dist, quant=quant, qprep=qprep)

    if getattr(backend, "persistent", False):
        # Launch-grouped form of the same loop: an inner bounded while of up
        # to cfg.steps_per_launch steps per outer trip. Bit-identical to the
        # flat loop (inactive-lane steps are no-ops, and the inner/outer
        # bounds compose to the same max_steps cutoff); the grouping is what
        # a persistent backend's dispatch amortization maps onto when this
        # traced path runs under shard_map.
        spl = max(1, cfg.steps_per_launch)

        def cond(carry):
            state, it = carry
            return jnp.any(state.active) & (it < cfg.max_steps)

        def body(carry):
            state, it = carry

            def icond(c):
                st, j = c
                return ((j < spl) & (it + j < cfg.max_steps)
                        & jnp.any(st.active))

            def ibody(c):
                st, j = c
                return step(st), j + 1

            state, j = jax.lax.while_loop(icond, ibody, (state, jnp.int32(0)))
            return state, it + j

        state, _ = jax.lax.while_loop(cond, body, (state, jnp.int32(0)))
        return state

    def cond(carry):
        state, it = carry
        return jnp.any(state.active) & (it < cfg.max_steps)

    def body(carry):
        state, it = carry
        return step(state), it + 1

    state, _ = jax.lax.while_loop(cond, body, (state, jnp.int32(0)))
    return state


# `state` is donated: the carry is consumed by the call that resumes it, so
# XLA updates the ~17 state buffers in place instead of copying them on
# every probe→resume / preemption slice. (Donation inside a traced context —
# e.g. under the sharded engine's shard_map — is ignored by JAX, which is
# exactly the safe behavior.)
run_search = functools.partial(
    jax.jit,
    static_argnames=("cfg", "entry_point"),
    donate_argnames=("state",),
)(_run_search_impl)

# Untraced entry for callers already inside a traced context (the sharded
# engine's shard_map body runs one traversal per local index shard, with a
# *traced* per-shard entry point — init_state only touches entry_point via
# jnp ops, so tracing it is safe where run_search's static_argnames aren't).
run_search_impl = _run_search_impl


# --------------------------------------------------------------------------
# persistent execution: multi-step launches + eager active-lane compaction
# --------------------------------------------------------------------------

# Driver-observed dispatch accounting. `_persistent_launch` is the only
# device dispatch the persistent driver makes, so counting calls here is
# ground truth for "how many launches did this search actually cost" — the
# quantity the serving metrics report (a ⌈steps/spl⌉ estimate undercounts:
# probe phases dispatch once per snapshot, and compaction relaunches split
# what a step count would merge). Lifetime counters, read via deltas.
_DISPATCH_COUNTERS = {"launches": 0, "compactions": 0, "steps": 0}


def dispatch_counters() -> dict:
    """Snapshot of lifetime persistent-driver dispatch counters:
    `launches` (device dispatches), `compactions` (launches at reduced
    lane width), `steps` (lockstep trips actually advanced). Callers
    measure work by differencing two snapshots."""
    return dict(_DISPATCH_COUNTERS)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "entry_point", "mode", "use_kernel"),
    donate_argnames=("state",),
)
def _persistent_launch(
    cfg: SearchConfig,
    queries, prog, base_vectors, attrs, neighbors, budgets,
    entry_point: int,
    state, gt_dist, quant, qprep, rem,
    rows=None, aux=None,
    *, mode: str, use_kernel: bool = False,
):
    """One persistent dispatch: advance by up to cfg.steps_per_launch steps.

    mode  "init"    no incoming state — build it (first launch of a search)
          "resume"  incoming probe carry — reactivate budget-stopped lanes
          "cont"    mid-search launch — must NOT reactivate: lanes that
                    terminated in an earlier launch of the *same* search
                    stay terminated (this is what makes a launch boundary
                    invisible, not a resume point)

    rem bounds the steps this launch may take (max_steps accounting across
    launches); `use_kernel` routes to the VMEM-resident multi-step Pallas
    kernel (TPU, post mode — `rows`/`aux` are its DMA-padded operand stores),
    otherwise the host inner while_loop runs the same steps. Either way the
    result is a bit-exact step boundary of the single-step loop.
    """
    if mode == "init":
        state = init_state(cfg, queries, prog, base_vectors, attrs,
                           entry_point, gt_dist, quant=quant, qprep=qprep)
    elif mode == "resume":
        state = prepare_resume(state)
    spl = max(1, cfg.steps_per_launch)

    if use_kernel:
        from repro.kernels.persistent_step import persistent_multi_step

        return persistent_multi_step(
            cfg, queries, prog, rows, aux, neighbors, budgets, state, rem,
            gt_dist, qprep, steps=spl, n_values=int(attrs[1].shape[1]),
            has_gt=gt_dist is not None)

    backend = get_backend(cfg.backend or "dense")
    step = make_step(cfg, backend, queries, prog, base_vectors, attrs,
                     neighbors, budgets, gt_dist, quant=quant, qprep=qprep)

    def icond(c):
        st, j = c
        return (j < spl) & (j < rem) & jnp.any(st.active)

    def ibody(c):
        st, j = c
        return step(st), j + 1

    state, _ = jax.lax.while_loop(icond, ibody, (state, jnp.int32(0)))
    return state


def run_search_persistent(
    cfg: SearchConfig,
    queries: jax.Array,
    prog,
    base_vectors: jax.Array,
    attrs,
    neighbors: jax.Array,
    budgets,
    entry_point: int,
    state: SearchState | None = None,
    gt_dist: jax.Array | None = None,
    quant=None,
    tracer=None,
    trace_id: str = "",
) -> SearchState:
    """Eager launch-loop driver for persistent backends (single device).

    Same signature and bit-exact results as `run_search`; the difference is
    *how* the steps are dispatched. Each trip runs one `_persistent_launch`
    of up to cfg.steps_per_launch steps, then reads back only the per-lane
    `active`/`hops` scalars. Lanes that terminated early are compacted away
    between launches: the surviving lanes are gathered (`take_lanes`) into
    the next power-of-two batch width, advanced, and scattered back
    (`put_lanes`, donated). This host-side compaction is the CPU/GPU
    analogue of the TPU kernel's in-kernel early exit — finished lanes stop
    costing compute at launch granularity instead of riding as no-ops until
    the slowest lane finishes.

    The selection pad (repeating the first active lane up to the ladder
    width) is benign: duplicated lanes carry identical buffers, follow
    identical deterministic trajectories, and scatter back identical values.

    `state`, when passed, is donated (same contract as `run_search`).

    `tracer`/`trace_id` emit one span per launch (width, mode, steps
    advanced) and one instant event per compaction. Spans wrap only the
    dispatch + the `hops` readback the driver performs anyway — tracing
    adds no device synchronization and the state stream is untouched, so
    results are bit-identical with tracing on or off.
    """
    from repro.obs.trace import as_tracer

    tr = as_tracer(tracer)
    qprep = _make_qprep(cfg, queries, quant)
    b = int(queries.shape[0])
    budgets = jnp.broadcast_to(jnp.asarray(budgets, jnp.int32), (b,))
    use_kernel = (jax.default_backend() == "tpu" and cfg.mode == "post")
    rows = aux = None
    if use_kernel:
        from repro.kernels.persistent_step import build_persistent_operands

        rows, aux = build_persistent_operands(
            cfg.precision or "float32", base_vectors, attrs[0], attrs[1],
            quant)

    mode = "init" if state is None else "resume"
    hops0 = 0 if state is None else np.asarray(state.hops)
    with tr.span("launch", trace_id, mode=mode, width=b) as sp:
        state = _persistent_launch(
            cfg, queries, prog, base_vectors, attrs, neighbors, budgets,
            entry_point, state, gt_dist, quant, qprep,
            jnp.int32(cfg.max_steps), rows, aux, mode=mode,
            use_kernel=use_kernel)
        it = int((np.asarray(state.hops) - hops0).max(initial=0))
        sp.set(steps=it)
    _DISPATCH_COUNTERS["launches"] += 1
    _DISPATCH_COUNTERS["steps"] += it

    min_w = min(8, b)  # ladder floor bounds the retrace count to O(log B)
    while it < cfg.max_steps:
        sel = np.flatnonzero(np.asarray(state.active))
        if sel.size == 0:
            break
        w = min(b, max(min_w, 1 << (int(sel.size) - 1).bit_length()))
        rem = jnp.int32(cfg.max_steps - it)
        if w == b:  # no compaction win — relaunch at full width
            hops0 = np.asarray(state.hops)
            with tr.span("launch", trace_id, mode="cont", width=b,
                         active=int(sel.size)) as sp:
                state = _persistent_launch(
                    cfg, queries, prog, base_vectors, attrs, neighbors,
                    budgets, entry_point, state, gt_dist, quant, qprep, rem,
                    rows, aux, mode="cont", use_kernel=use_kernel)
                d = int((np.asarray(state.hops) - hops0).max(initial=0))
                sp.set(steps=d)
            it += d
            _DISPATCH_COUNTERS["launches"] += 1
            _DISPATCH_COUNTERS["steps"] += d
            continue
        pad = w - int(sel.size)
        tr.emit("compact", trace_id, from_width=b, to_width=w,
                active=int(sel.size), pad=pad)
        sel_p = (np.concatenate([sel, np.full(pad, sel[0], sel.dtype)])
                 if pad else sel)
        sub_state, sub_q, sub_prog, sub_bud, sub_gt, sub_qp = take_lanes(
            (state, queries, prog, budgets, gt_dist, qprep), sel_p)
        hops0 = np.asarray(sub_state.hops)
        with tr.span("launch", trace_id, mode="cont", width=w,
                     active=int(sel.size), compacted=True) as sp:
            out = _persistent_launch(
                cfg, sub_q, sub_prog, base_vectors, attrs, neighbors,
                sub_bud, entry_point, sub_state, sub_gt, quant, sub_qp, rem,
                rows, aux, mode="cont", use_kernel=use_kernel)
            d = int((np.asarray(out.hops) - hops0).max(initial=0))
            sp.set(steps=d)
        it += d
        _DISPATCH_COUNTERS["launches"] += 1
        _DISPATCH_COUNTERS["compactions"] += 1
        _DISPATCH_COUNTERS["steps"] += d
        state = put_lanes(state, out, sel_p)
    return state
