"""Batched lockstep filtered beam search — facade over the traversal stack.

This is the TPU-native adaptation of the paper's Algorithm 1 (PostFiltering
Early-Termination Search). A batch of B queries traverses the graph in
lockstep inside one `jax.lax.while_loop`; per-lane `active` masks realize
per-query adaptive termination (the E2E mechanism) without breaking SPMD.

The implementation is layered (see docs/ARCHITECTURE.md):

  repro.core.state     SearchConfig / SearchState, init + resume logic
  repro.core.step      backend-agnostic per-step bookkeeping (pop, visited
                       bitset, predicate, counters, convergence tracking)
  repro.core.backends  pluggable TraversalBackend hot paths — "dense"
                       (jnp reference) and "pallas" (fused kernel); selected
                       statically via SearchConfig.backend
  repro.core.engine    shard-aware SearchEngine facade over device meshes

`run_search` here stitches those layers into the jitted while_loop and is
*resumable*: it consumes and returns a `SearchState`, so the paper's
zero-overhead early probe is literally the same loop run with budget=f,
whose carry then seeds the adaptive-termination phase (budget=Ŵ_q).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# Re-exports: the public surface predates the layering and stays stable.
from repro.core.backends import (  # noqa: F401
    TraversalBackend,
    available_backends,
    get_backend,
    register_backend,
)
from repro.core.state import (  # noqa: F401
    INF,
    SearchConfig,
    SearchState,
    init_state,
    prepare_resume,
    topk_results,
)
from repro.core.step import make_step


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "entry_point"),
)
def run_search(
    cfg: SearchConfig,
    queries: jax.Array,
    prog,                          # FilterProgram (leaves [B, S, ...])
    base_vectors: jax.Array,
    attrs,                         # (labels [N, W] u32, values [N, V] f32)
    neighbors: jax.Array,
    budgets: jax.Array,            # [B] i32 NDC budgets (use big value for ∞)
    entry_point: int,
    state: SearchState | None = None,
    gt_dist: jax.Array | None = None,
    quant=None,                    # Int8Index | PQIndex for compressed mode
) -> SearchState:
    """Run (or resume) the lockstep search until all lanes terminate.

    Filters arrive pre-compiled: `prog` is a `FilterProgram` whose padded
    clause slots let a batch of heterogeneous boolean filters evaluate in
    one traced pass (the engine compiles FilterSpec / expression inputs).
    Termination per lane: queue exhausted, NDC ≥ budget, or (optional)
    greedy result-bound stop. Resuming with a larger budget continues
    exactly where the previous phase stopped — the paper's zero-overhead
    probe reuse. The traversal backend is resolved statically from
    `cfg.backend`, so dense and Pallas hot paths share this loop verbatim.

    When `cfg.precision` is "int8" or "pq", `quant` must carry the matching
    compressed index (repro.quant); the per-query ADC state is prepared
    once here and every step evaluates distances in the compressed domain.
    Probe/resume semantics are unchanged — the compressed traversal is
    bit-resumable within its precision mode.
    """
    backend = get_backend(cfg.backend or "dense")
    precision = cfg.precision or "float32"
    qprep = None
    if precision != "float32":
        if quant is None:
            raise ValueError(
                f"cfg.precision={precision!r} needs a quant index — build "
                "the engine with precision=... or pass quant= explicitly")
        from repro.quant.codecs import prepare_query

        qprep = prepare_query(precision, quant, queries)
    if state is None:
        state = init_state(cfg, queries, prog, base_vectors, attrs, entry_point,
                           gt_dist, quant=quant, qprep=qprep)
    else:
        state = prepare_resume(state)

    step = make_step(cfg, backend, queries, prog, base_vectors, attrs,
                     neighbors, budgets, gt_dist, quant=quant, qprep=qprep)

    def cond(carry):
        state, it = carry
        return jnp.any(state.active) & (it < cfg.max_steps)

    def body(carry):
        state, it = carry
        return step(state), it + 1

    state, _ = jax.lax.while_loop(cond, body, (state, jnp.int32(0)))
    return state
