"""Traversal-step layer: the backend-agnostic per-step logic.

One lockstep step = pop → gather frontier → visited test → (backend:
predicate program + distances + queue/result merge) → counters. Everything
except the backend call is pure bookkeeping shared by all traversal
backends, so a backend only has to implement the arithmetic hot path — the
compiled filter-program evaluation, distance evaluation, and the two
sorted-buffer merges — see `repro.core.backends`.

The filter arrives as a compiled `FilterProgram` (filters/compile.py), so a
batch whose queries have heterogeneous boolean structure (And/Or/Not
compositions, different clause counts) runs through one traced step with no
per-kind Python branching: the step gathers each candidate's label words
and numeric attribute channels and hands both, with the program, to the
backend.

Two traversal modes (static):
  post  PostFiltering (paper §2.2): all new nodes get distances (NDC) and
        enter the queue; only predicate-valid nodes enter the result set.
  pre   PreFiltering / ACORN-γ (paper §A.3): neighbors (1-hop ∪ strided
        2-hop) are *inspected* first; distances are computed only for valid
        nodes, and only those enter the queue. NDC counts valid only;
        ρ_visited = valid/inspected carries the cost signal.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.state import INF, SearchConfig, SearchState
from repro.quant.codecs import QuantGather


def gather_frontier(cfg: SearchConfig, neighbors, u_safe):
    """Neighbor ids to inspect for popped nodes u_safe [B].

    post: the 1-hop list [B, R]. pre/widen: 1-hop ∪ strided 2-hop with
    intra-step dedup (2-hop lists may repeat 1-hop entries), ACORN-γ style.
    widen shares the pre frontier but keeps post accounting/scoring — the
    planner's filtered-expansion plan: under a selective conjunction the
    1-hop frontier of valid nodes disconnects, so the step pays distance
    NDC for every new widened neighbor (no predicate-gated scoring) in
    exchange for hop-2 reach.
    """
    b = u_safe.shape[0]
    r = cfg.degree
    nb = neighbors[u_safe]                                   # [B, R]
    if cfg.mode in ("pre", "widen"):
        hop2 = neighbors[jnp.maximum(nb, 0)]                 # [B, R, R]
        hop2 = hop2[:, :, :: cfg.two_hop_stride].reshape(b, -1)
        hop2 = jnp.where(jnp.repeat(nb >= 0, hop2.shape[1] // r, axis=1), hop2, -1)
        nb = jnp.concatenate([nb, hop2], axis=1)
        order = jnp.argsort(nb, axis=1, stable=True)
        s = jnp.take_along_axis(nb, order, axis=1)
        dup_sorted = jnp.concatenate(
            [jnp.zeros((b, 1), bool), s[:, 1:] == s[:, :-1]], axis=1
        )
        inv = jnp.argsort(order, axis=1, stable=True)
        dup = jnp.take_along_axis(dup_sorted, inv, axis=1)
        nb = jnp.where(dup, -1, nb)
    return nb


def make_step(cfg: SearchConfig, backend, queries, prog, base_vectors, attrs,
              neighbors, budgets, gt_dist, quant=None, qprep=None):
    """Build the while_loop body closed over static data and per-lane budgets.

    `backend` is a `TraversalBackend`: it receives the gathered neighbor
    vectors and attributes plus the compiled filter program and the current
    sorted buffers, and returns the merged buffers together with the
    per-candidate validity mask and per-clause hit counters.

    In compressed mode (cfg.precision != "float32") the step gathers the
    quant index's codes (+ norms / reconstruction errors) instead of the
    float32 vectors — the full-precision store is never touched inside the
    hot loop — and hands the backend a `QuantGather` carrying the prepared
    per-query ADC state (`qprep`, built once per search call).
    """
    b = queries.shape[0]
    rows = jnp.arange(b, dtype=jnp.int32)[:, None]
    label_attrs, value_attrs = attrs
    compressed = (cfg.precision or "float32") != "float32"

    def step(state: SearchState) -> SearchState:
        # ---- pop best unexpanded candidate per lane ----
        unexp = (~state.cand_exp) & (state.cand_idx >= 0)
        pop_key = jnp.where(unexp, state.cand_dist, INF)
        p = jnp.argmin(pop_key, axis=1)                      # [B]
        best_d = jnp.take_along_axis(pop_key, p[:, None], axis=1)[:, 0]
        has_cand = jnp.isfinite(best_d)
        u = jnp.take_along_axis(state.cand_idx, p[:, None], axis=1)[:, 0]
        u_valid = jnp.take_along_axis(state.cand_valid, p[:, None], axis=1)[:, 0]

        stop_budget = state.cnt >= budgets
        act = state.active & has_cand & (~stop_budget)
        if cfg.greedy_stop:
            worst_res = state.res_dist[:, -1]
            act = act & ~(jnp.isfinite(worst_res) & (best_d > worst_res))

        # ---- mark popped slot expanded ----
        exp_new = state.cand_exp.at[rows[:, 0], p].set(True)
        cand_exp = jnp.where(act[:, None], exp_new, state.cand_exp)

        # ---- gather frontier neighbor ids ----
        nb = gather_frontier(cfg, neighbors, jnp.maximum(u, 0))
        nb_ok = (nb >= 0) & act[:, None]
        nb_safe = jnp.maximum(nb, 0)

        # ---- visited-set test (packed bitset) ----
        word_idx = nb_safe >> 5
        bit = jnp.uint32(1) << (nb_safe & 31).astype(jnp.uint32)
        words = jnp.take_along_axis(state.visited, word_idx, axis=1)
        seen = (words & bit) != 0
        is_new = nb_ok & (~seen)

        # ---- visited bits: set for every inspected-new node ----
        scat_w = jnp.where(is_new, word_idx, -1)              # -1 dropped
        scat_b = jnp.where(is_new, bit, jnp.uint32(0))
        visited = state.visited.at[rows, scat_w].add(scat_b, mode="drop")

        # ---- backend hot path: filter program + distances + merges ----
        labels_g = label_attrs[nb_safe]                       # [B, R', W]
        values_g = value_attrs[nb_safe]                       # [B, R', V]
        if compressed:
            xv = None  # bandwidth point: float vectors stay out of the loop
            codes_g = quant.codes[nb_safe]                    # [B,R',d|S·L]
            if codes_g.dtype == jnp.uint8:
                codes_g = codes_g.astype(jnp.int32)
            qg = QuantGather(prep=qprep, codes=codes_g,
                             norms=quant.norms[nb_safe])
            err_add = jnp.where(is_new, quant.err[nb_safe], 0.0).sum(axis=1)
        else:
            xv = base_vectors[nb_safe]                        # [B, R', d]
            qg = None
            err_add = jnp.zeros((b,), jnp.float32)
        (cand_dist, cand_idx, cand_exp2, cand_valid, res_dist, res_idx,
         valid, clause_add) = backend.merge_step(
            cfg, queries, xv, nb, is_new, prog, labels_g, values_g,
            state.cand_dist, state.cand_idx, cand_exp, state.cand_valid,
            state.res_dist, state.res_idx, quant=qg,
        )

        # ---- counters (dist mask: post = all new get NDC; pre = valid) ----
        dist_mask = valid if cfg.mode == "pre" else is_new
        ndc_add = dist_mask.sum(axis=1).astype(jnp.int32)
        insp_add = is_new.sum(axis=1).astype(jnp.int32)
        valid_add = valid.sum(axis=1).astype(jnp.int32)
        cnt = state.cnt + jnp.where(act, ndc_add, 0)
        n_inspected = state.n_inspected + jnp.where(act, insp_add, 0)
        n_valid_visited = state.n_valid_visited + jnp.where(act, valid_add, 0)
        n_clause_valid = state.n_clause_valid + jnp.where(
            act[:, None], clause_add, 0)
        n_pop_valid = state.n_pop_valid + jnp.where(act & u_valid, 1, 0)
        q_err_sum = state.q_err_sum + jnp.where(act, err_add, 0.0)
        hops = state.hops + jnp.where(act, 1, 0)

        # ---- convergence tracking for W_q ground truth ----
        if gt_dist is not None:
            covered = jnp.all(res_dist <= gt_dist + 1e-6, axis=1)
            first = (state.conv_cnt < 0) & covered
            conv_cnt = jnp.where(first, cnt, state.conv_cnt)
        else:
            conv_cnt = state.conv_cnt

        # ---- NDC at which the result set filled (feature) ----
        now_full = jnp.isfinite(res_dist[:, -1]) & act
        first_full = (state.res_full_cnt < 0) & now_full
        res_full_cnt = jnp.where(first_full, cnt, state.res_full_cnt)

        # ---- lane masking: inactive lanes keep their old arrays ----
        am = act[:, None]
        return SearchState(
            cand_dist=jnp.where(am, cand_dist, state.cand_dist),
            cand_idx=jnp.where(am, cand_idx, state.cand_idx),
            cand_exp=jnp.where(am, cand_exp2, cand_exp),
            cand_valid=jnp.where(am, cand_valid, state.cand_valid),
            res_dist=jnp.where(am, res_dist, state.res_dist),
            res_idx=jnp.where(am, res_idx, state.res_idx),
            visited=jnp.where(am, visited, state.visited),
            cnt=cnt,
            n_inspected=n_inspected,
            n_valid_visited=n_valid_visited,
            n_clause_valid=n_clause_valid,
            n_pop_valid=n_pop_valid,
            q_err_sum=q_err_sum,
            hops=hops,
            active=act,
            d_start=state.d_start,
            conv_cnt=conv_cnt,
            res_full_cnt=res_full_cnt,
        )

    return step
