"""SearchEngine — shard-aware device-resident index + traversal facade.

Bundles the arrays every search needs (vectors, packed attributes, graph,
entry point), selects a traversal backend by name, and places everything on
a 1-D device mesh when more than one accelerator is visible:

  index data (base_vectors / neighbors / attrs)  replicated over the mesh
  per-query arrays (queries, compiled filter programs, budgets,
                    every SearchState buffer)     sharded over the batch axis

Filters are accepted in any of three forms — a legacy `FilterSpec` batch, a
sequence of filter-algebra expressions (`repro.filters.expr`), or an
already-compiled `FilterProgram` — and are lowered here to one compiled
program per batch, so the traversal layers below never branch on a
predicate kind. The engine keeps *one* attribute bundle (label words +
numeric channels) and always passes both: which attributes a clause reads
is part of the program, not of the engine call.

The lockstep while_loop contains no cross-lane collectives, so `shard_map`
over the batch axis runs one independent traversal per device — each shard
even gets its own trip count (lanes on a finished shard stop paying for
stragglers elsewhere). Partition specs reuse `distributed.sharding`
(`batch_spec`), keeping the logical-axis rules in one place.

Probe/resume/search entry points are unchanged from the pre-shard engine:
the E2E pipeline, baselines, benchmarks and serving only change at the
constructor (`SearchEngine.build(ds, graph, backend="pallas")`).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.search import (SearchConfig, SearchState, get_backend,
                               run_search, run_search_persistent)
from repro.core.state import init_state, pad_lanes  # noqa: F401  (re-export)
from repro.data.synthetic import AttributedDataset
from repro.distributed.sharding import batch_spec
from repro.filters.compile import FilterProgram, as_program
from repro.index.graph import GraphIndex

BIG_BUDGET = 1 << 30

BATCH_AXIS = "data"


def make_search_mesh(devices=None) -> Mesh | None:
    """1-D batch mesh over the visible devices; None on a single device."""
    devices = list(jax.devices()) if devices is None else list(devices)
    if len(devices) <= 1:
        return None
    return Mesh(np.asarray(devices), (BATCH_AXIS,))


# Shard padding shares the serving layer's lane-surgery helper: padded lanes
# self-deactivate on their 0 NDC budget, so the values never influence real
# lanes.
_pad_batch = pad_lanes


@dataclasses.dataclass
class SearchEngine:
    base_vectors: jnp.ndarray   # [N, d]
    label_attrs: jnp.ndarray    # [N, W] uint32
    value_attrs: jnp.ndarray    # [N, V] f32 (a bare [N] is accepted and
                                # treated as one channel)
    neighbors: jnp.ndarray      # [N, R]
    entry_point: int
    backend: str | None = None  # None → whatever SearchConfig carries
    mesh: Mesh | None = None    # None → single-device execution
    precision: str = "float32"  # deployment default ("float32"|"int8"|"pq");
                                # a per-call SearchConfig(precision=...) wins
    quant: object | None = None  # Int8Index | PQIndex (repro.quant) — the
                                # compressed vector store the traversal
                                # gathers from when precision != float32
    vector_store: object | None = None  # quant.tiering store for the exact
                                # rerank; when set (host tier), base_vectors
                                # is a [N, 0] placeholder — only its row
                                # count is read in compressed mode

    @classmethod
    def build(cls, ds: AttributedDataset, graph: GraphIndex,
              backend: str | None = None, mesh: Mesh | str | None = "auto",
              precision: str = "float32", quant_cfg: dict | None = None,
              tier: str = "device",
              ) -> "SearchEngine":
        """Construct a device-resident engine.

        backend    registered TraversalBackend name ("dense" | "pallas"),
                   used whenever the per-call SearchConfig doesn't set one;
                   an explicit SearchConfig(backend=...) always wins.
        mesh       "auto" builds a 1-D batch mesh when >1 device is visible;
                   pass an explicit Mesh (first axis = batch) or None to
                   force single-device placement.
        precision  "float32" (default, bit-identical to the pre-quant
                   engine), or "int8" / "pq" — trains the codec on a sample
                   of the dataset, encodes the full store, and evaluates
                   traversal distances in the compressed domain (exact
                   float32 rerank available via `rerank`).
        quant_cfg  codec knobs forwarded to quant.build_quant_index
                   (pq_subspaces, pq_centroids, pq_iters, pq_levels, seed)
                   plus "train_sample_size" for the codec-fitting sample.
        tier       "device" keeps float32 vectors device-resident;
                   "host" (requires a non-float32 precision) moves them to
                   a host-memory rerank tier (quant.tiering
                   .HostVectorStore) and leaves only a [N, 0] placeholder
                   on device — the compressed codes bound device memory,
                   not the float32 store.
        """
        graph.validate()
        if mesh == "auto":
            mesh = make_search_mesh()
        store = None
        vectors = jnp.asarray(ds.vectors)
        if tier != "device":
            from repro.quant.tiering import as_vector_store

            if precision == "float32":
                raise ValueError(
                    "tier='host' requires a compressed traversal precision "
                    "('int8' or 'pq') — a float32 traversal reads the full "
                    "vector store every step, which defeats the tier")
            store = as_vector_store(ds.vectors, tier)
            vectors = jnp.zeros((vectors.shape[0], 0), jnp.float32)
        eng = cls(
            base_vectors=vectors,
            label_attrs=jnp.asarray(ds.labels_packed),
            value_attrs=jnp.asarray(ds.value_matrix),
            neighbors=jnp.asarray(graph.neighbors),
            entry_point=graph.entry_point,
            backend=backend,
            mesh=mesh,
            precision=precision,
            vector_store=store,
        )
        if precision != "float32":
            from repro.quant import build_quant_index

            qcfg = dict(quant_cfg or {})
            sample_n = qcfg.pop("train_sample_size", 16384)
            sample = ds.sample_vectors(sample_n, seed=qcfg.get("seed", 0))
            eng.quant = build_quant_index(precision, ds.vectors,
                                          train_sample=sample, **qcfg)
        if mesh is not None:
            rep = NamedSharding(mesh, P())
            eng.base_vectors = jax.device_put(eng.base_vectors, rep)
            eng.label_attrs = jax.device_put(eng.label_attrs, rep)
            eng.value_attrs = jax.device_put(eng.value_attrs, rep)
            eng.neighbors = jax.device_put(eng.neighbors, rep)
            if eng.quant is not None:
                eng.quant = jax.device_put(eng.quant, rep)
        return eng

    @property
    def n_words(self) -> int:
        return int(self.label_attrs.shape[1])

    @property
    def n_values(self) -> int:
        return 1 if self.value_attrs.ndim == 1 else int(self.value_attrs.shape[1])

    def _attrs(self):
        """The uniform (labels, values[N, V]) bundle every search receives."""
        vals = self.value_attrs
        if vals.ndim == 1:  # hand-built engines may carry a single channel
            vals = vals[:, None]
        return self.label_attrs, vals

    def compile(self, filt) -> FilterProgram:
        """Lower FilterSpec | Expr | sequence[Expr] to a device program."""
        prog = as_program(filt, self.n_words, self.n_values)
        return FilterProgram(*(jnp.asarray(a) for a in prog))

    # ------------------------------------------------------------- quant ----
    def effective_precision(self, cfg: SearchConfig) -> str:
        """The precision a call with `cfg` runs at (per-call override wins)."""
        return cfg.precision or self.precision

    def codec_key(self, cfg: SearchConfig | None = None) -> str:
        """Codec identity for result caching ("float32" | "int8:…" | "pq:…").

        Precision changes answers (compressed-domain traversal order), so
        the serving cache folds this into every request key. Pass the
        call's `cfg` so a per-call precision override (e.g. a quantized
        engine served at float32) keys under the precision the searches
        actually run at, not the engine default.
        """
        from repro.quant import codec_key

        prec = self.precision if cfg is None else self.effective_precision(cfg)
        return codec_key(prec, self.quant)

    def rerank_arrays(self, queries, state: SearchState):
        """Exact float32 re-scoring of a finished traversal's candidate pool.

        Returns (res_dist [B, K], res_idx [B, K]) — the compressed-domain
        pool (result set ∪ valid candidate queue) re-ranked against the
        retained full-precision vectors. Constant ≤ (M+K) float32 distance
        computations per query, not counted into `state.cnt`.
        """
        from repro.quant import exact_rerank, exact_rerank_store

        if self.vector_store is not None:
            return exact_rerank_store(jnp.asarray(queries, jnp.float32),
                                      self.vector_store, state.cand_idx,
                                      state.cand_valid, state.res_idx,
                                      int(state.res_idx.shape[1]))
        return exact_rerank(jnp.asarray(queries, jnp.float32),
                            self.base_vectors, state.cand_idx,
                            state.cand_valid, state.res_idx,
                            int(state.res_idx.shape[1]))

    def rerank(self, cfg: SearchConfig, queries, state: SearchState,
               ) -> SearchState:
        """Terminal exact-rerank: replace the result buffers with float32
        re-scored top-k. No-op at float32 precision. The returned state
        must not be resumed (results are exact, queue stays compressed)."""
        if self.effective_precision(cfg) == "float32":
            return state
        rd, ri = self.rerank_arrays(queries, state)
        return state._replace(res_dist=rd, res_idx=ri)

    def search(
        self,
        cfg: SearchConfig,
        queries: np.ndarray,
        filt,                         # FilterSpec | Expr(s) | FilterProgram
        budgets,                      # scalar or [B]
        state: SearchState | None = None,
        gt_dist: np.ndarray | None = None,
        tracer=None,                  # obs.Tracer | None — persistent driver
        trace_id: str = "",           # spans only; never enters traced code
    ) -> SearchState:
        cfg = dataclasses.replace(cfg, degree=int(self.neighbors.shape[1]))
        if cfg.backend is None:
            # engine default applies only when the call doesn't pick one:
            # an explicit SearchConfig(backend=...) always wins.
            cfg = dataclasses.replace(cfg, backend=self.backend or "dense")
        # same inheritance rule for precision: per-call override wins,
        # None inherits the engine's deployment default
        cfg = dataclasses.replace(cfg, precision=self.effective_precision(cfg))
        if cfg.precision != "float32" and self.quant is None:
            raise ValueError(
                f"SearchConfig(precision={cfg.precision!r}) on an engine "
                "without a quant index — build with precision=...")
        if cfg.precision == "float32" and self.base_vectors.shape[1] == 0:
            raise ValueError(
                "float32 traversal on a host-tiered engine: the device "
                "holds only a vector placeholder — search at the engine's "
                "compressed precision (rerank stays exact via the host "
                "tier) or rebuild with tier='device'")
        quant = self.quant if cfg.precision != "float32" else None
        prog = self.compile(filt)
        attrs = self._attrs()
        q = jnp.asarray(queries, jnp.float32)
        b = q.shape[0]
        budgets = jnp.broadcast_to(jnp.asarray(budgets, jnp.int32), (b,))
        gt = None if gt_dist is None else jnp.asarray(gt_dist, jnp.float32)
        if self.mesh is None:
            # Persistent backends go through the eager launch-loop driver:
            # same bit-exact results, but finished lanes are compacted away
            # between multi-step launches instead of riding as no-ops (and
            # on TPU each launch is the VMEM-resident multi-step kernel).
            # Under a mesh the traced run_search handles persistence via its
            # launch-grouped loop — host compaction can't cross shard_map.
            if getattr(get_backend(cfg.backend), "persistent", False):
                return run_search_persistent(
                    cfg, q, prog, self.base_vectors, attrs, self.neighbors,
                    budgets, self.entry_point, state=state, gt_dist=gt,
                    quant=quant, tracer=tracer, trace_id=trace_id,
                )
            return run_search(
                cfg, q, prog, self.base_vectors, attrs, self.neighbors,
                budgets, self.entry_point, state=state, gt_dist=gt,
                quant=quant,
            )
        return self._search_sharded(cfg, q, prog, attrs, budgets, state, gt,
                                    quant)

    # ---------------------------------------------------------- sharded ----
    def _search_sharded(self, cfg, q, prog, attrs, budgets, state, gt,
                        quant=None):
        from jax.experimental.shard_map import shard_map

        mesh = self.mesh
        ndev = int(np.prod(list(mesh.shape.values())))
        b = q.shape[0]
        pad = (-b) % ndev
        bspec = batch_spec(mesh, b + pad)
        if bspec == P(None):
            # explicit mesh whose axis names the sharding rule table doesn't
            # know — shard over the first axis rather than silently
            # replicating the whole batch on every device. (b + pad is a
            # multiple of ndev, hence of the first-axis size.)
            bspec = P(mesh.axis_names[0])
        rep = P()

        q = _pad_batch(q, pad)
        # program rows pad with all-zero (match-nothing) clauses — inert
        # under the 0 NDC budget the pad lanes carry
        prog = _pad_batch(prog, pad)
        budgets = _pad_batch(budgets, pad)  # 0-budget lanes stop immediately
        state = None if state is None else _pad_batch(state, pad)
        gt = None if gt is None else _pad_batch(gt, pad)

        args = [q, prog, self.base_vectors, attrs, self.neighbors, budgets]
        specs = [bspec, bspec, rep, rep, rep, bspec]
        has_state, has_gt = state is not None, gt is not None
        has_quant = quant is not None
        if has_state:
            args.append(state)
            specs.append(bspec)
        if has_gt:
            args.append(gt)
            specs.append(bspec)
        if has_quant:
            args.append(quant)      # index data: replicated like the vectors
            specs.append(rep)

        entry = self.entry_point

        def fn(*a):
            qq, qa, base, at, nb, bud = a[:6]
            st = a[6] if has_state else None
            g = a[6 + has_state] if has_gt else None
            qt = a[6 + has_state + has_gt] if has_quant else None
            return run_search(cfg, qq, qa, base, at, nb, bud, entry,
                              state=st, gt_dist=g, quant=qt)

        out = shard_map(
            fn, mesh=mesh, in_specs=tuple(specs), out_specs=bspec,
            check_rep=False,
        )(*args)
        if pad:
            out = jax.tree.map(lambda a: a[:b], out)
        return out
