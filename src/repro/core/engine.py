"""SearchEngine — shard-aware device-resident index + traversal facade.

Bundles the arrays every search needs (vectors, packed attributes, graph,
entry point), selects a traversal backend by name, and places everything on
a 1-D device mesh when more than one accelerator is visible:

  index data (base_vectors / neighbors / attrs)  replicated over the mesh
  per-query arrays (queries, compiled filter programs, budgets,
                    every SearchState buffer)     sharded over the batch axis

Filters are accepted in any of three forms — a legacy `FilterSpec` batch, a
sequence of filter-algebra expressions (`repro.filters.expr`), or an
already-compiled `FilterProgram` — and are lowered here to one compiled
program per batch, so the traversal layers below never branch on a
predicate kind. The engine keeps *one* attribute bundle (label words +
numeric channels) and always passes both: which attributes a clause reads
is part of the program, not of the engine call.

The lockstep while_loop contains no cross-lane collectives, so `shard_map`
over the batch axis runs one independent traversal per device — each shard
even gets its own trip count (lanes on a finished shard stop paying for
stragglers elsewhere). Partition specs reuse `distributed.sharding`
(`batch_spec`), keeping the logical-axis rules in one place.

Probe/resume/search entry points are unchanged from the pre-shard engine:
the E2E pipeline, baselines, benchmarks and serving only change at the
constructor (`SearchEngine.build(ds, graph, backend="pallas")`).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.search import SearchConfig, SearchState, run_search
from repro.core.state import init_state, pad_lanes  # noqa: F401  (re-export)
from repro.data.synthetic import AttributedDataset
from repro.distributed.sharding import batch_spec
from repro.filters.compile import FilterProgram, as_program
from repro.index.graph import GraphIndex

BIG_BUDGET = 1 << 30

BATCH_AXIS = "data"


def make_search_mesh(devices=None) -> Mesh | None:
    """1-D batch mesh over the visible devices; None on a single device."""
    devices = list(jax.devices()) if devices is None else list(devices)
    if len(devices) <= 1:
        return None
    return Mesh(np.asarray(devices), (BATCH_AXIS,))


# Shard padding shares the serving layer's lane-surgery helper: padded lanes
# self-deactivate on their 0 NDC budget, so the values never influence real
# lanes.
_pad_batch = pad_lanes


@dataclasses.dataclass
class SearchEngine:
    base_vectors: jnp.ndarray   # [N, d]
    label_attrs: jnp.ndarray    # [N, W] uint32
    value_attrs: jnp.ndarray    # [N, V] f32 (a bare [N] is accepted and
                                # treated as one channel)
    neighbors: jnp.ndarray      # [N, R]
    entry_point: int
    backend: str | None = None  # None → whatever SearchConfig carries
    mesh: Mesh | None = None    # None → single-device execution

    @classmethod
    def build(cls, ds: AttributedDataset, graph: GraphIndex,
              backend: str | None = None, mesh: Mesh | str | None = "auto",
              ) -> "SearchEngine":
        """Construct a device-resident engine.

        backend  registered TraversalBackend name ("dense" | "pallas"),
                 used whenever the per-call SearchConfig doesn't set one;
                 an explicit SearchConfig(backend=...) always wins.
        mesh     "auto" builds a 1-D batch mesh when >1 device is visible;
                 pass an explicit Mesh (first axis = batch) or None to
                 force single-device placement.
        """
        if mesh == "auto":
            mesh = make_search_mesh()
        eng = cls(
            base_vectors=jnp.asarray(ds.vectors),
            label_attrs=jnp.asarray(ds.labels_packed),
            value_attrs=jnp.asarray(ds.value_matrix),
            neighbors=jnp.asarray(graph.neighbors),
            entry_point=graph.entry_point,
            backend=backend,
            mesh=mesh,
        )
        if mesh is not None:
            rep = NamedSharding(mesh, P())
            eng.base_vectors = jax.device_put(eng.base_vectors, rep)
            eng.label_attrs = jax.device_put(eng.label_attrs, rep)
            eng.value_attrs = jax.device_put(eng.value_attrs, rep)
            eng.neighbors = jax.device_put(eng.neighbors, rep)
        return eng

    @property
    def n_words(self) -> int:
        return int(self.label_attrs.shape[1])

    @property
    def n_values(self) -> int:
        return 1 if self.value_attrs.ndim == 1 else int(self.value_attrs.shape[1])

    def _attrs(self):
        """The uniform (labels, values[N, V]) bundle every search receives."""
        vals = self.value_attrs
        if vals.ndim == 1:  # hand-built engines may carry a single channel
            vals = vals[:, None]
        return self.label_attrs, vals

    def compile(self, filt) -> FilterProgram:
        """Lower FilterSpec | Expr | sequence[Expr] to a device program."""
        prog = as_program(filt, self.n_words, self.n_values)
        return FilterProgram(*(jnp.asarray(a) for a in prog))

    def search(
        self,
        cfg: SearchConfig,
        queries: np.ndarray,
        filt,                         # FilterSpec | Expr(s) | FilterProgram
        budgets,                      # scalar or [B]
        state: SearchState | None = None,
        gt_dist: np.ndarray | None = None,
    ) -> SearchState:
        cfg = dataclasses.replace(cfg, degree=int(self.neighbors.shape[1]))
        if cfg.backend is None:
            # engine default applies only when the call doesn't pick one:
            # an explicit SearchConfig(backend=...) always wins.
            cfg = dataclasses.replace(cfg, backend=self.backend or "dense")
        prog = self.compile(filt)
        attrs = self._attrs()
        q = jnp.asarray(queries, jnp.float32)
        b = q.shape[0]
        budgets = jnp.broadcast_to(jnp.asarray(budgets, jnp.int32), (b,))
        gt = None if gt_dist is None else jnp.asarray(gt_dist, jnp.float32)
        if self.mesh is None:
            return run_search(
                cfg, q, prog, self.base_vectors, attrs, self.neighbors,
                budgets, self.entry_point, state=state, gt_dist=gt,
            )
        return self._search_sharded(cfg, q, prog, attrs, budgets, state, gt)

    # ---------------------------------------------------------- sharded ----
    def _search_sharded(self, cfg, q, prog, attrs, budgets, state, gt):
        from jax.experimental.shard_map import shard_map

        mesh = self.mesh
        ndev = int(np.prod(list(mesh.shape.values())))
        b = q.shape[0]
        pad = (-b) % ndev
        bspec = batch_spec(mesh, b + pad)
        if bspec == P(None):
            # explicit mesh whose axis names the sharding rule table doesn't
            # know — shard over the first axis rather than silently
            # replicating the whole batch on every device. (b + pad is a
            # multiple of ndev, hence of the first-axis size.)
            bspec = P(mesh.axis_names[0])
        rep = P()

        q = _pad_batch(q, pad)
        # program rows pad with all-zero (match-nothing) clauses — inert
        # under the 0 NDC budget the pad lanes carry
        prog = _pad_batch(prog, pad)
        budgets = _pad_batch(budgets, pad)  # 0-budget lanes stop immediately
        state = None if state is None else _pad_batch(state, pad)
        gt = None if gt is None else _pad_batch(gt, pad)

        args = [q, prog, self.base_vectors, attrs, self.neighbors, budgets]
        specs = [bspec, bspec, rep, rep, rep, bspec]
        has_state, has_gt = state is not None, gt is not None
        if has_state:
            args.append(state)
            specs.append(bspec)
        if has_gt:
            args.append(gt)
            specs.append(bspec)

        entry = self.entry_point

        def fn(*a):
            qq, qa, base, at, nb, bud = a[:6]
            st = a[6] if has_state else None
            g = a[6 + has_state] if has_gt else None
            return run_search(cfg, qq, qa, base, at, nb, bud, entry,
                              state=st, gt_dist=g)

        out = shard_map(
            fn, mesh=mesh, in_specs=tuple(specs), out_specs=bspec,
            check_rep=False,
        )(*args)
        if pad:
            out = jax.tree.map(lambda a: a[:b], out)
        return out
