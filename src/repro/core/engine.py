"""SearchEngine — device-resident index + attribute store + traversal facade.

Bundles the arrays every search needs (vectors, packed attributes, graph,
entry point) and exposes probe/resume/search entry points used by the E2E
pipeline, baselines, benchmarks and the serving layer.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.search import SearchConfig, SearchState, init_state, run_search
from repro.data.synthetic import AttributedDataset
from repro.filters.predicates import FilterSpec, PRED_RANGE
from repro.index.graph import GraphIndex

BIG_BUDGET = 1 << 30


@dataclasses.dataclass
class SearchEngine:
    base_vectors: jnp.ndarray   # [N, d]
    label_attrs: jnp.ndarray    # [N, W] uint32
    value_attrs: jnp.ndarray    # [N] f32
    neighbors: jnp.ndarray      # [N, R]
    entry_point: int

    @classmethod
    def build(cls, ds: AttributedDataset, graph: GraphIndex) -> "SearchEngine":
        return cls(
            base_vectors=jnp.asarray(ds.vectors),
            label_attrs=jnp.asarray(ds.labels_packed),
            value_attrs=jnp.asarray(ds.values),
            neighbors=jnp.asarray(graph.neighbors),
            entry_point=graph.entry_point,
        )

    def _attr_args(self, spec: FilterSpec):
        if spec.kind == PRED_RANGE:
            return self.value_attrs, (jnp.asarray(spec.range_lo), jnp.asarray(spec.range_hi))
        return self.label_attrs, jnp.asarray(spec.label_masks)

    def search(
        self,
        cfg: SearchConfig,
        queries: np.ndarray,
        spec: FilterSpec,
        budgets,                      # scalar or [B]
        state: SearchState | None = None,
        gt_dist: np.ndarray | None = None,
    ) -> SearchState:
        cfg = dataclasses.replace(cfg, degree=int(self.neighbors.shape[1]))
        attrs, q_attr = self._attr_args(spec)
        q = jnp.asarray(queries, jnp.float32)
        b = q.shape[0]
        budgets = jnp.broadcast_to(jnp.asarray(budgets, jnp.int32), (b,))
        gt = None if gt_dist is None else jnp.asarray(gt_dist, jnp.float32)
        return run_search(
            cfg, q, q_attr, self.base_vectors, attrs, self.neighbors,
            budgets, self.entry_point, state=state, gt_dist=gt,
        )
