"""Adaptive per-query planning across filter-execution strategies.

The paper's adaptive-termination estimator answers "how long should this
traversal run?". The planner generalizes the question to "which execution
strategy should this query use at all?" — per lane, between three plans:

  scan      pre-filter: bitmap + masked exact (or ADC) distance over the
            σ_q·N passing rows (core/plans.py). Cost is closed-form
            (σ_q·N·c_dist), recall is 1.0 by construction.
  traverse  the standard E2E pipeline: probe → GBDT Ŵ_q → resume.
  widen     filtered-expansion traversal (cfg.mode="widen"): the same
            pipeline but resuming with the ACORN-style widened frontier,
            for lanes whose valid sub-graph disconnects under 1-hop.

Routing happens in two stages so that clearly-scannable lanes never pay
the probe (which would otherwise dominate their cost — the probe is "zero
overhead" only for lanes that end up traversing):

  stage 0 (pre-probe)   the filter bitmap is compiled anyway (the scan
            plan needs it and it costs 0 NDC), which makes σ_q *exact*
            before any distance work. A static GBDT head — trained on
            bitmap/program features only — predicts the traversal cost;
            lanes with σ_q·N·c ≤ Ŵ_static (or σ_q·N under the scan floor)
            route straight to scan.
  stage 1 (post-probe)  surviving lanes run the shared probe prefix once;
            per-plan GBDT heads predict Ŵ_traverse and Ŵ_widen from the
            same trajectory features, and each lane takes
            argmin{probe_cnt + σ_q·N·c, Ŵ_traverse, Ŵ_widen}. A lane the
            static head mis-kept falls back to scan here ("late scan"),
            carrying its probe counters into the scan state.

Both heads share one probe: plan choice costs zero extra NDC beyond what
the chosen plan would have spent anyway (scan-routed lanes spend the
probe prefix only when stage 0 mispredicts, which stage 1 bounds).

`force_plan` pins every lane to one plan through the identical machinery —
tests/test_planner.py asserts bitwise equality (counters included) against
`run_plan`, which composes the corresponding single-plan pipeline directly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.e2e import predict_budgets, probe_and_features
from repro.core.engine import BIG_BUDGET, SearchEngine
from repro.core.estimator import CostEstimator
from repro.core.plans import ScanStats, scan_search, scan_stats
from repro.core.search import SearchConfig, SearchState
from repro.core.state import concat_lanes, take_lanes
from repro.data.synthetic import AttributedDataset, QueryWorkload
from repro.index.bruteforce import filtered_knn_exact

PLANS = ("scan", "traverse", "widen")
PLAN_SCAN, PLAN_TRAVERSE, PLAN_WIDEN = range(3)

STATIC_FEATURE_NAMES = [
    "sigma", "log_sigma_n",
    "clause_frac_0", "clause_frac_1", "clause_frac_2", "clause_frac_3",
    "n_slots", "n_terms",
]


def static_features(stats: ScanStats, prog) -> np.ndarray:
    """Pre-probe features [B, 8]: exact bitmap selectivity + program shape.

    Everything here is available before any distance computation — the
    stage-0 head may only see what costs 0 NDC. All-finite by construction
    (match-nothing lanes give sigma=0, log1p(0)=0)."""
    sig = stats.sigma.astype(np.float32)
    return np.stack([
        sig,
        np.log1p(sig * stats.n).astype(np.float32),
        *[stats.clause_frac[:, i] for i in range(stats.clause_frac.shape[1])],
        np.asarray(prog.active).sum(axis=1).astype(np.float32),
        np.asarray(prog.term_active).sum(axis=1).astype(np.float32),
    ], axis=1)


@dataclasses.dataclass
class Planner:
    """Per-plan cost heads + the scan plan's closed-form cost model."""

    traverse: CostEstimator          # probe features → W_traverse
    widen: CostEstimator             # probe features → W_widen
    static: CostEstimator            # static_features → W_traverse (stage 0)
    scan_dist_cost: float = 1.0      # c: scan-NDC ≡ traversal-NDC exchange rate
    scan_floor: int = 128            # σ·N at/below which scan always wins
                                     # (≈ 2× probe budget: cheaper than probing)


@dataclasses.dataclass
class PlanTrainingData:
    """Dual-exhaustion labels from one shared probe per query."""

    features: np.ndarray         # [n, F] probe trajectory features
    static_feats: np.ndarray     # [n, 8]
    w_traverse: np.ndarray       # [n] exhaustion/convergence NDC, post mode
    w_widen: np.ndarray          # [n] same, widen-mode resume
    converged_t: np.ndarray      # [n] bool
    converged_w: np.ndarray      # [n] bool
    sigma: np.ndarray            # [n] exact bitmap selectivity
    gt_idx: np.ndarray           # [n, k]
    gt_dist: np.ndarray          # [n, k]


def generate_plan_training_data(
    engine: SearchEngine,
    ds: AttributedDataset,
    workload: QueryWorkload,
    cfg: SearchConfig,
    probe_budget: int = 64,
    chunk: int = 64,
    n_probes: int = 2,
) -> PlanTrainingData:
    """Per query: one probe, two exhaustion resumes (post + widen).

    Both resumes continue the *same* probe carry, so each plan's label is
    the total NDC of "probe prefix + that plan's continuation" — exactly
    the quantity the router compares at serve time. Compressed engines
    judge convergence in the compressed metric (see core.training)."""
    compressed = engine.effective_precision(cfg) != "float32"
    cfg_w = dataclasses.replace(cfg, mode="widen")
    n = workload.batch
    out = {f.name: [] for f in dataclasses.fields(PlanTrainingData)}
    for s in range(0, n, chunk):
        e = min(s + chunk, n)
        q = workload.queries[s:e]
        filt = workload.filter_slice(s, e)
        # ground truth from the dataset, not the engine's device arrays —
        # host-tiered / index-sharded engines hold placeholders or slices
        gt_idx, gt_dist = filtered_knn_exact(
            q, np.asarray(ds.vectors), filt,
            np.asarray(ds.labels_packed), np.asarray(ds.value_matrix),
            cfg.k)
        if compressed:
            from repro.index.bruteforce import valid_mask
            from repro.quant import compressed_filtered_topk

            ok = valid_mask(filt, np.asarray(ds.labels_packed),
                            np.asarray(ds.value_matrix))
            conv_dist, _ = compressed_filtered_topk(
                engine.effective_precision(cfg),
                getattr(engine, "quant_concat", None) or engine.quant,
                q, ok, cfg.k)
        else:
            conv_dist = gt_dist
        prog = engine.compile(filt)
        stats = scan_stats(engine, prog)
        st, z = probe_and_features(engine, cfg, q, prog, probe_budget,
                                   n_probes, gt_dist=conv_dist)
        labels = {}
        for key, c, carry in (("t", cfg, st), ("w", cfg_w, st)):
            # search donates the resume carry — hand each plan its own copy
            # so the shared probe state survives the first resume
            fin = engine.search(c, q, prog, BIG_BUDGET,
                                state=jax.tree.map(jnp.copy, carry),
                                gt_dist=conv_dist)
            cc = np.asarray(fin.conv_cnt)
            conv = cc > 0
            labels[key] = (np.where(conv, cc, np.asarray(fin.cnt))
                           .astype(np.int64), conv)
        out["features"].append(np.asarray(z))
        out["static_feats"].append(static_features(stats, prog))
        out["w_traverse"].append(labels["t"][0])
        out["converged_t"].append(labels["t"][1])
        out["w_widen"].append(labels["w"][0])
        out["converged_w"].append(labels["w"][1])
        out["sigma"].append(stats.sigma)
        out["gt_idx"].append(gt_idx)
        out["gt_dist"].append(gt_dist)
    return PlanTrainingData(**{k: np.concatenate(v) for k, v in out.items()})


def fit_planner(data: PlanTrainingData, probe_budget: int = 64,
                scan_dist_cost: float = 1.0, **gbdt_kwargs) -> Planner:
    """Fit the three cost heads. The static head regresses the *traverse*
    label from pre-probe features only — it exists to catch lanes where
    even a pessimistic traversal estimate exceeds the exact scan cost."""
    tr = CostEstimator.fit(data.features, data.w_traverse, **gbdt_kwargs)
    wd = CostEstimator.fit(data.features, data.w_widen, **gbdt_kwargs)
    st = CostEstimator.fit(data.static_feats, data.w_traverse, **gbdt_kwargs)
    return Planner(traverse=tr, widen=wd, static=st,
                   scan_dist_cost=scan_dist_cost,
                   scan_floor=2 * probe_budget)


# ---- routing ---------------------------------------------------------------

def stage0_scan_mask(planner: Planner, stats: ScanStats, prog, alpha: float,
                     min_budget: int, max_budget: int,
                     packed=None) -> np.ndarray:
    """[B] bool — lanes routed to scan before (instead of) the probe."""
    sf = static_features(stats, prog)
    w_static, _ = predict_budgets(planner.static, jnp.asarray(sf), alpha,
                                  min_budget, max_budget, packed=packed)
    scan_cost = stats.counts.astype(np.float64) * planner.scan_dist_cost
    return ((scan_cost <= np.asarray(w_static)) |
            (stats.counts <= planner.scan_floor))


def choose_plans(planner: Planner, feats, probe_cnt: np.ndarray,
                 counts: np.ndarray, alpha: float, min_budget: int,
                 max_budget: int, packed_t=None, packed_w=None):
    """Post-probe per-lane argmin over predicted total NDC.

    Returns (plan_ids [B] int, w_traverse [B], w_widen [B]). Ties break
    toward the earlier plan in PLANS order — scan first, because its
    recall is exact at equal predicted cost."""
    w_t, _ = predict_budgets(planner.traverse, feats, alpha, min_budget,
                             max_budget, packed=packed_t)
    w_w, _ = predict_budgets(planner.widen, feats, alpha, min_budget,
                             max_budget, packed=packed_w)
    w_t = np.asarray(w_t).astype(np.int64)
    w_w = np.asarray(w_w).astype(np.int64)
    scan_total = probe_cnt.astype(np.int64) + np.ceil(
        counts * planner.scan_dist_cost).astype(np.int64)
    table = np.stack([scan_total, w_t, w_w], axis=1)
    return np.argmin(table, axis=1).astype(np.int32), w_t, w_w


@dataclasses.dataclass
class PlanResult:
    state: SearchState
    plan: np.ndarray              # [B] i32 — index into PLANS
    sigma: np.ndarray             # [B] exact bitmap selectivity
    pre_probe: np.ndarray         # [B] bool — routed at stage 0 (no probe)
    predicted_budget: np.ndarray  # [B] — chosen plan's predicted/closed-form
                                  # total NDC (σ·N·c for scan lanes)
    reports: list | None = None   # explain=True: [B] obs.QueryReport

    def plan_names(self) -> list[str]:
        return [PLANS[p] for p in self.plan]


def planned_search(
    engine: SearchEngine,
    planner: Planner,
    cfg: SearchConfig,
    queries: np.ndarray,
    filt,
    probe_budget: int = 64,
    n_probes: int = 2,
    alpha: float = 1.0,
    min_budget: int = 32,
    max_budget: int = BIG_BUDGET,
    force_plan: str | None = None,
    stats: ScanStats | None = None,
    tracer=None,
    trace_id: str = "",
    explain: bool = False,
) -> PlanResult:
    """Route each lane to its cheapest plan and execute. Terminal state
    (rerank applied on compressed engines) in the original lane order.

    `force_plan` pins all lanes to one plan — bitwise-equal (counters
    included) to `run_plan` with the same arguments.

    `tracer` spans the router stages (stage0 routing, shared probe via
    `probe_and_features`, plan-select, per-plan execution, rerank) at host
    dispatch boundaries only; `explain=True` builds one `obs.QueryReport`
    per lane in `PlanResult.reports` with the route each lane took."""
    from repro.core.search import dispatch_counters, get_backend
    from repro.obs.trace import as_tracer

    tr = as_tracer(tracer)
    if tracer is not None and not trace_id:
        trace_id = tr.new_trace("plan")
    prog = engine.compile(filt)
    if stats is None:
        stats = scan_stats(engine, prog)
    queries = np.asarray(queries, np.float32)
    b = queries.shape[0]
    counts = stats.counts
    d0 = dispatch_counters()
    n_exec_calls = 0

    plan = np.full(b, -1, np.int32)
    pre_probe = np.zeros(b, bool)
    pred = np.zeros(b, np.int64)

    if force_plan is not None:
        if force_plan not in PLANS:
            raise ValueError(f"force_plan must be one of {PLANS}, "
                             f"got {force_plan!r}")
        plan[:] = PLANS.index(force_plan)

    # ---- stage 0: pre-probe routing (exact σ + static cost head) ----
    if force_plan is None:
        with tr.span("plan-stage0", trace_id, lanes=b):
            s0 = stage0_scan_mask(planner, stats, prog, alpha, min_budget,
                                  max_budget)
        plan[s0] = PLAN_SCAN
        pre_probe[:] = s0
    elif force_plan == "scan":
        pre_probe[:] = True
    scan_now = pre_probe.nonzero()[0]

    parts: list[tuple[np.ndarray, SearchState]] = []
    if scan_now.size:
        with tr.span("scan", trace_id, lanes=int(scan_now.size), late=False):
            sub = _scan_part(engine, cfg, queries, prog, stats, scan_now)
        n_exec_calls += 1
        pred[scan_now] = np.ceil(
            counts[scan_now] * planner.scan_dist_cost).astype(np.int64)
        parts.append((scan_now, sub))

    # ---- stage 1: shared probe + per-plan heads on the survivors ----
    rest = (~pre_probe).nonzero()[0]
    probe_ndc = np.zeros(b, np.int64)
    if rest.size:
        q_r = queries[rest]
        prog_r = prog.slice(rest)
        carry, feats = probe_and_features(engine, cfg, q_r, prog_r,
                                          probe_budget, n_probes,
                                          tracer=tracer, trace_id=trace_id)
        probe_cnt = np.asarray(carry.cnt)
        probe_ndc[rest] = probe_cnt
        with tr.span("plan-select", trace_id, lanes=int(rest.size),
                     forced=force_plan or ""):
            if force_plan is None:
                ids, w_t, w_w = choose_plans(planner, feats, probe_cnt,
                                             counts[rest], alpha, min_budget,
                                             max_budget)
            else:
                ids = np.full(rest.size, PLANS.index(force_plan), np.int32)
                head = (planner.traverse if force_plan == "traverse"
                        else planner.widen)
                w, _ = predict_budgets(head, feats, alpha, min_budget,
                                       max_budget)
                w_t = w_w = np.asarray(w).astype(np.int64)
        plan[rest] = ids

        late = rest[ids == PLAN_SCAN]
        if late.size:
            sel = (ids == PLAN_SCAN).nonzero()[0]
            with tr.span("scan", trace_id, lanes=int(late.size), late=True):
                sub = _scan_part(engine, cfg, queries, prog, stats, late,
                                 base_state=take_lanes(carry, sel))
            n_exec_calls += 1
            pred[late] = (probe_cnt[sel] + np.ceil(
                counts[late] * planner.scan_dist_cost)).astype(np.int64)
            parts.append((late, sub))
        for pid, mode, w in ((PLAN_TRAVERSE, cfg.mode, w_t),
                             (PLAN_WIDEN, "widen", w_w)):
            lanes = rest[ids == pid]
            if not lanes.size:
                continue
            sel = (ids == pid).nonzero()[0]
            c = cfg if mode == cfg.mode else dataclasses.replace(cfg, mode=mode)
            with tr.span("resume", trace_id, plan=PLANS[pid],
                         lanes=int(lanes.size)):
                sub = engine.search(c, q_r[sel], prog_r.slice(sel), w[sel],
                                    state=take_lanes(carry, sel),
                                    tracer=tracer, trace_id=trace_id)
            n_exec_calls += 1
            pred[lanes] = w[sel]
            parts.append((lanes, sub))

    # ---- merge back into the original lane order ----
    perm = np.concatenate([idx for idx, _ in parts])
    inv = np.argsort(perm, kind="stable")
    state = take_lanes(concat_lanes([st for _, st in parts]), inv)
    with tr.span("rerank", trace_id,
                 precision=engine.effective_precision(cfg)):
        state = engine.rerank(cfg, queries, state)

    reports = None
    if explain:
        reports = _plan_reports(engine, cfg, state, plan, pred, pre_probe,
                                probe_ndc, trace_id, d0, n_exec_calls,
                                n_probes, probe_budget, get_backend,
                                dispatch_counters)
    return PlanResult(state=state, plan=plan, sigma=stats.sigma,
                      pre_probe=pre_probe, predicted_budget=pred,
                      reports=reports)


def _plan_reports(engine, cfg, state, plan, pred, pre_probe, probe_ndc,
                  trace_id, d0, n_exec_calls, n_probes, probe_budget,
                  get_backend, dispatch_counters):
    """Per-lane EXPLAIN reports for `planned_search` (host post-processing;
    reads the final counters back once — explain mode's documented cost)."""
    from repro.obs.explain import StageReport, build_reports

    backend_name = cfg.backend or engine.backend or "dense"
    if getattr(get_backend(backend_name), "persistent", False):
        total_l = dispatch_counters()["launches"] - d0["launches"]
    else:
        probe_calls = 0 if not (~pre_probe).any() else (
            1 if n_probes <= 1 else 2)
        total_l = probe_calls + n_exec_calls
    final_cnt = np.asarray(state.cnt)
    b = final_cnt.shape[0]
    names = [PLANS[p] for p in plan]
    stages = []
    for i in range(b):
        st = [StageReport("plan-stage0",
                          attrs=dict(pre_probe=bool(pre_probe[i])))]
        if not pre_probe[i]:
            st.append(StageReport("probe", ndc=int(probe_ndc[i]),
                                  attrs=dict(budget=int(probe_budget),
                                             n_probes=int(n_probes))))
            st.append(StageReport("plan-select",
                                  attrs=dict(plan=names[i])))
        exec_name = "scan" if plan[i] == PLAN_SCAN else "resume"
        st.append(StageReport(exec_name,
                              ndc=int(final_cnt[i] - probe_ndc[i]),
                              launches=total_l,
                              attrs=dict(plan=names[i])))
        st.append(StageReport("rerank", attrs=dict(
            precision=engine.effective_precision(cfg))))
        stages.append(st)
    reports = build_reports(
        cfg, state, pred, backend=backend_name, plans=names,
        probe_ndc=probe_ndc, trace_ids=[f"{trace_id or 'plan'}:{i}"
                                        for i in range(b)], stages=stages)
    if getattr(state, "shard", None) is not None:
        from repro.obs.shard import attach_shard_sections

        attach_shard_sections(reports, cfg, state, pred)
    # scan lanes terminate by construction (the masked scan is exhaustive
    # over the σ·N valid rows), not by any traversal stop condition —
    # globally and on every shard's slice of the bitmap
    for i, r in enumerate(reports):
        if plan[i] == PLAN_SCAN:
            r.termination = "scan-exhaustive"
            for sec in r.shards:
                sec.termination = "scan-exhaustive"
    return reports


def _scan_part(engine, cfg, queries, prog, stats, lanes, base_state=None):
    return scan_search(
        engine, cfg, queries[lanes], prog.slice(lanes),
        stats=ScanStats(valid=stats.valid[lanes], counts=stats.counts[lanes],
                        clause_frac=stats.clause_frac[lanes], n=stats.n),
        base_state=base_state)


def run_plan(
    engine: SearchEngine,
    planner: Planner,
    plan: str,
    cfg: SearchConfig,
    queries: np.ndarray,
    filt,
    probe_budget: int = 64,
    n_probes: int = 2,
    alpha: float = 1.0,
    min_budget: int = 32,
    max_budget: int = BIG_BUDGET,
) -> SearchState:
    """Execute one plan directly, bypassing the router — the structural
    reference `planned_search(force_plan=...)` is tested against."""
    prog = engine.compile(filt)
    queries = np.asarray(queries, np.float32)
    if plan == "scan":
        state = scan_search(engine, cfg, queries, prog)
    elif plan in ("traverse", "widen"):
        carry, feats = probe_and_features(engine, cfg, queries, prog,
                                          probe_budget, n_probes)
        head = planner.traverse if plan == "traverse" else planner.widen
        w, _ = predict_budgets(head, feats, alpha, min_budget, max_budget)
        c = cfg if plan == "traverse" else dataclasses.replace(cfg,
                                                               mode="widen")
        state = engine.search(c, queries, prog, w, state=carry)
    else:
        raise ValueError(f"unknown plan {plan!r} (one of {PLANS})")
    return engine.rerank(cfg, queries, state)
