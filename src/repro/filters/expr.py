"""Filter-expression algebra: composable boolean predicates over attributes.

The paper's traversal is predicate-agnostic (§2.1 Remark) — it only ever
consumes a boolean mask — but real filtered-ANNS workloads are dominated by
*composite* predicates (conjunctions/disjunctions of label and numeric
constraints; see PathFinder, arXiv 2511.00995, and the attribute-filtering
study, arXiv 2508.16263). This module is the user-facing algebra:

  leaves        Contain(labels)   L ⊆ A_i        (all listed labels present)
                Equal(labels)     L = A_i        (label set exactly equal)
                In(labels)        L ∩ A_i ≠ ∅    (at least one present)
                Range(lo, hi, attr)  value_attr[attr] ∈ [lo, hi]
  combinators   And(*), Or(*), Not(x)

Expressions are immutable and hashable. They are *lowered*, never
interpreted at search time: `canonical_dnf` rewrites any expression into a
sorted, deduplicated disjunctive normal form (negations pushed to the
leaves), which `filters.compile` turns into a fixed-shape `FilterProgram`
that a whole heterogeneous batch evaluates in one vectorized pass.

Canonicalization is semantic up to commutativity: `And(a, b)` and
`And(b, a)` produce the same DNF (and therefore the same compiled program
bytes and the same serving-cache key), while `And(a, b)` vs `Or(a, b)`
stay distinct.

`eval_expr` is the naive recursive host oracle (numpy, no DNF, no
compilation) used by selectivity, the brute-force ground truth, and the
compiled-program parity tests.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np

# Clause (leaf) kind tags shared with the compiled program representation.
CLAUSE_CONTAIN = 0
CLAUSE_EQUAL = 1
CLAUSE_RANGE = 2
CLAUSE_IN = 3


class Expr:
    """Base class; combinator sugar so filters compose as `a & b | ~c`."""

    __slots__ = ()

    def __and__(self, other: "Expr") -> "And":
        return And(self, other)

    def __or__(self, other: "Expr") -> "Or":
        return Or(self, other)

    def __invert__(self) -> "Not":
        return Not(self)


def _label_tuple(labels: Iterable[int]) -> tuple[int, ...]:
    out = tuple(sorted({int(x) for x in labels}))
    if any(x < 0 for x in out):
        raise ValueError(f"labels must be non-negative, got {out}")
    return out


@dataclasses.dataclass(frozen=True)
class Contain(Expr):
    """All listed labels present: L ⊆ A_i. Contain(()) is vacuously true."""

    labels: tuple[int, ...]

    def __init__(self, labels: Iterable[int]):
        object.__setattr__(self, "labels", _label_tuple(labels))


@dataclasses.dataclass(frozen=True)
class Equal(Expr):
    """Label set exactly equal: A_i = L."""

    labels: tuple[int, ...]

    def __init__(self, labels: Iterable[int]):
        object.__setattr__(self, "labels", _label_tuple(labels))


@dataclasses.dataclass(frozen=True)
class In(Expr):
    """At least one listed label present: L ∩ A_i ≠ ∅. In(()) is false."""

    labels: tuple[int, ...]

    def __init__(self, labels: Iterable[int]):
        object.__setattr__(self, "labels", _label_tuple(labels))


@dataclasses.dataclass(frozen=True)
class Range(Expr):
    """Numeric attribute channel `attr` inside the closed interval [lo, hi]."""

    lo: float
    hi: float
    attr: int = 0

    def __init__(self, lo: float, hi: float, attr: int = 0):
        object.__setattr__(self, "lo", float(lo))
        object.__setattr__(self, "hi", float(hi))
        object.__setattr__(self, "attr", int(attr))


@dataclasses.dataclass(frozen=True)
class And(Expr):
    children: tuple[Expr, ...]

    def __init__(self, *children: Expr):
        object.__setattr__(self, "children", tuple(children))


@dataclasses.dataclass(frozen=True)
class Or(Expr):
    children: tuple[Expr, ...]

    def __init__(self, *children: Expr):
        object.__setattr__(self, "children", tuple(children))


@dataclasses.dataclass(frozen=True)
class Not(Expr):
    child: Expr

    def __init__(self, child: Expr):
        object.__setattr__(self, "child", child)


_LEAF_TYPES = (Contain, Equal, In, Range)

# A literal is (leaf, negated); a term is a tuple of literals combined by
# AND; a DNF is a tuple of terms combined by OR. The empty term is TRUE,
# the empty DNF is FALSE.
Literal = tuple[Expr, bool]
Term = tuple[Literal, ...]
Dnf = tuple[Term, ...]


def _leaf_key(leaf: Expr) -> tuple:
    """Total order on leaves — drives the canonical literal/term sort."""
    if isinstance(leaf, Contain):
        return (CLAUSE_CONTAIN, leaf.labels, 0.0, 0.0, 0)
    if isinstance(leaf, Equal):
        return (CLAUSE_EQUAL, leaf.labels, 0.0, 0.0, 0)
    if isinstance(leaf, In):
        return (CLAUSE_IN, leaf.labels, 0.0, 0.0, 0)
    if isinstance(leaf, Range):
        return (CLAUSE_RANGE, (), leaf.lo, leaf.hi, leaf.attr)
    raise TypeError(f"not a filter leaf: {leaf!r}")


def _lit_key(lit: Literal) -> tuple:
    leaf, neg = lit
    return _leaf_key(leaf) + (bool(neg),)


def _to_dnf(e: Expr, neg: bool) -> Dnf:
    """Push negation to the leaves (De Morgan) while distributing AND over
    OR. Returns terms-of-literals; no simplification yet."""
    if isinstance(e, Not):
        return _to_dnf(e.child, not neg)
    if isinstance(e, (And, Or)):
        conjunctive = isinstance(e, And) ^ neg  # ¬(a∧b) = ¬a ∨ ¬b
        parts = [_to_dnf(c, neg) for c in e.children]
        if not conjunctive:
            return tuple(t for p in parts for t in p)
        out: list[Term] = [()]
        for p in parts:
            out = [t1 + t2 for t1 in out for t2 in p]
            if len(out) > 4096:
                raise ValueError("DNF expansion exceeds 4096 terms; "
                                 "restructure the filter expression")
        return tuple(out)
    if isinstance(e, _LEAF_TYPES):
        return (((e, neg),),)
    raise TypeError(f"not a filter expression: {e!r}")


def canonical_dnf(e: Expr) -> Dnf:
    """Sorted, deduplicated DNF with negation pushed to the leaves.

    Commutative rewrites collapse (And(a,b) == And(b,a)); contradictory
    terms (x ∧ ¬x) are dropped; an always-true term collapses the whole
    DNF to the single empty term. The result is the *identity* of the
    filter for compilation and for serving-cache keys.
    """
    terms = []
    for term in _to_dnf(e, False):
        lits = sorted(set(term), key=_lit_key)
        if any((leaf, not neg) in lits for leaf, neg in lits):
            continue  # x AND NOT x — statically false term
        if not lits:
            return ((),)  # one TRUE term subsumes everything
        terms.append(tuple(lits))
    dedup = sorted(set(terms), key=lambda t: tuple(map(_lit_key, t)))
    return tuple(dedup)


def canonical_key(e: Expr) -> bytes:
    """Stable byte serialization of the canonical DNF (cache-key preimage).

    Floats serialize via their exact hex form, so two ranges differing in
    the last ulp never alias; structure bytes keep And/Or/Not distinctions
    that share the same leaf multiset distinct.
    """
    parts = [b"dnf["]
    for term in canonical_dnf(e):
        parts.append(b"term(")
        for leaf, neg in term:
            kind, labels, lo, hi, attr = _leaf_key(leaf)
            parts.append(b"%d|%d|%s|%s|%s|%d;" % (
                kind, int(neg), ",".join(map(str, labels)).encode(),
                float(lo).hex().encode(), float(hi).hex().encode(), attr))
        parts.append(b")")
    parts.append(b"]")
    return b"".join(parts)


# ------------------------------------------------------------- host oracle ----
def _values_2d(values: np.ndarray) -> np.ndarray:
    v = np.asarray(values)
    return v[:, None] if v.ndim == 1 else v


def pack_mask(labels, n_words: int) -> np.ndarray:
    """[W] uint32 multi-hot mask for a label tuple — the single packing
    implementation shared by the host oracle and the program compiler."""
    mask = np.zeros(n_words, np.uint32)
    for lab in labels:
        if lab >= 32 * n_words:
            raise ValueError(f"label {lab} outside packed alphabet "
                             f"[0,{32 * n_words})")
        mask[lab // 32] |= np.uint32(1) << np.uint32(lab % 32)
    return mask


def eval_leaf(leaf: Expr, labels_packed: np.ndarray, values: np.ndarray,
              ) -> np.ndarray:
    """[N] bool — one leaf over the whole corpus (numpy, host)."""
    if isinstance(leaf, Range):
        v = _values_2d(values)[:, leaf.attr]
        return (v >= np.float32(leaf.lo)) & (v <= np.float32(leaf.hi))
    mask = pack_mask(leaf.labels, labels_packed.shape[-1])
    if isinstance(leaf, Contain):
        return ((labels_packed & mask) == mask).all(axis=-1)
    if isinstance(leaf, Equal):
        return (labels_packed == mask).all(axis=-1)
    if isinstance(leaf, In):
        return ((labels_packed & mask) != 0).any(axis=-1)
    raise TypeError(f"not a filter leaf: {leaf!r}")


def eval_expr(e: Expr, labels_packed: np.ndarray, values: np.ndarray,
              ) -> np.ndarray:
    """[N] bool — naive recursive evaluation (the parity/recall oracle).

    Deliberately structured nothing like the compiled path: no NNF, no DNF,
    no padding — plain recursive descent over the original expression.
    """
    if isinstance(e, And):
        out = np.ones(labels_packed.shape[0], bool)
        for c in e.children:
            out &= eval_expr(c, labels_packed, values)
        return out
    if isinstance(e, Or):
        out = np.zeros(labels_packed.shape[0], bool)
        for c in e.children:
            out |= eval_expr(c, labels_packed, values)
        return out
    if isinstance(e, Not):
        return ~eval_expr(e.child, labels_packed, values)
    return eval_leaf(e, labels_packed, values)


def labels_from_mask(mask: np.ndarray) -> tuple[int, ...]:
    """Unpack a [W] uint32 multi-hot mask back into a sorted label tuple."""
    mask = np.asarray(mask, np.uint32).reshape(-1)
    out = []
    for w, word in enumerate(mask):
        word = int(word)
        while word:
            low = word & -word
            out.append(32 * w + low.bit_length() - 1)
            word ^= low
    return tuple(out)
