"""Filter-program compiler: expression batches → fixed-shape predicate programs.

A `FilterProgram` is the jit-traceable lowering of a *batch* of filter
expressions with arbitrary (heterogeneous) boolean structure. Per query it
holds S padded clause slots — one per DNF literal — and a flattened
combiner table assigning each slot to one of T conjunctive terms:

  kinds   [B, S]    i32   CLAUSE_CONTAIN | EQUAL | RANGE | IN
  masks   [B, S, W] u32   packed label mask (label clauses)
  lo/hi   [B, S]    f32   closed interval (range clauses)
  vattr   [B, S]    i32   numeric-attribute channel (range clauses)
  neg     [B, S]    bool  literal negation
  term    [B, S]    i32   owning DNF term
  active  [B, S]    bool  slot in use (padding slots are neutral)
  term_active [B, T] bool term in use (a query is valid iff any active
                          term has no failing literal)

Evaluation (`eval_program_gathered`) computes every primitive for every
slot and selects by kind tag — one vectorized pass, no Python branching —
then combines through the term table. A query batch mixing `And(a, b)`,
`Or(a, Not(b))`, and bare single predicates therefore shares one traced
computation, which is what lets the serving layer batch requests of
different boolean shape into the same lanes.

The per-slot satisfaction mask is also returned: the traversal accumulates
per-clause valid counters from it, giving the cost estimator clause-wise
probe selectivities (the paper's "attribute distribution" signal,
generalized from one ρ to one ρ per clause).

Inert encodings (used for lane padding): a program row with no active term
evaluates to False everywhere; `pad_program` produces such rows.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.filters.expr import (
    CLAUSE_CONTAIN,
    CLAUSE_EQUAL,
    CLAUSE_IN,
    CLAUSE_RANGE,
    Expr,
    canonical_dnf,
    pack_mask,
)
from repro.filters.predicates import (
    PRED_CONTAIN,
    PRED_EQUAL,
    PRED_RANGE,
    FilterSpec,
)

# Fixed number of clause slots tracked by the per-clause probe-selectivity
# counters (SearchState.n_clause_valid / the rho_clause_* features). A
# program may have more slots; counters cover the first few canonical ones.
CLAUSE_FEATURE_SLOTS = 4

# Hard ceiling on compiled slots — masks ride in uint32 words and the
# per-clause counter path packs slot bits into an int32 lane on TPU.
MAX_SLOTS = 32


class FilterProgram(NamedTuple):
    kinds: jax.Array        # [B, S] i32
    masks: jax.Array        # [B, S, W] u32
    lo: jax.Array           # [B, S] f32
    hi: jax.Array           # [B, S] f32
    vattr: jax.Array        # [B, S] i32
    neg: jax.Array          # [B, S] bool
    term: jax.Array         # [B, S] i32
    active: jax.Array       # [B, S] bool
    term_active: jax.Array  # [B, T] bool

    @property
    def batch(self) -> int:
        return int(self.kinds.shape[0])

    @property
    def n_slots(self) -> int:
        return int(self.kinds.shape[1])

    @property
    def n_terms(self) -> int:
        return int(self.term_active.shape[1])

    @property
    def n_words(self) -> int:
        return int(self.masks.shape[2])

    def slice(self, sl) -> "FilterProgram":
        return FilterProgram(*(np.asarray(a)[sl] for a in self))


def _leaf_slot(leaf: Expr, n_words: int, n_values: int):
    """(kind, mask, lo, hi, vattr) arrays for one literal's leaf."""
    from repro.filters.expr import Contain, Equal, In, Range

    if isinstance(leaf, Range):
        if leaf.attr >= n_values:
            raise ValueError(f"value channel {leaf.attr} outside [0,{n_values})")
        return (CLAUSE_RANGE, np.zeros(n_words, np.uint32),
                np.float32(leaf.lo), np.float32(leaf.hi), leaf.attr)
    kind = {Contain: CLAUSE_CONTAIN, Equal: CLAUSE_EQUAL, In: CLAUSE_IN}[type(leaf)]
    return (kind, pack_mask(leaf.labels, n_words), np.float32(0.0),
            np.float32(0.0), 0)


def compile_query(expr: Expr, n_words: int, n_values: int = 1):
    """One expression → per-query program rows (numpy, batch dim of 1).

    Slot order is the canonical DNF order, so equivalent expressions
    compile to identical rows (the serving cache and the feature extractor
    both rely on this determinism).
    """
    dnf = canonical_dnf(expr)
    n_slots = sum(len(t) for t in dnf)
    if n_slots > MAX_SLOTS:
        raise ValueError(f"filter compiles to {n_slots} clauses "
                         f"(max {MAX_SLOTS}); simplify the expression")
    s = max(1, n_slots)
    t = max(1, len(dnf))
    kinds = np.zeros((1, s), np.int32)
    masks = np.zeros((1, s, n_words), np.uint32)
    lo = np.zeros((1, s), np.float32)
    hi = np.zeros((1, s), np.float32)
    vattr = np.zeros((1, s), np.int32)
    neg = np.zeros((1, s), bool)
    term = np.zeros((1, s), np.int32)
    active = np.zeros((1, s), bool)
    term_active = np.zeros((1, t), bool)
    i = 0
    for ti, lits in enumerate(dnf):
        term_active[0, ti] = True
        for leaf, negated in lits:
            kinds[0, i], masks[0, i], lo[0, i], hi[0, i], vattr[0, i] = (
                _leaf_slot(leaf, n_words, n_values))
            neg[0, i] = negated
            term[0, i] = ti
            active[0, i] = True
            i += 1
    return FilterProgram(kinds, masks, lo, hi, vattr, neg, term, active,
                         term_active)


def pad_program(prog: FilterProgram, n_slots: int | None = None,
                n_terms: int | None = None, batch: int | None = None,
                ) -> FilterProgram:
    """Grow a program to (batch, n_slots, n_terms) with inert padding.

    Padding slots are inactive (never fail a term); padding terms are
    inactive (never validate a node); padding *rows* have no active term
    and therefore match nothing — exactly the serving layer's inert-lane
    contract (they also carry a 0 NDC budget).
    """
    b0, s0 = prog.kinds.shape
    t0 = prog.term_active.shape[1]
    s = s0 if n_slots is None else max(n_slots, s0)
    t = t0 if n_terms is None else max(n_terms, t0)
    b = b0 if batch is None else max(batch, b0)

    def grow(a, shape):
        a = np.asarray(a)
        out = np.zeros(shape, a.dtype)
        out[tuple(slice(0, d) for d in a.shape)] = a
        return out

    w = prog.masks.shape[2]
    return FilterProgram(
        kinds=grow(prog.kinds, (b, s)),
        masks=grow(prog.masks, (b, s, w)),
        lo=grow(prog.lo, (b, s)),
        hi=grow(prog.hi, (b, s)),
        vattr=grow(prog.vattr, (b, s)),
        neg=grow(prog.neg, (b, s)),
        term=grow(prog.term, (b, s)),
        active=grow(prog.active, (b, s)),
        term_active=grow(prog.term_active, (b, t)),
    )


def stack_programs(progs: Sequence[FilterProgram], n_slots: int | None = None,
                   n_terms: int | None = None, pad_to: int | None = None,
                   ) -> FilterProgram:
    """Stack per-query programs (batch 1 each) into one padded batch.

    Slot/term counts pad to the max across the batch (or the explicit
    minimums); `pad_to` appends inert match-nothing rows up to a lane
    width.
    """
    s = max([p.kinds.shape[1] for p in progs] + [n_slots or 1])
    t = max([p.term_active.shape[1] for p in progs] + [n_terms or 1])
    rows = [pad_program(p, s, t) for p in progs]
    cat = FilterProgram(*(np.concatenate([np.asarray(r[i]) for r in rows])
                          for i in range(len(rows[0]))))
    if pad_to is not None and pad_to > cat.batch:
        cat = pad_program(cat, batch=pad_to)
    return cat


def compile_filters(exprs: Sequence[Expr], n_words: int, n_values: int = 1,
                    n_slots: int | None = None, n_terms: int | None = None,
                    ) -> FilterProgram:
    """Compile a batch of (heterogeneous) expressions into one program."""
    return stack_programs([compile_query(e, n_words, n_values) for e in exprs],
                          n_slots, n_terms)


def compile_spec(spec, n_words: int, n_values: int = 1) -> FilterProgram:
    """Vectorized single-clause lowering of a legacy `FilterSpec` batch.

    Equivalent to `compile_filters(spec.to_expr(), ...)` but builds the
    arrays directly — the legacy entry points (benchmarks, training loops)
    call this per engine.search and should not pay a per-query Python loop.
    """
    b = spec.batch
    return FilterProgram(
        kinds=np.full((b, 1), _SPEC_KIND[spec.kind], np.int32),
        masks=(np.zeros((b, 1, n_words), np.uint32) if spec.kind == PRED_RANGE
               else np.asarray(spec.label_masks, np.uint32)[:, None, :]),
        lo=(np.asarray(spec.range_lo, np.float32)[:, None]
            if spec.kind == PRED_RANGE else np.zeros((b, 1), np.float32)),
        hi=(np.asarray(spec.range_hi, np.float32)[:, None]
            if spec.kind == PRED_RANGE else np.zeros((b, 1), np.float32)),
        vattr=np.zeros((b, 1), np.int32),
        neg=np.zeros((b, 1), bool),
        term=np.zeros((b, 1), np.int32),
        active=np.ones((b, 1), bool),
        term_active=np.ones((b, 1), bool),
    )


def as_program(filt, n_words: int, n_values: int = 1) -> FilterProgram:
    """Accept a FilterProgram | FilterSpec | Expr | sequence of Expr."""
    if isinstance(filt, FilterProgram):
        return filt
    if isinstance(filt, FilterSpec):
        return compile_spec(filt, n_words, n_values)
    if isinstance(filt, Expr):
        return compile_query(filt, n_words, n_values)
    return compile_filters(list(filt), n_words, n_values)


# ----------------------------------------------------------- evaluation ----
def eval_program_gathered(prog: FilterProgram, labels_g, values_g):
    """Evaluate the program on gathered per-candidate attributes.

    prog      leaves [B, S, ...] (device arrays)
    labels_g  [B, R, W] uint32 — candidate label masks
    values_g  [B, R, V] float32 — candidate numeric attributes
    returns   (valid [B, R] bool, clause_sat [B, S, R] bool)

    `valid` is the program's boolean output; `clause_sat` is per-slot
    literal satisfaction (active slots only) feeding the clause-wise
    selectivity counters. All four primitives are evaluated for every slot
    and selected by kind tag — branch-free and batch-uniform.
    """
    m = prog.masks[:, :, None, :]                       # [B,S,1,W]
    lg = labels_g[:, None, :, :]                        # [B,1,R,W]
    inter = jnp.bitwise_and(lg, m)
    c_contain = jnp.all(inter == m, axis=-1)            # [B,S,R]
    c_equal = jnp.all(lg == m, axis=-1)
    c_in = jnp.any(inter != 0, axis=-1)
    vat = jnp.clip(prog.vattr, 0, values_g.shape[-1] - 1)
    vsel = jnp.take_along_axis(
        values_g[:, None, :, :],                        # [B,1,R,V]
        vat[:, :, None, None], axis=-1)[..., 0]         # [B,S,R]
    c_range = (vsel >= prog.lo[:, :, None]) & (vsel <= prog.hi[:, :, None])

    k = prog.kinds[:, :, None]
    prim = jnp.where(
        k == CLAUSE_CONTAIN, c_contain,
        jnp.where(k == CLAUSE_EQUAL, c_equal,
                  jnp.where(k == CLAUSE_RANGE, c_range, c_in)))
    act = prog.active[:, :, None]
    lit = jnp.logical_xor(prim, prog.neg[:, :, None])
    clause_sat = lit & act

    # combiner: a term fails iff any of its literals fails; valid iff any
    # active term survives. One [B,S,T]x[B,S,R] contraction, no branching.
    fail = (~lit) & act
    t = prog.term_active.shape[1]
    member = (prog.term[:, :, None] == jnp.arange(t, dtype=prog.term.dtype)[
        None, None, :]) & prog.active[:, :, None]       # [B,S,T]
    n_fail = jnp.einsum("bst,bsr->btr", member.astype(jnp.int32),
                        fail.astype(jnp.int32))
    term_ok = prog.term_active[:, :, None] & (n_fail == 0)
    return jnp.any(term_ok, axis=1), clause_sat


@jax.jit
def _matrix_chunk(prog: FilterProgram, labels, values):
    """One N-chunk of the full-store evaluation: (valid [B,nb], clause
    counts [B, CLAUSE_FEATURE_SLOTS])."""
    b = prog.kinds.shape[0]
    nb = labels.shape[0]
    lg = jnp.broadcast_to(labels[None], (b, nb, labels.shape[1]))
    vg = jnp.broadcast_to(values[None], (b, nb, values.shape[1]))
    valid, csat = eval_program_gathered(prog, lg, vg)
    return valid, clause_counts(csat, jnp.ones_like(valid))


def eval_program_matrix(prog: FilterProgram, labels, values,
                        chunk: int = 2048):
    """Evaluate a program batch against the *full* attribute store.

    prog leaves [B, S, ...]; labels [N, W] u32; values [N, V] f32 →
    (valid [B, N] bool, clause_frac [B, CLAUSE_FEATURE_SLOTS] f32).

    This is the scan plan's candidate-bitmap compiler and the planner's
    exact per-query selectivity source: `valid.sum(1)/N` is σ_q with no
    sampling error, and `clause_frac` is the *global* analogue of the
    probe's rho_clause_* features (clause satisfaction over the whole
    store instead of over the probe's inspected set). Chunked over N
    because eval_program_gathered materializes [B, S, nb, W]
    intermediates. Boolean evaluation only — no distances, so per the
    repo's NDC accounting (predicate evaluations are tracked separately
    in n_inspected) compiling the bitmap costs 0 NDC, like every other
    predicate evaluation in the traversal. Results are exact and
    per-lane independent: lane b's row depends only on its own program
    row, which the serving layer's batch-composition guarantees rely on.
    """
    labels = jnp.asarray(labels)
    values = jnp.asarray(values)
    if values.ndim == 1:
        values = values[:, None]
    n = labels.shape[0]
    outs, counts = [], None
    for s in range(0, n, chunk):
        valid, cc = _matrix_chunk(prog, labels[s:s + chunk],
                                  values[s:s + chunk])
        outs.append(np.asarray(valid))
        counts = cc if counts is None else counts + cc
    valid = np.concatenate(outs, axis=1)
    frac = np.asarray(counts, np.float32) / float(n)
    return valid, frac


def clause_counts(clause_sat, counted, n_slots: int = CLAUSE_FEATURE_SLOTS):
    """Per-clause hit counters over the counted (inspected-new) candidates.

    clause_sat [B, S, R] bool, counted [B, R] bool -> [B, n_slots] i32,
    truncating/zero-padding the program's S slots to the fixed feature
    width.
    """
    cs = (clause_sat & counted[:, None, :]).sum(-1).astype(jnp.int32)  # [B,S]
    s = cs.shape[1]
    if s >= n_slots:
        return cs[:, :n_slots]
    return jnp.pad(cs, ((0, 0), (0, n_slots - s)))


# legacy FilterSpec predicate tags → compiled clause kinds
_SPEC_KIND = {PRED_CONTAIN: CLAUSE_CONTAIN, PRED_EQUAL: CLAUSE_EQUAL,
              PRED_RANGE: CLAUSE_RANGE}
