"""Filter predicates for attributed vector datasets (paper §2.1).

Label sets are stored as packed multi-hot bitmasks (uint32 words) so that
containment / equality tests are pure bitwise ops — O(W) per item with
W = ceil(|alphabet| / 32), fully vectorizable on TPU VPU lanes.

Numeric attributes are plain float32 scalars; range predicates are two
comparisons.

All predicate functions are jnp-traceable and broadcast over arbitrary
leading batch dimensions:

  item_labels:  [..., W] uint32
  query_mask:   [W]      uint32  (or [..., W] broadcastable)
  item_value:   [...]    float32
  query_range:  (lo, hi) scalars (or broadcastable arrays)

The search engine is *predicate-agnostic* (paper §2.1 Remark): it only ever
consumes the boolean output of `evaluate_predicate`, so composite filters can
be added by composing these primitives.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

# Predicate type tags (static ints so they can be closed over by jit).
PRED_CONTAIN = 0  # L_q ⊆ A_i
PRED_EQUAL = 1    # L_q = A_i
PRED_RANGE = 2    # A_i ∈ [lo, hi]


def pack_labels(label_sets: Sequence[Sequence[int]], alphabet_size: int) -> np.ndarray:
    """Pack per-item label sets into [N, W] uint32 multi-hot bitmasks."""
    n_words = max(1, (alphabet_size + 31) // 32)
    out = np.zeros((len(label_sets), n_words), dtype=np.uint32)
    for i, labels in enumerate(label_sets):
        for lab in labels:
            if not 0 <= lab < alphabet_size:
                raise ValueError(f"label {lab} outside alphabet [0,{alphabet_size})")
            out[i, lab // 32] |= np.uint32(1) << np.uint32(lab % 32)
    return out


def pack_query_labels(labels: Sequence[int], alphabet_size: int) -> np.ndarray:
    """Pack one query label set into a [W] uint32 mask."""
    return pack_labels([labels], alphabet_size)[0]


def predicate_contains(item_labels, query_mask):
    """L_q ⊆ A_i  ⇔  (A_i & L_q) == L_q, reduced over mask words."""
    hit = jnp.bitwise_and(item_labels, query_mask) == query_mask
    return jnp.all(hit, axis=-1)


def predicate_equals(item_labels, query_mask):
    """L_q = A_i exactly (all words equal)."""
    return jnp.all(item_labels == query_mask, axis=-1)


def predicate_range(item_value, lo, hi):
    """A_i ∈ [lo, hi] (closed interval)."""
    return jnp.logical_and(item_value >= lo, item_value <= hi)


@dataclasses.dataclass(frozen=True)
class FilterSpec:
    """A batched filter workload.

    Exactly one of (label_masks) or (range_lo, range_hi) is set, matching
    `kind`. Arrays carry a leading query-batch dimension [B, ...] so a batch
    of queries can each have a *different* filter.
    """

    kind: int  # PRED_CONTAIN | PRED_EQUAL | PRED_RANGE
    label_masks: np.ndarray | None = None  # [B, W] uint32
    range_lo: np.ndarray | None = None     # [B] float32
    range_hi: np.ndarray | None = None     # [B] float32

    @property
    def batch(self) -> int:
        if self.kind == PRED_RANGE:
            return int(self.range_lo.shape[0])
        return int(self.label_masks.shape[0])

    def slice(self, sl) -> "FilterSpec":
        if self.kind == PRED_RANGE:
            return FilterSpec(self.kind, None, self.range_lo[sl], self.range_hi[sl])
        return FilterSpec(self.kind, self.label_masks[sl], None, None)


def evaluate_predicate(kind: int, node_attr, query_attr, node_ids=None):
    """Evaluate predicate for a batch of queries against gathered node attrs.

    kind        static predicate tag
    node_attr   labels  [B, R, W] uint32   (gathered per-lane candidates)
                or vals [B, R]    float32
    query_attr  masks   [B, W] uint32  or (lo[B], hi[B]) tuple
    returns     [B, R] bool
    """
    if kind == PRED_CONTAIN:
        return predicate_contains(node_attr, query_attr[:, None, :])
    if kind == PRED_EQUAL:
        return predicate_equals(node_attr, query_attr[:, None, :])
    if kind == PRED_RANGE:
        lo, hi = query_attr
        return predicate_range(node_attr, lo[:, None], hi[:, None])
    raise ValueError(f"unknown predicate kind {kind}")


def selectivity(spec: FilterSpec, labels_packed: np.ndarray | None,
                values: np.ndarray | None) -> np.ndarray:
    """Global selectivity σ_global per query (paper Def. 2.6), on host."""
    if spec.kind == PRED_RANGE:
        v = values[None, :]  # [1, N]
        ok = (v >= spec.range_lo[:, None]) & (v <= spec.range_hi[:, None])
        return ok.mean(axis=1)
    masks = spec.label_masks[:, None, :]  # [B,1,W]
    items = labels_packed[None, :, :]     # [1,N,W]
    if spec.kind == PRED_CONTAIN:
        ok = ((items & masks) == masks).all(axis=-1)
    else:
        ok = (items == masks).all(axis=-1)
    return ok.mean(axis=1)
