"""Filter predicates for attributed vector datasets (paper §2.1).

Label sets are stored as packed multi-hot bitmasks (uint32 words) so that
containment / equality tests are pure bitwise ops — O(W) per item with
W = ceil(|alphabet| / 32), fully vectorizable on TPU VPU lanes.

Numeric attributes are plain float32 scalars; range predicates are two
comparisons.

All predicate functions are jnp-traceable and broadcast over arbitrary
leading batch dimensions:

  item_labels:  [..., W] uint32
  query_mask:   [W]      uint32  (or [..., W] broadcastable)
  item_value:   [...]    float32
  query_range:  (lo, hi) scalars (or broadcastable arrays)

The search engine is *predicate-agnostic* (paper §2.1 Remark): it only ever
consumes the boolean output of `evaluate_predicate`, so composite filters can
be added by composing these primitives.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

# Predicate type tags (static ints so they can be closed over by jit).
PRED_CONTAIN = 0  # L_q ⊆ A_i
PRED_EQUAL = 1    # L_q = A_i
PRED_RANGE = 2    # A_i ∈ [lo, hi]


def pack_labels(label_sets: Sequence[Sequence[int]], alphabet_size: int) -> np.ndarray:
    """Pack per-item label sets into [N, W] uint32 multi-hot bitmasks."""
    n_words = max(1, (alphabet_size + 31) // 32)
    out = np.zeros((len(label_sets), n_words), dtype=np.uint32)
    for i, labels in enumerate(label_sets):
        for lab in labels:
            if not 0 <= lab < alphabet_size:
                raise ValueError(f"label {lab} outside alphabet [0,{alphabet_size})")
            out[i, lab // 32] |= np.uint32(1) << np.uint32(lab % 32)
    return out


def pack_query_labels(labels: Sequence[int], alphabet_size: int) -> np.ndarray:
    """Pack one query label set into a [W] uint32 mask."""
    return pack_labels([labels], alphabet_size)[0]


def predicate_contains(item_labels, query_mask):
    """L_q ⊆ A_i  ⇔  (A_i & L_q) == L_q, reduced over mask words."""
    hit = jnp.bitwise_and(item_labels, query_mask) == query_mask
    return jnp.all(hit, axis=-1)


def predicate_equals(item_labels, query_mask):
    """L_q = A_i exactly (all words equal)."""
    return jnp.all(item_labels == query_mask, axis=-1)


def predicate_range(item_value, lo, hi):
    """A_i ∈ [lo, hi] (closed interval)."""
    return jnp.logical_and(item_value >= lo, item_value <= hi)


@dataclasses.dataclass(frozen=True)
class FilterSpec:
    """A batched filter workload.

    Exactly one of (label_masks) or (range_lo, range_hi) is set, matching
    `kind`. Arrays carry a leading query-batch dimension [B, ...] so a batch
    of queries can each have a *different* filter.
    """

    kind: int  # PRED_CONTAIN | PRED_EQUAL | PRED_RANGE
    label_masks: np.ndarray | None = None  # [B, W] uint32
    range_lo: np.ndarray | None = None     # [B] float32
    range_hi: np.ndarray | None = None     # [B] float32

    @property
    def batch(self) -> int:
        if self.kind == PRED_RANGE:
            return int(self.range_lo.shape[0])
        return int(self.label_masks.shape[0])

    def slice(self, sl) -> "FilterSpec":
        if self.kind == PRED_RANGE:
            return FilterSpec(self.kind, None, self.range_lo[sl], self.range_hi[sl])
        return FilterSpec(self.kind, self.label_masks[sl], None, None)

    def to_expr(self) -> list:
        """Lower the batch into per-query filter-algebra expressions.

        The constructor shim that lets every pre-algebra call site migrate
        mechanically: a FilterSpec is exactly a batch of single-leaf
        expressions, so `engine.search(cfg, q, spec, ...)` and
        `engine.search(cfg, q, spec.to_expr(), ...)` compile to the same
        single-clause predicate program.
        """
        from repro.filters.expr import Contain, Equal, Range, labels_from_mask

        if self.kind == PRED_RANGE:
            return [Range(float(lo), float(hi))
                    for lo, hi in zip(self.range_lo, self.range_hi)]
        leaf = Contain if self.kind == PRED_CONTAIN else Equal
        return [leaf(labels_from_mask(m)) for m in self.label_masks]


def evaluate_predicate(kind: int, node_attr, query_attr, node_ids=None):
    """Evaluate predicate for a batch of queries against gathered node attrs.

    kind        static predicate tag
    node_attr   labels  [B, R, W] uint32   (gathered per-lane candidates)
                or vals [B, R]    float32
    query_attr  masks   [B, W] uint32  or (lo[B], hi[B]) tuple
    returns     [B, R] bool
    """
    if kind == PRED_CONTAIN:
        return predicate_contains(node_attr, query_attr[:, None, :])
    if kind == PRED_EQUAL:
        return predicate_equals(node_attr, query_attr[:, None, :])
    if kind == PRED_RANGE:
        lo, hi = query_attr
        return predicate_range(node_attr, lo[:, None], hi[:, None])
    raise ValueError(f"unknown predicate kind {kind}")


def filter_matrix(filt, labels_packed: np.ndarray | None,
                  values: np.ndarray | None) -> np.ndarray:
    """[B, N] bool validity of every item under every query's filter.

    `filt` is a FilterSpec batch or a sequence of filter-algebra
    expressions. This is the host *oracle* shared by selectivity, the
    brute-force ground truth, and the compiled-program parity tests —
    deliberately naive (FilterSpec: broadcast bitwise ops; expressions:
    recursive `eval_expr` per query), nothing like the compiled path.

    Materializes [B, N(, W)] intermediates — callers with large B chunk
    over queries (see `selectivity`).
    """
    if isinstance(filt, FilterSpec):
        if filt.kind == PRED_RANGE:
            v = np.asarray(values)
            v = (v[:, 0] if v.ndim == 2 else v)[None, :]  # channel 0 [1, N]
            return (v >= filt.range_lo[:, None]) & (v <= filt.range_hi[:, None])
        masks = filt.label_masks[:, None, :]  # [B,1,W]
        items = labels_packed[None, :, :]     # [1,N,W]
        if filt.kind == PRED_CONTAIN:
            return ((items & masks) == masks).all(axis=-1)
        return (items == masks).all(axis=-1)
    from repro.filters.expr import eval_expr

    return np.stack([eval_expr(e, labels_packed, values) for e in filt])


def selectivity(filt, labels_packed: np.ndarray | None,
                values: np.ndarray | None, chunk: int = 64) -> np.ndarray:
    """Global selectivity σ_global per query (paper Def. 2.6), on host.

    Chunked over queries: the naive broadcast materializes a [B, N, W]
    boolean intermediate, which at benchmark scale (B≈1.5k, N≈10⁵) is
    gigabytes — evaluating `chunk` queries at a time bounds the peak at
    chunk·N·W while returning the identical result.
    """
    filt = list(filt) if not isinstance(filt, FilterSpec) else filt
    b = filt.batch if isinstance(filt, FilterSpec) else len(filt)
    out = np.empty(b, np.float64)
    for s in range(0, b, max(1, chunk)):
        e = min(s + chunk, b)
        part = filt.slice(slice(s, e)) if isinstance(filt, FilterSpec) else filt[s:e]
        out[s:e] = filter_matrix(part, labels_packed, values).mean(axis=1)
    return out
