from repro.filters.predicates import (
    FilterSpec,
    pack_labels,
    predicate_contains,
    predicate_equals,
    predicate_range,
    evaluate_predicate,
    PRED_CONTAIN,
    PRED_EQUAL,
    PRED_RANGE,
)

__all__ = [
    "FilterSpec",
    "pack_labels",
    "predicate_contains",
    "predicate_equals",
    "predicate_range",
    "evaluate_predicate",
    "PRED_CONTAIN",
    "PRED_EQUAL",
    "PRED_RANGE",
]
