"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run must set XLA_FLAGS
before the first jax device query.

Topology intent (TPU v5e):
  single-pod  (data=16, model=16)        = 256 chips
  multi-pod   (pod=2, data=16, model=16) = 512 chips; "pod" is the DCN axis
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — the "
            "dry-run entrypoint must set XLA_FLAGS="
            '"--xla_force_host_platform_device_count=512" before any jax import')
    try:
        return jax.make_mesh(shape, axes, devices=devices[:n])
    except TypeError:  # older make_mesh without devices kwarg
        from jax.sharding import Mesh

        return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires forced host device count)."""
    import jax

    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    from jax.sharding import Mesh

    return Mesh(np.asarray(devices).reshape(shape), axes)
