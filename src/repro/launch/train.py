"""Production training launcher.

On real TPU pods this runs under `jax.distributed.initialize()` with the
production mesh; on this container it runs any arch's `tiny()` config on
the host devices. Wires together: sharded init → jit(train_step) with
NamedShardings → checkpoint/restart (elastic) → straggler monitor.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --steps 50 --batch 8 --seq 64 [--full-config] [--resume]
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full arch config (TPU pods only)")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.distributed.fault_tolerance import StepMonitor, best_mesh_shape
    from repro.distributed.sharding import batch_spec, tree_shardings
    from repro.launch.mesh import make_test_mesh
    from repro.models import build_model, split_tree
    from repro.train.checkpoint import CheckpointManager
    from repro.train.optimizer import AdamWConfig
    from repro.train.train_step import TrainConfig, make_init_state, make_train_step
    from jax.sharding import NamedSharding

    cfg = get_arch(args.arch)
    if not args.full_config:
        cfg = cfg.tiny()
    model = build_model(cfg)
    tc = TrainConfig(opt=AdamWConfig(lr=args.lr), grad_accum=args.grad_accum)

    n_dev = len(jax.devices())
    shape, axes = best_mesh_shape(n_dev)
    mesh = make_test_mesh(shape, axes)
    print(f"mesh {dict(zip(axes, shape))} on {n_dev} device(s)")

    init = make_init_state(model, tc)
    state_abs = jax.eval_shape(init, jax.random.key(0))
    sds, ax = split_tree(state_abs)
    shardings = tree_shardings(mesh, sds, ax)

    mgr = CheckpointManager(args.ckpt_dir, keep=3)
    start = 0
    with mesh:
        if args.resume and mgr.latest_step() is not None:
            state, manifest = mgr.restore_latest(sds, shardings)
            start = manifest["step"]
            print(f"resumed (elastic reshard onto current mesh) from step {start}")
        else:
            state, _ = split_tree(jax.jit(init, out_shardings=shardings)(
                jax.random.key(0)))
            state = jax.tree.map(lambda x: x, state)  # realized

        step_fn = jax.jit(make_train_step(model, tc), donate_argnums=(0,))
        rng = np.random.default_rng(0)
        mon = StepMonitor()
        t0 = time.time()
        for i in range(start, args.steps):
            batch = {"tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (args.batch, args.seq)),
                jnp.int32)}
            if cfg.family in ("encdec", "vlm"):
                se = cfg.encoder_seq if cfg.family == "encdec" else cfg.vision_seq
                batch["enc"] = jnp.asarray(
                    0.02 * rng.standard_normal((args.batch, se, cfg.d_model)),
                    cfg.compute_dtype)
            mon.start()
            state, metrics = step_fn(state, batch)
            ev = mon.stop()
            if ev:
                print(f"[straggler] step {ev.step}: {ev.duration:.2f}s "
                      f"(median {ev.median:.2f}s) — rollback candidates ready")
            if (i + 1) % 10 == 0:
                print(f"step {i+1:4d} loss={float(metrics['loss']):.4f} "
                      f"({(time.time()-t0)/(i+1-start):.2f}s/step)")
            if (i + 1) % args.ckpt_every == 0:
                path = mgr.save(i + 1, state)
                print(f"checkpoint -> {path}")
    print("done")


if __name__ == "__main__":
    main()
