"""Production serving launcher — thin client of the `repro.serve` subsystem.

Mixed (contain + range) filtered-AKNN requests flow through the cost-aware
scheduler: admission with backpressure → shared probe → GBDT cost estimate →
budget-bucketed micro-batches → resume/requeue on the carried SearchState.
Easy queries complete in short-budget batches instead of waiting on the
hardest lane of a fixed batch; hard queries are routed (or time-sliced) into
long-budget batches. This replaces the old fixed-batch loop whose
`clamp_budgets` call ran *after* the search had already finished — its
output was computed and discarded; budget bounding now happens where it
belongs, in the scheduler's bucket routing, before any resume work runs.

Optionally (--gen-len > 0) the retrieved doc ids condition a tiny decoder LM,
the paper's filtered-RAG deployment story.

    PYTHONPATH=src python -m repro.launch.serve --requests 64 --batch 16
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np


def build_world(corpus: int, train_queries: int, queue_size: int, k: int,
                probe: int, backend: str | None, seed: int = 0,
                precision: str = "float32", n_shards: int = 1):
    """Index + graph + engine + a single estimator trained on a *mixed*
    contain/range workload (features are predicate-agnostic, so one GBDT
    serves both request kinds). `precision` deploys the engine with a
    compressed vector store (int8 / pq) — the estimator is then trained on
    the same engine, so its cost model sees compressed-domain probes, and
    the scheduler reranks every finished lane with exact float32.
    `n_shards > 1` deploys an index-axis-sharded engine (core.sharded)
    with one independent graph per corpus slice; the estimator is trained
    on that same sharded engine, so it models the ⌈W/S⌉-split cost."""
    import dataclasses

    from repro.core import (CostEstimator, SearchConfig, SearchEngine,
                            generate_training_data)
    from repro.data import make_dataset, make_label_workload, make_range_workload
    from repro.filters.predicates import PRED_CONTAIN, PRED_RANGE
    from repro.index import build_graph_index

    # equal contiguous slices require S | N
    corpus = -(-corpus // max(n_shards, 1)) * max(n_shards, 1)
    ds = make_dataset(n=corpus, dim=48, n_clusters=16, alphabet_size=48,
                      seed=seed)
    if n_shards > 1:
        from repro.core.sharded import ShardedSearchEngine
        from repro.index.builder import build_sharded_graph_index

        sgraph = build_sharded_graph_index(np.asarray(ds.vectors), n_shards,
                                           degree=24, seed=seed)
        graph = sgraph
        engine = ShardedSearchEngine.build(ds, sgraph, backend=backend,
                                           mesh=None, precision=precision)
    else:
        graph = build_graph_index(ds.vectors, degree=24, seed=seed)
        engine = SearchEngine.build(ds, graph, backend=backend,
                                    precision=precision)
    cfg = SearchConfig(k=k, queue_size=queue_size, pred_kind=PRED_CONTAIN)

    half = train_queries // 2
    feats, w_q = [], []
    for kind, pred in (("contain", PRED_CONTAIN), ("range", PRED_RANGE)):
        wl = (make_label_workload(ds, batch=half, kind=kind, seed=7)
              if kind == "contain" else
              make_range_workload(ds, batch=half, seed=8))
        td = generate_training_data(
            engine, ds, wl, dataclasses.replace(cfg, pred_kind=pred),
            probe_budget=probe, chunk=128)
        feats.append(td.features)
        w_q.append(td.w_q)
    est = CostEstimator.fit(np.concatenate(feats), np.concatenate(w_q),
                            n_trees=120, depth=5)
    return ds, graph, engine, cfg, est


def mixed_requests(ds, n: int, seed: int = 100, hard_fraction: float = 0.5):
    """Interleaved contain/range requests (heterogeneous difficulty)."""
    from repro.data import make_label_workload, make_range_workload
    from repro.serve import requests_from_workload

    wl_c = make_label_workload(ds, batch=(n + 1) // 2, kind="contain",
                               hard_fraction=hard_fraction, seed=seed)
    wl_r = make_range_workload(ds, batch=n // 2,
                               hard_fraction=hard_fraction, seed=seed + 1)
    reqs = (requests_from_workload(wl_c, start_rid=0)
            + requests_from_workload(wl_r, start_rid=wl_c.batch))
    rng = np.random.default_rng(seed)
    rng.shuffle(reqs)
    return reqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16,
                    help="micro-batch lane width")
    ap.add_argument("--alpha", type=float, default=1.5)
    ap.add_argument("--buckets", default="256,1024,4096",
                    help="ascending NDC bucket caps (a final unbounded "
                         "bucket is always appended)")
    ap.add_argument("--policy", default="direct",
                    choices=["direct", "escalate"])
    ap.add_argument("--probe", type=int, default=64)
    ap.add_argument("--queue-capacity", type=int, default=None,
                    help="admission bound; default admits the whole "
                         "--requests stream (pass a smaller value to "
                         "demonstrate load shedding)")
    ap.add_argument("--corpus", type=int, default=6000)
    ap.add_argument("--train-queries", type=int, default=256)
    ap.add_argument("--queue-size", type=int, default=128,
                    help="search beam width M")
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--gen-len", type=int, default=0,
                    help="decode this many tokens per request with a tiny "
                         "LM over the retrieved ids (0 = retrieval only)")
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--precision", default="float32",
                    choices=["float32", "int8", "pq"],
                    help="engine vector-store precision: compressed-domain "
                         "traversal + exact float32 rerank on completion")
    ap.add_argument("--shards", type=int, default=1,
                    help="index-axis shards (>1 deploys core.sharded: "
                         "per-shard traversal at ceil(W/S) budgets + "
                         "cross-shard merge; per-shard skew telemetry "
                         "shows up in --status and --prometheus)")
    ap.add_argument("--status", action="store_true",
                    help="print the structured JSON health report (queue, "
                         "shard skew, calibration, drift alarms) after "
                         "the run")
    ap.add_argument("--explain", type=int, default=0, metavar="N",
                    help="trace request lifecycles and print the first N "
                         "served timelines (admit → probe → resume slices "
                         "→ complete)")
    ap.add_argument("--trace-out", default=None,
                    help="stream lifecycle spans to this JSONL file")
    ap.add_argument("--prometheus", action="store_true",
                    help="print a Prometheus text-format scrape (serving + "
                         "calibration metrics) after the run")
    args = ap.parse_args()

    from repro.obs import Tracer
    from repro.serve import CostAwareScheduler, ServeConfig

    print("== index + estimator bring-up")
    ds, graph, engine, cfg, est = build_world(
        args.corpus, args.train_queries, args.queue_size, args.k, args.probe,
        backend=os.environ.get("REPRO_BACKEND", "pallas"),
        precision=args.precision, n_shards=args.shards)
    if args.shards > 1:
        print(f"   index-axis sharded: {engine.n_shards} shards x "
              f"{engine.shard_size} rows")
    if args.precision != "float32":
        from repro.quant import store_ratio

        print(f"   quantized store ({engine.codec_key()}): "
              f"{store_ratio(engine.quant, engine.base_vectors):.1f}x "
              "smaller than float32")

    buckets = tuple(int(x) for x in args.buckets.split(",") if x) + (None,)
    # the launcher submits the whole stream before pumping, so the default
    # admission bound must cover it — otherwise an idle system sheds load
    capacity = (args.queue_capacity if args.queue_capacity is not None
                else max(512, args.requests))
    scfg = ServeConfig(lane_width=args.batch, buckets=buckets,
                       policy=args.policy, probe_budget=args.probe,
                       alpha=args.alpha, queue_capacity=capacity)
    t0 = time.perf_counter()
    # the tracer shares the launcher's relative clock, so span timestamps
    # line up with request arrival/completion times in the timelines below
    tracer = (Tracer(clock=lambda: time.perf_counter() - t0,
                     sink=args.trace_out)
              if (args.explain or args.trace_out) else None)
    sched = CostAwareScheduler(engine, est, cfg, scfg, tracer=tracer)

    print(f"== serving {args.requests} mixed contain/range requests "
          f"(lanes={args.batch}, buckets={buckets}, policy={args.policy})")
    reqs = mixed_requests(ds, args.requests)
    for r in reqs:
        sched.submit(r, time.perf_counter() - t0)
    sched.run_until_idle(time.perf_counter() - t0)

    s = sched.summary()
    lat, ndc = s["latency"], s["ndc"]
    print(f"retrieval: p50/p95/p99 = {1e3*lat['p50']:.1f}/"
          f"{1e3*lat['p95']:.1f}/{1e3*lat['p99']:.1f} ms  "
          f"NDC p50/p95/p99 = {ndc['p50']:.0f}/{ndc['p95']:.0f}/"
          f"{ndc['p99']:.0f}")
    print(f"batches={s['n_batches']} requeues={s['n_requeues']} "
          f"shed={s['n_shed']} cache_hit_rate="
          f"{s['cache']['hit_rate']:.2f} queue_depth_max="
          f"{s['queue_depth_max']} launches={s['launches_total']}")

    rep = sched.calibration_report()
    if rep and rep["n_records"]:
        plans = " ".join(f"{k}:{v['n']}(win={v['win_rate']:.2f})"
                         for k, v in rep["per_plan"].items())
        print(f"calibration: n={rep['n_records']} "
              f"log_rmse={rep['log_rmse']:.3f} over/under="
              f"{rep['overprediction_rate']:.2f}/"
              f"{rep['underprediction_rate']:.2f}  {plans}")

    if args.explain:
        print(f"== lifecycle timelines (first {args.explain} requests)")
        for r in reqs[: args.explain]:
            print(f"request {r.rid} [{r.trace_id}] "
                  f"plan={r.plan or 'traverse'} budget={r.budget} "
                  f"ndc={r.ndc} probe_ndc={r.probe_ndc} "
                  f"slices={r.n_slices} cache_hit={r.cache_hit}")
            for sp in tracer.spans(trace_id=r.trace_id):
                extras = "".join(f"  {k}={v}" for k, v in sp.attrs.items()
                                 if k != "rid")
                t = (f" (+{1e3 * sp.duration:.1f}ms)"
                     if sp.duration > 0 else "")
                print(f"  {1e3 * (sp.t0 - (r.arrival or 0.0)):8.1f}ms "
                      f"{sp.name}{t}{extras}")
    if tracer is not None:
        tracer.close()

    if args.status:
        import json

        print("== serving health")
        print(json.dumps(sched.status(), indent=2, sort_keys=True))

    if args.prometheus:
        print("== prometheus scrape")
        print(sched.prometheus(), end="")

    if args.gen_len > 0:
        _generate(args, reqs)


def _generate(args, reqs):
    """Filtered-RAG tail: retrieved ids condition a tiny decoder LM."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.models import build_model, split_tree
    from repro.models.transformer import _pad_cache_seq

    mcfg = get_arch(args.arch).tiny()
    model = build_model(mcfg)
    prm, _ = split_tree(model.init_params(jax.random.key(0)))
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    done = [r for r in reqs if r.res_idx is not None]
    if not done:
        print("generation: skipped (no served requests)")
        return
    b = len(done)
    doc_ids = np.stack([np.abs(r.res_idx) % mcfg.vocab_size for r in done])
    prompts = np.random.default_rng(0).integers(0, mcfg.vocab_size, (b, 8))
    tokens = jnp.asarray(np.concatenate([doc_ids, prompts], axis=1),
                         jnp.int32)
    t0 = time.perf_counter()
    logits, part = prefill(prm, {"tokens": tokens})
    cache, _ = split_tree(model.init_cache(b, tokens.shape[1] + args.gen_len))
    cache = _pad_cache_seq(cache, part)
    cur = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
    pos = jnp.full((b,), tokens.shape[1], jnp.int32)
    for t in range(args.gen_len - 1):
        logits, cache = decode(prm, cache, cur, pos + t, None)
        cur = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(cur)
    dt = time.perf_counter() - t0
    print(f"generation: {1e3*dt/b:.1f} ms/req ({args.gen_len} tokens)")


if __name__ == "__main__":
    main()
