"""Production serving launcher: filtered-RAG request loop.

Batches of (query vector, filter) requests flow through the E2E engine
(probe → cost estimate → adaptive termination) with batch-tail clamping;
retrieved doc ids condition a decoder LM (tiny config on this container).
Reports per-stage latency and the NDC distribution — the deployment
configuration the paper targets.

    PYTHONPATH=src python -m repro.launch.serve --requests 64 --batch 16
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--alpha", type=float, default=1.5)
    ap.add_argument("--gen-len", type=int, default=8)
    ap.add_argument("--corpus", type=int, default=8000)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.core import (CostEstimator, SearchConfig, SearchEngine,
                            e2e_search, generate_training_data)
    from repro.data import make_dataset, make_label_workload
    from repro.distributed.fault_tolerance import clamp_budgets
    from repro.filters.predicates import PRED_CONTAIN
    from repro.index import build_graph_index
    from repro.models import build_model, split_tree
    from repro.models.transformer import _pad_cache_seq

    print("== index + estimator bring-up")
    ds = make_dataset(n=args.corpus, dim=48, n_clusters=16, alphabet_size=48,
                      seed=0)
    graph = build_graph_index(ds.vectors, degree=24, seed=0)
    engine = SearchEngine.build(ds, graph,
                                backend=os.environ.get("REPRO_BACKEND", "pallas"))
    cfg = SearchConfig(k=4, queue_size=256, pred_kind=PRED_CONTAIN)
    wl_tr = make_label_workload(ds, batch=384, kind="contain", seed=7)
    td = generate_training_data(engine, ds, wl_tr, cfg, probe_budget=64,
                                chunk=128)
    est = CostEstimator.fit(td.features, td.w_q, n_trees=150, depth=5)

    mcfg = get_arch(args.arch).tiny()
    model = build_model(mcfg)
    prm, _ = split_tree(model.init_params(jax.random.key(0)))
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    print(f"== serving {args.requests} requests in batches of {args.batch}")
    lat_ret, lat_gen, ndcs, clamped_total = [], [], [], 0
    for s in range(0, args.requests, args.batch):
        b = min(args.batch, args.requests - s)
        wl = make_label_workload(ds, batch=b, kind="contain", seed=100 + s)
        t0 = time.perf_counter()
        r = e2e_search(engine, est, cfg, wl.queries, wl.spec, probe_budget=64,
                       alpha=args.alpha)
        budgets, flagged = clamp_budgets(r.predicted_budget, quantile=0.95)
        clamped_total += int(flagged.sum())
        lat_ret.append(time.perf_counter() - t0)
        ndcs.extend(np.asarray(r.state.cnt).tolist())

        doc_ids = np.abs(np.asarray(r.state.res_idx)) % mcfg.vocab_size
        prompts = np.random.default_rng(s).integers(
            0, mcfg.vocab_size, (b, 8))
        tokens = jnp.asarray(np.concatenate([doc_ids, prompts], axis=1),
                             jnp.int32)
        t0 = time.perf_counter()
        logits, part = prefill(prm, {"tokens": tokens})
        cache, _ = split_tree(model.init_cache(b, tokens.shape[1] + args.gen_len))
        cache = _pad_cache_seq(cache, part)
        cur = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
        pos = jnp.full((b,), tokens.shape[1], jnp.int32)
        for t in range(args.gen_len - 1):
            logits, cache = decode(prm, cache, cur, pos + t, None)
            cur = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
        jax.block_until_ready(cur)
        lat_gen.append(time.perf_counter() - t0)

    ndcs = np.asarray(ndcs)
    print(f"retrieval: {1e3*np.mean(lat_ret)/args.batch:.1f} ms/req  "
          f"NDC p50/p95/p99 = {np.percentile(ndcs, 50):.0f}/"
          f"{np.percentile(ndcs, 95):.0f}/{np.percentile(ndcs, 99):.0f}  "
          f"clamped(hard-requeue)={clamped_total}")
    print(f"generation: {1e3*np.mean(lat_gen)/args.batch:.1f} ms/req "
          f"({args.gen_len} tokens)")


if __name__ == "__main__":
    main()
