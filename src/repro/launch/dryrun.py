import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-touching import: jax locks the device count on
# first backend init. Placeholder host devices exist ONLY for this dry-run.

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import subprocess    # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import SHAPES, applicable_shapes, get_arch, list_archs  # noqa: E402
from repro.distributed.sharding import batch_spec, tree_shardings  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import decode_inputs_specs, train_batch_specs  # noqa: E402
from repro.models import build_model, split_tree  # noqa: E402
from repro.train.optimizer import AdamWConfig  # noqa: E402
from repro.train.train_step import TrainConfig, make_init_state, make_train_step  # noqa: E402

OUT_DIR_DEFAULT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                               "experiments", "dryrun")


def _prep_cfg(arch: str, parts: bool, shape=None):
    cfg = get_arch(arch)
    reps = dict(param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
                unroll_inner=parts)  # unrolled inner loops => exact body costs
    # keep the unrolled SSD chunk count bounded for long prefills
    if parts and cfg.ssm_state and shape is not None and shape.kind != "decode":
        reps["ssm_chunk"] = max(cfg.ssm_chunk, shape.seq_len // 16)
    return dataclasses.replace(cfg, **reps)


def run_cell(arch: str, shape_name: str, multi_pod: bool, parts: bool = True):
    t0 = time.time()
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    cfg = _prep_cfg(arch, parts, shape)
    model = build_model(cfg)
    big = cfg.n_params() > 30e9
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips, "mode": shape.kind,
        "n_params": cfg.n_params(), "n_active_params": cfg.n_active_params(),
        "moment_dtype": "int8" if big else "float32",
    }

    key = jax.random.key(0)
    if shape.kind == "train":
        # production memory policy: huge archs train with microbatching
        # (activation footprint / accum) and int8 Adam moments
        accum = 8 if big else 1
        tc = TrainConfig(opt=AdamWConfig(moment_dtype="int8" if big else "float32"),
                         grad_accum=accum)
        result["grad_accum"] = accum
        state_abs = jax.eval_shape(make_init_state(model, tc), key)
        state_sds, state_axes = split_tree(state_abs)
        state_sh = tree_shardings(mesh, state_sds, state_axes)
        batch_sds, batch_sh = train_batch_specs(cfg, shape, mesh)
        step = make_train_step(model, tc)
        with mesh:
            lowered = jax.jit(
                step, in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None), donate_argnums=(0,),
            ).lower(state_sds, batch_sds)
        prm_sds, prm_axes = state_sds["params"], state_axes["params"]
        cache_sds = cache_axes = None
    else:
        prm_abs = jax.eval_shape(model.init_params, key)
        prm_sds, prm_axes = split_tree(prm_abs)
        prm_sh = tree_shardings(mesh, prm_sds, prm_axes)
        if shape.kind == "prefill":
            batch_sds, batch_sh = train_batch_specs(cfg, shape, mesh)
            with mesh:
                lowered = jax.jit(
                    model.prefill, in_shardings=(prm_sh, batch_sh),
                ).lower(prm_sds, batch_sds)
            cache_sds = cache_axes = None
        else:  # decode
            cache_abs = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            cache_sds, cache_axes = split_tree(cache_abs)
            cache_sh = tree_shardings(mesh, cache_sds, cache_axes)
            in_sds, in_sh = decode_inputs_specs(cfg, shape, mesh)

            def serve_step(prm, cache, tokens, pos, enc):
                return model.decode_step(prm, cache, tokens, pos, enc)

            enc_sds = in_sds.get("enc")
            enc_sh = in_sh.get("enc")
            logits_sh = None
            if os.environ.get("REPRO_SHARD_LOGITS"):
                # keep logits vocab-sharded (sampler consumes them sharded;
                # avoids the per-token all-gather of [B, V])
                from jax.sharding import NamedSharding, PartitionSpec

                logits_sh = NamedSharding(mesh, PartitionSpec(None, None, "model"))
            with mesh:
                lowered = jax.jit(
                    serve_step,
                    in_shardings=(prm_sh, cache_sh, in_sh["tokens"],
                                  in_sh["pos"], enc_sh),
                    out_shardings=(logits_sh, cache_sh),
                    donate_argnums=(1,),
                ).lower(prm_sds, cache_sds, in_sds["tokens"], in_sds["pos"],
                        enc_sds)

    t_lower = time.time()
    compiled = lowered.compile()
    t_compile = time.time()

    mem = rl.memory_dict(compiled)
    cost_full = rl.cost_dict(compiled)
    coll_full = rl.collective_bytes(compiled.as_text())
    print("memory_analysis:", json.dumps(mem))          # proves it fits
    print("cost_analysis:", json.dumps(cost_full))      # FLOPs/bytes §Roofline

    result.update(
        memory=mem, cost_full=cost_full, collectives_full=coll_full,
        lower_s=round(t_lower - t0, 2), compile_s=round(t_compile - t_lower, 2),
    )

    # ---- per-segment body costs (scan trip-count correction) ----
    flops = cost_full["flops"]
    byts = cost_full["bytes"]
    coll = float(coll_full["total"])
    if parts:
        part_list = rl.group_parts(model, cfg, shape, mesh, shape.kind,
                                   prm_sds, prm_axes, cache_sds, cache_axes)
        part_results = []
        for name, mult, lower_fn in part_list:
            pl = lower_fn()
            pc = pl.compile()
            c = rl.cost_dict(pc)
            cb = rl.collective_bytes(pc.as_text())
            part_results.append({"name": name, "multiplier": mult,
                                 "cost": c, "collectives": cb["total"]})
            scale = (3.0 if shape.kind == "train" else 1.0)
            # train bodies are lowered as grad (fwd+bwd); the full program's
            # single-counted body is also fwd+bwd, so the correction factor
            # applies uniformly: add (mult-1) body costs.
            flops += (mult - 1) * c["flops"]
            byts += (mult - 1) * c["bytes"]
            coll += (mult - 1) * cb["total"]
        result["parts"] = part_results

    mf = rl.model_flops(cfg, shape)
    result["roofline"] = rl.roofline_terms(flops, byts, coll, n_chips, mf)
    result["adjusted"] = {"flops": flops, "bytes": byts, "collective_bytes": coll}
    result["env_overrides"] = {k: v for k, v in os.environ.items()
                               if k.startswith("REPRO_")}
    result["total_s"] = round(time.time() - t0, 2)
    return result


def cell_list(multi_pod: bool | None = None):
    cells = []
    for arch in list_archs():
        for shape in applicable_shapes(get_arch(arch)):
            for mp in ([False, True] if multi_pod is None else [multi_pod]):
                cells.append((arch, shape, mp))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=os.path.normpath(OUT_DIR_DEFAULT))
    ap.add_argument("--no-parts", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--tag", default="", help="suffix for the output file "
                    "(perf-iteration runs; env overrides recorded)")
    args = ap.parse_args()

    if args.list:
        for c in cell_list():
            print(c)
        return

    os.makedirs(args.out, exist_ok=True)

    if args.all:
        mp = {"single": False, "multi": True, "both": None}[args.mesh]
        failures = []
        for arch, shape, multi in cell_list(mp):
            tag = f"{arch}__{shape}__{'multi' if multi else 'single'}"
            path = os.path.join(args.out, tag + ".json")
            if args.skip_existing and os.path.exists(path):
                print("skip", tag)
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape,
                   "--mesh", "multi" if multi else "single", "--out", args.out]
            if args.no_parts:
                cmd.append("--no-parts")
            print(">>>", tag, flush=True)
            try:
                rc = subprocess.run(
                    cmd, timeout=2400,
                    env={**os.environ,
                         "PYTHONPATH": os.environ.get("PYTHONPATH", "")})
                code = rc.returncode
            except subprocess.TimeoutExpired:
                code = -9
            if code != 0:
                failures.append(tag)
                print("FAILED", tag, flush=True)
        print("done; failures:", failures)
        sys.exit(1 if failures else 0)

    multi = args.mesh == "multi"
    tag = f"{args.arch}__{args.shape}__{'multi' if multi else 'single'}"
    if args.tag:
        tag += f"__{args.tag}"
    try:
        res = run_cell(args.arch, args.shape, multi, parts=not args.no_parts)
    except Exception:
        res = {"arch": args.arch, "shape": args.shape,
               "mesh": "multi" if multi else "single",
               "error": traceback.format_exc()}
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(res, f, indent=2)
        print(res["error"])
        sys.exit(1)
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(res, f, indent=2)
    print(json.dumps({k: v for k, v in res.items() if k != "parts"}, indent=2))


if __name__ == "__main__":
    main()
