"""input_specs: ShapeDtypeStruct stand-ins + shardings for every model input.

No device allocation ever happens here — weak-type-correct abstract arrays
only. Modality frontends are stubs per the assignment: [audio]/[vlm] archs
receive precomputed frame/patch embeddings under batch["enc"].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig
from repro.distributed.sharding import DEFAULT_RULES, batch_spec, spec_for


def train_batch_specs(cfg: ArchConfig, shape: ShapeConfig, mesh):
    gb, s = shape.global_batch, shape.seq_len
    bspec = batch_spec(mesh, gb)
    sds = {"tokens": jax.ShapeDtypeStruct((gb, s), jnp.int32)}
    sh = {"tokens": NamedSharding(mesh, bspec)}
    if cfg.family in ("encdec", "vlm"):
        se = cfg.encoder_seq if cfg.family == "encdec" else cfg.vision_seq
        sds["enc"] = jax.ShapeDtypeStruct((gb, se, cfg.d_model), cfg.compute_dtype)
        sh["enc"] = NamedSharding(mesh, PartitionSpec(*(list(bspec) + [None, None])))
    return sds, sh


def decode_inputs_specs(cfg: ArchConfig, shape: ShapeConfig, mesh):
    """(tokens [B,1], pos [B], enc?) for a single decode step."""
    gb = shape.global_batch
    bspec = batch_spec(mesh, gb)
    sds = {
        "tokens": jax.ShapeDtypeStruct((gb, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((gb,), jnp.int32),
    }
    sh = {
        "tokens": NamedSharding(mesh, bspec),
        "pos": NamedSharding(mesh, bspec),
    }
    if cfg.family in ("encdec", "vlm"):
        se = cfg.encoder_seq if cfg.family == "encdec" else cfg.vision_seq
        sds["enc"] = jax.ShapeDtypeStruct((gb, se, cfg.d_model), cfg.compute_dtype)
        sh["enc"] = NamedSharding(mesh, PartitionSpec(*(list(bspec) + [None, None])))
    return sds, sh
