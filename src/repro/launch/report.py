"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the
per-cell JSON artifacts in experiments/dryrun/."""
from __future__ import annotations

import glob
import json
import os
import sys


def load_cells(out_dir: str, mesh: str):
    cells = []
    for path in sorted(glob.glob(os.path.join(out_dir, f"*__{mesh}.json"))):
        r = json.load(open(path))
        cells.append(r)
    return cells


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/1e9:.1f}"


def roofline_table(cells) -> str:
    hdr = ("| arch | shape | params | dom | compute s | memory s | coll s | "
           "MODEL/HLO | roofline frac | mem GB/dev | note |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for r in cells:
        if "error" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | - | ERROR | | | | | | | "
                        f"{r['error'].splitlines()[-1][:60]} |")
            continue
        rf = r["roofline"]
        note = "int8-adam" if r.get("moment_dtype") == "int8" else ""
        if r.get("grad_accum", 1) > 1:
            note += f" ga={r['grad_accum']}"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['n_params']/1e9:.1f}B "
            f"| {rf['dominant']} | {rf['compute_s']:.3f} | {rf['memory_s']:.3f} "
            f"| {rf['collective_s']:.3f} | {rf['useful_flops_ratio']:.2f} "
            f"| {rf['roofline_fraction']*100:.2f}% "
            f"| {fmt_bytes(r['memory'].get('per_device_bytes_est'))} | {note} |")
    return hdr + "\n".join(rows) + "\n"


def dryrun_table(cells) -> str:
    hdr = ("| arch | shape | compile s | HLO GFLOP/dev | coll GB/dev "
           "(AR/AG/RS/A2A/CP) | args GB/dev | temp GB/dev |\n"
           "|---|---|---|---|---|---|---|\n")
    rows = []
    for r in cells:
        if "error" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | |")
            continue
        c = r["collectives_full"]
        coll = "/".join(f"{c.get(k,0)/1e9:.1f}" for k in
                        ("all-reduce", "all-gather", "reduce-scatter",
                         "all-to-all", "collective-permute"))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r.get('compile_s','-')} "
            f"| {r['cost_full']['flops']/1e9:.0f} | {coll} "
            f"| {fmt_bytes(r['memory'].get('argument_size_in_bytes'))} "
            f"| {fmt_bytes(r['memory'].get('temp_size_in_bytes'))} |")
    return hdr + "\n".join(rows) + "\n"


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    for mesh in ("single", "multi"):
        cells = load_cells(out_dir, mesh)
        if not cells:
            continue
        print(f"\n### {mesh} mesh — roofline ({len(cells)} cells)\n")
        print(roofline_table(cells))
        print(f"\n### {mesh} mesh — dry-run detail\n")
        print(dryrun_table(cells))


if __name__ == "__main__":
    main()
