"""Roofline derivation from compiled dry-run artifacts (TPU v5e targets).

Three terms per (arch × shape × mesh), in seconds:

  compute  = HLO_FLOPs_per_device / peak_FLOPs_chip
  memory   = HLO_bytes_per_device / HBM_bw_chip
  coll     = collective_bytes_per_device / ICI_link_bw

Methodology note (recorded in EXPERIMENTS.md): XLA's cost analysis counts a
while-loop (scan) body ONCE regardless of trip count. The model stacks are
scan-over-groups, so the full program undercounts by ~n_groups. We therefore
lower each segment's *group body* separately under the same mesh/shardings
and combine:  total = cost(full) + Σ_seg (G_seg - 1) × cost(body_seg).
Inner chunk loops (flash KV, SSD, CE) are python-unrolled in dry-run configs
(`unroll_inner=True`) so body costs are exact.
"""
from __future__ import annotations

import dataclasses
import re

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.distributed.sharding import batch_spec, tree_shardings
from repro.models.common import split_tree

# ---- TPU v5e hardware constants (per chip) ----
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # B/s
ICI_BW = 50e9            # B/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?P<result>.+?)\s+"
    r"(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(")
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return default


def collective_bytes(hlo_text: str, default_group: int = 16) -> dict:
    """Per-device wire bytes of collectives from (SPMD) HLO text.

    Operands are printed as bare value names in compiled.as_text(), so
    volumes come from the RESULT shapes plus per-op group size g:
      all-reduce          2·B·(g-1)/g     (ring reduce-scatter + all-gather)
      all-gather          B·(g-1)/g       (B = gathered result)
      reduce-scatter      B·(g-1)         (B = scattered shard result)
      all-to-all          B·(g-1)/g
      collective-permute  B
    """
    out = {k: 0.0 for k in ("all-reduce", "all-gather", "reduce-scatter",
                            "all-to-all", "collective-permute")}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group("kind")
        if m.group("start") and kind in out:
            pass  # -start carries the shapes; -done lines don't match '=... op('
        b = sum(_shape_bytes(d, s)
                for d, s in _SHAPE_RE.findall(m.group("result")))
        # -start results are tuples (operand, result): halve to avoid double count
        if m.group("start"):
            b = b / 2
        g = _group_size(line, default_group)
        if kind == "all-reduce":
            wire = 2.0 * b * (g - 1) / g
        elif kind == "all-gather":
            wire = b * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = b * (g - 1)
        elif kind == "all-to-all":
            wire = b * (g - 1) / g
        else:  # collective-permute
            wire = b
        out[kind] += wire
    out["total"] = sum(out.values())
    return {k: int(v) for k, v in out.items()}


def cost_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        return {"flops": float(ca.get("flops", 0.0)),
                "bytes": float(ca.get("bytes accessed", 0.0))}
    except Exception as e:  # pragma: no cover
        return {"flops": 0.0, "bytes": 0.0, "error": str(e)}


def memory_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        keys = ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "alias_size_in_bytes",
                "generated_code_size_in_bytes")
        out = {}
        for k in keys:
            v = getattr(ma, k, None)
            if v is not None:
                out[k] = int(v)
        per_dev = (out.get("argument_size_in_bytes", 0)
                   + out.get("output_size_in_bytes", 0)
                   + out.get("temp_size_in_bytes", 0)
                   - out.get("alias_size_in_bytes", 0))
        out["per_device_bytes_est"] = int(per_dev)
        return out
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def roofline_terms(flops: float, byts: float, coll: float, n_chips: int,
                   model_flops_total: float) -> dict:
    """All inputs per-device; model_flops_total is whole-job analytic."""
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    coll_s = coll / ICI_BW
    dom = max(("compute", compute_s), ("memory", memory_s),
              ("collective", coll_s), key=lambda kv: kv[1])
    hlo_total = flops * n_chips
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dom[0],
        "bound_s": dom[1],
        "model_flops": model_flops_total,
        "hlo_flops_total": hlo_total,
        "useful_flops_ratio": (model_flops_total / hlo_total) if hlo_total else 0.0,
        "roofline_fraction": (
            (model_flops_total / n_chips / PEAK_FLOPS) / dom[1] if dom[1] else 0.0),
    }


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6·N·D train / 2·N·D forward (active params)."""
    n_act = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    # decode: one token per sequence — params read once, plus KV attention
    return 2.0 * n_act * shape.global_batch


# ------------------------------------------------------------ body parts ----
def _strip_layer(sds, axes):
    v = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), sds)
    a = jax.tree.map(lambda t: tuple(t[1:]), axes,
                     is_leaf=lambda x: isinstance(x, tuple) and (
                         len(x) == 0 or isinstance(x[0], (str, type(None)))))
    return v, a


def group_parts(model, cfg, shape, mesh, mode, prm_sds, prm_axes, cache_sds=None,
                cache_axes=None):
    """Yield (name, multiplier, lower_fn) per scanned segment (+ prefix/encoder).

    lower_fn() -> jax.stages.Lowered for the segment body under `mesh`.
    """
    from repro.models.transformer import BlockApplier, Ctx

    gb = shape.global_batch
    s = shape.seq_len
    d = cfg.d_model
    cd = cfg.compute_dtype
    bspec = batch_spec(mesh, gb)
    x_sh = NamedSharding(mesh, PartitionSpec(*(tuple(bspec) + (None, None))))
    enc_needed = cfg.family in ("encdec", "vlm")
    enc_len = cfg.vision_seq if cfg.family == "vlm" else cfg.encoder_seq

    shared_sds = prm_sds.get("shared")
    shared_axes = prm_axes.get("shared") if shared_sds is not None else None
    shared_sh = (tree_shardings(mesh, shared_sds, shared_axes)
                 if shared_sds is not None else None)

    parts = []

    def make_part(name, mult, period, bp_sds, bp_axes, cache_slice=None,
                  cache_slice_axes=None):
        bp_sh = tree_shardings(mesh, bp_sds, bp_axes)
        sq = 1 if mode == "decode" else s

        def fn(bp, shared, x, enc, cache, pos):
            applier = BlockApplier(cfg, shared=shared)
            if mode == "decode":
                ctx = Ctx(mode="decode", pos=pos, enc=enc)
            else:
                positions = jnp.broadcast_to(jnp.arange(sq)[None], (gb, sq))
                ctx = Ctx(mode="prefill" if mode == "prefill" else "train",
                          positions=positions, enc=enc, max_seq=sq)
            caches_out = []
            for pi, bt in enumerate(period):
                cc = cache[f"pos{pi}"] if cache is not None else None
                x, nc, _ = applier(bt, bp[f"pos{pi}"], x, ctx, cc)
                caches_out.append(nc)
            return x, caches_out

        x_sds = jax.ShapeDtypeStruct((gb, sq, d), cd)
        enc_sds = (jax.ShapeDtypeStruct((gb, enc_len, d), cd)
                   if enc_needed else None)
        enc_sh = (NamedSharding(mesh, PartitionSpec(*(tuple(bspec) + (None, None))))
                  if enc_needed else None)
        pos_sds = jax.ShapeDtypeStruct((gb,), jnp.int32) if mode == "decode" else None
        pos_sh = NamedSharding(mesh, bspec) if mode == "decode" else None
        cache_sh = (tree_shardings(mesh, cache_slice, cache_slice_axes)
                    if cache_slice is not None else None)

        if mode == "train":
            def loss_fn(bp, shared, x, enc, cache, pos):
                y, _ = fn(bp, shared, x, enc, cache, pos)
                return jnp.sum(y.astype(jnp.float32))

            target = jax.grad(loss_fn, argnums=(0, 2))
            out_sh = None
        else:
            target = fn
            out_sh = None

        def lower():
            with mesh:
                return jax.jit(
                    target,
                    in_shardings=(bp_sh, shared_sh, x_sh, enc_sh, cache_sh, pos_sh),
                ).lower(bp_sds, shared_sds, x_sds, enc_sds, cache_slice, pos_sds)

        parts.append((name, mult, lower))

    for si, seg in enumerate(model.segments):
        bp_sds, bp_axes = _strip_layer(prm_sds[f"seg{si}"], prm_axes[f"seg{si}"])
        csl = casl = None
        if mode == "decode" and cache_sds is not None:
            csl, casl = _strip_layer(cache_sds[f"seg{si}"], cache_axes[f"seg{si}"])
            csl = {f"pos{pi}": csl[f"pos{pi}"] for pi in range(len(seg.period))}
        make_part(f"seg{si}", seg.n_groups, seg.period, bp_sds, bp_axes, csl, casl)

    if model.prefix:
        bt = model.prefix[0]
        bp_sds = {"pos0": prm_sds["prefix0"]}
        bp_axes = {"pos0": prm_axes["prefix0"]}
        csl = casl = None
        if mode == "decode" and cache_sds is not None:
            csl = {"pos0": cache_sds["prefix0"]}
            casl = {"pos0": cache_axes["prefix0"]}
        make_part("prefix", len(model.prefix), (bt,), bp_sds, bp_axes, csl, casl)

    if cfg.family == "encdec" and mode != "decode":
        # encoder body over stub frames
        enc_bt = model.enc_bt
        bp_sds, bp_axes = _strip_layer(prm_sds["enc_blocks"], prm_axes["enc_blocks"])
        bp_sh = tree_shardings(mesh, bp_sds, bp_axes)

        def enc_fn(bp, x):
            from repro.models.transformer import BlockApplier, Ctx

            positions = jnp.broadcast_to(jnp.arange(enc_len)[None], (gb, enc_len))
            ctx = Ctx(mode="train", positions=positions)
            applier = BlockApplier(cfg)
            y, _, _ = applier(enc_bt, bp, x, ctx)
            return y

        x_sds = jax.ShapeDtypeStruct((gb, enc_len, d), cd)
        if mode == "train":
            tgt = jax.grad(lambda bp, x: jnp.sum(enc_fn(bp, x).astype(jnp.float32)),
                           argnums=(0, 1))
        else:
            tgt = enc_fn

        def lower_enc(tgt=tgt, bp_sh=bp_sh, bp_sds=bp_sds, x_sds=x_sds):
            with mesh:
                return jax.jit(tgt, in_shardings=(bp_sh, x_sh)).lower(bp_sds, x_sds)

        parts.append(("encoder", cfg.n_encoder_layers, lower_enc))

    return parts
