"""Per-(query, filter-spec) result cache.

Keys are SHA-1 digests over the *full byte content* of the query vector and
the filter, plus the predicate kind tag and every search parameter that
changes the answer (k, queue size, traversal mode/backend-independent α,
probe budget). Hashing the raw bytes — not a lossy summary like a mask
popcount or a range width — is what makes the cache safe under filter-spec
collisions: a contain mask and an equal mask with identical words, or a
range whose (lo, hi) float bytes happen to equal a mask's bytes, still map
to distinct keys because the kind tag is part of the preimage.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict

import numpy as np


def request_key(req, k: int, queue_size: int, alpha: float,
                probe_budget: int, min_budget: int = 32,
                max_budget: int = 1 << 30, n_probes: int = 2,
                ablate_filter: bool = False) -> str:
    h = hashlib.sha1()
    h.update(np.ascontiguousarray(req.query, np.float32).tobytes())
    h.update(b"|kind:%d" % req.kind)
    if req.label_mask is not None:
        h.update(b"|mask:")
        h.update(np.ascontiguousarray(req.label_mask, np.uint32).tobytes())
    if req.range_lo is not None:
        h.update(b"|range:")
        h.update(np.asarray([req.range_lo, req.range_hi], np.float32).tobytes())
    h.update(b"|k:%d|m:%d|a:%r|f:%d|lo:%d|hi:%d|np:%d|abl:%d"
             % (k, queue_size, alpha, probe_budget, min_budget, max_budget,
                n_probes, ablate_filter))
    return h.hexdigest()


class ResultCache:
    """LRU cache of completed results (res_idx, res_dist, ndc)."""

    def __init__(self, capacity: int = 4096):
        self.capacity = capacity
        self._d: OrderedDict[str, tuple] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._d)

    def get(self, key: str):
        hit = self._d.get(key)
        if hit is None:
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return hit

    def put(self, key: str, res_idx: np.ndarray, res_dist: np.ndarray,
            ndc: int) -> None:
        self._d[key] = (np.asarray(res_idx).copy(),
                        np.asarray(res_dist).copy(), int(ndc))
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0
