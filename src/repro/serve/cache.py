"""Per-(query, filter) result cache.

Keys are SHA-1 digests over the *full byte content* of the query vector and
the filter's canonical DNF serialization (`filters.expr.canonical_key`),
plus every search parameter that changes the answer (k, queue size, α,
probe budget, …). Canonicalization makes the key semantic up to
commutativity: `And(a, b)` and `And(b, a)` collide on purpose (same filter,
same compiled program, same traversal), while `And(a, b)` vs `Or(a, b)`
and any structural/leaf difference — a contain vs an equal over the same
labels, a range whose float bytes happen to shadow a label encoding — stay
distinct because kind tags, negation flags, and exact float hex forms are
all part of the canonical serialization.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict

import numpy as np

from repro.filters.expr import canonical_key


def request_key(req, k: int, queue_size: int, alpha: float,
                probe_budget: int, min_budget: int = 32,
                max_budget: int = 1 << 30, n_probes: int = 2,
                ablate_filter: bool = False,
                codec: str = "float32", plan: str = "traverse") -> str:
    """`codec` is the engine's codec identity (`SearchEngine.codec_key()`):
    precision tag + codec-parameter digest. Quantization changes traversal
    order and the surviving candidate pool, hence the answer — two engines
    differing only in precision (or in a retrained codebook) must never
    share cache entries.

    `plan` is the configured execution plan ("auto" or a forced plan). It
    is part of the key exactly because different plans return different
    answers (scan is exact, the traversals are approximate) — but it enters
    the digest only when it *can* change the result: "traverse" hashes
    identically to the pre-planner key (legacy entries stay valid), and an
    auto completion that executed some plan X through the same bitwise path
    a forced-X run would take is additionally stored under the forced-X key
    by the scheduler (dual put), so auto and forced deployments share
    entries whenever sharing is sound. See tests/test_serve.py's
    plan-collision matrix for the exact hit/miss contract."""
    h = hashlib.sha1()
    h.update(np.ascontiguousarray(req.query, np.float32).tobytes())
    h.update(b"|filter:")
    h.update(canonical_key(req.get_expr()))
    h.update(b"|k:%d|m:%d|a:%r|f:%d|lo:%d|hi:%d|np:%d|abl:%d"
             % (k, queue_size, alpha, probe_budget, min_budget, max_budget,
                n_probes, ablate_filter))
    h.update(b"|codec:" + codec.encode())
    if plan != "traverse":
        h.update(b"|plan:" + plan.encode())
    return h.hexdigest()


class ResultCache:
    """LRU cache of completed results (res_idx, res_dist, ndc)."""

    def __init__(self, capacity: int = 4096):
        self.capacity = capacity
        self._d: OrderedDict[str, tuple] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._d)

    def get(self, key: str):
        hit = self._d.get(key)
        if hit is None:
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return hit

    def put(self, key: str, res_idx: np.ndarray, res_dist: np.ndarray,
            ndc: int) -> None:
        self._d[key] = (np.asarray(res_idx).copy(),
                        np.asarray(res_dist).copy(), int(ndc))
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0
