"""Serving metrics: latency percentiles, NDC histogram, queue depth, cache.

One record per completed request plus periodic queue-depth samples; the
summary feeds the `BENCH_serve.json` artifact (see benchmarks/serve_bench.py)
and the `launch/serve.py` report. Times are in whatever unit the driving
clock uses (seconds for the real-clock launcher and the simulated bench).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from functools import partial

import numpy as np

# Retention caps: a long-lived serving process must not grow memory without
# bound, so per-request / per-batch observations are sliding windows (the
# aggregate counters n_batches / busy_time stay exact for the full
# lifetime). At serving rates that fill these windows, the percentiles in
# summary() describe the most recent traffic — which is what an operator
# wants from a live system anyway.
MAX_RECORDS = 1 << 17
MAX_SAMPLES = 1 << 16


@dataclasses.dataclass
class ServeMetrics:
    records: deque = dataclasses.field(
        default_factory=partial(deque, maxlen=MAX_RECORDS))
    depth_samples: deque = dataclasses.field(
        default_factory=partial(deque, maxlen=MAX_SAMPLES))
    batches: deque = dataclasses.field(
        default_factory=partial(deque, maxlen=MAX_SAMPLES))
    n_batches: int = 0
    n_completed: int = 0
    busy_time: float = 0.0
    # per-shard attribution (sharded engines only; stays None on S=1):
    # lifetime exact counters, the telemetry ROADMAP's skew-aware budget
    # routing will read
    shard_ndc: np.ndarray | None = None     # [S] i64, Σ == Σ request NDC
    shard_bitmap: np.ndarray | None = None  # [S] i64 filter-valid rows seen

    def observe_shard_ndc(self, deltas) -> None:
        """Accumulate per-shard NDC deltas [S] from one pump (already
        summed over the batch's real lanes by the scheduler)."""
        d = np.asarray(deltas, np.int64)
        if self.shard_ndc is None:
            self.shard_ndc = np.zeros(d.shape[0], np.int64)
        self.shard_ndc += d

    def observe_shard_bitmap(self, counts) -> None:
        """Accumulate per-shard filter-bitmap popcounts [S] from one
        compiled ScanStats observation (summed over real lanes)."""
        c = np.asarray(counts, np.int64)
        if self.shard_bitmap is None:
            self.shard_bitmap = np.zeros(c.shape[0], np.int64)
        self.shard_bitmap += c

    def observe_batch(self, phase: str, size: int, fill: int,
                      busy: float, steps: int = 0, launches: int = 0,
                      early_exit_frac: float = 0.0) -> None:
        """`launches` is how many device dispatches the batch cost (== steps
        for single-step backends, ≈ steps / steps_per_launch for persistent
        ones); `early_exit_frac` is the fraction of real lanes that
        terminated before the batch's slowest lane — the lanes a persistent
        backend's in-launch early exit stops paying for."""
        self.n_batches += 1
        self.busy_time += busy
        self.batches.append(dict(phase=phase, size=size, lanes=fill,
                                 busy=busy, steps=steps, launches=launches,
                                 early_exit=early_exit_frac))

    def observe_depth(self, now: float, depth: int) -> None:
        self.depth_samples.append((now, depth))

    def complete(self, req) -> None:
        self.n_completed += 1
        self.records.append(dict(
            rid=req.rid,
            latency=(req.completed - req.arrival),
            probe_latency=(None if req.probe_done is None
                           else req.probe_done - req.arrival),
            ndc=req.ndc,
            budget=req.budget,
            n_slices=req.n_slices,
            cache_hit=req.cache_hit,
            deadline_missed=(req.deadline is not None
                            and req.completed > req.deadline),
        ))

    # ------------------------------------------------------------ summary ----
    def _percentiles(self, values, qs=(50, 95, 99)) -> dict:
        """Percentiles that are finite for any window: empty → 0.0, and
        non-finite observations (a NaN latency from a mis-stamped clock
        must not poison the whole scrape) are dropped first. Singleton
        windows return that single value at every quantile."""
        v = np.asarray(values, np.float64)
        v = v[np.isfinite(v)]
        if v.size == 0:
            return {f"p{q}": 0.0 for q in qs}
        return {f"p{q}": float(np.percentile(v, q)) for q in qs}

    def summary(self, n_shed: int = 0, n_expired: int = 0,
                cache=None) -> dict:
        lat = np.asarray([r["latency"] for r in self.records], np.float64)
        plat = np.asarray([r["probe_latency"] for r in self.records
                           if r.get("probe_latency") is not None], np.float64)
        ndc = np.asarray([r["ndc"] for r in self.records
                          if r["ndc"] is not None], np.float64)
        hist, edges = (np.histogram(ndc, bins=8) if len(ndc)
                       else (np.zeros(8, int), np.zeros(9)))
        depth = np.asarray([d for _, d in self.depth_samples], np.float64)
        by_phase = {}
        for b in self.batches:
            d = by_phase.setdefault(b["phase"],
                                    dict(n=0, busy=0.0, size=0, lanes=0,
                                         steps=0, launches=0, early_w=0.0))
            lanes = b["size"]  # real lanes; "lanes" in the record is the
            d["n"] += 1        # padded dispatch width
            d["busy"] += b["busy"]
            d["size"] += b["size"]
            d["lanes"] += lanes
            d["steps"] += b.get("steps", 0)
            d["launches"] += b.get("launches", 0)
            # weight each batch's early-exit fraction by its real lane
            # count: an unweighted per-batch mean lets a 1-lane tail batch
            # count as much as a full 64-lane one, overstating (or
            # understating) how many lanes actually exited early
            d["early_w"] += b.get("early_exit", 0.0) * lanes
        launches_total = steps_total = lanes_total = 0
        early_w_total = 0.0
        for d in by_phase.values():
            launches_total += d["launches"]
            steps_total += d["steps"]
            lanes_total += d["lanes"]
            early_w_total += d["early_w"]
            d["mean_fill"] = d.pop("size") / d["n"]
            d["busy"] = round(d["busy"], 4)
            d["early_exit_frac"] = round(
                d.pop("early_w") / max(d.pop("lanes"), 1), 4)
        out = dict(
            n_completed=self.n_completed,
            n_batches=self.n_batches,
            busy_time=float(self.busy_time),
            batches_by_phase=by_phase,
            launches_total=int(launches_total),
            steps_total=int(steps_total),
            early_exit_frac=round(early_w_total / max(lanes_total, 1), 4),
            latency=self._percentiles(lat),
            latency_mean=float(lat.mean()) if len(lat) else 0.0,
            probe_latency=self._percentiles(plat),
            ndc=self._percentiles(ndc),
            ndc_hist=dict(counts=hist.tolist(),
                          edges=[float(e) for e in edges]),
            queue_depth_mean=float(depth.mean()) if len(depth) else 0.0,
            queue_depth_max=int(depth.max()) if len(depth) else 0,
            n_shed=int(n_shed),
            n_expired=int(n_expired),
            n_requeues=int(sum(max(0, r["n_slices"] - 1)
                               for r in self.records)),
            deadline_miss_rate=(float(np.mean([r["deadline_missed"]
                                               for r in self.records]))
                                if self.records else 0.0),
        )
        if cache is not None:
            out["cache"] = dict(hits=cache.hits, misses=cache.misses,
                                hit_rate=cache.hit_rate, entries=len(cache))
        if self.shard_ndc is not None or self.shard_bitmap is not None:
            out["shards"] = self._shard_summary()
        return out

    def _shard_summary(self) -> dict:
        def skew(v):
            # max/mean ≥ 1; 1.0 means perfectly even (also the empty case)
            if v is None or v.sum() <= 0:
                return 1.0
            return float(v.max() / max(v.mean(), 1e-12))

        ndc = self.shard_ndc
        bmp = self.shard_bitmap
        s = len(ndc) if ndc is not None else len(bmp)
        total = int(ndc.sum()) if ndc is not None else 0
        mx = int(ndc.max()) if ndc is not None else 0
        return dict(
            n_shards=int(s),
            ndc_by_shard=[] if ndc is None else [int(v) for v in ndc],
            ndc_skew=skew(ndc),
            bitmap_by_shard=[] if bmp is None else [int(v) for v in bmp],
            bitmap_skew=skew(bmp),
            work_balance=(total / (s * mx)) if mx > 0 else 1.0,
        )
