"""Serving metrics: latency percentiles, NDC histogram, queue depth, cache.

One record per completed request plus periodic queue-depth samples; the
summary feeds the `BENCH_serve.json` artifact (see benchmarks/serve_bench.py)
and the `launch/serve.py` report. Times are in whatever unit the driving
clock uses (seconds for the real-clock launcher and the simulated bench).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from functools import partial

import numpy as np

# Retention caps: a long-lived serving process must not grow memory without
# bound, so per-request / per-batch observations are sliding windows (the
# aggregate counters n_batches / busy_time stay exact for the full
# lifetime). At serving rates that fill these windows, the percentiles in
# summary() describe the most recent traffic — which is what an operator
# wants from a live system anyway.
MAX_RECORDS = 1 << 17
MAX_SAMPLES = 1 << 16


@dataclasses.dataclass
class ServeMetrics:
    records: deque = dataclasses.field(
        default_factory=partial(deque, maxlen=MAX_RECORDS))
    depth_samples: deque = dataclasses.field(
        default_factory=partial(deque, maxlen=MAX_SAMPLES))
    batches: deque = dataclasses.field(
        default_factory=partial(deque, maxlen=MAX_SAMPLES))
    n_batches: int = 0
    n_completed: int = 0
    busy_time: float = 0.0

    def observe_batch(self, phase: str, size: int, fill: int,
                      busy: float, steps: int = 0, launches: int = 0,
                      early_exit_frac: float = 0.0) -> None:
        """`launches` is how many device dispatches the batch cost (== steps
        for single-step backends, ≈ steps / steps_per_launch for persistent
        ones); `early_exit_frac` is the fraction of real lanes that
        terminated before the batch's slowest lane — the lanes a persistent
        backend's in-launch early exit stops paying for."""
        self.n_batches += 1
        self.busy_time += busy
        self.batches.append(dict(phase=phase, size=size, lanes=fill,
                                 busy=busy, steps=steps, launches=launches,
                                 early_exit=early_exit_frac))

    def observe_depth(self, now: float, depth: int) -> None:
        self.depth_samples.append((now, depth))

    def complete(self, req) -> None:
        self.n_completed += 1
        self.records.append(dict(
            rid=req.rid,
            latency=(req.completed - req.arrival),
            probe_latency=(None if req.probe_done is None
                           else req.probe_done - req.arrival),
            ndc=req.ndc,
            budget=req.budget,
            n_slices=req.n_slices,
            cache_hit=req.cache_hit,
            deadline_missed=(req.deadline is not None
                            and req.completed > req.deadline),
        ))

    # ------------------------------------------------------------ summary ----
    def _percentiles(self, values, qs=(50, 95, 99)) -> dict:
        if not len(values):
            return {f"p{q}": 0.0 for q in qs}
        return {f"p{q}": float(np.percentile(values, q)) for q in qs}

    def summary(self, n_shed: int = 0, n_expired: int = 0,
                cache=None) -> dict:
        lat = np.asarray([r["latency"] for r in self.records], np.float64)
        plat = np.asarray([r["probe_latency"] for r in self.records
                           if r.get("probe_latency") is not None], np.float64)
        ndc = np.asarray([r["ndc"] for r in self.records
                          if r["ndc"] is not None], np.float64)
        hist, edges = (np.histogram(ndc, bins=8) if len(ndc)
                       else (np.zeros(8, int), np.zeros(9)))
        depth = np.asarray([d for _, d in self.depth_samples], np.float64)
        by_phase = {}
        for b in self.batches:
            d = by_phase.setdefault(b["phase"],
                                    dict(n=0, busy=0.0, size=0,
                                         launches=0, early=0.0))
            d["n"] += 1
            d["busy"] += b["busy"]
            d["size"] += b["size"]
            d["launches"] += b.get("launches", 0)
            d["early"] += b.get("early_exit", 0.0)
        for d in by_phase.values():
            d["mean_fill"] = d.pop("size") / d["n"]
            d["busy"] = round(d["busy"], 4)
            d["early_exit_frac"] = round(d.pop("early") / d["n"], 4)
        out = dict(
            n_completed=self.n_completed,
            n_batches=self.n_batches,
            busy_time=float(self.busy_time),
            batches_by_phase=by_phase,
            latency=self._percentiles(lat),
            latency_mean=float(lat.mean()) if len(lat) else 0.0,
            probe_latency=self._percentiles(plat),
            ndc=self._percentiles(ndc),
            ndc_hist=dict(counts=hist.tolist(),
                          edges=[float(e) for e in edges]),
            queue_depth_mean=float(depth.mean()) if len(depth) else 0.0,
            queue_depth_max=int(depth.max()) if len(depth) else 0,
            n_shed=int(n_shed),
            n_expired=int(n_expired),
            n_requeues=int(sum(max(0, r["n_slices"] - 1)
                               for r in self.records)),
            deadline_miss_rate=(float(np.mean([r["deadline_missed"]
                                               for r in self.records]))
                                if self.records else 0.0),
        )
        if cache is not None:
            out["cache"] = dict(hits=cache.hits, misses=cache.misses,
                                hit_rate=cache.hit_rate, entries=len(cache))
        return out
