# Cost-aware serving subsystem: the paper's adaptive-termination signal
# (predicted budget Ŵ_q) used as a scheduling signal — admission control,
# fixed-shape micro-batching, budget-bucketed batch formation, and
# resume-based preemption over the lockstep engine.
from repro.serve.queue import AdmissionQueue, Request, requests_from_workload
from repro.serve.batcher import MicroBatcher
from repro.serve.cache import ResultCache, request_key
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import CostAwareScheduler, ServeConfig

__all__ = [
    "AdmissionQueue",
    "Request",
    "requests_from_workload",
    "MicroBatcher",
    "ResultCache",
    "request_key",
    "ServeMetrics",
    "CostAwareScheduler",
    "ServeConfig",
]
