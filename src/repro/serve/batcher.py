"""Micro-batcher: fixed-shape lanes + budget-bucketed batch formation.

Two jobs, both about feeding a jitted lockstep engine from a ragged request
stream:

**Fixed-shape lanes.** `run_search` recompiles per batch shape, so every
micro-batch is padded to exactly `lane_width` lanes — one compile per
(predicate kind, phase) for the whole serving session. Pad lanes carry
all-zero queries/filters/states and a 0 NDC budget, so they deactivate on
their first step; the engine's shard path uses the same invariant.

**Budget buckets.** After the shared probe phase every request owns a
predicted budget Ŵ_q. In a lockstep batch the wall time is set by the
*largest* lane budget — mixing a Ŵ=8000 request into a batch of Ŵ=150
requests makes the easy lanes pay 50× their own cost (the batch-tail
misalignment of paper Fig. 3, recreated at serving level). The batcher
therefore keeps one FIFO queue per budget bucket (ascending NDC caps, last
unbounded) and forms batches within a bucket, so batchmates always have
comparable remaining work. A request whose Ŵ_q exceeds its bucket's cap
runs a bounded time slice and is requeued one bucket up with its carried
`SearchState` (the scheduler's preemption path) — no batch ever runs past
its bucket's budget.

Opportunistic fill: when a bucket batch has spare lanes, requests waiting in
*higher* buckets may ride along for a time slice capped at this bucket's
budget. They make bounded progress without extending the batch (their lane
budget is clamped to the cap) and are requeued upward afterwards.
"""
from __future__ import annotations

from collections import deque

import jax.numpy as jnp
import numpy as np

from repro.core.state import concat_lanes, pad_lanes, take_lanes
from repro.serve.queue import Request, batch_spec, take_kind


class MicroBatcher:
    def __init__(self, lane_width: int = 16,
                 buckets: tuple = (256, 1024, 4096, None),
                 fill: bool = True):
        if buckets[-1] is not None:
            buckets = tuple(buckets) + (None,)
        caps = [c for c in buckets[:-1]]
        if any(b >= a for a, b in zip(caps[1:], caps[:-1])):
            raise ValueError(f"bucket caps must be ascending: {buckets}")
        self.lane_width = lane_width
        # A short ladder of lane widths bounds jit shapes while letting a
        # partial batch run at its natural width: on CPU/GPU the lockstep
        # per-step cost scales ~linearly with lane count, so an 8-wide
        # survivor batch costs half a 16-wide one — which is what makes
        # budget buckets cheaper than one tail-bound batch, not free lanes.
        self.lane_widths = tuple(sorted({max(1, lane_width // 4),
                                         max(1, lane_width // 2),
                                         lane_width}))
        self.buckets = tuple(buckets)
        self.fill = fill
        self._queues: list[deque[Request]] = [deque() for _ in buckets]

    def width_for(self, n: int) -> int:
        """Smallest configured lane width that fits `n` requests."""
        for w in self.lane_widths:
            if n <= w:
                return w
        return self.lane_width

    # ------------------------------------------------------------- routing ----
    def bucket_of(self, budget: int) -> int:
        """Smallest bucket whose cap covers `budget` (deterministic)."""
        for i, cap in enumerate(self.buckets):
            if cap is None or budget <= cap:
                return i
        raise AssertionError("unreachable: last bucket is unbounded")

    def enqueue(self, req: Request, bucket: int | None = None) -> int:
        """Queue a probed request; default routing is by its predicted
        budget, an explicit index supports the escalate policy's requeues.

        Queues are kept ordered by arrival: a requeued request (rider or
        escalated slice) carries its original arrival and must sit ahead of
        newer work, or the oldest-head dispatch rule and the batch_wait gate
        would under-serve exactly the hard-tail requests being time-sliced.
        Fresh submissions arrive in order, so the scan is O(1) for them."""
        i = self.bucket_of(req.budget) if bucket is None else bucket
        q = self._queues[i]
        if q and q[-1].arrival > req.arrival:
            pos = len(q)
            while pos > 0 and q[pos - 1].arrival > req.arrival:
                pos -= 1
            q.insert(pos, req)
        else:
            q.append(req)
        return i

    def depth(self) -> int:
        return sum(len(q) for q in self._queues)

    def head_arrival(self) -> float | None:
        heads = [q[0].arrival for q in self._queues if q]
        return min(heads) if heads else None

    def bucket_heads(self) -> list[tuple[float, int, int]]:
        """(head arrival, bucket index, head-kind batchable count) per
        non-empty bucket — the scheduler's dispatch-gating view."""
        out = []
        for i, q in enumerate(self._queues):
            if q:
                kind = q[0].kind
                n = sum(1 for r in q if r.kind == kind)
                out.append((q[0].arrival, i, n))
        return out

    # ------------------------------------------------------- batch forming ----
    def form_batch(self, bucket: int | None = None,
                   ) -> tuple[int, list[Request], int | None]:
        """Pop a same-kind batch of up to lane_width requests from `bucket`
        (default: the non-empty bucket with the oldest head — FIFO-fair
        across buckets). Returns (bucket index, requests, cap); requests is
        [] when idle."""
        live = [i for i, q in enumerate(self._queues) if q]
        if not live:
            return -1, [], None
        i = (min(live, key=lambda j: self._queues[j][0].arrival)
             if bucket is None else bucket)
        reqs = take_kind(self._queues[i], None, self.lane_width)
        cap = self.buckets[i]
        if not reqs:                  # explicitly-named bucket was empty
            return i, [], cap
        fill_to = self.width_for(len(reqs))
        if self.fill and len(reqs) < fill_to and cap is not None:
            # Riders take only the PAD lanes of the batch's natural ladder
            # width — widening the batch would make the resident requests
            # pay the riders' per-step cost (per-step cost scales with lane
            # width). Within the natural width they are free, resume-exact
            # progress, clamped to this bucket's cap. Eligibility requires
            # executed < cap: a rider that already reached this cap in an
            # earlier slice would be a no-op lane (dispatch cost, no
            # progress).
            kind = reqs[0].kind
            for j in range(i + 1, len(self._queues)):
                if len(reqs) == fill_to:
                    break
                reqs += take_kind(self._queues[j], kind,
                                  fill_to - len(reqs),
                                  pred=lambda r: r.executed < cap)
        return i, reqs, cap

    # ----------------------------------------------------------- assembly ----
    # `width=None` pads to the full lane_width; the scheduler passes
    # width_for(len(requests)) so partial batches run at their natural
    # (cheaper) shape.

    def pad_queries(self, requests: list[Request],
                    width: int | None = None) -> jnp.ndarray:
        width = self.lane_width if width is None else width
        q = np.stack([r.query for r in requests]).astype(np.float32)
        return jnp.asarray(np.pad(q, ((0, width - len(requests)), (0, 0))))

    def pad_spec(self, requests: list[Request], width: int | None = None):
        return batch_spec(requests,
                          self.lane_width if width is None else width)

    def pad_budgets(self, requests: list[Request], cap: int | None,
                    width: int | None = None) -> jnp.ndarray:
        """Per-lane budget targets: Ŵ_q clamped to the bucket cap; pad lanes
        get 0 and deactivate immediately."""
        b = np.zeros(self.lane_width if width is None else width, np.int32)
        for i, r in enumerate(requests):
            b[i] = r.budget if cap is None else min(r.budget, cap)
        return jnp.asarray(b)

    def pad_states(self, requests: list[Request],
                   width: int | None = None):
        """Assemble the carried states into one [lane_width, ...] batch
        state (zero states on pad lanes are inert under 0 budget).

        A request's `state` is a (batch SearchState, lane index) reference
        into the batch it last rode in — lanes are gathered here *per source
        batch* rather than sliced per request, which keeps the device-op
        count per assembled batch at a few× the leaf count instead of
        lanes× the leaf count (per-lane slicing dominated scheduler
        overhead on CPU)."""
        groups: dict[int, list] = {}
        for pos, r in enumerate(requests):
            st, lane = r.state
            groups.setdefault(id(st), [st, [], []])
            groups[id(st)][1].append(lane)
            groups[id(st)][2].append(pos)
        parts = [take_lanes(st, lanes) for st, lanes, _ in groups.values()]
        merged = parts[0] if len(parts) == 1 else concat_lanes(parts)
        order = [p for _, _, ps in groups.values() for p in ps]
        if order != list(range(len(order))):
            inv = np.empty(len(order), np.int32)
            inv[order] = np.arange(len(order), dtype=np.int32)
            merged = take_lanes(merged, inv)
        width = self.lane_width if width is None else width
        return pad_lanes(merged, width - len(requests))
