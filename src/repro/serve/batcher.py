"""Micro-batcher: fixed-shape lanes + budget-bucketed batch formation.

Two jobs, both about feeding a jitted lockstep engine from a ragged request
stream:

**Fixed-shape lanes.** `run_search` recompiles per batch shape, so every
micro-batch is padded to exactly `lane_width` lanes — one compile per
(predicate kind, phase) for the whole serving session. Pad lanes carry
all-zero queries/filters/states and a 0 NDC budget, so they deactivate on
their first step; the engine's shard path uses the same invariant.

**Budget buckets.** After the shared probe phase every request owns a
predicted budget Ŵ_q. In a lockstep batch the wall time is set by the
*largest* lane budget — mixing a Ŵ=8000 request into a batch of Ŵ=150
requests makes the easy lanes pay 50× their own cost (the batch-tail
misalignment of paper Fig. 3, recreated at serving level). The batcher
therefore keeps one FIFO queue per budget bucket (ascending NDC caps, last
unbounded) and forms batches within a bucket, so batchmates always have
comparable remaining work. A request whose Ŵ_q exceeds its bucket's cap
runs a bounded time slice and is requeued one bucket up with its carried
`SearchState` (the scheduler's preemption path) — no batch ever runs past
its bucket's budget.

Opportunistic fill: when a bucket batch has spare lanes, requests waiting in
*higher* buckets may ride along for a time slice capped at this bucket's
budget. They make bounded progress without extending the batch (their lane
budget is clamped to the cap) and are requeued upward afterwards.

Since filters are compiled predicate programs, batches mix requests of any
boolean structure — FIFO order alone decides who shares a batch. Program
rows are padded to a shared (slot, term) shape per batch, rounded up to a
power of two so the jit cache sees a bounded set of program shapes.

**Plan-keyed queues.** Under the planner (serve plan "auto"/"widen") a
probed request carries a chosen execution plan. Traverse and widen lanes
resume under *different* SearchConfigs (the widened frontier changes the
gather), so a resume batch must be plan-homogeneous: the bucket queues are
keyed by (plan, bucket) and opportunistic riders are drawn only from the
same plan's higher buckets. Scan-routed lanes never enter the batcher at
all — the scan plan is terminal and executes inside the ingress pump.
"""
from __future__ import annotations

from collections import deque

import jax.numpy as jnp
import numpy as np

from repro.core.state import concat_lanes, pad_lanes, take_lanes
from repro.filters.compile import stack_programs
from repro.serve.queue import Request, take_requests


def _pow2(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length())


class MicroBatcher:
    def __init__(self, lane_width: int = 16,
                 buckets: tuple = (256, 1024, 4096, None),
                 fill: bool = True, n_words: int | None = None,
                 n_values: int | None = None):
        if buckets[-1] is not None:
            buckets = tuple(buckets) + (None,)
        caps = [c for c in buckets[:-1]]
        if any(b >= a for a, b in zip(caps[1:], caps[:-1])):
            raise ValueError(f"bucket caps must be ascending: {buckets}")
        self.lane_width = lane_width
        # Engine attribute shapes for program compilation (the scheduler
        # passes them). They MUST match the engine: a mask compiled with
        # fewer words than the engine's label array broadcasts its word-0
        # bits across every word — silent false negatives, not a shape
        # error — so pad_program refuses to guess when they are unset.
        self.n_words = n_words
        self.n_values = n_values
        # A short ladder of lane widths bounds jit shapes while letting a
        # partial batch run at its natural width: on CPU/GPU the lockstep
        # per-step cost scales ~linearly with lane count, so an 8-wide
        # survivor batch costs half a 16-wide one — which is what makes
        # budget buckets cheaper than one tail-bound batch, not free lanes.
        self.lane_widths = tuple(sorted({max(1, lane_width // 4),
                                         max(1, lane_width // 2),
                                         lane_width}))
        self.buckets = tuple(buckets)
        self.fill = fill
        # (plan → bucket ladder); legacy requests (plan None) resume as
        # "traverse", so a planner-free deployment only ever populates one
        self.plans = ("traverse", "widen")
        self._queues: dict[str, list[deque[Request]]] = {
            p: [deque() for _ in buckets] for p in self.plans}

    def width_for(self, n: int) -> int:
        """Smallest configured lane width that fits `n` requests."""
        for w in self.lane_widths:
            if n <= w:
                return w
        return self.lane_width

    # ------------------------------------------------------------- routing ----
    def bucket_of(self, budget: int) -> int:
        """Smallest bucket whose cap covers `budget` (deterministic)."""
        for i, cap in enumerate(self.buckets):
            if cap is None or budget <= cap:
                return i
        raise AssertionError("unreachable: last bucket is unbounded")

    def enqueue(self, req: Request, bucket: int | None = None) -> int:
        """Queue a probed request; default routing is by its predicted
        budget, an explicit index supports the escalate policy's requeues.
        The queue ladder is the one for the request's chosen plan (None =
        legacy traverse).

        Queues are kept ordered by arrival: a requeued request (rider or
        escalated slice) carries its original arrival and must sit ahead of
        newer work, or the oldest-head dispatch rule and the batch_wait gate
        would under-serve exactly the hard-tail requests being time-sliced.
        Fresh submissions arrive in order, so the scan is O(1) for them."""
        plan = req.plan or "traverse"
        if plan not in self.plans:
            raise ValueError(f"plan {plan!r} cannot be bucketed "
                             f"(resumable plans: {self.plans})")
        i = self.bucket_of(req.budget) if bucket is None else bucket
        q = self._queues[plan][i]
        if q and q[-1].arrival > req.arrival:
            pos = len(q)
            while pos > 0 and q[pos - 1].arrival > req.arrival:
                pos -= 1
            q.insert(pos, req)
        else:
            q.append(req)
        return i

    def depth(self) -> int:
        return sum(len(q) for ladder in self._queues.values() for q in ladder)

    def head_arrival(self) -> float | None:
        heads = [q[0].arrival
                 for ladder in self._queues.values() for q in ladder if q]
        return min(heads) if heads else None

    def bucket_heads(self) -> list[tuple[float, tuple[str, int], int]]:
        """(head arrival, (plan, bucket index), batchable count) per
        non-empty bucket — the scheduler's dispatch-gating view. Any
        *structure* batches together (count = queue depth), but plans do
        not: each (plan, bucket) queue dispatches alone."""
        return [(q[0].arrival, (p, i), len(q))
                for p, ladder in self._queues.items()
                for i, q in enumerate(ladder) if q]

    # ------------------------------------------------------- batch forming ----
    def form_batch(self, bucket: tuple[str, int] | None = None,
                   ) -> tuple[tuple[str, int], list[Request], int | None]:
        """Pop a batch of up to lane_width requests from `bucket` — a
        (plan, index) pair (default: the non-empty bucket with the oldest
        head — FIFO-fair across plans and buckets). Compiled programs make
        batches structure-agnostic, so the FIFO prefix is taken as-is.
        Returns ((plan, bucket index), requests, cap); requests is [] when
        idle."""
        live = [(p, i) for p, ladder in self._queues.items()
                for i, q in enumerate(ladder) if q]
        if not live:
            return ("traverse", -1), [], None
        p, i = (min(live, key=lambda pi: self._queues[pi[0]][pi[1]][0].arrival)
                if bucket is None else bucket)
        ladder = self._queues[p]
        reqs = take_requests(ladder[i], self.lane_width)
        cap = self.buckets[i]
        if not reqs:                  # explicitly-named bucket was empty
            return (p, i), [], cap
        fill_to = self.width_for(len(reqs))
        if self.fill and len(reqs) < fill_to and cap is not None:
            # Riders take only the PAD lanes of the batch's natural ladder
            # width — widening the batch would make the resident requests
            # pay the riders' per-step cost (per-step cost scales with lane
            # width). Within the natural width they are free, resume-exact
            # progress, clamped to this bucket's cap. Eligibility requires
            # executed < cap: a rider that already reached this cap in an
            # earlier slice would be a no-op lane (dispatch cost, no
            # progress). Riders come from the SAME plan's higher buckets
            # only — a widen lane cannot ride a traverse batch (different
            # SearchConfig).
            for j in range(i + 1, len(ladder)):
                if len(reqs) == fill_to:
                    break
                reqs += take_requests(ladder[j],
                                      fill_to - len(reqs),
                                      pred=lambda r: r.executed < cap)
        return (p, i), reqs, cap

    # ----------------------------------------------------------- assembly ----
    # `width=None` pads to the full lane_width; the scheduler passes
    # width_for(len(requests)) so partial batches run at their natural
    # (cheaper) shape.

    def pad_queries(self, requests: list[Request],
                    width: int | None = None) -> jnp.ndarray:
        width = self.lane_width if width is None else width
        q = np.stack([r.query for r in requests]).astype(np.float32)
        return jnp.asarray(np.pad(q, ((0, width - len(requests)), (0, 0))))

    def pad_program(self, requests: list[Request], width: int | None = None):
        """Stack per-request compiled programs into one [width, S, ...]
        batch program. Slot/term counts pad to the batch max rounded up to
        a power of two (bounded jit shapes across heterogeneous batches);
        pad lanes get match-nothing rows — inert under their 0 NDC budget.
        """
        from repro.filters.compile import compile_query

        progs = []
        for r in requests:
            if r.program is None:  # scheduler stamps this at submit
                if self.n_words is None or self.n_values is None:
                    raise ValueError(
                        "MicroBatcher needs n_words/n_values matching the "
                        "engine to compile filter programs — construct it "
                        "with the engine's attribute shapes")
                r.program = compile_query(r.get_expr(), self.n_words,
                                          self.n_values)
            progs.append(r.program)
        s = _pow2(max(p.n_slots for p in progs))
        t = _pow2(max(p.n_terms for p in progs))
        return stack_programs(progs, n_slots=s, n_terms=t,
                              pad_to=self.lane_width if width is None else width)

    def pad_budgets(self, requests: list[Request], cap: int | None,
                    width: int | None = None) -> jnp.ndarray:
        """Per-lane budget targets: Ŵ_q clamped to the bucket cap; pad lanes
        get 0 and deactivate immediately."""
        b = np.zeros(self.lane_width if width is None else width, np.int32)
        for i, r in enumerate(requests):
            b[i] = r.budget if cap is None else min(r.budget, cap)
        return jnp.asarray(b)

    def pad_states(self, requests: list[Request],
                   width: int | None = None):
        """Assemble the carried states into one [lane_width, ...] batch
        state (zero states on pad lanes are inert under 0 budget).

        A request's `state` is a (batch SearchState, lane index) reference
        into the batch it last rode in — lanes are gathered here *per source
        batch* rather than sliced per request, which keeps the device-op
        count per assembled batch at a few× the leaf count instead of
        lanes× the leaf count (per-lane slicing dominated scheduler
        overhead on CPU)."""
        groups: dict[int, list] = {}
        for pos, r in enumerate(requests):
            st, lane = r.state
            groups.setdefault(id(st), [st, [], []])
            groups[id(st)][1].append(lane)
            groups[id(st)][2].append(pos)
        parts = [take_lanes(st, lanes) for st, lanes, _ in groups.values()]
        merged = parts[0] if len(parts) == 1 else concat_lanes(parts)
        order = [p for _, _, ps in groups.values() for p in ps]
        if order != list(range(len(order))):
            inv = np.empty(len(order), np.int32)
            inv[order] = np.arange(len(order), dtype=np.int32)
            merged = take_lanes(merged, inv)
        width = self.lane_width if width is None else width
        return pad_lanes(merged, width - len(requests))
