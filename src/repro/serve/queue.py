"""Request model + admission layer (serving lifecycle stage 1).

A `Request` is one (query vector, filter) pair with an arrival timestamp and
an optional latency deadline. The `AdmissionQueue` is the system's only
*bounded* queue: it sheds load when full (backpressure — the caller gets a
`False` and is expected to retry/degrade upstream) and rejects requests whose
deadline already expired on arrival. Everything behind admission (bucket
queues) is unbounded: admitted work is always finished.

Timestamps are plain floats in caller-defined units. The scheduler never
reads a wall clock itself — `launch/serve.py` feeds `time.perf_counter()`
deltas, while `benchmarks/serve_bench.py` feeds a simulated open-loop clock
driven by measured service times. Both exercise identical scheduling code.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.filters.predicates import FilterSpec, PRED_RANGE


@dataclasses.dataclass
class Request:
    """One filtered-AKNN request plus its scheduling lifecycle state."""

    rid: int
    query: np.ndarray                 # [d] float32
    kind: int                         # predicate tag (static per request)
    label_mask: np.ndarray | None = None   # [W] uint32 (label predicates)
    range_lo: float | None = None          # (range predicate)
    range_hi: float | None = None
    arrival: float | None = None      # stamped at submit() when unset
    deadline: float | None = None     # absolute time; None = best-effort

    # -- lifecycle, owned by the scheduler --
    state: tuple | None = None        # carried traversal state: a (batch
                                      # SearchState, lane index) reference
                                      # into the micro-batch it last rode in
    budget: int | None = None         # Ŵ_q once estimated
    executed: int = 0                 # budget target reached so far
    n_slices: int = 0                 # resume batches this request rode in
    probe_done: float | None = None
    completed: float | None = None
    cache_hit: bool = False
    res_idx: np.ndarray | None = None  # [k] final top-k ids
    res_dist: np.ndarray | None = None
    ndc: int | None = None


def requests_from_workload(wl, start_rid: int = 0, arrivals=None,
                           deadline: float | None = None) -> list[Request]:
    """Explode a batched QueryWorkload into per-request objects."""
    out = []
    for i in range(wl.batch):
        kind = wl.spec.kind
        if kind == PRED_RANGE:
            req = Request(rid=start_rid + i, query=wl.queries[i], kind=kind,
                          range_lo=float(wl.spec.range_lo[i]),
                          range_hi=float(wl.spec.range_hi[i]))
        else:
            req = Request(rid=start_rid + i, query=wl.queries[i], kind=kind,
                          label_mask=np.asarray(wl.spec.label_masks[i]))
        if arrivals is not None:
            req.arrival = float(arrivals[i])
        if deadline is not None:
            if arrivals is None:
                raise ValueError("a relative deadline requires explicit "
                                 "arrivals")
            req.deadline = float(arrivals[i]) + deadline
        out.append(req)
    return out


def batch_spec(requests: list[Request], pad_to: int) -> FilterSpec:
    """Stack single-request filters (all the same kind) into a padded batch
    spec. Pad lanes get all-zero filters — they are inert because the batcher
    assigns them a 0 NDC budget."""
    kind = requests[0].kind
    pad = pad_to - len(requests)
    assert pad >= 0 and all(r.kind == kind for r in requests)
    if kind == PRED_RANGE:
        lo = np.asarray([r.range_lo for r in requests], np.float32)
        hi = np.asarray([r.range_hi for r in requests], np.float32)
        return FilterSpec(kind, None, np.pad(lo, (0, pad)), np.pad(hi, (0, pad)))
    masks = np.stack([r.label_mask for r in requests]).astype(np.uint32)
    return FilterSpec(kind, np.pad(masks, ((0, pad), (0, 0))), None, None)


def take_kind(q: deque, kind: int | None, limit: int, pred=None,
              ) -> list[Request]:
    """Pop up to `limit` same-kind requests from a deque, preserving FIFO
    order within the kind (the traversal config is static per predicate
    kind, so a micro-batch cannot mix kinds). kind=None adopts the first
    eligible request's kind; `pred` optionally restricts eligibility.
    Shared by the admission queue and the bucket batcher — the
    pull-from-anywhere-FIFO invariant lives in exactly one place."""
    taken, kept = [], deque()
    while q:
        r = q.popleft()
        if (len(taken) < limit and (kind is None or r.kind == kind)
                and (pred is None or pred(r))):
            taken.append(r)
            kind = r.kind
        else:
            kept.append(r)
    q.extend(kept)
    return taken


class AdmissionQueue:
    """Bounded FIFO ingress with deadline-aware admission control."""

    def __init__(self, capacity: int = 256):
        self.capacity = capacity
        self._q: deque[Request] = deque()
        self.n_shed = 0        # rejected: queue full (backpressure)
        self.n_expired = 0     # rejected: deadline already passed

    def __len__(self) -> int:
        return len(self._q)

    def head_arrival(self) -> float | None:
        return self._q[0].arrival if self._q else None

    def offer(self, req: Request, now: float) -> bool:
        if req.deadline is not None and now > req.deadline:
            self.n_expired += 1
            return False
        if len(self._q) >= self.capacity:
            self.n_shed += 1
            return False
        self._q.append(req)
        return True

    def take_kind_group(self, limit: int) -> list[Request]:
        """Pop up to `limit` requests sharing the head's predicate kind."""
        return take_kind(self._q, None, limit)
