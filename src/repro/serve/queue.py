"""Request model + admission layer (serving lifecycle stage 1).

A `Request` is one (query vector, filter expression) pair with an arrival
timestamp and an optional latency deadline. Filters are filter-algebra
expressions (`repro.filters.expr`) — arbitrary And/Or/Not compositions; the
legacy (kind, label_mask / range) fields remain as constructor sugar and are
lowered to an expression on construction. Because the engine compiles any
batch of expressions into one fixed-shape predicate program, the scheduler
batches requests of *different boolean structure* into the same lanes —
there is no same-kind batching restriction anywhere in the serving path.

The `AdmissionQueue` is the system's only *bounded* queue: it sheds load
when full (backpressure — the caller gets a `False` and is expected to
retry/degrade upstream) and rejects requests whose deadline already expired
on arrival. Everything behind admission (bucket queues) is unbounded:
admitted work is always finished.

Timestamps are plain floats in caller-defined units. The scheduler never
reads a wall clock itself — `launch/serve.py` feeds `time.perf_counter()`
deltas, while `benchmarks/serve_bench.py` feeds a simulated open-loop clock
driven by measured service times. Both exercise identical scheduling code.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.filters.expr import Contain, Equal, Expr, Range, labels_from_mask
from repro.filters.predicates import PRED_CONTAIN, PRED_EQUAL, PRED_RANGE


@dataclasses.dataclass
class Request:
    """One filtered-AKNN request plus its scheduling lifecycle state."""

    rid: int
    query: np.ndarray                 # [d] float32
    kind: int | None = None           # legacy predicate tag (sugar)
    label_mask: np.ndarray | None = None   # [W] uint32 (legacy label sugar)
    range_lo: float | None = None          # (legacy range sugar)
    range_hi: float | None = None
    expr: Expr | None = None          # the filter; derived from the legacy
                                      # fields when not given directly
    arrival: float | None = None      # stamped at submit() when unset
    deadline: float | None = None     # absolute time; None = best-effort

    # -- lifecycle, owned by the scheduler --
    state: tuple | None = None        # carried traversal state: a (batch
                                      # SearchState, lane index) reference
                                      # into the micro-batch it last rode in
    program: object | None = None     # compiled single-query FilterProgram
                                      # (stamped by the scheduler at submit)
    cache_key: str | None = None      # memoized result-cache key (valid for
                                      # one scheduler's parameter set)
    budget: int | None = None         # Ŵ_q once estimated
    plan: str | None = None           # chosen execution plan (planner mode):
                                      # "scan" | "traverse" | "widen"; None
                                      # until routed (legacy = traverse)
    plan_pure: bool = False           # the executed path is bitwise the
                                      # forced-plan path (no probe carry
                                      # leaked into a scan) — gates the
                                      # cache dual-put under the forced key
    executed: int = 0                 # budget target reached so far
    n_slices: int = 0                 # resume batches this request rode in
    probe_done: float | None = None
    completed: float | None = None
    cache_hit: bool = False
    trace_id: str = ""                # obs lifecycle trace id (stamped at
                                      # submit when the scheduler traces)
    features: np.ndarray | None = None  # [F] probe feature vector the budget
                                      # prediction was made from (calibration)
    probe_ndc: int = 0                # NDC spent by the probe prefix
    res_idx: np.ndarray | None = None  # [k] final top-k ids
    res_dist: np.ndarray | None = None
    ndc: int | None = None

    def __post_init__(self):
        if self.expr is None and (self.label_mask is not None
                                  or self.range_lo is not None):
            self.expr = self._legacy_expr()

    def get_expr(self) -> Expr:
        """The filter expression, deriving from legacy fields on demand
        (callers may populate label_mask / range bounds post-construction)."""
        if self.expr is None:
            self.expr = self._legacy_expr()
        return self.expr

    def _legacy_expr(self) -> Expr:
        if self.kind == PRED_RANGE:
            return Range(float(self.range_lo), float(self.range_hi))
        if self.kind in (PRED_CONTAIN, PRED_EQUAL):
            leaf = Contain if self.kind == PRED_CONTAIN else Equal
            return leaf(labels_from_mask(self.label_mask))
        raise ValueError(
            f"request {self.rid}: provide expr= or a legacy predicate kind")


def requests_from_workload(wl, start_rid: int = 0, arrivals=None,
                           deadline: float | None = None) -> list[Request]:
    """Explode a batched QueryWorkload into per-request objects."""
    out = []
    exprs = getattr(wl, "exprs", None)
    for i in range(wl.batch):
        if exprs is not None:
            req = Request(rid=start_rid + i, query=wl.queries[i],
                          expr=exprs[i])
        else:
            kind = wl.spec.kind
            if kind == PRED_RANGE:
                req = Request(rid=start_rid + i, query=wl.queries[i],
                              kind=kind,
                              range_lo=float(wl.spec.range_lo[i]),
                              range_hi=float(wl.spec.range_hi[i]))
            else:
                req = Request(rid=start_rid + i, query=wl.queries[i],
                              kind=kind,
                              label_mask=np.asarray(wl.spec.label_masks[i]))
        if arrivals is not None:
            req.arrival = float(arrivals[i])
        if deadline is not None:
            if arrivals is None:
                raise ValueError("a relative deadline requires explicit "
                                 "arrivals")
            req.deadline = float(arrivals[i]) + deadline
        out.append(req)
    return out


def take_requests(q: deque, limit: int, pred=None) -> list[Request]:
    """Pop up to `limit` requests from a deque in FIFO order; `pred`
    optionally restricts eligibility (ineligible requests keep their
    position). Shared by the admission queue and the bucket batcher.

    Compiled predicate programs make micro-batches structure-agnostic, so
    unlike the pre-algebra serving path there is no same-kind constraint —
    any FIFO prefix batches together.
    """
    taken, kept = [], deque()
    while q:
        r = q.popleft()
        if len(taken) < limit and (pred is None or pred(r)):
            taken.append(r)
        else:
            kept.append(r)
    q.extend(kept)
    return taken


class AdmissionQueue:
    """Bounded FIFO ingress with deadline-aware admission control."""

    def __init__(self, capacity: int = 256):
        self.capacity = capacity
        self._q: deque[Request] = deque()
        self.n_shed = 0        # rejected: queue full (backpressure)
        self.n_expired = 0     # rejected: deadline already passed

    def __len__(self) -> int:
        return len(self._q)

    def head_arrival(self) -> float | None:
        return self._q[0].arrival if self._q else None

    def offer(self, req: Request, now: float) -> bool:
        if req.deadline is not None and now > req.deadline:
            self.n_expired += 1
            return False
        if len(self._q) >= self.capacity:
            self.n_shed += 1
            return False
        self._q.append(req)
        return True

    def take_group(self, limit: int) -> list[Request]:
        """Pop up to `limit` requests (any filter structure) FIFO."""
        return take_requests(self._q, limit)
