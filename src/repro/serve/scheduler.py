"""Cost-aware scheduler: probe → estimate → bucket → resume/requeue.

Turns the paper's per-query cost signal Ŵ_q into *system* behavior. The
request lifecycle:

  admit      bounded AdmissionQueue (backpressure + deadline checks), with a
             result-cache lookup in front; the filter expression is
             compiled to its canonical predicate program here, once
  probe      micro-batch of requests — *any* mix of filter structures, the
             compiled programs are batch-uniform — runs the shared early
             probe (the first f NDCs of the real traversal — identical code
             path to `e2e_search`)
  estimate   GBDT on probe features → Ŵ_q per request (`predict_budgets`,
             the exact stage-2 path of the one-shot pipeline)
  bucket     requests routed to budget buckets; each request carries its
             live per-lane `SearchState` out of the probe batch
  resume     a bucket batch resumes its lanes with budget min(Ŵ_q, cap) —
             batchmates always have comparable remaining work, so no easy
             lane ever waits on a batch tail
  requeue    lanes with Ŵ_q > cap ran a bounded time slice; their carried
             state is requeued one bucket up (preemption). Because the
             traversal is resume-exact, the final top-k is bit-identical to
             a one-shot `e2e_search` at the same α no matter how the work
             was sliced (tests/test_serve.py pins this).

The scheduler is clock-agnostic: callers pass `now` into submit()/pump() and
service time is measured with the injected `timer` around real engine work.
`launch/serve.py` drives it with a wall clock; `benchmarks/serve_bench.py`
drives an open-loop simulated clock off the measured service times.

Routing policies:
  direct    (default) each probed request goes to the smallest bucket whose
            cap covers Ŵ_q — one resume slice unless it rode an
            opportunistic fill.
  escalate  multilevel-feedback: every request starts in the shortest
            bucket and climbs on requeue — hard queries are time-sliced,
            which bounds every batch's wall time at the cost of extra
            slices (useful when the estimator's tail is untrusted).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.e2e import predict_budgets, probe_and_features
from repro.core.engine import SearchEngine
from repro.core.planner import (PLANS, choose_plans, scan_stats,
                                stage0_scan_mask)
from repro.core.plans import ScanStats, scan_search
from repro.core.search import SearchConfig
from repro.core.state import pad_lanes, take_lanes
from repro.serve.batcher import MicroBatcher
from repro.serve.cache import ResultCache, request_key
from repro.serve.metrics import ServeMetrics
from repro.serve.queue import AdmissionQueue, Request


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    lane_width: int = 16
    buckets: tuple = (256, 1024, 4096, None)
    policy: str = "direct"           # "direct" | "escalate"
    fill: bool = True                # opportunistic fill of spare lanes
    queue_capacity: int = 256
    batch_wait: float = 0.0          # dispatch a partial batch only after
                                     # its head waited this long (0 = eager)
    probe_budget: int = 64
    n_probes: int = 2
    alpha: float = 1.5
    min_budget: int = 32
    max_budget: int = 1 << 30
    ablate_filter: bool = False
    cache_capacity: int = 4096       # 0 disables the result cache
    plan: str = "traverse"           # execution plan: "traverse" (legacy
                                     # E2E pipeline), "scan" / "widen"
                                     # (forced single plan), or "auto"
                                     # (per-lane planner routing — needs a
                                     # fitted core.planner.Planner)


class CostAwareScheduler:
    def __init__(self, engine: SearchEngine, estimator, cfg: SearchConfig,
                 serve_cfg: ServeConfig = ServeConfig(),
                 timer=time.perf_counter, service_model=None, planner=None,
                 tracer=None, calibration: bool = True, drift=True):
        """service_model: optional callable (trip count, lane width) →
        seconds. When set, pump() charges batches by the model instead of
        the wall clock — a calibrated virtual clock that makes scheduling
        simulations deterministic on machines whose speed drifts (see
        benchmarks/serve_bench.py). Real engine work still runs either way;
        only the *charged* service time differs. (Scan batches have no
        lockstep trips; under a service model they charge ⌈σ·N / (lane
        degree)⌉ equivalent trips, the same distance work per lane.)

        planner: a fitted `core.planner.Planner`; required when
        serve_cfg.plan is "auto" or "widen" (those route on its cost
        heads), ignored for "traverse" (the legacy `estimator` head) and
        "scan" (closed-form).

        tracer: optional `obs.Tracer`. Requests get trace ids at submit;
        spans cover admit → probe → estimate → plan-select → resume
        slices (per-launch spans from the persistent driver) → complete.
        Spans wrap only host dispatch boundaries that already exist, so
        results are bit-identical with tracing on vs. off.

        calibration: record (features, predicted Ŵ_q, actual NDC, plan)
        per completed non-cache-hit request into `self.calibration` (a
        `obs.CalibrationMonitor`) — the log online recalibration trains
        from. Costs one feature-matrix device→host copy per probe batch,
        outside every launch loop.

        drift: watch the calibration log with an `obs.DriftMonitor`
        (True → default thresholds, a `DriftConfig` → custom, False →
        off; requires calibration). `drift_report()` / `status()` /
        `prometheus()` surface its alarm — the documented trigger for
        the future online-recalibration trainer. The monitor only runs
        when one of those is called: the pump path never pays for it."""
        if serve_cfg.policy not in ("direct", "escalate"):
            raise ValueError(f"unknown policy {serve_cfg.policy!r}")
        if serve_cfg.plan not in PLANS + ("auto",):
            raise ValueError(f"unknown plan {serve_cfg.plan!r} "
                             f"(one of {PLANS + ('auto',)})")
        if planner is None and serve_cfg.plan in ("auto", "widen"):
            raise ValueError(f"plan {serve_cfg.plan!r} needs a fitted "
                             "core.planner.Planner")
        self.engine = engine
        self.service_model = service_model
        self.estimator = estimator
        self.planner = planner
        self.cfg = cfg
        self.cfg_widen = dataclasses.replace(cfg, mode="widen")
        self.scfg = serve_cfg
        self.timer = timer
        self.ingress = AdmissionQueue(serve_cfg.queue_capacity)
        self.batcher = MicroBatcher(serve_cfg.lane_width, serve_cfg.buckets,
                                    serve_cfg.fill,
                                    n_words=engine.n_words,
                                    n_values=engine.n_values)
        self.cache = (ResultCache(serve_cfg.cache_capacity)
                      if serve_cfg.cache_capacity else None)
        self.metrics = ServeMetrics()
        # GBDT forests, packed once per scheduler; which ones exist depends
        # on the configured plan
        self._packed = (estimator.packed()
                        if serve_cfg.plan == "traverse" else None)
        if planner is not None and serve_cfg.plan in ("auto", "widen"):
            self._packed_t = planner.traverse.packed()
            self._packed_w = planner.widen.packed()
            self._packed_s = planner.static.packed()
        # precision is a per-engine deployment knob: the codec identity is
        # part of every cache key (resolved against THIS scheduler's cfg,
        # so a per-call precision override keys under what actually runs),
        # and quantized engines rerank finished lanes with exact float32
        # before results leave the scheduler
        self._codec = engine.codec_key(cfg)
        self._rerank = engine.effective_precision(cfg) != "float32"
        # index-sharded engines (core.sharded) report their layout through
        # the serving summary: per-shard budget splitting means a request's
        # NDC spreads over n_shards traversals, which capacity planning
        # needs to see. 1 = unsharded.
        self._n_shards = int(getattr(engine, "n_shards", 1))
        from repro.core.search import get_backend
        from repro.obs.calibration import CalibrationMonitor
        from repro.obs.drift import DriftConfig, DriftMonitor
        from repro.obs.trace import as_tracer
        self._persistent = getattr(
            get_backend(cfg.backend or engine.backend or "dense"),
            "persistent", False)
        self.tracer = tracer
        self._tr = as_tracer(tracer)
        self.calibration = CalibrationMonitor() if calibration else None
        self.drift_monitor = None
        if calibration and drift:
            self.drift_monitor = DriftMonitor(
                drift if isinstance(drift, DriftConfig) else None)

    def _launches0(self) -> int:
        """Persistent-driver dispatch counter snapshot (pump sites diff two
        snapshots around their engine work to get driver-observed launch
        counts; 0-cost for non-persistent backends, which never touch the
        counter)."""
        from repro.core.search import dispatch_counters

        return dispatch_counters()["launches"]

    def _launch_stats(self, steps: int, lane_steps,
                      observed: int | None = None) -> tuple[int, float]:
        """Dispatch accounting for one lockstep batch. On a persistent
        backend `observed` (a driver dispatch-counter delta around this
        batch's engine work) is ground truth — the old ⌈steps /
        steps_per_launch⌉ estimate undercounts because a probe dispatches
        once per snapshot (n_probes launches minimum) and the compaction
        ladder relaunches at reduced widths. Single-step backends pay one
        launch per trip. `early_exit_frac` is the fraction of real lanes
        that finished before the batch's slowest — the lanes the in-launch
        early exit stops paying for."""
        if self._persistent and observed is not None:
            launches = int(observed)
            if launches == 0 and steps <= 0:
                return 0, 0.0
        elif steps <= 0:
            return 0, 0.0
        else:
            spl = max(1, self.cfg.steps_per_launch)
            launches = -(-steps // spl) if self._persistent else steps
        lane_steps = np.asarray(lane_steps)
        early = (float(np.mean(lane_steps < steps))
                 if lane_steps.size and steps > 0 else 0.0)
        return launches, early

    def _observe_shards(self, out, entry, n_real: int) -> None:
        """Per-shard NDC deltas for one pump's real lanes (sharded engines
        only). `out`/`entry` are the batch's exit/entry states; entry=None
        means the batch started from scratch. Reads the per-shard [B, S]
        counter the merge already computed — no new dispatch, one small
        host copy on a batch the pump has already blocked on. Summed over
        pumps these telescope to exactly Σ completed-request NDC (the
        PR-8 accounting contract carried into serving telemetry)."""
        sh = getattr(out, "shard", None)
        if sh is None or n_real <= 0:
            return
        cnt = np.asarray(sh.cnt)[:n_real]              # [n_real, S]
        if entry is not None:
            cnt = cnt - np.asarray(entry.shard.cnt)[:n_real]
        self.metrics.observe_shard_ndc(cnt.sum(axis=0))

    def _observe_shard_bitmap(self, stats, n_real: int) -> None:
        """Per-shard popcounts of one freshly compiled filter bitmap
        (sharded engines only): slice the [B, N] validity mask at the
        engine's shard offsets. Called once per ScanStats compilation, so
        each admitted filter row is counted exactly once."""
        if self._n_shards <= 1 or n_real <= 0:
            return
        ns = self.engine.shard_size
        offs = self.engine.offsets
        valid = np.asarray(stats.valid[:n_real])
        counts = [int(valid[:, int(offs[s]):int(offs[s]) + ns].sum())
                  for s in range(self._n_shards)]
        self.metrics.observe_shard_bitmap(counts)

    # ------------------------------------------------------------- ingress ----
    def _key_for(self, req: Request, plan: str) -> str:
        s = self.scfg
        return request_key(
            req, self.cfg.k, self.cfg.queue_size, s.alpha,
            s.probe_budget, s.min_budget, s.max_budget, s.n_probes,
            s.ablate_filter, codec=self._codec, plan=plan)

    def _key(self, req: Request) -> str:
        # memoized on the request: the canonical-DNF serialization inside
        # request_key is a recursive Python walk, and the key is needed
        # twice per served request (submit lookup + completion put)
        if req.cache_key is None:
            req.cache_key = self._key_for(req, self.scfg.plan)
        return req.cache_key

    def submit(self, req: Request, now: float) -> str:
        """Returns "hit" | "queued" | "shed" | "expired"."""
        req.arrival = now if req.arrival is None else req.arrival
        if self.tracer is not None and not req.trace_id:
            req.trace_id = self._tr.new_trace("req")
        if self.cache is not None:
            # keyed on the canonical expression, so hits never pay compile
            hit = self.cache.get(self._key(req))
            if hit is not None:
                req.res_idx, req.res_dist, req.ndc = hit
                req.cache_hit = True
                req.completed = now
                self._tr.emit("complete", req.trace_id, rid=req.rid,
                              cache_hit=True, ndc=int(req.ndc))
                self.metrics.complete(req)
                return "hit"
        if req.program is None and len(self.ingress) < self.ingress.capacity:
            # compile once per request, BEFORE admission: an expression the
            # compiler rejects (label outside the alphabet, DNF blow-up)
            # must raise here, while nothing is queued — compiling after
            # offer() would leave a poisoned request that crashes the pump.
            # Every micro-batch the request rides in stacks this row (the
            # canonical DNF makes it deterministic). The capacity pre-check
            # keeps the overload shed path O(1): a request the bounded
            # queue is about to reject never pays the DNF walk.
            from repro.filters.compile import compile_query

            req.program = compile_query(req.get_expr(), self.engine.n_words,
                                        self.engine.n_values)
        if not self.ingress.offer(req, now):
            status = ("expired" if (req.deadline is not None
                                    and now > req.deadline) else "shed")
            self._tr.emit("admit", req.trace_id, rid=req.rid, status=status)
            return status
        self._tr.emit("admit", req.trace_id, rid=req.rid, status="queued")
        return "queued"

    def has_work(self) -> bool:
        return bool(len(self.ingress) or self.batcher.depth())

    def depth(self) -> int:
        return len(self.ingress) + self.batcher.depth()

    # --------------------------------------------------------------- pump ----
    def _dispatchable(self, now: float):
        """All queues holding work, as (head arrival, target) where target
        is "probe" or a bucket index — filtered by the batching gate: a
        batch dispatches when it can fill its lanes or when its head has
        waited `batch_wait` (anti-fragmentation: padded lanes cost the same
        lockstep compute as real ones, so eagerly dispatching slim batches
        shreds throughput)."""
        heads = []
        if len(self.ingress):
            # probe batches are never gated: a probe costs probe_budget NDC
            # per lane (≪ any bucket cap), so slim probe batches are cheap,
            # and eager probing routes work into buckets sooner — which is
            # what fills the expensive batches. (Under a scan/auto plan the
            # ingress pump may also *execute* scan lanes — still ungated:
            # those lanes are exactly the cheap ones.)
            heads.append((self.ingress.head_arrival(), "probe",
                          self.batcher.lane_width))
        for arrival, i, n in self.batcher.bucket_heads():
            heads.append((arrival, i, n))
        ready = [(a, t) for a, t, n in heads
                 if n >= self.batcher.lane_width
                 or now - a >= self.scfg.batch_wait]
        return ready, heads

    def next_deadline(self) -> float | None:
        """Earliest time a currently-gated batch becomes dispatchable (the
        driver's idle-advance target); None when no work is queued."""
        _, heads = self._dispatchable(float("inf"))
        if not heads:
            return None
        return min(a for a, _, _ in heads) + self.scfg.batch_wait

    def pump(self, now: float) -> tuple[list[Request], float]:
        """Execute one micro-batch: among dispatchable queues the oldest
        head wins, so probe work and bucket work interleave FIFO-fair.
        Returns (completed requests, measured busy seconds); completions
        are stamped at now + busy. (([], 0.0) means every queued batch is
        still gated — advance the clock to `next_deadline()`.)"""
        self.metrics.observe_depth(now, self.depth())
        ready, _ = self._dispatchable(now)
        if not ready:
            return [], 0.0
        # oldest head wins; on arrival ties probe work goes first (it feeds
        # the bucket queues, improving downstream batch fill)
        target = min(ready, key=lambda x: (x[0], x[1] != "probe"))[1]
        if target == "probe":
            return self._pump_probe(now)
        return self._pump_bucket(now, target)

    def run_until_idle(self, now: float) -> float:
        """Drain all queued work; returns the advanced clock."""
        while self.has_work():
            _, busy = self.pump(now)
            if busy > 0:
                now += busy
            else:
                # everything gated on batch_wait — jump to the deadline
                now = max(now, self.next_deadline())
        return now

    # ---------------------------------------------------------- internals ----
    def _final_results(self, queries, state, any_finish: bool = True):
        """Result arrays lanes finish with: the raw traversal buffers at
        float32 precision, the exact-reranked pool on a quantized engine.

        The rerank runs on the whole batch (it is jitted and costs a
        constant ≤ (M+K) float32 distances per lane — small next to any
        bucket's traversal work), but only when some lane actually
        finishes in this pump (`any_finish` — an escalate-policy slice
        whose every lane requeues would discard the whole computation).
        Lanes that continue keep their carried state untouched, so resumes
        stay in the compressed domain and the scheduled result remains
        bit-identical to one-shot `e2e_search`, whose terminal rerank sees
        the same per-lane pools.
        """
        if self._rerank and any_finish:
            rd, ri = self.engine.rerank_arrays(queries, state)
            return np.asarray(ri), np.asarray(rd)
        return np.asarray(state.res_idx), np.asarray(state.res_dist)

    def _pump_probe(self, now: float) -> tuple[list[Request], float]:
        """Ingress pump. Under the legacy/forced-traversal plans this is
        the shared early probe; under "scan" it executes the terminal scan
        plan directly (no probe — the bitmap makes σ exact for free); under
        "auto" it is the planner's two-stage router."""
        scfg = self.scfg
        reqs = self.ingress.take_group(self.batcher.lane_width)
        if scfg.plan == "scan":
            for r in reqs:
                r.plan, r.plan_pure = "scan", True
            return self._scan_batch(now, reqs, None)
        if scfg.plan == "auto":
            return self._pump_auto(now, reqs)
        cfg = self.cfg  # one static config serves every filter structure
        t0 = self.timer()
        bt = self._tr.new_trace("probe") if self.tracer is not None else ""
        l0 = self._launches0()
        width = self.batcher.width_for(len(reqs))
        queries = self.batcher.pad_queries(reqs, width)
        prog = self.batcher.pad_program(reqs, width)
        lane_on = np.zeros(width, np.int32)
        lane_on[: len(reqs)] = 1

        # Stage 1 — the shared early probe, via the same probe_and_features
        # as the one-shot pipeline (per-lane budget array: pad lanes get 0).
        # Sharing the code, not just the schedule, is what keeps the
        # scheduled == one-shot bit-identity from desynchronizing. The
        # probe always runs the *post* config — the widen plan, like
        # run_plan("widen"), widens only the resume.
        st, feats = probe_and_features(
            self.engine, cfg, queries, prog,
            jnp.asarray(lane_on * scfg.probe_budget), n_probes=scfg.n_probes,
            tracer=self.tracer, trace_id=bt)

        # Stage 2 — cost estimate (same path as one-shot e2e_search /
        # run_plan): the legacy estimator for traverse, the planner's widen
        # head for the forced widen plan.
        head, packed = ((self.estimator, self._packed)
                        if scfg.plan == "traverse"
                        else (self.planner.widen, self._packed_w))
        with self._tr.span("estimate", bt, lanes=len(reqs)):
            budgets, _ = predict_budgets(head, feats, scfg.alpha,
                                         scfg.min_budget, scfg.max_budget,
                                         scfg.ablate_filter, packed=packed)
            budgets = np.asarray(jax.block_until_ready(budgets))
        cnt = np.asarray(st.cnt)
        self._observe_shards(st, None, len(reqs))
        res_idx, res_dist = self._final_results(
            queries, st,
            any(int(budgets[i]) <= int(cnt[i]) for i in range(len(reqs))))
        lane_hops = np.asarray(st.hops)[: len(reqs)]
        steps = int(np.asarray(st.hops).max())  # lockstep trip count
        busy = (self.timer() - t0 if self.service_model is None
                else self.service_model(steps, width))
        launches, early = self._launch_stats(steps, lane_hops,
                                             observed=self._launches0() - l0)
        self.metrics.observe_batch("probe", len(reqs), width, busy, steps,
                                   launches=launches, early_exit_frac=early)
        feats_h = np.asarray(feats) if self.calibration is not None else None

        done = []
        for i, r in enumerate(reqs):
            r.plan, r.plan_pure = scfg.plan, True
            r.budget = int(budgets[i])
            r.probe_done = now + busy
            r.executed = int(cnt[i])
            r.probe_ndc = int(cnt[i])
            if feats_h is not None:
                r.features = feats_h[i]
            self._tr.emit("probe-done", r.trace_id, rid=r.rid, batch=bt,
                          budget=r.budget, probe_ndc=r.probe_ndc,
                          plan=str(r.plan))
            if r.budget <= r.executed:
                # the estimator says the probe already saw enough — the
                # one-shot pipeline's resume would be a no-op for this lane
                self._finish(r, res_idx[i], res_dist[i], cnt[i], now + busy)
                done.append(r)
            else:
                r.state = (st, i)   # lane reference into the probe batch
                bucket = (0 if self.scfg.policy == "escalate" else None)
                self.batcher.enqueue(r, bucket)
        return done, busy

    def _pump_auto(self, now: float, reqs: list[Request],
                   ) -> tuple[list[Request], float]:
        """Planner routing (plan="auto"): stage 0 compiles the bitmap and
        routes clearly-scannable lanes to scan *without probing*; the rest
        run the shared probe and split on the per-plan cost heads. Every
        sub-path is the same code the one-shot `planned_search` runs, which
        is what extends the scheduled == one-shot bit-identity to auto."""
        scfg = self.scfg
        t0 = self.timer()
        bt = self._tr.new_trace("auto") if self.tracer is not None else ""
        width = self.batcher.width_for(len(reqs))
        prog = self.batcher.pad_program(reqs, width)
        with self._tr.span("plan-stage0", bt, lanes=len(reqs)) as sp:
            stats = scan_stats(self.engine, prog)
            self._observe_shard_bitmap(stats, len(reqs))
            s0 = np.asarray(stage0_scan_mask(
                self.planner, stats, prog, scfg.alpha, scfg.min_budget,
                scfg.max_budget, packed=self._packed_s))[: len(reqs)]
            sp.set(scan_routed=int(s0.sum()))
        busy = self.timer() - t0 if self.service_model is None else 0.0
        done = []
        scan_i = np.nonzero(s0)[0]
        if scan_i.size:
            sub = [reqs[i] for i in scan_i]
            for r in sub:
                r.plan, r.plan_pure = "scan", True
            d, b = self._scan_batch(now, sub, stats.rows(scan_i))
            done += d
            busy += b
        rest_i = np.nonzero(~s0)[0]
        if rest_i.size:
            d, b = self._auto_probe(now, [reqs[i] for i in rest_i],
                                    stats.rows(rest_i))
            done += d
            busy += b
        return done, busy

    def _auto_probe(self, now: float, reqs: list[Request],
                    stats) -> tuple[list[Request], float]:
        """Stage 1 of auto routing: shared probe → per-plan heads →
        argmin route. Scan-routed lanes ("late scan" — the static head
        kept them past stage 0) execute immediately, carrying their probe
        counters; traverse/widen lanes enqueue into their plan's buckets."""
        from repro.core.planner import PLAN_SCAN, PLAN_TRAVERSE

        scfg = self.scfg
        cfg = self.cfg
        t0 = self.timer()
        bt = self._tr.new_trace("probe") if self.tracer is not None else ""
        l0 = self._launches0()
        width = self.batcher.width_for(len(reqs))
        queries = self.batcher.pad_queries(reqs, width)
        prog = self.batcher.pad_program(reqs, width)
        lane_on = np.zeros(width, np.int32)
        lane_on[: len(reqs)] = 1
        st, feats = probe_and_features(
            self.engine, cfg, queries, prog,
            jnp.asarray(lane_on * scfg.probe_budget), n_probes=scfg.n_probes,
            tracer=self.tracer, trace_id=bt)
        cnt = np.asarray(st.cnt)
        self._observe_shards(st, None, len(reqs))
        counts = np.zeros(width, np.int64)
        counts[: len(reqs)] = stats.counts
        with self._tr.span("plan-select", bt, lanes=len(reqs)):
            ids, w_t, w_w = choose_plans(
                self.planner, feats, cnt, counts, scfg.alpha,
                scfg.min_budget, scfg.max_budget, packed_t=self._packed_t,
                packed_w=self._packed_w)
        fin = [i for i in range(len(reqs)) if ids[i] != PLAN_SCAN
               and int((w_t if ids[i] == PLAN_TRAVERSE else w_w)[i])
               <= int(cnt[i])]
        res_idx, res_dist = self._final_results(queries, st, bool(fin))
        lane_hops = np.asarray(st.hops)[: len(reqs)]
        steps = int(np.asarray(st.hops).max())
        busy = (self.timer() - t0 if self.service_model is None
                else self.service_model(steps, width))
        launches, early = self._launch_stats(steps, lane_hops,
                                             observed=self._launches0() - l0)
        self.metrics.observe_batch("probe", len(reqs), width, busy, steps,
                                   launches=launches, early_exit_frac=early)
        feats_h = np.asarray(feats) if self.calibration is not None else None
        for i, r in enumerate(reqs):
            r.probe_ndc = int(cnt[i])
            if feats_h is not None:
                r.features = feats_h[i]

        done = []
        late = [i for i in range(len(reqs)) if ids[i] == PLAN_SCAN]
        if late:
            sub = [reqs[i] for i in late]
            for r in sub:
                # probe counters leak into the scan state: the result is
                # NOT bitwise the forced-scan path (cnt differs), so no
                # dual-put under the forced key
                r.plan, r.plan_pure = "scan", False
            d, b = self._scan_batch(now, sub, stats.rows(late),
                                    base=take_lanes(st, np.asarray(late)))
            done += d
            busy += b
        for i, r in enumerate(reqs):
            if ids[i] == PLAN_SCAN:
                continue
            plan = "traverse" if ids[i] == PLAN_TRAVERSE else "widen"
            r.plan, r.plan_pure = plan, True
            r.budget = int((w_t if ids[i] == PLAN_TRAVERSE else w_w)[i])
            r.probe_done = now + busy
            r.executed = int(cnt[i])
            self._tr.emit("probe-done", r.trace_id, rid=r.rid, batch=bt,
                          budget=r.budget, probe_ndc=r.probe_ndc, plan=plan)
            if r.budget <= r.executed:
                self._finish(r, res_idx[i], res_dist[i], cnt[i], now + busy)
                done.append(r)
            else:
                r.state = (st, i)
                bucket = (0 if self.scfg.policy == "escalate" else None)
                self.batcher.enqueue(r, bucket)
        return done, busy

    def _scan_batch(self, now: float, reqs: list[Request], stats,
                    base=None) -> tuple[list[Request], float]:
        """Execute the terminal scan plan for a group of requests. `stats`
        is the lanes' ScanStats rows (None → compile here, the forced-scan
        path); `base` carries probe states for late-scan lanes. The batch
        pads to the lane-width ladder like every other micro-batch — the
        per-lane-deterministic scan distance path makes the padding (and
        any batch composition) invisible in the results."""
        t0 = self.timer()
        bt = self._tr.new_trace("scan") if self.tracer is not None else ""
        width = self.batcher.width_for(len(reqs))
        queries = self.batcher.pad_queries(reqs, width)
        prog = self.batcher.pad_program(reqs, width)
        pad = width - len(reqs)
        if stats is None:
            stats = scan_stats(self.engine, prog)  # pads match nothing
            self._observe_shard_bitmap(stats, len(reqs))
        elif pad:
            stats = ScanStats(
                valid=np.pad(stats.valid, ((0, pad), (0, 0))),
                counts=np.pad(stats.counts, (0, pad)),
                clause_frac=np.pad(stats.clause_frac, ((0, pad), (0, 0))),
                n=stats.n)
        if base is not None and pad:
            base = pad_lanes(base, pad)
        with self._tr.span("scan", bt, lanes=len(reqs), width=width,
                           late=base is not None):
            st = scan_search(self.engine, self.cfg, queries, prog,
                             stats=stats, base_state=base)
            jax.block_until_ready(st.res_dist)
        res_idx, res_dist = self._final_results(queries, st, True)
        cnt = np.asarray(st.cnt)
        self._observe_shards(st, base, len(reqs))
        # scan has no lockstep trips; charge the service model the
        # distance-equivalent count (σ·N work / the per-trip lane degree)
        steps = int(np.ceil(stats.counts.max(initial=0)
                            / max(self.cfg.degree, 1)))
        busy = (self.timer() - t0 if self.service_model is None
                else self.service_model(steps, width))
        # scan is one fused dispatch regardless of backend; no lockstep
        # lanes to early-exit
        self.metrics.observe_batch("scan", len(reqs), width, busy, steps,
                                   launches=1)
        done = []
        for i, r in enumerate(reqs):
            r.budget = int(cnt[i])
            r.executed = int(cnt[i])
            self._finish(r, res_idx[i], res_dist[i], cnt[i], now + busy)
            done.append(r)
        return done, busy

    def _pump_bucket(self, now: float, bucket: tuple[str, int] | None = None,
                     ) -> tuple[list[Request], float]:
        (plan, idx), reqs, cap = self.batcher.form_batch(bucket)
        if not reqs:
            return [], 0.0
        # plan-homogeneous batch (the batcher keys queues by plan): widen
        # lanes resume under the widened-frontier config, traverse lanes
        # under the session config — same resume-exact lockstep either way
        cfg = self.cfg_widen if plan == "widen" else self.cfg
        t0 = self.timer()
        bt = self._tr.new_trace("bucket") if self.tracer is not None else ""
        l0 = self._launches0()
        width = self.batcher.width_for(len(reqs))
        queries = self.batcher.pad_queries(reqs, width)
        prog = self.batcher.pad_program(reqs, width)
        budgets = self.batcher.pad_budgets(reqs, cap, width)
        state = self.batcher.pad_states(reqs, width)

        # Stage 3 — adaptive termination, bounded by the bucket cap.
        entry_hops = np.asarray(state.hops)
        with self._tr.span("resume", bt, bucket=int(idx), plan=plan,
                           lanes=len(reqs), width=width) as sp:
            out = self.engine.search(cfg, queries, prog, budgets,
                                     state=state, tracer=self.tracer,
                                     trace_id=bt)
            jax.block_until_ready(out)
            lane_steps = (np.asarray(out.hops) - entry_hops)[: len(reqs)]
            steps = int((np.asarray(out.hops) - entry_hops).max())
            sp.set(steps=steps)
        res_idx, res_dist = self._final_results(
            queries, out,
            cap is None or any(r.budget <= cap for r in reqs))
        cnt = np.asarray(out.cnt)
        self._observe_shards(out, state, len(reqs))
        targets = np.asarray(budgets)
        busy = (self.timer() - t0 if self.service_model is None
                else self.service_model(steps, width))
        label = f"bucket{idx}" if plan == "traverse" else f"bucket{idx}:{plan}"
        launches, early = self._launch_stats(steps, lane_steps,
                                             observed=self._launches0() - l0)
        self.metrics.observe_batch(label, len(reqs), width, busy, steps,
                                   launches=launches, early_exit_frac=early)

        done = []
        for i, r in enumerate(reqs):
            r.n_slices += 1
            r.executed = int(targets[i])
            if cap is None or r.budget <= cap:
                r.state = None
                self._finish(r, res_idx[i], res_dist[i], cnt[i], now + busy)
                done.append(r)
            else:
                # preemption: bounded slice done, requeue the carried state
                r.state = (out, i)
                nxt = (idx + 1 if self.scfg.policy == "escalate" else None)
                self.batcher.enqueue(r, nxt)
        return done, busy

    def _finish(self, req: Request, res_idx, res_dist, ndc, at: float):
        req.res_idx = np.asarray(res_idx)
        req.res_dist = np.asarray(res_dist)
        req.ndc = int(ndc)
        req.completed = at
        if self.calibration is not None:
            # cache hits never reach _finish, so every record is a real
            # execution: predicted Ŵ_q vs the NDC the search actually spent
            self.calibration.record(
                rid=req.rid, plan=req.plan or "traverse",
                predicted=req.budget if req.budget is not None else req.ndc,
                actual=req.ndc, probe_ndc=req.probe_ndc,
                n_slices=req.n_slices, alpha=self.scfg.alpha,
                features=req.features)
        self._tr.emit("complete", req.trace_id, rid=req.rid, ndc=req.ndc,
                      plan=str(req.plan or "traverse"),
                      budget=int(req.budget or 0),
                      n_slices=req.n_slices, cache_hit=False)
        if self.cache is not None:
            self.cache.put(self._key(req), req.res_idx, req.res_dist, req.ndc)
            if self.scfg.plan == "auto" and req.plan_pure and req.plan:
                # dual put: this auto completion executed its chosen plan
                # through the exact bitwise path a forced-plan scheduler
                # would have taken (no probe carry leaked into a scan), so
                # the result is also valid under the forced key — forced
                # and auto deployments share entries whenever sound. Late
                # scans (plan_pure=False) skip this: their NDC includes the
                # probe a forced scan never pays.
                self.cache.put(self._key_for(req, req.plan),
                               req.res_idx, req.res_dist, req.ndc)
        self.metrics.complete(req)

    def summary(self) -> dict:
        out = self.metrics.summary(self.ingress.n_shed,
                                   self.ingress.n_expired, self.cache)
        out["n_shards"] = self._n_shards
        return out

    def calibration_report(self) -> dict | None:
        """Rolling calibration health (None when calibration is off)."""
        return (None if self.calibration is None
                else self.calibration.report())

    def drift_report(self) -> dict | None:
        """Current drift-monitor state against the calibration log (None
        when drift monitoring is off). Freezes the reference window on the
        first call that sees ≥ min_ref records — the analysis runs here,
        at poll/scrape cadence, never inside a pump."""
        if self.drift_monitor is None or self.calibration is None:
            return None
        return self.drift_monitor.observe(self.calibration)

    def status(self) -> dict:
        """The serving health surface: one structured, JSON-serializable
        report unifying queue/admission state, the metrics summary (incl.
        the per-shard skew block on sharded engines), calibration health
        and the drift-alarm state. `healthy` is the single pager bit:
        False exactly while a drift detector alarms."""
        drift = self.drift_report()
        return dict(
            healthy=drift is None or not drift["alarm"],
            queue=dict(depth=self.depth(),
                       ingress=len(self.ingress),
                       bucketed=self.batcher.depth(),
                       capacity=self.ingress.capacity,
                       shed=int(self.ingress.n_shed),
                       expired=int(self.ingress.n_expired)),
            summary=self.summary(),
            calibration=self.calibration_report(),
            drift=drift,
        )

    def prometheus(self, prefix: str = "repro") -> str:
        """One Prometheus-text-format scrape over the serving summary and
        (when enabled) the calibration and drift reports."""
        from repro.obs.export import prometheus_text

        return prometheus_text(self.summary(), self.calibration_report(),
                               self.drift_report(), prefix=prefix)
