"""Per-shard EXPLAIN attribution for index-axis-sharded searches.

A `ShardedSearchState` (core.sharded) carries two views of every query:
the merged global view all consumers read, and the per-shard carries
`state.shard` ([B, S, ...] leaves) the merge was reduced FROM. The merged
counters are exact integer sums over that stacked axis (the PR-8
accounting contract), so per-shard attribution built here is *exact by
construction*: for every lane,

    sum_s section[s].ndc          == merged cnt
    sum_s section[s].hops         == merged hops
    sum_s section[s].n_inspected  == merged n_inspected
    sum_s section[s].n_clause_valid[c] == merged n_clause_valid[c]

— no re-derivation, no sampling; the sections read the same stacked
arrays `merge_shard_states` summed. Everything is host post-processing of
the final carry (one device→host copy of the small counter leaves), the
same cost class as the rest of EXPLAIN.

Per-shard termination reuses `obs.explain.termination_reasons` on each
shard's slice of the carry, judged against the per-shard budget ⌈W/S⌉ the
shard actually ran under (core.sharded splits the global budget exactly
this way). The merge topology is reported from `distributed.merge`'s
structure: S pools reduce through S−1 pairwise merges in ⌈log2 S⌉ rounds
(the host tree and the device butterfly share both numbers).

The work-balance index is the shard_bench efficiency quantity,

    balance = total NDC / (S · max_s shard NDC)   ∈ (0, 1]

1.0 means every shard spent the same budget; a selectivity-skewed filter
that concentrates valid rows in one shard drives it toward 1/S — the
telemetry ROADMAP's skew-aware budget routing will act on.
"""
from __future__ import annotations

import dataclasses
from types import SimpleNamespace

import numpy as np

from repro.distributed.merge import merge_plan
from repro.obs.explain import termination_reasons


@dataclasses.dataclass
class ShardSection:
    """One shard's slice of one query's execution."""

    shard: int                 # shard index (global row offset order)
    budget: int                # the ⌈W/S⌉ slice this shard ran under
    ndc: int                   # per-shard NDC (sums exactly to merged cnt)
    hops: int                  # per-shard expansions
    n_inspected: int           # per-shard neighbor inspections
    termination: str           # obs.explain reason, judged at `budget`
    n_clause_valid: list = dataclasses.field(default_factory=list)


def shard_budgets(budgets, n_shards: int) -> np.ndarray:
    """Per-shard budget slices ⌈W/S⌉ [B] — mirrors core.sharded.search."""
    b = np.asarray(budgets, np.int64)
    return (b + n_shards - 1) // n_shards


def work_balance(shard_ndc: np.ndarray) -> np.ndarray:
    """[B] balance index from per-shard NDC [B, S]: total/(S·max), 1.0 for
    lanes that spent nothing anywhere (nothing to balance)."""
    shard_ndc = np.asarray(shard_ndc, np.float64)
    s = shard_ndc.shape[1]
    mx = shard_ndc.max(axis=1)
    tot = shard_ndc.sum(axis=1)
    return np.where(mx > 0, tot / np.maximum(s * mx, 1.0), 1.0)


def build_shard_sections(cfg, state, budgets) -> list[list[ShardSection]]:
    """[B][S] sections from a ShardedSearchState's per-shard carries.

    `budgets` is the *global* per-lane budget [B] (or scalar) the sharded
    search ran with — sections judge termination at its ⌈W/S⌉ slice.
    """
    sh = state.shard
    cnt = np.asarray(sh.cnt)              # [B, S]
    hops = np.asarray(sh.hops)
    insp = np.asarray(sh.n_inspected)
    clause = np.asarray(sh.n_clause_valid)  # [B, S, C]
    cand_dist = np.asarray(sh.cand_dist)
    cand_idx = np.asarray(sh.cand_idx)
    cand_exp = np.asarray(sh.cand_exp)
    res_dist = np.asarray(sh.res_dist)
    b, s = cnt.shape
    sbud = np.broadcast_to(shard_budgets(budgets, s), (b,))

    sections: list[list[ShardSection]] = [[] for _ in range(b)]
    for j in range(s):
        # duck-typed per-shard carry slice — termination_reasons only reads
        # these five fields, all already on the host
        sub = SimpleNamespace(cand_dist=cand_dist[:, j],
                              cand_idx=cand_idx[:, j],
                              cand_exp=cand_exp[:, j],
                              res_dist=res_dist[:, j], cnt=cnt[:, j])
        terms = termination_reasons(cfg, sub, sbud)
        for i in range(b):
            sections[i].append(ShardSection(
                shard=j, budget=int(sbud[i]), ndc=int(cnt[i, j]),
                hops=int(hops[i, j]), n_inspected=int(insp[i, j]),
                termination=terms[i],
                n_clause_valid=[int(v) for v in clause[i, j]]))
    return sections


def attach_shard_sections(reports, cfg, state, budgets) -> list:
    """Mutate `reports` (obs.explain.QueryReport list) with the per-shard
    section, merge topology and work-balance index. No-op (and returns the
    reports untouched) when `state` has no per-shard carries."""
    sh = getattr(state, "shard", None)
    if sh is None:
        return reports
    sections = build_shard_sections(cfg, state, budgets)
    bal = work_balance(np.asarray(sh.cnt))
    pairwise, depth = merge_plan(len(sections[0]) if sections else 1)
    for i, r in enumerate(reports):
        r.shards = sections[i]
        r.work_balance = float(bal[i])
        r.merge_pairwise = pairwise
        r.merge_depth = depth
    return reports
