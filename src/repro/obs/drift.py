"""Estimator drift monitoring over the frozen calibration log.

The paper trains the cost estimator once offline; the in-depth filtered-
ANNS literature shows search difficulty moves with filter selectivity and
attribute correlation, so a served workload walks away from the training
distribution over time. PR 7's `obs.calibration.CalibrationMonitor`
records (features, Ŵ_q, actual NDC, plan, recall proxy) per completed
query under a frozen schema — this module watches that log and raises the
trigger signal the future online-recalibration trainer will consume.

Three detectors, each judged against a frozen *reference window* (the
early, presumed-in-distribution stretch of the log):

1. **PSI over probe features** — Population Stability Index per feature
   column, binned at reference quantiles:

       psi = Σ_bins (p_cur − p_ref) · ln(p_cur / p_ref)

   Rule of thumb: <0.1 stationary, 0.1–0.25 drifting, >0.25 shifted. The
   default alarm threshold is 0.5 because small windows carry sampling
   noise of order bins·(1/n_ref + 1/n_cur); at the serve-loop window
   sizes here that noise can reach ~0.2 on a genuinely stationary stream.

2. **log-RMSE trend** — RMSE of ln(Ŵ_q) − ln(actual NDC), the error
   quantity `CalibrationMonitor.report()` already summarizes. Alarms when
   the current window degrades multiplicatively AND additively past the
   reference (ratio + margin, so a near-zero reference can't make noise
   alarm-worthy).

3. **Per-plan win-rate shift** — win rate = P(actual ≤ predicted) per
   planner arm. A selectivity shift changes which plans win before it
   moves aggregate RMSE; alarms on |shift| past a threshold when both
   windows have enough of that plan to compare. A plan present in the
   reference but absent from the current window (or vice versa) at
   comparable volume is itself a plan-mix shift and is counted.

The monitor is windowed by `CalibrationMonitor.n_recorded` (a lifetime
counter, immune to the ring buffer's wraparound) — `observe()` freezes
the reference once enough rows exist, then reports on the rows recorded
since. All report values are finite floats/ints so they export through
the strict Prometheus validator unchanged.
"""
from __future__ import annotations

import dataclasses

import numpy as np

_EPS = 1e-4


@dataclasses.dataclass(frozen=True)
class DriftConfig:
    """Thresholds for the three detectors. Defaults are deliberately
    conservative (see module docstring on PSI sampling noise)."""

    min_ref: int = 64            # rows before the reference freezes
    min_cur: int = 32            # rows before the current window is judged
    window: int = 4096           # max rows in the current window
    psi_bins: int = 8            # quantile bins per feature
    psi_threshold: float = 0.5   # alarm when max-feature PSI exceeds this
    rmse_ratio: float = 1.5      # alarm when cur > ref·ratio + margin
    rmse_margin: float = 0.1
    win_rate_shift: float = 0.25  # alarm on per-plan |Δ win rate| ≥ this
    min_plan_n: int = 24         # plan rows needed in both windows to judge


def psi(reference, current, *, bins: int = 8) -> float:
    """Population Stability Index of `current` against `reference`.

    Bin edges are interior reference quantiles (so the reference spreads
    ~uniformly across bins); both histograms are normalized and clipped
    away from zero before the log-ratio. Returns 0.0 when either side is
    empty or the reference is single-valued (no bins to compare).
    """
    ref = np.asarray(reference, np.float64).ravel()
    cur = np.asarray(current, np.float64).ravel()
    if ref.size == 0 or cur.size == 0:
        return 0.0
    qs = np.quantile(ref, np.linspace(0.0, 1.0, bins + 1)[1:-1])
    edges = np.unique(qs)
    if edges.size == 0:
        return 0.0
    # side='right' puts values equal to an edge in the lower bin, so a
    # point mass at a quantile lands deterministically
    r = np.bincount(np.searchsorted(edges, ref, side="right"),
                    minlength=edges.size + 1).astype(np.float64)
    c = np.bincount(np.searchsorted(edges, cur, side="right"),
                    minlength=edges.size + 1).astype(np.float64)
    r = np.clip(r / r.sum(), _EPS, None)
    c = np.clip(c / c.sum(), _EPS, None)
    r /= r.sum()
    c /= c.sum()
    return float(np.sum((c - r) * np.log(c / r)))


def _log_rmse(predicted, actual) -> float:
    p = np.log(np.maximum(np.asarray(predicted, np.float64), 1.0))
    a = np.log(np.maximum(np.asarray(actual, np.float64), 1.0))
    if p.size == 0:
        return 0.0
    return float(np.sqrt(np.mean((p - a) ** 2)))


def _win_rates(plan, predicted, actual, n_plans: int):
    """(win_rate [P], n [P]) per plan id; win rate 0.0 where a plan has
    no rows (n carries the support)."""
    plan = np.asarray(plan, np.int64)
    win = (np.asarray(actual, np.int64)
           <= np.asarray(predicted, np.int64)).astype(np.float64)
    rates = np.zeros(n_plans, np.float64)
    ns = np.zeros(n_plans, np.int64)
    for p in range(n_plans):
        m = plan == p
        ns[p] = int(m.sum())
        if ns[p]:
            rates[p] = float(win[m].mean())
    return rates, ns


class DriftMonitor:
    """Rolling-window drift detection over a `CalibrationMonitor`.

    Typical serving use is one call per scrape/health poll:

        monitor = DriftMonitor(DriftConfig())
        ...
        rep = monitor.observe(calibration)   # freezes ref when ready

    `set_reference` can pin the reference explicitly (e.g. right after
    warmup); `advance` moves the current-window start forward — the hook
    the recalibration trainer will call after consuming a window.
    """

    def __init__(self, config: DriftConfig | None = None):
        self.config = config or DriftConfig()
        self._ref = None       # frozen reference stats (dict) or None
        self._marker = 0       # n_recorded at the reference freeze/advance

    @property
    def ready(self) -> bool:
        return self._ref is not None

    def set_reference(self, calibration) -> bool:
        """Freeze the reference from `calibration`'s current contents.
        Returns False (and stays unfrozen) below `min_ref` rows."""
        cols = calibration.arrays()
        n = int(cols["rid"].shape[0])
        if n < self.config.min_ref:
            return False
        from repro.obs.calibration import PLAN_NAMES
        feats = np.asarray(cols["features"], np.float64)
        rates, ns = _win_rates(cols["plan"], cols["predicted"],
                               cols["actual"], len(PLAN_NAMES))
        self._ref = {
            "n": n,
            "features": feats,
            "log_rmse": _log_rmse(cols["predicted"], cols["actual"]),
            "win_rates": rates,
            "plan_n": ns,
        }
        self._marker = int(calibration.n_recorded)
        return True

    def advance(self, calibration) -> None:
        """Start a fresh current window at the present log position
        (reference stays frozen)."""
        self._marker = int(calibration.n_recorded)

    def _current_rows(self, calibration):
        """Row window recorded since the marker, as column dict, or None
        when below min_cur. Bounded by `window` and by what the ring
        buffer still holds."""
        cols = calibration.arrays()
        avail = int(cols["rid"].shape[0])
        since = int(calibration.n_recorded) - self._marker
        take = min(since, avail, self.config.window)
        if take < self.config.min_cur:
            return None
        return {k: v[avail - take:] for k, v in cols.items()}

    def observe(self, calibration) -> dict:
        """Freeze the reference if not yet ready, then `report()`."""
        if self._ref is None:
            self.set_reference(calibration)
        return self.report(calibration)

    def report(self, calibration) -> dict:
        """Finite-valued drift report. Shape is stable across states:

        {ready, alarm, alarms: {psi, log_rmse, win_rate}, n_ref, n_cur,
         psi_max, psi_mean, psi_by_feature: [...], log_rmse_ref,
         log_rmse_cur, win_rate_shift_max, plans: {name: {...}}}
        """
        cfg = self.config
        out = {
            "ready": self.ready, "alarm": False,
            "alarms": {"psi": False, "log_rmse": False, "win_rate": False},
            "n_ref": 0 if self._ref is None else int(self._ref["n"]),
            "n_cur": 0,
            "psi_max": 0.0, "psi_mean": 0.0, "psi_by_feature": [],
            "log_rmse_ref": (0.0 if self._ref is None
                             else float(self._ref["log_rmse"])),
            "log_rmse_cur": 0.0,
            "win_rate_shift_max": 0.0,
            "plans": {},
        }
        if self._ref is None:
            return out
        cur = self._current_rows(calibration)
        if cur is None:
            return out
        out["n_cur"] = int(cur["rid"].shape[0])

        ref_f = self._ref["features"]
        cur_f = np.asarray(cur["features"], np.float64)
        n_feat = min(ref_f.shape[1], cur_f.shape[1])
        by_feat = [psi(ref_f[:, j], cur_f[:, j], bins=cfg.psi_bins)
                   for j in range(n_feat)]
        out["psi_by_feature"] = [float(v) for v in by_feat]
        if by_feat:
            out["psi_max"] = float(max(by_feat))
            out["psi_mean"] = float(np.mean(by_feat))
        out["alarms"]["psi"] = out["psi_max"] > cfg.psi_threshold

        out["log_rmse_cur"] = _log_rmse(cur["predicted"], cur["actual"])
        out["alarms"]["log_rmse"] = (
            out["log_rmse_cur"]
            > out["log_rmse_ref"] * cfg.rmse_ratio + cfg.rmse_margin)

        from repro.obs.calibration import PLAN_NAMES
        rates, ns = _win_rates(cur["plan"], cur["predicted"],
                               cur["actual"], len(PLAN_NAMES))
        ref_rates, ref_ns = self._ref["win_rates"], self._ref["plan_n"]
        shift_max = 0.0
        for p, name in enumerate(PLAN_NAMES):
            shift = 0.0
            judged = ref_ns[p] >= cfg.min_plan_n and ns[p] >= cfg.min_plan_n
            if judged:
                shift = abs(float(rates[p]) - float(ref_rates[p]))
                shift_max = max(shift_max, shift)
            out["plans"][name] = {
                "n_ref": int(ref_ns[p]), "n_cur": int(ns[p]),
                "win_rate_ref": float(ref_rates[p]),
                "win_rate_cur": float(rates[p]),
                "shift": float(shift), "judged": bool(judged),
            }
        out["win_rate_shift_max"] = float(shift_max)
        out["alarms"]["win_rate"] = shift_max >= cfg.win_rate_shift

        out["alarm"] = any(out["alarms"].values())
        return out
