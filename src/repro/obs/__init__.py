"""Observability: lifecycle tracing, calibration telemetry, export, EXPLAIN.

Six pieces, all strictly outside the jitted hot path:

  trace        `Tracer` — trace IDs + spans at host dispatch boundaries
               (bounded ring, bounded+rotating JSONL sink); `NO_TRACE`
               no-op.
  calibration  `CalibrationMonitor` — the frozen per-query
               (features, Ŵ_q, actual NDC, plan, recall) log the online
               recalibration work trains from.
  drift        `DriftMonitor` — rolling-window PSI / log-RMSE / win-rate
               drift detection over the calibration log; its alarm is the
               trigger signal for the future recalibration trainer.
  export       `prometheus_text` / `validate_prometheus` — exposition-
               format scrape over ServeMetrics + calibration + drift
               reports.
  explain      `QueryReport` / `termination_reasons` — per-query EXPLAIN
               surface for `e2e_search` / `planned_search`.
  shard        `ShardSection` / `attach_shard_sections` — per-shard
               EXPLAIN attribution whose counters sum exactly to the
               merged ones (the PR-8 accounting contract).
"""
from repro.obs.calibration import (PLAN_NAMES, RECORD_FIELDS, SCHEMA_VERSION,
                                   CalibrationMonitor)
from repro.obs.drift import DriftConfig, DriftMonitor, psi
from repro.obs.explain import (QueryReport, StageReport, build_reports,
                               feature_dict, format_reports,
                               termination_reasons)
from repro.obs.export import prometheus_text, validate_prometheus
from repro.obs.shard import (ShardSection, attach_shard_sections,
                             build_shard_sections, work_balance)
from repro.obs.trace import (NO_TRACE, NullTracer, Span, Tracer, as_tracer)

__all__ = [
    "CalibrationMonitor", "PLAN_NAMES", "RECORD_FIELDS", "SCHEMA_VERSION",
    "DriftConfig", "DriftMonitor", "psi",
    "QueryReport", "StageReport", "build_reports", "feature_dict",
    "format_reports", "termination_reasons",
    "prometheus_text", "validate_prometheus",
    "ShardSection", "attach_shard_sections", "build_shard_sections",
    "work_balance",
    "NO_TRACE", "NullTracer", "Span", "Tracer", "as_tracer",
]
