"""Observability: lifecycle tracing, calibration telemetry, export, EXPLAIN.

Four pieces, all strictly outside the jitted hot path:

  trace        `Tracer` — trace IDs + spans at host dispatch boundaries
               (bounded ring, optional JSONL sink); `NO_TRACE` no-op.
  calibration  `CalibrationMonitor` — the frozen per-query
               (features, Ŵ_q, actual NDC, plan, recall) log the online
               recalibration work trains from.
  export       `prometheus_text` / `validate_prometheus` — exposition-
               format scrape over ServeMetrics + calibration reports.
  explain      `QueryReport` / `termination_reasons` — per-query EXPLAIN
               surface for `e2e_search` / `planned_search`.
"""
from repro.obs.calibration import (PLAN_NAMES, RECORD_FIELDS, SCHEMA_VERSION,
                                   CalibrationMonitor)
from repro.obs.explain import (QueryReport, StageReport, build_reports,
                               feature_dict, format_reports,
                               termination_reasons)
from repro.obs.export import prometheus_text, validate_prometheus
from repro.obs.trace import (NO_TRACE, NullTracer, Span, Tracer, as_tracer)

__all__ = [
    "CalibrationMonitor", "PLAN_NAMES", "RECORD_FIELDS", "SCHEMA_VERSION",
    "QueryReport", "StageReport", "build_reports", "feature_dict",
    "format_reports", "termination_reasons",
    "prometheus_text", "validate_prometheus",
    "NO_TRACE", "NullTracer", "Span", "Tracer", "as_tracer",
]
