"""EXPLAIN: per-query lifecycle reports for `e2e_search`/`planned_search`.

The paper's cost model reasons in per-query quantities — probe features
z_q, predicted budget Ŵ_q = α·exp(M(z_q)), actual NDC W_q — but the search
APIs return only the final `SearchState`. `explain=True` additionally
returns one `QueryReport` per lane: the features the prediction was made
from, the predicted cost, the plan the router chose, per-stage NDC and
launch counts, and *why* the traversal stopped.

Termination-reason semantics (derived from the final carry, priority
order — a lane can satisfy several conditions; we report the one the step
function would act on first):

  queue-drained  no unexpanded finite candidate remains — the valid
                 sub-graph reachable from the entry was exhausted before
                 the budget; the estimator's prediction was irrelevant.
  budget         cnt ≥ budget — the paper's adaptive termination fired;
                 the predicted Ŵ_q is what stopped the search.
  greedy         (cfg.greedy_stop only) best remaining candidate is worse
                 than the current k-th result — classic HNSW convergence.
  active         none of the above: the lane was still runnable when the
                 driver stopped stepping (max_steps, or an external pause).

Everything here is host-side post-processing of arrays the caller already
synchronized — building reports adds no device work to any search path.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.core.features import FEATURE_NAMES
from repro.core.state import SearchConfig, SearchState

TERM_QUEUE_DRAINED = "queue-drained"
TERM_BUDGET = "budget"
TERM_GREEDY = "greedy"
TERM_ACTIVE = "active"


def termination_reasons(cfg: SearchConfig, state: SearchState,
                        budgets) -> list[str]:
    """Per-lane stop reason from the final carry (see module docstring for
    the priority order). `budgets` is scalar or [B]."""
    cand_dist = np.asarray(state.cand_dist)
    cand_idx = np.asarray(state.cand_idx)
    cand_exp = np.asarray(state.cand_exp)
    res_dist = np.asarray(state.res_dist)
    cnt = np.asarray(state.cnt)
    b = cnt.shape[0]
    budgets = np.broadcast_to(np.asarray(budgets), (b,))

    unexp = (~cand_exp) & (cand_idx >= 0) & np.isfinite(cand_dist)
    has_cand = unexp.any(axis=1)
    best_d = np.where(unexp, cand_dist, np.inf).min(axis=1)
    over_budget = cnt >= budgets
    worst_res = res_dist[:, -1]
    greedy = (bool(cfg.greedy_stop) & np.isfinite(worst_res)
              & np.isfinite(best_d) & (best_d > worst_res))

    out = []
    for i in range(b):
        if not has_cand[i]:
            out.append(TERM_QUEUE_DRAINED)
        elif over_budget[i]:
            out.append(TERM_BUDGET)
        elif greedy[i]:
            out.append(TERM_GREEDY)
        else:
            out.append(TERM_ACTIVE)
    return out


def feature_dict(feats: np.ndarray) -> dict:
    """Name one lane's probe feature vector. Width F = n_probes×N_FEATURES:
    the first block is z_f (names from FEATURE_NAMES); with n_probes=2 the
    second block is the convergence-speed delta z_f − z_{f/2} (d_*)."""
    feats = np.asarray(feats).ravel()
    n = len(FEATURE_NAMES)
    out = {}
    for i, v in enumerate(feats):
        if i < n:
            out[FEATURE_NAMES[i]] = float(v)
        elif i < 2 * n:
            out[f"d_{FEATURE_NAMES[i - n]}"] = float(v)
        else:
            out[f"f{i}"] = float(v)
    return out


@dataclasses.dataclass
class StageReport:
    """One lifecycle stage of one query's execution.

    `ndc` is the NDC *delta* spent inside the stage (state.cnt is
    cumulative; stages partition it). `launches` is driver-observed device
    dispatches attributable to the stage's batch — a batch-level quantity
    (lanes in a lockstep batch share dispatches), reported per query so a
    report is self-contained."""

    name: str
    ndc: int = 0
    launches: int = 0
    duration: float = 0.0
    attrs: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class QueryReport:
    """The complete EXPLAIN record for one query."""

    trace_id: str
    backend: str
    plan: str                     # "traverse" | "scan" | "widen"
    predicted_budget: int         # Ŵ_q (scan lanes: closed-form σ·N·c)
    actual_ndc: int               # W_q actually spent (state.cnt)
    probe_ndc: int                # NDC of the probe prefix (0 if no probe)
    termination: str              # see termination_reasons
    k_found: int                  # valid results delivered (≤ k)
    hops: int                     # expansions performed
    features: dict = dataclasses.field(default_factory=dict)
    stages: list[StageReport] = dataclasses.field(default_factory=list)
    # sharded engines only (obs.shard.attach_shard_sections): per-shard
    # attribution whose counters sum exactly to the merged ones above
    shards: list = dataclasses.field(default_factory=list)
    work_balance: float = 1.0     # total NDC / (S · max shard NDC)
    merge_pairwise: int = 0       # pairwise top-k merges performed (S−1)
    merge_depth: int = 0          # merge tree depth (⌈log2 S⌉)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        return d

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    def format(self, features: bool = False) -> str:
        """Human-readable lifecycle, one query."""
        ratio = self.predicted_budget / max(self.actual_ndc, 1)
        lines = [
            f"query {self.trace_id or '?'} [{self.backend}] "
            f"plan={self.plan} terminated={self.termination}",
            f"  predicted Ŵ_q={self.predicted_budget}  "
            f"actual NDC={self.actual_ndc}  (pred/actual={ratio:.2f})  "
            f"probe={self.probe_ndc}  hops={self.hops}  "
            f"k_found={self.k_found}",
        ]
        for st in self.stages:
            extras = "".join(f"  {k}={v}" for k, v in st.attrs.items())
            t = (f" t={st.duration * 1e3:8.3f}ms" if st.duration > 0 else "")
            lines.append(
                f"    {st.name:<12} ndc=+{st.ndc:<8} "
                f"launches={st.launches:<4}{t}{extras}")
        if self.shards:
            lines.append(
                f"  shards={len(self.shards)}  "
                f"balance={self.work_balance:.3f}  "
                f"merge={self.merge_pairwise}x pairwise "
                f"depth={self.merge_depth}")
            for sec in self.shards:
                lines.append(
                    f"    shard {sec.shard:<3} ndc={sec.ndc:<8} "
                    f"budget={sec.budget:<8} hops={sec.hops:<6} "
                    f"inspected={sec.n_inspected:<8} "
                    f"terminated={sec.termination}")
        if features and self.features:
            top = sorted(self.features.items(),
                         key=lambda kv: -abs(kv[1]))[:8]
            lines.append("    features     " + "  ".join(
                f"{k}={v:.3g}" for k, v in top))
        return "\n".join(lines)


def format_reports(reports: list[QueryReport],
                   features: bool = False) -> str:
    return "\n".join(r.format(features=features) for r in reports)


def build_reports(
    cfg: SearchConfig,
    state: SearchState,
    budgets,
    *,
    backend: str = "",
    plans=None,                    # [B] plan names, or None → "traverse"
    probe_ndc=None,                # [B] NDC after the probe prefix
    features=None,                 # [B, F] probe feature matrix
    trace_ids=None,                # [B] trace ids
    stages=None,                   # [B] list of per-lane StageReport lists
) -> list[QueryReport]:
    """Assemble per-lane reports from the final carry + pipeline context.

    All array arguments are host arrays the pipeline already materialized
    (predicted budgets, probe counters) — this never triggers a sync the
    caller didn't pay anyway."""
    cnt = np.asarray(state.cnt)
    hops = np.asarray(state.hops)
    res_idx = np.asarray(state.res_idx)
    b = cnt.shape[0]
    budgets = np.broadcast_to(np.asarray(budgets), (b,))
    terms = termination_reasons(cfg, state, budgets)
    probe_ndc = (np.zeros(b, np.int64) if probe_ndc is None
                 else np.asarray(probe_ndc))
    reports = []
    for i in range(b):
        reports.append(QueryReport(
            trace_id="" if trace_ids is None else str(trace_ids[i]),
            backend=backend,
            plan="traverse" if plans is None else str(plans[i]),
            predicted_budget=int(budgets[i]),
            actual_ndc=int(cnt[i]),
            probe_ndc=int(probe_ndc[i]),
            termination=terms[i],
            k_found=int((res_idx[i] >= 0).sum()),
            hops=int(hops[i]),
            features={} if features is None else feature_dict(features[i]),
            stages=[] if stages is None else list(stages[i]),
        ))
    return reports
