"""Calibration telemetry: the served-traffic log the estimator retrains on.

The paper trains the GBDT cost model offline, once. A production system
can't — workloads drift — and ROADMAP's online-recalibration item needs
exactly one thing from serving: a per-completed-query record of

    (probe feature vector z_q, predicted Ŵ_q, actual NDC spent, plan
     chosen, recall proxy when ground truth is available)

`CalibrationMonitor` collects those records in a bounded window, computes
rolling calibration health (log-space error, over-/under-prediction rates,
per-plan routing shares and win rates), and persists the window with the
same atomic npz + sha256-manifest discipline as `train/checkpoint.py` — a
torn write can never be mistaken for a valid calibration log.

**The record schema is frozen** (`SCHEMA_VERSION`, `RECORD_FIELDS`): the
future recalibration PR trains directly from saved windows, so field names,
dtypes and semantics must not change without bumping the version. The
feature vector's width is workload-dependent (n_probes × N_FEATURES) and is
recorded per window in the manifest, not in the schema.
"""
from __future__ import annotations

import hashlib
import json
import os
from collections import deque

import numpy as np

SCHEMA_VERSION = 1

#: plan id encoding in records (index into this tuple); "traverse" is the
#: legacy/no-planner pipeline and therefore the default.
PLAN_NAMES = ("traverse", "scan", "widen")

#: frozen per-record scalar fields: (name, numpy dtype, meaning)
RECORD_FIELDS = (
    ("rid", "int64", "request id (-1 for one-shot pipelines)"),
    ("plan", "int32", "index into PLAN_NAMES"),
    ("predicted", "int64", "predicted total budget Ŵ_q (NDC)"),
    ("actual", "int64", "actual NDC spent (state.cnt at completion)"),
    ("probe_ndc", "int64", "NDC spent by the probe prefix"),
    ("n_slices", "int32", "resume batches the query rode in"),
    ("alpha", "float32", "recall knob the prediction was scaled by"),
    ("recall", "float32", "recall proxy vs ground truth; NaN if unknown"),
)

_EPS = 1e-12


def _plan_id(plan) -> int:
    if isinstance(plan, (int, np.integer)):
        return int(plan)
    try:
        return PLAN_NAMES.index(plan or "traverse")
    except ValueError:
        raise ValueError(f"unknown plan {plan!r} (one of {PLAN_NAMES})")


class CalibrationMonitor:
    """Bounded rolling window of per-query calibration records."""

    def __init__(self, capacity: int = 1 << 16):
        self.capacity = capacity
        self._rows: deque[tuple] = deque(maxlen=capacity)
        self._feats: deque[np.ndarray] = deque(maxlen=capacity)
        self.n_recorded = 0          # lifetime count (window may evict)

    # ----------------------------------------------------------- record ----
    def record(self, *, predicted, actual, plan="traverse", rid: int = -1,
               probe_ndc: int = 0, n_slices: int = 0, alpha: float = 1.0,
               recall: float = float("nan"), features=None) -> None:
        """One completed query. `features` is the probe feature vector the
        prediction was made from (host array; None stores an empty row —
        the record still contributes to the rolling rates)."""
        self._rows.append((int(rid), _plan_id(plan), int(predicted),
                           int(actual), int(probe_ndc), int(n_slices),
                           float(alpha), float(recall)))
        self._feats.append(np.zeros(0, np.float32) if features is None
                           else np.asarray(features, np.float32).ravel())
        self.n_recorded += 1

    def set_recall(self, recalls: dict) -> None:
        """Backfill recall proxies (rid → recall) after ground truth is
        computed — serving rarely knows gt at completion time."""
        for i, row in enumerate(self._rows):
            if row[0] in recalls:
                self._rows[i] = row[:7] + (float(recalls[row[0]]),)

    def __len__(self) -> int:
        return len(self._rows)

    # ------------------------------------------------------------ views ----
    def arrays(self) -> dict:
        """The window as a dict of column arrays (RECORD_FIELDS order) plus
        `features` [n, F] (F = max row width; short rows zero-pad)."""
        n = len(self._rows)
        cols = {name: np.zeros(n, dtype) for name, dtype, _ in RECORD_FIELDS}
        for i, row in enumerate(self._rows):
            for (name, _, _), v in zip(RECORD_FIELDS, row):
                cols[name][i] = v
        width = max((f.size for f in self._feats), default=0)
        feats = np.zeros((n, width), np.float32)
        for i, f in enumerate(self._feats):
            feats[i, : f.size] = f
        cols["features"] = feats
        return cols

    # ----------------------------------------------------------- report ----
    def report(self) -> dict:
        """Rolling calibration health. All values finite for any window
        size (empty included) — this feeds the Prometheus exporter, which
        forbids NaN samples."""
        cols = self.arrays()
        n = len(self._rows)
        out = dict(schema_version=SCHEMA_VERSION, n_records=n,
                   n_recorded_total=self.n_recorded)
        if n == 0:
            out.update(log_rmse=0.0, mean_log_ratio=0.0,
                       overprediction_rate=0.0, underprediction_rate=0.0,
                       predicted=_quantiles(np.zeros(0)),
                       actual=_quantiles(np.zeros(0)),
                       ratio=_quantiles(np.zeros(0)),
                       recall_mean=0.0, n_with_recall=0, per_plan={})
            return out
        pred = np.maximum(cols["predicted"].astype(np.float64), 1.0)
        act = np.maximum(cols["actual"].astype(np.float64), 1.0)
        log_ratio = np.log(pred) - np.log(act)
        rec = cols["recall"]
        has_rec = np.isfinite(rec)
        out.update(
            log_rmse=float(np.sqrt(np.mean(log_ratio ** 2))),
            # >0: the estimator over-provisions on average (recall-safe,
            # cost-wasteful); <0: under-provisions (cheap, recall-risky)
            mean_log_ratio=float(np.mean(log_ratio)),
            overprediction_rate=float(np.mean(pred > act)),
            underprediction_rate=float(np.mean(pred < act)),
            # predicted-vs-actual scatter summary (the plot, as numbers)
            predicted=_quantiles(pred),
            actual=_quantiles(act),
            ratio=_quantiles(pred / np.maximum(act, _EPS)),
            recall_mean=(float(rec[has_rec].mean()) if has_rec.any() else 0.0),
            n_with_recall=int(has_rec.sum()),
        )
        per_plan = {}
        for pid, name in enumerate(PLAN_NAMES):
            m = cols["plan"] == pid
            if not m.any():
                continue
            # "win" = the plan delivered within its predicted budget — the
            # promise the router's argmin was based on
            per_plan[name] = dict(
                n=int(m.sum()),
                share=float(m.mean()),
                win_rate=float(np.mean(act[m] <= pred[m])),
                mean_log_ratio=float(np.mean(log_ratio[m])),
                mean_actual_ndc=float(act[m].mean()),
            )
        out["per_plan"] = per_plan
        return out

    # ---------------------------------------------------------- persist ----
    def save(self, directory: str, tag: str = "calibration") -> str:
        """Atomic write (tmp + rename) of the rolling window: arrays.npz +
        a JSON manifest with schema version, field docs and a sha256 — the
        `train/checkpoint.py` discipline, so the recalibration trainer can
        validate a window before fitting on it."""
        os.makedirs(directory, exist_ok=True)
        cols = self.arrays()
        tmp = os.path.join(directory, f".tmp_{tag}_{os.getpid()}")
        os.makedirs(tmp, exist_ok=True)
        data_path = os.path.join(tmp, "arrays.npz")
        np.savez(data_path, **cols)
        digest = hashlib.sha256(open(data_path, "rb").read()).hexdigest()
        manifest = dict(
            schema_version=SCHEMA_VERSION,
            sha256=digest,
            n_records=len(self._rows),
            n_recorded_total=self.n_recorded,
            feature_width=int(cols["features"].shape[1]),
            fields=[dict(name=n, dtype=d, doc=doc)
                    for n, d, doc in RECORD_FIELDS],
            plan_names=list(PLAN_NAMES),
        )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=2)
        final = os.path.join(directory, tag)
        if os.path.exists(final):
            import shutil

            shutil.rmtree(final)
        os.rename(tmp, final)
        return final

    @classmethod
    def load(cls, path: str, validate: bool = True,
             ) -> tuple["CalibrationMonitor", dict]:
        """Restore a saved window. Returns (monitor, manifest)."""
        manifest = json.load(open(os.path.join(path, "manifest.json")))
        if manifest["schema_version"] != SCHEMA_VERSION:
            raise ValueError(
                f"calibration log schema v{manifest['schema_version']} != "
                f"supported v{SCHEMA_VERSION}")
        data_path = os.path.join(path, "arrays.npz")
        if validate:
            digest = hashlib.sha256(open(data_path, "rb").read()).hexdigest()
            if digest != manifest["sha256"]:
                raise IOError(f"calibration log {path} failed integrity check")
        z = np.load(data_path)
        mon = cls(capacity=max(1, int(manifest["n_records"]) or 1))
        feats = z["features"]
        for i in range(int(manifest["n_records"])):
            mon.record(
                rid=z["rid"][i], plan=int(z["plan"][i]),
                predicted=z["predicted"][i], actual=z["actual"][i],
                probe_ndc=z["probe_ndc"][i], n_slices=z["n_slices"][i],
                alpha=z["alpha"][i], recall=z["recall"][i],
                features=feats[i] if feats.shape[1] else None)
        mon.n_recorded = int(manifest["n_recorded_total"])
        return mon, manifest


def _quantiles(v: np.ndarray, qs=(10, 50, 90)) -> dict:
    v = np.asarray(v, np.float64)
    v = v[np.isfinite(v)]
    if v.size == 0:
        return {f"p{q}": 0.0 for q in qs}
    return {f"p{q}": float(np.percentile(v, q)) for q in qs}
