"""Query-lifecycle tracing: spans at real dispatch boundaries.

Every request (serving) or batch pipeline invocation (one-shot) carries a
trace ID; `Span`s with monotonic timestamps and structured attributes cover
the lifecycle stages the paper's cost model reasons about:

  admit → probe → feature-extract/estimate → plan-select → resume launches
  (steps, width, compaction — from the persistent driver) → rerank → complete

Design constraints (pinned by tests/test_obs.py):

  * tracing must never enter the jitted hot path — spans are emitted only
    at host-level dispatch points that already exist (an `engine.search`
    call, a persistent-driver launch, a scheduler pump), so results are
    bit-identical with tracing on vs. off and no device synchronization is
    added inside any launch loop;
  * span attributes are plain Python scalars/strings at emit time — a span
    must never retain a live device array (that would pin device memory
    and turn a later repr into a sync);
  * memory is bounded: spans land in a ring (`deque(maxlen=capacity)`);
    an optional JSONL sink streams them out for offline analysis. The sink
    is bounded too — at `sink_max_bytes` the file rotates to `<path>.1`
    (replacing any previous rotation), so total disk use stays ≤ ~2× the
    cap no matter how long the process serves.

The tracer is clock-injected like the serving scheduler: pass `clock=` to
drive it from a virtual clock (benchmarks) or leave the default
`time.perf_counter` (monotonic) for wall-clock tracing.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import json
import os
import time
from collections import deque

import numpy as np

#: ring default — ~100 B/span of attrs keeps this well under 10 MB
DEFAULT_CAPACITY = 1 << 16

#: sink default — rotate the JSONL file once it reaches 64 MB, keeping one
#: predecessor (`<path>.1`), so a long-running serve process holds at most
#: ~2× this on disk
DEFAULT_SINK_MAX_BYTES = 64 << 20

_SCALARS = (str, int, float, bool, type(None))


def _host_scalar(v):
    """Coerce an attribute value to a plain host scalar (never a device
    array). numpy scalars become Python numbers; anything array-like is a
    bug at the call site — spans carry summaries, not tensors."""
    if isinstance(v, _SCALARS):
        return v
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, np.bool_):
        return bool(v)
    raise TypeError(
        f"span attribute of type {type(v).__name__} — spans must carry "
        "plain host scalars (summarize arrays before emitting)")


@dataclasses.dataclass
class Span:
    """One lifecycle interval: [t0, t1] in the tracer's clock units."""

    trace_id: str
    name: str
    t0: float
    t1: float = 0.0
    attrs: dict = dataclasses.field(default_factory=dict)

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    @property
    def duration(self) -> float:
        return self.t1 - self.t0

    def to_json(self) -> str:
        return json.dumps(dict(trace=self.trace_id, name=self.name,
                               t0=self.t0, t1=self.t1, **self.attrs),
                          sort_keys=True)


class Tracer:
    """Bounded in-memory span ring + optional JSONL sink.

    Trace IDs are deterministic counters (``q-000001``) — no RNG, so a
    traced run is replayable and two identically-driven runs produce
    identical span streams (up to timestamps)."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 clock=time.perf_counter, sink: str | None = None,
                 sink_max_bytes: int = DEFAULT_SINK_MAX_BYTES):
        self.capacity = capacity
        self.clock = clock
        self._ring: deque[Span] = deque(maxlen=capacity)
        self._ids = itertools.count(1)
        self.n_emitted = 0          # lifetime count (ring may have evicted)
        self._sink_path = sink
        self.sink_max_bytes = int(sink_max_bytes)
        self.n_rotations = 0
        self._sink = open(sink, "a") if sink else None
        # appending to a pre-existing file: count what's already there
        self._sink_bytes = self._sink.tell() if self._sink else 0

    # ------------------------------------------------------------- ids ----
    def new_trace(self, prefix: str = "q") -> str:
        return f"{prefix}-{next(self._ids):06d}"

    # ----------------------------------------------------------- record ----
    def emit(self, name: str, trace_id: str = "", t0: float | None = None,
             t1: float | None = None, **attrs) -> Span:
        """Record a completed span (t1 defaults to t0: an instant event)."""
        now = self.clock()
        t0 = now if t0 is None else t0
        t1 = t0 if t1 is None else t1
        sp = Span(trace_id=trace_id, name=name, t0=float(t0), t1=float(t1),
                  attrs={k: _host_scalar(v) for k, v in attrs.items()})
        self._append(sp)
        return sp

    @contextlib.contextmanager
    def span(self, name: str, trace_id: str = "", **attrs):
        """Context manager measuring the enclosed host work. The yielded
        span is mutable — `sp.set(steps=..., ndc=...)` attaches attributes
        discovered during the work (host scalars only)."""
        sp = Span(trace_id=trace_id, name=name, t0=self.clock(),
                  attrs={k: _host_scalar(v) for k, v in attrs.items()})
        try:
            yield sp
        finally:
            sp.t1 = self.clock()
            sp.attrs = {k: _host_scalar(v) for k, v in sp.attrs.items()}
            self._append(sp)

    def _append(self, sp: Span) -> None:
        self._ring.append(sp)
        self.n_emitted += 1
        if self._sink is not None:
            line = sp.to_json() + "\n"
            if (self._sink_bytes > 0
                    and self._sink_bytes + len(line) > self.sink_max_bytes):
                self._rotate_sink()
            self._sink.write(line)
            self._sink_bytes += len(line)

    def _rotate_sink(self) -> None:
        """Roll the sink file to `<path>.1` and start a fresh one. A span
        larger than the cap still lands (a file always takes ≥1 line)."""
        self._sink.close()
        os.replace(self._sink_path, self._sink_path + ".1")
        self._sink = open(self._sink_path, "w")
        self._sink_bytes = 0
        self.n_rotations += 1

    # ------------------------------------------------------------ query ----
    def __len__(self) -> int:
        return len(self._ring)

    def spans(self, trace_id: str | None = None,
              name: str | None = None) -> list[Span]:
        """Spans still in the ring, oldest first, optionally filtered."""
        return [s for s in self._ring
                if (trace_id is None or s.trace_id == trace_id)
                and (name is None or s.name == name)]

    def clear(self) -> None:
        self._ring.clear()

    # ------------------------------------------------------------- sink ----
    def flush(self) -> None:
        if self._sink is not None:
            self._sink.flush()

    def close(self) -> None:
        if self._sink is not None:
            self._sink.close()
            self._sink = None


class _NullSpan:
    """Inert span: accepts attribute writes, records nothing."""

    __slots__ = ("attrs",)

    def __init__(self):
        self.attrs = {}

    def set(self, **attrs):
        return self


class NullTracer:
    """No-op tracer — the default everywhere, so untraced call sites pay
    one attribute lookup and nothing else."""

    capacity = 0
    n_emitted = 0

    def new_trace(self, prefix: str = "q") -> str:
        return ""

    def emit(self, name, trace_id="", t0=None, t1=None, **attrs):
        return _NullSpan()

    @contextlib.contextmanager
    def span(self, name, trace_id="", **attrs):
        yield _NullSpan()

    def __len__(self):
        return 0

    def spans(self, trace_id=None, name=None):
        return []

    def clear(self):
        pass

    def flush(self):
        pass

    def close(self):
        pass


#: shared inert instance — `tr = tracer or NO_TRACE` normalizes call sites
NO_TRACE = NullTracer()


def as_tracer(tracer) -> "Tracer | NullTracer":
    return NO_TRACE if tracer is None else tracer
