"""Prometheus-text-format exporter over serving + calibration telemetry.

Renders a `ServeMetrics.summary()` dict (and optionally a
`CalibrationMonitor.report()`) as a Prometheus exposition-format scrape —
`# HELP` / `# TYPE` headers followed by samples, quantile-labeled gauges
for the latency/NDC distributions, phase-labeled counters for batches, and
plan-labeled calibration gauges.

`validate_prometheus(text)` is a strict structural checker used by the
tests and benchmarks: every sample line must parse, every metric must have
a TYPE declaration before its first sample, and no sample may be NaN/Inf
(Prometheus technically allows them; an exporter that emits them is almost
always leaking an unguarded empty-window division — see the ServeMetrics
hardening notes).
"""
from __future__ import annotations

import math
import re

_NAME = r"[a-zA-Z_:][a-zA-Z0-9_:]*"
_SAMPLE_RE = re.compile(
    rf"^({_NAME})(\{{[^{{}}]*\}})? (-?(?:\d+\.?\d*(?:[eE][-+]?\d+)?|NaN|Inf|"
    rf"-Inf))$")
_LABELS_RE = re.compile(r'^\{(?:[a-zA-Z_][a-zA-Z0-9_]*="[^"\\]*")'
                        r'(?:,[a-zA-Z_][a-zA-Z0-9_]*="[^"\\]*")*\}$')


class _Writer:
    def __init__(self, prefix: str):
        self.prefix = prefix
        self.lines: list[str] = []
        self._declared: set[str] = set()

    def metric(self, name: str, kind: str, help_text: str):
        full = f"{self.prefix}_{name}"
        if full not in self._declared:
            self.lines.append(f"# HELP {full} {help_text}")
            self.lines.append(f"# TYPE {full} {kind}")
            self._declared.add(full)
        return full

    def sample(self, full: str, value, labels: dict | None = None):
        v = float(value)
        if not math.isfinite(v):
            v = 0.0  # an exporter must not publish NaN windows
        lab = ""
        if labels:
            lab = "{" + ",".join(f'{k}="{_esc(v2)}"'
                                 for k, v2 in labels.items()) + "}"
        # integral values render without the trailing .0 noise
        s = str(int(v)) if float(v).is_integer() and abs(v) < 1e15 else repr(v)
        self.lines.append(f"{full}{lab} {s}")

    def gauge(self, name, value, help_text, labels=None):
        self.sample(self.metric(name, "gauge", help_text), value, labels)

    def counter(self, name, value, help_text, labels=None):
        self.sample(self.metric(name, "counter", help_text), value, labels)

    def text(self) -> str:
        return "\n".join(self.lines) + "\n"


def _esc(v) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"')


def prometheus_text(summary: dict, calibration: dict | None = None,
                    drift: dict | None = None,
                    prefix: str = "repro") -> str:
    """Serialize a serving summary (+ optional calibration report and
    drift report) as one Prometheus scrape. Pure function of its dict
    inputs — callers decide when a scrape happens, nothing here touches
    the scheduler."""
    w = _Writer(prefix)

    w.counter("requests_completed_total", summary.get("n_completed", 0),
              "requests finished (cache hits included)")
    w.counter("batches_total", summary.get("n_batches", 0),
              "micro-batches executed")
    w.counter("busy_seconds_total", summary.get("busy_time", 0.0),
              "engine busy time (charged clock units)")
    w.counter("requests_shed_total", summary.get("n_shed", 0),
              "requests rejected by admission backpressure")
    w.counter("requests_expired_total", summary.get("n_expired", 0),
              "requests rejected with an already-passed deadline")
    w.counter("requeues_total", summary.get("n_requeues", 0),
              "preemption slices beyond each request's first")
    w.gauge("deadline_miss_rate", summary.get("deadline_miss_rate", 0.0),
            "fraction of completed requests past their deadline")

    for key, help_text in (("latency", "end-to-end request latency"),
                           ("probe_latency", "arrival-to-probe latency"),
                           ("ndc", "node distance computations per request")):
        dist = summary.get(key, {})
        full = w.metric(f"{key}", "gauge",
                        f"{help_text} (rolling-window quantiles)")
        for q_key, q_lab in (("p50", "0.5"), ("p95", "0.95"), ("p99", "0.99")):
            if q_key in dist:
                w.sample(full, dist[q_key], {"quantile": q_lab})
    if "latency_mean" in summary:
        w.gauge("latency_mean", summary["latency_mean"],
                "mean end-to-end latency over the window")

    w.gauge("queue_depth_mean", summary.get("queue_depth_mean", 0.0),
            "mean total queue depth at pump times")
    w.gauge("queue_depth_max", summary.get("queue_depth_max", 0),
            "max total queue depth observed")

    # dispatch accounting (the persistent-execution story): launches are
    # driver-observed device dispatches; early_exit_frac is lane-weighted
    w.counter("launches_total", summary.get("launches_total", 0),
              "device dispatches across all batches")
    w.counter("steps_total", summary.get("steps_total", 0),
              "lockstep trips across all batches")
    w.gauge("early_exit_frac", summary.get("early_exit_frac", 0.0),
            "lane-weighted fraction of lanes finishing before their batch")

    for phase, d in sorted(summary.get("batches_by_phase", {}).items()):
        lab = {"phase": phase}
        w.counter("phase_batches_total", d.get("n", 0),
                  "batches per lifecycle phase", lab)
        w.counter("phase_busy_seconds_total", d.get("busy", 0.0),
                  "busy time per lifecycle phase", lab)
        w.counter("phase_launches_total", d.get("launches", 0),
                  "device dispatches per lifecycle phase", lab)
        w.gauge("phase_mean_fill", d.get("mean_fill", 0.0),
                "mean real lanes per batch", lab)
        w.gauge("phase_early_exit_frac", d.get("early_exit_frac", 0.0),
                "lane-weighted early-exit fraction per phase", lab)

    # per-shard work/skew telemetry (sharded engines only) — the inputs
    # ROADMAP's skew-aware budget routing will consume
    shards = summary.get("shards")
    if shards:
        w.gauge("shards", shards.get("n_shards", 1),
                "index-axis shards behind the engine")
        for s, v in enumerate(shards.get("ndc_by_shard", [])):
            w.counter("shard_ndc_total", v,
                      "distance computations attributed per shard",
                      {"shard": str(s)})
        for s, v in enumerate(shards.get("bitmap_by_shard", [])):
            w.counter("shard_bitmap_count_total", v,
                      "filter-bitmap valid rows observed per shard",
                      {"shard": str(s)})
        w.gauge("shard_ndc_skew", shards.get("ndc_skew", 1.0),
                "max/mean per-shard NDC (1.0 = perfectly balanced)")
        w.gauge("shard_bitmap_skew", shards.get("bitmap_skew", 1.0),
                "max/mean per-shard filter-bitmap count")
        w.gauge("shard_work_balance", shards.get("work_balance", 1.0),
                "total NDC / (S * max shard NDC); 1.0 = balanced")

    cache = summary.get("cache")
    if cache:
        w.counter("cache_hits_total", cache.get("hits", 0),
                  "result-cache hits")
        w.counter("cache_misses_total", cache.get("misses", 0),
                  "result-cache misses")
        w.gauge("cache_entries", cache.get("entries", 0),
                "live result-cache entries")

    if calibration is not None:
        w.counter("calibration_records_total",
                  calibration.get("n_recorded_total", 0),
                  "calibration records observed (lifetime)")
        w.gauge("calibration_window_size", calibration.get("n_records", 0),
                "records in the rolling calibration window")
        w.gauge("calibration_log_rmse", calibration.get("log_rmse", 0.0),
                "rolling RMSE of log(predicted) - log(actual)")
        w.gauge("calibration_mean_log_ratio",
                calibration.get("mean_log_ratio", 0.0),
                "mean log(predicted/actual); >0 over-provisions")
        w.gauge("calibration_overprediction_rate",
                calibration.get("overprediction_rate", 0.0),
                "fraction of queries with predicted > actual NDC")
        w.gauge("calibration_underprediction_rate",
                calibration.get("underprediction_rate", 0.0),
                "fraction of queries with predicted < actual NDC")
        ratio = calibration.get("ratio", {})
        full = w.metric("calibration_ratio", "gauge",
                        "predicted/actual NDC ratio quantiles")
        for q_key, q_lab in (("p10", "0.1"), ("p50", "0.5"), ("p90", "0.9")):
            if q_key in ratio:
                w.sample(full, ratio[q_key], {"quantile": q_lab})
        for plan, d in sorted(calibration.get("per_plan", {}).items()):
            lab = {"plan": plan}
            w.counter("plan_queries_total", d.get("n", 0),
                      "completed queries per chosen plan", lab)
            w.gauge("plan_share", d.get("share", 0.0),
                    "routing share per plan over the window", lab)
            w.gauge("plan_win_rate", d.get("win_rate", 0.0),
                    "fraction delivered within predicted budget", lab)
            w.gauge("plan_mean_actual_ndc", d.get("mean_actual_ndc", 0.0),
                    "mean actual NDC per plan", lab)

    if drift is not None:
        w.gauge("drift_ready", 1.0 if drift.get("ready") else 0.0,
                "1 once the drift reference window is frozen")
        w.gauge("drift_alarm", 1.0 if drift.get("alarm") else 0.0,
                "1 while any drift detector is alarming (the "
                "recalibration trigger)")
        for kind, on in sorted(drift.get("alarms", {}).items()):
            w.gauge("drift_alarm_detail", 1.0 if on else 0.0,
                    "per-detector alarm state", {"kind": kind})
        w.gauge("drift_n_ref", drift.get("n_ref", 0),
                "rows in the frozen drift reference window")
        w.gauge("drift_n_cur", drift.get("n_cur", 0),
                "rows in the current drift window")
        w.gauge("drift_psi_max", drift.get("psi_max", 0.0),
                "max per-feature PSI, current vs reference window")
        w.gauge("drift_psi_mean", drift.get("psi_mean", 0.0),
                "mean per-feature PSI")
        w.gauge("drift_log_rmse_ref", drift.get("log_rmse_ref", 0.0),
                "estimator log-RMSE over the reference window")
        w.gauge("drift_log_rmse_cur", drift.get("log_rmse_cur", 0.0),
                "estimator log-RMSE over the current window")
        w.gauge("drift_win_rate_shift_max",
                drift.get("win_rate_shift_max", 0.0),
                "max per-plan |win-rate shift| among judged plans")

    return w.text()


def validate_prometheus(text: str) -> dict:
    """Strict structural validation of an exposition-format scrape.

    Returns {metric name: sample count}; raises ValueError on any
    malformed line, a sample without a prior TYPE declaration, malformed
    labels, or a non-finite sample value."""
    declared: set[str] = set()
    counts: dict[str, int] = {}
    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# HELP ") or line.startswith("# TYPE "):
            parts = line.split(" ", 3)
            if len(parts) < 4 or not re.fullmatch(_NAME, parts[2]):
                raise ValueError(f"line {ln}: malformed comment {line!r}")
            if parts[1] == "TYPE":
                if parts[3].split()[0] not in ("counter", "gauge",
                                               "histogram", "summary",
                                               "untyped"):
                    raise ValueError(f"line {ln}: bad TYPE {line!r}")
                declared.add(parts[2])
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {ln}: malformed sample {line!r}")
        name, labels, value = m.group(1), m.group(2), m.group(3)
        if name not in declared:
            raise ValueError(f"line {ln}: sample {name} before TYPE")
        if labels and not _LABELS_RE.match(labels):
            raise ValueError(f"line {ln}: malformed labels {labels!r}")
        if value in ("NaN", "Inf", "-Inf"):
            raise ValueError(f"line {ln}: non-finite sample {line!r}")
        counts[name] = counts.get(name, 0) + 1
    if not counts:
        raise ValueError("scrape contains no samples")
    return counts
