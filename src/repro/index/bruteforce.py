"""Exact (filtered) KNN oracle — ground truth for recall and for W_q labels.

Filters are accepted as a legacy `FilterSpec` batch or a sequence of
filter-algebra expressions; validity is delegated to the shared host
oracle in `repro.filters.predicates.filter_matrix` (naive, nothing like
the compiled traversal path)."""
from __future__ import annotations

import numpy as np

from repro.filters.predicates import filter_matrix


def _pairwise_sqdist(queries: np.ndarray, base: np.ndarray, block: int = 4096) -> np.ndarray:
    """[B, N] squared L2, blocked over N to bound memory.

    Blocks route through the scan plan's per-lane distance path
    (`kernels.distance.scan_sqdist_lanes`, i.e. `sqdist_bdrd` at a
    canonical [1, V, d] shape) rather than a host BLAS matmul: the
    pre-filter scan plan must be bit-identical to this oracle on float32
    (tests/test_planner.py pins it) and numpy BLAS disagrees with XLA:CPU
    in the last ulp. Blocks are SCAN_ALIGN-padded with zero rows, so the
    block decomposition cannot change a value either (64-aligned widths
    are mutually bitwise-stable — see kernels.distance).
    """
    import jax.numpy as jnp

    from repro.kernels.distance import SCAN_ALIGN, scan_sqdist_lanes

    q = jnp.asarray(queries, jnp.float32)
    b = queries.shape[0]
    n = base.shape[0]
    out = np.empty((b, n), dtype=np.float32)
    for s in range(0, n, block):
        e = min(s + block, n)
        v = e - s
        pad = (-v) % SCAN_ALIGN
        blk = np.zeros((v + pad, base.shape[1]), np.float32)
        blk[:v] = base[s:e]
        xg = jnp.broadcast_to(jnp.asarray(blk)[None], (b, v + pad, blk.shape[1]))
        d = scan_sqdist_lanes(q, xg, jnp.ones((b, v + pad), bool))
        out[:, s:e] = np.asarray(d[:, :v])
    return out


def valid_mask(filt, labels_packed: np.ndarray, values: np.ndarray) -> np.ndarray:
    """[B, N] bool validity of every base item for every query filter.

    `filt`: FilterSpec batch or sequence of filter-algebra expressions;
    `values`: [N] (single channel) or [N, V] numeric attributes.
    """
    return filter_matrix(filt, labels_packed, values)


def knn_exact(queries: np.ndarray, base: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
    """Unfiltered exact top-k. Returns (idx[B,k], dist[B,k]) ascending."""
    d2 = _pairwise_sqdist(queries, base)
    idx = np.argpartition(d2, kth=min(k, d2.shape[1] - 1), axis=1)[:, :k]
    dd = np.take_along_axis(d2, idx, axis=1)
    order = np.argsort(dd, axis=1, kind="stable")
    return np.take_along_axis(idx, order, axis=1), np.take_along_axis(dd, order, axis=1)


def filtered_knn_exact(
    queries: np.ndarray,
    base: np.ndarray,
    filt,                      # FilterSpec batch | sequence of expressions
    labels_packed: np.ndarray,
    values: np.ndarray,
    k: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Exact filtered top-k (paper Def. 2.5).

    Returns (idx[B,k], dist[B,k]) ascending; rows with fewer than k valid
    items are padded with idx=-1, dist=+inf.
    """
    d2 = _pairwise_sqdist(queries, base)
    ok = valid_mask(filt, labels_packed, values)
    d2 = np.where(ok, d2, np.inf)
    idx = np.argpartition(d2, kth=min(k, d2.shape[1] - 1), axis=1)[:, :k]
    dd = np.take_along_axis(d2, idx, axis=1)
    order = np.argsort(dd, axis=1, kind="stable")
    idx = np.take_along_axis(idx, order, axis=1)
    dd = np.take_along_axis(dd, order, axis=1)
    idx = np.where(np.isinf(dd), -1, idx)
    return idx.astype(np.int32), dd.astype(np.float32)


def recall_at_k(found_idx: np.ndarray, gt_idx: np.ndarray) -> np.ndarray:
    """Recall@k per query; -1 padding in gt shrinks the denominator."""
    b, k = gt_idx.shape
    rec = np.zeros(b, dtype=np.float64)
    for i in range(b):
        gt = set(int(x) for x in gt_idx[i] if x >= 0)
        if not gt:
            rec[i] = 1.0
            continue
        got = set(int(x) for x in found_idx[i] if x >= 0)
        rec[i] = len(gt & got) / len(gt)
    return rec
