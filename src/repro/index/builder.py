"""Batched proximity-graph construction: NN-descent + α-pruning (Vamana-style).

HNSW's sequential insertion is pointer-chasing and thread-serial; on an
accelerator (and on this 1-core container) we instead build the graph with
matmul-batched primitives:

  1. random R-regular init
  2. NN-descent rounds: candidates = fwd ∪ sampled two-hop ∪ symmetrized
     edges; blockwise distance evaluation; keep best-R distinct
  3. α-prune (RNG/Vamana diversity rule) to restore long-range navigability
  4. symmetrize + cap degree
  5. entry point = medoid

The result is a flat DiskANN/Vamana-style graph searched greedily from the
medoid — the paper's phase-1 (greedy routing) cost remains O(log N)-ish and
negligible (§3.1), which we verify in tests via hop counts.
"""
from __future__ import annotations

import numpy as np

from repro.index.graph import GraphIndex, ShardedGraphIndex


def _block_sqdist(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    """x[B,d], y[B,C,d] -> [B,C] squared L2."""
    xn = (x**2).sum(-1)[:, None]
    yn = (y**2).sum(-1)
    xy = np.einsum("bd,bcd->bc", x, y)
    return np.maximum(xn + yn - 2.0 * xy, 0.0)


def _best_r_distinct(cand: np.ndarray, dist: np.ndarray, r: int, self_ids: np.ndarray):
    """Per-row: drop duplicate / self candidates, keep the r nearest."""
    big = np.float32(np.inf)
    # mark self
    dist = np.where(cand == self_ids[:, None], big, dist)
    dist = np.where(cand < 0, big, dist)
    # dedupe: sort by id, mask repeats, restore by taking topk over masked dist
    order = np.argsort(cand, axis=1, kind="stable")
    cs = np.take_along_axis(cand, order, axis=1)
    ds = np.take_along_axis(dist, order, axis=1)
    dup = np.zeros_like(cs, dtype=bool)
    dup[:, 1:] = cs[:, 1:] == cs[:, :-1]
    ds = np.where(dup, big, ds)
    sel = np.argsort(ds, axis=1, kind="stable")[:, :r]
    out_c = np.take_along_axis(cs, sel, axis=1)
    out_d = np.take_along_axis(ds, sel, axis=1)
    out_c = np.where(np.isinf(out_d), -1, out_c)
    return out_c.astype(np.int32), out_d.astype(np.float32)


def _alpha_prune_block(
    node_ids: np.ndarray,
    cand: np.ndarray,
    cand_dist: np.ndarray,
    vectors: np.ndarray,
    r: int,
    alpha: float,
) -> np.ndarray:
    """Vamana robust-prune for a block of nodes (vectorized over the block).

    cand[blk, C] sorted ascending by cand_dist. Greedily keep candidate j
    unless some already-kept u dominates it: alpha * d(u, j) <= d(p, j).
    """
    blk, c = cand.shape
    safe = np.maximum(cand, 0)
    cv = vectors[safe]  # [blk, C, d]
    # pairwise candidate-candidate distances [blk, C, C]
    nrm = (cv**2).sum(-1)
    cc = nrm[:, :, None] + nrm[:, None, :] - 2.0 * np.einsum("bcd,bed->bce", cv, cv)
    np.maximum(cc, 0.0, out=cc)

    keep = np.zeros((blk, c), dtype=bool)
    pruned = ~np.isfinite(cand_dist) | (cand < 0)
    kept_count = np.zeros(blk, dtype=np.int64)
    a2 = np.float32(alpha * alpha)  # squared-distance domain
    for j in range(c):
        sel = (~pruned[:, j]) & (kept_count < r)
        keep[:, j] |= sel
        kept_count += sel
        # j dominates later t where a2 * d(j,t) <= d(p,t)
        dom = a2 * cc[:, j, :] <= cand_dist
        dom[:, : j + 1] = False
        pruned |= dom & sel[:, None]
    out = np.where(keep, cand, -1)
    # compact kept-first
    order = np.argsort(~keep, axis=1, kind="stable")
    return np.take_along_axis(out, order, axis=1)[:, :r].astype(np.int32)


def _symmetrize(neighbors: np.ndarray, r_cap: int) -> tuple[np.ndarray, np.ndarray]:
    """Return (cand, pad) with forward plus reverse edges per node (ragged->
    dense with cap 2*r_cap reverse samples)."""
    n, r = neighbors.shape
    src = np.repeat(np.arange(n, dtype=np.int32), r)
    dst = neighbors.reshape(-1)
    ok = dst >= 0
    src, dst = src[ok], dst[ok]
    # reverse adjacency via sort by dst
    order = np.argsort(dst, kind="stable")
    rsrc = src[order]
    rdst = dst[order]
    counts = np.bincount(rdst, minlength=n)
    offsets = np.concatenate([[0], np.cumsum(counts)])
    cap = 2 * r_cap
    rev = np.full((n, cap), -1, dtype=np.int32)
    for v in range(n):
        s, e = offsets[v], offsets[v + 1]
        take = min(e - s, cap)
        rev[v, :take] = rsrc[s : s + take]
    return rev, counts


def build_graph_index(
    vectors: np.ndarray,
    degree: int = 32,
    n_iters: int = 10,
    two_hop_sample: int = 32,
    alpha: float = 1.2,
    block: int = 1024,
    seed: int = 0,
    verbose: bool = False,
) -> GraphIndex:
    n, dim = vectors.shape
    r = min(degree, n - 1)
    rng = np.random.default_rng(seed)
    vectors = np.ascontiguousarray(vectors, dtype=np.float32)

    # --- init: random r-regular ---
    nb = rng.integers(0, n - 1, size=(n, r)).astype(np.int32)
    rows = np.arange(n, dtype=np.int32)[:, None]
    nb = np.where(nb >= rows, nb + 1, nb)  # avoid self
    nb_dist = np.full((n, r), np.inf, dtype=np.float32)
    for s in range(0, n, block):
        e = min(s + block, n)
        nb_dist[s:e] = _block_sqdist(vectors[s:e], vectors[np.maximum(nb[s:e], 0)])

    # --- NN-descent rounds (full 2-hop join: converges in ~5 rounds) ---
    cand_width = r + 2 * r + r * r
    join_block = int(max(64, min(block, (1 << 26) // max(cand_width * dim, 1))))
    for it in range(n_iters):
        rev, _ = _symmetrize(nb, r_cap=r)
        new_nb = np.empty_like(nb)
        new_d = np.empty_like(nb_dist)
        for s in range(0, n, join_block):
            e = min(s + join_block, n)
            hop2 = nb[np.maximum(nb[s:e], 0)].reshape(e - s, r * r)
            hop2 = np.where(np.repeat(nb[s:e] >= 0, r, axis=1), hop2, -1)
            cb = np.concatenate([nb[s:e], rev[s:e, : 2 * r], hop2], axis=1)
            db = _block_sqdist(vectors[s:e], vectors[np.maximum(cb, 0)])
            db = np.where(cb < 0, np.inf, db)
            new_nb[s:e], new_d[s:e] = _best_r_distinct(cb, db, r, rows[s:e, 0])
        changed = (new_nb != nb).mean()
        nb, nb_dist = new_nb, new_d
        if verbose:
            print(f"[nn-descent] iter {it}: changed={changed:.3f}")
        if changed < 0.01:
            break

    # --- alpha prune for navigability (keeps some long edges) ---
    pruned = np.empty_like(nb)
    for s in range(0, n, block):
        e = min(s + block, n)
        pruned[s:e] = _alpha_prune_block(
            rows[s:e, 0], nb[s:e], nb_dist[s:e], vectors, r, alpha
        )

    # --- fill spare slots with reverse edges (preserve pruned diversity:
    #     α-pruned edges always stay; reverse edges only top up) ---
    rev, _ = _symmetrize(pruned, r_cap=r)
    final = pruned.copy()
    for s in range(0, n, block):
        e = min(s + block, n)
        blk = final[s:e]
        have = (blk >= 0).sum(axis=1)
        if np.all(have >= r):
            continue
        # candidate reverse edges not already present, nearest-first
        cb = rev[s:e]
        db = _block_sqdist(vectors[s:e], vectors[np.maximum(cb, 0)])
        db = np.where(cb < 0, np.inf, db)
        # mark rev entries duplicating existing pruned edges
        dup = (cb[:, :, None] == blk[:, None, :]).any(axis=2)
        db = np.where(dup | (cb == rows[s:e]), np.inf, db)
        order = np.argsort(db, axis=1, kind="stable")
        cb = np.take_along_axis(cb, order, axis=1)
        db = np.take_along_axis(db, order, axis=1)
        # dedupe within rev itself
        for row in range(blk.shape[0]):
            need = r - have[row]
            if need <= 0:
                continue
            seen = set(int(x) for x in blk[row] if x >= 0)
            fills = []
            for cval, dval in zip(cb[row], db[row]):
                if not np.isfinite(dval):
                    break
                c = int(cval)
                if c not in seen:
                    seen.add(c)
                    fills.append(c)
                    if len(fills) >= need:
                        break
            if fills:
                slots = np.where(blk[row] < 0)[0][: len(fills)]
                blk[row, slots] = fills
        final[s:e] = blk

    # --- medoid entry ---
    mean = vectors.mean(axis=0)
    entry = int(np.argmin(((vectors - mean) ** 2).sum(axis=1)))

    g = GraphIndex(neighbors=final, entry_point=entry, dim=dim)
    g.validate()
    return g


def build_sharded_graph_index(
    vectors: np.ndarray,
    n_shards: int,
    degree: int = 32,
    **build_kw,
) -> ShardedGraphIndex:
    """Partition the corpus into contiguous equal slices and build one
    independent proximity graph per slice (shard-local node ids).

    Per-shard graphs keep the builder embarrassingly parallel and the
    traversal loop unchanged; the price is that a query must probe every
    shard (the sharded engine splits its NDC budget ⌈W/S⌉ per shard) and
    the global result set comes from the cross-shard merge. `build_kw`
    forwards to `build_graph_index` (n_iters, alpha, seed, ...).
    """
    n = vectors.shape[0]
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if n % n_shards != 0:
        raise ValueError(
            f"N={n} not divisible by n_shards={n_shards} — pad the corpus "
            f"to a multiple of {n_shards} (equal slices are what lets "
            "shard_map place one stacked [S, n_s, R] neighbor array)")
    ns = n // n_shards
    shards = []
    for s in range(n_shards):
        g = build_graph_index(vectors[s * ns:(s + 1) * ns], degree=degree,
                              **build_kw)
        g.shard = s
        g.offset = s * ns
        shards.append(g)
    out = ShardedGraphIndex(shards=shards)
    out.validate()
    return out
