"""Fixed-degree proximity-graph container.

TPU-friendly representation: one dense int32 array `neighbors[N, R]`
(padded with -1). Fixed out-degree makes every traversal step a static-shape
gather + distance block, which is what the lockstep search engine and the
Pallas distance kernel consume.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class GraphIndex:
    neighbors: np.ndarray  # [N, R] int32, -1 padded
    entry_point: int       # medoid node id
    dim: int

    @property
    def n(self) -> int:
        return self.neighbors.shape[0]

    @property
    def degree(self) -> int:
        return self.neighbors.shape[1]

    def out_degrees(self) -> np.ndarray:
        return (self.neighbors >= 0).sum(axis=1)

    def validate(self) -> None:
        """Structural invariants the traversal stack relies on.

        Raises TypeError/ValueError with actionable messages (`assert`
        would vanish under `python -O`, silently admitting a graph whose
        out-of-range ids scribble across the visited bitset and gathers).
        `SearchEngine.build` calls this on every engine construction.
        """
        if self.neighbors.ndim != 2:
            raise ValueError(
                f"neighbors must be [N, R], got shape {self.neighbors.shape}")
        n, r = self.neighbors.shape
        if self.neighbors.dtype != np.int32:
            raise TypeError(
                f"neighbors must be int32 (the gather/bitset index type), "
                f"got {self.neighbors.dtype}; cast with .astype(np.int32) "
                "after checking ids fit")
        mx = int(self.neighbors.max())
        if mx >= n:
            raise ValueError(
                f"neighbor id {mx} out of range for N={n} nodes — the "
                "graph references a node that does not exist")
        mn = int(self.neighbors.min())
        if mn < -1:
            raise ValueError(
                f"neighbor id {mn} < -1 (only -1 marks an empty slot)")
        rows = np.arange(n)[:, None]
        valid = self.neighbors >= 0
        loops = np.any((self.neighbors == rows) & valid, axis=1)
        if loops.any():
            bad = int(np.argmax(loops))
            raise ValueError(
                f"self loop at node {bad} ({int(loops.sum())} total) — "
                "prune self edges before building an engine")
        if not 0 <= self.entry_point < n:
            raise ValueError(
                f"entry_point {self.entry_point} outside [0, {n})")

    def save(self, path: str) -> None:
        np.savez_compressed(
            path, neighbors=self.neighbors, entry_point=self.entry_point, dim=self.dim
        )

    @staticmethod
    def load(path: str) -> "GraphIndex":
        z = np.load(path)
        return GraphIndex(
            neighbors=z["neighbors"].astype(np.int32),
            entry_point=int(z["entry_point"]),
            dim=int(z["dim"]),
        )
