"""Fixed-degree proximity-graph container.

TPU-friendly representation: one dense int32 array `neighbors[N, R]`
(padded with -1). Fixed out-degree makes every traversal step a static-shape
gather + distance block, which is what the lockstep search engine and the
Pallas distance kernel consume.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class GraphIndex:
    neighbors: np.ndarray  # [N, R] int32, -1 padded
    entry_point: int       # medoid node id
    dim: int

    @property
    def n(self) -> int:
        return self.neighbors.shape[0]

    @property
    def degree(self) -> int:
        return self.neighbors.shape[1]

    def out_degrees(self) -> np.ndarray:
        return (self.neighbors >= 0).sum(axis=1)

    def validate(self) -> None:
        n, r = self.neighbors.shape
        assert self.neighbors.dtype == np.int32
        assert self.neighbors.max() < n
        assert self.neighbors.min() >= -1
        # no self loops among valid entries
        rows = np.arange(n)[:, None]
        valid = self.neighbors >= 0
        assert not np.any((self.neighbors == rows) & valid), "self loop"
        assert 0 <= self.entry_point < n

    def save(self, path: str) -> None:
        np.savez_compressed(
            path, neighbors=self.neighbors, entry_point=self.entry_point, dim=self.dim
        )

    @staticmethod
    def load(path: str) -> "GraphIndex":
        z = np.load(path)
        return GraphIndex(
            neighbors=z["neighbors"].astype(np.int32),
            entry_point=int(z["entry_point"]),
            dim=int(z["dim"]),
        )
