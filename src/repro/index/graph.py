"""Fixed-degree proximity-graph container (single-slice and sharded).

TPU-friendly representation: one dense int32 array `neighbors[N, R]`
(padded with -1). Fixed out-degree makes every traversal step a static-shape
gather + distance block, which is what the lockstep search engine and the
Pallas distance kernel consume.

For index-axis sharding (`core.sharded`), `ShardedGraphIndex` holds one
independent `GraphIndex` per contiguous corpus slice. Each shard graph uses
shard-*local* node ids in [0, n_s) — edges never cross slices — and carries
its slice coordinates (`shard`, `offset`) so validation errors name the
offending shard instead of surfacing later as a silent bad gather.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class GraphIndex:
    neighbors: np.ndarray  # [N, R] int32, -1 padded, shard-local ids
    entry_point: int       # medoid node id (shard-local)
    dim: int
    shard: int | None = None  # shard ordinal when part of a ShardedGraphIndex
    offset: int = 0           # global row id of local row 0 (slice start)

    @property
    def n(self) -> int:
        return self.neighbors.shape[0]

    @property
    def degree(self) -> int:
        return self.neighbors.shape[1]

    def out_degrees(self) -> np.ndarray:
        return (self.neighbors >= 0).sum(axis=1)

    def _where(self) -> str:
        """Locator suffix for error messages: which shard/slice is bad."""
        if self.shard is None:
            return ""
        return (f" (shard {self.shard}, global rows "
                f"[{self.offset}, {self.offset + self.n}))")

    def validate(self) -> None:
        """Structural invariants the traversal stack relies on.

        Raises TypeError/ValueError with actionable messages (`assert`
        would vanish under `python -O`, silently admitting a graph whose
        out-of-range ids scribble across the visited bitset and gathers).
        `SearchEngine.build` calls this on every engine construction; for
        sharded graphs every message carries the shard/slice coordinates,
        because an id that is ≥ n_s but < N is a *cross-shard* edge — valid
        globally, fatal locally — and the global range alone can't show it.
        """
        if self.neighbors.ndim != 2:
            raise ValueError(
                f"neighbors must be [N, R], got shape "
                f"{self.neighbors.shape}{self._where()}")
        n, r = self.neighbors.shape
        if self.neighbors.dtype != np.int32:
            raise TypeError(
                f"neighbors must be int32 (the gather/bitset index type), "
                f"got {self.neighbors.dtype}{self._where()}; cast with "
                ".astype(np.int32) after checking ids fit")
        mx = int(self.neighbors.max())
        if mx >= n:
            row = int(np.argmax(self.neighbors.max(axis=1) >= n))
            raise ValueError(
                f"neighbor id {mx} out of range for N={n} nodes (first bad "
                f"row: local {row} = global {self.offset + row})"
                f"{self._where()} — ids must be shard-local; a value in "
                f"[{n}, ∞) usually means a global id leaked into a shard "
                "slice")
        mn = int(self.neighbors.min())
        if mn < -1:
            raise ValueError(
                f"neighbor id {mn} < -1 (only -1 marks an empty slot)"
                f"{self._where()}")
        rows = np.arange(n)[:, None]
        valid = self.neighbors >= 0
        loops = np.any((self.neighbors == rows) & valid, axis=1)
        if loops.any():
            bad = int(np.argmax(loops))
            raise ValueError(
                f"self loop at node {bad} ({int(loops.sum())} total)"
                f"{self._where()} — prune self edges before building an "
                "engine")
        if not 0 <= self.entry_point < n:
            raise ValueError(
                f"entry_point {self.entry_point} outside [0, {n})"
                f"{self._where()}")

    def save(self, path: str) -> None:
        np.savez_compressed(
            path, neighbors=self.neighbors, entry_point=self.entry_point, dim=self.dim
        )

    @staticmethod
    def load(path: str) -> "GraphIndex":
        z = np.load(path)
        return GraphIndex(
            neighbors=z["neighbors"].astype(np.int32),
            entry_point=int(z["entry_point"]),
            dim=int(z["dim"]),
        )


@dataclasses.dataclass
class ShardedGraphIndex:
    """S independent per-slice graphs over one corpus (JAG-style partition).

    Slices are contiguous and equal-sized: shard s owns global rows
    [s·n_s, (s+1)·n_s). Every shard graph is self-contained (local ids,
    its own medoid entry point), which is what lets per-shard traversal run
    with an unmodified lockstep loop; the cross-shard top-k merge
    (`distributed.merge`) is the only global operation.
    """

    shards: list  # [S] GraphIndex, equal n and degree

    def __post_init__(self):
        if not self.shards:
            raise ValueError("ShardedGraphIndex needs at least one shard")
        ns = {g.n for g in self.shards}
        if len(ns) != 1:
            raise ValueError(
                f"shard sizes must match for stacked shard_map placement, "
                f"got {sorted(ns)}")
        rs = {g.degree for g in self.shards}
        if len(rs) != 1:
            raise ValueError(f"shard degrees must match, got {sorted(rs)}")

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def shard_size(self) -> int:
        return self.shards[0].n

    @property
    def n(self) -> int:
        return self.shard_size * self.n_shards

    @property
    def degree(self) -> int:
        return self.shards[0].degree

    @property
    def dim(self) -> int:
        return self.shards[0].dim

    @property
    def offsets(self) -> np.ndarray:
        """[S] global row id of each shard's local row 0."""
        return np.asarray([g.offset for g in self.shards], np.int32)

    @property
    def entry_points(self) -> np.ndarray:
        """[S] shard-local entry node ids."""
        return np.asarray([g.entry_point for g in self.shards], np.int32)

    def validate(self) -> None:
        for s, g in enumerate(self.shards):
            if g.shard != s:
                raise ValueError(
                    f"shard list order broken: position {s} holds shard "
                    f"{g.shard}")
            if g.offset != s * self.shard_size:
                raise ValueError(
                    f"shard {s} offset {g.offset} != contiguous slice start "
                    f"{s * self.shard_size}")
            g.validate()
