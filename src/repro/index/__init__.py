from repro.index.graph import GraphIndex, ShardedGraphIndex
from repro.index.builder import build_graph_index, build_sharded_graph_index
from repro.index.bruteforce import filtered_knn_exact, knn_exact

__all__ = ["GraphIndex", "ShardedGraphIndex", "build_graph_index",
           "build_sharded_graph_index", "filtered_knn_exact", "knn_exact"]
