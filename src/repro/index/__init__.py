from repro.index.graph import GraphIndex
from repro.index.builder import build_graph_index
from repro.index.bruteforce import filtered_knn_exact, knn_exact

__all__ = ["GraphIndex", "build_graph_index", "filtered_knn_exact", "knn_exact"]
