"""DecoderLM — unified decoder-only assembly for all assigned families.

A model is a list of *segments*; each segment is a scanned stack of groups,
and a group applies a static *period* of block types, e.g.

  olmo/granite          period = (gqa-global+mlp,)            x L groups
  h2o-danube3 (SWA)     period = (gqa-local+mlp,)             x L
  gemma3 (5:1)          period = (local x5, global)           x L/6
  phi3.5-moe            period = (gqa-global+moe,)            x L
  deepseek-v3           prefix  = 3 unrolled (mla+dense)
                        period = (mla+moe,)                   x 58
  mamba2                period = (ssd,)                       x L
  zamba2                period = (ssd x6, shared-attn+mlp)    x L/6
  llama-3.2-vision      period = (self x4, self+cross)        x L/5

Scan-over-groups keeps HLO size depth-independent (compile time on the
512-way dry-run) while the per-period python loop keeps heterogeneous
layer kinds fully static. `jax.checkpoint` wraps each group in training
(remat policy: save only block boundaries).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models import mamba2 as ssm_mod
from repro.models.common import P, apply_norm, dense_init, init_norm, split_tree


class BlockType(NamedTuple):
    mixer: str = "gqa"      # gqa | mla | ssm | shared_attn
    window: int = 0         # 0 = global attention
    ffn: str = "dense"      # dense | moe | none
    cross: bool = False     # + cross-attention sub-block (vlm / encdec decoder)
    bidir: bool = False     # non-causal self-attention (encoder stacks)


class Segment(NamedTuple):
    period: tuple           # tuple[BlockType]
    n_groups: int
    scanned: bool = True


class Ctx(NamedTuple):
    mode: str               # train | prefill | decode
    positions: jax.Array | None = None   # [B, S] for full-seq modes
    pos: jax.Array | None = None         # [B] decode position
    enc: jax.Array | None = None         # [B, Se, d] cross-attn memory
    max_seq: int = 0                     # cache capacity for prefill


def layer_plan(cfg: ArchConfig) -> tuple[list[Segment], list[BlockType]]:
    """Returns (scanned segments, unrolled prefix block types)."""
    mixer = "mla" if cfg.use_mla else ("ssm" if cfg.family in ("ssm", "hybrid") and not cfg.hybrid_period else "gqa")
    prefix: list[BlockType] = []
    if cfg.family == "ssm":
        return [Segment((BlockType("ssm", ffn="none"),), cfg.n_layers)], prefix
    if cfg.family == "hybrid":
        per = (BlockType("ssm", ffn="none"),) * cfg.hybrid_period + (
            BlockType("shared_attn", ffn="dense"),)
        return [Segment(per, cfg.n_layers // cfg.hybrid_period)], prefix
    if cfg.family == "vlm":
        per = (BlockType("gqa"),) * (cfg.cross_attn_period - 1) + (
            BlockType("gqa", cross=True),)
        return [Segment(per, cfg.n_layers // cfg.cross_attn_period)], prefix
    ffn_kind = "moe" if cfg.n_experts else "dense"
    if cfg.attn_kind == "local":
        per = (BlockType(mixer, window=cfg.local_window, ffn=ffn_kind),)
        return [Segment(per, cfg.n_layers)], prefix
    if cfg.attn_kind == "local_global":
        p = cfg.local_global_period
        per = (BlockType(mixer, window=cfg.local_window, ffn=ffn_kind),) * (p - 1) + (
            BlockType(mixer, ffn=ffn_kind),)
        return [Segment(per, cfg.n_layers // p)], prefix
    # global attention; maybe dense prefix before MoE stack
    if cfg.first_dense_layers:
        prefix = [BlockType(mixer, ffn="dense")] * cfg.first_dense_layers
        n_rest = cfg.n_layers - cfg.first_dense_layers
        return [Segment((BlockType(mixer, ffn=ffn_kind),), n_rest)], prefix
    return [Segment((BlockType(mixer, ffn=ffn_kind),), cfg.n_layers)], prefix


# ------------------------------------------------------------------ blocks ----
def _init_block(key, cfg: ArchConfig, bt: BlockType):
    ks = jax.random.split(key, 6)
    p = {"norm1": init_norm(ks[0], cfg, cfg.d_model)}
    if bt.mixer == "gqa":
        p["attn"] = attn.init_attention(ks[1], cfg)
    elif bt.mixer == "mla":
        p["attn"] = attn.init_mla(ks[1], cfg)
    elif bt.mixer == "ssm":
        p["mixer"] = ssm_mod.init_mamba2(ks[1], cfg)
    elif bt.mixer == "shared_attn":
        pass  # weights live in the shared top-level block
    if bt.cross:
        p["norm_cross"] = init_norm(ks[2], cfg, cfg.d_model)
        p["cross"] = attn.init_attention(ks[3], cfg)
    if bt.ffn != "none":
        p["norm2"] = init_norm(ks[4], cfg, cfg.d_model)
        p["ffn"] = ffn_mod.init_moe(ks[5], cfg) if bt.ffn == "moe" else ffn_mod.init_mlp(ks[5], cfg)
    return p


def _init_block_cache(cfg: ArchConfig, bt: BlockType, b: int, s_max: int):
    """Zero cache arrays (P-wrapped with logical axes) for one block."""
    kv, hd = cfg.n_kv_heads, cfg.hd
    dt = cfg.compute_dtype
    c = {}
    if bt.mixer == "gqa" or bt.mixer == "shared_attn":
        s = min(bt.window, s_max) if bt.window else s_max
        kv_axes = ("batch", "seq", "kv_heads", "head_dim")
        c["k"] = P(jnp.zeros((b, s, kv, hd), dt), kv_axes)
        c["v"] = P(jnp.zeros((b, s, kv, hd), dt), kv_axes)
    elif bt.mixer == "mla":
        c["c_kv"] = P(jnp.zeros((b, s_max, cfg.kv_lora_rank), dt),
                      ("batch", "seq", None))
        c["k_rope"] = P(jnp.zeros((b, s_max, cfg.qk_rope_dim), dt),
                        ("batch", "seq", None))
    elif bt.mixer == "ssm":
        di = cfg.ssm_expand * cfg.d_model
        h = di // cfg.ssm_head_dim
        c["h"] = P(jnp.zeros((b, h, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
                   ("batch", "heads", None, None))
        c["conv"] = P(jnp.zeros((b, cfg.ssm_conv - 1, di + 2 * cfg.ssm_state), dt),
                      ("batch", None, "mlp"))
    if bt.cross:
        c["ck"] = P(jnp.zeros((b, _cross_len(cfg), kv, hd), dt),
                    ("batch", None, "kv_heads", "head_dim"))
        c["cv"] = P(jnp.zeros((b, _cross_len(cfg), kv, hd), dt),
                    ("batch", None, "kv_heads", "head_dim"))
    return c


def _cross_len(cfg: ArchConfig) -> int:
    return cfg.vision_seq if cfg.family == "vlm" else cfg.encoder_seq


def _pad_cache_seq(full, part):
    """Place prefill-length cache `part` into capacity-sized `full` at t=0."""
    return jax.tree.map(
        lambda f, pp: jax.lax.dynamic_update_slice(f, pp.astype(f.dtype),
                                                   (0,) * f.ndim),
        full, part)


class BlockApplier:
    """Applies one block type in any mode; closes over cfg + shared params."""

    def __init__(self, cfg: ArchConfig, shared=None):
        self.cfg = cfg
        self.shared = shared  # zamba2 shared transformer block params

    def __call__(self, bt: BlockType, bp, x, ctx: Ctx, cache=None):
        cfg = self.cfg
        aux = jnp.float32(0.0)
        new_cache = {}
        h = apply_norm(cfg, bp["norm1"], x)

        if bt.mixer == "ssm":
            if ctx.mode == "decode":
                out, new_mix = ssm_mod.mamba2_decode(cfg, bp["mixer"], h,
                                                     {"h": cache["h"], "conv": cache["conv"]},
                                                     pos=ctx.pos)
                new_cache.update(new_mix)
            elif ctx.mode == "prefill":
                out, st = ssm_mod.mamba2_forward(cfg, bp["mixer"], h, return_state=True)
                new_cache.update(st)
            else:
                out = ssm_mod.mamba2_forward(cfg, bp["mixer"], h)
        elif bt.mixer == "mla":
            if ctx.mode == "decode":
                out, new_mla = attn.mla_decode(cfg, bp["attn"], h,
                                               {"c_kv": cache["c_kv"], "k_rope": cache["k_rope"]},
                                               pos=ctx.pos)
                new_cache.update(new_mla)
            else:
                out, (ckv, krope) = attn.mla_forward(cfg, bp["attn"], h,
                                                     positions=ctx.positions)
                if ctx.mode == "prefill":
                    new_cache["c_kv"], new_cache["k_rope"] = ckv, krope
        else:  # gqa / shared_attn
            ap = self.shared["attn"] if bt.mixer == "shared_attn" else bp["attn"]
            if ctx.mode == "decode":
                out, kvc = attn.attention_decode(cfg, ap, h,
                                                 {"k": cache["k"], "v": cache["v"]},
                                                 pos=ctx.pos, window=bt.window)
                new_cache.update(kvc)
            else:
                out, (kk, vv) = attn.attention_forward(
                    cfg, ap, h, positions=ctx.positions, causal=not bt.bidir,
                    window=bt.window)
                if ctx.mode == "prefill":
                    if bt.window:  # rolling window cache: keep last W roped keys
                        w = min(bt.window, kk.shape[1])
                        new_cache["k"], new_cache["v"] = kk[:, -w:], vv[:, -w:]
                    else:
                        new_cache["k"], new_cache["v"] = kk, vv
        x = x + out

        if bt.cross:
            hc = apply_norm(cfg, bp["norm_cross"], x)
            if ctx.mode == "decode":
                out, _ = attn.attention_decode(cfg, bp["cross"], hc, None, pos=ctx.pos,
                                               cross_kv=(cache["ck"], cache["cv"]))
                # static cross KV passes through (keeps cache pytree stable)
                new_cache["ck"], new_cache["cv"] = cache["ck"], cache["cv"]
            else:
                out, (ck, cv) = attn.attention_forward(
                    cfg, bp["cross"], hc, positions=ctx.positions, kv_override=ctx.enc)
                if ctx.mode == "prefill":
                    new_cache["ck"], new_cache["cv"] = ck, cv
            x = x + out

        if bt.ffn != "none":
            fp = self.shared["ffn"] if bt.mixer == "shared_attn" else bp["ffn"]
            np_ = self.shared["norm2"] if bt.mixer == "shared_attn" else bp["norm2"]
            h2 = apply_norm(cfg, np_, x)
            if bt.ffn == "moe":
                if ctx.mode == "train":
                    out, a = ffn_mod.moe_forward(cfg, fp, h2, return_aux=True)
                    aux = aux + a
                else:
                    out = ffn_mod.moe_forward(cfg, fp, h2)
            else:
                out = ffn_mod.mlp_forward(cfg, fp, h2)
            x = x + out
        return x, new_cache, aux


# ---------------------------------------------------------------- the LM ----
class DecoderLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.segments, self.prefix = layer_plan(cfg)

    # ---------- init ----------
    def init_params(self, key):
        cfg = self.cfg
        ks = iter(jax.random.split(key, 64))
        prm = {
            "embed": dense_init(next(ks), (cfg.vocab_size, cfg.d_model),
                                cfg.d_model, cfg.param_dtype, ("vocab", "embed")),
            "final_norm": init_norm(next(ks), cfg, cfg.d_model),
        }
        if not cfg.tie_embeddings:
            prm["head"] = dense_init(next(ks), (cfg.d_model, cfg.vocab_size),
                                     cfg.d_model, cfg.param_dtype, ("embed", "vocab"))
        if cfg.family == "hybrid":
            prm["shared"] = {
                "attn": attn.init_attention(next(ks), cfg),
                "norm2": init_norm(next(ks), cfg, cfg.d_model),
                "ffn": ffn_mod.init_mlp(next(ks), cfg),
            }
        for i, bt in enumerate(self.prefix):
            prm[f"prefix{i}"] = _init_block(next(ks), cfg, bt)
        for si, seg in enumerate(self.segments):
            pos_params = []
            for pi, bt in enumerate(seg.period):
                if seg.scanned and seg.n_groups > 1:
                    stacked = _stack_inits(
                        [_init_block(k, cfg, bt)
                         for k in jax.random.split(next(ks), seg.n_groups)])
                else:
                    stacked = _stack_inits([_init_block(next(ks), cfg, bt)])
                pos_params.append(stacked)
            prm[f"seg{si}"] = {f"pos{pi}": pp for pi, pp in enumerate(pos_params)}
        if cfg.mtp:
            prm["mtp_proj"] = dense_init(next(ks), (2 * cfg.d_model, cfg.d_model),
                                         2 * cfg.d_model, cfg.param_dtype,
                                         ("embed", "embed2"))
            bt = self.segments[-1].period[-1]
            prm["mtp_block"] = _init_block(next(ks), cfg, bt)
            prm["mtp_norm"] = init_norm(next(ks), cfg, cfg.d_model)
        return prm

    def init_cache(self, b: int, s_max: int):
        cfg = self.cfg
        cache = {}
        for i, bt in enumerate(self.prefix):
            cache[f"prefix{i}"] = _init_block_cache(cfg, bt, b, s_max)
        for si, seg in enumerate(self.segments):
            seg_c = {}
            for pi, bt in enumerate(seg.period):
                one = _init_block_cache(cfg, bt, b, s_max)
                seg_c[f"pos{pi}"] = jax.tree.map(
                    lambda p: P(jnp.broadcast_to(p.value[None], (seg.n_groups,) + p.value.shape),
                                ("layers",) + p.axes),
                    one, is_leaf=lambda x: isinstance(x, P))
            cache[f"seg{si}"] = seg_c
        return cache

    # ---------- forward ----------
    def _backbone(self, prm, x, ctx: Ctx, cache=None):
        from repro.distributed.sharding import constrain

        cfg = self.cfg
        applier = BlockApplier(cfg, shared=prm.get("shared"))
        aux_total = jnp.float32(0.0)
        new_cache = {}
        act_axes = ("batch", "seq", None)
        x = constrain(x, act_axes)

        for i, bt in enumerate(self.prefix):
            c = cache.get(f"prefix{i}") if cache else None

            def pfx(bp, x, cc, bt=bt):
                return applier(bt, bp, x, ctx, cc)

            if cfg.remat and ctx.mode == "train":
                pfx = jax.checkpoint(pfx)
            x, nc, aux = pfx(prm[f"prefix{i}"], x, c)
            x = constrain(x, act_axes)
            aux_total += aux
            if nc:
                new_cache[f"prefix{i}"] = nc

        for si, seg in enumerate(self.segments):
            sp = prm[f"seg{si}"]
            sc = cache.get(f"seg{si}") if cache else None

            def group_body(carry, xs):
                x, aux = carry
                x = constrain(x, act_axes)
                outs = {}
                for pi, bt in enumerate(seg.period):
                    bp = xs[f"pos{pi}"]
                    cc = xs.get(f"cache{pi}")
                    x, nc, a = applier(bt, bp, x, ctx, cc)
                    x = constrain(x, act_axes)
                    aux = aux + a
                    outs[f"cache{pi}"] = nc
                return (x, aux), outs

            body = group_body
            if cfg.remat and ctx.mode == "train":
                import os
                pol = os.environ.get("REPRO_REMAT_POLICY")
                if pol == "dots":
                    body = jax.checkpoint(
                        group_body,
                        policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
                else:
                    body = jax.checkpoint(group_body)

            xs = {f"pos{pi}": sp[f"pos{pi}"] for pi in range(len(seg.period))}
            if sc is not None:
                for pi in range(len(seg.period)):
                    xs[f"cache{pi}"] = sc[f"pos{pi}"]
            (x, aux_total), seg_out = jax.lax.scan(body, (x, aux_total), xs)
            if ctx.mode != "train":
                new_cache[f"seg{si}"] = {
                    f"pos{pi}": seg_out[f"cache{pi}"] for pi in range(len(seg.period))}
        return x, new_cache, aux_total

    def _embed(self, prm, tokens):
        cd = self.cfg.compute_dtype
        return prm["embed"].astype(cd)[tokens]

    def _logits(self, prm, x):
        cd = self.cfg.compute_dtype
        x = apply_norm(self.cfg, prm["final_norm"], x)
        head = prm["embed"].T if self.cfg.tie_embeddings else prm["head"]
        return x @ head.astype(cd)

    def _head_fn(self, prm):
        cfg = self.cfg

        def head_fn(x):
            x = apply_norm(cfg, prm["final_norm"], x)
            head = prm["embed"].T if cfg.tie_embeddings else prm["head"]
            return x @ head.astype(cfg.compute_dtype)

        return head_fn

    def loss(self, prm, batch):
        """Next-token CE + MoE aux (+ MTP). batch: tokens [B,S] (+ stubs)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        ctx = Ctx(mode="train", positions=positions, enc=batch.get("enc"))
        x = self._embed(prm, tokens)
        h, _, aux = self._backbone(prm, x, ctx)
        # shifted labels with the final position masked out
        labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
        mask = jnp.ones((b, s), jnp.float32).at[:, -1].set(0.0)
        ce = _xent_chunked(self._head_fn(prm), h, labels, mask,
                           unroll=cfg.unroll_inner)
        loss = ce + cfg.router_aux_weight * aux
        metrics = {"ce": ce, "aux": aux}
        if cfg.mtp:
            # predict t+2 from (h_t, emb(t+1)) through one extra block
            emb_next = self._embed(prm, labels)  # emb(t+1), last pos masked
            cat = jnp.concatenate([apply_norm(cfg, prm["mtp_norm"], h), emb_next],
                                  axis=-1)
            hm = cat @ prm["mtp_proj"].astype(cfg.compute_dtype)
            applier = BlockApplier(cfg, shared=prm.get("shared"))
            ctx2 = Ctx(mode="train", positions=positions)
            bt = self.segments[-1].period[-1]

            def mtp_fn(bp, hh):
                return applier(bt, bp, hh, ctx2)

            if cfg.remat:
                mtp_fn = jax.checkpoint(mtp_fn)
            hm, _, aux2 = mtp_fn(prm["mtp_block"], hm)
            labels2 = jnp.concatenate([tokens[:, 2:], tokens[:, :2]], axis=1)
            mask2 = jnp.ones((b, s), jnp.float32).at[:, -2:].set(0.0)
            mtp_ce = _xent_chunked(self._head_fn(prm), hm, labels2, mask2,
                                   unroll=cfg.unroll_inner)
            loss = loss + 0.3 * mtp_ce + cfg.router_aux_weight * aux2
            metrics["mtp_ce"] = mtp_ce
        return loss, metrics

    def prefill(self, prm, batch):
        """Full-seq forward; returns (last-position logits, cache)."""
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        ctx = Ctx(mode="prefill", positions=positions, enc=batch.get("enc"),
                  max_seq=s)
        x = self._embed(prm, tokens)
        h, cache, _ = self._backbone(prm, x, ctx)
        return self._logits(prm, h[:, -1:]), cache

    def decode_step(self, prm, cache, tokens, pos, enc=None):
        """One token: tokens [B,1], pos [B]. Returns (logits [B,1,V], cache)."""
        ctx = Ctx(mode="decode", pos=pos, enc=enc)
        x = self._embed(prm, tokens)
        h, new_cache, _ = self._backbone(prm, x, ctx, cache)
        return self._logits(prm, h), new_cache


def _xent(logits, labels):
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32), labels[..., None],
                               axis=-1)[..., 0]
    return (lse - gold).mean()


def _xent_chunked(head_fn, h, labels, mask, chunk=512, unroll=False):
    """Sequence-chunked CE: never materializes [B, S, V] logits.

    Essential for 256k-vocab archs (gemma3): peak logits memory becomes
    B × chunk × V/shards. The chunk body is rematerialized on backward.
    """
    b, s, d = h.shape
    c = min(chunk, s)
    nc = -(-s // c)
    pad = nc * c - s
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    hc = h.reshape(b, nc, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, c).transpose(1, 0, 2)
    mc = mask.reshape(b, nc, c).transpose(1, 0, 2)

    @jax.checkpoint
    def body(acc, xs):
        hh, ll, mm = xs
        logits = head_fn(hh).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        ce = ((lse - gold) * mm).sum()
        return (acc[0] + ce, acc[1] + mm.sum()), None

    from repro.models.common import maybe_scan

    (tot, cnt), _ = maybe_scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                               (hc, lc, mc), unroll)
    return tot / jnp.maximum(cnt, 1.0)


def _stack_inits(dicts):
    """Stack a list of P-trees along a new leading 'layers' axis."""
    return jax.tree.map(
        lambda *ps: P(jnp.stack([p.value for p in ps]), ("layers",) + ps[0].axes),
        *dicts, is_leaf=lambda x: isinstance(x, P))
