"""build_model(cfg) — the single entry point from config to model object."""
from __future__ import annotations

from repro.configs.base import ArchConfig
from repro.models.encdec import EncDecLM
from repro.models.transformer import DecoderLM


def build_model(cfg: ArchConfig):
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    return DecoderLM(cfg)
