"""Mamba2 SSD (state-space duality) mixer — chunked matmul formulation.

Train/prefill: `lax.scan` over sequence chunks; each chunk does the
quadratic intra-chunk term (attention-like, MXU-friendly [B,H,Q,Q]
matmuls) plus the inter-chunk state recurrence — the SSD algorithm of
Mamba2 adapted so no [B,nc,H,Q,Q] tensor is ever materialized (VMEM/HBM
bounded by one chunk).

Decode: O(1) recurrent state update h[t] = e^{aΔ} h[t-1] + Δ·(B ⊗ x),
y = C·h + D·x — the reason mamba archs run the long_500k cell.

Single B/C group (n_groups=1, the 2.7b default): B,C ∈ [B,S,N].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import P, dense_init, rms_norm, silu


def init_mamba2(key, cfg):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    hd = cfg.ssm_head_dim
    h = di // hd
    k = cfg.ssm_conv
    ks = jax.random.split(key, 8)
    return {
        "wz": dense_init(ks[0], (d, di), d, cfg.param_dtype, ("embed", "mlp")),
        "wx": dense_init(ks[1], (d, di), d, cfg.param_dtype, ("embed", "mlp")),
        "wB": dense_init(ks[2], (d, n), d, cfg.param_dtype, ("embed", None)),
        "wC": dense_init(ks[3], (d, n), d, cfg.param_dtype, ("embed", None)),
        "wdt": dense_init(ks[4], (d, h), d, cfg.param_dtype, ("embed", None)),
        "conv_x": P(jnp.zeros((k, di), cfg.param_dtype), (None, "mlp")),
        "conv_B": P(jnp.zeros((k, n), cfg.param_dtype), (None, None)),
        "conv_C": P(jnp.zeros((k, n), cfg.param_dtype), (None, None)),
        "a_log": P(jnp.zeros((h,), cfg.param_dtype), (None,)),
        "d_skip": P(jnp.ones((h,), cfg.param_dtype), (None,)),
        "dt_bias": P(jnp.zeros((h,), cfg.param_dtype), (None,)),
        "norm": P(jnp.zeros((di,), cfg.param_dtype), ("mlp",)),
        "wo": dense_init(ks[5], (di, d), di, cfg.param_dtype, ("mlp", "embed")),
    }


def _causal_conv(x, w):
    """Depthwise causal conv via k shifted adds. x [B,S,C], w [k,C]."""
    k = w.shape[0]
    s = x.shape[1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    acc = jnp.zeros_like(x)
    for i in range(k):
        acc = acc + xp[:, i : i + s, :] * w[i]
    return acc


def _ssd_chunked(xdt, a, bb, cc, chunk, unroll=False):
    """SSD over chunks.

    xdt [B,S,H,P]  inputs pre-scaled by dt
    a   [B,S,H]    per-step log decay (dt * A, negative)
    bb  [B,S,N]    input projection (shared across heads)
    cc  [B,S,N]    output projection
    returns y [B,S,H,P], final state [B,H,P,N]
    """
    b, s, h, p = xdt.shape
    n = bb.shape[-1]
    q = min(chunk, s)
    nc = s // q
    assert nc * q == s, "seq must be divisible by ssm_chunk"

    xdt_c = xdt.reshape(b, nc, q, h, p).transpose(1, 0, 2, 3, 4)
    a_c = a.reshape(b, nc, q, h).transpose(1, 0, 2, 3)
    b_c = bb.reshape(b, nc, q, n).transpose(1, 0, 2, 3)
    c_c = cc.reshape(b, nc, q, n).transpose(1, 0, 2, 3)

    tri = jnp.tril(jnp.ones((q, q), bool))

    def body(hstate, xs):
        xq, aq, bq, cq = xs                       # [B,Q,H,P] [B,Q,H] [B,Q,N] [B,Q,N]
        a_cs = jnp.cumsum(aq, axis=1)             # inclusive [B,Q,H]
        # intra-chunk (quadratic, attention-like)
        cb = jnp.einsum("bqn,bkn->bqk", cq, bq,
                        preferred_element_type=jnp.float32)       # [B,Q,Q]
        ldec = jnp.exp(a_cs[:, :, None, :] - a_cs[:, None, :, :]) # [B,Q,K,H]
        ldec = jnp.where(tri[None, :, :, None], ldec, 0.0)
        y_intra = jnp.einsum("bqk,bqkh,bkhp->bqhp", cb, ldec, xq,
                             preferred_element_type=jnp.float32)
        # inter-chunk from carried state
        y_inter = jnp.einsum("bqn,bhpn->bqhp", cq, hstate,
                             preferred_element_type=jnp.float32)
        y_inter = y_inter * jnp.exp(a_cs)[..., None]
        # state update
        a_sum = a_cs[:, -1, :]                                    # [B,H]
        w = jnp.exp(a_sum[:, None, :] - a_cs)                     # [B,Q,H]
        h_new = hstate * jnp.exp(a_sum)[..., None, None] + jnp.einsum(
            "bqh,bqn,bqhp->bhpn", w, bq, xq,
            preferred_element_type=jnp.float32)
        return h_new, y_intra + y_inter

    from repro.models.common import maybe_scan

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    hfin, y = maybe_scan(body, h0, (xdt_c, a_c, b_c, c_c), unroll)
    y = y.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    return y, hfin


def mamba2_forward(cfg, prm, x, return_state=False):
    """Full-sequence mixer. x [B,S,d] -> [B,S,d]."""
    b, s, d = x.shape
    di = cfg.ssm_expand * d
    hd = cfg.ssm_head_dim
    h = di // hd
    cd = cfg.compute_dtype

    z = x @ prm["wz"].astype(cd)
    xi = _causal_conv(x @ prm["wx"].astype(cd), prm["conv_x"].astype(cd))
    bi = _causal_conv(x @ prm["wB"].astype(cd), prm["conv_B"].astype(cd))
    ci = _causal_conv(x @ prm["wC"].astype(cd), prm["conv_C"].astype(cd))
    xi, bi, ci = silu(xi), silu(bi), silu(ci)

    dt = jax.nn.softplus(
        (x @ prm["wdt"].astype(cd)).astype(jnp.float32) + prm["dt_bias"].astype(jnp.float32)
    )                                                             # [B,S,H]
    a = -jnp.exp(prm["a_log"].astype(jnp.float32))                # [H]
    alog = dt * a[None, None, :]                                  # [B,S,H]

    xh = xi.reshape(b, s, h, hd)
    xdt = xh.astype(jnp.float32) * dt[..., None]
    y, hfin = _ssd_chunked(xdt, alog, bi.astype(jnp.float32), ci.astype(jnp.float32),
                           cfg.ssm_chunk, unroll=cfg.unroll_inner)
    y = y + xh.astype(jnp.float32) * prm["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, di).astype(cd)
    y = rms_norm(y * silu(z), prm["norm"])
    out = y @ prm["wo"].astype(cd)
    if return_state:
        conv_tail = jnp.concatenate(
            [
                (x @ prm["wx"].astype(cd))[:, -(cfg.ssm_conv - 1):, :],
                (x @ prm["wB"].astype(cd))[:, -(cfg.ssm_conv - 1):, :],
                (x @ prm["wC"].astype(cd))[:, -(cfg.ssm_conv - 1):, :],
            ],
            axis=-1,
        )
        return out, {"h": hfin, "conv": conv_tail}
    return out


def mamba2_decode(cfg, prm, x, cache, *, pos):
    """Single-token recurrent step. x [B,1,d]; cache {h:[B,H,P,N], conv:[B,k-1,C]}."""
    b = x.shape[0]
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    hd = cfg.ssm_head_dim
    h = di // hd
    k = cfg.ssm_conv
    cd = cfg.compute_dtype

    x0 = x[:, 0, :]
    z = x0 @ prm["wz"].astype(cd)
    raw = jnp.concatenate(
        [x0 @ prm["wx"].astype(cd), x0 @ prm["wB"].astype(cd), x0 @ prm["wC"].astype(cd)],
        axis=-1,
    )                                                             # [B, di+2N]
    win = jnp.concatenate([cache["conv"], raw[:, None, :]], axis=1)  # [B,k,C]
    wfull = jnp.concatenate(
        [prm["conv_x"].astype(cd), prm["conv_B"].astype(cd), prm["conv_C"].astype(cd)],
        axis=-1,
    )                                                             # [k, di+2N]
    conv_out = jnp.einsum("bkc,kc->bc", win, wfull)
    xi = silu(conv_out[:, :di])
    bi = silu(conv_out[:, di : di + n]).astype(jnp.float32)
    ci = silu(conv_out[:, di + n :]).astype(jnp.float32)

    dt = jax.nn.softplus(
        (x0 @ prm["wdt"].astype(cd)).astype(jnp.float32) + prm["dt_bias"].astype(jnp.float32)
    )                                                             # [B,H]
    a = -jnp.exp(prm["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a[None, :])                              # [B,H]

    xh = xi.reshape(b, h, hd).astype(jnp.float32)
    hnew = cache["h"] * decay[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt, bi, xh)
    y = jnp.einsum("bn,bhpn->bhp", ci, hnew)
    y = y + xh * prm["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, di).astype(cd)
    y = rms_norm(y * silu(z), prm["norm"])
    out = (y @ prm["wo"].astype(cd))[:, None, :]
    return out, {"h": hnew, "conv": win[:, 1:, :]}
