from repro.models.common import P, split_tree
from repro.models.transformer import DecoderLM, BlockType, Segment, Ctx
from repro.models.encdec import EncDecLM
from repro.models.zoo import build_model

__all__ = ["P", "split_tree", "DecoderLM", "EncDecLM", "BlockType", "Segment",
           "Ctx", "build_model"]
