"""Attention: GQA flash (chunked online-softmax), SWA/local-global, cross,
MLA (DeepSeek multi-head latent attention), plus decode paths with KV caches.

All shapes static; flash attention scans KV in chunks so prefill_32k never
materializes an S×S score matrix. Decode attends over the full (or rolling,
for SWA) cache with a single masked matmul.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import P, apply_rope, dense_init, rms_norm

NEG_INF = jnp.float32(-1e30)


# ------------------------------------------------------------------ flash ----
def flash_attention(
    q: jax.Array,   # [B, Sq, H, dh]
    k: jax.Array,   # [B, Skv, KV, dh]
    v: jax.Array,   # [B, Skv, KV, dhv]
    *,
    causal: bool,
    window: int = 0,          # >0: sliding-window (local) attention
    q_offset: int = 0,        # absolute position of q[0] (prefill resume)
    kv_chunk: int = 1024,
    causal_skip: bool = False,  # skip fully-masked KV chunks (beyond-paper opt)
    unroll: bool = False,
) -> jax.Array:
    import os
    b, sq, h, dh = q.shape
    skv, kv = k.shape[1], k.shape[2]
    g = h // kv
    dhv = v.shape[-1]
    kv_chunk = int(os.environ.get("REPRO_KV_CHUNK", kv_chunk))
    c = min(kv_chunk, skv)
    nc = -(-skv // c)
    pad = nc * c - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    scale = dh ** -0.5
    qq = (q * scale).reshape(b, sq, kv, g, dh)
    q_pos = q_offset + jnp.arange(sq)

    kc = k.reshape(b, nc, c, kv, dh).transpose(1, 0, 2, 3, 4)    # [nc,B,C,KV,dh]
    vc = v.reshape(b, nc, c, kv, dhv).transpose(1, 0, 2, 3, 4)

    def chunk_scores(kj, j):
        s = jnp.einsum("bqkgd,bckd->bqkgc", qq, kj,
                       preferred_element_type=jnp.float32)
        k_pos = j * c + jnp.arange(c)
        m = k_pos[None, :] < skv                                  # kv padding
        if causal:
            m = m & (k_pos[None, :] <= q_pos[:, None])
        if window > 0:
            m = m & (k_pos[None, :] > q_pos[:, None] - window)
        return jnp.where(m[None, :, None, None, :], s, NEG_INF)

    def body(carry, xs):
        m, l, acc = carry
        kj, vj, j = xs
        s = chunk_scores(kj, j)                                   # [B,Sq,KV,G,C]
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l, acc), None

    m0 = jnp.full((b, sq, kv, g), NEG_INF)
    l0 = jnp.zeros((b, sq, kv, g), jnp.float32)
    a0 = jnp.zeros((b, sq, kv, g, dhv), jnp.float32)
    from repro.models.common import maybe_scan

    (m, l, acc), _ = maybe_scan(body, (m0, l0, a0), (kc, vc, jnp.arange(nc)),
                                unroll)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, sq, h, dhv).astype(q.dtype)


def decode_attention(
    q: jax.Array,        # [B, 1, H, dh]
    k_cache: jax.Array,  # [B, S, KV, dh]
    v_cache: jax.Array,  # [B, S, KV, dhv]
    valid_len: jax.Array,  # [B] number of valid cache slots
) -> jax.Array:
    b, _, h, dh = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    qq = (q * dh**-0.5).reshape(b, kv, g, dh)
    sc = jnp.einsum("bkgd,bskd->bkgs", qq, k_cache,
                    preferred_element_type=jnp.float32)
    mask = jnp.arange(s)[None, :] < valid_len[:, None]            # [B, S]
    sc = jnp.where(mask[:, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, -1).astype(q.dtype)


# ----------------------------------------------------------- standard GQA ----
def init_attention(key, cfg, name="attn"):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, h * hd), d, cfg.param_dtype, ("embed", "heads")),
        "wk": dense_init(ks[1], (d, kv * hd), d, cfg.param_dtype, ("embed", "kv_heads")),
        "wv": dense_init(ks[2], (d, kv * hd), d, cfg.param_dtype, ("embed", "kv_heads")),
        "wo": dense_init(ks[3], (h * hd, d), h * hd, cfg.param_dtype, ("heads", "embed")),
    }


def attention_forward(
    cfg, p, x, *, positions, causal=True, window=0,
    kv_override=None,  # (k, v) for cross attention (already projected? no: raw enc output)
    causal_skip=False,
):
    """Full-sequence attention (train / prefill). Returns (out, (k, v))."""
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    cd = cfg.compute_dtype
    q = (x @ p["wq"].astype(cd)).reshape(b, s, h, hd)
    if kv_override is None:
        kk = (x @ p["wk"].astype(cd)).reshape(b, s, kv, hd)
        vv = (x @ p["wv"].astype(cd)).reshape(b, s, kv, hd)
        q = apply_rope(q, positions, cfg.rope_theta)
        kk = apply_rope(kk, positions, cfg.rope_theta)
    else:
        enc = kv_override
        se = enc.shape[1]
        kk = (enc @ p["wk"].astype(cd)).reshape(b, se, kv, hd)
        vv = (enc @ p["wv"].astype(cd)).reshape(b, se, kv, hd)
        causal = False
        window = 0
    out = flash_attention(q, kk, vv, causal=causal, window=window,
                          causal_skip=causal_skip, unroll=cfg.unroll_inner)
    return out.reshape(b, s, h * hd) @ p["wo"].astype(cd), (kk, vv)


def attention_decode(
    cfg, p, x, cache, *, pos,  # x [B,1,d]; cache dict k/v [B,S,KV,hd]; pos [B]
    window=0,
    cross_kv=None,  # precomputed (k, v) for cross attention (static cache)
):
    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    cd = cfg.compute_dtype
    q = (x @ p["wq"].astype(cd)).reshape(b, 1, h, hd)
    if cross_kv is not None:
        kk, vv = cross_kv
        valid = jnp.full((b,), kk.shape[1], jnp.int32)
        return decode_attention(q, kk, vv, valid).reshape(b, 1, h * hd) @ p["wo"].astype(cd), cache
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    knew = (x @ p["wk"].astype(cd)).reshape(b, 1, kv, hd)
    vnew = (x @ p["wv"].astype(cd)).reshape(b, 1, kv, hd)
    knew = apply_rope(knew, pos[:, None], cfg.rope_theta)
    s_max = cache["k"].shape[1]
    slot = (pos % s_max) if window > 0 else pos                   # rolling for SWA
    kc = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(c, n, (i, 0, 0)))(
        cache["k"], knew, slot
    )
    vc = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(c, n, (i, 0, 0)))(
        cache["v"], vnew, slot
    )
    valid = jnp.minimum(pos + 1, s_max)
    out = decode_attention(q, kc, vc, valid)
    return out.reshape(b, 1, h * hd) @ p["wo"].astype(cd), {"k": kc, "v": vc}


# -------------------------------------------------------------------- MLA ----
def init_mla(key, cfg, name="mla"):
    d = cfg.d_model
    h = cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    ks = jax.random.split(key, 6)
    prm = {
        "wq_a": dense_init(ks[0], (d, cfg.q_lora_rank), d, cfg.param_dtype, ("embed", None)),
        "q_norm": P(jnp.zeros((cfg.q_lora_rank,), cfg.param_dtype), (None,)),
        "wq_b": dense_init(ks[1], (cfg.q_lora_rank, h * qk), cfg.q_lora_rank,
                           cfg.param_dtype, (None, "heads")),
        "wkv_a": dense_init(ks[2], (d, cfg.kv_lora_rank + cfg.qk_rope_dim), d,
                            cfg.param_dtype, ("embed", None)),
        "kv_norm": P(jnp.zeros((cfg.kv_lora_rank,), cfg.param_dtype), (None,)),
        "wkv_b": dense_init(
            ks[3], (cfg.kv_lora_rank, h * (cfg.qk_nope_dim + cfg.v_head_dim)),
            cfg.kv_lora_rank, cfg.param_dtype, (None, "heads")),
        "wo": dense_init(ks[4], (h * cfg.v_head_dim, d), h * cfg.v_head_dim,
                         cfg.param_dtype, ("heads", "embed")),
    }
    return prm


def mla_forward(cfg, p, x, *, positions, causal_skip=False):
    """Train/prefill MLA: materialize per-head K/V from the latent."""
    b, s, d = x.shape
    h = cfg.n_heads
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    cd = cfg.compute_dtype

    q = rms_norm(x @ p["wq_a"].astype(cd), p["q_norm"]) @ p["wq_b"].astype(cd)
    q = q.reshape(b, s, h, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = x @ p["wkv_a"].astype(cd)                              # [B,S,lora+rd]
    c_kv = rms_norm(kv_a[..., : cfg.kv_lora_rank], p["kv_norm"])
    k_rope = apply_rope(kv_a[..., cfg.kv_lora_rank :][:, :, None, :], positions,
                        cfg.rope_theta)                           # [B,S,1,rd]
    kv = (c_kv @ p["wkv_b"].astype(cd)).reshape(b, s, h, nd + vd)
    k_nope, v = kv[..., :nd], kv[..., nd:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, rd))], axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = flash_attention(qf, k, v, causal=True, causal_skip=causal_skip,
                          unroll=cfg.unroll_inner)
    return out.reshape(b, s, h * vd) @ p["wo"].astype(cd), (c_kv, k_rope[:, :, 0, :])


def mla_decode(cfg, p, x, cache, *, pos):
    """Absorbed MLA decode: score against the compressed latent cache.

    cache: {"c_kv": [B,S,lora], "k_rope": [B,S,rd]} — 576 B/token for
    deepseek-v3 instead of 2*H*dh, the MLA memory win.
    """
    b = x.shape[0]
    h = cfg.n_heads
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    lr = cfg.kv_lora_rank
    cd = cfg.compute_dtype

    q = rms_norm(x @ p["wq_a"].astype(cd), p["q_norm"]) @ p["wq_b"].astype(cd)
    q = q.reshape(b, h, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope[:, None], pos[:, None], cfg.rope_theta)[:, 0]

    kv_a = x[:, 0, :] @ p["wkv_a"].astype(cd)
    c_new = rms_norm(kv_a[..., :lr], p["kv_norm"])
    kr_new = apply_rope(kv_a[:, None, None, lr:], pos[:, None], cfg.rope_theta)[:, 0, 0]

    ckv = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(c, n[None], (i, 0)))(
        cache["c_kv"], c_new, pos
    )
    krc = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(c, n[None], (i, 0)))(
        cache["k_rope"], kr_new, pos
    )

    # absorb W_uk into q: q_lat [B,H,lora]
    wkv_b = p["wkv_b"].astype(cd).reshape(lr, h, nd + vd)
    w_uk = wkv_b[..., :nd]                                        # [lora, H, nd]
    w_uv = wkv_b[..., nd:]                                        # [lora, H, vd]
    q_lat = jnp.einsum("bhn,lhn->bhl", q_nope, w_uk)
    scale = (nd + rd) ** -0.5
    s_lat = jnp.einsum("bhl,bsl->bhs", q_lat, ckv)
    s_rope = jnp.einsum("bhr,bsr->bhs", q_rope, krc)
    sc = (s_lat + s_rope) * scale
    mask = jnp.arange(ckv.shape[1])[None, :] <= pos[:, None]
    sc = jnp.where(mask[:, None, :], sc.astype(jnp.float32), NEG_INF)
    pr = jax.nn.softmax(sc, axis=-1).astype(cd)
    o_lat = jnp.einsum("bhs,bsl->bhl", pr, ckv)
    out = jnp.einsum("bhl,lhv->bhv", o_lat, w_uv).reshape(b, 1, h * vd)
    return out @ p["wo"].astype(cd), {"c_kv": ckv, "k_rope": krc}
