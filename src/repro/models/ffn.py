"""Feed-forward layers: gated dense MLP and sort-based dropping MoE.

MoE dispatch is the TPU-standard sorted-scatter ("dropping") scheme:
token→expert assignments are sorted by expert id, ranked within expert,
and scattered into a static [E, C, d] buffer sharded over the model axis
(expert parallelism). Capacity overflow drops (classic GShard semantics);
a load-balance auxiliary loss keeps the router honest.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import P, dense_init, gelu, silu


def init_mlp(key, cfg, d_in=None, d_ff=None):
    d = d_in or cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    prm = {
        "w_in": dense_init(ks[0], (d, f), d, cfg.param_dtype, ("embed", "mlp")),
        "w_out": dense_init(ks[1], (f, d), f, cfg.param_dtype, ("mlp", "embed")),
    }
    if cfg.act == "silu":  # gated (llama-style)
        prm["w_gate"] = dense_init(ks[2], (d, f), d, cfg.param_dtype, ("embed", "mlp"))
    return prm


def mlp_forward(cfg, p, x):
    cd = cfg.compute_dtype
    h = x @ p["w_in"].astype(cd)
    if "w_gate" in p:
        h = silu(x @ p["w_gate"].astype(cd)) * h
    else:
        h = gelu(h)
    return h @ p["w_out"].astype(cd)


# --------------------------------------------------------------------- MoE ----
def init_moe(key, cfg):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    prm = {
        "router": dense_init(ks[0], (d, e), d, cfg.param_dtype, ("embed", None)),
        "w_in": dense_init(ks[1], (e, d, f), d, cfg.param_dtype, ("expert", "embed", "mlp")),
        "w_gate": dense_init(ks[2], (e, d, f), d, cfg.param_dtype, ("expert", "embed", "mlp")),
        "w_out": dense_init(ks[3], (e, f, d), f, cfg.param_dtype, ("expert", "mlp", "embed")),
    }
    if cfg.n_shared_experts:
        prm["shared"] = init_mlp(ks[4], cfg, d_ff=cfg.moe_d_ff * cfg.n_shared_experts)
    return prm


def moe_forward_global(cfg, p, x, return_aux=False):
    """Beyond-baseline MoE dispatch: global sort + capacity-sharded buffer.

    buf [E, C, d] is sharded (expert→model, capacity→data): the expert
    einsums then contract an UNSHARDED d — no activation-sized partial-sum
    all-reduces (the baseline per-row variant contracts the FSDP-sharded
    embed dim and pays ~2.5 TB/device/layer on deepseek-v3). The dispatch
    scatter from x [B(data),S,d] into buf is the canonical EP all-to-all.
    Enabled with REPRO_MOE_GLOBAL=1 (perf iteration; see EXPERIMENTS §Perf).
    """
    from repro.distributed.sharding import constrain

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cd = cfg.compute_dtype
    t = b * s
    xf = x.reshape(t, d)

    logits = (xf @ p["router"].astype(cd)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_v, gate_e = jax.lax.top_k(probs, k)
    gate_v = gate_v / jnp.maximum(gate_v.sum(-1, keepdims=True), 1e-9)

    cap = int(cfg.capacity_factor * k * t / e) + 1
    cap = -(-cap // 16) * 16

    flat_e = gate_e.reshape(-1)
    flat_g = gate_v.reshape(-1)
    tok_ix = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    order = jnp.argsort(flat_e, stable=True)
    se, sg, st = flat_e[order], flat_g[order], tok_ix[order]
    starts = jnp.searchsorted(se, jnp.arange(e, dtype=se.dtype))
    rank = jnp.arange(t * k, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    keep = rank < cap
    e_ix = jnp.where(keep, se, e).astype(jnp.int32)
    r_ix = jnp.where(keep, rank, cap)

    buf = jnp.zeros((e, cap, d), cd)
    buf = buf.at[e_ix, r_ix].set(xf[st].astype(cd), mode="drop")
    buf = constrain(buf, ("expert", "capacity", None))             # EP × DP

    # ZeRO-3 weight gather: unshard the contraction dim so the expert
    # einsums are fully local (weight-sized AG ≪ activation-sized AR)
    w_in = constrain(p["w_in"].astype(cd), ("expert", None, None))
    w_gate = constrain(p["w_gate"].astype(cd), ("expert", None, None))
    w_out = constrain(p["w_out"].astype(cd), ("expert", None, None))
    h = jnp.einsum("ecd,edf->ecf", buf, w_in)
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    yb = jnp.einsum("ecf,efd->ecd", silu(g) * h, w_out)
    yb = constrain(yb, ("expert", "capacity", None))

    gathered = yb[e_ix, r_ix] * jnp.where(keep, sg, 0.0)[:, None].astype(cd)
    out = jnp.zeros((t, d), cd).at[st].add(gathered, mode="drop")
    out = constrain(out.reshape(b, s, d), ("batch", None, None))

    if cfg.n_shared_experts:
        out = out + mlp_forward(cfg, p["shared"], xf).reshape(b, s, d)

    if return_aux:
        me = probs.mean(axis=0)
        ce = jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0) / (t * k)
        return out, e * jnp.sum(me * ce)
    return out


def moe_forward(cfg, p, x, return_aux=False):
    import os

    if os.environ.get("REPRO_MOE_GLOBAL"):
        return moe_forward_global(cfg, p, x, return_aux)
    return _moe_forward_rowwise(cfg, p, x, return_aux)


def _moe_forward_rowwise(cfg, p, x, return_aux=False):
    """x [B, S, d] -> [B, S, d] (+ load-balance aux loss).

    Dispatch is PER BATCH ROW: each row sorts its own S·k assignments and
    scatters into a [B, E, C_row, d] buffer with C_row = cf·k·S/E. The
    leading B dim keeps the data sharding (each data shard dispatches its
    local rows only — no global token sort, no cross-shard gather), and the
    E dim carries expert parallelism over the model axis. Row-level
    capacity slightly raises drop variance vs global capacity; cf covers it
    (recorded in DESIGN.md).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cd = cfg.compute_dtype

    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(cd)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_v, gate_e = jax.lax.top_k(probs, k)                      # [B, S, k]
    gate_v = gate_v / jnp.maximum(gate_v.sum(-1, keepdims=True), 1e-9)

    cap = int(cfg.capacity_factor * k * s / e) + 1
    cap = -(-cap // 8) * 8

    flat_e = gate_e.reshape(b, s * k)
    flat_g = gate_v.reshape(b, s * k)
    tok_ix = jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)[None, :]  # [1, S*k]
    tok_ix = jnp.broadcast_to(tok_ix, (b, s * k))
    order = jnp.argsort(flat_e, axis=1, stable=True)
    se = jnp.take_along_axis(flat_e, order, axis=1)
    sg = jnp.take_along_axis(flat_g, order, axis=1)
    st = jnp.take_along_axis(tok_ix, order, axis=1)
    starts = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(e, dtype=row.dtype)))(se)
    rank = jnp.arange(s * k, dtype=jnp.int32)[None, :] - jnp.take_along_axis(
        starts, se.astype(jnp.int32), axis=1).astype(jnp.int32)
    keep = rank < cap
    e_ix = jnp.where(keep, se, e).astype(jnp.int32)               # OOB drops
    r_ix = jnp.where(keep, rank, cap)
    b_ix = jnp.arange(b, dtype=jnp.int32)[:, None]

    from repro.distributed.sharding import _ambient_mesh, constrain

    import os
    mesh = _ambient_mesh()
    use_shmap = bool(os.environ.get("REPRO_MOE_SHMAP")) and mesh is not None \
        and "data" in mesh.shape

    if use_shmap:
        # Dispatch under shard_map: the token gather + capacity scatter are
        # *provably local* per data shard (GSPMD otherwise lowers the
        # cross-shard gather as full-result all-reduces; §Perf iter 4).
        from jax.sharding import PartitionSpec as PS
        try:
            from jax import shard_map as _shm
        except ImportError:
            from jax.experimental.shard_map import shard_map as _shm

        dp = PS(("pod", "data") if "pod" in mesh.shape else "data")
        row = PS(*dp, None)
        row3 = PS(*dp, None, None)

        def _dispatch(xl, stl, el, rl):
            bl = xl.shape[0]
            bi = jnp.arange(bl, dtype=jnp.int32)[:, None]
            xt = jnp.take_along_axis(xl, stl[..., None], axis=1).astype(cd)
            bufl = jnp.zeros((bl, e, cap, d), cd)
            return bufl.at[bi, el, rl].set(xt, mode="drop")

        buf = _shm(_dispatch, mesh=mesh, in_specs=(row3, row, row, row),
                   out_specs=PS(*dp, None, None, None), check_vma=False)(
                       x, st, e_ix, r_ix)
        buf = constrain(buf, ("batch", "expert", None, None))      # slice E: free
    else:
        xt = jnp.take_along_axis(x, st[..., None], axis=1).astype(cd)  # [B, S*k, d]
        xt = constrain(xt, ("batch", None, None))
        buf = jnp.zeros((b, e, cap, d), cd)
        buf = buf.at[b_ix, e_ix, r_ix].set(xt, mode="drop")
        buf = constrain(buf, ("batch", "expert", None, None))      # DP × EP

    import os
    w_in = p["w_in"].astype(cd)
    w_gate = p["w_gate"].astype(cd)
    w_out = p["w_out"].astype(cd)
    if os.environ.get("REPRO_MOE_ZERO3"):
        # ZeRO-3 weight gather: unshard the FSDP (embed) dim so the expert
        # einsums contract locally — weight-sized AG instead of
        # activation-sized partial-sum AR (see EXPERIMENTS §Perf iter 3)
        w_in = constrain(w_in, ("expert", None, None))
        w_gate = constrain(w_gate, ("expert", None, None))
        w_out = constrain(w_out, ("expert", None, None))
    h = jnp.einsum("becd,edf->becf", buf, w_in)
    g = jnp.einsum("becd,edf->becf", buf, w_gate)
    if os.environ.get("REPRO_MOE_CONSTRAIN_OUT"):
        h = constrain(h, ("batch", "expert", None, None))
        g = constrain(g, ("batch", "expert", None, None))
    yb = jnp.einsum("becf,efd->becd", silu(g) * h, w_out)
    if os.environ.get("REPRO_MOE_CONSTRAIN_OUT"):
        yb = constrain(yb, ("batch", "expert", None, None))

    if use_shmap:
        # combine under shard_map: gather yb over E locally (one explicit
        # activation-sized all-gather over model) then scatter-add locally
        yb = constrain(yb, ("batch", None, None, None))  # AG over model
        gates = jnp.where(keep, sg, 0.0).astype(cd)

        def _combine(ybl, el, rl, stl, gl):
            bl = ybl.shape[0]
            bi = jnp.arange(bl, dtype=jnp.int32)[:, None]
            bk = ybl[bi, el, rl] * gl[..., None]
            return jnp.zeros((bl, s, d), cd).at[bi, stl].add(bk, mode="drop")

        from jax.sharding import PartitionSpec as PS
        try:
            from jax import shard_map as _shm
        except ImportError:
            from jax.experimental.shard_map import shard_map as _shm
        dp = PS(("pod", "data") if "pod" in mesh.shape else "data")
        row = PS(*dp, None)
        out = _shm(_combine, mesh=mesh,
                   in_specs=(PS(*dp, None, None, None), row, row, row, row),
                   out_specs=PS(*dp, None, None), check_vma=False)(
                       yb, e_ix, r_ix, st, gates)
    else:
        back = yb[b_ix, e_ix, r_ix] * jnp.where(keep, sg, 0.0)[..., None].astype(cd)
        back = constrain(back, ("batch", None, None))
        out = jnp.zeros((b, s, d), cd).at[b_ix, st].add(back, mode="drop")
    out = constrain(out, ("batch", None, None))

    if cfg.n_shared_experts:
        out = out + mlp_forward(cfg, p["shared"], x.reshape(b * s, d)).reshape(b, s, d)

    if return_aux:
        # GShard load-balance loss: E * Σ_e f_e · p_e
        me = probs.mean(axis=(0, 1))                              # [E]
        ce = jnp.zeros((e,), jnp.float32).at[flat_e.reshape(-1)].add(1.0) / (b * s * k)
        aux = e * jnp.sum(me * ce)
        return out, aux
    return out
