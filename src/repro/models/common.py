"""Shared model substrate: params-with-logical-axes, norms, RoPE, init.

Param convention: module init functions return nested dicts whose leaves are
`P(value, axes)` — the array plus a tuple of *logical* axis names
("embed", "vocab", "heads", "kv_heads", "mlp", "expert", "layers", ...).
`split_tree` separates values from axes; the distributed layer maps logical
axes to mesh axes with divisibility-aware rules (repro.distributed.sharding).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class P:
    """A param leaf: array value + static logical-axis names.

    Registered as a pytree node whose *only child* is the value and whose
    axes ride along as static aux data — so `jax.eval_shape` can trace init
    functions (the dry-run's no-allocation path) and transformations map
    over values while preserving axes.
    """

    __slots__ = ("value", "axes")

    def __init__(self, value, axes):
        self.value = value
        self.axes = tuple(axes)

    def __repr__(self):
        return f"P({self.value!r}, axes={self.axes})"


jax.tree_util.register_pytree_node(
    P,
    lambda p: ((p.value,), p.axes),
    lambda axes, children: P(children[0], axes),
)


def split_tree(tree):
    """Nested dict of P -> (values tree, axes tree)."""
    vals = jax.tree.map(lambda p: p.value, tree, is_leaf=lambda x: isinstance(x, P))
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=lambda x: isinstance(x, P))
    return vals, axes


def dense_init(key, shape, in_axis_size, dtype, axes) -> P:
    scale = 1.0 / np.sqrt(max(in_axis_size, 1))
    v = jax.random.normal(key, shape, dtype=jnp.float32) * scale
    return P(v.astype(dtype), axes)


def zeros_init(shape, dtype, axes) -> P:
    return P(jnp.zeros(shape, dtype=dtype), axes)


def ones_init(shape, dtype, axes) -> P:
    return P(jnp.ones(shape, dtype=dtype), axes)


# ---------------------------------------------------------------- norms ----
def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def nonparam_layer_norm(x, eps=1e-5):
    """OLMo-style non-parametric LayerNorm (no scale/bias)."""
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def init_norm(key, cfg, d, name="norm"):
    if cfg.norm_type == "layernorm":
        return {
            "scale": ones_init((d,), cfg.param_dtype, ("embed",)),
            "bias": zeros_init((d,), cfg.param_dtype, ("embed",)),
        }
    if cfg.norm_type == "nonparam_ln":
        return {}
    return {"scale": zeros_init((d,), cfg.param_dtype, ("embed",))}


def apply_norm(cfg, p, x):
    if cfg.norm_type == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    if cfg.norm_type == "nonparam_ln":
        return nonparam_layer_norm(x)
    return rms_norm(x, p["scale"])


# ----------------------------------------------------------------- rope ----
def rope_freqs(dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x, positions, theta: float):
    """x [..., S, H, dh] (dh even), positions [..., S] int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # [dh/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, dh/2]
    cos = jnp.cos(ang)[..., None, :]                    # [..., S, 1, dh/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x)


def gelu(x):
    return jax.nn.gelu(x)


def maybe_scan(body, init, xs, unroll: bool):
    """lax.scan, or a python-unrolled equivalent.

    XLA cost analysis counts a while-loop body ONCE regardless of trip
    count; the dry-run roofline therefore lowers inner loops (flash KV
    chunks, SSD chunks, CE chunks) unrolled so FLOPs/bytes are exact.
    Training/serving keep the scan (compile-time friendly).
    """
    if not unroll:
        return jax.lax.scan(body, init, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(n):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *e: jnp.stack(e), *ys)
    else:
        ys = None
    return carry, ys
