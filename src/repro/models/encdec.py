"""Encoder-decoder LM (whisper-small backbone).

The audio frontend (mel + conv downsampling) is a STUB per the assignment:
`batch["enc"]` carries precomputed frame embeddings [B, encoder_seq, d].
The encoder is a scanned stack of bidirectional attention blocks; the
decoder is a DecoderLM whose every block carries cross-attention to the
encoder output. Decode caches both self-attn KV and the static cross KV.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import apply_norm, dense_init, init_norm
from repro.models.transformer import (
    BlockApplier,
    BlockType,
    Ctx,
    DecoderLM,
    Segment,
    _init_block,
    _stack_inits,
)


class EncDecLM(DecoderLM):
    def __init__(self, cfg: ArchConfig):
        super().__init__(cfg)
        # decoder plan: every layer = causal self-attn + cross-attn + mlp
        per = (BlockType("gqa", cross=True),)
        self.segments = [Segment(per, cfg.n_layers)]
        self.prefix = []
        self.enc_bt = BlockType("gqa", bidir=True)

    def init_params(self, key):
        k1, k2, k3 = jax.random.split(key, 3)
        prm = super().init_params(k1)
        cfg = self.cfg
        prm["enc_blocks"] = _stack_inits(
            [_init_block(k, cfg, self.enc_bt)
             for k in jax.random.split(k2, cfg.n_encoder_layers)])
        prm["enc_norm"] = init_norm(k3, cfg, cfg.d_model)
        return prm

    def encode(self, prm, frames):
        """frames [B, Se, d] (stub frontend output) -> encoder states."""
        cfg = self.cfg
        b, se, _ = frames.shape
        positions = jnp.broadcast_to(jnp.arange(se)[None], (b, se))
        ctx = Ctx(mode="train", positions=positions)
        applier = BlockApplier(cfg)

        def body(x, bp):
            x, _, _ = applier(self.enc_bt, bp, x, ctx)
            return x, None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, frames.astype(cfg.compute_dtype),
                            prm["enc_blocks"])
        return apply_norm(cfg, prm["enc_norm"], x)

    def loss(self, prm, batch):
        enc = self.encode(prm, batch["enc"])
        return super().loss(prm, {**batch, "enc": enc})

    def prefill(self, prm, batch):
        enc = self.encode(prm, batch["enc"])
        return super().prefill(prm, {**batch, "enc": enc})
