"""Persistent multi-step Pallas traversal kernel: VMEM-resident search state.

The single-step path (kernels/fused_step.py) pays a fixed per-step tax: one
kernel dispatch per lockstep step, with queue / result / visited buffers and
the gathered codes bouncing through HBM between steps. This kernel runs up
to `steps_per_launch` steps in ONE launch:

  * the candidate queue, result set, visited bitset, and every per-lane
    counter ride the kernel's step loop as VMEM-resident carries — nothing
    round-trips HBM until the launch boundary;
  * neighbor rows are gathered straight from HBM with per-row async copies
    into VMEM landing buffers, split into two streams (vector/code rows and
    packed attribute rows) so the chunked visited-bitset pass — pure VPU
    work that needs only the neighbor ids — overlaps both streams' DMAs,
    the attribute wait lands just before the filter-program evaluation and
    the row wait just before the MXU distance block;
  * per-lane termination (budget exhausted, queue drained, or — with
    `greedy_stop` — the paper's early-exit condition queue-head ≥
    result-tail) is evaluated *in-kernel*: a lane that trips it contributes
    no DMAs and all of its merge writebacks are lane-masked no-ops, and the
    launch itself exits early (`lax.while_loop`) once every lane is done.

Bit-compatibility contract: each in-kernel step reproduces
`core.step.make_step` + the pallas backend exactly — same pop, same
visited test-before-set semantics (duplicate ids within a row both count,
as on the host), same `_merge_core` program+merge tail shared with the
single-step kernels, same lane-masked counter updates — so the kernel can
stop after ANY step boundary and emit a full `SearchState` that
probe→estimate→resume, the planner's shared probe carry, and serve's lane
surgery consume unchanged.

Operand layout (built once per search call, NOT per launch):

  rows [N, Dp]   f32 vectors | int8 codes | int32 PQ codes, row-padded to
                 a 128-lane multiple so each row is one clean DMA.
  aux  [N, Ap]   uint32-packed per-node words:
                 [0:W) label words | [W:W+V) value channels (f32 bitcast) |
                 W+V   ‖x̂‖² ADC norm | W+V+1 reconstruction error.
                 One aux row DMA replaces three separate gathers.

VMEM per block (bb lanes), on top of the single-step budget:
visited bitset bb·ceil(N/32)·4 B (~12.5 KB/lane at N=100k), landing
buffers bb·R·(Dp + Ap)·4 B, plus the loop-carried queue/result buffers the
single-step kernel already held — comfortably inside the ~2.3 MB/block
budget of docs/ARCHITECTURE.md for bb=8.

The kernel covers `mode="post"` (1-hop frontier, the serving hot path);
pre/widen frontiers (1-hop ∪ strided 2-hop with intra-step dedup) keep the
host multi-step path in core/search.py, which is also the non-TPU
(XLA:CPU) execution of the `pallas_persistent` backend. A further step of
DMA pipelining — speculatively prefetching the *next* pop's rows during
the current merge, with an eviction guard when the merge changes the queue
head — is documented in docs/ARCHITECTURE.md as TPU-measurement future
work; the pop→gather dependency makes it a semantics-preserving gamble
rather than a straight rotation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.filters.compile import CLAUSE_FEATURE_SLOTS
from repro.kernels.fused_step import _merge_core
from repro.kernels.topk import pack_payload, unpack_payload

INF = float("inf")

# Column order of the packed per-lane counter block ([bb, 8] int32) that
# carries every scalar SearchState leaf through the kernel.
_CTR_FIELDS = ("cnt", "n_inspected", "n_valid_visited", "n_pop_valid",
               "hops", "conv_cnt", "res_full_cnt", "active")


def _pad_cols(a, width, fill=0):
    """Zero-pad the trailing axis to `width` (DMA row alignment)."""
    pad = width - a.shape[-1]
    if pad <= 0:
        return a
    widths = ((0, 0),) * (a.ndim - 1) + ((0, pad),)
    return jnp.pad(a, widths, constant_values=fill)


@functools.partial(jax.jit, static_argnames=("precision",))
def build_persistent_operands(precision, base_vectors, label_attrs,
                              value_attrs, quant):
    """Pack the per-node HBM operands (rows, aux) for the persistent kernel.

    Called once per search call — per-launch packing would cost O(N·A)
    every launch and erase the dispatch-amortization win. Returns
    (rows [N, Dp], aux [N, Ap] u32); see the module docstring for layout.
    """
    from repro.quant.codecs import pad_rows_for_dma

    n = label_attrs.shape[0]
    if precision == "float32":
        rows = pad_rows_for_dma(jnp.asarray(base_vectors, jnp.float32))
        xn = jnp.zeros((n,), jnp.float32)
        err = jnp.zeros((n,), jnp.float32)
    elif precision == "int8":
        rows = pad_rows_for_dma(quant.codes)                   # [N, d] i8
        xn, err = quant.norms, quant.err
    elif precision == "pq":
        # uint8 store widened to i32 once: the in-kernel one-hot LUT
        # contraction consumes i32 slots. (A production TPU build would DMA
        # the uint8 rows and widen in-register; 4× operand memory is the
        # price of keeping this kernel's row DMA layout uniform.)
        rows = pad_rows_for_dma(quant.codes.astype(jnp.int32))
    else:
        raise ValueError(f"unknown precision {precision!r}")
    if precision == "pq":
        xn, err = quant.norms, quant.err
    bc = functools.partial(jax.lax.bitcast_convert_type,
                           new_dtype=jnp.uint32)
    aux = jnp.concatenate([
        label_attrs.astype(jnp.uint32),
        bc(value_attrs.astype(jnp.float32)),
        bc(xn)[:, None],
        bc(err)[:, None],
    ], axis=1)
    return rows, pad_rows_for_dma(aux)


def _persistent_kernel(*refs, bb, m, k, r, w, v, wq, wr, cw, n_chunks,
                       n_head, steps, greedy, has_gt, precision, n_clause):
    """One launch: up to `steps` lockstep traversal steps, state in VMEM.

    Ref order: rem (SMEM) | nbrs, rows, aux (HBM) | head inputs (n_head) |
    8 program leaves | budgets | [gt] | cd, cp, rd, ri, vis, ctr, ncl, qerr
    | 8 outputs | nbid, vbuf, abuf + 3 DMA semaphore arrays (scratch).
    """
    it = iter(refs)
    rem_ref = next(it)
    nbrs_hbm, rows_hbm, aux_hbm = next(it), next(it), next(it)
    heads = [next(it) for _ in range(n_head)]
    (kinds_ref, masks_ref, lo_ref, hi_ref, vattr_ref, neg_ref, term_ref,
     tact_ref) = (next(it) for _ in range(8))
    bud_ref = next(it)
    gt_ref = next(it) if has_gt else None
    (cd_ref, cp_ref, rd_ref, ri_ref, vis_ref, ctr_ref, ncl_ref,
     qerr_ref) = (next(it) for _ in range(8))
    (ocd_ref, ocp_ref, ord_ref, ori_ref, ovis_ref, octr_ref, oncl_ref,
     oqerr_ref) = (next(it) for _ in range(8))
    nbid, vbuf, abuf, nsem, vsem, asem = (next(it) for _ in range(6))

    # ---- loop-invariant VMEM loads (once per launch, not per step) ----
    kinds, masks = kinds_ref[...], masks_ref[...]
    lo, hi = lo_ref[...], hi_ref[...]
    vattr, neg = vattr_ref[...], neg_ref[...]
    term_pack, tact = term_ref[...], tact_ref[...]
    budgets = bud_ref[...][:, 0]
    gt = gt_ref[...] if has_gt else None
    rem = rem_ref[0]
    if precision == "float32":
        q = heads[0][...].astype(jnp.float32)                  # [bb, Dp]
        qn_head = jnp.sum(q * q, axis=-1)[:, None]
    elif precision == "int8":
        qq = heads[0][...]                                     # [bb, Dp] i8
        sq, qn_head = heads[1][...], heads[2][...]             # [bb, 1] f32
    else:                                                      # pq
        lut = heads[0][...]                                    # [bb, SL, Kc]
        qn_head = heads[1][...]                                # [bb, 1] f32
        sl = lut.shape[1]

    ctr0 = ctr_ref[...]
    f32 = functools.partial(jax.lax.bitcast_convert_type,
                            new_dtype=jnp.float32)

    def body(carry):
        (s, cd, cp, rdv, riv, vis, cnt, nin, nvv, nclv, npv, qerr, hops,
         prev_act, conv, rfull) = carry

        # ---- pop best unexpanded candidate per lane ----
        idx, exp, vbit = unpack_payload(cp)
        unexp = (~exp) & (idx >= 0)
        pop_key = jnp.where(unexp, cd, INF)
        p = jnp.argmin(pop_key, axis=1)                        # [bb]
        sel = (jax.lax.broadcasted_iota(jnp.int32, (bb, m), 1)
               == p[:, None])
        best_d = jnp.min(pop_key, axis=1)
        has_cand = jnp.isfinite(best_d)
        u = jnp.sum(jnp.where(sel, idx, 0), axis=1)
        u_valid = jnp.any(sel & vbit, axis=1)

        # ---- in-kernel per-lane termination (the adaptive early exit) ----
        act = prev_act & has_cand & (cnt < budgets)
        if greedy:
            worst_res = rdv[:, -1]
            act = act & ~(jnp.isfinite(worst_res) & (best_d > worst_res))

        # mark the popped slot expanded (lane-masked, as on the host)
        cp_pop = jnp.where(sel & act[:, None], cp | (1 << 29), cp)

        # ---- gather frontier neighbor ids (1-hop row DMA per lane) ----
        u_safe = jnp.maximum(u, 0)
        for l in range(bb):
            @pl.when(act[l])
            def _(l=l):
                pltpu.make_async_copy(
                    nbrs_hbm.at[u_safe[l]], nbid.at[l], nsem.at[l]).start()
        for l in range(bb):
            @pl.when(act[l])
            def _(l=l):
                pltpu.make_async_copy(
                    nbrs_hbm.at[u_safe[l]], nbid.at[l], nsem.at[l]).wait()
        nb = jnp.where(act[:, None], nbid[...], -1)
        nb_safe = jnp.maximum(nb, 0)

        # ---- launch both gather streams (vector/code rows + aux rows) ----
        # Finished lanes issue nothing: their DMA slots stay idle and the
        # stale landing buffers are masked out of every consumer below.
        for l in range(bb):
            @pl.when(act[l])
            def _(l=l):
                for ri_ in range(r):
                    j = nb_safe[l, ri_]
                    pltpu.make_async_copy(
                        rows_hbm.at[j], vbuf.at[l, ri_],
                        vsem.at[l, ri_]).start()
                    pltpu.make_async_copy(
                        aux_hbm.at[j], abuf.at[l, ri_],
                        asem.at[l, ri_]).start()

        # ---- visited test-before-set, overlapping the in-flight DMAs ----
        # Chunked over the word axis: per chunk, membership is an equality
        # one-hot against the chunk's word ids — no dynamic gather/scatter,
        # only elementwise + reductions (Mosaic-friendly). Testing against
        # the PRE-step words per chunk preserves the host's duplicate-id
        # semantics exactly (both copies of a repeated id count as new).
        word_idx = nb_safe >> 5
        bit = jnp.uint32(1) << (nb_safe & 31).astype(jnp.uint32)
        nb_ok = (nb >= 0) & act[:, None]
        seen = jnp.zeros((bb, r), bool)
        new_chunks = []
        for c in range(n_chunks):
            ids = (jax.lax.broadcasted_iota(jnp.int32, (bb, r, cw), 2)
                   + c * cw)
            match = word_idx[:, :, None] == ids
            vw = vis[:, c * cw:(c + 1) * cw]                   # [bb, cw]
            hit = match & ((vw[:, None, :] & bit[:, :, None]) != 0)
            seen_c = jnp.any(hit, axis=2)
            seen = seen | seen_c
            new_c = nb_ok & (~seen_c) & jnp.any(match, axis=2)
            bits = jnp.where(match & new_c[:, :, None], bit[:, :, None],
                             jnp.uint32(0))
            # integer ADD, not OR: the host marks via .add(mode="drop"), so
            # a neighbor id repeated within one row carries into the next
            # bit — bit-compatibility means reproducing that carry exactly.
            add = bits[:, 0, :]
            for ri_ in range(1, r):
                add = add + bits[:, ri_, :]
            new_chunks.append(vw + add)
        vis_new = (jnp.concatenate(new_chunks, axis=1)
                   if n_chunks > 1 else new_chunks[0])
        is_new = nb_ok & (~seen)

        # ---- attribute stream lands: unpack the packed aux words ----
        for l in range(bb):
            @pl.when(act[l])
            def _(l=l):
                for ri_ in range(r):
                    j = nb_safe[l, ri_]
                    pltpu.make_async_copy(
                        aux_hbm.at[j], abuf.at[l, ri_],
                        asem.at[l, ri_]).wait()
        auxv = abuf[...]
        labels_g = auxv[:, :, :w]
        values_g = f32(auxv[:, :, w:w + v])
        xn_aux = f32(auxv[:, :, w + v])                        # [bb, r]
        err_g = f32(auxv[:, :, w + v + 1])

        # ---- row stream lands: distance block (same math per codec as
        # the single-step kernels in fused_step.py) ----
        for l in range(bb):
            @pl.when(act[l])
            def _(l=l):
                for ri_ in range(r):
                    j = nb_safe[l, ri_]
                    pltpu.make_async_copy(
                        rows_hbm.at[j], vbuf.at[l, ri_],
                        vsem.at[l, ri_]).wait()
        if precision == "float32":
            x = vbuf[...].astype(jnp.float32)                  # [bb, r, Dp]
            xn = jnp.sum(x * x, axis=-1)
            qx = jax.lax.dot_general(
                q[:, None, :], x,
                dimension_numbers=(((2,), (2,)), ((0,), (0,))),
                preferred_element_type=jnp.float32)[:, 0, :]
            d = jnp.maximum(qn_head + xn - 2.0 * qx, 0.0)
        elif precision == "int8":
            codes = vbuf[...]                                  # [bb, r, Dp] i8
            dot = jax.lax.dot_general(
                qq[:, None, :], codes,
                dimension_numbers=(((2,), (2,)), ((0,), (0,))),
                preferred_element_type=jnp.int32)[:, 0, :]
            d = jnp.maximum(
                qn_head + xn_aux - 2.0 * sq * dot.astype(jnp.float32), 0.0)
        else:                                                  # pq
            codes = vbuf[...][:, :, :sl]                       # [bb, r, SL]
            kc = lut.shape[2]
            ip = jnp.zeros((bb, r), jnp.float32)
            for si in range(sl):
                onehot = (codes[:, :, si][:, :, None]
                          == jnp.arange(kc, dtype=jnp.int32)[None, None, :]
                          ).astype(jnp.float32)
                ip = ip + jax.lax.dot_general(
                    onehot, lut[:, si, :][:, :, None],
                    dimension_numbers=(((2,), (1,)), ((0,), (0,))),
                    preferred_element_type=jnp.float32)[:, :, 0]
            d = jnp.maximum(qn_head + xn_aux - 2.0 * ip, 0.0)

        # ---- shared program + merge tail (bit-shared with fused_step) ----
        ocd, ocp, ordd, ori, valid, occ = _merge_core(
            d, nb, is_new, kinds, masks, lo, hi, vattr, neg, term_pack,
            tact, labels_g, values_g, cd, cp_pop, rdv, riv,
            m=m, k=k, wq=wq, wr=wr, pre=False, n_clause=n_clause)

        # ---- counters, lane-masked exactly as core.step.make_step ----
        ndc_add = is_new.sum(axis=1).astype(jnp.int32)         # post mode
        valid_add = valid.sum(axis=1).astype(jnp.int32)
        err_add = jnp.where(is_new, err_g, 0.0).sum(axis=1)
        cnt_n = cnt + jnp.where(act, ndc_add, 0)
        nin_n = nin + jnp.where(act, ndc_add, 0)
        nvv_n = nvv + jnp.where(act, valid_add, 0)
        nclv_n = nclv + jnp.where(act[:, None], occ, 0)
        npv_n = npv + jnp.where(act & u_valid, 1, 0)
        qerr_n = qerr + jnp.where(act, err_add, 0.0)
        hops_n = hops + jnp.where(act, 1, 0)

        if has_gt:
            covered = jnp.all(ordd <= gt + 1e-6, axis=1)
            conv_n = jnp.where((conv < 0) & covered, cnt_n, conv)
        else:
            conv_n = conv
        now_full = jnp.isfinite(ordd[:, -1]) & act
        rfull_n = jnp.where((rfull < 0) & now_full, cnt_n, rfull)

        am = act[:, None]
        return (s + 1,
                jnp.where(am, ocd, cd), jnp.where(am, ocp, cp_pop),
                jnp.where(am, ordd, rdv), jnp.where(am, ori, riv),
                jnp.where(am, vis_new, vis),
                cnt_n, nin_n, nvv_n, nclv_n, npv_n, qerr_n, hops_n,
                act, conv_n, rfull_n)

    def cond(carry):
        s = carry[0]
        prev_act = carry[13]
        return (s < steps) & (s < rem) & jnp.any(prev_act)

    init = (jnp.int32(0), cd_ref[...], cp_ref[...], rd_ref[...], ri_ref[...],
            vis_ref[...], ctr0[:, 0], ctr0[:, 1], ctr0[:, 2], ncl_ref[...],
            ctr0[:, 3], qerr_ref[...][:, 0], ctr0[:, 4],
            ctr0[:, 7].astype(bool), ctr0[:, 5], ctr0[:, 6])
    (_, cd, cp, rdv, riv, vis, cnt, nin, nvv, nclv, npv, qerr, hops, act,
     conv, rfull) = jax.lax.while_loop(cond, body, init)

    ocd_ref[...] = cd
    ocp_ref[...] = cp
    ord_ref[...] = rdv
    ori_ref[...] = riv
    ovis_ref[...] = vis
    octr_ref[...] = jnp.stack(
        [cnt, nin, nvv, npv, hops, conv, rfull, act.astype(jnp.int32)],
        axis=1)
    oncl_ref[...] = nclv
    oqerr_ref[...] = qerr[:, None]


@functools.partial(jax.jit, static_argnames=("cfg", "steps", "n_values",
                                             "has_gt", "interpret",
                                             "block_b"))
def persistent_multi_step(cfg, queries, prog, rows, aux, neighbors, budgets,
                          state, rem, gt_dist, qprep, *, steps: int,
                          n_values: int, has_gt: bool,
                          interpret: bool = False, block_b: int = 8):
    """Run up to `steps` lockstep traversal steps in one kernel launch.

    rows/aux are the per-node HBM operands from `build_persistent_operands`
    (packed once per search call); `rem` is a traced scalar bound on how
    many steps this launch may still take (cfg.max_steps bookkeeping), and
    the kernel additionally stops the moment every lane terminates.
    Returns a full `SearchState`, bit-compatible with `steps` iterations of
    the single-step path (post mode).
    """
    precision = cfg.precision or "float32"
    b = queries.shape[0]
    m, k, r = cfg.queue_size, cfg.k, cfg.degree
    s = prog.kinds.shape[1]
    t = prog.term_active.shape[1]
    w = prog.masks.shape[2]
    nw = state.visited.shape[1]
    dp = rows.shape[1]
    ap = aux.shape[1]
    v = n_values  # aux cols [w, w+v) — ap is DMA-padded, not layout-tight
    wq = 1 << (m + r - 1).bit_length()
    wr = 1 << (k + r - 1).bit_length()
    cw = min(128, 1 << (nw - 1).bit_length())
    n_chunks = -(-nw // cw)
    nwp = n_chunks * cw
    term_pack = jnp.where(prog.active, prog.term, -1).astype(jnp.int32)

    # The per-lane DMA issue is statically unrolled over the block's lanes,
    # so the block stays small even in interpret mode (unlike fused_step's
    # full-batch interpret block).
    bb = min(block_b, b)
    pad = (-b) % bb

    def pad0(a, fill=0):
        if pad == 0:
            return a
        widths = ((0, pad),) + ((0, 0),) * (a.ndim - 1)
        return jnp.pad(a, widths, constant_values=fill)

    # head inputs per codec (query side of the distance block)
    if precision == "float32":
        head_in = [pad0(_pad_cols(queries.astype(jnp.float32), dp))]
        head_specs = [_row((bb, dp))]
    elif precision == "int8":
        head_in = [pad0(_pad_cols(qprep.qq, dp)), pad0(qprep.sq[:, None]),
                   pad0(qprep.qn[:, None])]
        head_specs = [_row((bb, dp)), _row((bb, 1)), _row((bb, 1))]
    elif precision == "pq":
        sl, kc = qprep.lut.shape[1], qprep.lut.shape[2]
        head_in = [pad0(qprep.lut), pad0(qprep.qn[:, None])]
        head_specs = [_row((bb, sl, kc)), _row((bb, 1))]
    else:
        raise ValueError(f"unknown precision {precision!r}")

    cp = pack_payload(state.cand_idx, state.cand_exp, state.cand_valid)
    ctr = jnp.stack(
        [state.cnt, state.n_inspected, state.n_valid_visited,
         state.n_pop_valid, state.hops, state.conv_cnt, state.res_full_cnt,
         state.active.astype(jnp.int32)], axis=1)

    inputs = head_in + [
        pad0(prog.kinds), pad0(prog.masks), pad0(prog.lo), pad0(prog.hi),
        pad0(prog.vattr), pad0(prog.neg), pad0(term_pack, -1),
        pad0(prog.term_active),
        pad0(jnp.asarray(budgets, jnp.int32)[:, None]),
    ]
    in_specs = head_specs + [
        _row((bb, s)), _row((bb, s, w)), _row((bb, s)), _row((bb, s)),
        _row((bb, s)), _row((bb, s)), _row((bb, s)), _row((bb, t)),
        _row((bb, 1)),
    ]
    if has_gt:
        inputs.append(pad0(jnp.asarray(gt_dist, jnp.float32)))
        in_specs.append(_row((bb, k)))
    inputs += [
        pad0(state.cand_dist.astype(jnp.float32), jnp.inf), pad0(cp, -1),
        pad0(state.res_dist.astype(jnp.float32), jnp.inf),
        pad0(state.res_idx, -1),
        _pad_cols(pad0(state.visited), nwp), pad0(ctr),
        pad0(state.n_clause_valid), pad0(state.q_err_sum[:, None]),
    ]
    in_specs += [
        _row((bb, m)), _row((bb, m)), _row((bb, k)), _row((bb, k)),
        _row((bb, nwp)), _row((bb, 8)), _row((bb, CLAUSE_FEATURE_SLOTS)),
        _row((bb, 1)),
    ]
    bp = b + pad

    kern = functools.partial(
        _persistent_kernel, bb=bb, m=m, k=k, r=r, w=w, v=v, wq=wq, wr=wr,
        cw=cw, n_chunks=n_chunks, n_head=len(head_in), steps=steps,
        greedy=cfg.greedy_stop, has_gt=has_gt, precision=precision,
        n_clause=CLAUSE_FEATURE_SLOTS)
    ocd, ocp, ordd, ori, ovis, octr, oncl, oqerr = pl.pallas_call(
        kern,
        grid=(bp // bb,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM)]
        + [pl.BlockSpec(memory_space=pltpu.ANY)] * 3 + in_specs,
        out_specs=[
            _row((bb, m)), _row((bb, m)), _row((bb, k)), _row((bb, k)),
            _row((bb, nwp)), _row((bb, 8)),
            _row((bb, CLAUSE_FEATURE_SLOTS)), _row((bb, 1)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, m), jnp.float32),
            jax.ShapeDtypeStruct((bp, m), jnp.int32),
            jax.ShapeDtypeStruct((bp, k), jnp.float32),
            jax.ShapeDtypeStruct((bp, k), jnp.int32),
            jax.ShapeDtypeStruct((bp, nwp), jnp.uint32),
            jax.ShapeDtypeStruct((bp, 8), jnp.int32),
            jax.ShapeDtypeStruct((bp, CLAUSE_FEATURE_SLOTS), jnp.int32),
            jax.ShapeDtypeStruct((bp, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bb, r), jnp.int32),
            pltpu.VMEM((bb, r, dp), rows.dtype),
            pltpu.VMEM((bb, r, ap), jnp.uint32),
            pltpu.SemaphoreType.DMA((bb,)),
            pltpu.SemaphoreType.DMA((bb, r)),
            pltpu.SemaphoreType.DMA((bb, r)),
        ],
        interpret=interpret,
    )(jnp.asarray(rem, jnp.int32).reshape(1), neighbors, rows, aux, *inputs)

    idx, exp, vbit = unpack_payload(ocp[:b])
    from repro.core.state import SearchState

    return SearchState(
        cand_dist=ocd[:b], cand_idx=idx, cand_exp=exp, cand_valid=vbit,
        res_dist=ordd[:b], res_idx=ori[:b], visited=ovis[:b, :nw],
        cnt=octr[:b, 0], n_inspected=octr[:b, 1],
        n_valid_visited=octr[:b, 2], n_clause_valid=oncl[:b],
        n_pop_valid=octr[:b, 3], q_err_sum=oqerr[:b, 0], hops=octr[:b, 4],
        active=octr[:b, 7].astype(bool), d_start=state.d_start,
        conv_cnt=octr[:b, 5], res_full_cnt=octr[:b, 6])


def _row(shape):
    return pl.BlockSpec(shape, lambda i: (i,) + (0,) * (len(shape) - 1))
