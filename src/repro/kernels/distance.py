"""Pallas TPU kernel: batched masked squared-L2 distance (the NDC hot spot).

Every traversal step evaluates distances from B query lanes to their R
gathered neighbor vectors — the paper's cost unit (NDC). The kernel tiles
lanes into VMEM blocks and drives the contraction through the MXU via
dot_general; the predicate/visited mask is fused (masked entries emit +inf
so they never enter the queues).

Block shapes: (bB lanes) × (R neighbors) × (full d). VMEM per block
≈ bB·R·d·4 B — for bB=8, R=64, d=1024 that's 2 MB, comfortably inside the
~16 MB v5e VMEM, with d as the MXU lane dimension (pad d to 128 upstream
for peak utilization).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INF = float("inf")

# Row-count alignment for the pre-filter scan plan's gathered distance
# blocks. Empirically (pinned by tests/test_planner.py), XLA:CPU emits the
# same reduction for the bdrd einsum at every R that is a multiple of 64,
# so a (query, row) pair evaluates to the same bits no matter how wide the
# gathered block around it is — which is what lets the scan plan, the
# bruteforce oracle, and any serving-time batch shape agree bitwise. Widths
# off the alignment (R=7, R=257, …) pick different vectorizations and drift
# in the last ulp.
SCAN_ALIGN = 64


def sqdist_bdrd(q, x):
    """Pure-jnp squared L2: q [B,d], x [B,R,d] -> [B,R], clamped >= 0.

    The single source of the distance expression — the engine's init path,
    the dense backend, and the fused kernel's host path all call this so a
    numerics tweak can never desynchronize them (backend parity depends on
    bitwise-identical distances).
    """
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    qn = jnp.sum(q * q, axis=-1)[:, None]
    xn = jnp.sum(x * x, axis=-1)
    qx = jnp.einsum("bd,brd->br", q, x)
    return jnp.maximum(qn + xn - 2.0 * qx, 0.0)


@jax.jit
def scan_sqdist_lanes(q, x, mask):
    """Per-lane deterministic masked squared L2 for the pre-filter scan plan.

    q [B,d], x [B,V,d], mask [B,V] -> [B,V] f32 (+inf where ~mask).

    Each lane is evaluated at the canonical [1, V, d] shape via `lax.map`,
    so the value of any (query, row) pair is independent of which lanes
    share the batch — the serving layer pads scan batches to different lane
    widths than the one-shot planner, and the scheduled == one-shot
    bit-identity for scan-routed requests rides on this. V must be a
    multiple of SCAN_ALIGN (64-aligned widths are mutually bitwise-stable,
    see above), so the same pair also evaluates identically regardless of
    how much padding the gather added. Shares `sqdist_bdrd` per lane: one
    distance expression for traversal, scan, and oracle.
    """
    if x.shape[1] % SCAN_ALIGN:
        raise ValueError(
            f"scan width {x.shape[1]} not a multiple of SCAN_ALIGN "
            f"({SCAN_ALIGN}); pad the gathered block")
    d = jax.lax.map(lambda qx: sqdist_bdrd(qx[0][None], qx[1][None])[0],
                    (q.astype(jnp.float32), x.astype(jnp.float32)))
    return jnp.where(mask, d, INF)


def _sqdist_kernel(q_ref, x_ref, mask_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)          # [bB, d]
    x = x_ref[...].astype(jnp.float32)          # [bB, R, d]
    qn = jnp.sum(q * q, axis=-1)[:, None]       # [bB, 1]
    xn = jnp.sum(x * x, axis=-1)                # [bB, R]
    # per-lane MXU contraction: [bB,1,d] · [bB,R,d]^T -> [bB,R]
    qx = jax.lax.dot_general(
        q[:, None, :], x,
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )[:, 0, :]
    d = jnp.maximum(qn + xn - 2.0 * qx, 0.0)
    o_ref[...] = jnp.where(mask_ref[...], d, INF)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def sqdist_masked(q, x, mask, *, block_b: int = 8, interpret: bool = False):
    """q [B,d], x [B,R,d], mask [B,R] -> [B,R] f32 (+inf where masked)."""
    b, d = q.shape
    r = x.shape[1]
    bb = min(block_b, b)
    pad = (-b) % bb
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0)))
        x = jnp.pad(x, ((0, pad), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
    bp = q.shape[0]

    out = pl.pallas_call(
        _sqdist_kernel,
        grid=(bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, d), lambda i: (i, 0)),
            pl.BlockSpec((bb, r, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, r), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, r), jnp.float32),
        interpret=interpret,
    )(q, x, mask)
    return out[:b]
