"""jit'd wrappers around the Pallas kernels with automatic host fallback.

On a TPU backend the kernels run compiled (Mosaic). On this CPU container:

  sqdist / gbdt   execute via `interpret=True` — the kernel body itself runs
                  through the Pallas interpreter, validating semantics
                  (tests assert allclose vs ref.py) while the BlockSpec
                  tiling remains the TPU-target source of truth.
  top-M merges    the unrolled compare-exchange networks make XLA:CPU
                  compile time explode exponentially in stage count (the
                  Mosaic lowering is unaffected), so the merge kernels
                  dispatch to semantically-equivalent log-depth host
                  implementations in kernels.topk / kernels.fused_step;
                  tests assert exact agreement vs the ref.py oracles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import distance as _distance
from repro.kernels import fused_step as _fused
from repro.kernels import gbdt as _gbdt
from repro.kernels import topk as _topk
from repro.kernels.topk import pack_payload, unpack_payload  # re-export


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def batched_sqdist(q, x, mask=None):
    """q [B,d], x [B,R,d] -> [B,R] squared L2 (+inf where ~mask)."""
    if mask is None:
        mask = jnp.ones(x.shape[:2], bool)
    return _distance.sqdist_masked(q, x, mask, interpret=_interpret())


def masked_scan_dist(q, x, mask):
    """Pre-filter scan distance block: q [B,d], x [B,V,d] gathered valid
    rows (V a multiple of distance.SCAN_ALIGN), mask [B,V] -> [B,V] f32
    with +inf on masked pad entries.

    On TPU this is the fused masked-distance Pallas kernel (`sqdist_masked`
    — the scan plan reuses the traversal's distance kernel with the bitmap
    gather as its mask). On CPU it dispatches to the per-lane-deterministic
    host path instead: the batched kernel's values depend on the lane count,
    and the scan plan's bit-identity guarantees (vs the bruteforce oracle,
    and scheduled vs one-shot) need every (query, row) pair to evaluate to
    the same bits in any batch shape. The kernel itself is still
    interpret-validated against the host path in tests/test_planner.py.
    """
    if _interpret():
        return _distance.scan_sqdist_lanes(q, x, mask)
    return _distance.sqdist_masked(q, x, mask)


def queue_merge(dist, payload, new_dist, new_payload):
    """Merge a **sorted-ascending** [B,M] buffer with raw [B,R] entries.

    The sortedness precondition is load-bearing on the host path (the
    log-depth merge assumes the buffer is an ascending run); the TPU kernel
    happens to fully re-sort but callers must not rely on that.
    """
    if _interpret():
        return _topk.topm_merge_host(dist, payload, new_dist, new_payload)
    return _topk.topm_merge(dist, payload, new_dist, new_payload)


def fused_traversal_step(q, x, nb, is_new, prog, labels_g, values_g,
                         cand_dist, cand_pay, res_dist, res_idx, *,
                         pre: bool = False, quant=None,
                         precision: str = "float32"):
    """Fused filter program + distance + queue/result merge (one step).

    Returns (cand_dist, cand_pay, res_dist, res_idx, valid, clause_add) —
    see kernels.fused_step. `pre` selects the ACORN distance accounting
    (score predicate-valid first-visits only). `quant`/`precision` select
    the compressed-domain distance block (int8 ADC dot / PQ LUT gather);
    the host path shares `quant.codecs.quant_dist` with the dense backend
    so compressed-mode dense/pallas parity is exact on CPU.
    """
    if _interpret():
        return _fused.fused_step_host(q, x, nb, is_new, prog, labels_g,
                                      values_g, cand_dist, cand_pay,
                                      res_dist, res_idx, pre=pre,
                                      quant=quant, precision=precision)
    return _fused.fused_step(q, x, nb, is_new, prog, labels_g, values_g,
                             cand_dist, cand_pay, res_dist, res_idx, pre=pre,
                             quant=quant, precision=precision)


def estimator_predict(feats, packed_model, depth):
    feat_idx, thresh, leaf, base = packed_model
    return _gbdt.gbdt_predict(feats, feat_idx, thresh, leaf, base, depth,
                              interpret=_interpret())
