"""jit'd wrappers around the Pallas kernels with automatic CPU fallback.

On a TPU backend the kernels run compiled (Mosaic); on this CPU container
they execute in `interpret=True` mode — the kernel body runs in Python on
CPU, which validates semantics (tests assert allclose vs ref.py) while the
BlockSpec tiling remains the TPU-target source of truth.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import distance as _distance
from repro.kernels import gbdt as _gbdt
from repro.kernels import topk as _topk
from repro.kernels.topk import pack_payload, unpack_payload  # re-export


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def batched_sqdist(q, x, mask=None):
    """q [B,d], x [B,R,d] -> [B,R] squared L2 (+inf where ~mask)."""
    if mask is None:
        mask = jnp.ones(x.shape[:2], bool)
    return _distance.sqdist_masked(q, x, mask, interpret=_interpret())


def queue_merge(dist, payload, new_dist, new_payload):
    return _topk.topm_merge(dist, payload, new_dist, new_payload,
                            interpret=_interpret())


def estimator_predict(feats, packed_model, depth):
    feat_idx, thresh, leaf, base = packed_model
    return _gbdt.gbdt_predict(feats, feat_idx, thresh, leaf, base, depth,
                              interpret=_interpret())
