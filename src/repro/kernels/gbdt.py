"""Pallas TPU kernel: GBDT ensemble inference (the E2E cost estimator).

The estimator runs once per query between the probe and the resumed
traversal — it must cost microseconds (the paper's 0.025 ms LightGBM
budget). Trees are heap-packed complete binary trees; inference is `depth`
rounds of (gather feature id, gather threshold, compare, descend) across
all T trees at once, with the whole forest resident in VMEM
(T·(2^D)·8 B ≈ 0.2 MB for 400 depth-5 trees) and a [bB, F] feature tile.

Gathers are expressed as one-hot contractions (`take`) — Mosaic-friendly
and exactly matching core.gbdt.predict_jax (the numpy/JAX oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gbdt_kernel(feats_ref, fidx_ref, thr_ref, leaf_ref, o_ref, *, depth):
    feats = feats_ref[...]                       # [bB, F]
    fidx = fidx_ref[...]                         # [T, NI]
    thr = thr_ref[...]                           # [T, NI]
    leaf = leaf_ref[...]                         # [T, NL]
    bb = feats.shape[0]
    t, ni = fidx.shape
    t_ix = jnp.arange(t)[None, :]
    idx = jnp.zeros((bb, t), jnp.int32)
    flat_f = fidx.reshape(-1)
    flat_t = thr.reshape(-1)
    for _ in range(depth):
        node = t_ix * ni + idx                   # [bB, T] flat node ids
        f = jnp.take(flat_f, node, axis=0)       # feature tested per (lane, tree)
        th = jnp.take(flat_t, node, axis=0)
        xv = jnp.take_along_axis(feats, f, axis=1)
        go_left = xv <= th
        idx = 2 * idx + 1 + (1 - go_left.astype(jnp.int32))
    flat_leaf = leaf.reshape(-1)
    vals = jnp.take(flat_leaf, t_ix * leaf.shape[1] + (idx - ni), axis=0)
    o_ref[...] = vals.sum(axis=1)


@functools.partial(jax.jit, static_argnames=("depth", "block_b", "interpret"))
def gbdt_predict(feats, feat_idx, thresh, leaf, base, depth: int,
                 *, block_b: int = 32, interpret: bool = False):
    """feats [B,F] -> [B] f32 ensemble predictions."""
    b, f = feats.shape
    t, ni = feat_idx.shape
    nl = leaf.shape[1]
    bb = min(block_b, b)
    pad = (-b) % bb
    if pad:
        feats = jnp.pad(feats, ((0, pad), (0, 0)))
    bp = feats.shape[0]

    kern = functools.partial(_gbdt_kernel, depth=depth)
    out = pl.pallas_call(
        kern,
        grid=(bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, f), lambda i: (i, 0)),
            pl.BlockSpec((t, ni), lambda i: (0, 0)),   # forest resident
            pl.BlockSpec((t, ni), lambda i: (0, 0)),
            pl.BlockSpec((t, nl), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bp,), jnp.float32),
        interpret=interpret,
    )(feats.astype(jnp.float32), feat_idx, thresh.astype(jnp.float32),
      leaf.astype(jnp.float32))
    return out[:b] + base
