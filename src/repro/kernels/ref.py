"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

INF = jnp.float32(jnp.inf)


def sqdist_masked_ref(q, x, mask):
    """q [B,d], x [B,R,d], mask [B,R] -> [B,R] f32 squared L2, +inf masked."""
    qn = jnp.sum(q.astype(jnp.float32) ** 2, axis=-1)[:, None]
    xn = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)
    qx = jnp.einsum("bd,brd->br", q.astype(jnp.float32), x.astype(jnp.float32))
    d = jnp.maximum(qn + xn - 2.0 * qx, 0.0)
    return jnp.where(mask, d, INF)


def _bitonic_stages(n):
    """(stride, direction-block) pairs of a bitonic sorting network of width n."""
    stages = []
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            stages.append((j, k))
            j //= 2
        k *= 2
    return stages


def bitonic_sort_kv(keys, vals):
    """Ascending bitonic sort of keys [B, N] (N power of 2) carrying vals."""
    b, n = keys.shape
    assert n & (n - 1) == 0, "width must be a power of two"
    idx = jnp.arange(n)
    for j, k in _bitonic_stages(n):
        partner = idx ^ j
        asc = (idx & k) == 0
        k_self, k_part = keys, keys[:, partner]
        v_self, v_part = vals, vals[:, partner]
        first = idx < partner
        keep_self = jnp.where(
            first,
            jnp.where(asc, k_self <= k_part, k_self >= k_part),
            jnp.where(asc, k_part <= k_self, k_part >= k_self),
        )
        keys = jnp.where(keep_self, k_self, k_part)
        vals = jnp.where(keep_self, v_self, v_part)
    return keys, vals


def topm_merge_ref(dist, payload, new_dist, new_payload):
    """Merge sorted [B,M] buffer with [B,R] candidates -> best-M (bitonic).

    payloads are int32 (packed idx+flags) carried through the sort.
    """
    b, m = dist.shape
    r = new_dist.shape[1]
    width = 1 << (m + r - 1).bit_length()
    pad = width - (m + r)
    keys = jnp.concatenate(
        [dist, new_dist, jnp.full((b, pad), INF)], axis=1)
    vals = jnp.concatenate(
        [payload, new_payload, jnp.full((b, pad), -1, jnp.int32)], axis=1)
    keys, vals = bitonic_sort_kv(keys, vals)
    return keys[:, :m], vals[:, :m]


def eval_program_ref(prog, labels_g, values_g):
    """Oracle for the compiled filter-program evaluation.

    Formulated unlike either production path (no einsum combiner, no
    unrolled slot loop): a full [B, T, S, R] membership broadcast reduced
    with jnp.all/any. Returns (valid [B,R] bool, clause_sat [B,S,R] bool).
    """
    m = prog.masks[:, :, None, :]
    lg = labels_g[:, None, :, :]
    c_contain = jnp.all((lg & m) == m, axis=-1)
    c_equal = jnp.all(lg == m, axis=-1)
    c_in = jnp.any((lg & m) != 0, axis=-1)
    vat = jnp.clip(prog.vattr, 0, values_g.shape[-1] - 1)
    vsel = jnp.take_along_axis(values_g[:, None, :, :],
                               vat[:, :, None, None], axis=-1)[..., 0]
    c_range = (vsel >= prog.lo[:, :, None]) & (vsel <= prog.hi[:, :, None])
    k = prog.kinds[:, :, None]
    prim = jnp.where(k == 0, c_contain,
                     jnp.where(k == 1, c_equal,
                               jnp.where(k == 2, c_range, c_in)))
    lit = jnp.logical_xor(prim, prog.neg[:, :, None])
    clause_sat = lit & prog.active[:, :, None]
    t = prog.term_active.shape[1]
    member = ((prog.term[:, :, None] == jnp.arange(t)[None, None, :])
              & prog.active[:, :, None])                   # [B,S,T]
    # [B,T,S,R]: literal holds, or the slot isn't part of this term
    holds = lit[:, None, :, :] | ~member.transpose(0, 2, 1)[:, :, :, None]
    term_ok = jnp.all(holds, axis=2) & prog.term_active[:, :, None]
    return jnp.any(term_ok, axis=1), clause_sat


def fused_step_ref(q, x, nb, is_new, prog, labels_g, values_g,
                   cand_dist, cand_pay, res_dist, res_idx, *,
                   pre: bool = False, n_clause: int = 4):
    """Oracle for kernels.fused_step: program eval + masked distances +
    dual bitonic merge + clause counters."""
    pvalid, clause_sat = eval_program_ref(prog, labels_g, values_g)
    valid = pvalid & is_new
    dist_mask = valid if pre else is_new
    cs = (clause_sat & is_new[:, None, :]).sum(-1).astype(jnp.int32)
    s = cs.shape[1]
    cadd = (cs[:, :n_clause] if s >= n_clause
            else jnp.pad(cs, ((0, 0), (0, n_clause - s))))
    dd = sqdist_masked_ref(q, x, dist_mask)
    new_pay = jnp.where(dist_mask, nb | (valid.astype(jnp.int32) << 30), -1)
    ocd, ocp = topm_merge_ref(cand_dist, cand_pay, dd, new_pay)
    res_in = jnp.where(valid & dist_mask, dd, INF)
    res_pay = jnp.where(valid & dist_mask, nb, -1)
    ordd, ori = topm_merge_ref(res_dist, res_idx, res_in, res_pay)
    return ocd, ocp, ordd, ori, valid, cadd


def gbdt_predict_ref(feats, feat_idx, thresh, leaf, base, depth):
    """feats [B,F] -> [B]; complete heap-packed trees (see core.gbdt)."""
    b = feats.shape[0]
    t = feat_idx.shape[0]
    n_internal = feat_idx.shape[1]
    t_ix = jnp.arange(t)[None, :]
    idx = jnp.zeros((b, t), jnp.int32)
    for _ in range(depth):
        f = feat_idx[t_ix, idx]
        xv = jnp.take_along_axis(feats, f, axis=1)
        go_left = xv <= thresh[t_ix, idx]
        idx = 2 * idx + 1 + (1 - go_left.astype(jnp.int32))
    return base + leaf[t_ix, idx - n_internal].sum(axis=1)
