"""Pallas TPU kernel: fused traversal step (filter program + distance + merge).

One lockstep traversal step turns R gathered neighbor vectors *and their
attribute words* into updated candidate-queue and result-set buffers.
Executed as separate ops that is: a clause-program evaluation over
[B,S,R(,W|V)] intermediates, a [B,R] distance batch, a [B,M+R] argsort, a
[B,K+R] argsort, and six take_along_axis gathers — every intermediate
bouncing through HBM.

This kernel fuses the whole step for a block of lanes in one VMEM pass:

  1. compiled filter program (filters/compile.py): per clause slot all four
     primitives (contain / equal / range / any-of) over the gathered label
     words + numeric channels, selected by kind tag, combined through the
     DNF term table — statically unrolled over the S slots / T terms of the
     program shape, vectorized over lanes × neighbors
  2. squared-L2 distances q·x via the MXU (dot_general, f32 accumulate)
  3. mode-dependent mask (post: every first-visit scores; pre: valid only);
     masked entries emit +inf
  4. candidate-queue merge: bitonic top-M over width next_pow2(M+R)
  5. result-set merge: bitonic top-K over width next_pow2(K+R)

Besides the merged buffers it emits the validity mask and per-clause hit
counters (for the estimator's clause-wise probe selectivities) — the only
predicate state that leaves VMEM.

Payloads ride as packed int32 (node id + expanded/valid flags, see
kernels.topk.pack_payload) so the sorting network permutes one value lane.
Wired in as `SearchConfig(backend="pallas")` via repro.core.backends.

VMEM per block ≈ bB·(R·(d+W+V) + S·W + 2·next_pow2(M+R) + 2·next_pow2(K+R))·4 B;
for bB=8, R=64, d=1024, M=512, S=8, W=4 that's ~2.3 MB — comfortable on a
16 MB core.

Compressed-domain variants (repro.quant): two sibling kernels swap only
the distance block (step 2) and share the program-eval + merge tail via
`_program_and_merge` —

  int8  gathered [bB, R, d] int8 codes · quantized query factor, an
        int8×int8 → int32 MXU dot (exact integer arithmetic); the float32
        vector block never enters VMEM — ~4× less per-NDC bandwidth.
  pq    per-query inner-product LUT rows [bB, S·L, Kc] f32 stay
        VMEM-resident (≈ bB·S·L·Kc·4 B — 1.5 MB at bB=8, S·L=48, Kc=256)
        and each code row costs S·L lookups, lowered as one-hot × LUT-row
        contractions per slot, bit-equal to the gather; the distance
        assembles as ‖q‖² + ‖x̂‖² − 2·Σ lookups.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.filters.compile import (
    CLAUSE_FEATURE_SLOTS,
    clause_counts,
    eval_program_gathered,
)
from repro.kernels.distance import sqdist_bdrd
from repro.kernels.topk import bitonic_merge_sorted, merge_topm, sort_kv_f32

INF = float("inf")


def _program_valid_kernel(kinds, masks, lo, hi, vattr, neg, term, active,
                          term_active, labels, values):
    """In-kernel clause-program evaluation, unrolled over static S and T.

    labels [bb, R, W] u32, values [bb, R, V] f32; program leaves [bb, S, ...].
    Returns (valid [bb, R] bool, sats: S × [bb, R] bool). Same formulas as
    `filters.compile.eval_program_gathered`, restructured as per-slot loops
    (static python unrolling) so Mosaic sees only 2-D elementwise work.
    """
    s = kinds.shape[1]
    t = term_active.shape[1]
    v_chan = values.shape[2]
    lits, sats = [], []
    for si in range(s):
        msk = masks[:, si, :][:, None, :]                     # [bb,1,W]
        inter = jnp.bitwise_and(labels, msk)
        c_contain = jnp.all(inter == msk, axis=-1)            # [bb,R]
        c_equal = jnp.all(labels == msk, axis=-1)
        c_in = jnp.any(inter != 0, axis=-1)
        vs = values[:, :, 0]
        for ch in range(1, v_chan):                           # channel select
            vs = jnp.where(vattr[:, si][:, None] == ch, values[:, :, ch], vs)
        c_range = (vs >= lo[:, si][:, None]) & (vs <= hi[:, si][:, None])
        kk = kinds[:, si][:, None]
        prim = jnp.where(kk == 0, c_contain,
                         jnp.where(kk == 1, c_equal,
                                   jnp.where(kk == 2, c_range, c_in)))
        lit = jnp.logical_xor(prim, neg[:, si][:, None])
        act = active[:, si][:, None]
        sats.append(lit & act)
        lits.append(lit | ~act)                               # inactive: no veto
    valid = jnp.zeros(labels.shape[:2], bool)
    for ti in range(t):
        ok = term_active[:, ti][:, None]
        for si in range(s):
            member = (term[:, si] == ti) & active[:, si]
            ok = ok & (lits[si] | ~member[:, None])
        valid = valid | ok
    return valid, sats


def _merge_core(d, nb, is_new, kinds, masks, lo, hi, vattr, neg, term_pack,
                tact, labels, values, cd, cp, rd, ri,
                *, m, k, wq, wr, pre, n_clause):
    """Value-level shared tail: filter program, masking, both bitonic merges.

    Pure function of the step's values — no refs — so it is callable both
    from the single-step kernels below (via the ref-plumbing wrapper
    `_program_and_merge`) and per step from the persistent multi-step
    kernel (kernels.persistent_step), whose state lives in VMEM scratch
    across steps. Returns (cand_dist, cand_pay, res_dist, res_idx,
    valid [bB, R] bool, clause_counts [bB, C] i32).
    """
    # ---- compiled filter program on the gathered attribute words ----
    # (kinds == -1 never matches a primitive tag; the active mask rides in
    # term_pack's sign bit — see fused_step packing below)
    active = term_pack >= 0
    term = jnp.maximum(term_pack, 0)
    pvalid, sats = _program_valid_kernel(
        kinds, masks, lo, hi, vattr, neg, term, active, tact, labels, values)
    valid = pvalid & is_new
    dmask = valid if pre else is_new

    counts = []
    for c in range(n_clause):
        if c < len(sats):
            counts.append((sats[c] & is_new).sum(axis=1).astype(jnp.int32))
        else:
            counts.append(jnp.zeros(nb.shape[:1], jnp.int32))
    occ = jnp.stack(counts, axis=1)

    # ---- mask: non-scored neighbors never enter the buffers ----
    dd = jnp.where(dmask, d, INF)
    # pack_payload(nb, expanded=False, valid) inline; dmask ⇒ nb >= 0
    new_pay = jnp.where(dmask, nb | (valid.astype(jnp.int32) << 30), -1)

    # ---- candidate-queue merge (bitonic top-M) ----
    ocd, ocp = merge_topm(cd, cp, dd, new_pay, m, wq)

    # ---- result-set merge (valid only, bitonic top-K) ----
    res_in = jnp.where(valid & dmask, dd, INF)
    res_pay = jnp.where(valid & dmask, nb, -1)
    ordd, ori = merge_topm(rd, ri, res_in, res_pay, k, wr)
    return ocd, ocp, ordd, ori, valid, occ


def _program_and_merge(d, nb, is_new,
                       kinds_ref, masks_ref, lo_ref, hi_ref, vattr_ref,
                       neg_ref, term_ref, tact_ref, lab_ref, val_ref,
                       cd_ref, cp_ref, rd_ref, ri_ref,
                       ocd_ref, ocp_ref, ord_ref, ori_ref, ov_ref, occ_ref,
                       *, m, k, wq, wr, pre, n_clause):
    """Ref-plumbing wrapper over `_merge_core` for the single-step kernels.

    Every fused-step kernel variant (float32 MXU distances, int8 ADC, PQ
    ADC) computes its [bB, R] distance block `d` and delegates the rest
    here, so the program evaluation and merge dataflow can never diverge
    between precision modes (or between the single-step and persistent
    kernels, which share `_merge_core`).
    """
    ocd, ocp, ordd, ori, valid, occ = _merge_core(
        d, nb, is_new,
        kinds_ref[...], masks_ref[...], lo_ref[...], hi_ref[...],
        vattr_ref[...], neg_ref[...], term_ref[...], tact_ref[...],
        lab_ref[...], val_ref[...],
        cd_ref[...], cp_ref[...], rd_ref[...], ri_ref[...],
        m=m, k=k, wq=wq, wr=wr, pre=pre, n_clause=n_clause)
    ov_ref[...] = valid.astype(jnp.int32)
    occ_ref[...] = occ
    ocd_ref[...] = ocd
    ocp_ref[...] = ocp
    ord_ref[...] = ordd
    ori_ref[...] = ori


def _fused_step_kernel(q_ref, x_ref, nb_ref, new_ref, lab_ref, val_ref,
                       kinds_ref, masks_ref, lo_ref, hi_ref, vattr_ref,
                       neg_ref, term_ref, tact_ref,
                       cd_ref, cp_ref, rd_ref, ri_ref,
                       ocd_ref, ocp_ref, ord_ref, ori_ref, ov_ref, occ_ref,
                       *, m, k, wq, wr, pre, n_clause):
    q = q_ref[...].astype(jnp.float32)          # [bB, d]
    x = x_ref[...].astype(jnp.float32)          # [bB, R, d]

    # ---- distances (per-lane MXU contraction) ----
    qn = jnp.sum(q * q, axis=-1)[:, None]
    xn = jnp.sum(x * x, axis=-1)
    qx = jax.lax.dot_general(
        q[:, None, :], x,
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )[:, 0, :]
    d = jnp.maximum(qn + xn - 2.0 * qx, 0.0)

    _program_and_merge(
        d, nb_ref[...], new_ref[...],
        kinds_ref, masks_ref, lo_ref, hi_ref, vattr_ref, neg_ref, term_ref,
        tact_ref, lab_ref, val_ref, cd_ref, cp_ref, rd_ref, ri_ref,
        ocd_ref, ocp_ref, ord_ref, ori_ref, ov_ref, occ_ref,
        m=m, k=k, wq=wq, wr=wr, pre=pre, n_clause=n_clause)


def _fused_step_int8_kernel(codes_ref, xn_ref, qq_ref, sq_ref, qn_ref,
                            nb_ref, new_ref, lab_ref, val_ref,
                            kinds_ref, masks_ref, lo_ref, hi_ref, vattr_ref,
                            neg_ref, term_ref, tact_ref,
                            cd_ref, cp_ref, rd_ref, ri_ref,
                            ocd_ref, ocp_ref, ord_ref, ori_ref, ov_ref,
                            occ_ref, *, m, k, wq, wr, pre, n_clause):
    """int8 ADC variant: the distance block is an int8×int8 → int32 MXU dot
    over the gathered codes — the index's float vectors never enter VMEM.

    codes [bB, R, d] i8, xn [bB, R] f32 (per-node ‖scale⊙c‖²),
    qq [bB, d] i8 (quantized query factor), sq/qn [bB, 1] f32.
    Same arithmetic as quant.codecs.adc_int8: the integer dot is exact, so
    kernel vs host agreement is bitwise up to the identical float tail.
    """
    qq = qq_ref[...]                             # [bB, d] i8
    codes = codes_ref[...]                       # [bB, R, d] i8
    dot = jax.lax.dot_general(
        qq[:, None, :], codes,
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.int32,
    )[:, 0, :]                                   # [bB, R] i32
    d = jnp.maximum(
        qn_ref[...] + xn_ref[...] - 2.0 * sq_ref[...] * dot.astype(jnp.float32),
        0.0)

    _program_and_merge(
        d, nb_ref[...], new_ref[...],
        kinds_ref, masks_ref, lo_ref, hi_ref, vattr_ref, neg_ref, term_ref,
        tact_ref, lab_ref, val_ref, cd_ref, cp_ref, rd_ref, ri_ref,
        ocd_ref, ocp_ref, ord_ref, ori_ref, ov_ref, occ_ref,
        m=m, k=k, wq=wq, wr=wr, pre=pre, n_clause=n_clause)


def _fused_step_pq_kernel(codes_ref, lut_ref, xn_ref, qn_ref,
                          nb_ref, new_ref, lab_ref, val_ref,
                          kinds_ref, masks_ref, lo_ref, hi_ref, vattr_ref,
                          neg_ref, term_ref, tact_ref,
                          cd_ref, cp_ref, rd_ref, ri_ref,
                          ocd_ref, ocp_ref, ord_ref, ori_ref, ov_ref,
                          occ_ref, *, m, k, wq, wr, pre, n_clause):
    """PQ ADC variant: per-query inner-product LUT rows stay resident in
    VMEM ([bB, S·L, Kc] f32 ≈ bB·S·L·Kc·4 B — 1.5 MB at bB=8, S·L=48,
    Kc=256) and each gathered code row costs S·L table lookups, realized
    as one-hot × LUT-row MXU contractions per slot (statically unrolled):
    exactly one unit weight per row, so the contraction equals the gather
    bit-for-bit while avoiding per-element dynamic indexing in the kernel.
    The distance assembles as ‖q‖² + ‖x̂‖² − 2·Σ lookups (xn = gathered
    per-node ‖x̂‖², qn = per-lane ‖q‖²).
    """
    codes = codes_ref[...]                       # [bB, R, S·L] i32
    lut = lut_ref[...]                           # [bB, S·L, Kc] f32
    s = codes.shape[2]
    kc = lut.shape[2]
    ip = jnp.zeros(codes.shape[:2], jnp.float32)
    for si in range(s):
        onehot = (codes[:, :, si][:, :, None]
                  == jnp.arange(kc, dtype=jnp.int32)[None, None, :]
                  ).astype(jnp.float32)          # [bB, R, Kc]
        ip = ip + jax.lax.dot_general(
            onehot, lut[:, si, :][:, :, None],
            dimension_numbers=(((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )[:, :, 0]
    d = jnp.maximum(qn_ref[...] + xn_ref[...] - 2.0 * ip, 0.0)

    _program_and_merge(
        d, nb_ref[...], new_ref[...],
        kinds_ref, masks_ref, lo_ref, hi_ref, vattr_ref, neg_ref, term_ref,
        tact_ref, lab_ref, val_ref, cd_ref, cp_ref, rd_ref, ri_ref,
        ocd_ref, ocp_ref, ord_ref, ori_ref, ov_ref, occ_ref,
        m=m, k=k, wq=wq, wr=wr, pre=pre, n_clause=n_clause)


def fused_step_host(q, x, nb, is_new, prog, labels_g, values_g,
                    cand_dist, cand_pay, res_dist, res_idx, *, pre: bool,
                    quant=None, precision: str = "float32"):
    """Host-path (non-TPU) equivalent of the fused kernel.

    Same dataflow — program evaluation, distances, mask, queue merge,
    result merge in one traced region — but the program evaluation is the
    *shared* `filters.compile.eval_program_gathered` (so dense/pallas
    parity is exact by construction) and the unrolled bitonic networks are
    replaced by the log-depth sorted-merge of kernels.topk (XLA:CPU
    compiles the full network pathologically; see the note there).
    Distance arithmetic matches the dense backend expression exactly —
    compressed mode included: both call `quant.codecs.quant_dist` — so
    dense/pallas parity is bitwise on CPU up to distance ties.
    """
    m, k = cand_dist.shape[1], res_dist.shape[1]
    pvalid, clause_sat = eval_program_gathered(prog, labels_g, values_g)
    valid = pvalid & is_new
    cadd = clause_counts(clause_sat, is_new)
    dist_mask = valid if pre else is_new

    if quant is None:
        d_raw = sqdist_bdrd(q, x)
    else:
        from repro.quant.codecs import quant_dist

        d_raw = quant_dist(precision, quant)
    dd = jnp.where(dist_mask, d_raw, INF)
    new_pay = jnp.where(dist_mask, nb | (valid.astype(jnp.int32) << 30), -1)

    ns_d, ns_p = sort_kv_f32(dd, new_pay)
    ocd, ocp = bitonic_merge_sorted(cand_dist.astype(jnp.float32), cand_pay,
                                    ns_d, ns_p, m)

    res_in = jnp.where(valid & dist_mask, dd, INF)
    res_pay = jnp.where(valid & dist_mask, nb, -1)
    rs_d, rs_p = sort_kv_f32(res_in, res_pay)
    ordd, ori = bitonic_merge_sorted(res_dist.astype(jnp.float32), res_idx,
                                     rs_d, rs_p, k)
    return ocd, ocp, ordd, ori, valid, cadd


@functools.partial(jax.jit,
                   static_argnames=("pre", "block_b", "interpret", "precision"))
def fused_step(q, x, nb, is_new, prog, labels_g, values_g, cand_dist,
               cand_pay, res_dist, res_idx, *, pre: bool = False,
               block_b: int = 8, interpret: bool = False,
               quant=None, precision: str = "float32"):
    """One fused traversal step over a batch of lanes.

    q [B,d], x [B,R,d], nb [B,R] i32, is_new [B,R] bool,
    prog FilterProgram (leaves [B,S,...]), labels_g [B,R,W] u32,
    values_g [B,R,V] f32,
    cand_dist [B,M] f32 + cand_pay [B,M] i32 (packed, sorted ascending),
    res_dist [B,K] f32 + res_idx [B,K] i32 (sorted ascending)
    -> (cand_dist, cand_pay, res_dist, res_idx, valid [B,R] bool,
        clause_add [B,C] i32) merged, sorted, best-M/K.

    Compressed mode: precision "int8" | "pq" with `quant` a QuantGather
    (per-query ADC prep + the step's gathered codes/norms); `x` may be
    None — the distance block runs on the codes (int8 MXU dot / in-VMEM
    LUT rows), the float vectors never enter the kernel.
    """
    b, dm = q.shape
    r = nb.shape[1]
    m = cand_dist.shape[1]
    k = res_dist.shape[1]
    s = prog.kinds.shape[1]
    t = prog.term_active.shape[1]
    w = labels_g.shape[2]
    v = values_g.shape[2]
    wq = 1 << (m + r - 1).bit_length()
    wr = 1 << (k + r - 1).bit_length()

    # slot activity riding in the term id's sign bit keeps the ref count
    # down (term >= 0 ⇔ active); neg packs as int32 for the same reason
    term_pack = jnp.where(prog.active, prog.term, -1).astype(jnp.int32)

    # Interpret mode simulates grid steps sequentially; a single full-batch
    # block keeps the simulated step vectorized. On TPU the block size is a
    # VMEM knob and stays small.
    bb = min(b, 1024) if interpret else min(block_b, b)
    pad = (-b) % bb

    def pad0(a, fill=0):
        if pad == 0:
            return a
        widths = ((0, pad),) + ((0, 0),) * (a.ndim - 1)
        return jnp.pad(a, widths, constant_values=fill)

    q = pad0(q)
    if x is not None:
        x = pad0(x)
    nb = pad0(nb, -1)
    is_new = pad0(is_new)
    labels_g = pad0(labels_g)
    values_g = pad0(values_g)
    kinds = pad0(prog.kinds)
    masks = pad0(prog.masks)
    lo = pad0(prog.lo)
    hi = pad0(prog.hi)
    vattr = pad0(prog.vattr)
    neg = pad0(prog.neg)
    term_pack = pad0(term_pack, -1)
    tact = pad0(prog.term_active)
    cand_dist = pad0(cand_dist, jnp.inf)
    cand_pay = pad0(cand_pay, -1)
    res_dist = pad0(res_dist, jnp.inf)
    res_idx = pad0(res_idx, -1)
    bp = q.shape[0]

    def row(shape):
        return pl.BlockSpec(shape, lambda i: (i,) + (0,) * (len(shape) - 1))

    # variant head: (kernel fn, leading inputs + specs). The shared tail
    # (attributes, program, buffers) is identical across precisions.
    if precision == "float32":
        head_kern = _fused_step_kernel
        head_in = [q.astype(jnp.float32), x]
        head_specs = [row((bb, dm)), row((bb, r, dm))]
    elif precision == "int8":
        codes = pad0(quant.codes.astype(jnp.int8))
        xn = pad0(quant.norms)
        qq = pad0(quant.prep.qq)
        sq = pad0(quant.prep.sq[:, None])
        qn = pad0(quant.prep.qn[:, None])
        dq = codes.shape[2]
        head_kern = _fused_step_int8_kernel
        head_in = [codes, xn, qq, sq, qn]
        head_specs = [row((bb, r, dq)), row((bb, r)), row((bb, dq)),
                      row((bb, 1)), row((bb, 1))]
    elif precision == "pq":
        codes = pad0(quant.codes.astype(jnp.int32))
        lut = pad0(quant.prep.lut)
        xn = pad0(quant.norms)
        qn = pad0(quant.prep.qn[:, None])
        sp, kc = lut.shape[1], lut.shape[2]
        head_kern = _fused_step_pq_kernel
        head_in = [codes, lut, xn, qn]
        head_specs = [row((bb, r, sp)), row((bb, sp, kc)), row((bb, r)),
                      row((bb, 1))]
    else:
        raise ValueError(f"unknown precision {precision!r}")

    kern = functools.partial(head_kern, m=m, k=k, wq=wq, wr=wr,
                             pre=pre, n_clause=CLAUSE_FEATURE_SLOTS)
    ocd, ocp, ordd, ori, ov, occ = pl.pallas_call(
        kern,
        grid=(bp // bb,),
        in_specs=head_specs + [
            row((bb, r)), row((bb, r)),
            row((bb, r, w)), row((bb, r, v)),
            row((bb, s)), row((bb, s, w)), row((bb, s)), row((bb, s)),
            row((bb, s)), row((bb, s)), row((bb, s)), row((bb, t)),
            row((bb, m)), row((bb, m)), row((bb, k)), row((bb, k)),
        ],
        out_specs=[
            row((bb, m)), row((bb, m)), row((bb, k)), row((bb, k)),
            row((bb, r)), row((bb, CLAUSE_FEATURE_SLOTS)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, m), jnp.float32),
            jax.ShapeDtypeStruct((bp, m), jnp.int32),
            jax.ShapeDtypeStruct((bp, k), jnp.float32),
            jax.ShapeDtypeStruct((bp, k), jnp.int32),
            jax.ShapeDtypeStruct((bp, r), jnp.int32),
            jax.ShapeDtypeStruct((bp, CLAUSE_FEATURE_SLOTS), jnp.int32),
        ],
        interpret=interpret,
    )(*head_in, nb, is_new, labels_g, values_g,
      kinds, masks, lo, hi, vattr, neg, term_pack, tact,
      cand_dist.astype(jnp.float32), cand_pay,
      res_dist.astype(jnp.float32), res_idx)
    return (ocd[:b], ocp[:b], ordd[:b], ori[:b], ov[:b].astype(bool),
            occ[:b])
