"""Pallas TPU kernel: fused traversal step (distance + mask + dual merge).

One lockstep traversal step turns R gathered neighbor vectors into updated
candidate-queue and result-set buffers. Executed as separate ops that is:
a [B,R] distance batch, a [B,M+R] argsort, a [B,K+R] argsort, and six
take_along_axis gathers — every intermediate bouncing through HBM.

This kernel fuses the whole step for a block of lanes in one VMEM pass:

  1. squared-L2 distances q·x via the MXU (dot_general, f32 accumulate)
  2. filter/visited mask application (masked entries emit +inf)
  3. candidate-queue merge: bitonic top-M over width next_pow2(M+R)
  4. result-set merge: bitonic top-K over width next_pow2(K+R)

Payloads ride as packed int32 (node id + expanded/valid flags, see
kernels.topk.pack_payload) so the sorting network permutes one value lane.
Replaces the per-step argsort pair of the dense reference backend; wired in
as `SearchConfig(backend="pallas")` via repro.core.backends.

VMEM per block ≈ bB·(R·d + 2·next_pow2(M+R) + 2·next_pow2(K+R))·4 B; for
bB=8, R=64, d=1024, M=512 that's ~2.2 MB — comfortable on a 16 MB core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.distance import sqdist_bdrd
from repro.kernels.topk import bitonic_merge_sorted, merge_topm, sort_kv_f32

INF = float("inf")


def _fused_step_kernel(q_ref, x_ref, nb_ref, dmask_ref, vmask_ref,
                       cd_ref, cp_ref, rd_ref, ri_ref,
                       ocd_ref, ocp_ref, ord_ref, ori_ref,
                       *, m, k, wq, wr):
    q = q_ref[...].astype(jnp.float32)          # [bB, d]
    x = x_ref[...].astype(jnp.float32)          # [bB, R, d]
    dmask = dmask_ref[...]                      # [bB, R]
    valid = vmask_ref[...]                      # [bB, R]
    nb = nb_ref[...]                            # [bB, R]

    # ---- 1. distances (per-lane MXU contraction) ----
    qn = jnp.sum(q * q, axis=-1)[:, None]
    xn = jnp.sum(x * x, axis=-1)
    qx = jax.lax.dot_general(
        q[:, None, :], x,
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )[:, 0, :]
    d = jnp.maximum(qn + xn - 2.0 * qx, 0.0)

    # ---- 2. mask: non-scored neighbors never enter the buffers ----
    dd = jnp.where(dmask, d, INF)
    # pack_payload(nb, expanded=False, valid) inline; dmask ⇒ nb >= 0
    new_pay = jnp.where(dmask, nb | (valid.astype(jnp.int32) << 30), -1)

    # ---- 3. candidate-queue merge (bitonic top-M) ----
    ocd_ref[...], ocp_ref[...] = merge_topm(
        cd_ref[...], cp_ref[...], dd, new_pay, m, wq)

    # ---- 4. result-set merge (valid only, bitonic top-K) ----
    res_in = jnp.where(valid & dmask, dd, INF)
    res_pay = jnp.where(valid & dmask, nb, -1)
    ord_ref[...], ori_ref[...] = merge_topm(
        rd_ref[...], ri_ref[...], res_in, res_pay, k, wr)


def fused_step_host(q, x, nb, dist_mask, valid, cand_dist, cand_pay,
                    res_dist, res_idx):
    """Host-path (non-TPU) equivalent of the fused kernel.

    Same dataflow — distances, mask, queue merge, result merge in one traced
    region — but the unrolled bitonic networks are replaced by the log-depth
    sorted-merge of kernels.topk (XLA:CPU compiles the full network
    pathologically; see the note there). Distance arithmetic matches the
    dense backend expression exactly, so dense/pallas parity is bitwise on
    CPU up to distance ties.
    """
    m, k = cand_dist.shape[1], res_dist.shape[1]
    dd = jnp.where(dist_mask, sqdist_bdrd(q, x), INF)
    new_pay = jnp.where(dist_mask, nb | (valid.astype(jnp.int32) << 30), -1)

    ns_d, ns_p = sort_kv_f32(dd, new_pay)
    ocd, ocp = bitonic_merge_sorted(cand_dist.astype(jnp.float32), cand_pay,
                                    ns_d, ns_p, m)

    res_in = jnp.where(valid & dist_mask, dd, INF)
    res_pay = jnp.where(valid & dist_mask, nb, -1)
    rs_d, rs_p = sort_kv_f32(res_in, res_pay)
    ordd, ori = bitonic_merge_sorted(res_dist.astype(jnp.float32), res_idx,
                                     rs_d, rs_p, k)
    return ocd, ocp, ordd, ori


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def fused_step(q, x, nb, dist_mask, valid, cand_dist, cand_pay,
               res_dist, res_idx, *, block_b: int = 8, interpret: bool = False):
    """One fused traversal step over a batch of lanes.

    q [B,d], x [B,R,d], nb [B,R] i32, dist_mask/valid [B,R] bool,
    cand_dist [B,M] f32 + cand_pay [B,M] i32 (packed, sorted ascending),
    res_dist [B,K] f32 + res_idx [B,K] i32 (sorted ascending)
    -> (cand_dist, cand_pay, res_dist, res_idx) merged, sorted, best-M/K.
    """
    b, dm = q.shape
    r = x.shape[1]
    m = cand_dist.shape[1]
    k = res_dist.shape[1]
    wq = 1 << (m + r - 1).bit_length()
    wr = 1 << (k + r - 1).bit_length()

    # Interpret mode simulates grid steps sequentially; a single full-batch
    # block keeps the simulated step vectorized. On TPU the block size is a
    # VMEM knob and stays small.
    bb = min(b, 1024) if interpret else min(block_b, b)
    pad = (-b) % bb
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0)))
        x = jnp.pad(x, ((0, pad), (0, 0), (0, 0)))
        nb = jnp.pad(nb, ((0, pad), (0, 0)), constant_values=-1)
        dist_mask = jnp.pad(dist_mask, ((0, pad), (0, 0)))
        valid = jnp.pad(valid, ((0, pad), (0, 0)))
        cand_dist = jnp.pad(cand_dist, ((0, pad), (0, 0)), constant_values=jnp.inf)
        cand_pay = jnp.pad(cand_pay, ((0, pad), (0, 0)), constant_values=-1)
        res_dist = jnp.pad(res_dist, ((0, pad), (0, 0)), constant_values=jnp.inf)
        res_idx = jnp.pad(res_idx, ((0, pad), (0, 0)), constant_values=-1)
    bp = q.shape[0]

    kern = functools.partial(_fused_step_kernel, m=m, k=k, wq=wq, wr=wr)
    ocd, ocp, ordd, ori = pl.pallas_call(
        kern,
        grid=(bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, dm), lambda i: (i, 0)),
            pl.BlockSpec((bb, r, dm), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, r), lambda i: (i, 0)),
            pl.BlockSpec((bb, r), lambda i: (i, 0)),
            pl.BlockSpec((bb, r), lambda i: (i, 0)),
            pl.BlockSpec((bb, m), lambda i: (i, 0)),
            pl.BlockSpec((bb, m), lambda i: (i, 0)),
            pl.BlockSpec((bb, k), lambda i: (i, 0)),
            pl.BlockSpec((bb, k), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, m), lambda i: (i, 0)),
            pl.BlockSpec((bb, m), lambda i: (i, 0)),
            pl.BlockSpec((bb, k), lambda i: (i, 0)),
            pl.BlockSpec((bb, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, m), jnp.float32),
            jax.ShapeDtypeStruct((bp, m), jnp.int32),
            jax.ShapeDtypeStruct((bp, k), jnp.float32),
            jax.ShapeDtypeStruct((bp, k), jnp.int32),
        ],
        interpret=interpret,
    )(q.astype(jnp.float32), x, nb, dist_mask, valid,
      cand_dist.astype(jnp.float32), cand_pay,
      res_dist.astype(jnp.float32), res_idx)
    return ocd[:b], ocp[:b], ordd[:b], ori[:b]
