"""Pallas TPU kernel: sorted-buffer top-M merge (queue maintenance).

Each traversal step merges the sorted candidate buffer [B, M] with R fresh
neighbor distances and keeps the best M. Heaps don't vectorize; instead a
bitonic compare-exchange network (static data flow, pure VPU selects) sorts
the padded concatenation in VMEM. Payloads (packed node-id + expanded/valid
flags) ride through the same selects.

Width = next_pow2(M+R); the network has log²(width) stages of [bB, width]
element-wise ops — for M=512, R=64 that's 55 stages on a 1024-wide block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import _bitonic_stages

INF = float("inf")


def _merge_kernel(dist_ref, pay_ref, nd_ref, np_ref, od_ref, op_ref, *, m, width):
    b = dist_ref.shape[0]
    pad = width - dist_ref.shape[1] - nd_ref.shape[1]
    keys = jnp.concatenate(
        [dist_ref[...], nd_ref[...], jnp.full((b, pad), INF)], axis=1)
    vals = jnp.concatenate(
        [pay_ref[...], np_ref[...], jnp.full((b, pad), -1, jnp.int32)], axis=1)
    idx = jnp.arange(width)
    for j, k in _bitonic_stages(width):
        partner = idx ^ j
        asc = (idx & k) == 0
        k_part = keys[:, partner]
        v_part = vals[:, partner]
        first = idx < partner
        keep_self = jnp.where(
            first,
            jnp.where(asc, keys <= k_part, keys >= k_part),
            jnp.where(asc, k_part <= keys, k_part >= keys),
        )
        keys = jnp.where(keep_self, keys, k_part)
        vals = jnp.where(keep_self, vals, v_part)
    od_ref[...] = keys[:, :m]
    op_ref[...] = vals[:, :m]


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def topm_merge(dist, payload, new_dist, new_payload, *, block_b: int = 8,
               interpret: bool = False):
    """Merge sorted [B,M] + [B,R] -> sorted best-M (dist, payload)."""
    b, m = dist.shape
    r = new_dist.shape[1]
    width = 1 << (m + r - 1).bit_length()
    bb = min(block_b, b)
    pad = (-b) % bb
    if pad:
        dist = jnp.pad(dist, ((0, pad), (0, 0)), constant_values=jnp.inf)
        payload = jnp.pad(payload, ((0, pad), (0, 0)), constant_values=-1)
        new_dist = jnp.pad(new_dist, ((0, pad), (0, 0)), constant_values=jnp.inf)
        new_payload = jnp.pad(new_payload, ((0, pad), (0, 0)), constant_values=-1)
    bp = dist.shape[0]

    kern = functools.partial(_merge_kernel, m=m, width=width)
    od, op = pl.pallas_call(
        kern,
        grid=(bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, m), lambda i: (i, 0)),
            pl.BlockSpec((bb, m), lambda i: (i, 0)),
            pl.BlockSpec((bb, r), lambda i: (i, 0)),
            pl.BlockSpec((bb, r), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, m), lambda i: (i, 0)),
            pl.BlockSpec((bb, m), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, m), jnp.float32),
            jax.ShapeDtypeStruct((bp, m), jnp.int32),
        ],
        interpret=interpret,
    )(dist.astype(jnp.float32), payload, new_dist.astype(jnp.float32), new_payload)
    return od[:b], op[:b]


def pack_payload(idx, expanded, valid):
    """node id (<2^29) + expanded/valid flags into one non-negative int32."""
    p = idx | (expanded.astype(jnp.int32) << 29) | (valid.astype(jnp.int32) << 30)
    return jnp.where(idx < 0, -1, p)


def unpack_payload(p):
    neg = p < 0
    idx = jnp.where(neg, -1, p & ((1 << 29) - 1))
    expanded = jnp.where(neg, False, (p >> 29) & 1 != 0)
    valid = jnp.where(neg, False, (p >> 30) & 1 != 0)
    return idx, expanded, valid
