"""Pallas TPU kernel: sorted-buffer top-M merge (queue maintenance).

Each traversal step merges the sorted candidate buffer [B, M] with R fresh
neighbor distances and keeps the best M. Heaps don't vectorize; instead a
bitonic compare-exchange network (static data flow, pure VPU selects) sorts
the padded concatenation in VMEM. Payloads (packed node-id + expanded/valid
flags) ride through the same selects.

Width = next_pow2(M+R); the network has log²(width) stages of [bB, width]
element-wise ops — for M=512, R=64 that's 55 stages on a 1024-wide block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import _bitonic_stages

INF = float("inf")


def bitonic_topm(keys, vals, m):
    """In-kernel ascending bitonic sort of [b, width] keys (width = pow2)
    carrying int32 vals through the same selects; returns the best-m prefix.

    Shared by the standalone queue-merge kernel below and the fused
    traversal-step kernel (kernels.fused_step), which runs it twice —
    once at queue width, once at result width — inside one VMEM pass.
    """
    width = keys.shape[1]
    idx = jnp.arange(width)
    for j, k in _bitonic_stages(width):
        partner = idx ^ j
        asc = (idx & k) == 0
        k_part = keys[:, partner]
        v_part = vals[:, partner]
        first = idx < partner
        keep_self = jnp.where(
            first,
            jnp.where(asc, keys <= k_part, keys >= k_part),
            jnp.where(asc, k_part <= keys, k_part >= keys),
        )
        keys = jnp.where(keep_self, keys, k_part)
        vals = jnp.where(keep_self, vals, v_part)
    return keys[:, :m], vals[:, :m]


def merge_topm(dist, pay, new_dist, new_pay, m, width):
    """Pad-concatenate a sorted [b,M] buffer with [b,R] fresh entries and
    keep the best m via the bitonic network (width = next_pow2(M+R))."""
    b = dist.shape[0]
    pad = width - dist.shape[1] - new_dist.shape[1]
    keys = jnp.concatenate(
        [dist, new_dist, jnp.full((b, pad), INF)], axis=1)
    vals = jnp.concatenate(
        [pay, new_pay, jnp.full((b, pad), -1, jnp.int32)], axis=1)
    return bitonic_topm(keys, vals, m)


def _merge_kernel(dist_ref, pay_ref, nd_ref, np_ref, od_ref, op_ref, *, m, width):
    od_ref[...], op_ref[...] = merge_topm(
        dist_ref[...], pay_ref[...], nd_ref[...], np_ref[...], m, width)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def topm_merge(dist, payload, new_dist, new_payload, *, block_b: int = 8,
               interpret: bool = False):
    """Merge sorted [B,M] + [B,R] -> sorted best-M (dist, payload)."""
    b, m = dist.shape
    r = new_dist.shape[1]
    width = 1 << (m + r - 1).bit_length()
    bb = min(block_b, b)
    pad = (-b) % bb
    if pad:
        dist = jnp.pad(dist, ((0, pad), (0, 0)), constant_values=jnp.inf)
        payload = jnp.pad(payload, ((0, pad), (0, 0)), constant_values=-1)
        new_dist = jnp.pad(new_dist, ((0, pad), (0, 0)), constant_values=jnp.inf)
        new_payload = jnp.pad(new_payload, ((0, pad), (0, 0)), constant_values=-1)
    bp = dist.shape[0]

    kern = functools.partial(_merge_kernel, m=m, width=width)
    od, op = pl.pallas_call(
        kern,
        grid=(bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, m), lambda i: (i, 0)),
            pl.BlockSpec((bb, m), lambda i: (i, 0)),
            pl.BlockSpec((bb, r), lambda i: (i, 0)),
            pl.BlockSpec((bb, r), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, m), lambda i: (i, 0)),
            pl.BlockSpec((bb, m), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, m), jnp.float32),
            jax.ShapeDtypeStruct((bp, m), jnp.int32),
        ],
        interpret=interpret,
    )(dist.astype(jnp.float32), payload, new_dist.astype(jnp.float32), new_payload)
    return od[:b], op[:b]


# --------------------------------------------------------------------------
# host fallback (non-TPU): log-depth merge instead of the unrolled network
# --------------------------------------------------------------------------
# XLA:CPU compile time explodes exponentially in the depth of the unrolled
# compare-exchange chain (measured ~1.7× per stage), so the full log²-stage
# network above is TPU-only (Mosaic handles it fine). The host path exploits
# that the *buffer* is already sorted: stable-sort only the R fresh entries
# (uint32 monotone bitcast — squared distances are non-negative — makes the
# XLA sort an integer sort), then a single log(width)-stage bitonic *merge*
# phase combines the two sorted runs. Reshape-based pair exchange keeps every
# stage pure elementwise min/max — no gathers, which XLA:CPU executes
# scalar-slow. Exact stable-argsort semantics up to distance ties.


def sort_kv_f32(keys, vals):
    """Stable ascending sort of non-negative f32 keys [B,R] carrying vals."""
    k_u32 = jax.lax.bitcast_convert_type(keys.astype(jnp.float32), jnp.uint32)
    ks, vs = jax.lax.sort((k_u32, vals), dimension=1, num_keys=1, is_stable=True)
    return jax.lax.bitcast_convert_type(ks, jnp.float32), vs


def bitonic_merge_phase(keys, pos, lanes):
    """One full bitonic merge phase (strides w/2 … 1, all ascending) over a
    row-bitonic [B, w] block under the lexicographic total order (key, pos).

    `lanes` is a tuple of extra [B, w] arrays riding the same selects.
    Because `pos` participates in the comparison, the phase realizes a
    *total* order whenever the pos values within a row are distinct — the
    property the cross-shard merge (distributed.merge) uses to make the
    merged result independent of the merge-tree shape, bit for bit.
    """
    b, w = keys.shape
    j = w // 2
    while j >= 1:
        kk = keys.reshape(b, w // (2 * j), 2, j)
        pp = pos.reshape(b, w // (2 * j), 2, j)
        ll = [x.reshape(b, w // (2 * j), 2, j) for x in lanes]
        lo_k, hi_k = kk[:, :, 0, :], kk[:, :, 1, :]
        lo_p, hi_p = pp[:, :, 0, :], pp[:, :, 1, :]
        keep = (lo_k < hi_k) | ((lo_k == hi_k) & (lo_p <= hi_p))
        keys = jnp.stack([jnp.where(keep, lo_k, hi_k),
                          jnp.where(keep, hi_k, lo_k)], axis=2).reshape(b, w)
        pos = jnp.stack([jnp.where(keep, lo_p, hi_p),
                         jnp.where(keep, hi_p, lo_p)], axis=2).reshape(b, w)
        lanes = tuple(
            jnp.stack([jnp.where(keep, x[:, :, 0, :], x[:, :, 1, :]),
                       jnp.where(keep, x[:, :, 1, :], x[:, :, 0, :])],
                      axis=2).reshape(b, w)
            for x in ll)
        j //= 2
    return keys, pos, lanes


def bitonic_merge_sorted(old_d, old_p, ns_d, ns_p, m):
    """Merge sorted asc [B,M0] with sorted asc [B,R] -> best m, log-depth.

    The inf-padded concat `old ++ pad ++ reversed(new)` is bitonic, so a
    single merge phase (strides w/2 … 1, all ascending) sorts it. A carried
    position lane breaks key ties lexicographically in concat order (old
    entries first, then new in their sorted order, pads last), making the
    result bitwise-identical to a stable argsort over `[old | new]` — ties
    included. (The TPU kernel's full network has no such tiebreak; on real
    ties its payload order may differ.)
    """
    b, m0 = old_d.shape
    r = ns_d.shape[1]
    w = 1 << (m0 + r - 1).bit_length()
    pad = w - m0 - r
    keys = jnp.concatenate(
        [old_d, jnp.full((b, pad), INF, jnp.float32), ns_d[:, ::-1]], axis=1)
    vals = jnp.concatenate(
        [old_p, jnp.full((b, pad), -1, jnp.int32), ns_p[:, ::-1]], axis=1)
    pos = jnp.broadcast_to(
        jnp.concatenate([jnp.arange(m0, dtype=jnp.int32),
                         jnp.arange(m0 + r, w, dtype=jnp.int32),  # pads last
                         jnp.arange(m0 + r - 1, m0 - 1, -1, dtype=jnp.int32)]),
        (b, w))
    keys, _, (vals,) = bitonic_merge_phase(keys, pos, (vals,))
    return keys[:, :m], vals[:, :m]


def topm_merge_host(dist, payload, new_dist, new_payload):
    """Host-path equivalent of `topm_merge` (sorted [B,M] + raw [B,R])."""
    ns_d, ns_p = sort_kv_f32(new_dist, new_payload)
    return bitonic_merge_sorted(dist.astype(jnp.float32), payload, ns_d, ns_p,
                                dist.shape[1])


def pack_payload(idx, expanded, valid):
    """node id (<2^29) + expanded/valid flags into one non-negative int32."""
    p = idx | (expanded.astype(jnp.int32) << 29) | (valid.astype(jnp.int32) << 30)
    return jnp.where(idx < 0, -1, p)


def unpack_payload(p):
    neg = p < 0
    idx = jnp.where(neg, -1, p & ((1 << 29) - 1))
    expanded = jnp.where(neg, False, (p >> 29) & 1 != 0)
    valid = jnp.where(neg, False, (p >> 30) & 1 != 0)
    return idx, expanded, valid
