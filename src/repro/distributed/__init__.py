from repro.distributed.sharding import (
    ShardingRules,
    DEFAULT_RULES,
    spec_for,
    tree_shardings,
    batch_spec,
)

__all__ = ["ShardingRules", "DEFAULT_RULES", "spec_for", "tree_shardings",
           "batch_spec"]
