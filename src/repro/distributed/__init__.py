from repro.distributed.sharding import (
    ShardingRules,
    DEFAULT_RULES,
    INDEX_AXIS,
    spec_for,
    tree_shardings,
    batch_spec,
    search_mesh_2d,
)
from repro.distributed.fault_tolerance import (
    best_mesh_shape,
    best_search_mesh_shape,
)
from repro.distributed.merge import (
    butterfly_merge,
    merge_sorted_pools,
    merge_stacked,
    pool_positions,
)

__all__ = ["ShardingRules", "DEFAULT_RULES", "INDEX_AXIS", "spec_for",
           "tree_shardings", "batch_spec", "search_mesh_2d",
           "best_mesh_shape", "best_search_mesh_shape", "butterfly_merge",
           "merge_sorted_pools", "merge_stacked", "pool_positions"]
