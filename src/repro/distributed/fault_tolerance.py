"""Fault tolerance & straggler mitigation for 1000+ node deployments.

Three mechanisms:

1. **Elastic mesh selection** — after a node failure the job restarts on
   whatever device count survives; `best_mesh_shape` picks the largest
   usable (pod, data, model) factorization and `CheckpointManager.restore`
   reshards the state onto it (see train/checkpoint.py).

2. **Step watchdog** — `StepMonitor` tracks per-step wall times; a step
   exceeding `factor` × trailing-median flags a straggler event. On a real
   cluster this triggers the preplanned-rollback path (restore from the
   last checkpoint minus the slow host); here it drives tests and logs.

3. **Search-tail clamping** — the paper's own tail-latency story applied
   at the batch level: in lockstep filtered search, one hard query holds
   every lane of its batch. `clamp_budgets` caps per-lane predicted budgets
   at a batch quantile so the predicted tail is bounded; the clamped lanes
   are reported so the serving layer can re-queue them into a dedicated
   "hard query" batch (two-tier scheduling) instead of stalling the fleet.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np


def best_mesh_shape(n_devices: int, model_parallel: int = 16,
                    pod_size: int = 256) -> tuple[tuple, tuple]:
    """Largest (pod, data, model) mesh using ≤ n_devices.

    model is fixed by the arch sharding (TP degree); pods are whole
    multiples of pod_size; leftover chips form the data axis.
    """
    if n_devices >= 2 * pod_size:
        pods = n_devices // pod_size
        data = pod_size // model_parallel
        return (pods, data, model_parallel), ("pod", "data", "model")
    model = min(model_parallel, n_devices)
    data = max(1, n_devices // model)
    return (data, model), ("data", "model")


def best_search_mesh_shape(n_devices: int, n_shards: int,
                           ) -> tuple[tuple, tuple]:
    """Largest valid 2-D (data, index) search mesh using ≤ n_devices.

    The index axis must own whole shards (its size must divide `n_shards`)
    or per-shard traversal state cannot be placed; elastic restart after a
    node loss therefore picks index = the largest divisor of the surviving
    device count that also divides the shard count, and gives the rest to
    batch parallelism. Indivisible counts degrade gracefully: with 7
    devices and 4 shards the index axis collapses to 1 (every device holds
    all shards' share of the batch work) instead of wedging the restart.
    """
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    index = max(i for i in range(1, min(n_devices, n_shards) + 1)
                if n_devices % i == 0 and n_shards % i == 0)
    return (n_devices // index, index), ("data", "index")


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    median: float


class StepMonitor:
    def __init__(self, factor: float = 3.0, window: int = 32):
        self.factor = factor
        self.window = window
        self.durations: list[float] = []
        self.events: list[StragglerEvent] = []
        self._t0 = None
        self._step = 0

    def start(self):
        self._t0 = time.monotonic()

    def stop(self) -> StragglerEvent | None:
        dt = time.monotonic() - self._t0
        self._step += 1
        ev = self.observe(self._step, dt)
        return ev

    def observe(self, step: int, duration: float) -> StragglerEvent | None:
        hist = self.durations[-self.window:]
        self.durations.append(duration)
        if len(hist) >= 8:
            med = float(np.median(hist))
            if duration > self.factor * med:
                ev = StragglerEvent(step=step, duration=duration, median=med)
                self.events.append(ev)
                return ev
        return None


def clamp_budgets(budgets: np.ndarray, quantile: float = 0.95,
                  floor: int = 1) -> tuple[np.ndarray, np.ndarray]:
    """Cap per-lane search budgets at the batch quantile.

    Returns (clamped budgets, mask of lanes that were clamped — candidates
    for the hard-query re-queue).
    """
    budgets = np.asarray(budgets)
    cap = max(float(np.quantile(budgets, quantile)), floor)
    clamped = np.minimum(budgets, cap).astype(budgets.dtype)
    return clamped, budgets > cap
