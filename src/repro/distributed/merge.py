"""Log-depth cross-shard top-k merge for index-axis-sharded search.

Each index shard finishes a traversal holding sorted per-shard pools
(result set [B, K], candidate queue [B, M]) over shard-*local* node ids.
This module combines S such pools into the global top-m — the operation
both execution paths of the sharded engine share:

  * host / single-device: `merge_stacked` — a pairwise merge tree over the
    stacked [B, S, W] pools, ⌈log2 S⌉ rounds;
  * under `shard_map`: `butterfly_merge` — the same pairwise primitive over
    `ppermute` XOR-butterfly rounds (power-of-two index axis) or one
    `all_gather` + in-device tree (any axis size), log-depth either way.

Bitwise determinism is the whole design. Every pool entry carries an
explicit *position* lane — its slot in the virtual concatenation of the S
pools (pos = shard·W + slot), unique across the union. The pairwise
primitive (`merge_sorted_pools`, a single bitonic merge phase from
kernels.topk with the pos lane in the comparator) keeps the best m under
the lexicographic total order (dist, pos). A top-m under a total order is
associative and commutative, so *any* merge tree — the host loop, the
device butterfly, or a flat host sort of the concatenated pools — produces
THE unique answer: the first m entries of the stable-by-position sort of
the union, ties included. That is what lets the bench assert the sharded
shard_map path bit-identical to the single-device loop path.

Distances are moved, never recomputed, so no float reassociation can leak
in through the merge itself.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.topk import bitonic_merge_phase

INF = jnp.float32(jnp.inf)
#: pos value for width padding — sorts after every real entry (real pos are
#: small non-negative slot indices)
PAD_POS = jnp.int32(2**31 - 1)


def merge_plan(n_shards: int) -> tuple[int, int]:
    """(pairwise merges, tree depth) for an S-way cross-shard reduction.

    The host tree in `merge_stacked` and the device butterfly both perform
    S−1 pairwise pool merges over ⌈log2 S⌉ rounds — the numbers EXPLAIN
    attributes to the merge stage. S ≤ 1 merges nothing: (0, 0)."""
    if n_shards <= 1:
        return 0, 0
    return n_shards - 1, (n_shards - 1).bit_length()


def pool_positions(width: int, shard0, n_shards: int, b: int):
    """Position lanes [B, n_shards, width] for pools of global shard ids
    shard0 … shard0+n_shards-1: pos = global_shard · width + slot.

    `shard0` may be a traced scalar (the shard_map body offsets by
    `axis_index · shards_per_device`)."""
    s = jnp.arange(n_shards, dtype=jnp.int32) + jnp.int32(shard0)
    pos = s[:, None] * jnp.int32(width) + jnp.arange(width, dtype=jnp.int32)
    return jnp.broadcast_to(pos[None], (b, n_shards, width))


def merge_sorted_pools(d_a, p_a, o_a, d_b, p_b, o_b, m: int):
    """Merge two pools sorted ascending by (dist, pos); keep the best m.

    d_* [B, W*] f32, p_* int32 payloads, o_* int32 positions (unique across
    both pools). `A ++ inf-pad ++ reversed(B)` is bitonic under (dist, pos)
    — pads carry (inf, PAD_POS), ≥ every real entry — so one log-depth
    bitonic merge phase sorts it. Returns (dist, payload, pos) [B, m].
    """
    b, wa = d_a.shape
    wb = d_b.shape[1]
    w = 1 << (wa + wb - 1).bit_length()
    pad = w - wa - wb
    keys = jnp.concatenate(
        [d_a, jnp.full((b, pad), INF, jnp.float32), d_b[:, ::-1]], axis=1)
    pos = jnp.concatenate(
        [o_a, jnp.full((b, pad), PAD_POS, jnp.int32), o_b[:, ::-1]], axis=1)
    pay = jnp.concatenate(
        [p_a, jnp.full((b, pad), -1, jnp.int32), p_b[:, ::-1]], axis=1)
    keys, pos, (pay,) = bitonic_merge_phase(keys, pos, (pay,))
    return keys[:, :m], pay[:, :m], pos[:, :m]


def merge_stacked(dists, pays, m: int, shard0: int = 0, pos=None):
    """Merge stacked per-shard pools [B, S, W] → global best m [B, m].

    Pairwise merge tree over the shard axis (⌈log2 S⌉ rounds). `shard0`
    offsets the position lane so a device holding a contiguous slice of
    shards composes with the cross-device butterfly on the same global
    position space. Returns (dist, payload, pos).
    """
    b, s, w = dists.shape
    if pos is None:
        pos = pool_positions(w, shard0, s, b)
    pools = [(dists[:, i], pays[:, i], pos[:, i]) for i in range(s)]
    while len(pools) > 1:
        nxt = []
        for i in range(0, len(pools) - 1, 2):
            a, c = pools[i], pools[i + 1]
            nxt.append(merge_sorted_pools(*a, *c, m))
        if len(pools) % 2:
            d, p, o = pools[-1]
            nxt.append((d[:, :m], p[:, :m], o[:, :m]) if d.shape[1] > m
                       else (d, p, o))
        pools = nxt
    d, p, o = pools[0]
    if d.shape[1] > m:
        d, p, o = d[:, :m], p[:, :m], o[:, :m]
    return d, p, o


def butterfly_merge(d, p, o, m: int, axis_name: str, axis_size: int):
    """Cross-device merge of per-device pools under shard_map, log-depth.

    d/p/o [B, m] — each device's already locally-merged pool (sorted by
    (dist, pos), positions globally unique). Power-of-two axes run the
    XOR butterfly: round r exchanges pools with partner `i ^ 2^r` via
    `ppermute` and merges, so after log2(S) rounds every device holds the
    identical global top-m. Other sizes fall back to one `all_gather` +
    the in-device merge tree (same result, one bulkier collective).
    """
    if axis_size == 1:
        return d, p, o
    if axis_size & (axis_size - 1) == 0:
        for r in range(axis_size.bit_length() - 1):
            perm = [(i, i ^ (1 << r)) for i in range(axis_size)]
            pd = jax.lax.ppermute(d, axis_name, perm)
            pp = jax.lax.ppermute(p, axis_name, perm)
            po = jax.lax.ppermute(o, axis_name, perm)
            # operand order is irrelevant: the merge output is the unique
            # (dist, pos)-sorted top-m of the union, so both partners of a
            # pair compute byte-identical pools without coordinating
            d, p, o = merge_sorted_pools(d, p, o, pd, pp, po, m)
        return d, p, o
    ad = jax.lax.all_gather(d, axis_name)          # [S, B, m]
    ap = jax.lax.all_gather(p, axis_name)
    ao = jax.lax.all_gather(o, axis_name)
    return merge_stacked(jnp.moveaxis(ad, 0, 1), jnp.moveaxis(ap, 0, 1), m,
                         pos=jnp.moveaxis(ao, 0, 1))
