"""Logical-axis → mesh-axis sharding rules (divisibility-aware).

The params / caches carry *logical* axis names ("embed", "heads", "vocab",
"expert", "batch", "seq", ...). This module maps them onto the physical mesh:

  TP    over "model"  — heads / kv_heads / mlp / vocab / expert (EP)
  FSDP  over "data"   — the "embed" axis of weight matrices
  DP    over ("pod","data") — the "batch" axis of inputs/activations/caches
  SP    over "data"   — "seq" fallback when batch doesn't divide (long_500k)

Rules are *candidate chains*: each logical name lists mesh axes to try in
order; a candidate is taken only if (a) the dim divides evenly and (b) the
mesh axis isn't already used by another dim of the same tensor. This is what
lets kv_heads=8 fall through to head_dim sharding on a 16-way model axis,
and batch=1 fall through to sequence sharding.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


# Each logical axis name maps to a list of candidates; a candidate is either
# a mesh-axis name or a tuple of mesh-axis names (sharded jointly).
@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: tuple

    def candidates(self, logical: str | None):
        if logical is None:
            return ()
        return dict(self.rules).get(logical, ())


#: mesh axis the sharded search engine partitions *index* data over
#: (graph neighbor lists, quantized codes, attribute bundles). Composes
#: with the batch axis ("data") as a 2-D (batch × index) search mesh.
INDEX_AXIS = "index"

DEFAULT_RULES = ShardingRules(
    rules=(
        ("vocab", ("model",)),
        ("shard", (INDEX_AXIS,)),     # per-shard index data (search scale-out)
        ("embed", ("data",)),         # FSDP
        ("heads", ("model",)),
        ("kv_heads", ("model",)),
        ("head_dim", ("model",)),     # fallback when kv_heads can't take model
        ("mlp", ("model",)),
        ("expert", ("model",)),       # EP
        ("capacity", (("data",),)),   # MoE buffer token dim (EP × DP)
        ("layers", ()),
        ("batch", (("pod", "data"), ("data",),)),
        # seq falls through to "model" when DP consumed the data axis:
        # decode caches become sequence-parallel (flash-decoding style --
        # per-token collectives shrink from cache-sized AG to score-sized AR)
        ("seq", (("pod", "data"), ("data",), ("model",))),
        ("embed2", ()),
    )
)


def _axis_size(mesh: Mesh, cand) -> int:
    if isinstance(cand, tuple):
        return int(np.prod([mesh.shape[a] for a in cand]))
    return mesh.shape[cand]


def _mesh_axes(cand):
    return cand if isinstance(cand, tuple) else (cand,)


def spec_for(mesh: Mesh, shape, logical_axes, rules: ShardingRules = DEFAULT_RULES,
             ) -> PartitionSpec:
    """Build a PartitionSpec for one array given its logical axes."""
    assert len(shape) == len(logical_axes), (shape, logical_axes)
    used: set = set()
    out = []
    for dim, name in zip(shape, logical_axes):
        placed = None
        for cand in rules.candidates(name):
            axes = _mesh_axes(cand)
            if any(a not in mesh.shape for a in axes):
                continue
            if any(a in used for a in axes):
                continue
            if dim % _axis_size(mesh, cand) != 0:
                continue
            # singleton tuples denote the same sharding as the bare axis name
            # but PartitionSpec(('data',)) != PartitionSpec('data') — unwrap.
            placed = cand[0] if isinstance(cand, tuple) and len(cand) == 1 else cand
            used.update(axes)
            break
        out.append(placed)
    # trim trailing Nones for cleanliness
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


def tree_shardings(mesh: Mesh, value_tree, axes_tree,
                   rules: ShardingRules = DEFAULT_RULES):
    """Map (values, logical-axes) trees -> NamedSharding tree."""

    def one(v, ax):
        return NamedSharding(mesh, spec_for(mesh, v.shape, ax, rules))

    # value_tree's array leaves define the structure; axes_tree's tuple
    # leaves are matched "up to" that structure by jax.tree.map.
    return jax.tree.map(one, value_tree, axes_tree)


def batch_spec(mesh: Mesh, global_batch: int,
               rules: ShardingRules = DEFAULT_RULES) -> PartitionSpec:
    """Sharding for a [B, ...] input batch dim (replicate if indivisible)."""
    for cand in rules.candidates("batch"):
        axes = _mesh_axes(cand)
        if any(a not in mesh.shape for a in axes):
            continue
        if global_batch % _axis_size(mesh, cand) == 0:
            if isinstance(cand, tuple) and len(cand) == 1:
                cand = cand[0]
            return PartitionSpec(cand)
    return PartitionSpec(None)


def search_mesh_2d(n_shards: int, devices=None) -> Mesh | None:
    """2-D ("data", "index") mesh for index-axis-sharded search.

    The index axis gets the largest device divisor that also divides
    `n_shards` (each index device then owns n_shards/index whole shards);
    the rest of the devices parallelize the batch. Returns None on a
    single device — the sharded engine's loop path needs no mesh.
    """
    from repro.distributed.fault_tolerance import best_search_mesh_shape

    devices = list(jax.devices()) if devices is None else list(devices)
    if len(devices) <= 1:
        return None
    shape, names = best_search_mesh_shape(len(devices), n_shards)
    n_used = int(np.prod(shape))
    return Mesh(np.asarray(devices[:n_used]).reshape(shape), names)


def _ambient_mesh():
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def constrain(x, logical_axes, rules: ShardingRules = DEFAULT_RULES):
    """with_sharding_constraint by logical axis names, if a mesh is active.

    No-op outside a `with mesh:` context (CPU smoke tests). This is how the
    model pins activation shardings (batch over DP, seq over SP fallback)
    so GSPMD doesn't drift into replicated-batch weight-stationary layouts.
    """
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    import jax

    spec = spec_for(mesh, x.shape, logical_axes, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
