"""Memory tiering for the full-precision vector store.

With a quantized traversal (int8 / PQ), device memory only needs the
compressed codes — the float32 vectors are touched exactly once per query,
by the terminal rerank, and only for the ≤ (M + K) pool rows that survived.
That access pattern (tiny, batched, index-driven) is what lets the float32
store leave the device entirely:

  VMEM   per-step traversal working set (queue merge, persistent kernel)
  HBM    compressed codes + norms + err, graph, packed attributes
  host   float32 vectors — `HostVectorStore`, streamed per rerank batch

`HostVectorStore` keeps the primary copy as host numpy and *attempts* a
`pinned_host` memory-kind placement so accelerator backends with memory
tiers (TPU) DMA the gathered rows directly; backends without the tier
(this container's XLA:CPU) fall back to a numpy row gather + one
host→device transfer of the [B, P, d] result — semantically identical,
bitwise identical rows. Either way the device never holds the [N, d]
float32 array, which is the term that bounded N before tiering
(float32 d=64 at 10M rows = 2.4 GiB vs 56 B/vec PQ = 0.5 GiB).

`DeviceVectorStore` is the degenerate tier for small corpora and float32
engines — same gather interface, vectors device-resident.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


class DeviceVectorStore:
    """Device-resident float32 vector tier (the pre-tiering layout)."""

    kind = "device"

    def __init__(self, vectors):
        self.vectors = jnp.asarray(vectors, jnp.float32)

    @property
    def shape(self):
        return tuple(self.vectors.shape)

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape)) * 4

    def gather(self, idx) -> jax.Array:
        """Rows `idx` [B, P] (negative ids clipped to row 0 — callers mask
        by validity, matching `exact_rerank`'s clip-then-mask contract)."""
        return self.vectors[jnp.maximum(jnp.asarray(idx), 0)]


class HostVectorStore:
    """Host-memory float32 vector tier with batched streaming gather."""

    kind = "host"

    def __init__(self, vectors, chunk_rows: int = 1 << 18):
        self._np = np.ascontiguousarray(np.asarray(vectors), np.float32)
        self._chunk = int(chunk_rows)
        self._pinned = self._try_pin()

    def _try_pin(self):
        """Best-effort pinned-host placement for DMA-capable backends.

        jax memory kinds are backend-dependent; a failed placement (XLA:CPU
        has no pinned_host tier) silently selects the numpy gather path —
        the returned rows are the same bytes either way.
        """
        try:
            dev = jax.devices()[0]
            sharding = jax.sharding.SingleDeviceSharding(
                dev, memory_kind="pinned_host")
            arr = jax.device_put(self._np, sharding)
            arr.block_until_ready()
            return arr
        except Exception:
            return None

    @property
    def shape(self):
        return tuple(self._np.shape)

    @property
    def nbytes(self) -> int:
        return self._np.nbytes

    def gather(self, idx) -> jax.Array:
        """Stream rows `idx` [B, P] to device; negative ids clip to row 0.

        P is the rerank pool width (≤ M + K), so the transferred slab is
        B·P·d floats per batch — independent of N. Very large requests
        stream in `chunk_rows` row-chunks to bound peak host scratch.
        """
        if self._pinned is not None:
            return self._pinned[jnp.maximum(jnp.asarray(idx), 0)]
        idx = np.maximum(np.asarray(idx), 0)
        flat = idx.reshape(-1)
        if flat.size <= self._chunk:
            rows = self._np[flat]
        else:
            rows = np.empty((flat.size, self._np.shape[1]), np.float32)
            for s in range(0, flat.size, self._chunk):
                e = min(s + self._chunk, flat.size)
                rows[s:e] = self._np[flat[s:e]]
        return jnp.asarray(rows.reshape(*idx.shape, self._np.shape[1]))


def as_vector_store(vectors, tier: str = "device"):
    """Construct the tier named by `tier` ("device" | "host")."""
    if tier == "device":
        return DeviceVectorStore(vectors)
    if tier == "host":
        return HostVectorStore(vectors)
    raise ValueError(f"unknown vector tier {tier!r} "
                     "(expected 'device' or 'host')")
