"""Vector-quantization codecs: int8 scalar quantization + product quantization.

The paper's adaptive termination reduces the *number* of distance
computations (NDC); this layer reduces the *cost and bandwidth of each one*.
Both codecs replace the float32 vector store in the traversal hot loop with
a compressed code store and an asymmetric distance (ADC: the query stays
full precision on the host side, the database side is compressed):

  int8  per-dimension affine quantization. A vector decodes as
        x̂ = zero + scale ⊙ c with c ∈ [-127, 127]^d (int8). The distance
        ‖q − x̂‖² = ‖q − zero‖² + ‖scale ⊙ c‖² − 2·(q − zero)⊙scale · c
        needs one integer dot per candidate: the query factor
        qs = (q − zero) ⊙ scale is itself quantized once per query to
        int8 (one per-query scale sq), so the per-candidate work is an
        int8×int8 → int32 dot — the MXU-native low-precision path — plus
        two precomputed scalars (‖q − zero‖² per query, ‖scale ⊙ c‖² per
        node). ~4× less index bandwidth per NDC.

  pq    multi-level product quantization (residual / additive PQ). d splits
        into S subspaces; level 0 k-means-quantizes each subspace
        (Kc ≤ 256 centroids), and each further level quantizes the
        *residual* left by the previous ones, so a vector is S·L bytes and
        reconstructs as the sum of L centroids per subspace. Reconstruction
        error falls geometrically in L (≈ Kc^(2/dsub) per level), which is
        what keeps compressed-domain *routing* faithful enough for
        matched-budget recall. Distances use the inner-product ADC form —
        d̂ = ‖q‖² + ‖x̂‖² − 2·Σ_sl lut[sl, code_sl] with
        lut[sl, c] = q_s · centroid — which stays a plain per-code table
        lookup for any L (the cross-level terms live in the stored ‖x̂‖²,
        one f32 per node). L=1 is classical PQ.

Both codecs also store a per-node reconstruction error ‖x − x̂‖² (the
compressed-distance bias scale). The traversal accumulates it over
inspected nodes, and the feature extractor turns it into the
`quant_err_*` probe features — how noisy the compressed distances a lane
has seen are, relative to the distances that matter — which keeps the GBDT
cost model calibrated under quantization.

Parity contract: `quant_dist` is the single source of the compressed
distance expression. The dense backend and the fused kernel's host path
both call it, so dense/pallas top-k and NDC agree exactly on CPU (the
int8 dot is integer arithmetic — exact — and the float tail is the same
traced expression). The TPU kernel body re-states the same arithmetic and
is validated against it in interpret mode (tests/test_quant.py).
"""
from __future__ import annotations

import functools
import hashlib
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

_EPS = 1e-12


# ---------------------------------------------------------------- indices ----
class Int8Index(NamedTuple):
    """Device-resident int8 scalar-quantized vector store."""

    codes: jax.Array   # [N, d] int8
    scale: jax.Array   # [d] f32 — dequant step per dimension
    zero: jax.Array    # [d] f32 — per-dimension zero point
    norms: jax.Array   # [N] f32 — ‖scale ⊙ codes‖² (the ADC xn term)
    err: jax.Array     # [N] f32 — ‖x − x̂‖² reconstruction error


class PQIndex(NamedTuple):
    """Device-resident (multi-level) product-quantized vector store.

    The L levels are flattened level-major into one slot axis of size S·L
    (slot l·S + s holds level l of subspace s), so the per-step gather and
    the ADC lookup sum are shape-identical to classical PQ.
    """

    codes: jax.Array      # [N, S·L] uint8 — per-slot centroid ids
                          # (slot l·S + s = level l of subspace s)
    codebooks: jax.Array  # [L, S, Kc, dsub] f32
    norms: jax.Array      # [N] f32 — ‖x̂‖² (the ADC xn term)
    err: jax.Array        # [N] f32 — ‖x − x̂‖² reconstruction error


class Int8Prep(NamedTuple):
    """Per-query ADC state for the int8 codec (built once per search)."""

    qq: jax.Array  # [B, d] int8 — quantized (q − zero) ⊙ scale
    sq: jax.Array  # [B] f32 — per-query dequant step for qq
    qn: jax.Array  # [B] f32 — ‖q − zero‖²


class PQPrep(NamedTuple):
    """Per-query ADC state for the PQ codec: inner-product lookup table."""

    lut: jax.Array  # [B, S·L, Kc] f32 — q_s · centroid (slot l·S + s)
    qn: jax.Array   # [B] f32 — ‖q‖²


class QuantGather(NamedTuple):
    """One traversal step's gathered compressed data, handed to the backend.

    `codes` is [B, R, d] int8 (int8 codec) or [B, R, S·L] int32 (pq —
    widened after the gather; the resident store stays uint8). `norms` is
    [B, R] f32: ‖scale⊙c‖² for int8, ‖x̂‖² for pq.
    """

    prep: Any              # Int8Prep | PQPrep
    codes: jax.Array
    norms: jax.Array


# --------------------------------------------------------------- int8 SQ ----
def train_int8(vectors) -> tuple[jax.Array, jax.Array]:
    """Per-dimension affine parameters (scale, zero) from a training sample."""
    v = jnp.asarray(vectors, jnp.float32)
    lo = v.min(axis=0)
    hi = v.max(axis=0)
    scale = jnp.maximum((hi - lo) / 254.0, _EPS)
    zero = (hi + lo) / 2.0
    return scale, zero


@jax.jit
def encode_int8(scale, zero, vectors):
    """vectors [N, d] → (codes int8 [N, d], norms [N], err [N]).

    jitted: encoding is ~6 elementwise ops over [N, d]; eager per-op
    dispatch (~0.7 ms/op on this CPU) would dominate index build for the
    many small encodes in tests and serving bring-up.
    """
    v = jnp.asarray(vectors, jnp.float32)
    c = jnp.clip(jnp.round((v - zero) / scale), -127, 127)
    dec = c * scale                       # x̂ − zero
    norms = jnp.sum(dec * dec, axis=1)
    resid = (v - zero) - dec
    err = jnp.sum(resid * resid, axis=1)
    return c.astype(jnp.int8), norms, err


@jax.jit
def prep_int8(index: Int8Index, queries) -> Int8Prep:
    """Quantize the per-query ADC factor qs = (q − zero) ⊙ scale to int8."""
    q = jnp.asarray(queries, jnp.float32)
    qz = q - index.zero[None, :]
    qs = qz * index.scale[None, :]
    sq = jnp.maximum(jnp.max(jnp.abs(qs), axis=1) / 127.0, _EPS)
    qq = jnp.clip(jnp.round(qs / sq[:, None]), -127, 127).astype(jnp.int8)
    qn = jnp.sum(qz * qz, axis=1)
    return Int8Prep(qq=qq, sq=sq, qn=qn)


def _int8_assemble(prep: Int8Prep, norms, dot):
    """The int8 ADC float tail: qn + xn − 2·sq·dot, clamped ≥ 0.

    Single source of the rescale/clamp for every int8 distance layout —
    the per-step gathered form (`adc_int8`) and the corpus-blocked
    brute-force form share it, so the two can never drift apart.
    """
    d = prep.qn[:, None] + norms - 2.0 * prep.sq[:, None] * dot.astype(jnp.float32)
    return jnp.maximum(d, 0.0)


def adc_int8(prep: Int8Prep, codes_g, norms_g):
    """Compressed squared L2: prep + gathered codes [B,R,d] / norms [B,R].

    The dot is int8×int8 → int32 (exact integer arithmetic, MXU-native on
    TPU); only the final rescale is float.
    """
    dot = jax.lax.dot_general(
        prep.qq[:, None, :], codes_g,
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.int32,
    )[:, 0, :]
    return _int8_assemble(prep, norms_g, dot)


def decode_int8(index: Int8Index, codes=None):
    """codes int8 [..., d] → float32 reconstruction x̂."""
    c = index.codes if codes is None else codes
    return index.zero + c.astype(jnp.float32) * index.scale


# -------------------------------------------------------------------- PQ ----
def _kmeans(x, cent0, iters: int):
    """Lloyd iterations on one subspace: x [n, dsub], cent0 [Kc, dsub]."""

    def step(_, cent):
        d = (jnp.sum(x * x, axis=1)[:, None]
             + jnp.sum(cent * cent, axis=1)[None, :]
             - 2.0 * x @ cent.T)
        assign = jnp.argmin(d, axis=1)
        onehot = jax.nn.one_hot(assign, cent.shape[0], dtype=jnp.float32)
        counts = onehot.sum(axis=0)
        sums = onehot.T @ x
        return jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts, 1.0)[:, None],
                         cent)

    return jax.lax.fori_loop(0, iters, step, cent0)


_kmeans_jit = jax.jit(_kmeans, static_argnames=("iters",))


def train_pq(vectors, n_subspaces: int, n_centroids: int = 256,
             iters: int = 20, seed: int = 0, n_levels: int = 1) -> jax.Array:
    """Residual k-means codebooks [L, S, Kc, dsub] from a training sample.

    Level 0 quantizes the subspace vectors; level l > 0 quantizes the
    residual left by levels < l (additive quantization).
    """
    v = np.asarray(vectors, np.float32)
    n, d = v.shape
    if d % n_subspaces:
        raise ValueError(f"dim {d} not divisible by {n_subspaces} subspaces")
    if not 2 <= n_centroids <= 256:
        raise ValueError(f"n_centroids must be in [2, 256] (uint8 codes), "
                         f"got {n_centroids}")
    if n_levels < 1:
        raise ValueError(f"n_levels must be >= 1, got {n_levels}")
    dsub = d // n_subspaces
    rng = np.random.default_rng(seed)
    xs = v.reshape(n, n_subspaces, dsub).copy()
    books = []
    for _ in range(n_levels):
        level = []
        for s in range(n_subspaces):
            init = xs[rng.choice(n, size=n_centroids,
                                 replace=n < n_centroids), s]
            cent = np.asarray(_kmeans_jit(jnp.asarray(xs[:, s]),
                                          jnp.asarray(init), iters=iters))
            level.append(cent)
            dd = ((xs[:, s][:, None, :] - cent[None]) ** 2).sum(-1)
            xs[:, s] -= cent[dd.argmin(axis=1)]
        books.append(np.stack(level))
    return jnp.asarray(np.stack(books))


@jax.jit
def _encode_pq_chunk(codebooks, v):
    levels, s, kc, dsub = codebooks.shape
    n = v.shape[0]
    xs = v.reshape(n, s, dsub)
    codes = []
    for lvl in range(levels):
        bl = codebooks[lvl]                                    # [S, Kc, dsub]
        dd = (jnp.sum(xs * xs, axis=2)[:, :, None]
              + jnp.sum(bl * bl, axis=2)[None, :, :]
              - 2.0 * jnp.einsum("nsd,scd->nsc", xs, bl))
        c = jnp.argmin(dd, axis=2)                             # [n, S]
        codes.append(c)
        picked = jnp.take_along_axis(bl[None], c[:, :, None, None],
                                     axis=2)[:, :, 0, :]       # [n, S, dsub]
        xs = xs - picked
    codes = jnp.concatenate(codes, axis=1)                     # [n, S·L]
    err = jnp.sum(xs * xs, axis=(1, 2))
    dec = v.reshape(n, s, dsub) - xs                           # x̂ per subspace
    norms = jnp.sum(dec * dec, axis=(1, 2))
    return codes.astype(jnp.uint8), norms, err


def encode_pq(codebooks, vectors, chunk: int = 4096):
    """vectors [N, d] → (codes uint8 [N, S·L], norms ‖x̂‖² [N], err [N]);
    chunked over N to bound the [chunk, S, Kc] assignment intermediate."""
    v = jnp.asarray(vectors, jnp.float32)
    parts = [_encode_pq_chunk(codebooks, v[i:i + chunk])
             for i in range(0, v.shape[0], chunk)]
    return tuple(jnp.concatenate([p[i] for p in parts]) for i in range(3))


@jax.jit
def build_pq_lut(codebooks, queries):
    """Per-query inner-product ADC table [B, S·L, Kc] (slot l·S + s holds
    q_s · centroid_{l,s,c}).

    jitted: rebuilt for every probe/resume call in the serving hot path —
    un-jitted it is ~8 eager dispatches per search, which previously bit
    this suite on other many-tiny-op helpers.
    """
    levels, s, kc, dsub = codebooks.shape
    q = jnp.asarray(queries, jnp.float32)
    qs = q.reshape(q.shape[0], s, dsub)
    lut = jnp.einsum("bsd,lscd->blsc", qs, codebooks)
    return lut.reshape(q.shape[0], levels * s, kc)


def _pq_assemble(prep: PQPrep, norms, ip):
    """The PQ ADC float tail: qn + xn − 2·Σ lookups, clamped ≥ 0 — shared
    by the gathered and corpus-blocked layouts (see `_int8_assemble`)."""
    return jnp.maximum(prep.qn[:, None] + norms - 2.0 * ip, 0.0)


def adc_pq(prep: PQPrep, codes_g, norms_g):
    """Compressed squared L2 via the inner-product lookup sum.

    codes_g [B, R, S·L] int, norms_g [B, R] = gathered ‖x̂‖²:
    d̂ = ‖q‖² + ‖x̂‖² − 2·Σ_sl lut[sl, code_sl].
    """
    idx = codes_g.astype(jnp.int32).transpose(0, 2, 1)        # [B, S·L, R]
    ip = jnp.take_along_axis(prep.lut, idx, axis=2).sum(axis=1)
    return _pq_assemble(prep, norms_g, ip)


def decode_pq(index: PQIndex, codes=None):
    """codes [..., S·L] → float32 reconstruction x̂ (sum of the L level
    centroids per subspace)."""
    c = (index.codes if codes is None else codes).astype(jnp.int32)
    levels, s, kc, dsub = index.codebooks.shape
    n = c.shape[0]
    flat = index.codebooks.reshape(levels * s, kc, dsub)
    gathered = jnp.take_along_axis(
        flat[None], c[:, :, None, None], axis=2
    )[:, :, 0, :]                                              # [N, S·L, dsub]
    return gathered.reshape(n, levels, s, dsub).sum(axis=1).reshape(n, s * dsub)


def pad_rows_for_dma(arr, multiple: int = 128):
    """Zero-pad the trailing axis of a per-node row store to a lane multiple.

    The persistent traversal kernel (kernels/persistent_step.py) gathers
    node rows — float vectors, int8 codes, widened PQ codes, packed
    attribute words — straight from HBM with one async copy per row;
    padding every row to a 128-lane multiple keeps each copy a clean,
    tileable VMEM landing. Zero fill is semantics-free for every consumer:
    dot-product contractions against zero-padded queries, sliced-off PQ
    slots, and ignored attribute columns.
    """
    a = jnp.asarray(arr)
    pad = (-a.shape[-1]) % multiple
    if pad == 0:
        return a
    widths = ((0, 0),) * (a.ndim - 1) + ((0, pad),)
    return jnp.pad(a, widths, constant_values=0)


# ------------------------------------------------------------- dispatch ----
def prepare_query(precision: str, index, queries):
    """Per-search query preparation (the satellite-jitted helpers above)."""
    if precision == "int8":
        return prep_int8(index, queries)
    if precision == "pq":
        q = jnp.asarray(queries, jnp.float32)
        return PQPrep(lut=build_pq_lut(index.codebooks, q),
                      qn=jnp.sum(q * q, axis=1))
    raise ValueError(f"unknown precision {precision!r}")


def quant_dist(precision: str, qg: QuantGather):
    """[B, R] compressed squared L2 from one step's gathered codes.

    The single source of the ADC expression: the dense backend and the
    fused kernel's host path both call this, which is what makes
    dense/pallas compressed-domain parity exact by construction.
    """
    if precision == "int8":
        return adc_int8(qg.prep, qg.codes, qg.norms)
    if precision == "pq":
        return adc_pq(qg.prep, qg.codes, qg.norms)
    raise ValueError(f"unknown precision {precision!r}")


def build_quant_index(precision: str, vectors, train_sample=None, *,
                      pq_subspaces: int | None = None, pq_centroids: int = 256,
                      pq_iters: int = 20, pq_levels: int | None = None,
                      seed: int = 0):
    """Train a codec and encode the full vector store.

    train_sample: optional [n, d] subset for codec fitting (k-means /
    min-max); defaults to the full set. Encoding always covers `vectors`.
    """
    v = jnp.asarray(vectors, jnp.float32)
    t = v if train_sample is None else jnp.asarray(train_sample, jnp.float32)
    if precision == "int8":
        scale, zero = train_int8(t)
        codes, norms, err = encode_int8(scale, zero, v)
        return Int8Index(codes=codes, scale=scale, zero=zero, norms=norms,
                         err=err)
    if precision == "pq":
        d = int(v.shape[1])
        if pq_subspaces is None:
            # 4-dim subspaces by default (S·L stays well under d)
            pq_subspaces = next(s for s in (d // 4, 8, 4, 2, 1)
                                if s >= 1 and d % s == 0)
        if pq_levels is None:
            # Three residual levels: reconstruction error falls ~Kc^(2/dsub)
            # per level, and err ≈ 1e-3·‖x‖² is what keeps compressed
            # *routing* (not just the reranked pool) faithful enough for
            # matched-budget recall. S·L + 8 bytes/vec stays ≥4x under 4d.
            pq_levels = 3
        books = train_pq(t, pq_subspaces, pq_centroids, pq_iters, seed,
                         n_levels=pq_levels)
        codes, norms, err = encode_pq(books, v)
        return PQIndex(codes=codes, codebooks=books, norms=norms, err=err)
    raise ValueError(f"unknown precision {precision!r} "
                     "(expected 'int8' or 'pq')")


def codec_key(precision: str, index) -> str:
    """Stable identity string for a codec: precision tag + parameter digest.

    Hashes only the small codec parameters (scale/zero or codebooks), not
    the [N, ...] code arrays — two engines over the same corpus with the
    same trained codec collide on purpose (same answers), while a retrained
    codebook or different precision changes every cache key.
    """
    if index is None or precision == "float32":
        return "float32"
    h = hashlib.sha1()
    if isinstance(index, Int8Index):
        h.update(np.asarray(index.scale).tobytes())
        h.update(np.asarray(index.zero).tobytes())
    elif isinstance(index, PQIndex):
        h.update(np.asarray(index.codebooks).tobytes())
    else:
        raise TypeError(f"unknown quant index {type(index).__name__}")
    return f"{precision}:{h.hexdigest()[:12]}"


def index_nbytes(index) -> int:
    """Traversal-resident bytes of a quant index (codes + per-node stats +
    codec parameters) — the quantity the ≥4× memory claim is about."""
    return sum(np.asarray(leaf).nbytes for leaf in jax.tree.leaves(index))


def store_ratio(index, base_vectors) -> float:
    """How many × smaller the quant store is than the float32 vector store
    (total bytes incl. codec parameters). One definition shared by every
    surface that prints the claim (quickstart, serving launcher, bench)."""
    return np.asarray(base_vectors).nbytes / index_nbytes(index)


@jax.jit
def _compressed_dist_int8(prep, codes, norms):
    dot = jax.lax.dot_general(
        prep.qq, codes,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return _int8_assemble(prep, norms[None, :], dot)


@jax.jit
def _compressed_dist_pq(prep, codes, norms):
    idx = codes.astype(jnp.int32)                              # [Nb, S·L]
    ip = jnp.take_along_axis(
        prep.lut[:, None, :, :],                               # [B,1,SL,Kc]
        idx[None, :, :, None], axis=3)[..., 0].sum(axis=2)     # [B,Nb]
    return _pq_assemble(prep, norms[None, :], ip)


@functools.partial(jax.jit, static_argnames=("k",))
def _masked_topk(dd, valid, k):
    dd = jnp.where(valid, dd, jnp.inf)
    neg, ti = jax.lax.top_k(-dd, k)
    return -neg, jnp.where(jnp.isfinite(-neg), ti, -1)


def compressed_filtered_topk(precision: str, index, queries, valid_mask, k: int,
                             chunk: int = 128, n_block: int = 1024):
    """Brute-force compressed-domain filtered top-k (dist [B,k], idx [B,k]).

    The compressed-domain analogue of `index.bruteforce.filtered_knn_exact`:
    the best any traversal can do *before* the exact rerank. Training uses
    its distances as the convergence target on quantized engines — against
    exact float32 ground truth a compressed traversal would (rightly) never
    converge, and every W_q label would degenerate to the exhaustion cost.

    Blocked over queries (`chunk`) *and* corpus (`n_block`): the PQ lookup
    materializes a [chunk, n_block, S·L] intermediate, which unblocked
    would scale host memory with N — the same [B, N, ·] blowup the chunked
    filter-selectivity oracle exists to avoid.
    """
    q = jnp.asarray(queries, jnp.float32)
    dist_fn = (_compressed_dist_int8 if precision == "int8"
               else _compressed_dist_pq)
    n = index.codes.shape[0]
    outs_d, outs_i = [], []
    for s in range(0, q.shape[0], chunk):
        prep = prepare_query(precision, index, q[s:s + chunk])
        dd = jnp.concatenate(
            [dist_fn(prep, index.codes[b:b + n_block],
                     index.norms[b:b + n_block])
             for b in range(0, n, n_block)], axis=1)           # [B, N]
        d, i = _masked_topk(dd, jnp.asarray(valid_mask[s:s + chunk]), k)
        outs_d.append(d)
        outs_i.append(i)
    return (np.asarray(jnp.concatenate(outs_d)),
            np.asarray(jnp.concatenate(outs_i)))
