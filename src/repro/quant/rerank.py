"""Exact float32 rerank of a finished compressed-domain traversal.

Compressed distances decide *which* nodes the traversal keeps; they must
not decide the final ranking — quantization noise near the decision
boundary is exactly where recall dies. The rerank stage re-scores the
final candidate pool (result set ∪ predicate-valid candidate queue) with
exact float32 squared L2 against the retained full-precision vectors and
re-selects the top-k, so end-to-end recall degrades only when a true
neighbor never entered the pool at all — the event the candidate queue's
slack (M ≫ K) makes rare.

Cost accounting: one rerank is ≤ (M + K) float32 distance computations per
query, a *constant* independent of the traversal budget — it is not added
to `cnt` (the adaptive-termination NDC signal) and benchmarks report it
separately.

The rerank is terminal: it overwrites the result buffers with exact
distances while the candidate queue keeps compressed ones, so a reranked
state must not be resumed (the engine's probe→resume phases rerank only
after the last resume).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.distance import sqdist_bdrd


def _dedup_pool(cand_idx, cand_valid, res_idx):
    """Deduplicated candidate pool [B, P] (invalid/duplicate rows → -1)."""
    b = cand_idx.shape[0]
    pool = jnp.concatenate(
        [res_idx, jnp.where(cand_valid, cand_idx, -1)], axis=1)   # [B, P]

    # dedup (a node can sit in both buffers): sort by id, mask repeats,
    # scatter the mask back — same pattern as the pre-mode frontier dedup
    order = jnp.argsort(pool, axis=1, stable=True)
    s = jnp.take_along_axis(pool, order, axis=1)
    dup_sorted = jnp.concatenate(
        [jnp.zeros((b, 1), bool), s[:, 1:] == s[:, :-1]], axis=1)
    inv = jnp.argsort(order, axis=1, stable=True)
    dup = jnp.take_along_axis(dup_sorted, inv, axis=1)
    return jnp.where(dup, -1, pool)


def _score_pool(queries, pool, xv, k: int):
    """Exact distances over gathered pool rows → stable ascending top-k."""
    ok = pool >= 0
    dd = jnp.where(ok, sqdist_bdrd(jnp.asarray(queries, jnp.float32), xv),
                   jnp.inf)
    sel = jnp.argsort(dd, axis=1, stable=True)[:, :k]
    rd = jnp.take_along_axis(dd, sel, axis=1)
    ri = jnp.take_along_axis(pool, sel, axis=1)
    return rd, jnp.where(jnp.isfinite(rd), ri, -1)


rerank_pool = jax.jit(_dedup_pool)
score_pool = functools.partial(jax.jit, static_argnames=("k",))(_score_pool)


@functools.partial(jax.jit, static_argnames=("k",))
def exact_rerank(queries, base_vectors, cand_idx, cand_valid, res_idx, k: int):
    """Re-score the candidate pool with exact float32 distances.

    queries [B, d], base_vectors [N, d] f32, cand_idx/cand_valid [B, M],
    res_idx [B, K0] → (res_dist [B, k] ascending, res_idx [B, k]); rows
    with fewer than k valid pool entries pad with dist=+inf, idx=-1.
    """
    pool = _dedup_pool(cand_idx, cand_valid, res_idx)
    xv = base_vectors[jnp.maximum(pool, 0)]                       # [B, P, d]
    return _score_pool(queries, pool, xv, k)


def exact_rerank_store(queries, store, cand_idx, cand_valid, res_idx, k: int):
    """`exact_rerank` against a tiered vector store (quant.tiering).

    Same three stages — dedup, gather, score — but the gather goes through
    `store.gather`, which on the host tier streams only the ≤ (M + K) pool
    rows per query instead of requiring the [N, d] float32 array on device.
    The dedup and score stages are the *same jitted functions* the fused
    path runs and the gathered rows are the same bytes, so both paths
    return bit-identical (dist, idx).
    """
    pool = rerank_pool(cand_idx, cand_valid, res_idx)
    xv = store.gather(pool)
    return score_pool(jnp.asarray(queries, jnp.float32), pool, xv, k)
