"""Exact float32 rerank of a finished compressed-domain traversal.

Compressed distances decide *which* nodes the traversal keeps; they must
not decide the final ranking — quantization noise near the decision
boundary is exactly where recall dies. The rerank stage re-scores the
final candidate pool (result set ∪ predicate-valid candidate queue) with
exact float32 squared L2 against the retained full-precision vectors and
re-selects the top-k, so end-to-end recall degrades only when a true
neighbor never entered the pool at all — the event the candidate queue's
slack (M ≫ K) makes rare.

Cost accounting: one rerank is ≤ (M + K) float32 distance computations per
query, a *constant* independent of the traversal budget — it is not added
to `cnt` (the adaptive-termination NDC signal) and benchmarks report it
separately.

The rerank is terminal: it overwrites the result buffers with exact
distances while the candidate queue keeps compressed ones, so a reranked
state must not be resumed (the engine's probe→resume phases rerank only
after the last resume).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.distance import sqdist_bdrd


@functools.partial(jax.jit, static_argnames=("k",))
def exact_rerank(queries, base_vectors, cand_idx, cand_valid, res_idx, k: int):
    """Re-score the candidate pool with exact float32 distances.

    queries [B, d], base_vectors [N, d] f32, cand_idx/cand_valid [B, M],
    res_idx [B, K0] → (res_dist [B, k] ascending, res_idx [B, k]); rows
    with fewer than k valid pool entries pad with dist=+inf, idx=-1.
    """
    b = queries.shape[0]
    pool = jnp.concatenate(
        [res_idx, jnp.where(cand_valid, cand_idx, -1)], axis=1)   # [B, P]

    # dedup (a node can sit in both buffers): sort by id, mask repeats,
    # scatter the mask back — same pattern as the pre-mode frontier dedup
    order = jnp.argsort(pool, axis=1, stable=True)
    s = jnp.take_along_axis(pool, order, axis=1)
    dup_sorted = jnp.concatenate(
        [jnp.zeros((b, 1), bool), s[:, 1:] == s[:, :-1]], axis=1)
    inv = jnp.argsort(order, axis=1, stable=True)
    dup = jnp.take_along_axis(dup_sorted, inv, axis=1)
    pool = jnp.where(dup, -1, pool)

    ok = pool >= 0
    xv = base_vectors[jnp.maximum(pool, 0)]                       # [B, P, d]
    dd = jnp.where(ok, sqdist_bdrd(jnp.asarray(queries, jnp.float32), xv),
                   jnp.inf)
    sel = jnp.argsort(dd, axis=1, stable=True)[:, :k]
    rd = jnp.take_along_axis(dd, sel, axis=1)
    ri = jnp.take_along_axis(pool, sel, axis=1)
    return rd, jnp.where(jnp.isfinite(rd), ri, -1)
