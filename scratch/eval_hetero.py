import numpy as np, time, json
from repro.data import make_dataset, make_label_workload, make_range_workload
from repro.index import build_graph_index, filtered_knn_exact
from repro.index.bruteforce import recall_at_k
from repro.core import (SearchConfig, SearchEngine, BIG_BUDGET, generate_training_data,
                        CostEstimator, e2e_search, baselines)
from repro.filters.predicates import PRED_CONTAIN, PRED_EQUAL

ds = make_dataset(n=20000, dim=64, n_clusters=24, alphabet_size=48, max_labels=3, seed=0)
t0=time.time(); g = build_graph_index(ds.vectors, degree=32, seed=0)
print('build', round(time.time()-t0,1), flush=True)
eng = SearchEngine.build(ds, g)

results = {}
for kind, ptag in (('contain', PRED_CONTAIN), ('equal', PRED_EQUAL)):
    cfg = SearchConfig(k=10, queue_size=1024, pred_kind=ptag, max_steps=80000)
    wl_tr = make_label_workload(ds, batch=512, kind=kind, hard_fraction=0.5, seed=10)
    t0=time.time()
    td = generate_training_data(eng, ds, wl_tr, cfg, probe_budget=128, chunk=64)
    print(kind, 'traindata', round(time.time()-t0,1), 's; W_q pct:',
          np.percentile(td.w_q, [5,25,50,75,95,99]).round(0), 'conv', round(td.converged.mean(),3), flush=True)
    est = CostEstimator.fit(td.features, td.w_q, n_trees=300, depth=5, learning_rate=0.08, min_child=10)
    print(kind, 'train metrics:', {k: round(v,3) for k,v in est.eval_metrics(td.features, td.w_q).items()}, flush=True)

    wl = make_label_workload(ds, batch=128, kind=kind, hard_fraction=0.5, seed=99)
    gt_idx, gt_dist = filtered_knn_exact(wl.queries, ds.vectors, wl.spec, ds.labels_packed, ds.values, k=10)
    # held-out estimator metrics
    td_ev = generate_training_data(eng, ds, wl, cfg, probe_budget=128, chunk=64)
    print(kind, 'TEST metrics:', {k: round(v,3) for k,v in est.eval_metrics(td_ev.features, td_ev.w_q).items()}, flush=True)
    curves = {'e2e': [], 'naive': []}
    for alpha in (0.75, 1.0, 1.5, 2.5, 4.0):
        r = e2e_search(eng, est, cfg, wl.queries, wl.spec, probe_budget=128, alpha=alpha)
        rec = recall_at_k(np.asarray(r.state.res_idx), gt_idx).mean()
        curves['e2e'].append((float(np.asarray(r.state.cnt).mean()), float(rec)))
    for ef in (64, 128, 256, 512, 1024):
        st = baselines.naive_search(eng, cfg, wl.queries, wl.spec, ef)
        rec = recall_at_k(np.asarray(st.res_idx), gt_idx).mean()
        curves['naive'].append((float(np.asarray(st.cnt).mean()), float(rec)))
    results[kind] = curves
    print(kind, json.dumps(curves), flush=True)
print('DONE')
