import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax, jax.numpy as jnp, dataclasses, re, sys
from collections import Counter
from repro.configs import get_arch
from repro.models import build_model, split_tree
from repro.models.transformer import BlockApplier, Ctx
from repro.launch.mesh import make_production_mesh
from repro.distributed.sharding import tree_shardings
from jax.sharding import NamedSharding, PartitionSpec

mesh = make_production_mesh()
cfg = dataclasses.replace(get_arch('deepseek-v3-671b'), param_dtype=jnp.bfloat16,
                          compute_dtype=jnp.bfloat16)
model = build_model(cfg)
prm_abs = jax.eval_shape(model.init_params, jax.random.key(0))
sds, axes = split_tree(prm_abs)
bp_sds = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), sds['seg0']['pos0'])
bp_axes = jax.tree.map(lambda t: tuple(t[1:]), axes['seg0']['pos0'],
                       is_leaf=lambda x: isinstance(x, tuple) and (len(x)==0 or isinstance(x[0],(str,type(None)))))
bp_sh = tree_shardings(mesh, bp_sds, bp_axes)

B, S, D = 32, 4096, 7168   # the grad-accum micro shape
bt = model.segments[0].period[0]
def fwd(bp, x):
    applier = BlockApplier(cfg)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    ctx = Ctx(mode="train", positions=positions)
    y, _, _ = applier(bt, bp, x, ctx)
    return jnp.sum(y.astype(jnp.float32))
tgt = jax.grad(fwd, argnums=(0,1))
x_sds = jax.ShapeDtypeStruct((B, S, D), jnp.bfloat16)
x_sh = NamedSharding(mesh, PartitionSpec('data', None, None))
with mesh:
    comp = jax.jit(tgt, in_shardings=(bp_sh, x_sh)).lower(bp_sds, x_sds).compile()
txt = comp.as_text()
DT = {'f32':4,'bf16':2,'s32':4,'u32':4,'s8':1,'u8':1,'pred':1}
tot = Counter()
for line in txt.splitlines():
    m = re.search(r'=\s+(.+?)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)(-start)?\(', line)
    if not m: continue
    b = sum(int(eval(s.replace(',','*') or '1'))*DT[d] for d,s in re.findall(r'\b(f32|bf16|s32|u32|s8|u8|pred)\[([0-9,]*)\]', m.group(1)))
    opname = re.search(r'op_name="([^"]*)"', line)
    key = (m.group(2), (opname.group(1)[-60:] if opname else '?'))
    tot[key] += b
print("fwd+bwd ONE MoE block @ micro batch 32, per-device collective result-bytes:")
for (kind, op), b in tot.most_common(12):
    print(f"{b/1e9:8.2f} GB  {kind:<12} {op}")
print("TOTAL: %.1f GB" % (sum(tot.values())/1e9))
