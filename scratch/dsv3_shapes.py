import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax, jax.numpy as jnp, dataclasses, re
from collections import Counter
from repro.configs import get_arch, SHAPES
from repro.models import build_model, split_tree
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import train_batch_specs
from repro.distributed.sharding import tree_shardings
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainConfig, make_init_state, make_train_step

mesh = make_production_mesh()
cfg = dataclasses.replace(get_arch('deepseek-v3-671b'), param_dtype=jnp.bfloat16,
                          compute_dtype=jnp.bfloat16, unroll_inner=True)
model = build_model(cfg)
tc = TrainConfig(opt=AdamWConfig(moment_dtype='int8'))
state_abs = jax.eval_shape(make_init_state(model, tc), jax.random.key(0))
sds, axes = split_tree(state_abs)
sh = tree_shardings(mesh, sds, axes)
batch_sds, batch_sh = train_batch_specs(cfg, SHAPES['train_4k'], mesh)
step = make_train_step(model, tc)
with mesh:
    lowered = jax.jit(step, in_shardings=(sh, batch_sh), out_shardings=(sh, None), donate_argnums=(0,)).lower(sds, batch_sds)
compiled = lowered.compile()
ma = compiled.memory_analysis()
print('temp GB:', ma.temp_size_in_bytes/1e9)
txt = compiled.as_text()
DT = {'f32':4,'bf16':2,'s32':4,'u32':4,'s8':1,'u8':1,'pred':1,'s64':8,'u64':8}
sizes = Counter()
for m in re.finditer(r'\b(f32|bf16|s32|u32|s8|u8|pred|s64|u64)\[([0-9,]+)\]', txt):
    dims = [int(x) for x in m.group(2).split(',')]
    n = 1
    for d in dims: n *= d
    bb = n * DT[m.group(1)]
    if bb > 1e9:
        sizes[(m.group(1), m.group(2))] += 1
tot=0
for (dt, shp), cnt in sizes.most_common(15):
    dims=[int(x) for x in shp.split(',')]
    n=1
    for d in dims: n*=d
    print(f"{dt}[{shp}] x{cnt}  {n*DT[dt]/1e9:.1f} GB each")
