import numpy as np, time, json
from repro.data import make_dataset, make_label_workload
from repro.index import build_graph_index, filtered_knn_exact
from repro.index.bruteforce import recall_at_k
from repro.core import (SearchConfig, SearchEngine, BIG_BUDGET, generate_training_data,
                        CostEstimator, e2e_search, baselines)
from repro.filters.predicates import PRED_CONTAIN, PRED_EQUAL

ds = make_dataset(n=20000, dim=64, n_clusters=24, alphabet_size=48, max_labels=3, seed=0)
g = build_graph_index(ds.vectors, degree=32, seed=0)
eng = SearchEngine.build(ds, g)
print('setup done', flush=True)

for kind, ptag in (('contain', PRED_CONTAIN), ('equal', PRED_EQUAL)):
    cfg = SearchConfig(k=10, queue_size=1024, pred_kind=ptag, max_steps=80000)
    t0 = time.time()
    wl_tr = make_label_workload(ds, batch=4096, kind=kind, hard_fraction=0.5, seed=10)
    td = generate_training_data(eng, ds, wl_tr, cfg, probe_budget=128, chunk=256)
    print(kind, 'traindata', round(time.time()-t0,1), 's; W_q pct:',
          np.percentile(td.w_q, [5,50,95,99]).round(0), 'conv', round(td.converged.mean(),3), flush=True)
    est = CostEstimator.fit(td.features, td.w_q, n_trees=400, depth=6, learning_rate=0.05,
                            min_child=5, subsample=0.8)
    estq = CostEstimator.fit(td.features, td.w_q, n_trees=400, depth=6, learning_rate=0.05,
                             min_child=5, subsample=0.8, objective='quantile', tau=0.7)
    wl = make_label_workload(ds, batch=256, kind=kind, hard_fraction=0.5, seed=99)
    gt_idx, gt_dist = filtered_knn_exact(wl.queries, ds.vectors, wl.spec, ds.labels_packed, ds.values, k=10)
    td_ev = generate_training_data(eng, ds, wl, cfg, probe_budget=128, chunk=256)
    print(kind, 'TEST metrics mean-model:', {k: round(v,3) for k,v in est.eval_metrics(td_ev.features, td_ev.w_q).items()}, flush=True)
    curves = {'e2e': [], 'e2e_q': [], 'naive': [], 'oracle': []}
    for alpha in (0.75, 1.0, 1.5, 2.5, 4.0):
        r = e2e_search(eng, est, cfg, wl.queries, wl.spec, probe_budget=128, alpha=alpha)
        curves['e2e'].append((float(np.asarray(r.state.cnt).mean()),
                             float(recall_at_k(np.asarray(r.state.res_idx), gt_idx).mean())))
        r = e2e_search(eng, estq, cfg, wl.queries, wl.spec, probe_budget=128, alpha=alpha)
        curves['e2e_q'].append((float(np.asarray(r.state.cnt).mean()),
                               float(recall_at_k(np.asarray(r.state.res_idx), gt_idx).mean())))
        st = baselines.oracle_search(eng, cfg, wl.queries, wl.spec, td_ev.w_q, alpha=alpha)
        curves['oracle'].append((float(np.asarray(st.cnt).mean()),
                                float(recall_at_k(np.asarray(st.res_idx), gt_idx).mean())))
    for ef in (64, 128, 256, 512, 1024):
        st = baselines.naive_search(eng, cfg, wl.queries, wl.spec, ef)
        curves['naive'].append((float(np.asarray(st.cnt).mean()),
                               float(recall_at_k(np.asarray(st.res_idx), gt_idx).mean())))
    print(kind, json.dumps(curves), flush=True)
print('DONE')
