#!/usr/bin/env bash
# Tier-1 suite, chunked.
#
# One monolithic pytest run is flaky on this container: the process
# accumulates jit caches / forced-device subprocesses for ~10 minutes and
# trips external timeouts. Each chunk below is an independent interpreter
# with a fresh XLA, comfortably under the per-command budget, and a chunk
# failure pinpoints the layer that broke.
#
# Usage: scripts/ci.sh [extra pytest args]
set -uo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# The image ships libtpu; without this, jax may spend minutes probing for
# TPU workers before falling back to CPU (override to run on real TPUs).
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

CHUNKS=(
  "tests/test_kernels.py tests/test_property.py"
  "tests/test_filters.py"
  "tests/test_backends.py"
  "tests/test_quant.py"
  "tests/test_system.py"
  "tests/test_serve.py"
  "tests/test_planner.py"
  "tests/test_persistent.py"
  "tests/test_obs.py"
  "tests/test_obs_shard.py"
  "tests/test_distributed.py"
  "tests/test_shard.py"
  "tests/test_models_smoke.py tests/test_dryrun_small.py"
)

fail=0
for chunk in "${CHUNKS[@]}"; do
  echo "=== pytest ${chunk} ==="
  # shellcheck disable=SC2086
  python -m pytest -q ${chunk} "$@" || fail=1
done

# Serving-path smoke: the launcher must stay runnable end to end (admission →
# probe → bucket → resume → report), not just unit-tested. Shrunk bring-up
# (corpus/training) — the serving path exercised is identical and the W_q
# ground-truth labeling is the expensive part. --explain/--prometheus keep
# the observability surfaces (lifecycle timelines, calibration report,
# exposition scrape) runnable, not just unit-tested.
echo "=== serve smoke ==="
python -m repro.launch.serve --requests 8 --batch 4 \
  --corpus 2000 --train-queries 64 --explain 2 --prometheus || fail=1

# Sharded serving smoke: the same launcher on a 2-shard engine with the
# health surface — per-shard EXPLAIN attribution, shard skew gauges in the
# scrape, and the --status structured JSON report.
echo "=== serve smoke (sharded + status) ==="
python -m repro.launch.serve --requests 8 --batch 4 \
  --corpus 2000 --train-queries 64 --explain 2 --prometheus \
  --shards 2 --status || fail=1

# EXPLAIN smoke: the quickstart's per-query lifecycle reports across all
# three backends (dense / pallas / pallas_persistent) plus planner routing.
echo "=== quickstart --explain smoke ==="
python examples/quickstart.py --explain --backend dense \
  --corpus 2000 --train-queries 96 --eval-batch 16 --plan-queries 64 \
  || fail=1

# Filter-algebra smoke: composite (AND/OR/NOT) workloads end to end through
# probe → estimate → resume, recall vs the brute-force pre-filter oracle.
# --quick keeps it small and does not overwrite BENCH_filter_algebra.json.
echo "=== filter-algebra smoke ==="
python -m benchmarks.filter_algebra --quick || fail=1

# Benchmark smoke + artifact gate: runs each headline bench (quant,
# persistent, planner, serve, obs, shard) at --quick scale into a temp
# dir, then
# structurally validates both the fresh output and the committed BENCH_*.json
# artifacts (headline metric present, acceptance booleans true). Quick runs
# never scale-match the committed protocol, so no timing-noise regression
# gating happens here — run `scripts/bench_check.py --run` at full scale
# before refreshing a committed artifact.
echo "=== bench smoke + artifact check ==="
python scripts/bench_check.py --run --quick \
  quant persistent planner serve obs shard || fail=1

if [ "$fail" -ne 0 ]; then
  echo "CI: FAILURES (see chunks above)"
  exit 1
fi
echo "CI: all chunks green"
