#!/usr/bin/env python
"""Benchmark regression gate over the committed BENCH_*.json artifacts.

Each growth PR commits headline benchmark artifacts at the repo root. This
script keeps them honest in both directions:

  * **structural**: the committed artifact (and a fresh one, when present)
    must still contain its headline metric and every required boolean must
    be true — an artifact that silently lost its acceptance flags is
    treated as a failure, not a shrug;
  * **regression**: when a fresh artifact was produced under the *same
    protocol scale* as the committed one (same corpus / request counts /
    quick flag), the headline metric may not regress by more than
    --threshold (default 15%). Quick-mode runs never match the committed
    full-scale protocol, so CI's `--run --quick` sweep exercises every
    bench end to end and structurally checks its output without timing
    noise failing the build.

Usage:
    scripts/bench_check.py                      # check committed artifacts
    scripts/bench_check.py --run --quick        # fresh quick run + check
    scripts/bench_check.py --fresh-dir DIR      # compare pre-built fresh set
    scripts/bench_check.py serve persistent     # subset
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile

ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))

#: per-artifact contract: where the headline lives ("dotted.path", better
#: direction), which booleans must hold, and which protocol keys define the
#: scale (regression comparison requires them all equal)
SPECS = {
    "serve": dict(
        module="benchmarks.serve_bench",
        headline=("speedup.p99", "higher"),
        booleans=("results_bit_identical",),
        protocol="protocol",
        scale_keys=("requests", "corpus", "lane_width", "probe_budget",
                    "load", "queue_size"),
    ),
    "persistent": dict(
        module="benchmarks.persistent_bench",
        headline=("throughput.speedup", "higher"),
        booleans=("throughput.topk_identical",),
        protocol="config",
        scale_keys=("n", "dim", "degree", "batch", "queue",
                    "steps_per_launch", "quick"),
    ),
    "planner": dict(
        module="benchmarks.planner_bench",
        headline=("checks.selective_speedup_vs_traverse", "higher"),
        booleans=("checks.within_5pct_of_best_single",
                  "checks.selective_bar_ok"),
        protocol="protocol",
        scale_keys=("corpus", "train_queries", "eval_queries",
                    "probe_budget", "quick"),
    ),
    "quant": dict(
        module="benchmarks.quant_bench",
        headline=None,                      # acceptance booleans are the bar
        booleans=("acceptance.pq_memory_reduction_ge_4x",
                  "acceptance.ndc_throughput_gain",
                  "acceptance.recall_within_0p01"),
        protocol="protocol",
        scale_keys=("corpus", "dim", "train_queries", "eval_queries",
                    "quick"),
    ),
    "obs": dict(
        module="benchmarks.obs_bench",
        headline=("overhead.total_ratio", "lower"),
        booleans=("results_bit_identical", "prometheus.valid",
                  "sharded.bit_identical", "sharded.sections_sum_exact",
                  "sharded.zero_added_dispatches",
                  "drift.quiet_on_stationary", "drift.alarm_on_shift"),
        protocol="protocol",
        scale_keys=("requests", "corpus", "lane_width", "probe_budget",
                    "quick"),
    ),
    "shard": dict(
        module="benchmarks.shard_bench",
        headline=("scaling.efficiency_at_4", "higher"),
        booleans=("acceptance.results_bit_identical",
                  "acceptance.ndc_accounting_exact",
                  "acceptance.efficiency_ge_0p7"),
        protocol="protocol",
        scale_keys=("n", "dim", "degree", "batch", "budget", "precision",
                    "quick"),
    ),
}


def _get(d: dict, dotted: str):
    for part in dotted.split("."):
        if not isinstance(d, dict) or part not in d:
            return None
        d = d[part]
    return d


def check_structure(name: str, spec: dict, doc: dict, label: str) -> list:
    """Headline present + required booleans true. Returns failure strings."""
    fails = []
    if spec["headline"] is not None:
        v = _get(doc, spec["headline"][0])
        if not isinstance(v, (int, float)):
            fails.append(f"{name}[{label}]: headline "
                         f"{spec['headline'][0]} missing or non-numeric")
    for b in spec["booleans"]:
        if _get(doc, b) is not True:
            fails.append(f"{name}[{label}]: required flag {b} is "
                         f"{_get(doc, b)!r}, expected true")
    return fails


def compare(name: str, spec: dict, committed: dict, fresh: dict,
            threshold: float) -> tuple[list, str]:
    """Regression check; returns (failures, human summary line)."""
    proto_c = committed.get(spec["protocol"], {})
    proto_f = fresh.get(spec["protocol"], {})
    mismatched = [k for k in spec["scale_keys"]
                  if proto_c.get(k) != proto_f.get(k)]
    if mismatched:
        return [], (f"{name}: protocol scale differs on "
                    f"{','.join(mismatched)} — structural checks only")
    if spec["headline"] is None:
        return [], f"{name}: protocol match; boolean acceptance only"
    path, direction = spec["headline"]
    old, new = _get(committed, path), _get(fresh, path)
    if direction == "higher":
        ok, bound = new >= old * (1 - threshold), old * (1 - threshold)
    else:
        ok, bound = new <= old * (1 + threshold), old * (1 + threshold)
    line = (f"{name}: {path} committed={old:.4g} fresh={new:.4g} "
            f"({direction} is better, gate at {bound:.4g})")
    return ([] if ok else
            [f"{name}: headline {path} regressed past {threshold:.0%}: "
             f"committed {old:.4g} → fresh {new:.4g}"]), line


def run_fresh(name: str, spec: dict, out_dir: str, quick: bool) -> str:
    out = os.path.join(out_dir, f"BENCH_{name}.json")
    cmd = [sys.executable, "-m", spec["module"], "--out", out]
    if quick:
        cmd.append("--quick")
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    print(f"# running {' '.join(cmd[1:])}")
    subprocess.run(cmd, cwd=ROOT, env=env, check=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("benches", nargs="*", default=[],
                    help=f"subset of {sorted(SPECS)} (default: all with a "
                         "committed artifact)")
    ap.add_argument("--run", action="store_true",
                    help="produce fresh artifacts by running each bench")
    ap.add_argument("--quick", action="store_true",
                    help="with --run: quick protocol (structural checks "
                         "only — quick never scale-matches committed)")
    ap.add_argument("--fresh-dir", default=None,
                    help="directory holding freshly produced BENCH_*.json "
                         "to compare against the committed set")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="max allowed headline regression (fraction)")
    args = ap.parse_args()

    names = args.benches or [n for n in SPECS
                             if os.path.exists(
                                 os.path.join(ROOT, f"BENCH_{n}.json"))
                             or args.run]
    unknown = [n for n in names if n not in SPECS]
    if unknown:
        raise SystemExit(f"unknown bench(es) {unknown}; known: "
                         f"{sorted(SPECS)}")

    tmp = None
    fresh_dir = args.fresh_dir
    if args.run:
        tmp = tempfile.TemporaryDirectory(prefix="bench_check_")
        fresh_dir = tmp.name

    failures = []
    for name in names:
        spec = SPECS[name]
        committed_path = os.path.join(ROOT, f"BENCH_{name}.json")
        committed = (json.load(open(committed_path))
                     if os.path.exists(committed_path) else None)
        if committed is not None:
            failures += check_structure(name, spec, committed, "committed")
        if args.run:
            run_fresh(name, spec, fresh_dir, args.quick)
        fresh = None
        if fresh_dir:
            fp = os.path.join(fresh_dir, f"BENCH_{name}.json")
            if os.path.exists(fp):
                fresh = json.load(open(fp))
        if fresh is not None:
            failures += check_structure(name, spec, fresh, "fresh")
            if committed is not None:
                fails, line = compare(name, spec, committed, fresh,
                                      args.threshold)
                print(line)
                failures += fails
            else:
                print(f"{name}: fresh artifact structurally ok "
                      f"(no committed baseline yet)")
        elif committed is None:
            print(f"{name}: no committed or fresh artifact — skipped")
        else:
            print(f"{name}: committed artifact structurally ok "
                  f"(no fresh run to compare)")

    if failures:
        print("\nBENCH CHECK FAILURES:")
        for f in failures:
            print(f"  - {f}")
        raise SystemExit(1)
    print("bench_check: all green")


if __name__ == "__main__":
    main()
