"""Table 3 — cost-estimator accuracy on held-out queries:
Log-RMSE, R² (log space), Spearman ρ per (dataset, filter)."""
from __future__ import annotations

from benchmarks.common import Bench, eval_workload, search_cfg, PROBE
from repro.core import generate_training_data
from benchmarks.common import make_workload


def run(bench: Bench, batch=160):
    cfg = search_cfg(bench.kind)
    wl = make_workload(bench.ds, bench.kind, batch, seed=97)
    td = generate_training_data(bench.engine, bench.ds, wl, cfg,
                                probe_budget=PROBE, chunk=256)
    m = bench.estimator.eval_metrics(td.features, td.w_q)
    return [{
        "name": f"table3_{bench.preset}_{bench.kind}",
        "log_rmse": round(m["log_rmse"], 3),
        "r2": round(m["r2"], 3),
        "spearman": round(m["spearman"], 3),
        "n_eval": int(td.w_q.shape[0]),
    }]
