"""Fig. 3 — misalignment between local correlation ρ_local and global
selectivity σ_global on every dataset preset (the paper's motivation)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import make_workload
from repro.data import make_preset
from repro.index.bruteforce import knn_exact, valid_mask


def run(presets=("tripclick-s", "youtube-s", "arxiv-s", "msmarco-s"),
        batch=128, m=100):
    rows = []
    for preset in presets:
        ds = make_preset(preset)
        kind = "range" if preset == "msmarco-s" else "contain"
        wl = make_workload(ds, kind, batch, seed=31)
        nn_idx, _ = knn_exact(wl.queries, ds.vectors, m)
        ok = valid_mask(wl.spec, ds.labels_packed, ds.values)     # [B, N]
        rho_local = np.take_along_axis(ok, nn_idx, axis=1).mean(axis=1)
        sig = wl.sigma_global
        # misalignment magnitude: |log ratio| (∞-safe)
        ratio = np.log10(np.maximum(rho_local, 1e-4) / np.maximum(sig, 1e-4))
        rows.append({
            "name": f"fig3_{preset}_{kind}",
            "spearman_rho_sigma": float(_corr(rho_local, sig)),
            "mean_abs_log_ratio": float(np.abs(ratio).mean()),
            "frac_gt_10x_off": float((np.abs(ratio) > 1.0).mean()),
            "rho_local": rho_local,
            "sigma_global": sig,
        })
    return rows


def _corr(a, b):
    from repro.core.estimator import spearman

    return spearman(a, b)
