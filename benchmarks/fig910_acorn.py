"""Figs. 9/10 — generalization to PreFiltering indices (ACORN-γ, §A.3).

PreFiltering traversal keeps only predicate-valid nodes in the queue
(ρ_queue ≡ 1) and expands 1-hop ∪ strided 2-hop neighborhoods; the cost
signal moves to ρ_visited = valid/inspected. E2E-ACORN trains on pre-mode
trajectories and budget-terminates the same traversal.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from benchmarks.common import (CACHE, eval_workload, get_bench, make_workload,
                               search_cfg, PROBE)
from repro.core import CostEstimator, baselines, e2e_search, generate_training_data
from repro.core.gbdt import GBDTModel
from repro.index.bruteforce import recall_at_k


def run(preset="tripclick-s", kind="contain"):
    bench = get_bench(preset, kind)
    cfg = dataclasses.replace(search_cfg(kind), mode="pre", queue_size=512,
                              two_hop_stride=8)

    mp = os.path.join(CACHE, f"{preset}_{kind}_pre.npz")
    if os.path.exists(mp):
        est = CostEstimator(model=GBDTModel.load(mp))
    else:
        wl_tr = make_workload(bench.ds, kind, 512, seed=12)
        td = generate_training_data(bench.engine, bench.ds, wl_tr, cfg,
                                    probe_budget=PROBE, chunk=256)
        est = CostEstimator.fit(td.features, td.w_q, n_trees=300, depth=6,
                                learning_rate=0.05, min_child=5, subsample=0.8)
        est.model.save(mp)

    wl, gt_idx, _ = eval_workload(bench)
    rows = []
    for a in (1.0, 2.0, 4.0):
        r = e2e_search(bench.engine, est, cfg, wl.queries, wl.spec,
                       probe_budget=PROBE, alpha=a)
        rows.append({
            "name": f"fig910_{preset}_{kind}_e2e-acorn_a{a}",
            "recall": float(recall_at_k(np.asarray(r.state.res_idx), gt_idx).mean()),
            "ndc": float(np.asarray(r.state.cnt).mean()),
            "inspected": float(np.asarray(r.state.n_inspected).mean()),
        })
    for ef in (64, 128, 256, 512):
        st = baselines.naive_search(bench.engine, cfg, wl.queries, wl.spec, ef)
        rows.append({
            "name": f"fig910_{preset}_{kind}_acorn_ef{ef}",
            "recall": float(recall_at_k(np.asarray(st.res_idx), gt_idx).mean()),
            "ndc": float(np.asarray(st.cnt).mean()),
            "inspected": float(np.asarray(st.n_inspected).mean()),
        })
    return rows
