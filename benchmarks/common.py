"""Shared benchmark workbench: datasets, graphs, trained estimators — cached
to disk so `python -m benchmarks.run` is re-entrant and the expensive
ground-truth generation (the paper's offline one-time step, §4.3) happens
once per (dataset, filter-type)."""
from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

from repro.core import (
    BIG_BUDGET,
    CostEstimator,
    SearchConfig,
    SearchEngine,
    generate_training_data,
)
from repro.core.gbdt import GBDTModel
from repro.data import make_preset
from repro.data.synthetic import make_label_workload, make_range_workload
from repro.filters.predicates import PRED_CONTAIN, PRED_EQUAL, PRED_RANGE
from repro.index import build_graph_index, filtered_knn_exact
from repro.index.graph import GraphIndex

CACHE = os.environ.get("REPRO_BENCH_CACHE",
                       os.path.join(os.path.dirname(__file__), "..",
                                    "experiments", "bench_cache"))

PRED_OF = {"contain": PRED_CONTAIN, "equal": PRED_EQUAL, "range": PRED_RANGE}

# benchmark-scale knobs (container-scaled; see EXPERIMENTS.md §Scaling)
QUEUE = 1024
K = 10
PROBE = 128
TRAIN_QUERIES = 1536
EVAL_QUERIES = 128


def search_cfg(kind: str) -> SearchConfig:
    return SearchConfig(k=K, queue_size=QUEUE, pred_kind=PRED_OF[kind],
                        max_steps=100000)


def make_workload(ds, kind: str, batch: int, seed: int, hard_fraction=0.5):
    if kind == "range":
        return make_range_workload(ds, batch=batch, hard_fraction=hard_fraction,
                                   seed=seed)
    return make_label_workload(ds, batch=batch, kind=kind,
                               hard_fraction=hard_fraction, seed=seed)


@dataclasses.dataclass
class Bench:
    preset: str
    kind: str
    ds: object
    graph: GraphIndex
    engine: SearchEngine
    estimator: CostEstimator          # mean model (paper-faithful)
    estimator_q: CostEstimator        # τ=0.7 quantile model (beyond-paper)
    estimator_nf: CostEstimator       # trained w/o filter features (LAET abl.)
    train_data: object


def _graph_path(preset):
    return os.path.join(CACHE, f"{preset}_graph.npz")


def get_engine(preset: str, verbose=True):
    os.makedirs(CACHE, exist_ok=True)
    ds = make_preset(preset)
    gp = _graph_path(preset)
    if os.path.exists(gp):
        graph = GraphIndex.load(gp)
    else:
        t0 = time.time()
        graph = build_graph_index(ds.vectors, degree=32, seed=0)
        graph.save(gp)
        if verbose:
            print(f"# built graph for {preset} in {time.time()-t0:.0f}s")
    # backend override for apples-to-apples sweeps: REPRO_BACKEND=pallas
    return ds, graph, SearchEngine.build(ds, graph,
                                         backend=os.environ.get("REPRO_BACKEND"))


def get_bench(preset: str, kind: str, verbose=True) -> Bench:
    ds, graph, engine = get_engine(preset, verbose)
    cfg = search_cfg(kind)
    td_path = os.path.join(CACHE, f"{preset}_{kind}_train.npz")
    if os.path.exists(td_path):
        z = np.load(td_path)
        feats, w_q = z["features"], z["w_q"]
    else:
        t0 = time.time()
        wl = make_workload(ds, kind, TRAIN_QUERIES, seed=10)
        td = generate_training_data(engine, ds, wl, cfg, probe_budget=PROBE,
                                    chunk=256)
        feats, w_q = td.features, td.w_q
        np.savez_compressed(td_path, features=feats, w_q=w_q,
                            converged=td.converged)
        if verbose:
            print(f"# W_q ground truth for {preset}/{kind}: "
                  f"{time.time()-t0:.0f}s, conv={td.converged.mean():.2f}")

    ests = {}
    for variant, kwargs in (
        ("mean", dict()),
        ("q", dict(objective="quantile", tau=0.7)),
        ("nf", dict(ablate=True)),
    ):
        mp = os.path.join(CACHE, f"{preset}_{kind}_{variant}.npz")
        ablate = kwargs.pop("ablate", False)
        x = feats.copy()
        if ablate:
            from repro.core.features import FILTER_FEATURE_IDX, N_FEATURES

            for b in range(x.shape[1] // N_FEATURES):
                for ix in FILTER_FEATURE_IDX:
                    x[:, b * N_FEATURES + ix] = 0.0
        if os.path.exists(mp):
            ests[variant] = CostEstimator(model=GBDTModel.load(mp))
        else:
            est = CostEstimator.fit(x, w_q, n_trees=400, depth=6,
                                    learning_rate=0.05, min_child=5,
                                    subsample=0.8, **kwargs)
            est.model.save(mp)
            ests[variant] = est

    class _TD:
        features = feats
        w_q_ = w_q

    return Bench(preset=preset, kind=kind, ds=ds, graph=graph, engine=engine,
                 estimator=ests["mean"], estimator_q=ests["q"],
                 estimator_nf=ests["nf"], train_data=_TD)


def eval_workload(bench: Bench, seed=99, batch=EVAL_QUERIES):
    wl = make_workload(bench.ds, bench.kind, batch, seed=seed)
    gt_idx, gt_dist = filtered_knn_exact(
        wl.queries, bench.ds.vectors, wl.spec, bench.ds.labels_packed,
        bench.ds.values, K)
    return wl, gt_idx, gt_dist
