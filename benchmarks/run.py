"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = measured
per-query wall time where the benchmark is timed; 0 for accuracy-only
tables). Full JSON dumps land in experiments/bench_results.json.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _row(name, us, **derived):
    d = ";".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us:.1f},{d}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig3,fig56,table3,fig7,fig8,fig910")
    ap.add_argument("--quick", action="store_true",
                    help="smoke mode: backend-throughput benchmark only "
                         "(N=100k, B=64, warmup + best-of-3 timing)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    if args.quick:
        from benchmarks import quick

        rows = quick.run()
        for r in rows:
            _row(r["name"], r["latency_us_per_query"],
                 **{k: (round(v, 4) if isinstance(v, float) else v)
                    for k, v in r.items()
                    if k not in ("name", "latency_us_per_query")})
        out = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "bench_quick.json")
        os.makedirs(os.path.dirname(out), exist_ok=True)
        with open(out, "w") as f:
            json.dump(rows, f, indent=2, default=str)
        return

    def want(x):
        return only is None or x in only

    from benchmarks import (fig3_misalignment, fig56_tradeoff, fig7_equality,
                            fig8_importance, fig910_acorn, table3_estimator)
    from benchmarks.common import get_bench

    all_results = []
    t_start = time.time()

    if want("fig3"):
        for r in fig3_misalignment.run():
            _row(r["name"], 0.0,
                 spearman_rho_sigma=round(r["spearman_rho_sigma"], 3),
                 mean_abs_log_ratio=round(r["mean_abs_log_ratio"], 3),
                 frac_gt_10x_off=round(r["frac_gt_10x_off"], 3))
            all_results.append({k: v for k, v in r.items()
                                if k not in ("rho_local", "sigma_global")})

    bench_specs = [("tripclick-s", "contain"), ("tripclick-s", "equal"),
                   ("msmarco-s", "range")]
    benches = {}
    for preset, kind in bench_specs:
        benches[(preset, kind)] = get_bench(preset, kind)

    if want("fig56"):
        for key, bench in benches.items():
            curves = fig56_tradeoff.run(bench)
            for c in curves:
                _row(c["name"], c["latency_ms_per_query"] * 1e3,
                     recall=round(c["recall"], 4), ndc=round(c["ndc"], 1))
            all_results.extend(curves)
            for variant in ("e2e", "e2e_quantile"):
                sp = fig56_tradeoff.speedup_at_matched_recall(curves, variant)
                if sp:
                    best = max(sp.values())
                    _row(f"fig56_{key[0]}_{key[1]}_{variant}_speedup", 0.0,
                         max_ndc_speedup_vs_naive=round(best, 2),
                         at_recalls=";".join(f"{r}:{round(s,2)}"
                                             for r, s in sorted(sp.items())))

    if want("table3"):
        for key, bench in benches.items():
            for r in table3_estimator.run(bench):
                _row(r["name"], 0.0, log_rmse=r["log_rmse"], r2=r["r2"],
                     spearman=r["spearman"])
                all_results.append(r)

    if want("fig7"):
        for r in fig7_equality.run():
            _row(r["name"], 0.0, **{k: round(v, 3) for k, v in r.items()
                                    if k != "name"})
            all_results.append(r)

    if want("fig8"):
        for key, bench in benches.items():
            for r in fig8_importance.run(bench):
                _row(r["name"], 0.0,
                     filter_features_in_top8=r["filter_features_in_top8"],
                     top3=";".join(f"{n}:{round(v,2)}" for n, v in r["top8"][:3]))
                all_results.append(r)

    if want("fig910"):
        for r in fig910_acorn.run():
            _row(r["name"], 0.0, **{k: round(v, 3) for k, v in r.items()
                                    if k != "name"})
            all_results.append(r)

    out = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "bench_results.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(all_results, f, indent=2, default=str)
    print(f"# total benchmark wall time: {time.time()-t_start:.0f}s",
          file=sys.stderr)


if __name__ == "__main__":
    main()
