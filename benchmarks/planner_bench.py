"""Planner benchmark: adaptive per-query routing across filter-execution
plans, swept over filter selectivity.

For each target global selectivity σ (conjunction workloads, 0.005 → 0.5)
this runs every single-plan strategy and the planner:

  scan       pre-filter: bitmap + masked exact top-k over the valid set —
             recall 1.0 by construction, NDC = σ_q·N exactly
  traverse   the standard E2E pipeline (probe → GBDT budget → resume)
  widen      filtered-expansion traversal (1-hop ∪ strided 2-hop frontier)
  planner    two-stage per-lane routing: exact-σ stage 0 (free scan
             dispatch), shared probe + cost heads for the rest

and reports per-plan recall (vs the brute-force oracle), mean NDC, and the
planner's chosen-plan histogram per sweep point.

Acceptance bars (recorded under "checks" in BENCH_planner.json):
  * at every swept selectivity, planner mean NDC ≤ 1.05 × the best
    single plan's (routing never costs more than 5% over the per-workload
    winner it is supposed to find);
  * on the σ ≈ 0.009 conjunction workload (the filter-algebra bench's
    "and" shape), planner NDC is ≥ 10× below standard traversal at
    recall ≥ 0.93 (the crossover the planning layer exists to exploit —
    stage 0 routes these lanes to scan with zero probe overhead).

    PYTHONPATH=src python -m benchmarks.planner_bench [--quick]

--quick shrinks the world for the ci.sh smoke and does not overwrite
BENCH_planner.json (the bars are printed but only enforced at full scale —
at N=3000 the scan/traversal crossover itself shrinks below 10×).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

PLAN_NAMES = ("scan", "traverse", "widen")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--corpus", type=int, default=12000)
    ap.add_argument("--train-queries", type=int, default=384)
    ap.add_argument("--eval-queries", type=int, default=96)
    ap.add_argument("--queue-size", type=int, default=512)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--probe", type=int, default=64)
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--sweep", default="0.005,0.01,0.05,0.1,0.2,0.5",
                    help="target global selectivities (conjunctions)")
    ap.add_argument("--quick", action="store_true",
                    help="small world for the ci.sh smoke run")
    ap.add_argument("--out", default=None,
                    help="explicit output JSON path — written even with "
                         "--quick (an explicit path never clobbers the "
                         "committed artifact)")
    args = ap.parse_args()
    if args.quick:
        args.corpus, args.train_queries = 3000, 96
        args.eval_queries, args.queue_size = 24, 128
        args.sweep = "0.01,0.1,0.5"

    from repro.core import (SearchConfig, SearchEngine, fit_planner,
                            generate_plan_training_data, planned_search,
                            run_plan)
    from repro.data import make_composite_workload, make_dataset
    from repro.index import build_graph_index, filtered_knn_exact
    from repro.index.bruteforce import recall_at_k

    backend = os.environ.get("REPRO_BACKEND", "dense")
    print(f"# bring-up: corpus={args.corpus} backend={backend}")
    ds = make_dataset(n=args.corpus, dim=48, n_clusters=16, alphabet_size=48,
                      seed=0)
    graph = build_graph_index(ds.vectors, degree=24, seed=0)
    engine = SearchEngine.build(ds, graph, backend=backend)
    cfg = SearchConfig(k=args.k, queue_size=args.queue_size)

    # One planner for the whole sweep: cost heads trained on a
    # mixed-structure workload (dual-exhaustion labels for traverse AND
    # widen from one shared probe per query)
    print("# plan training data (dual exhaustion) + planner fit")
    t0 = time.time()
    wl_tr = make_composite_workload(ds, batch=args.train_queries,
                                    structure="mixed", seed=10)
    td = generate_plan_training_data(engine, ds, wl_tr, cfg,
                                     probe_budget=args.probe, chunk=96)
    planner = fit_planner(td, probe_budget=args.probe, n_trees=150, depth=5)
    print(f"#   {time.time()-t0:.0f}s, converged: "
          f"traverse={td.converged_t.mean():.2f} "
          f"widen={td.converged_w.mean():.2f}")

    def evaluate(queries, filters, gt_idx):
        """Planner + every single plan on one workload → result row."""
        auto = planned_search(engine, planner, cfg, queries, filters,
                              probe_budget=args.probe, alpha=args.alpha)
        hist = np.bincount(np.asarray(auto.plan), minlength=3)
        singles = {}
        for p in PLAN_NAMES:
            st = run_plan(engine, planner, p, cfg, queries, filters,
                          probe_budget=args.probe, alpha=args.alpha)
            singles[p] = dict(
                recall=float(recall_at_k(
                    np.asarray(st.res_idx), gt_idx).mean()),
                mean_ndc=float(np.asarray(st.cnt, np.int64).mean()))
        auto_row = dict(
            recall=float(recall_at_k(
                np.asarray(auto.state.res_idx), gt_idx).mean()),
            mean_ndc=float(np.asarray(auto.state.cnt, np.int64).mean()),
            plan_hist={PLAN_NAMES[i]: int(hist[i]) for i in range(3)},
            pre_probe_frac=float(np.asarray(auto.pre_probe).mean()))
        return dict(planner=auto_row, singles=singles)

    def range_workload(target, seed):
        """Queries from the corpus + per-query Range windows of exact width
        `target` on the empirical value CDF — selectivity controlled
        directly, which composite label leaves cannot reach at the high end
        (their σ saturates near the label marginals)."""
        from repro.filters import Range

        rng = np.random.default_rng(seed)
        src = rng.integers(0, ds.n, size=args.eval_queries)
        queries = (ds.vectors[src]
                   + 0.05 * rng.standard_normal(
                       (args.eval_queries, ds.dim)).astype(np.float32))
        vals = np.sort(ds.value_matrix[:, 0])
        exprs = []
        for _ in range(args.eval_queries):
            lo_q = rng.uniform(0.0, 1.0 - target)
            lo = float(np.quantile(vals, lo_q))
            hi = float(np.quantile(vals, lo_q + target))
            exprs.append(Range(lo, hi))
        sigma = float(np.mean([((ds.value_matrix[:, 0] >= e.lo)
                                & (ds.value_matrix[:, 0] <= e.hi)).mean()
                               for e in exprs]))
        return queries.astype(np.float32), exprs, sigma

    # ---------------------------------------------------- selectivity sweep
    sweep = tuple(float(x) for x in args.sweep.split(","))
    sweep_rows = []
    for si, target in enumerate(sweep):
        queries, exprs, sigma = range_workload(target, seed=100 + si)
        gt_idx, _ = filtered_knn_exact(queries, ds.vectors, exprs,
                                       ds.labels_packed, ds.value_matrix,
                                       args.k)
        row = dict(target_sigma=target, sigma_global_mean=sigma,
                   **evaluate(queries, exprs, gt_idx))
        best_p = min(row["singles"], key=lambda p: row["singles"][p]["mean_ndc"])
        best = row["singles"][best_p]["mean_ndc"]
        row["best_single"] = best_p
        row["planner_vs_best_ndc"] = row["planner"]["mean_ndc"] / max(best, 1.0)
        sweep_rows.append(row)
        h = row["planner"]["plan_hist"]
        print(f"σ≈{row['sigma_global_mean']:.4f} (target {target}): "
              f"planner NDC={row['planner']['mean_ndc']:.0f} "
              f"recall={row['planner']['recall']:.3f} "
              f"best single={best_p}({best:.0f}) "
              f"ratio={row['planner_vs_best_ndc']:.3f} "
              f"hist scan/trav/widen={h['scan']}/{h['traverse']}/{h['widen']}")

    # ------------------------- selective-conjunction bar (σ ≈ 0.009 shape)
    wl_sel = make_composite_workload(ds, batch=args.eval_queries,
                                     structure="and", seed=99)
    gt_sel, _ = filtered_knn_exact(wl_sel.queries, ds.vectors, wl_sel.exprs,
                                   ds.labels_packed, ds.value_matrix, args.k)
    sel = dict(sigma_global_mean=float(np.mean(wl_sel.sigma_global)),
               **evaluate(wl_sel.queries, wl_sel.filters, gt_sel))
    trav = sel["singles"]["traverse"]
    speedup = trav["mean_ndc"] / max(sel["planner"]["mean_ndc"], 1.0)
    print(f"selective conjunctions σ≈{sel['sigma_global_mean']:.4f}: "
          f"planner NDC={sel['planner']['mean_ndc']:.0f} "
          f"recall={sel['planner']['recall']:.3f} vs standard traversal "
          f"NDC={trav['mean_ndc']:.0f} → {speedup:.1f}× reduction")

    checks = dict(
        within_5pct_of_best_single=bool(
            all(r["planner_vs_best_ndc"] <= 1.05 for r in sweep_rows)),
        worst_ratio_vs_best_single=float(
            max(r["planner_vs_best_ndc"] for r in sweep_rows)),
        selective_sigma=sel["sigma_global_mean"],
        selective_speedup_vs_traverse=float(speedup),
        selective_recall=sel["planner"]["recall"],
        selective_bar_ok=bool(speedup >= 10.0
                              and sel["planner"]["recall"] >= 0.93),
    )
    print(f"# checks: {checks}")

    out = dict(
        protocol=dict(corpus=args.corpus, dim=48,
                      train_queries=args.train_queries,
                      eval_queries=args.eval_queries,
                      queue_size=args.queue_size, k=args.k,
                      probe_budget=args.probe, alpha=args.alpha,
                      backend=backend, sweep=list(sweep),
                      quick=bool(args.quick),
                      ndc_accounting="cnt includes probe distances for "
                                     "traverse/widen and for planner lanes "
                                     "that probed; scan pays none"),
        planner=dict(n_train=int(td.features.shape[0]),
                     converged_traverse=float(td.converged_t.mean()),
                     converged_widen=float(td.converged_w.mean()),
                     scan_floor=planner.scan_floor),
        sweep=sweep_rows,
        selective_conjunctions=sel,
        checks=checks,
    )
    path = args.out or os.path.join(os.path.dirname(__file__), "..",
                                    "BENCH_planner.json")
    if args.out or not args.quick:  # smoke must not clobber the artifact
        with open(path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"# wrote {os.path.normpath(path)}")
    if not args.quick:
        if not (checks["within_5pct_of_best_single"]
                and checks["selective_bar_ok"]):
            raise SystemExit("planner acceptance bars FAILED (see checks)")


if __name__ == "__main__":
    main()
