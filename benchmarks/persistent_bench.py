"""Persistent-traversal benchmark: launch amortization, dispatch floor,
state-donation savings. Recorded in BENCH_persistent.json at the repo root.

Three sections:

  throughput  end-to-end lockstep search, single-step "pallas" backend vs
              "pallas_persistent", N=100k / B=64 / heterogeneous per-lane
              NDC budgets (lognormal, median ~1200, clipped to [64, 6000] —
              the adaptive-termination regime the paper produces, where
              lanes finish at very different steps). The persistent driver
              groups steps_per_launch steps per dispatch and compacts
              finished lanes away between launches; results are asserted
              bit-identical to the single-step backend before any number is
              reported. Acceptance: ≥ 1.3× end-to-end.
  dispatch    the per-launch overhead separated from per-NDC compute. The
              dispatch floor C0 is measured by resuming a finished state
              (already-met budgets → every lane terminates on its first
              step: the call pays dispatch + state round-trip but ~no
              traversal); per-step compute is (full − C0) / steps. Launches
              per search are counted directly in the persistent driver.
  donation    run_search donates the resumed SearchState (the ~17 carry
              buffers alias in place instead of copying on every
              probe→resume / preemption slice). Measured as a chain of
              no-op resumes through the donating `run_search` vs a
              non-donating jit of the same implementation.

Honest-artifact caveats (XLA:CPU container numbers):

  * On CPU there is no persistent kernel — the driver runs the same jitted
    multi-step launch body and its win comes from (a) host-side compaction
    of terminated lanes (the CPU analogue of the TPU kernel's in-kernel
    early exit: XLA:CPU's lockstep step cost scales with batch width) and
    (b) fewer dispatch/donation round-trips. On TPU the same driver routes
    each launch to the VMEM-resident multi-step Pallas kernel
    (repro.kernels.persistent_step), where the win is launch overhead and
    HBM↔VMEM state traffic amortized over steps_per_launch steps with
    double-buffered neighbor DMA; that path's bit-parity is pinned in
    interpret mode by tests/test_persistent.py, not timed here.
  * This container's machine speed drifts by several × on a scale of
    minutes; every number is best-of-N with one untimed warmup, and the
    headline is a ratio of back-to-back measurements, not an absolute.

    PYTHONPATH=src python -m benchmarks.persistent_bench [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import numpy as np

N = 100_000
DIM = 64
DEGREE = 32
BATCH = 64
QUEUE = 512
K = 10
SPL = 8            # steps_per_launch under test
MED_BUDGET = 1200  # lognormal median of the heterogeneous budgets
CLIP = (64, 6000)
REPEATS = 3
NOOP_REPS = 10     # chain length for dispatch-floor / donation timing


def _timed(fn, repeats=REPEATS):
    import jax

    jax.block_until_ready(fn())  # warmup: compile + first run
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _world(n, batch, queue, seed=0):
    import jax.numpy as jnp

    from repro.core import SearchConfig, SearchEngine
    from repro.filters.predicates import FilterSpec, PRED_RANGE

    rng = np.random.default_rng(seed)
    vectors = rng.normal(size=(n, DIM)).astype(np.float32)
    vectors /= np.linalg.norm(vectors, axis=1, keepdims=True)
    neighbors = rng.integers(0, n, size=(n, DEGREE), dtype=np.int64)
    neighbors[neighbors == np.arange(n)[:, None]] = 0
    values = rng.random(n).astype(np.float32)
    queries = vectors[rng.integers(0, n, batch)] + 0.05 * rng.normal(
        size=(batch, DIM)).astype(np.float32)
    spec = FilterSpec(PRED_RANGE, None, np.full(batch, 0.2, np.float32),
                      np.full(batch, 0.8, np.float32))
    engine = SearchEngine(
        base_vectors=jnp.asarray(vectors),
        label_attrs=jnp.zeros((n, 1), jnp.uint32),
        value_attrs=jnp.asarray(values),
        neighbors=jnp.asarray(neighbors.astype(np.int32)),
        entry_point=0,
    )
    cfg = SearchConfig(k=K, queue_size=queue, pred_kind=PRED_RANGE,
                       steps_per_launch=SPL)
    return engine, cfg, queries, spec


def _hetero_budgets(batch, med, clip, seed=7):
    rng = np.random.default_rng(seed)
    b = rng.lognormal(mean=np.log(med), sigma=1.0, size=batch)
    return np.clip(b, *clip).astype(np.int32)


def _count_launches(fn):
    """Run fn() once while counting persistent-driver launches."""
    import repro.core.search as search_mod

    orig = search_mod._persistent_launch
    count = {"n": 0}

    def counting(*a, **k):
        count["n"] += 1
        return orig(*a, **k)

    search_mod._persistent_launch = counting
    try:
        out = fn()
    finally:
        search_mod._persistent_launch = orig
    return out, count["n"]


def run(quick=False):
    import jax
    import jax.numpy as jnp

    import repro.core.search as search_mod

    n = 16_000 if quick else N
    batch = 32 if quick else BATCH
    queue = 256 if quick else QUEUE
    med = 400 if quick else MED_BUDGET
    clip = (32, 1500) if quick else CLIP

    engine, cfg, queries, spec = _world(n, batch, queue)
    budgets = _hetero_budgets(batch, med, clip)
    out = {"config": dict(n=n, dim=DIM, degree=DEGREE, batch=batch,
                          queue=queue, k=K, steps_per_launch=SPL,
                          budget_median=med, budget_clip=list(clip),
                          quick=bool(quick),
                          jax_backend=jax.default_backend())}

    # ---- throughput: single-step vs persistent, identical budgets ----
    c_single = dataclasses.replace(cfg, backend="pallas")
    c_pers = dataclasses.replace(cfg, backend="pallas_persistent")
    st_single = engine.search(c_single, queries, spec, budgets)
    (st_pers, launches) = _count_launches(
        lambda: engine.search(c_pers, queries, spec, budgets))
    for f in st_single._fields:  # parity gate before any timing is reported
        np.testing.assert_array_equal(
            np.asarray(getattr(st_single, f)), np.asarray(getattr(st_pers, f)),
            err_msg=f"persistent/pallas diverged on {f}")
    t_single = _timed(lambda: engine.search(c_single, queries, spec, budgets))
    t_pers = _timed(lambda: engine.search(c_pers, queries, spec, budgets))
    steps = int(np.asarray(st_single.hops).max())
    lane_steps = np.asarray(st_single.hops)
    out["throughput"] = dict(
        wall_s_pallas=t_single,
        wall_s_persistent=t_pers,
        speedup=t_single / t_pers,
        steps=steps,
        launches_persistent=int(launches),
        steps_per_dispatch=steps / max(launches, 1),
        early_exit_frac=float(np.mean(lane_steps < steps)),
        mean_ndc=float(np.asarray(st_single.cnt).mean()),
        topk_identical=True,  # asserted above
    )

    # ---- dispatch floor vs per-step compute ----
    # Resuming an already-finished state makes every lane terminate on its
    # first step: the call costs dispatch + carry round-trip, ~no traversal.
    disp = {}
    for name, c in (("pallas", c_single), ("persistent", c_pers)):
        done = engine.search(c, queries, spec, budgets)

        def noop(done=done, c=c):
            st = jax.tree.map(jnp.copy, done)
            return engine.search(c, queries, spec, budgets, state=st)

        c0 = _timed(noop, NOOP_REPS)
        full = out["throughput"][f"wall_s_{'pallas' if name == 'pallas' else 'persistent'}"]
        disp[name] = dict(
            noop_resume_s=c0,
            per_step_compute_s=(full - c0) / max(steps, 1),
        )
    # the noop copy inside the timed region is common to both rows; the
    # delta between them is the launch-count difference, which is the claim
    out["dispatch"] = disp

    # ---- donation: run_search(donate) vs the same impl without donation ----
    prog = engine.compile(spec)
    attrs = engine._attrs()
    budj = jnp.broadcast_to(jnp.asarray(budgets, jnp.int32), (batch,))
    nodonate = jax.jit(search_mod._run_search_impl,
                       static_argnames=("cfg", "entry_point"))

    def _chain(fn, reps=NOOP_REPS):
        base = engine.search(c_single, queries, spec, budgets)

        def once():
            return fn(c_single, queries, prog, engine.base_vectors, attrs,
                      engine.neighbors, budj, engine.entry_point,
                      state=jax.tree.map(jnp.copy, base), gt_dist=None,
                      quant=None)

        jax.block_until_ready(once())  # warmup/compile
        best = float("inf")
        for _ in range(3):
            st = jax.tree.map(jnp.copy, base)
            jax.block_until_ready(st)
            t0 = time.perf_counter()
            for _ in range(reps):
                st = fn(c_single, queries, prog, engine.base_vectors, attrs,
                        engine.neighbors, budj, engine.entry_point, state=st,
                        gt_dist=None, quant=None)
            jax.block_until_ready(st)
            best = min(best, (time.perf_counter() - t0) / reps)
        return best

    t_don = _chain(search_mod.run_search)
    t_nodon = _chain(nodonate)
    out["donation"] = dict(
        noop_resume_s_donated=t_don,
        noop_resume_s_copying=t_nodon,
        saving_frac=1.0 - t_don / t_nodon,
        note="XLA:CPU may not alias donated host buffers, so the CPU "
             "saving can be ~0; the aliasing win lands on accelerator HBM. "
             "Donation also pins the no-accidental-copy contract that "
             "test_persistent asserts (donated carry is consumed).",
    )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small world, no artifact write (CI smoke)")
    ap.add_argument("--out", default=None,
                    help="explicit output JSON path — written even with "
                         "--quick (an explicit path never clobbers the "
                         "committed artifact)")
    args = ap.parse_args()
    out = run(quick=args.quick)
    print(json.dumps(out, indent=2))
    sp = out["throughput"]["speedup"]
    bar = 1.3
    print(f"\npersistent vs single-step: {sp:.2f}x "
          f"({'meets' if sp >= bar else 'BELOW'} the {bar}x bar)"
          + (" [quick mode: bar not enforced]" if args.quick else ""))
    path = args.out or os.path.join(os.path.dirname(__file__), "..",
                                    "BENCH_persistent.json")
    if args.out or not args.quick:  # smoke must not clobber the artifact
        with open(path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {os.path.normpath(path)}")


if __name__ == "__main__":
    main()
