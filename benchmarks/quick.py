"""`--quick` smoke benchmark: traversal-backend throughput at scale.

Times the full lockstep search (dense vs fused-Pallas backend) on a
synthetic N=100k / B=64 workload with a fixed NDC budget, so both backends
do identical graph work and the measured delta is purely the per-step hot
path (distances + queue/result merges). The graph is a random regular
digraph — navigability is irrelevant for throughput timing, and building a
real Vamana index on 100k points would dominate the smoke-run wall time.

Timing discipline (this container's CPU timings are noisy): one untimed
warmup call per backend to absorb compilation, then best-of-3 timed runs.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

N = 100_000
DIM = 64
DEGREE = 32
BATCH = 64
QUEUE = 512
K = 10
BUDGET = 4_000
REPEATS = 3


def _timed(fn):
    """Best-of-REPEATS wall time of fn() (after one warmup) + last result."""
    import jax

    jax.block_until_ready(fn())  # warmup: compile + first run
    best, out = float("inf"), None
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best, out


def run(seed: int = 0):
    import jax.numpy as jnp

    from repro.core import BIG_BUDGET, SearchConfig, SearchEngine
    from repro.filters.predicates import FilterSpec, PRED_RANGE

    rng = np.random.default_rng(seed)
    vectors = rng.normal(size=(N, DIM)).astype(np.float32)
    vectors /= np.linalg.norm(vectors, axis=1, keepdims=True)
    neighbors = rng.integers(0, N, size=(N, DEGREE), dtype=np.int64)
    neighbors[neighbors == np.arange(N)[:, None]] = 0  # drop self loops
    neighbors = neighbors.astype(np.int32)
    values = rng.random(N).astype(np.float32)

    queries = vectors[rng.integers(0, N, BATCH)] + 0.05 * rng.normal(
        size=(BATCH, DIM)).astype(np.float32)
    lo = np.full(BATCH, 0.2, np.float32)
    hi = np.full(BATCH, 0.8, np.float32)
    spec = FilterSpec(PRED_RANGE, None, lo, hi)

    engine = SearchEngine(
        base_vectors=jnp.asarray(vectors),
        label_attrs=jnp.zeros((N, 1), jnp.uint32),
        value_attrs=jnp.asarray(values),
        neighbors=jnp.asarray(neighbors),
        entry_point=0,
    )
    cfg = SearchConfig(k=K, queue_size=QUEUE, pred_kind=PRED_RANGE)

    rows = []
    states = {}
    for backend in ("dense", "pallas"):
        c = dataclasses.replace(cfg, backend=backend)
        sec, states[backend] = _timed(
            lambda: engine.search(c, queries, spec, BUDGET))
        ndc = float(np.asarray(states[backend].cnt).mean())
        rows.append({
            "name": f"quick_{backend}",
            "latency_us_per_query": sec / BATCH * 1e6,
            "wall_s": sec,
            "mean_ndc": ndc,
            "n": N, "batch": BATCH, "queue": QUEUE, "budget": BUDGET,
        })

    same = bool(np.array_equal(np.asarray(states["dense"].res_idx),
                               np.asarray(states["pallas"].res_idx)))
    speedup = rows[0]["wall_s"] / rows[1]["wall_s"]
    rows.append({"name": "quick_speedup", "latency_us_per_query": 0.0,
                 "pallas_speedup_vs_dense": speedup,
                 "topk_indices_identical": same})
    return rows
