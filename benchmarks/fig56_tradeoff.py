"""Figs. 5/6 — recall vs query latency (fig5) and vs NDC (fig6):
E2E vs Naive-HNSW-style vs the no-filter-features ablation ("w/o filter")
plus the beyond-paper quantile-budget variant and the oracle lower bound."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Bench, eval_workload, search_cfg, PROBE
from repro.core import baselines, e2e_search
from repro.index.bruteforce import recall_at_k

ALPHAS = (0.75, 1.0, 1.5, 2.5)
EFS = (64, 128, 256, 512, 1024)


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def run(bench: Bench):
    cfg = search_cfg(bench.kind)
    wl, gt_idx, _ = eval_workload(bench)
    b = wl.batch
    curves = []

    def add(variant, param, state, dt):
        rec = recall_at_k(np.asarray(state.res_idx), gt_idx).mean()
        curves.append({
            "name": f"fig56_{bench.preset}_{bench.kind}_{variant}_{param}",
            "variant": variant, "param": param,
            "recall": float(rec),
            "ndc": float(np.asarray(state.cnt).mean()),
            "latency_ms_per_query": dt / b * 1e3,
        })

    for a in ALPHAS:
        r, dt = _timed(lambda a=a: e2e_search(
            bench.engine, bench.estimator, cfg, wl.queries, wl.spec,
            probe_budget=PROBE, alpha=a))
        add("e2e", a, r.state, dt)
        r, dt = _timed(lambda a=a: e2e_search(
            bench.engine, bench.estimator_q, cfg, wl.queries, wl.spec,
            probe_budget=PROBE, alpha=a))
        add("e2e_quantile", a, r.state, dt)
        r, dt = _timed(lambda a=a: e2e_search(
            bench.engine, bench.estimator_nf, cfg, wl.queries, wl.spec,
            probe_budget=PROBE, alpha=a, ablate_filter=True))
        add("laet_nofilter", a, r.state, dt)
    for ef in EFS:
        st, dt = _timed(lambda ef=ef: baselines.naive_search(
            bench.engine, cfg, wl.queries, wl.spec, ef))
        add("naive", ef, st, dt)
    return curves


def speedup_at_matched_recall(curves, a="e2e", b="naive"):
    """NDC speedup of a's curve over b's at a's recall points (interp)."""
    ca = sorted([(c["recall"], c["ndc"]) for c in curves if c["variant"] == a])
    cb = sorted([(c["recall"], c["ndc"]) for c in curves if c["variant"] == b])
    if not ca or not cb:
        return {}
    out = {}
    rb = [r for r, _ in cb]
    nb = [n for _, n in cb]
    for r, n in ca:
        if r < rb[0] or r > rb[-1]:
            continue
        nb_interp = float(np.interp(r, rb, nb))
        out[round(r, 3)] = nb_interp / n
    return out
