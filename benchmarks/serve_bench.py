"""Serving benchmark: budget-bucketed scheduling vs fixed synchronous batches.

Protocol (container noisy-timing discipline — this machine's speed drifts
by several × on a scale of minutes, so raw wall-clock A/B comparisons
measure the machine, not the scheduler):

- One world (index + graph + mixed contain/range estimator, via
  `repro.launch.serve.build_world`), one mixed-difficulty request stream.
- A *calibrated virtual clock*: warmed-up real engine calls measure
  `busy = C0 + C1(width)·steps` (dispatch floor + lockstep trip count ×
  per-step cost; per-step cost scales ~linearly with lane width on CPU,
  which is why the batcher's width ladder matters). Both systems are then
  simulated deterministically under the same measured model, with real
  engine execution driving the scheduling decisions and results.
- Open-loop Poisson arrivals at `--load` × the baseline's model capacity,
  replayed identically against both systems — queueing delay is modeled
  honestly and identically for both.
- **fixed-batch baseline** = the scheduler with a single unbounded bucket:
  FIFO micro-batches where every lane resumes to its full Ŵ_q and easy
  lanes wait on the batch tail. Identical code path, so the measured delta
  is purely the bucket scheduling.
- **bucketed** = budget buckets fit to the offline W_q distribution (caps
  inside the cost mass — see the comment at the fitting site) under
  direct routing: each probed request goes to the bucket covering its
  Ŵ_q, so batchmates have similar remaining work (each batch's wall is
  its own cost level, not the global tail) and partial batches run at
  their natural ladder width, whose per-step cost is proportionally
  cheaper. The escalate (MLFQ) time-slicing policy remains available via
  ServeConfig(policy="escalate").

Both systems execute every request to the same predicted budget, so results
(top-k ids, distances, NDC) are bit-identical and recall is equal by
construction — enforced with hard assertions, so the bench fails rather
than record a speedup at different quality; the
benchmark reports the latency distribution delta and writes
`BENCH_serve.json` at the repo root.

    PYTHONPATH=src python -m benchmarks.serve_bench [--quick]
"""
from __future__ import annotations

import argparse
import copy
import json
import os

import numpy as np


def clone_requests(reqs):
    """Fresh lifecycle state, shared immutable payloads."""
    out = []
    for r in reqs:
        c = copy.copy(r)
        c.state = None
        c.cache_key = None  # scheduler-parameter-scoped memo
        c.budget = None
        c.executed = 0
        c.n_slices = 0
        c.probe_done = None
        c.completed = None
        c.cache_hit = False
        c.res_idx = None
        c.res_dist = None
        c.ndc = None
        out.append(c)
    return out


def simulate(make_sched, reqs, arrivals):
    """Open-loop replay on a simulated clock driven by measured service
    times. Returns (scheduler, served requests)."""
    sched = make_sched()
    reqs = clone_requests(reqs)
    n = len(reqs)
    t, i = float(arrivals[0]), 0
    pumps = 0
    while i < n or sched.has_work():
        pumps += 1
        if pumps > 100 * n:  # safety: a scheduler bug must fail, not hang
            raise RuntimeError(f"simulate stuck: t={t} i={i} "
                               f"depth={sched.depth()}")
        while i < n and arrivals[i] <= t + 1e-12:
            sched.submit(reqs[i], float(arrivals[i]))
            i += 1
        if not sched.has_work():
            t = float(arrivals[i])
            continue
        _, busy = sched.pump(t)
        if busy > 0:
            t += busy
        else:
            # every queued batch is gated on batch_wait: idle-advance to
            # the next arrival or the earliest batch deadline
            nxt = [sched.next_deadline()]
            if i < n:
                nxt.append(float(arrivals[i]))
            t = max(t, min(x for x in nxt if x is not None))
    return sched, reqs


def calibrate_service_model(engine, cfg, ds, widths, probe, queue_size):
    """Measure the engine's real cost constants per lane width.

    The lockstep per-batch cost is C0 (dispatch floor — measured by
    resuming with an already-met budget) plus trip-count × C1(width);
    C1 genuinely scales with lane width on CPU (the einsum is B-wide), so
    each width in the batcher's ladder is measured separately. Charging
    both systems by this measured model instead of the wall clock makes
    the simulation deterministic: this container's speed drifts by
    several × on a scale of minutes, which otherwise swamps any scheduling
    effect (one system's timed window lands in a fast phase, the other's
    in a slow one). min-of-N timing per constant, per the container's
    noisy-timing discipline."""
    import time as _time

    import jax
    import jax.numpy as jnp

    from repro.data import make_label_workload

    budget = probe + 8 * queue_size
    c0s, c1 = [], {}
    for w in widths:
        wl = make_label_workload(ds, batch=w, kind="contain", seed=321)
        st = engine.search(cfg, wl.queries, wl.spec, probe)
        entry_hops = np.asarray(jax.block_until_ready(st).hops)

        # search donates the resume state — each timed rep gets its own copy
        # so `st` survives the repetitions
        def noop():
            return engine.search(cfg, wl.queries, wl.spec, probe,
                                 state=jax.tree.map(jnp.copy, st))

        def run():
            return engine.search(cfg, wl.queries, wl.spec, budget,
                                 state=jax.tree.map(jnp.copy, st))

        jax.block_until_ready(noop())
        c0 = min(_timed(noop) for _ in range(5))
        c0s.append(c0)
        out = jax.block_until_ready(run())  # compile + warm
        best = min(_timed(run) for _ in range(3))
        steps = int((np.asarray(out.hops) - entry_hops).max())
        c1[w] = max(best - c0, 1e-6) / max(steps, 1)
    return float(np.median(c0s)), c1


def _timed(fn):
    import time as _time

    import jax

    t0 = _time.perf_counter()
    jax.block_until_ready(fn())
    return _time.perf_counter() - t0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=224)
    ap.add_argument("--corpus", type=int, default=12000)
    ap.add_argument("--train-queries", type=int, default=384)
    # M=512 keeps real cost heterogeneity: at small M the candidate queue
    # exhausts early and every query's step cost compresses toward the
    # same exhaustion ceiling, leaving nothing for a scheduler to separate.
    # The calibrated virtual clock makes the large-M regime affordable —
    # the engine's (slow) real CPU wall time no longer sets the measured
    # latencies, only the per-step/per-dispatch constants do.
    ap.add_argument("--queue-size", type=int, default=512)
    ap.add_argument("--lane-width", type=int, default=16)
    ap.add_argument("--alpha", type=float, default=1.5)
    ap.add_argument("--probe", type=int, default=64)
    ap.add_argument("--load", type=float, default=0.95,
                    help="offered load as a fraction of fixed-batch capacity")
    ap.add_argument("--hard-fraction", type=float, default=0.2,
                    help="fraction of anti-correlated (hard) filters; the "
                         "production-shaped default is a mostly-easy stream "
                         "with a hard tail, so nearly every fixed batch of "
                         "16 contains at least one tail lane")
    ap.add_argument("--quick", action="store_true",
                    help="small world for smoke runs")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: repo-root "
                         "BENCH_serve.json)")
    args = ap.parse_args()
    if args.quick:
        args.requests, args.corpus = 48, 4000
        args.train_queries, args.queue_size = 192, 128

    from repro.index.bruteforce import recall_at_k
    from repro.launch.serve import build_world, mixed_requests
    from repro.serve import CostAwareScheduler, ServeConfig

    print("# bring-up (index + graph + mixed-workload estimator)")
    backend = os.environ.get("REPRO_BACKEND", "dense")
    ds, graph, engine, cfg, est = build_world(
        args.corpus, args.train_queries, args.queue_size, k=10,
        probe=args.probe, backend=backend)
    reqs = mixed_requests(ds, args.requests, seed=500,
                          hard_fraction=args.hard_fraction)
    for i, r in enumerate(reqs):
        r.rid = i

    # Budget buckets fit to the offline cost distribution. Under direct
    # routing a batch's wall is the max Ŵ inside it, so caps belong inside
    # the mass — splitting the bulk from the tail shoulder — where they
    # actually separate batch walls; caps out in the tails separate
    # nothing and only fragment the queues.
    wq = np.concatenate([np.asarray(b) for b in _train_wq(engine, ds, cfg, est,
                                                          args)])
    caps = tuple(int(np.quantile(wq, q) * args.alpha) for q in (0.40, 0.70))
    caps = tuple(sorted(set(caps)))
    print(f"# bucket caps (from W_q p40/p70 × α): {caps}")

    def make(buckets, model=None, policy="direct", wait=0.0, tracer=None,
             calibration=False):
        def mk():
            # fill=True: riders take only the pad lanes of a batch's
            # natural ladder width (free — they never widen the batch),
            # giving queued hard requests clamped resume-exact progress
            return CostAwareScheduler(engine, est, cfg, ServeConfig(
                lane_width=args.lane_width, buckets=buckets, fill=True,
                policy=policy, batch_wait=wait, probe_budget=args.probe,
                alpha=args.alpha, cache_capacity=0,
                queue_capacity=10 * args.requests),
                service_model=model, tracer=tracer, calibration=calibration)
        return mk

    # measure the engine's real cost constants, then everything downstream
    # runs on the deterministic virtual clock
    widths = tuple(sorted({max(1, args.lane_width // 4),
                           max(1, args.lane_width // 2), args.lane_width}))
    print("# calibrating service model (per lane width)")
    c0, c1 = calibrate_service_model(engine, cfg, ds, widths, args.probe,
                                     args.queue_size)
    model = lambda steps, w: c0 + c1[w] * steps  # noqa: E731
    print("# model: busy = %.1f ms + steps × {%s} µs" % (
        1e3 * c0, ", ".join(f"w{w}: {1e6*v:.0f}" for w, v in c1.items())))

    # offered load calibrated against the baseline's virtual capacity
    sched, _ = simulate(make((None,), model), reqs, np.zeros(len(reqs)))
    capacity = len(reqs) / sched.summary()["busy_time"]
    rate = args.load * capacity
    rng = np.random.default_rng(9)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=len(reqs)))
    # both systems get the same anti-fragmentation dispatch gate: a partial
    # batch may wait about half a lane-fill interval for batchmates
    wait = 0.5 * args.lane_width / rate
    print(f"# capacity ≈ {capacity:.1f} req/s → offered {rate:.1f} req/s, "
          f"batch_wait={1e3*wait:.0f} ms")

    rows = {}
    served = {}
    # virtual-clock runs are deterministic — one round each suffices
    for name, mk in (("fixed_batch", make((None,), model, wait=wait)),
                     ("bucketed", make(caps + (None,), model, wait=wait))):
        sched, done = simulate(mk, reqs, arrivals)
        s = sched.summary()
        rows[name], served[name] = s, done
        lat = s["latency"]
        print(f"{name}: p50/p95/p99 = {1e3*lat['p50']:.0f}/"
              f"{1e3*lat['p95']:.0f}/{1e3*lat['p99']:.0f} ms  "
              f"busy={s['busy_time']:.2f}s batches={s['n_batches']} "
              f"requeues={s['n_requeues']}")
        for ph, d in sorted(s["batches_by_phase"].items()):
            print(f"#   {ph}: n={d['n']} fill={d['mean_fill']:.1f} "
                  f"busy={d['busy']:.2f}s")

    # equal results / equal recall by construction — enforced, not assumed:
    # a scheduler change that breaks resume-exactness must fail the bench,
    # not publish a speedup at silently different quality
    by_rid = {r.rid: r for r in served["fixed_batch"]}
    identical = all(
        np.array_equal(by_rid[r.rid].res_idx, r.res_idx)
        and np.array_equal(by_rid[r.rid].res_dist, r.res_dist)
        and by_rid[r.rid].ndc == r.ndc
        for r in served["bucketed"])
    assert identical, "bucketed results diverged from fixed-batch"
    recall = {}
    gt = _ground_truth(ds, reqs, k=cfg.k)
    for name, done in served.items():
        idx = np.stack([r.res_idx for r in sorted(done, key=lambda x: x.rid)])
        recall[name] = float(recall_at_k(idx, gt).mean())
    assert recall["fixed_batch"] == recall["bucketed"], recall
    speedup = {q: rows["fixed_batch"]["latency"][q] /
                  max(rows["bucketed"]["latency"][q], 1e-12)
               for q in ("p50", "p95", "p99")}
    print(f"results_bit_identical={identical} recall={recall}")
    print(f"speedup p50/p95/p99 = {speedup['p50']:.2f}x/"
          f"{speedup['p95']:.2f}x/{speedup['p99']:.2f}x")

    # -- observability arm: the winning system, fully observed ------------
    # Same virtual-clock replay with lifecycle tracing + calibration on:
    # results must stay bit-identical to the untraced bucketed run and the
    # charged latency distribution must not regress (spans wrap host
    # dispatch points only, so on the virtual clock the p99 ratio is
    # exactly 1.0 — any drift means tracing leaked into scheduling).
    from repro.obs import Tracer, validate_prometheus

    tracer = Tracer()
    sched_obs, done_obs = simulate(
        make(caps + (None,), model, wait=wait, tracer=tracer,
             calibration=True), reqs, arrivals)
    by_rid_b = {r.rid: r for r in served["bucketed"]}
    obs_identical = all(
        np.array_equal(by_rid_b[r.rid].res_idx, r.res_idx)
        and np.array_equal(by_rid_b[r.rid].res_dist, r.res_dist)
        and by_rid_b[r.rid].ndc == r.ndc
        for r in done_obs)
    assert obs_identical, "traced run diverged from untraced bucketed"
    s_obs = sched_obs.summary()
    p99_ratio = (s_obs["latency"]["p99"] /
                 max(rows["bucketed"]["latency"]["p99"], 1e-12))
    assert p99_ratio < 1.05, f"traced p99 regressed {p99_ratio:.3f}x"
    calib = sched_obs.calibration_report()
    n_scrape = sum(validate_prometheus(sched_obs.prometheus()).values())
    print(f"observability: traced bit-identical, p99 ratio "
          f"{p99_ratio:.3f}x, {tracer.n_emitted} spans, "
          f"{calib['n_records']} calibration records, "
          f"{n_scrape} prometheus samples")

    out = dict(
        protocol=dict(requests=args.requests, corpus=args.corpus,
                      lane_width=args.lane_width, alpha=args.alpha,
                      probe_budget=args.probe, load=args.load,
                      hard_fraction=args.hard_fraction, backend=backend,
                      queue_size=args.queue_size, bucket_caps=list(caps),
                      arrivals="poisson", batch_wait=wait,
                      service_model=dict(
                          c0_seconds=c0,
                          c1_seconds_by_width={str(w): v
                                               for w, v in c1.items()}),
                      timing="calibrated virtual clock: busy = C0 + "
                             "C1(width)*steps, constants measured on "
                             "warmed-up real engine calls per lane width"),
        fixed_batch=rows["fixed_batch"],
        bucketed=rows["bucketed"],
        speedup=speedup,
        recall=recall,
        results_bit_identical=bool(identical),
        observability=dict(
            traced_bit_identical=bool(obs_identical),
            p99_ratio=float(p99_ratio),
            n_spans=int(tracer.n_emitted),
            calibration=dict(n_records=calib["n_records"],
                             log_rmse=calib["log_rmse"],
                             overprediction_rate=calib["overprediction_rate"],
                             per_plan=calib["per_plan"]),
            prometheus_samples=int(n_scrape),
        ),
    )
    path = args.out or os.path.join(os.path.dirname(__file__), "..",
                                    "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {os.path.normpath(path)}")


def _train_wq(engine, ds, cfg, est, args):
    """Offline W_q samples for bucket fitting — reuse the estimator's own
    training distribution by re-predicting on a held-out mixed workload
    (cheap: probe only, no exhaustion)."""
    from repro.core import probe_and_features
    from repro.core.e2e import predict_budgets
    from repro.data import make_label_workload, make_range_workload

    out = []
    for kind in ("contain", "range"):
        wl = (make_label_workload(ds, batch=96, kind=kind, seed=77,
                                  hard_fraction=args.hard_fraction)
              if kind == "contain" else
              make_range_workload(ds, batch=96, seed=78,
                                  hard_fraction=args.hard_fraction))
        _, z = probe_and_features(engine, cfg, wl.queries, wl.spec, args.probe)
        budgets, _ = predict_budgets(est, z, 1.0)
        out.append(np.asarray(budgets))
    return out


def _ground_truth(ds, reqs, k: int):
    from repro.index import filtered_knn_exact

    order = sorted(reqs, key=lambda r: r.rid)
    exprs = [r.expr for r in order]  # any mix of filter structures
    q = np.stack([r.query for r in order])
    idx, _ = filtered_knn_exact(q, ds.vectors, exprs, ds.labels_packed,
                                ds.value_matrix, k)
    gt = np.zeros((len(order), k), np.int64)
    for r, row in zip(order, idx):
        gt[r.rid] = row
    return gt


if __name__ == "__main__":
    main()
