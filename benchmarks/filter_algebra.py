"""Filter-algebra benchmark: composite (AND / OR / NOT) filtered-AKNN
workloads end-to-end through the E2E pipeline.

For each boolean structure the compiled predicate programs unlock
(conjunction, disjunction, negation, and a heterogeneous mix), this runs
the full probe → estimate → resume pipeline — one GBDT trained once on a
mixed-structure workload serves every shape, its per-clause probe
selectivities (rho_clause_* features) included — and reports:

  recall        vs the exact filtered top-k (brute-force oracle)
  mean/p95 NDC  adaptive per-query cost actually spent
  oracle NDC    the brute-force *pre-filter* baseline's cost: scanning the
                valid set exactly costs one distance per valid item, i.e.
                σ_global·N NDC per query — the classic pre-filter strategy
                every filtered-ANNS paper benchmarks against
  latency       wall µs/query, warmup + best-of-3 (container noisy-timing
                discipline)

Writes BENCH_filter_algebra.json at the repo root.

Known limits (recorded, not hidden): the pre-filter oracle's cost is
σ_global·N, so at this container-scaled corpus (N ≈ 10⁴) ultra-selective
conjunctions (σ ≈ 1%, ≈100 valid items) are genuinely cheaper to brute-force
— the crossover the filtered-ANNS literature consistently reports. The
graph path wins where the valid set is large relative to the traversal
(negation / disjunction / mixed shapes here, and everything at the paper's
N ≥ 10⁶ scale, where σ·N is 100× larger while NDC grows far slower).
Conjunctions also show the lowest convergence rate in training (filtered
sub-graph disconnection, the paper's PreFiltering pathology), which caps
their recall at matched α.

    PYTHONPATH=src python -m benchmarks.filter_algebra [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

STRUCTURES = ("and", "or", "not", "mixed")


def _timed(fn, repeats=3):
    import jax

    jax.block_until_ready(fn())  # warmup: compile + first run
    best, out = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out.state.res_idx)
        best = min(best, time.perf_counter() - t0)
    return best, out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--corpus", type=int, default=12000)
    ap.add_argument("--train-queries", type=int, default=384)
    ap.add_argument("--eval-queries", type=int, default=96)
    ap.add_argument("--queue-size", type=int, default=512)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--probe", type=int, default=64)
    ap.add_argument("--alphas", default="1.0,1.5")
    ap.add_argument("--quick", action="store_true",
                    help="small world for the ci.sh smoke run")
    args = ap.parse_args()
    if args.quick:
        args.corpus, args.train_queries = 3000, 96
        args.eval_queries, args.queue_size = 32, 128

    from repro.core import (CostEstimator, SearchConfig, SearchEngine,
                            e2e_search, generate_training_data)
    from repro.data import make_composite_workload, make_dataset
    from repro.index import build_graph_index, filtered_knn_exact
    from repro.index.bruteforce import recall_at_k

    backend = os.environ.get("REPRO_BACKEND", "dense")
    print(f"# bring-up: corpus={args.corpus} backend={backend}")
    ds = make_dataset(n=args.corpus, dim=48, n_clusters=16, alphabet_size=48,
                      seed=0)
    graph = build_graph_index(ds.vectors, degree=24, seed=0)
    engine = SearchEngine.build(ds, graph, backend=backend)
    cfg = SearchConfig(k=args.k, queue_size=args.queue_size)

    # One estimator for every boolean structure: trained on the mixed
    # workload so the GBDT sees conjunctions, disjunctions, negations, and
    # bare leaves — the per-clause rho features carry the structure signal.
    print("# W_q ground truth + estimator (mixed-structure training set)")
    t0 = time.time()
    wl_tr = make_composite_workload(ds, batch=args.train_queries,
                                    structure="mixed", seed=10)
    td = generate_training_data(engine, ds, wl_tr, cfg,
                                probe_budget=args.probe, chunk=96)
    est = CostEstimator.fit(td.features, td.w_q, n_trees=150, depth=5)
    print(f"#   {time.time()-t0:.0f}s, converged={td.converged.mean():.2f}")

    alphas = tuple(float(x) for x in args.alphas.split(","))
    results = {}
    for structure in STRUCTURES:
        wl = make_composite_workload(ds, batch=args.eval_queries,
                                     structure=structure, seed=99)
        gt_idx, _ = filtered_knn_exact(wl.queries, ds.vectors, wl.exprs,
                                       ds.labels_packed, ds.value_matrix,
                                       args.k)
        oracle_ndc = float(np.mean(wl.sigma_global) * ds.n)
        rows = []
        for alpha in alphas:
            sec, r = _timed(lambda a=alpha: e2e_search(
                engine, est, cfg, wl.queries, wl.exprs,
                probe_budget=args.probe, alpha=a))
            ndc = np.asarray(r.state.cnt)
            rec = recall_at_k(np.asarray(r.state.res_idx), gt_idx)
            rows.append(dict(
                alpha=alpha,
                recall=float(rec.mean()),
                mean_ndc=float(ndc.mean()),
                p95_ndc=float(np.percentile(ndc, 95)),
                latency_us_per_query=sec / wl.batch * 1e6,
                ndc_vs_prefilter=float(oracle_ndc / max(ndc.mean(), 1.0)),
            ))
            print(f"{structure:6s} α={alpha}: recall={rows[-1]['recall']:.3f} "
                  f"NDC={rows[-1]['mean_ndc']:.0f} "
                  f"(pre-filter oracle {oracle_ndc:.0f} → "
                  f"{rows[-1]['ndc_vs_prefilter']:.1f}× fewer) "
                  f"{rows[-1]['latency_us_per_query']:.0f} µs/q")
        results[structure] = dict(
            sigma_global_mean=float(np.mean(wl.sigma_global)),
            prefilter_oracle_ndc=oracle_ndc,   # recall 1.0 by construction
            e2e=rows,
        )

    out = dict(
        protocol=dict(corpus=args.corpus, dim=48,
                      train_queries=args.train_queries,
                      eval_queries=args.eval_queries,
                      queue_size=args.queue_size, k=args.k,
                      probe_budget=args.probe, backend=backend,
                      alphas=list(alphas), quick=bool(args.quick),
                      baseline="brute-force pre-filter: exact scan of the "
                               "valid set, NDC = sigma_global * N, "
                               "recall = 1.0",
                      timing="warmup + best-of-3 wall time"),
        estimator=dict(n_train=int(td.features.shape[0]),
                       converged=float(td.converged.mean())),
        results=results,
    )
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_filter_algebra.json")
    if not args.quick:  # the smoke run must not clobber the real artifact
        with open(path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"# wrote {os.path.normpath(path)}")


if __name__ == "__main__":
    main()
